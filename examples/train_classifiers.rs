//! Full classifier-comparison pipeline (paper §IV-A/B, Fig. 4).
//!
//! Generates (or loads) the labeled layer corpus, trains all 12
//! classifiers over multiple train/test splits, prints the accuracy
//! ranking, and deploys the best model to `data/adaboost.json`.
//!
//! ```bash
//! cargo run --release --example train_classifiers            # medium grid, 5 seeds
//! S2SWITCH_FULL=1 cargo run --release --example train_classifiers  # 16k grid, 20 seeds
//! ```

use s2switch::coordinator::{dataset_cached, train_and_save_adaboost, train_roster};
use s2switch::dataset::SweepConfig;
use std::path::PathBuf;

fn main() -> anyhow::Result<()> {
    let full = std::env::var_os("S2SWITCH_FULL").is_some();
    let (cfg, seeds, cache) = if full {
        (SweepConfig::default(), 20, "data/dataset.csv")
    } else {
        (SweepConfig::medium(), 5, "data/dataset_medium.csv")
    };
    println!(
        "corpus: {} layers ({}); seeds: {seeds}",
        cfg.n_layers(),
        if full { "the paper's full 16k grid" } else { "medium grid — set S2SWITCH_FULL=1 for 16k" }
    );

    let dataset = dataset_cached(&PathBuf::from(cache), &cfg)?;
    let n_parallel = dataset.samples.iter().filter(|s| s.parallel_pes < s.serial_pes).count();
    println!(
        "labels: {} favor parallel, {} favor serial\n",
        n_parallel,
        dataset.len() - n_parallel
    );

    println!("training 12 classifiers × {seeds} seeds…");
    let t0 = std::time::Instant::now();
    let scores = train_roster(&dataset, seeds);
    println!("trained in {:.1?}\n", t0.elapsed());

    let mut ranked: Vec<_> = scores.iter().collect();
    ranked.sort_by(|a, b| b.mean().partial_cmp(&a.mean()).unwrap());
    println!("{:<22} {:>8} {:>8} {:>8}   (paper Fig. 4: AdaBoost best at 91.69%)", "classifier", "mean", "min", "max");
    println!("{}", "-".repeat(64));
    for s in &ranked {
        println!(
            "{:<22} {:>7.2}% {:>7.2}% {:>7.2}%",
            s.name,
            100.0 * s.mean(),
            100.0 * s.min(),
            100.0 * s.max()
        );
    }

    let model_path = PathBuf::from("data/adaboost.json");
    let acc = train_and_save_adaboost(&dataset, 150, &model_path)?;
    println!(
        "\ndeployed AdaBoost → {} (held-out accuracy {:.2}%)",
        model_path.display(),
        100.0 * acc
    );
    Ok(())
}
