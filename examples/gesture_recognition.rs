//! The paper's §IV-C case study: "the gesture recognition SNN model with
//! 2048-20-4 structure and 3.16% weight density … needs 9 PEs on the serial
//! paradigm, 5 PEs on the parallel paradigm, and only 4 PEs by deploying the
//! switching system."
//!
//! We rebuild the same topology/density synthetically and compare the three
//! systems under whole-machine accounting (layer PEs + source hosting —
//! see `switching::network_pe_count`). Absolute counts differ from the
//! paper's 9/5/4 (its compiler internals are unpublished); the *ordering*
//! — serial > parallel > switching — is the reproduced claim.
//!
//! ```bash
//! cargo run --release --example gesture_recognition
//! ```

use s2switch::dataset::{generate_grid, SweepConfig};
use s2switch::hardware::PeSpec;
use s2switch::model::connector::{Connector, SynapseDraw};
use s2switch::model::{LifParams, Network, NetworkBuilder, PopulationId};
use s2switch::paradigm::parallel::WdmConfig;
use s2switch::rng::Rng;
use s2switch::sim::BatchRunner;
use s2switch::switching::{network_pe_count, SwitchMode, SwitchingSystem};

const DENSITY: f64 = 0.0316;
const DELAY: u16 = 1; // DVS gesture SNNs use single-step delays

fn gesture_net() -> Network {
    let mut b = NetworkBuilder::new(2048);
    let input = b.spike_source("dvs-input", 2048);
    let hidden = b.lif_population("hidden", 20, LifParams { alpha: 0.9, ..Default::default() });
    let output = b.lif_population("classes", 4, LifParams::default());
    let draw = SynapseDraw { delay_range: DELAY, w_max: 100, ..Default::default() };
    b.project(input, hidden, Connector::FixedProbability(DENSITY), draw, 0.01);
    b.project(hidden, output, Connector::FixedProbability(0.5), draw, 0.05);
    b.build()
}

fn main() -> anyhow::Result<()> {
    let pe = PeSpec::default();
    println!("gesture model: 2048-20-4, density {:.2}%, delay {DELAY}", DENSITY * 100.0);

    // Train the prejudger (the deployed switching system).
    let dataset = generate_grid(&SweepConfig::medium(), &pe, WdmConfig::default());
    let mut results = Vec::new();
    for (label, mut system) in [
        ("serial   ", SwitchingSystem::new(SwitchMode::ForceSerial, pe)),
        ("parallel ", SwitchingSystem::new(SwitchMode::ForceParallel, pe)),
        ("switching", SwitchingSystem::train_adaboost(&dataset, 100, pe)),
    ] {
        let net = gesture_net();
        let (layers, _) = system.compile_network(&net)?;
        let total = network_pe_count(&net, &layers, &pe);
        let detail: Vec<String> = layers
            .iter()
            .map(|l| format!("{}:{}", l.paradigm(), l.n_pes()))
            .collect();
        println!(
            "  {label} → {total:>2} PEs   (layers: {}, source hosting: {})",
            detail.join(", "),
            s2switch::switching::source_hosting_pes(&net, &layers, &pe),
        );
        results.push((label.trim().to_string(), total));
    }

    let serial = results[0].1;
    let parallel = results[1].1;
    let switching = results[2].1;
    println!("\npaper reports 9 / 5 / 4; this reproduction: {serial} / {parallel} / {switching}");
    anyhow::ensure!(
        serial > parallel && parallel >= switching,
        "ordering serial > parallel ≥ switching must hold"
    );
    println!("ordering serial > parallel ≥ switching reproduced ✓");

    // Batched inference on the deployed (switching) compile: a gesture
    // classifier serves streams of samples, so run a batch through the
    // BatchRunner and report per-sample throughput.
    const SAMPLES: usize = 8;
    const STEPS: u64 = 200;
    let net = gesture_net();
    let mut deployed = SwitchingSystem::train_adaboost(&dataset, 100, pe);
    let (layers, _) = deployed.compile_network(&net)?;
    let provider_for = |sample: usize| {
        let mut rng = Rng::new(31_000 + sample as u64);
        move |_p: PopulationId, _t: u64, out: &mut Vec<u32>| {
            out.extend((0..2048u32).filter(|_| rng.chance(0.05)));
        }
    };
    println!("\nbatched inference: {SAMPLES} samples × {STEPS} steps on the switching compile");
    let run = BatchRunner::new(&net, layers)?.run(SAMPLES, STEPS, provider_for);
    for (i, rec) in run.recorders.iter().enumerate() {
        println!(
            "  sample {i}: {:>4} class spikes in {:.3}s",
            rec.spike_count(PopulationId(2)),
            run.sample_nanos[i] as f64 / 1e9,
        );
    }
    println!(
        "  {} worker(s): {:.0} steps/s | {:.2} Mevents/s | {:.2} MMAC/s (issued)",
        run.jobs,
        run.steps_per_sec(),
        run.events_per_sec() / 1e6,
        run.macs_per_sec() / 1e6,
    );
    Ok(())
}
