//! End-to-end driver: the full three-layer stack on a real small workload.
//!
//! Pipeline: label corpus → train AdaBoost prejudger → compile a
//! gesture-class SNN (2048-20-4 @ 3.16%) with fast switching → run a
//! **batch of synthetic DVS-like samples** through the
//! [`BatchRunner`](s2switch::sim::BatchRunner), verifying the batched path
//! is bit-identical at any worker count and reporting per-sample
//! throughput. With `--features pjrt` (and `make artifacts`) an extra
//! single-sim pass cross-checks every spike against the AOT-compiled
//! JAX/Pallas artifact running through PJRT.
//!
//! ```bash
//! cargo run --release --example e2e_inference
//! ```

use s2switch::dataset::{generate_grid, SweepConfig};
use s2switch::hardware::PeSpec;
use s2switch::model::connector::{Connector, SynapseDraw};
use s2switch::model::{LifParams, Network, NetworkBuilder, PopulationId};
use s2switch::paradigm::parallel::WdmConfig;
use s2switch::rng::Rng;
use s2switch::sim::BatchRunner;
use s2switch::switching::{network_pe_count, SwitchingSystem};

const STEPS: u64 = 500;
const N_INPUT: usize = 2048;
const SAMPLES: usize = 8;

fn build_net() -> Network {
    let mut b = NetworkBuilder::new(2048);
    let input = b.spike_source("dvs-input", N_INPUT);
    let hidden = b.lif_population("hidden", 20, LifParams { alpha: 0.9, ..Default::default() });
    let output = b.lif_population("classes", 4, LifParams { alpha: 0.95, ..Default::default() });
    let draw = SynapseDraw { delay_range: 1, w_max: 100, ..Default::default() };
    b.project(input, hidden, Connector::FixedProbability(0.0316), draw, 0.012);
    b.project(hidden, output, Connector::FixedProbability(0.5), draw, 0.08);
    b.build()
}

/// Synthetic DVS-like stimulus: a moving bump of activity over the 2048
/// input neurons plus background noise (deterministic per sample seed),
/// filled into the caller-owned buffer — steady state allocates nothing.
fn stimulus(t: u64, rng: &mut Rng, out: &mut Vec<u32>) {
    let center = ((t as f64 * 13.7) as usize) % N_INPUT;
    out.extend((0..N_INPUT as u32).filter(|&i| {
        let dist = (i as i64 - center as i64).unsigned_abs() as usize;
        let dist = dist.min(N_INPUT - dist);
        let p = if dist < 100 { 0.25 } else { 0.01 };
        rng.chance(p)
    }));
    out.dedup();
}

fn provider_for(sample: usize) -> impl FnMut(PopulationId, u64, &mut Vec<u32>) {
    let mut rng = Rng::new(424242 + sample as u64);
    move |_p: PopulationId, t: u64, out: &mut Vec<u32>| stimulus(t, &mut rng, out)
}

fn main() -> anyhow::Result<()> {
    let pe = PeSpec::default();

    println!("── fast-switching compile ──");
    let dataset = generate_grid(&SweepConfig::medium(), &pe, WdmConfig::default());
    let mut system = SwitchingSystem::train_adaboost(&dataset, 100, pe);
    let net = build_net();
    let (layers, _) = system.compile_network(&net)?;
    for (i, l) in layers.iter().enumerate() {
        let ch = l.character();
        println!(
            "layer {i}: {:>4}×{:<3} d={:.3} delay={} → {:8} {} PEs, {} B",
            ch.n_source,
            ch.n_target,
            ch.density,
            ch.delay_range,
            l.paradigm().to_string(),
            l.n_pes(),
            l.total_dtcm()
        );
    }
    println!(
        "whole machine: {} PEs | compiles run: {} (ideal needs {})",
        network_pe_count(&net, &layers, &pe),
        system.stats.total_compiles(),
        2 * layers.len()
    );

    // ── batched native inference ─────────────────────────────────────────
    println!("\n── batch: {SAMPLES} DVS samples × {STEPS} steps (native MAC) ──");
    let runner = BatchRunner::new(&net, layers.clone())?;
    let seq = runner.run(SAMPLES, STEPS, provider_for); // jobs resolved to CPUs
    for (i, rec) in seq.recorders.iter().enumerate() {
        println!(
            "sample {i}: hidden={:>4} classes={:>3} spikes in {:.3}s",
            rec.spike_count(PopulationId(1)),
            rec.spike_count(PopulationId(2)),
            seq.sample_nanos[i] as f64 / 1e9,
        );
    }
    println!(
        "batch on {} worker(s): {:.3}s wall | {:.0} steps/s | {:.2} Mevents/s | {:.2} MMAC/s",
        seq.jobs,
        seq.wall_nanos as f64 / 1e9,
        seq.steps_per_sec(),
        seq.events_per_sec() / 1e6,
        seq.macs_per_sec() / 1e6,
    );

    // Worker-count invariance: single worker must reproduce every sample.
    let single = BatchRunner::new(&net, layers.clone())?
        .with_jobs(1)
        .run(SAMPLES, STEPS, provider_for);
    anyhow::ensure!(
        single.recorders == seq.recorders,
        "BatchRunner output must be identical at any worker count"
    );
    println!("✓ batch output identical at jobs=1 and jobs={}", seq.jobs);

    // Class histogram of sample 0 — the "inference result" of the workload.
    let mut hist = [0usize; 4];
    for &(_, n) in seq.recorders[0].spikes_of(PopulationId(2)) {
        hist[n as usize] += 1;
    }
    println!("sample 0 class spike histogram: {hist:?}");

    pjrt_crosscheck(&net, layers, &seq.recorders[0])?;
    Ok(())
}

/// PJRT pass: rerun sample 0 through the AOT JAX/Pallas MAC artifact and
/// demand bit-identical spike trains against the batched native run.
#[cfg(feature = "pjrt")]
fn pjrt_crosscheck(
    net: &Network,
    layers: Vec<s2switch::switching::CompiledLayer>,
    native: &s2switch::sim::Recorder,
) -> anyhow::Result<()> {
    use s2switch::runtime::{artifact_dir, PjrtMac, PjrtRuntime};
    use std::cell::RefCell;
    use std::rc::Rc;
    use std::time::Instant;

    println!("\n── simulate sample 0 × {STEPS} steps (PJRT: AOT JAX/Pallas MAC kernel) ──");
    let rt = Rc::new(RefCell::new(PjrtRuntime::new(artifact_dir())?));
    let mut sim =
        s2switch::sim::NetworkSim::new(net, layers, || Box::new(PjrtMac::new(rt.clone())))?;
    let mut provider = provider_for(0);
    let t0 = Instant::now();
    sim.run(STEPS, &mut provider);
    let secs = t0.elapsed().as_secs_f64();
    println!("pjrt: {:.3}s ({:.0} steps/s)", secs, STEPS as f64 / secs);
    anyhow::ensure!(&sim.recorder == native, "PJRT and native spike trains must be identical");
    println!("✓ PJRT and native spike trains identical ({} spikes)", native.total_spikes());
    Ok(())
}

#[cfg(not(feature = "pjrt"))]
fn pjrt_crosscheck(
    _net: &Network,
    _layers: Vec<s2switch::switching::CompiledLayer>,
    _native: &s2switch::sim::Recorder,
) -> anyhow::Result<()> {
    println!("\n(built without the `pjrt` feature — skipping the PJRT cross-check)");
    Ok(())
}
