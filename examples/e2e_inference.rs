//! End-to-end driver: the full three-layer stack on a real small workload.
//!
//! Pipeline: label corpus → train AdaBoost prejudger → compile a
//! gesture-class SNN (2048-20-4 @ 3.16%) with fast switching → simulate
//! 500 timesteps of synthetic DVS-like input where the parallel layers'
//! MAC matmuls execute through the **AOT-compiled JAX/Pallas artifact via
//! PJRT** — and cross-check every spike against the pure-native run.
//!
//! Reports: per-layer paradigm choice, PE/DTCM footprint, spike counts,
//! wall-clock throughput for both backends. Recorded in EXPERIMENTS.md §E2E.
//!
//! ```bash
//! make artifacts && cargo run --release --example e2e_inference
//! ```

use s2switch::dataset::{generate_grid, SweepConfig};
use s2switch::hardware::PeSpec;
use s2switch::model::connector::{Connector, SynapseDraw};
use s2switch::model::{LifParams, Network, NetworkBuilder, PopulationId};
use s2switch::paradigm::parallel::WdmConfig;
use s2switch::rng::Rng;
use s2switch::runtime::{artifact_dir, PjrtMac, PjrtRuntime};
use s2switch::sim::NetworkSim;
use s2switch::switching::{network_pe_count, SwitchingSystem};
use std::cell::RefCell;
use std::rc::Rc;
use std::time::Instant;

const STEPS: u64 = 500;
const N_INPUT: usize = 2048;

fn build_net() -> Network {
    let mut b = NetworkBuilder::new(2048);
    let input = b.spike_source("dvs-input", N_INPUT);
    let hidden = b.lif_population("hidden", 20, LifParams { alpha: 0.9, ..Default::default() });
    let output = b.lif_population("classes", 4, LifParams { alpha: 0.95, ..Default::default() });
    let draw = SynapseDraw { delay_range: 1, w_max: 100, ..Default::default() };
    b.project(input, hidden, Connector::FixedProbability(0.0316), draw, 0.012);
    b.project(hidden, output, Connector::FixedProbability(0.5), draw, 0.08);
    b.build()
}

/// Synthetic DVS-like stimulus: a moving bump of activity over the 2048
/// input neurons plus background noise (deterministic).
fn stimulus(t: u64, rng: &mut Rng) -> Vec<u32> {
    let center = ((t as f64 * 13.7) as usize) % N_INPUT;
    let mut spikes: Vec<u32> = (0..N_INPUT as u32)
        .filter(|&i| {
            let dist = (i as i64 - center as i64).unsigned_abs() as usize;
            let dist = dist.min(N_INPUT - dist);
            let p = if dist < 100 { 0.25 } else { 0.01 };
            rng.chance(p)
        })
        .collect();
    spikes.dedup();
    spikes
}

fn main() -> anyhow::Result<()> {
    let pe = PeSpec::default();

    println!("── fast-switching compile ──");
    let dataset = generate_grid(&SweepConfig::medium(), &pe, WdmConfig::default());
    let mut system = SwitchingSystem::train_adaboost(&dataset, 100, pe);
    let net = build_net();
    let (layers, _) = system.compile_network(&net)?;
    for (i, l) in layers.iter().enumerate() {
        let ch = l.character();
        println!(
            "layer {i}: {:>4}×{:<3} d={:.3} delay={} → {:8} {} PEs, {} B",
            ch.n_source,
            ch.n_target,
            ch.density,
            ch.delay_range,
            l.paradigm().to_string(),
            l.n_pes(),
            l.total_dtcm()
        );
    }
    println!(
        "whole machine: {} PEs | compiles run: {} (ideal needs {})",
        network_pe_count(&net, &layers, &pe),
        system.stats.total_compiles(),
        2 * layers.len()
    );

    // Native run.
    println!("\n── simulate {STEPS} steps (native MAC) ──");
    let run = |use_pjrt: bool| -> anyhow::Result<(Vec<(u64, u32)>, Vec<(u64, u32)>, f64, u64)> {
        let net = build_net();
        let mut sys2 = SwitchingSystem::train_adaboost(&dataset, 100, pe);
        let (layers, _) = sys2.compile_network(&net)?;
        let mut sim = if use_pjrt {
            let rt = Rc::new(RefCell::new(PjrtRuntime::new(artifact_dir())?));
            NetworkSim::new(&net, layers, || Box::new(PjrtMac::new(rt.clone())))?
        } else {
            NetworkSim::native(&net, layers)?
        };
        let mut rng = Rng::new(424242);
        let mut provider = move |_p: PopulationId, t: u64| stimulus(t, &mut rng);
        let t0 = Instant::now();
        sim.run(STEPS, &mut provider);
        let secs = t0.elapsed().as_secs_f64();
        let events = sim.recorder.total_spikes() as u64;
        Ok((
            sim.recorder.spikes_of(PopulationId(1)).to_vec(),
            sim.recorder.spikes_of(PopulationId(2)).to_vec(),
            secs,
            events,
        ))
    };

    let (hid_n, out_n, secs_native, _) = run(false)?;
    println!(
        "native: {:.3}s ({:.0} steps/s) | spikes hidden={} classes={}",
        secs_native,
        STEPS as f64 / secs_native,
        hid_n.len(),
        out_n.len()
    );

    println!("\n── simulate {STEPS} steps (PJRT: AOT JAX/Pallas MAC kernel) ──");
    let (hid_p, out_p, secs_pjrt, _) = run(true)?;
    println!(
        "pjrt:   {:.3}s ({:.0} steps/s) | spikes hidden={} classes={}",
        secs_pjrt,
        STEPS as f64 / secs_pjrt,
        hid_p.len(),
        out_p.len()
    );

    anyhow::ensure!(hid_n == hid_p && out_n == out_p, "backends must agree bit-exactly");
    println!("\n✓ PJRT and native spike trains identical ({} + {} spikes)", hid_n.len(), out_n.len());

    // Class histogram — the "inference result" of the workload.
    let mut hist = [0usize; 4];
    for &(_, n) in &out_n {
        hist[n as usize] += 1;
    }
    println!("class spike histogram: {hist:?}");
    Ok(())
}
