//! Quickstart: build an SNN, train a prejudger, compile with fast
//! switching, and simulate — the whole public API in one file.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use s2switch::dataset::{generate_grid, SweepConfig};
use s2switch::hardware::PeSpec;
use s2switch::model::connector::{Connector, SynapseDraw};
use s2switch::model::{LifParams, NetworkBuilder, PopulationId};
use s2switch::paradigm::parallel::WdmConfig;
use s2switch::rng::Rng;
use s2switch::sim::NetworkSim;
use s2switch::switching::SwitchingSystem;

fn main() -> anyhow::Result<()> {
    // 1. Acquire a labeled corpus (medium grid: 640 layers, ~seconds) and
    //    train the AdaBoost prejudger — the paper's fast-switching tool.
    println!("① labeling 640-layer corpus (both paradigms per layer)…");
    let dataset = generate_grid(&SweepConfig::medium(), &PeSpec::default(), WdmConfig::default());
    let mut system = SwitchingSystem::train_adaboost(&dataset, 100, PeSpec::default());
    println!("   trained AdaBoost prejudger on {} layers", dataset.len());

    // 2. Describe an SNN.
    let mut b = NetworkBuilder::new(7);
    let input = b.spike_source("input", 300);
    let hidden = b.lif_population("hidden", 200, LifParams { alpha: 0.9, ..Default::default() });
    let output = b.lif_population("output", 10, LifParams::default());
    b.project(
        input,
        hidden,
        Connector::FixedProbability(0.8), // dense → parallel-friendly
        SynapseDraw { delay_range: 2, w_max: 100, ..Default::default() },
        0.01,
    );
    b.project(
        hidden,
        output,
        Connector::FixedProbability(0.15), // sparse → serial-friendly
        SynapseDraw { delay_range: 12, w_max: 100, ..Default::default() },
        0.03,
    );
    let net = b.build();

    // 3. Compile: the classifier prejudges each layer — one compile each,
    //    no double compilation.
    println!("② compiling with classifier switching…");
    let (layers, pes) = system.compile_network(&net)?;
    for (i, l) in layers.iter().enumerate() {
        let ch = l.character();
        println!(
            "   layer {i}: {}×{} density {:.2} delay {:>2} → {:8} ({} PEs, {} B DTCM)",
            ch.n_source,
            ch.n_target,
            ch.density,
            ch.delay_range,
            l.paradigm().to_string(),
            l.n_pes(),
            l.total_dtcm()
        );
    }
    println!(
        "   total: {pes} PEs, {} paradigm compilations (ideal switching would need {})",
        system.stats.total_compiles(),
        2 * layers.len()
    );

    // 4. Simulate 100 timesteps with Poisson-ish input.
    println!("③ simulating 100 timesteps…");
    let mut sim = NetworkSim::native(&net, layers)?;
    let mut rng = Rng::new(123);
    let mut provider = move |_pop: PopulationId, _t: u64, out: &mut Vec<u32>| {
        out.extend((0..300u32).filter(|_| rng.chance(0.1)));
    };
    sim.run(100, &mut provider);
    println!(
        "   spikes: hidden {} | output {}",
        sim.recorder.spike_count(PopulationId(1)),
        sim.recorder.spike_count(PopulationId(2))
    );
    println!("done.");
    Ok(())
}
