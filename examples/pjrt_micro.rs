//! Micro-measurement of PJRT matvec dispatch cost per bucket (perf pass).
use s2switch::runtime::{artifact_dir, PjrtMac, PjrtRuntime};
use s2switch::sim::backend::MacBackend;
use std::cell::RefCell;
use std::rc::Rc;
use std::time::Instant;

fn main() -> anyhow::Result<()> {
    let rt = Rc::new(RefCell::new(PjrtRuntime::new(artifact_dir())?));
    let mut mac = PjrtMac::new(rt);
    for &(r, c) in &[(256usize, 256usize), (2048, 256), (8192, 256)] {
        let stacked = vec![1.0f32; r];
        let weights = vec![1.0f32; r * c];
        mac.matvec(&stacked, &weights, r, c); // warm (compile + weight upload)
        let t0 = Instant::now();
        let n = 50;
        for _ in 0..n {
            std::hint::black_box(mac.matvec(&stacked, &weights, r, c));
        }
        println!("bucket {r}x{c}: {:?}/call", t0.elapsed() / n);
    }
    Ok(())
}
