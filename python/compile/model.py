"""L2 JAX model: one parallel-paradigm timestep of an SNN layer.

Composes the L1 Pallas kernels — MAC-array matvec over the stacked spike
vector and weight-delay-map, then the LIF neural update — into the fused
computation the rust coordinator executes per timestep through PJRT. This
file is build-time only; it is lowered once by ``aot.py`` and never imported
at runtime.
"""

from .kernels.lif_update import lif_step
from .kernels.mac_matmul import mac_matvec


def model_step(stacked, weights, v, alpha, v_th, *, n_rows, n_cols):
    """One fused layer timestep; returns ``(v_next, spiked)``.

    * ``stacked``  f32[n_rows]        — stacked spike lanes (source x delay)
    * ``weights``  f32[n_rows, n_cols] — optimized weight-delay-map chunk
    * ``v``        f32[n_cols]        — membrane potentials
    * ``alpha``/``v_th``              — LIF scalars (traced)
    """
    current = mac_matvec(stacked, weights, n_rows=n_rows, n_cols=n_cols)
    return lif_step(v, current, alpha, v_th, n=n_cols)


def matvec_only(stacked, weights, *, n_rows, n_cols):
    """The bare MAC matvec (the ``mac_matvec_RxC`` artifacts)."""
    return (mac_matvec(stacked, weights, n_rows=n_rows, n_cols=n_cols),)
