"""AOT export: lower the L2 model (with its L1 Pallas kernels) to HLO text.

Run once at build time (``make artifacts``); the rust runtime loads the
emitted ``artifacts/*.hlo.txt`` via ``HloModuleProto::from_text_file`` and
executes them on the PJRT CPU client.

HLO *text* (not ``.serialize()``) is the interchange format: jax >= 0.5
emits protos with 64-bit instruction ids which the ``xla`` crate's
xla_extension 0.5.1 rejects; the text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md).

Artifacts (shape buckets mirrored in ``rust/src/runtime/pjrt.rs``):
  mac_matvec_256x256 / 2048x256 / 8192x256   (stacked, wdm) -> (current,)
  lif_step_256                               (v, i, alpha, v_th) -> (v', z)
  model_step_2048x256                        fused matvec + LIF
"""

import argparse
import functools
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .kernels.lif_update import lif_step
from .model import matvec_only, model_step

# Must match rust/src/runtime/pjrt.rs.
MATVEC_BUCKETS = [(256, 256), (2048, 256), (8192, 256)]
LIF_BUCKET = 256
MODEL_BUCKET = (2048, 256)


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def f32(*dims):
    return jax.ShapeDtypeStruct(dims, jnp.float32)


def emit(out_dir: str, name: str, fn, *specs) -> None:
    lowered = jax.jit(fn).lower(*specs)
    text = to_hlo_text(lowered)
    path = os.path.join(out_dir, f"{name}.hlo.txt")
    with open(path, "w") as f:
        f.write(text)
    print(f"  wrote {path} ({len(text)} chars)")


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--out-dir", default="../artifacts")
    args = parser.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    for rows, cols in MATVEC_BUCKETS:
        emit(
            args.out_dir,
            f"mac_matvec_{rows}x{cols}",
            functools.partial(matvec_only, n_rows=rows, n_cols=cols),
            f32(rows),
            f32(rows, cols),
        )

    n = LIF_BUCKET
    emit(
        args.out_dir,
        f"lif_step_{n}",
        functools.partial(lif_step, n=n),
        f32(n),
        f32(n),
        f32(),
        f32(),
    )

    rows, cols = MODEL_BUCKET
    emit(
        args.out_dir,
        f"model_step_{rows}x{cols}",
        functools.partial(model_step, n_rows=rows, n_cols=cols),
        f32(rows),
        f32(rows, cols),
        f32(cols),
        f32(),
        f32(),
    )
    print("AOT export complete.")


if __name__ == "__main__":
    main()
