"""L1 Pallas kernel: the SpiNNaker2 MAC-array matvec.

The parallel paradigm's hot-spot (paper §III-B): a subordinate PE multiplies
the stacked spike vector against its optimized weight-delay-map chunk on the
4x16 MAC array.

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the MAC array consumes
operands aligned to its 4x16 geometry from a 128 kB SRAM; the Pallas
analogue tiles the contraction dimension in ROW_BLOCK = 32 lanes (a multiple
of the 16-lane input side) and keeps each weight tile in VMEM under the same
96 kB DTCM budget the Table I cost model enforces:

    ROW_BLOCK x C_max x 4 B = 32 x 512 x 4 = 64 kB  <  96 kB.

The kernel MUST be lowered with interpret=True: the CPU PJRT plugin cannot
execute Mosaic custom-calls (real-TPU lowering); interpret mode lowers to
plain HLO that the rust runtime's CPU client runs. Real-TPU efficiency is
estimated analytically in DESIGN.md §Perf.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Contraction tile: multiple of the MAC array's 16-lane input side, sized so
# a weight tile fits the 96 kB DTCM-analogue VMEM budget (see module doc).
ROW_BLOCK = 32


def _matvec_kernel(s_ref, w_ref, o_ref):
    """One grid step: accumulate s[block] . W[block, :] into the output."""

    @pl.when(pl.program_id(0) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(s_ref[...], w_ref[...])


@functools.partial(jax.jit, static_argnames=("n_rows", "n_cols"))
def mac_matvec(stacked, weights, *, n_rows, n_cols):
    """``out[c] = sum_r stacked[r] * weights[r, c]`` on the MAC-array tiling.

    ``n_rows`` must be a multiple of ``ROW_BLOCK`` (the AOT shape buckets
    are); ``n_cols`` is consumed whole per tile.
    """
    if n_rows % ROW_BLOCK != 0:
        raise ValueError(f"n_rows={n_rows} not a multiple of ROW_BLOCK={ROW_BLOCK}")
    grid = (n_rows // ROW_BLOCK,)
    return pl.pallas_call(
        _matvec_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((ROW_BLOCK,), lambda i: (i,)),
            pl.BlockSpec((ROW_BLOCK, n_cols), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((n_cols,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((n_cols,), jnp.float32),
        interpret=True,
    )(stacked, weights)
