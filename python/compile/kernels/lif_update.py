"""L1 Pallas kernel: the LIF neural update (paper Eq. 1).

    V^{t+1} = I + alpha * V^t - z * V_th

with z = [I + alpha*V >= V_th] (subtractive reset). Elementwise over the
population; one VMEM tile holds the whole 256-neuron bucket (256 x 4 B x 2
operands = 2 kB, far under budget). The semantics mirror
``rust/src/model/lif.rs::lif_step`` exactly (zero-refractory path — the
compiled artifact targets inference-time populations with t_refrac = 0;
refractory handling stays on the coordinator).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _lif_kernel(v_ref, i_ref, alpha_ref, vth_ref, v_out_ref, spike_ref):
    alpha = alpha_ref[0]
    v_th = vth_ref[0]
    v_new = i_ref[...] + alpha * v_ref[...]
    spiked = (v_new >= v_th).astype(jnp.float32)
    v_out_ref[...] = v_new - spiked * v_th
    spike_ref[...] = spiked


@functools.partial(jax.jit, static_argnames=("n",))
def lif_step(v, current, alpha, v_th, *, n):
    """One LIF step over ``n`` neurons; returns ``(v_next, spiked)``.

    ``alpha``/``v_th`` are traced scalars so one artifact serves any
    parameterization.
    """
    alpha_v = jnp.reshape(alpha.astype(jnp.float32), (1,))
    vth_v = jnp.reshape(v_th.astype(jnp.float32), (1,))
    return pl.pallas_call(
        _lif_kernel,
        out_shape=(
            jax.ShapeDtypeStruct((n,), jnp.float32),
            jax.ShapeDtypeStruct((n,), jnp.float32),
        ),
        interpret=True,
    )(v, current, alpha_v, vth_v)
