"""Pure-jnp correctness oracles for the Pallas kernels.

These are the CORE correctness signal: pytest asserts the Pallas kernels
match these references exactly (the operands are integer-valued, so even
float accumulation is exact), and the rust engines mirror the same
semantics (``rust/src/model/lif.rs``, ``rust/src/sim/*``).
"""

import jax.numpy as jnp


def mac_matvec_ref(stacked, weights):
    """out[c] = sum_r stacked[r] * weights[r, c]."""
    return jnp.dot(stacked, weights)


def lif_step_ref(v, current, alpha, v_th):
    """Paper Eq. 1 with subtractive reset; returns (v_next, spiked)."""
    v_new = current + alpha * v
    spiked = (v_new >= v_th).astype(jnp.float32)
    return v_new - spiked * v_th, spiked


def model_step_ref(stacked, weights, v, alpha, v_th):
    """Fused timestep: MAC matvec then LIF update."""
    current = mac_matvec_ref(stacked, weights)
    return lif_step_ref(v, current, alpha, v_th)
