"""Pallas kernels vs pure-jnp oracles — the core L1 correctness signal.

Hypothesis sweeps shapes and integer-valued operands (the production regime:
spike counts x quantized weights), asserting exact agreement with ref.py.
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.lif_update import lif_step
from compile.kernels.mac_matmul import ROW_BLOCK, mac_matvec
from compile.kernels import ref

settings.register_profile("kernels", max_examples=25, deadline=None)
settings.load_profile("kernels")


def rand_state(seed):
    return np.random.default_rng(seed)


# ---------------------------------------------------------------- mac_matvec


@given(
    blocks=st.integers(min_value=1, max_value=8),
    cols=st.integers(min_value=1, max_value=64),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_matvec_matches_ref_integer_exact(blocks, cols, seed):
    rng = rand_state(seed)
    rows = blocks * ROW_BLOCK
    # Integer-valued f32: spike counts 0..3, signed 8-bit weights.
    s = rng.integers(0, 4, rows).astype(np.float32)
    w = rng.integers(-127, 128, (rows, cols)).astype(np.float32)
    got = mac_matvec(jnp.asarray(s), jnp.asarray(w), n_rows=rows, n_cols=cols)
    want = ref.mac_matvec_ref(jnp.asarray(s), jnp.asarray(w))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@given(
    blocks=st.integers(min_value=1, max_value=4),
    cols=st.integers(min_value=1, max_value=32),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_matvec_matches_ref_float_close(blocks, cols, seed):
    rng = rand_state(seed)
    rows = blocks * ROW_BLOCK
    s = rng.standard_normal(rows).astype(np.float32)
    w = rng.standard_normal((rows, cols)).astype(np.float32)
    got = mac_matvec(jnp.asarray(s), jnp.asarray(w), n_rows=rows, n_cols=cols)
    want = ref.mac_matvec_ref(jnp.asarray(s), jnp.asarray(w))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


def test_matvec_zero_input_gives_zeros():
    rows, cols = 2 * ROW_BLOCK, 16
    out = mac_matvec(jnp.zeros(rows), jnp.ones((rows, cols)), n_rows=rows, n_cols=cols)
    np.testing.assert_array_equal(np.asarray(out), np.zeros(cols, np.float32))


def test_matvec_rejects_unaligned_rows():
    with pytest.raises(ValueError, match="ROW_BLOCK"):
        mac_matvec(jnp.zeros(10), jnp.zeros((10, 4)), n_rows=10, n_cols=4)


def test_matvec_bucket_shapes_compile():
    # The exact AOT bucket shapes (keep the small ones; 8192 is slow under
    # interpret mode and is covered by the rust integration test).
    for rows, cols in [(256, 256), (2048, 256)]:
        s = jnp.ones(rows)
        w = jnp.ones((rows, cols))
        out = mac_matvec(s, w, n_rows=rows, n_cols=cols)
        np.testing.assert_array_equal(np.asarray(out), np.full(cols, rows, np.float32))


# ------------------------------------------------------------------ lif_step


@given(
    n=st.integers(min_value=1, max_value=300),
    alpha=st.floats(min_value=0.0, max_value=1.0, width=32),
    v_th=st.floats(min_value=0.125, max_value=5.0, width=32),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_lif_matches_ref(n, alpha, v_th, seed):
    rng = rand_state(seed)
    v = rng.uniform(-1, 1, n).astype(np.float32)
    cur = rng.uniform(-2, 2, n).astype(np.float32)
    a = jnp.float32(alpha)
    t = jnp.float32(v_th)
    got_v, got_z = lif_step(jnp.asarray(v), jnp.asarray(cur), a, t, n=n)
    want_v, want_z = ref.lif_step_ref(jnp.asarray(v), jnp.asarray(cur), a, t)
    np.testing.assert_allclose(np.asarray(got_v), np.asarray(want_v), rtol=1e-6, atol=1e-6)
    np.testing.assert_array_equal(np.asarray(got_z), np.asarray(want_z))


def test_lif_subtractive_reset_matches_rust_semantics():
    # Mirrors rust/src/model/lif.rs::threshold_crossing_spikes: v=0.5,
    # input=0.8, alpha=0.9 -> v_new=1.25 >= 1.0 -> spike, reset to 0.25.
    v_next, z = lif_step(
        jnp.asarray([0.5]), jnp.asarray([0.8]), jnp.float32(0.9), jnp.float32(1.0), n=1
    )
    assert float(z[0]) == 1.0
    np.testing.assert_allclose(float(v_next[0]), 0.25, rtol=1e-6)


def test_lif_subthreshold_decays():
    v_next, z = lif_step(
        jnp.asarray([0.5]), jnp.asarray([0.0]), jnp.float32(0.9), jnp.float32(1.0), n=1
    )
    assert float(z[0]) == 0.0
    np.testing.assert_allclose(float(v_next[0]), 0.45, rtol=1e-6)
