"""L2 model + AOT export tests: fused step vs oracle, HLO emission."""

import os
import tempfile

import numpy as np
import jax
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile import aot
from compile.kernels import ref
from compile.kernels.mac_matmul import ROW_BLOCK
from compile.model import model_step

settings.register_profile("model", max_examples=10, deadline=None)
settings.load_profile("model")


@given(
    blocks=st.integers(min_value=1, max_value=4),
    cols=st.integers(min_value=1, max_value=48),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_model_step_matches_ref(blocks, cols, seed):
    rng = np.random.default_rng(seed)
    rows = blocks * ROW_BLOCK
    s = rng.integers(0, 3, rows).astype(np.float32)
    w = rng.integers(-50, 51, (rows, cols)).astype(np.float32) * 0.01
    v = rng.uniform(-0.5, 0.5, cols).astype(np.float32)
    a = jnp.float32(0.9)
    t = jnp.float32(1.0)
    got_v, got_z = model_step(
        jnp.asarray(s), jnp.asarray(w), jnp.asarray(v), a, t, n_rows=rows, n_cols=cols
    )
    want_v, want_z = ref.model_step_ref(jnp.asarray(s), jnp.asarray(w), jnp.asarray(v), a, t)
    np.testing.assert_allclose(np.asarray(got_v), np.asarray(want_v), rtol=1e-5, atol=1e-6)
    np.testing.assert_array_equal(np.asarray(got_z), np.asarray(want_z))


def test_hlo_text_emission_roundtrips_through_parser():
    # Lower the smallest matvec bucket and sanity-check the HLO text.
    import functools
    from compile.model import matvec_only

    lowered = jax.jit(functools.partial(matvec_only, n_rows=256, n_cols=256)).lower(
        aot.f32(256), aot.f32(256, 256)
    )
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text
    assert "f32[256,256]" in text
    # return_tuple=True -> tuple root.
    assert "tuple" in text


def test_aot_main_writes_all_artifacts(monkeypatch):
    with tempfile.TemporaryDirectory() as d:
        monkeypatch.setattr(
            "sys.argv", ["aot", "--out-dir", d]
        )
        # Shrink the expensive buckets for test speed; the full set is
        # exercised by `make artifacts`.
        monkeypatch.setattr(aot, "MATVEC_BUCKETS", [(64, 32)])
        monkeypatch.setattr(aot, "MODEL_BUCKET", (64, 32))
        monkeypatch.setattr(aot, "LIF_BUCKET", 32)
        aot.main()
        names = sorted(os.listdir(d))
        assert names == [
            "lif_step_32.hlo.txt",
            "mac_matvec_64x32.hlo.txt",
            "model_step_64x32.hlo.txt",
        ]
        for n in names:
            with open(os.path.join(d, n)) as f:
                assert "HloModule" in f.read()
