#!/usr/bin/env python3
"""Validate a regenerated BENCH_*.json baseline.

The committed baselines are schema placeholders whose measured fields are
null (the authoring environment has no Rust toolchain); the *-baseline CI
jobs regenerate them by running the bench binaries. Before this check, a
bench that silently failed to measure (or a schema drift that left the
placeholder untouched) would upload a null-filled artifact that passes CI.

Usage:
    check_bench_json.py FILE REQUIRED_KEY [REQUIRED_KEY ...]

REQUIRED_KEY may be a dotted path (e.g. "artifact.speedup") to require a
key nested inside objects, not just at the top level.

Fails (exit 1) if:
  * FILE is missing or not valid JSON;
  * any REQUIRED_KEY (dotted path) is absent;
  * any value anywhere in the document is null;
  * the placeholder marker key "status" is still present (the bench binary
    never writes it, so its survival means the file was not regenerated).
"""

import json
import sys


def find_nulls(node, path="$"):
    """Yield JSON paths of every null value under node."""
    if node is None:
        yield path
    elif isinstance(node, dict):
        for key, value in node.items():
            yield from find_nulls(value, f"{path}.{key}")
    elif isinstance(node, list):
        for i, value in enumerate(node):
            yield from find_nulls(value, f"{path}[{i}]")


def has_path(doc, dotted):
    """True iff the dotted key path resolves through nested objects."""
    node = doc
    for part in dotted.split("."):
        if not isinstance(node, dict) or part not in node:
            return False
        node = node[part]
    return True


def main(argv):
    if len(argv) < 3:
        print(f"usage: {argv[0]} FILE REQUIRED_KEY [REQUIRED_KEY ...]", file=sys.stderr)
        return 2
    path, required = argv[1], argv[2:]
    try:
        with open(path, encoding="utf-8") as fh:
            doc = json.load(fh)
    except (OSError, json.JSONDecodeError) as exc:
        print(f"FAIL {path}: unreadable or invalid JSON: {exc}", file=sys.stderr)
        return 1

    errors = []
    if "status" in doc:
        errors.append(
            "placeholder marker 'status' still present — the bench did not regenerate this file"
        )
    for key in required:
        if not has_path(doc, key):
            errors.append(f"required key '{key}' missing")
    errors.extend(f"null value at {p}" for p in find_nulls(doc))

    if errors:
        print(f"FAIL {path}:", file=sys.stderr)
        for err in errors:
            print(f"  - {err}", file=sys.stderr)
        return 1
    print(f"OK {path}: keys {required} present, no null fields")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
