//! PJRT client wrapper, executable cache, and the PJRT-backed MAC backend.

use crate::sim::backend::MacBackend;
use anyhow::{anyhow, Result};
use std::cell::RefCell;
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::rc::Rc;

/// Matvec shape buckets `(rows, cols)` emitted by `python/compile/aot.py`.
/// Rows = stacked-input lanes (contraction), cols = target neurons.
pub const MATVEC_BUCKETS: &[(usize, usize)] = &[(256, 256), (2048, 256), (8192, 256)];

/// LIF-step size bucket emitted alongside (see `aot.py`).
pub const LIF_BUCKET: usize = 256;

/// Smallest bucket that fits an `(r, c)` matvec, if any.
pub fn matvec_bucket(r: usize, c: usize) -> Option<(usize, usize)> {
    MATVEC_BUCKETS.iter().copied().find(|&(br, bc)| r <= br && c <= bc)
}

/// Default artifacts directory: `$S2SWITCH_ARTIFACTS` or `./artifacts`.
pub fn artifact_dir() -> PathBuf {
    std::env::var_os("S2SWITCH_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}

/// PJRT CPU client plus a compiled-executable cache.
pub struct PjrtRuntime {
    pub client: xla::PjRtClient,
    dir: PathBuf,
    exes: HashMap<String, Rc<xla::PjRtLoadedExecutable>>,
}

impl PjrtRuntime {
    /// Create a CPU PJRT client rooted at an artifacts directory.
    pub fn new(dir: impl AsRef<Path>) -> Result<Self> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
        Ok(PjrtRuntime { client, dir: dir.as_ref().to_path_buf(), exes: HashMap::new() })
    }

    /// Load + compile `<dir>/<name>.hlo.txt` (cached).
    pub fn load(&mut self, name: &str) -> Result<Rc<xla::PjRtLoadedExecutable>> {
        if let Some(exe) = self.exes.get(name) {
            return Ok(exe.clone());
        }
        let path = self.dir.join(format!("{name}.hlo.txt"));
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(|e| anyhow!("loading {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {name}: {e:?}"))?;
        let exe = Rc::new(exe);
        self.exes.insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    /// Number of compiled executables held.
    pub fn cached_executables(&self) -> usize {
        self.exes.len()
    }
}

/// MAC backend executing matvecs through the AOT Pallas/JAX artifact.
///
/// Weights are uploaded to the device once per distinct chunk (keyed by the
/// chunk's storage address — stable for the engine's lifetime) and reused
/// every timestep; only the stacked-input vector travels per call.
pub struct PjrtMac {
    rt: Rc<RefCell<PjrtRuntime>>,
    weight_buffers: HashMap<(usize, usize, usize), xla::PjRtBuffer>,
    /// Telemetry: device executions issued.
    pub executions: u64,
}

impl PjrtMac {
    pub fn new(rt: Rc<RefCell<PjrtRuntime>>) -> Self {
        PjrtMac { rt, weight_buffers: HashMap::new(), executions: 0 }
    }

    fn weights_key(weights: &[f32], r: usize, c: usize) -> (usize, usize, usize) {
        (weights.as_ptr() as usize, r, c)
    }
}

impl PjrtMac {
    /// One bucketed artifact execution (rows ≤ smallest fitting bucket).
    fn matvec_single(
        &mut self,
        stacked: &[f32],
        weights: &[f32],
        n_rows: usize,
        n_cols: usize,
    ) -> Vec<f32> {
        let (br, bc) = matvec_bucket(n_rows, n_cols).unwrap_or_else(|| {
            panic!("no matvec artifact bucket fits {n_rows}×{n_cols}")
        });
        let mut rt = self.rt.borrow_mut();
        let exe = rt
            .load(&format!("mac_matvec_{br}x{bc}"))
            .expect("matvec artifact must be built (make artifacts)");

        // Pad stacked to [br].
        let mut s = vec![0.0f32; br];
        s[..n_rows].copy_from_slice(stacked);
        let s_buf = rt
            .client
            .buffer_from_host_buffer(&s, &[br], None)
            .expect("stacked upload");

        // Weights: cached padded upload [br, bc].
        let key = Self::weights_key(weights, n_rows, n_cols);
        if !self.weight_buffers.contains_key(&key) {
            let mut w = vec![0.0f32; br * bc];
            for r in 0..n_rows {
                w[r * bc..r * bc + n_cols]
                    .copy_from_slice(&weights[r * n_cols..(r + 1) * n_cols]);
            }
            let buf = rt
                .client
                .buffer_from_host_buffer(&w, &[br, bc], None)
                .expect("weights upload");
            self.weight_buffers.insert(key, buf);
        }
        let w_buf = &self.weight_buffers[&key];

        let result = exe.execute_b(&[&s_buf, w_buf]).expect("matvec execute");
        self.executions += 1;
        let lit = result[0][0].to_literal_sync().expect("readback");
        // aot.py lowers with return_tuple=True → unwrap the 1-tuple.
        let out = lit.to_tuple1().expect("tuple1").to_vec::<f32>().expect("f32 vec");
        out[..n_cols].to_vec()
    }
}

/// Row-tile size for decomposed execution (§Perf iteration 3): interpret-
/// mode pallas lowers to an XLA while-loop that carries the whole weight
/// operand per grid step, making one big-bucket call O(rows²·cols). Running
/// ceil(rows/256) small-bucket calls and summing is 15–20× faster and
/// exactly equal (integer-valued operands).
const ROW_TILE: usize = 256;

impl MacBackend for PjrtMac {
    fn matvec_into(
        &mut self,
        out: &mut [f32],
        stacked: &[f32],
        weights: &[f32],
        n_rows: usize,
        n_cols: usize,
    ) -> u64 {
        assert_eq!(stacked.len(), n_rows);
        assert_eq!(weights.len(), n_rows * n_cols);
        assert_eq!(out.len(), n_cols);
        out.fill(0.0);
        let mut issued = 0u64;
        let mut r0 = 0usize;
        while r0 < n_rows {
            let r1 = (r0 + ROW_TILE).min(n_rows);
            // Skip fully-silent row tiles (stacked input is sparse).
            if stacked[r0..r1].iter().any(|&s| s != 0.0) {
                let part = self.matvec_single(
                    &stacked[r0..r1],
                    &weights[r0 * n_cols..r1 * n_cols],
                    r1 - r0,
                    n_cols,
                );
                for (o, p) in out.iter_mut().zip(part) {
                    *o += p;
                }
                // Logical rows × cols dispatched to the device (bucket
                // padding excluded — keeps MACs/s comparable to native).
                issued += ((r1 - r0) * n_cols) as u64;
            }
            r0 = r1;
        }
        issued
    }

    fn name(&self) -> &'static str {
        "pjrt"
    }

    /// The MAC inner loop is the AOT-compiled JAX/Pallas HLO — neither the
    /// scalar nor the `std::simd` native kernel, so profile output gets its
    /// own label (the `simd` feature changes nothing on this path).
    fn kernel_variant(&self) -> &'static str {
        "pjrt-aot"
    }
}

/// Convenience: run the fused LIF-step artifact (used by the e2e example and
/// integration tests to validate the L2 model end-to-end).
///
/// Artifact signature (see `python/compile/model.py`):
/// `lif_step_256(v[256], current[256], alpha, v_th) -> (v_next[256], spiked[256])`.
pub fn run_lif_step(
    rt: &mut PjrtRuntime,
    v: &[f32],
    current: &[f32],
    alpha: f32,
    v_th: f32,
) -> Result<(Vec<f32>, Vec<f32>)> {
    let n = LIF_BUCKET;
    anyhow::ensure!(v.len() <= n && current.len() <= n, "exceeds LIF bucket {n}");
    let exe = rt.load(&format!("lif_step_{n}"))?;
    let mut vp = vec![0.0f32; n];
    vp[..v.len()].copy_from_slice(v);
    let mut cp = vec![0.0f32; n];
    cp[..current.len()].copy_from_slice(current);
    let args = [
        xla::Literal::vec1(&vp).reshape(&[n as i64]).map_err(|e| anyhow!("{e:?}"))?,
        xla::Literal::vec1(&cp).reshape(&[n as i64]).map_err(|e| anyhow!("{e:?}"))?,
        xla::Literal::scalar(alpha),
        xla::Literal::scalar(v_th),
    ];
    let result = exe.execute::<xla::Literal>(&args).map_err(|e| anyhow!("{e:?}"))?;
    let lit = result[0][0].to_literal_sync().map_err(|e| anyhow!("{e:?}"))?;
    let (v_next, spiked) = lit.to_tuple2().map_err(|e| anyhow!("{e:?}"))?;
    Ok((
        v_next.to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))?[..v.len()].to_vec(),
        spiked.to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))?[..v.len()].to_vec(),
    ))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_selection() {
        assert_eq!(matvec_bucket(10, 10), Some((256, 256)));
        assert_eq!(matvec_bucket(256, 256), Some((256, 256)));
        assert_eq!(matvec_bucket(257, 10), Some((2048, 256)));
        assert_eq!(matvec_bucket(4000, 100), Some((8192, 256)));
        assert_eq!(matvec_bucket(10_000, 10), None);
        assert_eq!(matvec_bucket(10, 300), None);
    }

    // PJRT-backed execution tests live in rust/tests/pjrt_integration.rs —
    // they need `make artifacts` to have run first.
}
