//! PJRT runtime — loads and executes the AOT-compiled JAX/Pallas artifacts.
//!
//! Python runs once at build time (`make artifacts`): `python/compile/aot.py`
//! lowers the L2 JAX model (which calls the L1 Pallas MAC kernel) to **HLO
//! text** under `artifacts/`. This module loads those files with the `xla`
//! crate (`HloModuleProto::from_text_file` → compile on the PJRT CPU client)
//! and exposes them to the simulator; Python is never on the request path.
//!
//! HLO shapes are static, so the matvec artifacts come in shape *buckets*;
//! the runtime pads operands up to the bucket and truncates results. WDM
//! chunk weights are uploaded once per chunk as device buffers and reused
//! every timestep.

pub mod pjrt;

pub use pjrt::{artifact_dir, matvec_bucket, PjrtMac, PjrtRuntime, MATVEC_BUCKETS};
