//! PJRT runtime — loads and executes the AOT-compiled JAX/Pallas artifacts.
//!
//! Python runs once at build time (`make artifacts`): `python/compile/aot.py`
//! lowers the L2 JAX model (which calls the L1 Pallas MAC kernel) to **HLO
//! text** under `artifacts/`. This module loads those files with the `xla`
//! crate (`HloModuleProto::from_text_file` → compile on the PJRT CPU client)
//! and exposes them to the simulator; Python is never on the request path.
//!
//! HLO shapes are static, so the matvec artifacts come in shape *buckets*;
//! the runtime pads operands up to the bucket and truncates results. WDM
//! chunk weights are uploaded once per chunk as device buffers and reused
//! every timestep.
//!
//! **Feature gate**: the `xla` crate is not part of the offline vendored
//! crate set, so the whole PJRT path sits behind the `pjrt` cargo feature
//! (DESIGN.md §2). The default build runs everything on the native MAC
//! backend; `--features pjrt` (plus the locally-vendored `xla` crate and
//! `make artifacts`) enables this module, the `--pjrt` CLI flag, and the
//! PJRT integration tests.

#[cfg(feature = "pjrt")]
pub mod pjrt;

#[cfg(feature = "pjrt")]
pub use pjrt::{artifact_dir, matvec_bucket, PjrtMac, PjrtRuntime, MATVEC_BUCKETS};
