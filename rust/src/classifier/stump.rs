//! Decision stumps — the weak learners behind AdaBoost and gradient
//! boosting.

use super::N_FEATURES;

/// A one-split classifier: `x[feature] <= threshold → left else right`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Stump {
    pub feature: usize,
    pub threshold: f64,
    /// Output for the left branch (class for AdaBoost in ±1 space, value
    /// for regression stumps).
    pub left: f64,
    pub right: f64,
}

impl Stump {
    #[inline]
    pub fn eval(&self, x: &[f64; N_FEATURES]) -> f64 {
        if x[self.feature] <= self.threshold {
            self.left
        } else {
            self.right
        }
    }
}

/// Candidate thresholds for a feature: midpoints between consecutive
/// distinct sorted values (capped for speed on large corpora).
pub fn candidate_thresholds(values: &mut Vec<f64>, max_candidates: usize) -> Vec<f64> {
    values.sort_by(f64::total_cmp);
    values.dedup();
    if values.len() < 2 {
        return values.clone();
    }
    let mids: Vec<f64> = values.windows(2).map(|w| 0.5 * (w[0] + w[1])).collect();
    if mids.len() <= max_candidates {
        return mids;
    }
    // Subsample evenly.
    let step = mids.len() as f64 / max_candidates as f64;
    (0..max_candidates).map(|i| mids[(i as f64 * step) as usize]).collect()
}

/// Fit the stump minimizing weighted classification error in ±1 label space.
///
/// Returns the best stump and its weighted error. `y[i] ∈ {-1.0, +1.0}`,
/// `w` are non-negative sample weights summing to ~1.
pub fn fit_classification_stump(
    x: &[[f64; N_FEATURES]],
    y: &[f64],
    w: &[f64],
) -> (Stump, f64) {
    let mut best = (
        Stump { feature: 0, threshold: 0.0, left: 1.0, right: -1.0 },
        f64::INFINITY,
    );
    for feature in 0..N_FEATURES {
        // Sort samples once per feature; sweep thresholds accumulating the
        // weighted class sums on the left side.
        let mut order: Vec<usize> = (0..x.len()).collect();
        order.sort_by(|&a, &b| x[a][feature].total_cmp(&x[b][feature]));

        let total_pos: f64 = y.iter().zip(w).filter(|(y, _)| **y > 0.0).map(|(_, w)| w).sum();
        let total_neg: f64 = y.iter().zip(w).filter(|(y, _)| **y < 0.0).map(|(_, w)| w).sum();

        let mut left_pos = 0.0f64;
        let mut left_neg = 0.0f64;
        let mut i = 0usize;
        while i < order.len() {
            // Advance over ties so the threshold sits between distinct values.
            let v = x[order[i]][feature];
            while i < order.len() && x[order[i]][feature] == v {
                let s = order[i];
                if y[s] > 0.0 {
                    left_pos += w[s];
                } else {
                    left_neg += w[s];
                }
                i += 1;
            }
            if i == order.len() {
                break;
            }
            let threshold = 0.5 * (v + x[order[i]][feature]);
            // Orientation A: left=+1, right=-1 → errors: left_neg + right_pos.
            let err_a = left_neg + (total_pos - left_pos);
            // Orientation B: the mirror.
            let err_b = left_pos + (total_neg - left_neg);
            let (err, left, right) =
                if err_a <= err_b { (err_a, 1.0, -1.0) } else { (err_b, -1.0, 1.0) };
            if err < best.1 {
                best = (Stump { feature, threshold, left, right }, err);
            }
        }
    }
    best
}

/// Fit the stump minimizing weighted squared error against real-valued
/// targets (for gradient boosting). Returns the stump; leaf values are the
/// weighted means of each side.
pub fn fit_regression_stump(
    x: &[[f64; N_FEATURES]],
    targets: &[f64],
    max_candidates: usize,
) -> Stump {
    let n = x.len();
    let mut best = Stump {
        feature: 0,
        threshold: f64::NEG_INFINITY,
        left: 0.0,
        right: targets.iter().sum::<f64>() / n.max(1) as f64,
    };
    let mut best_sse = f64::INFINITY;
    for feature in 0..N_FEATURES {
        let mut vals: Vec<f64> = x.iter().map(|r| r[feature]).collect();
        let thresholds = candidate_thresholds(&mut vals, max_candidates);
        // Pre-sort for a sweep.
        let mut order: Vec<usize> = (0..n).collect();
        order.sort_by(|&a, &b| x[a][feature].total_cmp(&x[b][feature]));
        let total_sum: f64 = targets.iter().sum();
        let total_sq: f64 = targets.iter().map(|t| t * t).sum();

        let mut i = 0usize;
        let mut left_sum = 0.0f64;
        let mut left_n = 0usize;
        for &threshold in &thresholds {
            while i < n && x[order[i]][feature] <= threshold {
                left_sum += targets[order[i]];
                left_n += 1;
                i += 1;
            }
            if left_n == 0 || left_n == n {
                continue;
            }
            let right_sum = total_sum - left_sum;
            let right_n = n - left_n;
            let left_mean = left_sum / left_n as f64;
            let right_mean = right_sum / right_n as f64;
            // SSE = Σt² − n_l·m_l² − n_r·m_r² (up to the constant Σt²).
            let sse = total_sq - left_n as f64 * left_mean * left_mean
                - right_n as f64 * right_mean * right_mean;
            if sse < best_sse {
                best_sse = sse;
                best = Stump { feature, threshold, left: left_mean, right: right_mean };
            }
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    fn xy_separable() -> (Vec<[f64; 4]>, Vec<f64>) {
        // Separable on feature 2 at 5.0.
        let x: Vec<[f64; 4]> = (0..20)
            .map(|i| [0.0, 1.0, if i < 10 { i as f64 / 3.0 } else { 6.0 + i as f64 }, 2.0])
            .collect();
        let y: Vec<f64> = (0..20).map(|i| if i < 10 { -1.0 } else { 1.0 }).collect();
        (x, y)
    }

    #[test]
    fn classification_stump_finds_separator() {
        let (x, y) = xy_separable();
        let w = vec![1.0 / 20.0; 20];
        let (stump, err) = fit_classification_stump(&x, &y, &w);
        assert_eq!(stump.feature, 2);
        assert!(err < 1e-12, "separable data → zero error, got {err}");
        for (row, &label) in x.iter().zip(&y) {
            assert_eq!(stump.eval(row), label);
        }
    }

    #[test]
    fn classification_stump_respects_weights() {
        // Two conflicting points; the heavier one wins.
        let x = vec![[0.0, 0.0, 1.0, 0.0], [0.0, 0.0, 2.0, 0.0]];
        let y = vec![1.0, -1.0];
        let (s, err) = fit_classification_stump(&x, &y, &[0.9, 0.1]);
        assert!(err <= 0.1 + 1e-12);
        assert_eq!(s.eval(&x[0]), 1.0);
    }

    #[test]
    fn regression_stump_fits_step() {
        let x: Vec<[f64; 4]> = (0..10).map(|i| [i as f64, 0.0, 0.0, 0.0]).collect();
        let t: Vec<f64> = (0..10).map(|i| if i < 5 { -2.0 } else { 3.0 }).collect();
        let s = fit_regression_stump(&x, &t, 64);
        assert_eq!(s.feature, 0);
        assert!((s.left - -2.0).abs() < 1e-9);
        assert!((s.right - 3.0).abs() < 1e-9);
    }

    #[test]
    fn thresholds_are_midpoints() {
        let mut v = vec![3.0, 1.0, 2.0, 2.0];
        let t = candidate_thresholds(&mut v, 16);
        assert_eq!(t, vec![1.5, 2.5]);
    }
}
