//! Random forest: bootstrap-bagged CART trees with random feature subsets.

use super::tree::DecisionTree;
use super::{Classifier, N_FEATURES};
use crate::rng::Rng;

/// Majority-vote ensemble of randomized trees.
pub struct RandomForest {
    pub n_trees: usize,
    pub max_depth: usize,
    pub min_samples_split: usize,
    pub seed: u64,
    trees: Vec<DecisionTree>,
}

impl RandomForest {
    pub fn new(n_trees: usize, max_depth: usize, min_samples_split: usize, seed: u64) -> Self {
        RandomForest { n_trees, max_depth, min_samples_split, seed, trees: Vec::new() }
    }

    pub fn n_fitted_trees(&self) -> usize {
        self.trees.len()
    }
}

impl Classifier for RandomForest {
    fn name(&self) -> &'static str {
        "Random Forest"
    }

    fn train(&mut self, x: &[[f64; N_FEATURES]], y: &[usize]) {
        let mut rng = Rng::new(self.seed);
        self.trees.clear();
        let n = x.len();
        for _ in 0..self.n_trees {
            // Bootstrap sample.
            let mut bx = Vec::with_capacity(n);
            let mut by = Vec::with_capacity(n);
            for _ in 0..n {
                let i = rng.below(n);
                bx.push(x[i]);
                by.push(y[i]);
            }
            // Random feature subset *per split* (sklearn's max_features =
            // √4 = 2) — per-tree masks starve trees on a 4-feature problem.
            let mut tree = DecisionTree::new(self.max_depth, self.min_samples_split);
            tree.per_split_features = Some((2, rng.next_u64()));
            tree.train(&bx, &by);
            self.trees.push(tree);
        }
    }

    fn predict(&self, x: &[f64; N_FEATURES]) -> usize {
        let votes: usize = self.trees.iter().map(|t| t.predict(x)).sum();
        usize::from(votes * 2 > self.trees.len())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::classifier::metrics::accuracy;
    use crate::rng::Rng;

    #[test]
    fn forest_beats_chance_and_is_deterministic() {
        let mut rng = Rng::new(8);
        let mut x = Vec::new();
        let mut y = Vec::new();
        for _ in 0..400 {
            let a = rng.f64();
            let b = rng.f64();
            x.push([a, b, rng.f64(), rng.f64()]);
            y.push(usize::from(a + b > 1.0));
        }
        let mut f1 = RandomForest::new(30, 8, 4, 42);
        f1.train(&x, &y);
        let acc = accuracy(&f1.predict_batch(&x), &y);
        assert!(acc > 0.85, "forest should learn a linear boundary, got {acc}");
        assert_eq!(f1.n_fitted_trees(), 30);

        let mut f2 = RandomForest::new(30, 8, 4, 42);
        f2.train(&x, &y);
        assert_eq!(f1.predict_batch(&x), f2.predict_batch(&x), "same seed → same model");
    }

    #[test]
    fn different_seeds_give_different_models() {
        let mut rng = Rng::new(9);
        let x: Vec<[f64; 4]> =
            (0..200).map(|_| [rng.f64(), rng.f64(), rng.f64(), rng.f64()]).collect();
        let y: Vec<usize> = (0..200).map(|_| rng.below(2)).collect();
        let mut a = RandomForest::new(10, 6, 4, 1);
        let mut b = RandomForest::new(10, 6, 4, 2);
        a.train(&x, &y);
        b.train(&x, &y);
        // On noise labels, differently-seeded forests disagree somewhere.
        assert_ne!(a.predict_batch(&x), b.predict_batch(&x));
    }
}
