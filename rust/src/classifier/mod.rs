//! Twelve from-scratch binary classifiers (paper §IV-B).
//!
//! "We train 12 kinds of classifiers with the dataset acquired in
//! Subsection IV-A, and the highest accuracy of 91.69% comes from the
//! Adaptive Boost algorithm."
//!
//! All classifiers implement [`Classifier`] over the 4-feature layer
//! character with labels {0 = serial, 1 = parallel}. The roster mirrors a
//! standard scikit-learn comparison (the paper does not enumerate its 12;
//! Fig. 4 shows AdaBoost plus "MLP x" variants — DESIGN.md §2):
//!
//! | name              | module          |
//! |-------------------|-----------------|
//! | AdaBoost          | [`adaboost`]    |
//! | Decision Tree     | [`tree`]        |
//! | Random Forest     | [`forest`]      |
//! | Gradient Boosting | [`gboost`]      |
//! | k-Nearest Neighb. | [`knn`]         |
//! | Gaussian NB       | [`naive_bayes`] |
//! | Logistic Regr.    | [`linear`]      |
//! | Linear SVM        | [`linear`]      |
//! | LDA               | [`discriminant`]|
//! | QDA               | [`discriminant`]|
//! | MLP-8             | [`mlp`]         |
//! | MLP-32            | [`mlp`]         |

pub mod adaboost;
pub mod discriminant;
pub mod forest;
pub mod gboost;
pub mod knn;
pub mod linear;
pub mod metrics;
pub mod mlp;
pub mod naive_bayes;
pub mod stump;
pub mod tree;

pub use adaboost::AdaBoost;
pub use metrics::{accuracy, train_test_split, Standardizer};

use crate::io::Json;

/// Number of input features (delay range, n_source, n_target, density).
pub const N_FEATURES: usize = 4;
/// Number of classes (serial, parallel).
pub const N_CLASSES: usize = 2;

/// A trainable binary classifier over the layer-character features.
pub trait Classifier: Send {
    /// Human-readable name (matches Fig. 4 x-axis labels).
    fn name(&self) -> &'static str;

    /// Fit on a training set. `x[i]` is a feature row, `y[i] ∈ {0, 1}`.
    fn train(&mut self, x: &[[f64; N_FEATURES]], y: &[usize]);

    /// Predict the class of one feature row.
    fn predict(&self, x: &[f64; N_FEATURES]) -> usize;

    /// Batch prediction.
    fn predict_batch(&self, x: &[[f64; N_FEATURES]]) -> Vec<usize> {
        x.iter().map(|row| self.predict(row)).collect()
    }

    /// Model persistence (implemented by the deployed classifier).
    fn to_json(&self) -> Option<Json> {
        None
    }
}

/// Instantiate the full 12-classifier roster with a given seed (seed feeds
/// the stochastic learners: forest bagging, MLP init, SGD shuffles).
pub fn roster(seed: u64) -> Vec<Box<dyn Classifier>> {
    vec![
        Box::new(adaboost::AdaBoost::new(100)),
        Box::new(tree::DecisionTree::new(8, 5)),
        Box::new(forest::RandomForest::new(40, 10, 5, seed)),
        Box::new(gboost::GradientBoost::new(150, 0.3)),
        Box::new(knn::Knn::new(5)),
        Box::new(naive_bayes::GaussianNb::new()),
        Box::new(linear::LogisticRegression::new(300, 0.1)),
        Box::new(linear::LinearSvm::new(300, 0.05, 1e-4, seed)),
        Box::new(discriminant::Lda::new()),
        Box::new(discriminant::Qda::new()),
        Box::new(mlp::Mlp::new(8, 200, 0.05, seed)),
        Box::new(mlp::Mlp::new(32, 200, 0.05, seed ^ 0xabcdef)),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roster_has_twelve_distinctly_named_classifiers() {
        let r = roster(1);
        assert_eq!(r.len(), 12);
        let mut names: Vec<&str> = r.iter().map(|c| c.name()).collect();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), 12, "duplicate classifier names");
    }
}
