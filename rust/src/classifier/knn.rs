//! k-nearest neighbours with standardized Euclidean distance.

use super::metrics::Standardizer;
use super::{Classifier, N_FEATURES};

/// Brute-force kNN (the corpus is 16k rows; exact search is fast enough and
/// exactness keeps Fig. 4 deterministic).
pub struct Knn {
    pub k: usize,
    scaler: Option<Standardizer>,
    x: Vec<[f64; N_FEATURES]>,
    y: Vec<usize>,
}

impl Knn {
    pub fn new(k: usize) -> Self {
        assert!(k >= 1);
        Knn { k, scaler: None, x: Vec::new(), y: Vec::new() }
    }
}

impl Classifier for Knn {
    fn name(&self) -> &'static str {
        "kNN"
    }

    fn train(&mut self, x: &[[f64; N_FEATURES]], y: &[usize]) {
        let scaler = Standardizer::fit(x);
        self.x = scaler.apply_all(x);
        self.y = y.to_vec();
        self.scaler = Some(scaler);
    }

    fn predict(&self, x: &[f64; N_FEATURES]) -> usize {
        let q = self.scaler.as_ref().expect("train first").apply(x);
        // Keep a small max-heap of the k best via a sorted insertion buffer
        // (k is tiny).
        let k = self.k.min(self.x.len());
        let mut best: Vec<(f64, usize)> = Vec::with_capacity(k + 1);
        for (row, &label) in self.x.iter().zip(&self.y) {
            let mut d = 0.0;
            for j in 0..N_FEATURES {
                let t = row[j] - q[j];
                d += t * t;
            }
            if best.len() < k {
                best.push((d, label));
                best.sort_by(|a, b| a.0.total_cmp(&b.0));
            } else if d < best[k - 1].0 {
                best[k - 1] = (d, label);
                best.sort_by(|a, b| a.0.total_cmp(&b.0));
            }
        }
        let ones: usize = best.iter().map(|&(_, l)| l).sum();
        usize::from(ones * 2 > best.len())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::classifier::metrics::accuracy;
    use crate::rng::Rng;

    #[test]
    fn memorizes_training_data_with_k1() {
        let mut rng = Rng::new(20);
        let x: Vec<[f64; 4]> =
            (0..100).map(|_| [rng.f64(), rng.f64(), rng.f64(), rng.f64()]).collect();
        let y: Vec<usize> = (0..100).map(|_| rng.below(2)).collect();
        let mut knn = Knn::new(1);
        knn.train(&x, &y);
        assert_eq!(accuracy(&knn.predict_batch(&x), &y), 1.0);
    }

    #[test]
    fn standardization_makes_scales_irrelevant() {
        // Feature 0 informative in [0,1]; feature 1 pure noise at scale 1e6.
        let mut rng = Rng::new(21);
        let mut x = Vec::new();
        let mut y = Vec::new();
        for _ in 0..300 {
            let a = rng.f64();
            x.push([a, rng.f64() * 1e6, 0.0, 0.0]);
            y.push(usize::from(a > 0.5));
        }
        let mut knn = Knn::new(5);
        knn.train(&x, &y);
        let acc = accuracy(&knn.predict_batch(&x), &y);
        // Noise at huge scale gets standardized to σ=1; the informative
        // feature stays usable.
        assert!(acc > 0.8, "standardized kNN should cope with scales, got {acc}");
    }

    #[test]
    fn majority_vote() {
        // 3 close class-1 points vs 2 close class-0 points.
        let x = vec![
            [0.0, 0.0, 0.0, 0.0],
            [0.1, 0.0, 0.0, 0.0],
            [0.2, 0.0, 0.0, 0.0],
            [5.0, 0.0, 0.0, 0.0],
            [5.1, 0.0, 0.0, 0.0],
        ];
        let y = vec![1, 1, 1, 0, 0];
        let mut knn = Knn::new(5);
        knn.train(&x, &y);
        assert_eq!(knn.predict(&[0.05, 0.0, 0.0, 0.0]), 1);
    }
}
