//! Multilayer perceptron — the paper's "MLP x" classifiers (x = hidden
//! units). One tanh hidden layer, sigmoid output, SGD on cross-entropy with
//! standardized inputs.

use super::metrics::Standardizer;
use super::{Classifier, N_FEATURES};
use crate::rng::Rng;

/// MLP with one hidden layer of `hidden` units.
pub struct Mlp {
    pub hidden: usize,
    pub epochs: usize,
    pub learning_rate: f64,
    pub seed: u64,
    scaler: Option<Standardizer>,
    /// w1[h][j], b1[h]: input → hidden.
    w1: Vec<[f64; N_FEATURES]>,
    b1: Vec<f64>,
    /// w2[h], b2: hidden → output logit.
    w2: Vec<f64>,
    b2: f64,
    /// Leaked name ("MLP 8"), created once per constructor call.
    name: &'static str,
}

fn sigmoid(z: f64) -> f64 {
    1.0 / (1.0 + (-z).exp())
}

impl Mlp {
    pub fn new(hidden: usize, epochs: usize, learning_rate: f64, seed: u64) -> Self {
        // Fig. 4 labels these "MLP x"; leak the small name string so the
        // Classifier trait can stay `&'static str`.
        let name: &'static str = Box::leak(format!("MLP {hidden}").into_boxed_str());
        Mlp {
            hidden,
            epochs,
            learning_rate,
            seed,
            scaler: None,
            w1: Vec::new(),
            b1: Vec::new(),
            w2: Vec::new(),
            b2: 0.0,
            name,
        }
    }

    fn forward(&self, x: &[f64; N_FEATURES], h_out: &mut [f64]) -> f64 {
        for (h, (w, b)) in self.w1.iter().zip(&self.b1).enumerate() {
            let z: f64 = w.iter().zip(x).map(|(w, x)| w * x).sum::<f64>() + b;
            h_out[h] = z.tanh();
        }
        let logit: f64 =
            self.w2.iter().zip(h_out.iter()).map(|(w, h)| w * h).sum::<f64>() + self.b2;
        logit
    }
}

impl Classifier for Mlp {
    fn name(&self) -> &'static str {
        self.name
    }

    fn train(&mut self, x: &[[f64; N_FEATURES]], y: &[usize]) {
        let scaler = Standardizer::fit(x);
        let xs = scaler.apply_all(x);
        self.scaler = Some(scaler);

        let mut rng = Rng::new(self.seed);
        // Xavier-ish init.
        let scale1 = (1.0 / N_FEATURES as f64).sqrt();
        let scale2 = (1.0 / self.hidden as f64).sqrt();
        self.w1 = (0..self.hidden)
            .map(|_| std::array::from_fn(|_| rng.normal() * scale1))
            .collect();
        self.b1 = vec![0.0; self.hidden];
        self.w2 = (0..self.hidden).map(|_| rng.normal() * scale2).collect();
        self.b2 = 0.0;

        let mut order: Vec<usize> = (0..xs.len()).collect();
        let mut h = vec![0.0; self.hidden];
        for epoch in 0..self.epochs {
            rng.shuffle(&mut order);
            // 1/√epoch decay keeps late epochs stable.
            let lr = self.learning_rate / (1.0 + 0.05 * epoch as f64);
            for &i in &order {
                let logit = self.forward(&xs[i], &mut h);
                let err = sigmoid(logit) - y[i] as f64; // dL/dlogit
                // Hidden-layer gradients need the *pre-update* w2.
                let w2_old = self.w2.clone();
                // Output layer.
                for (w2, &hv) in self.w2.iter_mut().zip(h.iter()) {
                    *w2 -= lr * err * hv;
                }
                self.b2 -= lr * err;
                // Hidden layer.
                for hh in 0..self.hidden {
                    let dh = err * w2_old[hh] * (1.0 - h[hh] * h[hh]);
                    for j in 0..N_FEATURES {
                        self.w1[hh][j] -= lr * dh * xs[i][j];
                    }
                    self.b1[hh] -= lr * dh;
                }
            }
        }
    }

    fn predict(&self, x: &[f64; N_FEATURES]) -> usize {
        let xs = self.scaler.as_ref().expect("train first").apply(x);
        let mut h = vec![0.0; self.hidden];
        usize::from(self.forward(&xs, &mut h) > 0.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::classifier::metrics::accuracy;
    use crate::rng::Rng;

    fn xor_data(n: usize, seed: u64) -> (Vec<[f64; 4]>, Vec<usize>) {
        let mut rng = Rng::new(seed);
        let mut x = Vec::new();
        let mut y = Vec::new();
        for _ in 0..n {
            let a = rng.f64();
            let b = rng.f64();
            x.push([a, b, 0.0, 0.0]);
            y.push(usize::from((a > 0.5) != (b > 0.5)));
        }
        (x, y)
    }

    #[test]
    fn solves_xor() {
        let (x, y) = xor_data(400, 60);
        let mut mlp = Mlp::new(8, 300, 0.1, 1);
        mlp.train(&x, &y);
        let acc = accuracy(&mlp.predict_batch(&x), &y);
        assert!(acc > 0.9, "MLP-8 should solve XOR, got {acc}");
    }

    #[test]
    fn names_include_width() {
        assert_eq!(Mlp::new(8, 1, 0.1, 1).name(), "MLP 8");
        assert_eq!(Mlp::new(32, 1, 0.1, 1).name(), "MLP 32");
    }

    #[test]
    fn deterministic_given_seed() {
        let (x, y) = xor_data(200, 61);
        let mut a = Mlp::new(8, 50, 0.1, 5);
        let mut b = Mlp::new(8, 50, 0.1, 5);
        a.train(&x, &y);
        b.train(&x, &y);
        assert_eq!(a.predict_batch(&x), b.predict_batch(&x));
    }

    #[test]
    fn different_seed_different_model() {
        let (x, y) = xor_data(200, 62);
        let mut a = Mlp::new(4, 10, 0.1, 1);
        let mut b = Mlp::new(4, 10, 0.1, 2);
        a.train(&x, &y);
        b.train(&x, &y);
        assert_ne!(a.predict_batch(&x), b.predict_batch(&x));
    }
}
