//! CART decision tree (Gini impurity, binary splits).

use super::{Classifier, N_FEATURES};

/// Tree node: either a split or a leaf class.
#[derive(Clone, Debug)]
enum Node {
    Leaf(usize),
    Split { feature: usize, threshold: f64, left: usize, right: usize },
}

/// A depth-limited CART classifier.
#[derive(Clone, Debug)]
pub struct DecisionTree {
    pub max_depth: usize,
    pub min_samples_split: usize,
    /// Optional feature subset restriction per split (random forests set
    /// this per-tree via `feature_mask`).
    pub feature_mask: [bool; N_FEATURES],
    /// Random-forest mode: sample `k` candidate features *per split*
    /// (sklearn's `max_features`) from the given seed.
    pub per_split_features: Option<(usize, u64)>,
    nodes: Vec<Node>,
}

impl DecisionTree {
    pub fn new(max_depth: usize, min_samples_split: usize) -> Self {
        DecisionTree {
            max_depth,
            min_samples_split,
            feature_mask: [true; N_FEATURES],
            per_split_features: None,
            nodes: Vec::new(),
        }
    }

    fn gini(counts: [f64; 2]) -> f64 {
        let n = counts[0] + counts[1];
        if n <= 0.0 {
            return 0.0;
        }
        let p0 = counts[0] / n;
        let p1 = counts[1] / n;
        1.0 - p0 * p0 - p1 * p1
    }

    /// Best (feature, threshold, weighted-gini) over allowed features.
    /// `w` are per-sample weights (AdaBoost reweights them each round).
    fn best_split(
        &self,
        x: &[[f64; N_FEATURES]],
        y: &[usize],
        w: &[f64],
        idx: &[usize],
        rng: &mut Option<crate::rng::Rng>,
    ) -> Option<(usize, f64, f64)> {
        let mut best: Option<(usize, f64, f64)> = None;
        let total: [f64; 2] = idx.iter().fold([0.0, 0.0], |mut acc, &i| {
            acc[y[i]] += w[i];
            acc
        });
        let n = total[0] + total[1];
        // Per-split feature sampling (random-forest mode).
        let split_mask: [bool; N_FEATURES] = match (&self.per_split_features, rng) {
            (Some((k, _)), Some(rng)) => {
                let mut m = [false; N_FEATURES];
                for f in rng.sample_indices(N_FEATURES, (*k).min(N_FEATURES)) {
                    m[f] = true;
                }
                m
            }
            _ => [true; N_FEATURES],
        };
        for feature in 0..N_FEATURES {
            if !self.feature_mask[feature] || !split_mask[feature] {
                continue;
            }
            let mut order: Vec<usize> = idx.to_vec();
            order.sort_by(|&a, &b| x[a][feature].total_cmp(&x[b][feature]));
            let mut left = [0.0f64; 2];
            let mut i = 0usize;
            while i < order.len() {
                let v = x[order[i]][feature];
                while i < order.len() && x[order[i]][feature] == v {
                    left[y[order[i]]] += w[order[i]];
                    i += 1;
                }
                if i == order.len() {
                    break;
                }
                let right = [total[0] - left[0], total[1] - left[1]];
                let nl = left[0] + left[1];
                let nr = right[0] + right[1];
                let g = (nl / n) * Self::gini(left) + (nr / n) * Self::gini(right);
                let threshold = 0.5 * (v + x[order[i]][feature]);
                if best.map_or(true, |(_, _, bg)| g < bg) {
                    best = Some((feature, threshold, g));
                }
            }
        }
        best
    }

    fn majority(y: &[usize], w: &[f64], idx: &[usize]) -> usize {
        let mut mass = [0.0f64; 2];
        for &i in idx {
            mass[y[i]] += w[i];
        }
        usize::from(mass[1] > mass[0])
    }

    fn build(
        &mut self,
        x: &[[f64; N_FEATURES]],
        y: &[usize],
        w: &[f64],
        idx: Vec<usize>,
        depth: usize,
        rng: &mut Option<crate::rng::Rng>,
    ) -> usize {
        let mut mass = [0.0f64; 2];
        for &i in &idx {
            mass[y[i]] += w[i];
        }
        let pure = mass[0] <= 0.0 || mass[1] <= 0.0;
        if pure || depth >= self.max_depth || idx.len() < self.min_samples_split {
            let node = Node::Leaf(Self::majority(y, w, &idx));
            self.nodes.push(node);
            return self.nodes.len() - 1;
        }
        let Some((feature, threshold, gain_gini)) = self.best_split(x, y, w, &idx, rng) else {
            self.nodes.push(Node::Leaf(Self::majority(y, w, &idx)));
            return self.nodes.len() - 1;
        };
        // No useful split (e.g. identical rows with mixed labels).
        let parent_gini = Self::gini(mass);
        if gain_gini >= parent_gini - 1e-12 {
            self.nodes.push(Node::Leaf(Self::majority(y, w, &idx)));
            return self.nodes.len() - 1;
        }
        let (l_idx, r_idx): (Vec<usize>, Vec<usize>) =
            idx.into_iter().partition(|&i| x[i][feature] <= threshold);
        // Reserve this node's slot before recursing.
        self.nodes.push(Node::Leaf(0));
        let me = self.nodes.len() - 1;
        let left = self.build(x, y, w, l_idx, depth + 1, rng);
        let right = self.build(x, y, w, r_idx, depth + 1, rng);
        self.nodes[me] = Node::Split { feature, threshold, left, right };
        me
    }

    /// Number of nodes (diagnostics).
    pub fn n_nodes(&self) -> usize {
        self.nodes.len()
    }

    /// Fit with per-sample weights (the AdaBoost weak-learner entrypoint).
    pub fn train_weighted(&mut self, x: &[[f64; N_FEATURES]], y: &[usize], w: &[f64]) {
        assert_eq!(x.len(), y.len());
        assert_eq!(x.len(), w.len());
        self.nodes.clear();
        if x.is_empty() {
            self.nodes.push(Node::Leaf(0));
            return;
        }
        let idx: Vec<usize> = (0..x.len()).collect();
        let mut rng = self.per_split_features.map(|(_, seed)| crate::rng::Rng::new(seed));
        self.build(x, y, w, idx, 0, &mut rng);
    }

    /// Serialize the fitted tree (for AdaBoost model persistence).
    ///
    /// Nodes encode as flat arrays: leaves `[class]`, splits
    /// `[feature, threshold, left, right]`.
    pub fn to_json(&self) -> crate::io::Json {
        use crate::io::Json;
        Json::obj(vec![
            ("max_depth", Json::Num(self.max_depth as f64)),
            ("min_samples_split", Json::Num(self.min_samples_split as f64)),
            (
                "nodes",
                Json::Arr(
                    self.nodes
                        .iter()
                        .map(|n| match n {
                            Node::Leaf(c) => Json::nums(vec![*c as f64]),
                            Node::Split { feature, threshold, left, right } => Json::nums(vec![
                                *feature as f64,
                                *threshold,
                                *left as f64,
                                *right as f64,
                            ]),
                        })
                        .collect(),
                ),
            ),
        ])
    }

    /// Deserialize a fitted tree.
    pub fn from_json(j: &crate::io::Json) -> Option<DecisionTree> {
        let max_depth = j.get("max_depth")?.as_usize()?;
        let min_samples_split = j.get("min_samples_split")?.as_usize()?;
        let nodes: Option<Vec<Node>> = j
            .get("nodes")?
            .as_arr()?
            .iter()
            .map(|n| {
                let v = n.as_f64_vec()?;
                match v.len() {
                    1 => Some(Node::Leaf(v[0] as usize)),
                    4 => Some(Node::Split {
                        feature: v[0] as usize,
                        threshold: v[1],
                        left: v[2] as usize,
                        right: v[3] as usize,
                    }),
                    _ => None,
                }
            })
            .collect();
        Some(DecisionTree {
            max_depth,
            min_samples_split,
            feature_mask: [true; N_FEATURES],
            per_split_features: None,
            nodes: nodes?,
        })
    }
}

impl Classifier for DecisionTree {
    fn name(&self) -> &'static str {
        "Decision Tree"
    }

    fn train(&mut self, x: &[[f64; N_FEATURES]], y: &[usize]) {
        let w = vec![1.0; x.len()];
        self.train_weighted(x, y, &w);
    }

    fn predict(&self, x: &[f64; N_FEATURES]) -> usize {
        // Root is node 0 when the tree was built from a split-first root;
        // the builder pushes leaves first for pure roots, so node 0 is
        // always the root either way... except split nodes reserve their
        // slot before children. Root is the first node created: index 0
        // only when the root was a leaf. Track instead: root is the node
        // returned by build(), which is the *first* pushed frame = 0 for a
        // leaf root, or the reserved slot (also the first pushed) = 0.
        let mut node = 0usize;
        loop {
            match &self.nodes[node] {
                Node::Leaf(c) => return *c,
                Node::Split { feature, threshold, left, right } => {
                    node = if x[*feature] <= *threshold { *left } else { *right };
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::classifier::metrics::accuracy;
    use crate::rng::Rng;

    #[test]
    fn fits_axis_aligned_rectangle() {
        // Class 1 inside [0.3, 0.7]² — needs depth ≥ 2.
        let mut rng = Rng::new(5);
        let mut x = Vec::new();
        let mut y = Vec::new();
        for _ in 0..500 {
            let a = rng.f64();
            let b = rng.f64();
            x.push([a, b, 0.0, 0.0]);
            y.push(usize::from((0.3..0.7).contains(&a) && (0.3..0.7).contains(&b)));
        }
        let mut t = DecisionTree::new(6, 2);
        t.train(&x, &y);
        let acc = accuracy(&t.predict_batch(&x), &y);
        assert!(acc > 0.97, "rectangle should be carved out, got {acc}");
    }

    #[test]
    fn depth_limit_restricts_size() {
        let mut rng = Rng::new(6);
        let x: Vec<[f64; 4]> =
            (0..200).map(|_| [rng.f64(), rng.f64(), rng.f64(), rng.f64()]).collect();
        let y: Vec<usize> = (0..200).map(|_| rng.below(2)).collect();
        let mut shallow = DecisionTree::new(1, 2);
        shallow.train(&x, &y);
        // Depth 1 → at most 1 split + 2 leaves.
        assert!(shallow.n_nodes() <= 3);
    }

    #[test]
    fn pure_data_single_leaf() {
        let x = vec![[1.0, 2.0, 3.0, 4.0]; 10];
        let y = vec![1usize; 10];
        let mut t = DecisionTree::new(5, 2);
        t.train(&x, &y);
        assert_eq!(t.n_nodes(), 1);
        assert_eq!(t.predict(&[0.0; 4]), 1);
    }

    #[test]
    fn identical_rows_mixed_labels_dont_loop() {
        let x = vec![[1.0; 4]; 10];
        let y: Vec<usize> = (0..10).map(|i| i % 2).collect();
        let mut t = DecisionTree::new(10, 2);
        t.train(&x, &y);
        assert_eq!(t.n_nodes(), 1, "unsplittable data → single leaf");
    }

    #[test]
    fn feature_mask_restricts_splits() {
        // Label depends only on feature 0, but the mask hides it.
        let x: Vec<[f64; 4]> = (0..100).map(|i| [i as f64, 0.0, 0.0, 0.0]).collect();
        let y: Vec<usize> = (0..100).map(|i| usize::from(i >= 50)).collect();
        let mut t = DecisionTree::new(4, 2);
        t.feature_mask = [false, true, true, true];
        t.train(&x, &y);
        let acc = accuracy(&t.predict_batch(&x), &y);
        assert!(acc <= 0.6, "masked feature must be unusable, got {acc}");
    }
}
