//! Train/test split, accuracy, confusion counts, feature standardization.

use super::N_FEATURES;
use crate::rng::Rng;

/// Shuffled train/test split (paper-style 80/20).
pub fn train_test_split(
    x: &[[f64; N_FEATURES]],
    y: &[usize],
    test_fraction: f64,
    seed: u64,
) -> (Vec<[f64; N_FEATURES]>, Vec<usize>, Vec<[f64; N_FEATURES]>, Vec<usize>) {
    assert_eq!(x.len(), y.len());
    assert!((0.0..1.0).contains(&test_fraction));
    let mut idx: Vec<usize> = (0..x.len()).collect();
    Rng::new(seed).shuffle(&mut idx);
    let n_test = (x.len() as f64 * test_fraction).round() as usize;
    let (test_idx, train_idx) = idx.split_at(n_test);
    let take = |ids: &[usize]| -> (Vec<[f64; N_FEATURES]>, Vec<usize>) {
        (ids.iter().map(|&i| x[i]).collect(), ids.iter().map(|&i| y[i]).collect())
    };
    let (xte, yte) = take(test_idx);
    let (xtr, ytr) = take(train_idx);
    (xtr, ytr, xte, yte)
}

/// Fraction of correct predictions.
pub fn accuracy(pred: &[usize], truth: &[usize]) -> f64 {
    assert_eq!(pred.len(), truth.len());
    if pred.is_empty() {
        return 0.0;
    }
    let hits = pred.iter().zip(truth).filter(|(p, t)| p == t).count();
    hits as f64 / pred.len() as f64
}

/// 2×2 confusion counts: `counts[truth][pred]`.
pub fn confusion(pred: &[usize], truth: &[usize]) -> [[usize; 2]; 2] {
    let mut m = [[0usize; 2]; 2];
    for (&p, &t) in pred.iter().zip(truth) {
        m[t][p] += 1;
    }
    m
}

/// Per-feature z-score standardization fitted on training data.
///
/// The scale-sensitive learners (kNN, linear models, MLP, discriminants)
/// standardize internally so every classifier sees raw features at the API
/// boundary.
#[derive(Clone, Debug)]
pub struct Standardizer {
    pub mean: [f64; N_FEATURES],
    pub std: [f64; N_FEATURES],
}

impl Standardizer {
    pub fn fit(x: &[[f64; N_FEATURES]]) -> Self {
        let n = x.len().max(1) as f64;
        let mut mean = [0.0; N_FEATURES];
        for row in x {
            for (m, v) in mean.iter_mut().zip(row) {
                *m += v;
            }
        }
        for m in &mut mean {
            *m /= n;
        }
        let mut var = [0.0; N_FEATURES];
        for row in x {
            for j in 0..N_FEATURES {
                let d = row[j] - mean[j];
                var[j] += d * d;
            }
        }
        let mut std = [0.0; N_FEATURES];
        for j in 0..N_FEATURES {
            std[j] = (var[j] / n).sqrt().max(1e-12);
        }
        Standardizer { mean, std }
    }

    #[inline]
    pub fn apply(&self, x: &[f64; N_FEATURES]) -> [f64; N_FEATURES] {
        let mut out = [0.0; N_FEATURES];
        for j in 0..N_FEATURES {
            out[j] = (x[j] - self.mean[j]) / self.std[j];
        }
        out
    }

    pub fn apply_all(&self, x: &[[f64; N_FEATURES]]) -> Vec<[f64; N_FEATURES]> {
        x.iter().map(|row| self.apply(row)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn split_partitions_data() {
        let x: Vec<[f64; 4]> = (0..100).map(|i| [i as f64; 4]).collect();
        let y: Vec<usize> = (0..100).map(|i| i % 2).collect();
        let (xtr, ytr, xte, yte) = train_test_split(&x, &y, 0.2, 1);
        assert_eq!(xte.len(), 20);
        assert_eq!(xtr.len(), 80);
        assert_eq!(ytr.len(), 80);
        assert_eq!(yte.len(), 20);
        // Every original row appears exactly once.
        let mut all: Vec<f64> = xtr.iter().chain(&xte).map(|r| r[0]).collect();
        all.sort_by(f64::total_cmp);
        assert_eq!(all, (0..100).map(|i| i as f64).collect::<Vec<_>>());
    }

    #[test]
    fn split_differs_across_seeds() {
        let x: Vec<[f64; 4]> = (0..100).map(|i| [i as f64; 4]).collect();
        let y = vec![0usize; 100];
        let (_, _, a, _) = train_test_split(&x, &y, 0.2, 1);
        let (_, _, b, _) = train_test_split(&x, &y, 0.2, 2);
        assert_ne!(a, b);
    }

    #[test]
    fn accuracy_and_confusion() {
        let pred = vec![0, 1, 1, 0];
        let truth = vec![0, 1, 0, 0];
        assert!((accuracy(&pred, &truth) - 0.75).abs() < 1e-12);
        let m = confusion(&pred, &truth);
        assert_eq!(m, [[2, 1], [0, 1]]);
    }

    #[test]
    fn standardizer_zero_mean_unit_var() {
        let x: Vec<[f64; 4]> = (0..50).map(|i| [i as f64, 2.0 * i as f64, 5.0, -(i as f64)]).collect();
        let s = Standardizer::fit(&x);
        let z = s.apply_all(&x);
        for j in [0usize, 1, 3] {
            let mean: f64 = z.iter().map(|r| r[j]).sum::<f64>() / 50.0;
            let var: f64 = z.iter().map(|r| r[j] * r[j]).sum::<f64>() / 50.0;
            assert!(mean.abs() < 1e-9);
            assert!((var - 1.0).abs() < 1e-9);
        }
        // Constant feature: guarded std, stays finite.
        assert!(z.iter().all(|r| r[2].is_finite()));
    }
}
