//! Gradient boosting (Friedman) with regression stumps on the logistic
//! loss.

use super::stump::{fit_regression_stump, Stump};
use super::{Classifier, N_FEATURES};

/// Boosted additive model `F(x) = f0 + lr · Σ stump_t(x)` trained on
/// negative gradients of log-loss; class = sigmoid(F) > 0.5.
#[derive(Clone, Debug)]
pub struct GradientBoost {
    pub n_rounds: usize,
    pub learning_rate: f64,
    f0: f64,
    stumps: Vec<Stump>,
}

impl GradientBoost {
    pub fn new(n_rounds: usize, learning_rate: f64) -> Self {
        GradientBoost { n_rounds, learning_rate, f0: 0.0, stumps: Vec::new() }
    }

    fn raw(&self, x: &[f64; N_FEATURES]) -> f64 {
        self.f0 + self.learning_rate * self.stumps.iter().map(|s| s.eval(x)).sum::<f64>()
    }

    pub fn n_fitted_rounds(&self) -> usize {
        self.stumps.len()
    }
}

fn sigmoid(z: f64) -> f64 {
    1.0 / (1.0 + (-z).exp())
}

impl Classifier for GradientBoost {
    fn name(&self) -> &'static str {
        "Gradient Boosting"
    }

    fn train(&mut self, x: &[[f64; N_FEATURES]], y: &[usize]) {
        let n = x.len();
        self.stumps.clear();
        // Initial log-odds.
        let pos = y.iter().filter(|&&l| l == 1).count() as f64;
        let p = (pos / n as f64).clamp(1e-6, 1.0 - 1e-6);
        self.f0 = (p / (1.0 - p)).ln();

        let mut f: Vec<f64> = vec![self.f0; n];
        for _ in 0..self.n_rounds {
            // Negative gradient of log-loss: y − σ(F).
            let residuals: Vec<f64> = (0..n)
                .map(|i| y[i] as f64 - sigmoid(f[i]))
                .collect();
            let stump = fit_regression_stump(x, &residuals, 64);
            for i in 0..n {
                f[i] += self.learning_rate * stump.eval(&x[i]);
            }
            self.stumps.push(stump);
        }
    }

    fn predict(&self, x: &[f64; N_FEATURES]) -> usize {
        usize::from(self.raw(x) > 0.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::classifier::metrics::accuracy;
    use crate::rng::Rng;

    #[test]
    fn learns_nonlinear_boundary() {
        // Band: class 1 when 0.3 < a < 0.7 — nonlinear in a, additive, so
        // depth-1 boosting can express it exactly (rings/XOR cannot be
        // expressed by additive single-feature models).
        let mut rng = Rng::new(10);
        let mut x = Vec::new();
        let mut y = Vec::new();
        for _ in 0..600 {
            let a = rng.f64();
            x.push([a, rng.f64(), rng.f64(), 0.0]);
            y.push(usize::from((0.3..0.7).contains(&a)));
        }
        let mut g = GradientBoost::new(300, 0.3);
        g.train(&x, &y);
        let acc = accuracy(&g.predict_batch(&x), &y);
        assert!(acc > 0.95, "band should be learnable by boosting, got {acc}");
    }

    #[test]
    fn f0_matches_class_prior() {
        let x = vec![[0.0; 4]; 100];
        let y: Vec<usize> = (0..100).map(|i| usize::from(i < 75)).collect();
        let mut g = GradientBoost::new(1, 0.1);
        g.train(&x, &y);
        // 75% positive → f0 = ln(3).
        assert!((g.f0 - 3.0f64.ln()).abs() < 1e-9);
        // Identical features → prior class predicted.
        assert_eq!(g.predict(&[0.0; 4]), 1);
    }

    #[test]
    fn more_rounds_do_not_hurt_train_fit() {
        let mut rng = Rng::new(12);
        let mut x = Vec::new();
        let mut y = Vec::new();
        for _ in 0..300 {
            let a = rng.f64();
            x.push([a, rng.f64(), 0.0, 0.0]);
            y.push(usize::from(a > 0.6));
        }
        let mut few = GradientBoost::new(5, 0.3);
        few.train(&x, &y);
        let mut many = GradientBoost::new(100, 0.3);
        many.train(&x, &y);
        assert!(
            accuracy(&many.predict_batch(&x), &y) >= accuracy(&few.predict_batch(&x), &y)
        );
    }
}
