//! Adaptive Boosting (discrete AdaBoost / AdaBoost.M1, Freund & Schapire)
//! over weighted shallow CART trees — the paper's deployed classifier
//! (91.69% accuracy, Fig. 4).
//!
//! Depth-3 trees as weak learners: expressive enough for the corpus'
//! interaction structure (delay×density trade-offs), weak enough to boost.

use super::tree::DecisionTree;
use super::{Classifier, N_FEATURES};
use crate::io::Json;

/// Weak-learner depth (a standard AdaBoost configuration).
pub const WEAK_DEPTH: usize = 3;

/// AdaBoost ensemble of weighted shallow trees.
#[derive(Default)]
pub struct AdaBoost {
    pub n_rounds: usize,
    pub trees: Vec<DecisionTree>,
    pub alphas: Vec<f64>,
}

impl AdaBoost {
    pub fn new(n_rounds: usize) -> Self {
        AdaBoost { n_rounds, trees: Vec::new(), alphas: Vec::new() }
    }

    /// Signed ensemble margin; the predicted class is its sign.
    pub fn decision_function(&self, x: &[f64; N_FEATURES]) -> f64 {
        self.trees
            .iter()
            .zip(&self.alphas)
            .map(|(t, a)| a * if t.predict(x) == 1 { 1.0 } else { -1.0 })
            .sum()
    }

    /// Reconstruct from persisted JSON (see [`Classifier::to_json`]).
    pub fn from_json(j: &Json) -> Option<AdaBoost> {
        let n_rounds = j.get("n_rounds")?.as_usize()?;
        let alphas = j.get("alphas")?.as_f64_vec()?;
        let trees: Option<Vec<DecisionTree>> =
            j.get("trees")?.as_arr()?.iter().map(DecisionTree::from_json).collect();
        let trees = trees?;
        (trees.len() == alphas.len()).then_some(AdaBoost { n_rounds, trees, alphas })
    }
}

impl Classifier for AdaBoost {
    fn name(&self) -> &'static str {
        "AdaBoost"
    }

    fn train(&mut self, x: &[[f64; N_FEATURES]], y: &[usize]) {
        assert_eq!(x.len(), y.len());
        let n = x.len();
        let mut w = vec![1.0 / n as f64; n];
        self.trees.clear();
        self.alphas.clear();

        for _ in 0..self.n_rounds {
            let mut tree = DecisionTree::new(WEAK_DEPTH, 4);
            tree.train_weighted(x, y, &w);
            // Weighted error.
            let mut err = 0.0;
            let preds: Vec<usize> = x.iter().map(|row| tree.predict(row)).collect();
            for i in 0..n {
                if preds[i] != y[i] {
                    err += w[i];
                }
            }
            let err = err.clamp(1e-12, 1.0);
            if err >= 0.5 {
                // Weak learner no better than chance: stop boosting.
                break;
            }
            let alpha = 0.5 * ((1.0 - err) / err).ln();
            // Reweight: misclassified up, correct down; renormalize.
            let mut z = 0.0;
            for i in 0..n {
                let agree = if preds[i] == y[i] { 1.0 } else { -1.0 };
                w[i] *= (-alpha * agree).exp();
                z += w[i];
            }
            for wi in &mut w {
                *wi /= z;
            }
            self.trees.push(tree);
            self.alphas.push(alpha);
            if err < 1e-10 {
                break; // perfectly separated
            }
        }
    }

    fn predict(&self, x: &[f64; N_FEATURES]) -> usize {
        usize::from(self.decision_function(x) > 0.0)
    }

    fn to_json(&self) -> Option<Json> {
        Some(Json::obj(vec![
            ("kind", Json::Str("adaboost".into())),
            ("n_rounds", Json::Num(self.n_rounds as f64)),
            ("alphas", Json::nums(self.alphas.iter().copied())),
            ("trees", Json::Arr(self.trees.iter().map(|t| t.to_json()).collect())),
        ]))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::classifier::metrics::accuracy;
    use crate::rng::Rng;

    /// XOR — solvable by depth-≥2 weak learners (stumps provably cannot).
    fn xor_data(n: usize, seed: u64) -> (Vec<[f64; 4]>, Vec<usize>) {
        let mut rng = Rng::new(seed);
        let mut x = Vec::with_capacity(n);
        let mut y = Vec::with_capacity(n);
        for _ in 0..n {
            let a = rng.f64();
            let b = rng.f64();
            x.push([a, b, rng.f64() * 0.01, 0.0]);
            y.push(usize::from((a > 0.5) != (b > 0.5)));
        }
        (x, y)
    }

    #[test]
    fn boosted_trees_solve_xor() {
        let (x, y) = xor_data(400, 3);
        let mut boosted = AdaBoost::new(60);
        boosted.train(&x, &y);
        let acc = accuracy(&boosted.predict_batch(&x), &y);
        assert!(acc > 0.95, "XOR should be solved by boosted trees, got {acc}");
    }

    #[test]
    fn boosting_improves_over_one_weak_learner() {
        // Diagonal boundary: one depth-3 tree staircases coarsely; boosting
        // refines it.
        let mut rng = Rng::new(8);
        let mut x = Vec::new();
        let mut y = Vec::new();
        for _ in 0..600 {
            let a = rng.f64();
            let b = rng.f64();
            x.push([a, b, 0.0, 0.0]);
            y.push(usize::from(a + b > 1.0));
        }
        let mut single = AdaBoost::new(1);
        single.train(&x, &y);
        let mut many = AdaBoost::new(80);
        many.train(&x, &y);
        let a1 = accuracy(&single.predict_batch(&x), &y);
        let a80 = accuracy(&many.predict_batch(&x), &y);
        assert!(a80 > a1, "boosting must help: {a1} → {a80}");
        assert!(a80 > 0.97, "diagonal nearly solved, got {a80}");
    }

    #[test]
    fn separable_data_short_circuits() {
        let x: Vec<[f64; 4]> = (0..50).map(|i| [i as f64, 0.0, 0.0, 0.0]).collect();
        let y: Vec<usize> = (0..50).map(|i| usize::from(i >= 25)).collect();
        let mut ab = AdaBoost::new(100);
        ab.train(&x, &y);
        assert!(ab.trees.len() < 100, "perfect weak learner should stop boosting");
        assert_eq!(accuracy(&ab.predict_batch(&x), &y), 1.0);
    }

    #[test]
    fn json_roundtrip_preserves_decisions() {
        let (x, y) = xor_data(200, 9);
        let mut ab = AdaBoost::new(25);
        ab.train(&x, &y);
        let j = ab.to_json().unwrap();
        let text = j.to_string_compact();
        let back = AdaBoost::from_json(&Json::parse(&text).unwrap()).unwrap();
        for row in &x {
            assert_eq!(ab.predict(row), back.predict(row));
        }
    }
}
