//! Linear and quadratic discriminant analysis (closed-form, 4×4 Gaussian
//! class models).

use super::{Classifier, N_CLASSES, N_FEATURES};

type Mat = [[f64; N_FEATURES]; N_FEATURES];

/// Invert a 4×4 (symmetric PD in practice) matrix by Gauss–Jordan with
/// partial pivoting. Returns (inverse, log|det|); the caller regularizes
/// singular inputs beforehand.
fn invert(m: &Mat) -> Option<(Mat, f64)> {
    let n = N_FEATURES;
    let mut a = *m;
    let mut inv = [[0.0; N_FEATURES]; N_FEATURES];
    for (i, row) in inv.iter_mut().enumerate() {
        row[i] = 1.0;
    }
    let mut log_det = 0.0;
    for col in 0..n {
        // Pivot.
        let mut pivot = col;
        for r in col + 1..n {
            if a[r][col].abs() > a[pivot][col].abs() {
                pivot = r;
            }
        }
        if a[pivot][col].abs() < 1e-300 {
            return None;
        }
        if pivot != col {
            a.swap(pivot, col);
            inv.swap(pivot, col);
        }
        let p = a[col][col];
        log_det += p.abs().ln();
        for j in 0..n {
            a[col][j] /= p;
            inv[col][j] /= p;
        }
        for r in 0..n {
            if r != col {
                let f = a[r][col];
                if f != 0.0 {
                    for j in 0..n {
                        a[r][j] -= f * a[col][j];
                        inv[r][j] -= f * inv[col][j];
                    }
                }
            }
        }
    }
    Some((inv, log_det))
}

/// Per-class mean + covariance estimation with ridge regularization.
fn class_stats(
    x: &[[f64; N_FEATURES]],
    y: &[usize],
    pooled: bool,
) -> ([usize; N_CLASSES], [[f64; N_FEATURES]; N_CLASSES], [Mat; N_CLASSES]) {
    let mut count = [0usize; N_CLASSES];
    let mut mean = [[0.0; N_FEATURES]; N_CLASSES];
    for (row, &c) in x.iter().zip(y) {
        count[c] += 1;
        for j in 0..N_FEATURES {
            mean[c][j] += row[j];
        }
    }
    for c in 0..N_CLASSES {
        let n = count[c].max(1) as f64;
        for j in 0..N_FEATURES {
            mean[c][j] /= n;
        }
    }
    let mut cov = [[[0.0; N_FEATURES]; N_FEATURES]; N_CLASSES];
    for (row, &c) in x.iter().zip(y) {
        for j in 0..N_FEATURES {
            for k in 0..N_FEATURES {
                cov[c][j][k] += (row[j] - mean[c][j]) * (row[k] - mean[c][k]);
            }
        }
    }
    if pooled {
        // Sum both classes' scatter, divide by total, copy to both slots.
        let total = (count[0] + count[1]).max(1) as f64;
        let mut shared = [[0.0; N_FEATURES]; N_FEATURES];
        for c in 0..N_CLASSES {
            for j in 0..N_FEATURES {
                for k in 0..N_FEATURES {
                    shared[j][k] += cov[c][j][k] / total;
                }
            }
        }
        cov = [shared, shared];
    } else {
        for c in 0..N_CLASSES {
            let n = count[c].max(1) as f64;
            for j in 0..N_FEATURES {
                for k in 0..N_FEATURES {
                    cov[c][j][k] /= n;
                }
            }
        }
    }
    // Ridge.
    for c in 0..N_CLASSES {
        for (j, row) in cov[c].iter_mut().enumerate() {
            row[j] += 1e-6;
        }
    }
    (count, mean, cov)
}

/// Shared scoring core for LDA/QDA.
#[derive(Clone, Debug, Default)]
struct GaussianScorer {
    prior_log: [f64; N_CLASSES],
    mean: [[f64; N_FEATURES]; N_CLASSES],
    inv: [Mat; N_CLASSES],
    log_det: [f64; N_CLASSES],
}

impl GaussianScorer {
    fn fit(x: &[[f64; N_FEATURES]], y: &[usize], pooled: bool) -> Self {
        let (count, mean, cov) = class_stats(x, y, pooled);
        let total = x.len().max(1) as f64;
        let mut s = GaussianScorer { mean, ..Default::default() };
        for c in 0..N_CLASSES {
            s.prior_log[c] = ((count[c].max(1) as f64) / total).ln();
            let (inv, log_det) = invert(&cov[c]).expect("regularized covariance is invertible");
            s.inv[c] = inv;
            s.log_det[c] = log_det;
        }
        s
    }

    fn score(&self, c: usize, x: &[f64; N_FEATURES]) -> f64 {
        let mut d = [0.0; N_FEATURES];
        for j in 0..N_FEATURES {
            d[j] = x[j] - self.mean[c][j];
        }
        let mut maha = 0.0;
        for j in 0..N_FEATURES {
            for k in 0..N_FEATURES {
                maha += d[j] * self.inv[c][j][k] * d[k];
            }
        }
        self.prior_log[c] - 0.5 * (self.log_det[c] + maha)
    }

    fn predict(&self, x: &[f64; N_FEATURES]) -> usize {
        usize::from(self.score(1, x) > self.score(0, x))
    }
}

/// Linear discriminant analysis (pooled covariance).
#[derive(Default)]
pub struct Lda {
    scorer: Option<GaussianScorer>,
}

impl Lda {
    pub fn new() -> Self {
        Lda::default()
    }
}

impl Classifier for Lda {
    fn name(&self) -> &'static str {
        "LDA"
    }

    fn train(&mut self, x: &[[f64; N_FEATURES]], y: &[usize]) {
        self.scorer = Some(GaussianScorer::fit(x, y, true));
    }

    fn predict(&self, x: &[f64; N_FEATURES]) -> usize {
        self.scorer.as_ref().expect("train first").predict(x)
    }
}

/// Quadratic discriminant analysis (per-class covariance).
#[derive(Default)]
pub struct Qda {
    scorer: Option<GaussianScorer>,
}

impl Qda {
    pub fn new() -> Self {
        Qda::default()
    }
}

impl Classifier for Qda {
    fn name(&self) -> &'static str {
        "QDA"
    }

    fn train(&mut self, x: &[[f64; N_FEATURES]], y: &[usize]) {
        self.scorer = Some(GaussianScorer::fit(x, y, false));
    }

    fn predict(&self, x: &[f64; N_FEATURES]) -> usize {
        self.scorer.as_ref().expect("train first").predict(x)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::classifier::metrics::accuracy;
    use crate::rng::Rng;

    #[test]
    fn invert_identity_and_known() {
        let eye: Mat = {
            let mut m = [[0.0; 4]; 4];
            for (i, row) in m.iter_mut().enumerate() {
                row[i] = 1.0;
            }
            m
        };
        let (inv, log_det) = invert(&eye).unwrap();
        assert_eq!(inv, eye);
        assert!(log_det.abs() < 1e-12);

        // Diagonal matrix.
        let mut d = eye;
        d[0][0] = 2.0;
        d[1][1] = 4.0;
        let (inv, log_det) = invert(&d).unwrap();
        assert!((inv[0][0] - 0.5).abs() < 1e-12);
        assert!((inv[1][1] - 0.25).abs() < 1e-12);
        assert!((log_det - (8.0f64).ln()).abs() < 1e-12);
    }

    #[test]
    fn invert_roundtrips_random_spd() {
        let mut rng = Rng::new(50);
        for _ in 0..20 {
            // A^T A + I is SPD.
            let a: Mat = std::array::from_fn(|_| std::array::from_fn(|_| rng.normal()));
            let mut spd = [[0.0; 4]; 4];
            for i in 0..4 {
                for j in 0..4 {
                    for (k, row) in a.iter().enumerate() {
                        spd[i][j] += row[i] * a[k][j];
                    }
                }
                spd[i][i] += 1.0;
            }
            let (inv, _) = invert(&spd).unwrap();
            // spd * inv ≈ I.
            for i in 0..4 {
                for j in 0..4 {
                    let mut v = 0.0;
                    for k in 0..4 {
                        v += spd[i][k] * inv[k][j];
                    }
                    let want = if i == j { 1.0 } else { 0.0 };
                    assert!((v - want).abs() < 1e-8, "({i},{j}) = {v}");
                }
            }
        }
    }

    fn gaussian_blobs(n: usize, seed: u64) -> (Vec<[f64; 4]>, Vec<usize>) {
        let mut rng = Rng::new(seed);
        let mut x = Vec::new();
        let mut y = Vec::new();
        for _ in 0..n {
            let c = rng.below(2);
            let shift = if c == 1 { 2.5 } else { 0.0 };
            x.push([rng.normal() + shift, rng.normal(), rng.normal() - shift, rng.normal()]);
            y.push(c);
        }
        (x, y)
    }

    #[test]
    fn lda_separates_blobs() {
        let (x, y) = gaussian_blobs(500, 51);
        let mut lda = Lda::new();
        lda.train(&x, &y);
        let acc = accuracy(&lda.predict_batch(&x), &y);
        assert!(acc > 0.95, "LDA on shifted gaussians, got {acc}");
    }

    #[test]
    fn qda_beats_lda_on_unequal_covariances() {
        // Class 0 tight, class 1 wide, same mean: only covariance separates.
        let mut rng = Rng::new(52);
        let mut x = Vec::new();
        let mut y = Vec::new();
        for _ in 0..800 {
            let c = rng.below(2);
            let s = if c == 1 { 3.0 } else { 0.5 };
            x.push([rng.normal() * s, rng.normal() * s, rng.normal() * s, rng.normal() * s]);
            y.push(c);
        }
        let mut lda = Lda::new();
        lda.train(&x, &y);
        let mut qda = Qda::new();
        qda.train(&x, &y);
        let acc_l = accuracy(&lda.predict_batch(&x), &y);
        let acc_q = accuracy(&qda.predict_batch(&x), &y);
        assert!(acc_q > acc_l + 0.15, "QDA {acc_q} should beat LDA {acc_l} here");
        assert!(acc_q > 0.8);
    }
}
