//! Linear models: logistic regression (full-batch gradient descent) and a
//! linear SVM (hinge loss, SGD with L2 regularization, Pegasos-style).

use super::metrics::Standardizer;
use super::{Classifier, N_FEATURES};
use crate::rng::Rng;

fn sigmoid(z: f64) -> f64 {
    1.0 / (1.0 + (-z).exp())
}

/// L2-free full-batch logistic regression on standardized features.
pub struct LogisticRegression {
    pub epochs: usize,
    pub learning_rate: f64,
    scaler: Option<Standardizer>,
    w: [f64; N_FEATURES],
    b: f64,
}

impl LogisticRegression {
    pub fn new(epochs: usize, learning_rate: f64) -> Self {
        LogisticRegression {
            epochs,
            learning_rate,
            scaler: None,
            w: [0.0; N_FEATURES],
            b: 0.0,
        }
    }

    fn raw(&self, x: &[f64; N_FEATURES]) -> f64 {
        self.w.iter().zip(x).map(|(w, x)| w * x).sum::<f64>() + self.b
    }
}

impl Classifier for LogisticRegression {
    fn name(&self) -> &'static str {
        "Logistic Regression"
    }

    fn train(&mut self, x: &[[f64; N_FEATURES]], y: &[usize]) {
        let scaler = Standardizer::fit(x);
        let xs = scaler.apply_all(x);
        self.scaler = Some(scaler);
        self.w = [0.0; N_FEATURES];
        self.b = 0.0;
        let n = xs.len() as f64;
        for _ in 0..self.epochs {
            let mut gw = [0.0; N_FEATURES];
            let mut gb = 0.0;
            for (row, &label) in xs.iter().zip(y) {
                let err = sigmoid(self.raw(row)) - label as f64;
                for j in 0..N_FEATURES {
                    gw[j] += err * row[j];
                }
                gb += err;
            }
            for j in 0..N_FEATURES {
                self.w[j] -= self.learning_rate * gw[j] / n;
            }
            self.b -= self.learning_rate * gb / n;
        }
    }

    fn predict(&self, x: &[f64; N_FEATURES]) -> usize {
        let xs = self.scaler.as_ref().expect("train first").apply(x);
        usize::from(self.raw(&xs) > 0.0)
    }
}

/// Linear SVM via Pegasos SGD on the hinge loss.
pub struct LinearSvm {
    pub epochs: usize,
    pub learning_rate: f64,
    pub lambda: f64,
    pub seed: u64,
    scaler: Option<Standardizer>,
    w: [f64; N_FEATURES],
    b: f64,
}

impl LinearSvm {
    pub fn new(epochs: usize, learning_rate: f64, lambda: f64, seed: u64) -> Self {
        LinearSvm {
            epochs,
            learning_rate,
            lambda,
            seed,
            scaler: None,
            w: [0.0; N_FEATURES],
            b: 0.0,
        }
    }

    fn raw(&self, x: &[f64; N_FEATURES]) -> f64 {
        self.w.iter().zip(x).map(|(w, x)| w * x).sum::<f64>() + self.b
    }
}

impl Classifier for LinearSvm {
    fn name(&self) -> &'static str {
        "Linear SVM"
    }

    fn train(&mut self, x: &[[f64; N_FEATURES]], y: &[usize]) {
        let scaler = Standardizer::fit(x);
        let xs = scaler.apply_all(x);
        self.scaler = Some(scaler);
        self.w = [0.0; N_FEATURES];
        self.b = 0.0;
        let mut rng = Rng::new(self.seed);
        let mut order: Vec<usize> = (0..xs.len()).collect();
        let mut t = 1.0f64;
        for _ in 0..self.epochs {
            rng.shuffle(&mut order);
            for &i in &order {
                let eta = self.learning_rate / (1.0 + self.learning_rate * self.lambda * t);
                let ypm = if y[i] == 1 { 1.0 } else { -1.0 };
                let margin = ypm * self.raw(&xs[i]);
                // L2 shrink.
                for w in &mut self.w {
                    *w *= 1.0 - eta * self.lambda;
                }
                if margin < 1.0 {
                    for j in 0..N_FEATURES {
                        self.w[j] += eta * ypm * xs[i][j];
                    }
                    self.b += eta * ypm;
                }
                t += 1.0;
            }
        }
    }

    fn predict(&self, x: &[f64; N_FEATURES]) -> usize {
        let xs = self.scaler.as_ref().expect("train first").apply(x);
        usize::from(self.raw(&xs) > 0.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::classifier::metrics::accuracy;
    use crate::rng::Rng;

    fn linear_data(n: usize, seed: u64, margin: f64) -> (Vec<[f64; 4]>, Vec<usize>) {
        let mut rng = Rng::new(seed);
        let mut x = Vec::new();
        let mut y = Vec::new();
        while x.len() < n {
            let row = [rng.f64(), rng.f64(), rng.f64(), rng.f64()];
            let score = 2.0 * row[0] - row[1] + 0.5 * row[2] - 0.6;
            if score.abs() < margin {
                continue; // enforce a margin band
            }
            x.push(row);
            y.push(usize::from(score > 0.0));
        }
        (x, y)
    }

    #[test]
    fn logistic_learns_linear_boundary() {
        let (x, y) = linear_data(500, 40, 0.05);
        let mut lr = LogisticRegression::new(300, 0.5);
        lr.train(&x, &y);
        let acc = accuracy(&lr.predict_batch(&x), &y);
        assert!(acc > 0.97, "logistic on separable data, got {acc}");
    }

    #[test]
    fn svm_learns_linear_boundary() {
        let (x, y) = linear_data(500, 41, 0.05);
        let mut svm = LinearSvm::new(100, 0.1, 1e-4, 1);
        svm.train(&x, &y);
        let acc = accuracy(&svm.predict_batch(&x), &y);
        assert!(acc > 0.97, "svm on separable data, got {acc}");
    }

    #[test]
    fn svm_training_is_seed_deterministic() {
        let (x, y) = linear_data(200, 42, 0.05);
        let mut a = LinearSvm::new(20, 0.1, 1e-4, 7);
        let mut b = LinearSvm::new(20, 0.1, 1e-4, 7);
        a.train(&x, &y);
        b.train(&x, &y);
        assert_eq!(a.predict_batch(&x), b.predict_batch(&x));
    }

    #[test]
    fn logistic_balanced_prior_gives_half_split_on_noise() {
        // On pure noise the classifier should not collapse to one class
        // when classes are balanced.
        let mut rng = Rng::new(44);
        let x: Vec<[f64; 4]> =
            (0..400).map(|_| [rng.f64(), rng.f64(), rng.f64(), rng.f64()]).collect();
        let y: Vec<usize> = (0..400).map(|i| i % 2).collect();
        let mut lr = LogisticRegression::new(50, 0.5);
        lr.train(&x, &y);
        let ones: usize = lr.predict_batch(&x).iter().sum();
        assert!(ones > 50 && ones < 350, "degenerate collapse: {ones}/400 ones");
    }
}
