//! Gaussian naive Bayes.

use super::{Classifier, N_CLASSES, N_FEATURES};

/// Per-class independent Gaussians with class priors.
#[derive(Clone, Debug, Default)]
pub struct GaussianNb {
    prior_log: [f64; N_CLASSES],
    mean: [[f64; N_FEATURES]; N_CLASSES],
    var: [[f64; N_FEATURES]; N_CLASSES],
}

impl GaussianNb {
    pub fn new() -> Self {
        GaussianNb::default()
    }

    fn log_likelihood(&self, class: usize, x: &[f64; N_FEATURES]) -> f64 {
        let mut ll = self.prior_log[class];
        for j in 0..N_FEATURES {
            let var = self.var[class][j];
            let d = x[j] - self.mean[class][j];
            ll += -0.5 * ((2.0 * std::f64::consts::PI * var).ln() + d * d / var);
        }
        ll
    }
}

impl Classifier for GaussianNb {
    fn name(&self) -> &'static str {
        "Gaussian NB"
    }

    fn train(&mut self, x: &[[f64; N_FEATURES]], y: &[usize]) {
        let mut count = [0usize; N_CLASSES];
        let mut mean = [[0.0; N_FEATURES]; N_CLASSES];
        for (row, &c) in x.iter().zip(y) {
            count[c] += 1;
            for j in 0..N_FEATURES {
                mean[c][j] += row[j];
            }
        }
        for c in 0..N_CLASSES {
            let n = count[c].max(1) as f64;
            for j in 0..N_FEATURES {
                mean[c][j] /= n;
            }
        }
        let mut var = [[0.0; N_FEATURES]; N_CLASSES];
        for (row, &c) in x.iter().zip(y) {
            for j in 0..N_FEATURES {
                let d = row[j] - mean[c][j];
                var[c][j] += d * d;
            }
        }
        for c in 0..N_CLASSES {
            let n = count[c].max(1) as f64;
            for j in 0..N_FEATURES {
                // Variance smoothing à la sklearn (1e-9 of max variance is
                // too data-dependent; a small absolute floor suffices here).
                var[c][j] = (var[c][j] / n).max(1e-9);
            }
        }
        let total = x.len().max(1) as f64;
        for c in 0..N_CLASSES {
            self.prior_log[c] = ((count[c].max(1) as f64) / total).ln();
        }
        self.mean = mean;
        self.var = var;
    }

    fn predict(&self, x: &[f64; N_FEATURES]) -> usize {
        usize::from(self.log_likelihood(1, x) > self.log_likelihood(0, x))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::classifier::metrics::accuracy;
    use crate::rng::Rng;

    #[test]
    fn separates_shifted_gaussians() {
        let mut rng = Rng::new(30);
        let mut x = Vec::new();
        let mut y = Vec::new();
        for _ in 0..400 {
            let c = rng.below(2);
            let shift = if c == 1 { 3.0 } else { 0.0 };
            x.push([
                rng.normal() + shift,
                rng.normal() - shift,
                rng.normal(),
                rng.normal(),
            ]);
            y.push(c);
        }
        let mut nb = GaussianNb::new();
        nb.train(&x, &y);
        let acc = accuracy(&nb.predict_batch(&x), &y);
        assert!(acc > 0.95, "well-separated gaussians, got {acc}");
    }

    #[test]
    fn respects_priors_when_features_useless() {
        let mut rng = Rng::new(31);
        let x: Vec<[f64; 4]> = (0..200)
            .map(|_| [rng.normal(), rng.normal(), rng.normal(), rng.normal()])
            .collect();
        // 90% class 0.
        let y: Vec<usize> = (0..200).map(|i| usize::from(i % 10 == 0)).collect();
        let mut nb = GaussianNb::new();
        nb.train(&x, &y);
        let preds = nb.predict_batch(&x);
        let zeros = preds.iter().filter(|&&p| p == 0).count();
        assert!(zeros > 150, "prior should dominate on noise features");
    }

    #[test]
    fn zero_variance_feature_does_not_nan() {
        let x = vec![[1.0, 5.0, 0.0, 0.0]; 10];
        let y: Vec<usize> = (0..10).map(|i| i % 2).collect();
        let mut nb = GaussianNb::new();
        nb.train(&x, &y);
        let p = nb.predict(&[1.0, 5.0, 0.0, 0.0]);
        assert!(p < 2);
    }
}
