//! Fault model over the machine: dead PEs, dead chips, degraded NoC links.
//!
//! SpiNNaker2-class machines are large enough that dead resources are an
//! operational fact, not an edge case (the 10M-core system paper budgets
//! for them explicitly). This module gives the mapping stack a first-class
//! fault vocabulary:
//!
//! * [`FaultMap`] — the set of resources planning must never place on:
//!   dead PEs, whole dead chips, and degraded inter-chip links (a latency
//!   multiplier the NoC estimator can price). Loadable from a JSON file
//!   (`simulate --fault-map`) and mutable at runtime as faults are
//!   detected.
//! * [`FaultSchedule`] — a deterministic, seeded mid-run fault injector:
//!   each sample boundary draws (seed-reproducibly) whether a fault fires
//!   and which victim PE it kills. Two runs with the same seed, rate, and
//!   victim list produce bit-identical [`FaultEvent`] sequences — the
//!   chaos-test contract CI enforces.
//! * [`FaultEvent`] / [`FaultError`] — the typed currency of the recovery
//!   state machine in `switching::recovery` (detect → rollback → re-admit
//!   → re-materialize → re-place → replay; DESIGN.md §Fault-Tolerance).
//!   Unsurvivable faults surface as a typed error and a per-layer
//!   `Skipped` status, never a panic or a silently wrong answer.

use super::machine::PeHandle;
use super::spec::MachineSpec;
use crate::io::json::Json;
use crate::rng::Rng;
use std::collections::{BTreeMap, BTreeSet};
use std::fmt;
use std::path::Path;

/// An undirected inter-chip link, stored with endpoints sorted so
/// `(a, b)` and `(b, a)` name the same link.
pub type ChipLink = ((usize, usize), (usize, usize));

fn link_key(a: (usize, usize), b: (usize, usize)) -> ChipLink {
    if a <= b {
        (a, b)
    } else {
        (b, a)
    }
}

/// Typed fault-path failure. Recovery code returns these instead of
/// panicking; the CLI and the run report render them with full context.
#[derive(Debug)]
pub enum FaultError {
    /// The `--fault-map` file could not be read.
    Io { path: String, source: std::io::Error },
    /// The `--fault-map` file parsed but is not a valid fault map.
    BadFaultMap { path: String, detail: String },
    /// A fault names a resource outside the machine.
    OutOfRange { what: &'static str, detail: String },
    /// Recovery found no feasible re-placement for a layer on the
    /// surviving machine (the degraded-mode trigger, not a crash).
    NoFeasiblePlacement { layer: usize, detail: String },
    /// A replacement layer could not be re-materialized.
    Rematerialize { layer: usize, detail: String },
}

impl fmt::Display for FaultError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            FaultError::Io { path, source } => {
                write!(f, "fault map {path}: {source}")
            }
            FaultError::BadFaultMap { path, detail } => {
                write!(f, "fault map {path}: {detail}")
            }
            FaultError::OutOfRange { what, detail } => {
                write!(f, "fault targets nonexistent {what}: {detail}")
            }
            FaultError::NoFeasiblePlacement { layer, detail } => {
                write!(f, "no feasible re-placement for layer {layer}: {detail}")
            }
            FaultError::Rematerialize { layer, detail } => {
                write!(f, "re-materializing layer {layer}: {detail}")
            }
        }
    }
}

impl std::error::Error for FaultError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            FaultError::Io { source, .. } => Some(source),
            _ => None,
        }
    }
}

/// The set of faulted resources planning must route around.
///
/// Dead chips subsume their PEs: a PE is faulted when it is listed dead
/// *or* its chip is. Degraded links carry a latency multiplier ≥ 1 that
/// the NoC traffic estimator applies per traversal.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct FaultMap {
    dead_pes: BTreeSet<PeHandle>,
    dead_chips: BTreeSet<(usize, usize)>,
    degraded_links: BTreeMap<ChipLink, f64>,
}

impl FaultMap {
    /// A pristine machine: nothing faulted.
    pub fn healthy() -> Self {
        FaultMap::default()
    }

    pub fn is_empty(&self) -> bool {
        self.dead_pes.is_empty() && self.dead_chips.is_empty() && self.degraded_links.is_empty()
    }

    /// Mark one PE dead. Returns `true` if it was previously healthy.
    pub fn kill_pe(&mut self, pe: PeHandle) -> bool {
        let fresh = !self.is_pe_dead(pe);
        self.dead_pes.insert(pe);
        fresh
    }

    /// Mark a whole chip (all its PEs) dead.
    pub fn kill_chip(&mut self, chip_x: usize, chip_y: usize) {
        self.dead_chips.insert((chip_x, chip_y));
    }

    /// Degrade the inter-chip link between `a` and `b` by `factor` (≥ 1;
    /// a traversal costs `factor ×` the healthy latency). Direction does
    /// not matter.
    pub fn degrade_link(&mut self, a: (usize, usize), b: (usize, usize), factor: f64) {
        self.degraded_links.insert(link_key(a, b), factor.max(1.0));
    }

    /// Is this PE unusable (listed dead, or on a dead chip)?
    pub fn is_pe_dead(&self, pe: PeHandle) -> bool {
        self.dead_pes.contains(&pe) || self.dead_chips.contains(&(pe.chip_x, pe.chip_y))
    }

    pub fn is_chip_dead(&self, chip_x: usize, chip_y: usize) -> bool {
        self.dead_chips.contains(&(chip_x, chip_y))
    }

    /// Latency multiplier for the link `a`↔`b` (1.0 when healthy).
    pub fn link_factor(&self, a: (usize, usize), b: (usize, usize)) -> f64 {
        self.degraded_links.get(&link_key(a, b)).copied().unwrap_or(1.0)
    }

    /// Individually-dead PEs (dead chips not expanded).
    pub fn dead_pes(&self) -> impl Iterator<Item = PeHandle> + '_ {
        self.dead_pes.iter().copied()
    }

    pub fn dead_chips(&self) -> impl Iterator<Item = (usize, usize)> + '_ {
        self.dead_chips.iter().copied()
    }

    pub fn n_dead_pes(&self) -> usize {
        self.dead_pes.len()
    }

    pub fn n_dead_chips(&self) -> usize {
        self.dead_chips.len()
    }

    pub fn n_degraded_links(&self) -> usize {
        self.degraded_links.len()
    }

    /// How many PEs of a `spec`-sized machine this map rules out (dead
    /// chips expand to their PE count; out-of-grid faults count zero).
    /// Admission uses this to shrink its capacity headroom.
    pub fn dead_pe_count(&self, spec: &MachineSpec) -> usize {
        let per_chip = spec.chip.pes_per_chip;
        let chips = self
            .dead_chips
            .iter()
            .filter(|&&(x, y)| x < spec.total_chips_x() && y < spec.chips_y)
            .count();
        let lone = self
            .dead_pes
            .iter()
            .filter(|pe| {
                pe.chip_x < spec.total_chips_x() && pe.chip_y < spec.chips_y && pe.core < per_chip
            })
            .filter(|pe| !self.dead_chips.contains(&(pe.chip_x, pe.chip_y)))
            .count();
        chips * per_chip + lone
    }

    /// Parse the `--fault-map` JSON schema:
    ///
    /// ```json
    /// {
    ///   "dead_pes":       [{"chip_x": 0, "chip_y": 0, "core": 3}],
    ///   "dead_chips":     [{"x": 1, "y": 0}],
    ///   "degraded_links": [{"ax": 0, "ay": 0, "bx": 1, "by": 0, "factor": 2.5}]
    /// }
    /// ```
    ///
    /// Every section is optional; unknown keys are rejected so a typo'd
    /// map fails loudly instead of silently faulting nothing.
    pub fn from_json(text: &str, origin: &str) -> Result<FaultMap, FaultError> {
        let bad = |detail: String| FaultError::BadFaultMap {
            path: origin.to_string(),
            detail,
        };
        let json = Json::parse(text).map_err(|e| bad(format!("invalid JSON: {e}")))?;
        let Json::Obj(fields) = &json else {
            return Err(bad("top level must be an object".into()));
        };
        for key in fields.keys() {
            if !matches!(key.as_str(), "dead_pes" | "dead_chips" | "degraded_links") {
                return Err(bad(format!(
                    "unknown key '{key}' (want dead_pes/dead_chips/degraded_links)"
                )));
            }
        }
        let arr = |key: &str| -> Result<&[Json], FaultError> {
            match json.get(key) {
                None => Ok(&[]),
                Some(Json::Arr(items)) => Ok(items.as_slice()),
                Some(_) => Err(bad(format!("'{key}' must be an array"))),
            }
        };
        let field = |obj: &Json, section: &str, key: &str| -> Result<usize, FaultError> {
            let v = obj.get(key).and_then(Json::as_f64).ok_or_else(|| {
                bad(format!("{section} entry: missing numeric '{key}'"))
            })?;
            if v < 0.0 || v.fract() != 0.0 {
                return Err(bad(format!(
                    "{section} entry: '{key}' must be a non-negative integer, got {v}"
                )));
            }
            Ok(v as usize)
        };

        let mut map = FaultMap::healthy();
        for item in arr("dead_pes")? {
            map.dead_pes.insert(PeHandle {
                chip_x: field(item, "dead_pes", "chip_x")?,
                chip_y: field(item, "dead_pes", "chip_y")?,
                core: field(item, "dead_pes", "core")?,
            });
        }
        for item in arr("dead_chips")? {
            map.dead_chips.insert((
                field(item, "dead_chips", "x")?,
                field(item, "dead_chips", "y")?,
            ));
        }
        for item in arr("degraded_links")? {
            let factor = item
                .get("factor")
                .and_then(Json::as_f64)
                .ok_or_else(|| bad("degraded_links entry: missing numeric 'factor'".into()))?;
            if !factor.is_finite() || factor < 1.0 {
                return Err(bad(format!(
                    "degraded_links entry: factor must be finite and >= 1, got {factor}"
                )));
            }
            let a = (
                field(item, "degraded_links", "ax")?,
                field(item, "degraded_links", "ay")?,
            );
            let b = (
                field(item, "degraded_links", "bx")?,
                field(item, "degraded_links", "by")?,
            );
            map.degrade_link(a, b, factor);
        }
        Ok(map)
    }

    /// Load a fault map from a `--fault-map` JSON file.
    pub fn load(path: &Path) -> Result<FaultMap, FaultError> {
        let text = std::fs::read_to_string(path).map_err(|source| FaultError::Io {
            path: path.display().to_string(),
            source,
        })?;
        FaultMap::from_json(&text, &path.display().to_string())
    }

    /// Serialize back to the [`FaultMap::from_json`] schema (report/debug
    /// output; lossless round trip modulo float formatting).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            (
                "dead_pes",
                Json::Arr(
                    self.dead_pes
                        .iter()
                        .map(|pe| {
                            Json::obj(vec![
                                ("chip_x", Json::Num(pe.chip_x as f64)),
                                ("chip_y", Json::Num(pe.chip_y as f64)),
                                ("core", Json::Num(pe.core as f64)),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "dead_chips",
                Json::Arr(
                    self.dead_chips
                        .iter()
                        .map(|&(x, y)| {
                            Json::obj(vec![
                                ("x", Json::Num(x as f64)),
                                ("y", Json::Num(y as f64)),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "degraded_links",
                Json::Arr(
                    self.degraded_links
                        .iter()
                        .map(|(&((ax, ay), (bx, by)), &factor)| {
                            Json::obj(vec![
                                ("ax", Json::Num(ax as f64)),
                                ("ay", Json::Num(ay as f64)),
                                ("bx", Json::Num(bx as f64)),
                                ("by", Json::Num(by as f64)),
                                ("factor", Json::Num(factor)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }
}

/// One injected fault: a PE died at a sample boundary.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct FaultEvent {
    /// Sample index at whose boundary the fault fired.
    pub sample: u64,
    /// The PE that died.
    pub pe: PeHandle,
}

/// Deterministic seeded mid-run fault injector.
///
/// At each sample boundary the caller offers the list of currently
/// *occupied, healthy* PEs (sorted — `Vec<PeHandle>` from a `BTreeSet`
/// or placement order); with probability `rate` the schedule kills one of
/// them, chosen uniformly from the offered list. The draw stream is a
/// pure function of the seed, so identical runs inject identical faults —
/// the determinism CI's chaos test asserts.
#[derive(Clone, Debug)]
pub struct FaultSchedule {
    rng: Rng,
    rate: f64,
    injected: usize,
}

impl FaultSchedule {
    /// `rate` is the per-sample fault probability, clamped to [0, 1].
    pub fn new(seed: u64, rate: f64) -> Self {
        FaultSchedule {
            rng: Rng::new(seed ^ 0xfa17_fa17_fa17_fa17),
            rate: rate.clamp(0.0, 1.0),
            injected: 0,
        }
    }

    pub fn rate(&self) -> f64 {
        self.rate
    }

    /// Faults injected so far.
    pub fn injected(&self) -> usize {
        self.injected
    }

    /// Draw the fault decision for the boundary before `sample`.
    /// `victims` are the PEs eligible to die (occupied and healthy);
    /// an empty list means nothing can fault this round. One uniform
    /// draw is consumed for the fire decision and, when it fires, one
    /// more for victim choice — so the stream stays aligned across runs
    /// regardless of outcome order.
    pub fn draw(&mut self, sample: u64, victims: &[PeHandle]) -> Option<FaultEvent> {
        if !self.rng.chance(self.rate) || victims.is_empty() {
            return None;
        }
        let idx = self.rng.below(victims.len());
        self.injected += 1;
        Some(FaultEvent { sample, pe: victims[idx] })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pe(x: usize, y: usize, core: usize) -> PeHandle {
        PeHandle { chip_x: x, chip_y: y, core }
    }

    #[test]
    fn dead_chip_subsumes_its_pes() {
        let mut map = FaultMap::healthy();
        assert!(map.is_empty());
        map.kill_chip(1, 0);
        assert!(map.is_pe_dead(pe(1, 0, 17)));
        assert!(!map.is_pe_dead(pe(0, 0, 17)));
        assert!(map.is_chip_dead(1, 0));
        assert!(!map.is_empty());
    }

    #[test]
    fn kill_pe_reports_freshness() {
        let mut map = FaultMap::healthy();
        assert!(map.kill_pe(pe(0, 0, 3)));
        assert!(!map.kill_pe(pe(0, 0, 3)), "second kill is stale");
        map.kill_chip(2, 2);
        assert!(!map.kill_pe(pe(2, 2, 9)), "already dead via chip");
        assert_eq!(map.n_dead_pes(), 2);
    }

    #[test]
    fn link_degradation_is_symmetric() {
        let mut map = FaultMap::healthy();
        map.degrade_link((0, 0), (1, 0), 2.5);
        assert_eq!(map.link_factor((1, 0), (0, 0)), 2.5);
        assert_eq!(map.link_factor((0, 0), (1, 0)), 2.5);
        assert_eq!(map.link_factor((0, 0), (0, 1)), 1.0);
        assert_eq!(map.n_degraded_links(), 1);
    }

    #[test]
    fn dead_pe_count_expands_chips_and_ignores_out_of_grid() {
        let spec = MachineSpec { chips_x: 2, chips_y: 2, ..Default::default() };
        let per_chip = spec.chip.pes_per_chip;
        let mut map = FaultMap::healthy();
        map.kill_chip(0, 1);
        map.kill_pe(pe(0, 1, 3)); // subsumed by its dead chip
        map.kill_pe(pe(1, 1, 7)); // counts alone
        map.kill_pe(pe(9, 9, 0)); // outside the 2x2 grid
        map.kill_chip(5, 5); // outside the grid
        map.kill_pe(pe(0, 0, per_chip + 1)); // core beyond the chip
        assert_eq!(map.dead_pe_count(&spec), per_chip + 1);
        assert_eq!(FaultMap::healthy().dead_pe_count(&spec), 0);
    }

    #[test]
    fn json_roundtrip_is_lossless() {
        let mut map = FaultMap::healthy();
        map.kill_pe(pe(0, 0, 3));
        map.kill_pe(pe(3, 2, 151));
        map.kill_chip(1, 1);
        map.degrade_link((0, 0), (1, 0), 4.0);
        let text = map.to_json().to_string_compact();
        let back = FaultMap::from_json(&text, "test").unwrap();
        assert_eq!(back, map);
    }

    #[test]
    fn json_rejects_malformed_maps() {
        let cases = [
            ("not json", "invalid JSON"),
            ("[1,2]", "top level"),
            (r#"{"dead_pe":[]}"#, "unknown key"),
            (r#"{"dead_pes":{"chip_x":0}}"#, "must be an array"),
            (r#"{"dead_pes":[{"chip_x":0,"chip_y":0}]}"#, "missing numeric 'core'"),
            (r#"{"dead_pes":[{"chip_x":0.5,"chip_y":0,"core":1}]}"#, "non-negative integer"),
            (r#"{"dead_chips":[{"x":-1,"y":0}]}"#, "non-negative integer"),
            (
                r#"{"degraded_links":[{"ax":0,"ay":0,"bx":1,"by":0,"factor":0.5}]}"#,
                "factor must be finite and >= 1",
            ),
        ];
        for (text, want) in cases {
            let err = FaultMap::from_json(text, "t").unwrap_err();
            let msg = err.to_string();
            assert!(msg.contains(want), "for {text:?}: got {msg:?}, want {want:?}");
            assert!(matches!(err, FaultError::BadFaultMap { .. }));
        }
    }

    #[test]
    fn missing_file_is_a_typed_io_error() {
        let err = FaultMap::load(Path::new("/definitely/not/here.json")).unwrap_err();
        assert!(matches!(err, FaultError::Io { .. }));
        assert!(err.to_string().contains("not/here.json"));
    }

    #[test]
    fn schedule_is_deterministic_per_seed() {
        let victims: Vec<PeHandle> = (0..10).map(|c| pe(0, 0, c)).collect();
        let run = |seed| {
            let mut sched = FaultSchedule::new(seed, 0.5);
            (0..64).map(|s| sched.draw(s, &victims)).collect::<Vec<_>>()
        };
        assert_eq!(run(7), run(7), "same seed must replay identically");
        assert_ne!(run(7), run(8), "different seeds must differ");
        let events: Vec<FaultEvent> = run(7).into_iter().flatten().collect();
        assert!(!events.is_empty(), "rate 0.5 over 64 samples must fire");
        assert!(events.iter().all(|e| victims.contains(&e.pe)));
    }

    #[test]
    fn zero_rate_never_fires_and_empty_victims_cannot() {
        let victims = vec![pe(0, 0, 0)];
        let mut sched = FaultSchedule::new(1, 0.0);
        assert!((0..100).all(|s| sched.draw(s, &victims).is_none()));
        let mut sched = FaultSchedule::new(1, 1.0);
        assert!(sched.draw(0, &[]).is_none(), "no victims, no fault");
        assert_eq!(sched.injected(), 0);
        assert!(sched.draw(1, &victims).is_some());
        assert_eq!(sched.injected(), 1);
    }
}
