//! Network-on-Chip model.
//!
//! SpiNNaker-family machines deliver spikes as multicast packets routed by
//! per-chip routing tables. For the functional simulator we model the NoC at
//! the level the paper's evaluation needs: deterministic delivery with a
//! hop-count latency estimate (intra-chip hop + XY routing between chips),
//! plus multicast fan-out from one source PE to a set of sink PEs. This is a
//! timing *model*, not a cycle-accurate router — the paper's results are
//! memory/PE-count results and the simulator only needs causally-correct
//! spike delivery with plausible latency accounting.

use super::machine::PeHandle;
use std::collections::BTreeSet;

/// NoC timing constants (rough SpiNNaker2-class numbers; configurable).
#[derive(Clone, Copy, Debug)]
pub struct NocConfig {
    /// Latency for a packet that stays on-chip (ns).
    pub intra_chip_ns: u64,
    /// Additional latency per inter-chip hop (ns).
    pub per_hop_ns: u64,
    /// Router fan-out cost per additional multicast target (ns).
    pub per_target_ns: u64,
    /// Additional latency when an x hop crosses between adjacent boards of
    /// a board array (ns) — board-level links are an order of magnitude
    /// slower than on-board chip links.
    pub per_board_link_ns: u64,
    /// Chip columns per board (board boundaries sit at multiples of this
    /// along x). `0` = no board boundaries: every hop is on-board, the
    /// single-machine seed behavior.
    pub board_chips_x: usize,
}

impl Default for NocConfig {
    fn default() -> Self {
        NocConfig {
            intra_chip_ns: 100,
            per_hop_ns: 40,
            per_target_ns: 10,
            per_board_link_ns: 400,
            board_chips_x: 0,
        }
    }
}

/// Multicast tree link counts split by link class: on-board x-then-y chip
/// links vs board-level links (x hops that cross a board boundary).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct TreeHops {
    /// Links staying within one board's chip grid.
    pub on_board: u64,
    /// Links crossing between adjacent boards.
    pub board_links: u64,
}

impl TreeHops {
    /// All tree links regardless of class (the pre-board-array hop count).
    pub fn total(&self) -> u64 {
        self.on_board + self.board_links
    }
}

impl std::ops::AddAssign for TreeHops {
    fn add_assign(&mut self, rhs: TreeHops) {
        self.on_board += rhs.on_board;
        self.board_links += rhs.board_links;
    }
}

/// Hop-count + latency NoC model.
#[derive(Clone, Debug, Default)]
pub struct Noc {
    pub config: NocConfig,
    /// Cumulative packets sent (telemetry).
    pub packets: u64,
    /// Cumulative hop count (telemetry; board links included).
    pub hops: u64,
    /// Cumulative board-link crossings (telemetry; subset of `hops`).
    pub board_hops: u64,
}

impl Noc {
    pub fn new(config: NocConfig) -> Self {
        Noc { config, packets: 0, hops: 0, board_hops: 0 }
    }

    /// Manhattan (XY-routing) hop distance between two PEs' chips.
    pub fn hop_distance(a: PeHandle, b: PeHandle) -> u64 {
        let dx = a.chip_x.abs_diff(b.chip_x) as u64;
        let dy = a.chip_y.abs_diff(b.chip_y) as u64;
        dx + dy
    }

    /// Latency estimate for a unicast packet from `src` to `dst` (board
    /// crossings along x are charged `per_board_link_ns` each on top of the
    /// per-hop cost when the config carries board boundaries).
    pub fn unicast_latency_ns(&self, src: PeHandle, dst: PeHandle) -> u64 {
        let crossings = match self.config.board_chips_x {
            0 => 0,
            w => (src.chip_x / w).abs_diff(dst.chip_x / w) as u64,
        };
        self.config.intra_chip_ns
            + Self::hop_distance(src, dst) * self.config.per_hop_ns
            + crossings * self.config.per_board_link_ns
    }

    /// Inter-chip links one multicast packet traverses under x-then-y
    /// dimension-ordered routing: the packet travels the x axis first, then
    /// the y axis, and duplicates at branch points — shared trunk segments
    /// are charged **once**, not once per destination. This is what makes
    /// chip-packed placements measurably cheaper than scattered ones.
    pub fn multicast_tree_hops(src: PeHandle, targets: &[PeHandle]) -> u64 {
        Self::multicast_tree_hops_split(src, targets, 0).total()
    }

    /// [`Noc::multicast_tree_hops`] with link classification: an x link
    /// between columns `x` and `x+1` is a **board link** when the two
    /// columns belong to different boards of `board_chips_x`-column boards
    /// (`board_chips_x == 0` = no board boundaries, everything on-board).
    /// Shared trunk segments are still charged once per class.
    pub fn multicast_tree_hops_split(
        src: PeHandle,
        targets: &[PeHandle],
        board_chips_x: usize,
    ) -> TreeHops {
        let mut links: BTreeSet<((usize, usize), (usize, usize))> = BTreeSet::new();
        for dst in targets {
            let (mut x, mut y) = (src.chip_x, src.chip_y);
            while x != dst.chip_x {
                let nx = if dst.chip_x > x { x + 1 } else { x - 1 };
                links.insert(((x, y), (nx, y)));
                x = nx;
            }
            while y != dst.chip_y {
                let ny = if dst.chip_y > y { y + 1 } else { y - 1 };
                links.insert(((x, y), (x, ny)));
                y = ny;
            }
        }
        let mut hops = TreeHops::default();
        for &((ax, _), (bx, _)) in &links {
            let crosses = board_chips_x > 0 && ax / board_chips_x != bx / board_chips_x;
            if crosses {
                hops.board_links += 1;
            } else {
                hops.on_board += 1;
            }
        }
        hops
    }

    /// Deliver a multicast packet; returns per-target latencies in the order
    /// of `targets`. Updates telemetry counters (hop telemetry charges the
    /// x-then-y multicast tree, not the per-destination Manhattan sum).
    pub fn multicast(&mut self, src: PeHandle, targets: &[PeHandle]) -> Vec<u64> {
        self.multicast_scaled(src, targets, 1)
    }

    /// Deliver `count` identical multicast packets, charging telemetry for
    /// all of them; returns one packet's per-target latencies. This is the
    /// traffic estimator's bulk path (N spikes along one routing entry).
    pub fn multicast_scaled(&mut self, src: PeHandle, targets: &[PeHandle], count: u64) -> Vec<u64> {
        self.packets += count;
        let tree = Self::multicast_tree_hops_split(src, targets, self.config.board_chips_x);
        self.hops += count * tree.total();
        self.board_hops += count * tree.board_links;
        targets
            .iter()
            .enumerate()
            .map(|(i, &dst)| {
                self.unicast_latency_ns(src, dst) + i as u64 * self.config.per_target_ns
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pe(x: usize, y: usize, core: usize) -> PeHandle {
        PeHandle { chip_x: x, chip_y: y, core }
    }

    #[test]
    fn same_chip_zero_hops() {
        assert_eq!(Noc::hop_distance(pe(0, 0, 1), pe(0, 0, 99)), 0);
    }

    #[test]
    fn xy_distance() {
        assert_eq!(Noc::hop_distance(pe(0, 0, 0), pe(3, 4, 0)), 7);
    }

    #[test]
    fn multicast_latency_monotone_in_target_index() {
        let mut noc = Noc::new(NocConfig::default());
        let lat = noc.multicast(pe(0, 0, 0), &[pe(0, 0, 1), pe(0, 0, 2), pe(0, 0, 3)]);
        assert!(lat[0] < lat[1] && lat[1] < lat[2]);
        assert_eq!(noc.packets, 1);
    }

    #[test]
    fn tree_hops_charge_shared_trunk_once() {
        // (0,0) → {(3,0), (3,1)}: the 3-link x trunk is shared; only the
        // final y branch is extra. Per-destination Manhattan would be 3+4=7.
        let hops = Noc::multicast_tree_hops(pe(0, 0, 0), &[pe(3, 0, 1), pe(3, 1, 1)]);
        assert_eq!(hops, 4);
    }

    #[test]
    fn tree_hops_route_x_then_y() {
        assert_eq!(Noc::multicast_tree_hops(pe(0, 0, 0), &[pe(2, 2, 0)]), 4);
        assert_eq!(Noc::multicast_tree_hops(pe(2, 2, 0), &[pe(0, 0, 0)]), 4);
        assert_eq!(Noc::multicast_tree_hops(pe(1, 1, 0), &[pe(1, 1, 5), pe(1, 1, 9)]), 0);
    }

    #[test]
    fn multicast_scaled_multiplies_telemetry() {
        let mut noc = Noc::new(NocConfig::default());
        let lat_bulk = noc.multicast_scaled(pe(0, 0, 0), &[pe(2, 0, 0), pe(2, 1, 0)], 10);
        assert_eq!(noc.packets, 10);
        assert_eq!(noc.hops, 10 * 3); // 2 x-links + 1 y-branch per packet
        let mut one = Noc::new(NocConfig::default());
        let lat_one = one.multicast(pe(0, 0, 0), &[pe(2, 0, 0), pe(2, 1, 0)]);
        assert_eq!(lat_bulk, lat_one, "latency profile is per packet");
        assert_eq!(one.hops, 3);
    }

    #[test]
    fn board_links_split_out_of_the_tree() {
        // Boards 2 columns wide: (0,0) → (3,0) walks x links 0-1, 1-2, 2-3;
        // 1-2 and only 1-2 crosses the board boundary.
        let hops = Noc::multicast_tree_hops_split(pe(0, 0, 0), &[pe(3, 0, 0)], 2);
        assert_eq!(hops, TreeHops { on_board: 2, board_links: 1 });
        assert_eq!(hops.total(), Noc::multicast_tree_hops(pe(0, 0, 0), &[pe(3, 0, 0)]));
        // Width 0 = no boundaries: everything on-board.
        let flat = Noc::multicast_tree_hops_split(pe(0, 0, 0), &[pe(3, 0, 0)], 0);
        assert_eq!(flat, TreeHops { on_board: 3, board_links: 0 });
        // y links never cross boards.
        let y_only = Noc::multicast_tree_hops_split(pe(1, 0, 0), &[pe(1, 4, 0)], 2);
        assert_eq!(y_only, TreeHops { on_board: 4, board_links: 0 });
    }

    #[test]
    fn board_hops_telemetry_and_latency() {
        let cfg = NocConfig { board_chips_x: 2, ..Default::default() };
        let mut noc = Noc::new(cfg);
        noc.multicast_scaled(pe(0, 0, 0), &[pe(3, 0, 0)], 5);
        assert_eq!(noc.hops, 5 * 3);
        assert_eq!(noc.board_hops, 5);
        // Crossing a board boundary costs more than the same distance
        // within one board.
        let across = noc.unicast_latency_ns(pe(1, 0, 0), pe(2, 0, 0));
        let within = noc.unicast_latency_ns(pe(0, 0, 0), pe(1, 0, 0));
        assert_eq!(across - within, cfg.per_board_link_ns);
    }

    #[test]
    fn farther_chips_cost_more() {
        let noc = Noc::new(NocConfig::default());
        let near = noc.unicast_latency_ns(pe(0, 0, 0), pe(1, 0, 0));
        let far = noc.unicast_latency_ns(pe(0, 0, 0), pe(5, 5, 0));
        assert!(far > near);
    }
}
