//! Network-on-Chip model.
//!
//! SpiNNaker-family machines deliver spikes as multicast packets routed by
//! per-chip routing tables. For the functional simulator we model the NoC at
//! the level the paper's evaluation needs: deterministic delivery with a
//! hop-count latency estimate (intra-chip hop + XY routing between chips),
//! plus multicast fan-out from one source PE to a set of sink PEs. This is a
//! timing *model*, not a cycle-accurate router — the paper's results are
//! memory/PE-count results and the simulator only needs causally-correct
//! spike delivery with plausible latency accounting.

use super::machine::PeHandle;
use std::collections::BTreeSet;

/// NoC timing constants (rough SpiNNaker2-class numbers; configurable).
#[derive(Clone, Copy, Debug)]
pub struct NocConfig {
    /// Latency for a packet that stays on-chip (ns).
    pub intra_chip_ns: u64,
    /// Additional latency per inter-chip hop (ns).
    pub per_hop_ns: u64,
    /// Router fan-out cost per additional multicast target (ns).
    pub per_target_ns: u64,
}

impl Default for NocConfig {
    fn default() -> Self {
        NocConfig { intra_chip_ns: 100, per_hop_ns: 40, per_target_ns: 10 }
    }
}

/// Hop-count + latency NoC model.
#[derive(Clone, Debug, Default)]
pub struct Noc {
    pub config: NocConfig,
    /// Cumulative packets sent (telemetry).
    pub packets: u64,
    /// Cumulative hop count (telemetry).
    pub hops: u64,
}

impl Noc {
    pub fn new(config: NocConfig) -> Self {
        Noc { config, packets: 0, hops: 0 }
    }

    /// Manhattan (XY-routing) hop distance between two PEs' chips.
    pub fn hop_distance(a: PeHandle, b: PeHandle) -> u64 {
        let dx = a.chip_x.abs_diff(b.chip_x) as u64;
        let dy = a.chip_y.abs_diff(b.chip_y) as u64;
        dx + dy
    }

    /// Latency estimate for a unicast packet from `src` to `dst`.
    pub fn unicast_latency_ns(&self, src: PeHandle, dst: PeHandle) -> u64 {
        self.config.intra_chip_ns + Self::hop_distance(src, dst) * self.config.per_hop_ns
    }

    /// Inter-chip links one multicast packet traverses under x-then-y
    /// dimension-ordered routing: the packet travels the x axis first, then
    /// the y axis, and duplicates at branch points — shared trunk segments
    /// are charged **once**, not once per destination. This is what makes
    /// chip-packed placements measurably cheaper than scattered ones.
    pub fn multicast_tree_hops(src: PeHandle, targets: &[PeHandle]) -> u64 {
        let mut links: BTreeSet<((usize, usize), (usize, usize))> = BTreeSet::new();
        for dst in targets {
            let (mut x, mut y) = (src.chip_x, src.chip_y);
            while x != dst.chip_x {
                let nx = if dst.chip_x > x { x + 1 } else { x - 1 };
                links.insert(((x, y), (nx, y)));
                x = nx;
            }
            while y != dst.chip_y {
                let ny = if dst.chip_y > y { y + 1 } else { y - 1 };
                links.insert(((x, y), (x, ny)));
                y = ny;
            }
        }
        links.len() as u64
    }

    /// Deliver a multicast packet; returns per-target latencies in the order
    /// of `targets`. Updates telemetry counters (hop telemetry charges the
    /// x-then-y multicast tree, not the per-destination Manhattan sum).
    pub fn multicast(&mut self, src: PeHandle, targets: &[PeHandle]) -> Vec<u64> {
        self.multicast_scaled(src, targets, 1)
    }

    /// Deliver `count` identical multicast packets, charging telemetry for
    /// all of them; returns one packet's per-target latencies. This is the
    /// traffic estimator's bulk path (N spikes along one routing entry).
    pub fn multicast_scaled(&mut self, src: PeHandle, targets: &[PeHandle], count: u64) -> Vec<u64> {
        self.packets += count;
        self.hops += count * Self::multicast_tree_hops(src, targets);
        targets
            .iter()
            .enumerate()
            .map(|(i, &dst)| {
                self.unicast_latency_ns(src, dst) + i as u64 * self.config.per_target_ns
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pe(x: usize, y: usize, core: usize) -> PeHandle {
        PeHandle { chip_x: x, chip_y: y, core }
    }

    #[test]
    fn same_chip_zero_hops() {
        assert_eq!(Noc::hop_distance(pe(0, 0, 1), pe(0, 0, 99)), 0);
    }

    #[test]
    fn xy_distance() {
        assert_eq!(Noc::hop_distance(pe(0, 0, 0), pe(3, 4, 0)), 7);
    }

    #[test]
    fn multicast_latency_monotone_in_target_index() {
        let mut noc = Noc::new(NocConfig::default());
        let lat = noc.multicast(pe(0, 0, 0), &[pe(0, 0, 1), pe(0, 0, 2), pe(0, 0, 3)]);
        assert!(lat[0] < lat[1] && lat[1] < lat[2]);
        assert_eq!(noc.packets, 1);
    }

    #[test]
    fn tree_hops_charge_shared_trunk_once() {
        // (0,0) → {(3,0), (3,1)}: the 3-link x trunk is shared; only the
        // final y branch is extra. Per-destination Manhattan would be 3+4=7.
        let hops = Noc::multicast_tree_hops(pe(0, 0, 0), &[pe(3, 0, 1), pe(3, 1, 1)]);
        assert_eq!(hops, 4);
    }

    #[test]
    fn tree_hops_route_x_then_y() {
        assert_eq!(Noc::multicast_tree_hops(pe(0, 0, 0), &[pe(2, 2, 0)]), 4);
        assert_eq!(Noc::multicast_tree_hops(pe(2, 2, 0), &[pe(0, 0, 0)]), 4);
        assert_eq!(Noc::multicast_tree_hops(pe(1, 1, 0), &[pe(1, 1, 5), pe(1, 1, 9)]), 0);
    }

    #[test]
    fn multicast_scaled_multiplies_telemetry() {
        let mut noc = Noc::new(NocConfig::default());
        let lat_bulk = noc.multicast_scaled(pe(0, 0, 0), &[pe(2, 0, 0), pe(2, 1, 0)], 10);
        assert_eq!(noc.packets, 10);
        assert_eq!(noc.hops, 10 * 3); // 2 x-links + 1 y-branch per packet
        let mut one = Noc::new(NocConfig::default());
        let lat_one = one.multicast(pe(0, 0, 0), &[pe(2, 0, 0), pe(2, 1, 0)]);
        assert_eq!(lat_bulk, lat_one, "latency profile is per packet");
        assert_eq!(one.hops, 3);
    }

    #[test]
    fn farther_chips_cost_more() {
        let noc = Noc::new(NocConfig::default());
        let near = noc.unicast_latency_ns(pe(0, 0, 0), pe(1, 0, 0));
        let far = noc.unicast_latency_ns(pe(0, 0, 0), pe(5, 5, 0));
        assert!(far > near);
    }
}
