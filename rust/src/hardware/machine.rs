//! A machine instance: PE allocation bookkeeping over a [`MachineSpec`].
//!
//! The mapping pipeline (graph partitioning → paradigm compilation) asks the
//! machine for free PEs and charges each allocation with its DTCM usage; the
//! machine enforces the per-PE budget and exposes utilization metrics that
//! the evaluation benches report.

use super::fault::FaultMap;
use super::spec::MachineSpec;
use anyhow::{bail, Result};

/// Identifies one PE on the machine.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct PeHandle {
    pub chip_x: usize,
    pub chip_y: usize,
    pub core: usize,
}

impl std::fmt::Display for PeHandle {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "({},{}):{}", self.chip_x, self.chip_y, self.core)
    }
}

/// Allocation record for one PE.
#[derive(Clone, Debug, PartialEq)]
struct PeState {
    allocated: bool,
    dtcm_used: usize,
    label: String,
}

/// A machine with allocation state and a fault map. Faulted PEs are
/// excluded from every free-capacity scan and rejected by allocation, so
/// strategies layered on top route around faults without knowing about
/// them. `PartialEq` compares the full allocation state byte for byte —
/// the allocator-rollback tests' exactness oracle.
#[derive(Clone, Debug, PartialEq)]
pub struct Machine {
    spec: MachineSpec,
    pes: Vec<PeState>,
    next_free: usize,
    faults: FaultMap,
}

impl Machine {
    pub fn new(spec: MachineSpec) -> Self {
        Machine::with_faults(spec, FaultMap::healthy())
    }

    /// A machine born with known-faulted resources (`--fault-map`).
    pub fn with_faults(spec: MachineSpec, faults: FaultMap) -> Self {
        let n = spec.total_pes();
        Machine {
            spec,
            pes: vec![PeState { allocated: false, dtcm_used: 0, label: String::new() }; n],
            next_free: 0,
            faults,
        }
    }

    /// Single-chip machine with default constants.
    pub fn single_chip() -> Self {
        Machine::new(MachineSpec::default())
    }

    pub fn spec(&self) -> &MachineSpec {
        &self.spec
    }

    fn index(&self, pe: PeHandle) -> usize {
        (pe.chip_y * self.spec.total_chips_x() + pe.chip_x) * self.spec.chip.pes_per_chip
            + pe.core
    }

    fn handle(&self, idx: usize) -> PeHandle {
        let per_chip = self.spec.chip.pes_per_chip;
        let chip = idx / per_chip;
        PeHandle {
            chip_x: chip % self.spec.total_chips_x(),
            chip_y: chip / self.spec.total_chips_x(),
            core: idx % per_chip,
        }
    }

    /// The machine's fault map.
    pub fn fault_map(&self) -> &FaultMap {
        &self.faults
    }

    /// Replace the fault map wholesale (e.g. after loading `--fault-map`).
    /// Existing allocations on newly-faulted PEs are kept — the recovery
    /// path detects and evacuates them.
    pub fn set_fault_map(&mut self, faults: FaultMap) {
        self.faults = faults;
    }

    /// Mark one PE dead mid-run. Returns `true` when the PE currently
    /// hosts an allocation (the caller must evacuate/re-place it).
    pub fn kill_pe(&mut self, pe: PeHandle) -> bool {
        self.faults.kill_pe(pe);
        self.pes[self.index(pe)].allocated
    }

    /// Is this PE unusable per the fault map?
    pub fn is_faulted(&self, pe: PeHandle) -> bool {
        self.faults.is_pe_dead(pe)
    }

    fn faulted_index(&self, idx: usize) -> bool {
        self.faults.is_pe_dead(self.handle(idx))
    }

    /// Allocate the next free PE, charging `dtcm_bytes` against its budget.
    ///
    /// Fails if the machine is full or the request exceeds the usable DTCM
    /// (total minus the OS reserve — the reserve is accounted inside the
    /// cost models, so `dtcm_bytes` here must already include it).
    pub fn allocate(&mut self, label: &str, dtcm_bytes: usize) -> Result<PeHandle> {
        let Some(idx) = self.first_free_index() else {
            bail!(
                "machine full: all {} usable PEs allocated ({} faulted)",
                self.usable_pes(),
                self.total_pes() - self.usable_pes()
            );
        };
        self.allocate_index(idx, label, dtcm_bytes)
    }

    /// Allocate one *specific* PE by linear index (the [`super::alloc::Allocator`]
    /// strategies pick the index). Fails if the PE is taken or the request
    /// exceeds the per-PE DTCM budget.
    pub(crate) fn allocate_index(
        &mut self,
        idx: usize,
        label: &str,
        dtcm_bytes: usize,
    ) -> Result<PeHandle> {
        if dtcm_bytes > self.spec.chip.pe.dtcm_bytes {
            bail!(
                "allocation '{label}' needs {dtcm_bytes} B DTCM > per-PE budget {} B",
                self.spec.chip.pe.dtcm_bytes
            );
        }
        if self.faulted_index(idx) {
            bail!("PE {} is faulted; allocation '{label}' refused", self.handle(idx));
        }
        if self.pes[idx].allocated {
            bail!("PE {} already allocated (to '{}')", self.handle(idx), self.pes[idx].label);
        }
        self.pes[idx] =
            PeState { allocated: true, dtcm_used: dtcm_bytes, label: label.to_string() };
        // Keep the low-water mark amortized: filling the lowest free slot
        // advances it, so strategy-driven scans stay O(N) overall.
        if idx == self.next_free {
            self.next_free += 1;
        }
        Ok(self.handle(idx))
    }

    /// Lowest free, non-faulted linear index, if any (pure scan from the
    /// low-water mark).
    pub(crate) fn first_free_index(&self) -> Option<usize> {
        (self.next_free..self.pes.len())
            .find(|&i| !self.pes[i].allocated && !self.faulted_index(i))
    }

    /// Release a PE back to the pool.
    pub fn free(&mut self, pe: PeHandle) {
        let idx = self.index(pe);
        self.pes[idx] = PeState { allocated: false, dtcm_used: 0, label: String::new() };
        self.next_free = self.next_free.min(idx);
    }

    /// Number of allocated PEs.
    pub fn allocated_count(&self) -> usize {
        self.pes.iter().filter(|p| p.allocated).count()
    }

    /// Total PEs on the machine.
    pub fn total_pes(&self) -> usize {
        self.pes.len()
    }

    /// PEs still allocatable on the machine (free and not faulted).
    pub fn free_pes(&self) -> usize {
        (0..self.pes.len())
            .filter(|&i| !self.pes[i].allocated && !self.faulted_index(i))
            .count()
    }

    /// PEs not ruled out by the fault map (allocated or not).
    pub fn usable_pes(&self) -> usize {
        (0..self.pes.len()).filter(|&i| !self.faulted_index(i)).count()
    }

    /// Chips on the machine (row-major linear chip index space over the
    /// full `(boards × chips_x) × chips_y` grid).
    pub fn n_chips(&self) -> usize {
        self.spec.chips()
    }

    /// Boards in the array.
    pub fn n_boards(&self) -> usize {
        self.spec.boards
    }

    /// The board owning a linear chip index.
    pub fn board_of_chip(&self, chip: usize) -> usize {
        self.spec.board_of_chip_x(chip % self.spec.total_chips_x())
    }

    /// Linear chip indices of board `b`, row by row. A board's chips are
    /// *column ranges per row* of the full grid — not one contiguous linear
    /// range when `chips_y > 1`.
    pub fn board_chips(&self, b: usize) -> impl Iterator<Item = usize> + '_ {
        let (w, total_x) = (self.spec.chips_x, self.spec.total_chips_x());
        (0..self.spec.chips_y)
            .flat_map(move |row| (0..w).map(move |cx| row * total_x + b * w + cx))
    }

    /// Allocatable PEs on one board (free and not faulted).
    pub fn board_free_pes(&self, b: usize) -> usize {
        self.board_chips(b).map(|c| self.chip_free_pes(c)).sum()
    }

    fn chip_range(&self, chip: usize) -> std::ops::Range<usize> {
        let per_chip = self.spec.chip.pes_per_chip;
        chip * per_chip..(chip + 1) * per_chip
    }

    /// Allocatable PEs on one chip (free and not faulted).
    pub fn chip_free_pes(&self, chip: usize) -> usize {
        self.chip_range(chip)
            .filter(|&i| !self.pes[i].allocated && !self.faulted_index(i))
            .count()
    }

    /// Lowest free, non-faulted linear index on one chip, if any.
    pub(crate) fn first_free_in_chip(&self, chip: usize) -> Option<usize> {
        self.chip_range(chip).find(|&i| !self.pes[i].allocated && !self.faulted_index(i))
    }

    /// DTCM bytes in use on one chip.
    pub fn chip_dtcm_used(&self, chip: usize) -> usize {
        self.chip_range(chip).map(|i| self.pes[i].dtcm_used).sum()
    }

    /// DTCM bytes still *allocatable* on one chip: every free PE accepts up
    /// to the per-PE budget (allocated PEs host exactly one vertex, so their
    /// slack is not allocatable). A capacity-reporting helper.
    pub fn chip_dtcm_headroom(&self, chip: usize) -> usize {
        self.chip_free_pes(chip) * self.spec.chip.pe.dtcm_bytes
    }

    /// Chips hosting at least one allocation.
    pub fn chips_used(&self) -> usize {
        (0..self.n_chips())
            .filter(|&c| self.chip_range(c).any(|i| self.pes[i].allocated))
            .count()
    }

    /// Total DTCM bytes in use across allocated PEs.
    pub fn total_dtcm_used(&self) -> usize {
        self.pes.iter().map(|p| p.dtcm_used).sum()
    }

    /// DTCM used on one PE.
    pub fn dtcm_used(&self, pe: PeHandle) -> usize {
        self.pes[self.index(pe)].dtcm_used
    }

    /// Label attached to an allocation.
    pub fn label(&self, pe: PeHandle) -> &str {
        &self.pes[self.index(pe)].label
    }

    /// Mean DTCM utilization over allocated PEs (0..1).
    pub fn mean_utilization(&self) -> f64 {
        let used: Vec<f64> = self
            .pes
            .iter()
            .filter(|p| p.allocated)
            .map(|p| p.dtcm_used as f64 / self.spec.chip.pe.dtcm_bytes as f64)
            .collect();
        if used.is_empty() {
            0.0
        } else {
            used.iter().sum::<f64>() / used.len() as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn allocate_and_free_roundtrip() {
        let mut m = Machine::single_chip();
        let a = m.allocate("a", 1000).unwrap();
        let b = m.allocate("b", 2000).unwrap();
        assert_ne!(a, b);
        assert_eq!(m.allocated_count(), 2);
        assert_eq!(m.total_dtcm_used(), 3000);
        assert_eq!(m.label(a), "a");
        m.free(a);
        assert_eq!(m.allocated_count(), 1);
        // Freed PE is reused first.
        let c = m.allocate("c", 500).unwrap();
        assert_eq!(c, a);
    }

    #[test]
    fn rejects_oversized_allocation() {
        let mut m = Machine::single_chip();
        assert!(m.allocate("huge", 200 * 1024).is_err());
    }

    #[test]
    fn machine_fills_up() {
        let mut m = Machine::single_chip();
        for i in 0..152 {
            m.allocate(&format!("pe{i}"), 100).unwrap();
        }
        assert!(m.allocate("overflow", 100).is_err());
    }

    #[test]
    fn handles_cover_multichip_grid() {
        let spec = MachineSpec { chips_x: 2, chips_y: 2, ..Default::default() };
        let mut m = Machine::new(spec);
        // Allocate past one chip's worth; handle should roll to the next chip.
        let mut last = None;
        for i in 0..(152 + 3) {
            last = Some(m.allocate(&format!("{i}"), 10).unwrap());
        }
        let h = last.unwrap();
        assert_eq!((h.chip_x, h.chip_y, h.core), (1, 0, 2));
    }

    #[test]
    fn utilization_tracks_usage() {
        let mut m = Machine::single_chip();
        assert_eq!(m.mean_utilization(), 0.0);
        m.allocate("half", 48 * 1024).unwrap();
        assert!((m.mean_utilization() - 0.5).abs() < 0.01);
    }

    #[test]
    fn chip_queries_track_occupancy() {
        let spec = MachineSpec {
            chips_x: 2,
            chips_y: 1,
            chip: crate::hardware::ChipSpec { pes_per_chip: 4, ..Default::default() },
            ..Default::default()
        };
        let mut m = Machine::new(spec);
        assert_eq!(m.n_chips(), 2);
        assert_eq!(m.total_pes(), 8);
        assert_eq!(m.free_pes(), 8);
        assert_eq!(m.chips_used(), 0);
        m.allocate("a", 1000).unwrap();
        m.allocate("b", 2000).unwrap();
        assert_eq!(m.chip_free_pes(0), 2);
        assert_eq!(m.chip_free_pes(1), 4);
        assert_eq!(m.chip_dtcm_used(0), 3000);
        assert_eq!(m.chip_dtcm_used(1), 0);
        assert_eq!(m.chip_dtcm_headroom(0), 2 * m.spec().chip.pe.dtcm_bytes);
        assert_eq!(m.chips_used(), 1);
        assert_eq!(m.first_free_in_chip(0), Some(2));
        assert_eq!(m.first_free_in_chip(1), Some(4));
    }

    #[test]
    fn faulted_pes_are_invisible_to_allocation() {
        let mut faults = FaultMap::healthy();
        faults.kill_pe(PeHandle { chip_x: 0, chip_y: 0, core: 0 });
        faults.kill_pe(PeHandle { chip_x: 0, chip_y: 0, core: 2 });
        let mut m = Machine::with_faults(MachineSpec::default(), faults);
        assert_eq!(m.usable_pes(), 150);
        assert_eq!(m.free_pes(), 150);
        // The scan routes around cores 0 and 2.
        assert_eq!(m.allocate("a", 100).unwrap().core, 1);
        assert_eq!(m.allocate("b", 100).unwrap().core, 3);
        assert_eq!(m.chip_free_pes(0), 148);
        // Direct placement on a faulted PE is refused with a typed message.
        let err = m.allocate_index(0, "x", 100).unwrap_err();
        assert!(err.to_string().contains("faulted"), "{err}");
    }

    #[test]
    fn dead_chip_shifts_allocation_to_the_next_chip() {
        let spec = MachineSpec { chips_x: 2, chips_y: 1, ..Default::default() };
        let mut faults = FaultMap::healthy();
        faults.kill_chip(0, 0);
        let mut m = Machine::with_faults(spec, faults);
        assert_eq!(m.usable_pes(), 152);
        let pe = m.allocate("a", 100).unwrap();
        assert_eq!((pe.chip_x, pe.chip_y), (1, 0));
        assert_eq!(m.chip_free_pes(0), 0);
        assert_eq!(m.first_free_in_chip(0), None);
    }

    #[test]
    fn kill_pe_reports_hosted_allocations_and_blocks_reuse() {
        let mut m = Machine::single_chip();
        let a = m.allocate("victim", 500).unwrap();
        assert!(m.kill_pe(a), "PE hosted an allocation");
        assert!(m.is_faulted(a));
        // Evacuating frees the bookkeeping, but the PE stays unallocatable.
        m.free(a);
        let b = m.allocate("next", 100).unwrap();
        assert_ne!(b, a, "dead PE must not be reused");
        // Killing a free PE reports no hosted allocation.
        let idle = PeHandle { chip_x: 0, chip_y: 0, core: 50 };
        assert!(!m.kill_pe(idle));
    }

    #[test]
    fn board_array_chips_are_per_row_column_ranges() {
        let spec = MachineSpec {
            boards: 2,
            chips_x: 2,
            chips_y: 2,
            chip: crate::hardware::ChipSpec { pes_per_chip: 3, ..Default::default() },
        };
        let mut m = Machine::new(spec);
        assert_eq!(m.n_boards(), 2);
        assert_eq!(m.n_chips(), 8);
        assert_eq!(m.total_pes(), 24);
        // Full grid is 4 columns × 2 rows; board 1 owns columns 2..4.
        assert_eq!(m.board_chips(0).collect::<Vec<_>>(), vec![0, 1, 4, 5]);
        assert_eq!(m.board_chips(1).collect::<Vec<_>>(), vec![2, 3, 6, 7]);
        for c in m.board_chips(1) {
            assert_eq!(m.board_of_chip(c), 1);
        }
        assert_eq!(m.board_free_pes(0), 12);
        // index/handle round-trip covers the whole board-array grid.
        for idx in 0..m.total_pes() {
            let h = m.handle(idx);
            assert_eq!(m.index(h), idx, "{h}");
            assert!(h.chip_x < spec.total_chips_x());
            assert!(h.chip_y < spec.chips_y);
        }
        // Allocations on board-1 columns report the right board.
        let pe = m.allocate_index(2 * 3, "b1", 10).unwrap();
        assert_eq!(spec.board_of_chip_x(pe.chip_x), 1);
        assert_eq!(m.board_free_pes(1), 11);
    }

    #[test]
    fn machine_equality_is_byte_level() {
        let mut a = Machine::single_chip();
        let mut b = Machine::single_chip();
        assert_eq!(a, b);
        a.allocate("x", 100).unwrap();
        assert_ne!(a, b);
        b.allocate("x", 100).unwrap();
        assert_eq!(a, b);
        b.allocate("y", 100).unwrap();
        assert_ne!(a, b);
    }

    #[test]
    fn allocate_index_rejects_taken_pe() {
        let mut m = Machine::single_chip();
        m.allocate_index(3, "x", 100).unwrap();
        assert!(m.allocate_index(3, "y", 100).is_err());
        // The low-water scan skips the hole-punched allocation.
        let a = m.allocate("z", 100).unwrap();
        assert_eq!(a.core, 0);
        assert_eq!(m.first_free_index(), Some(1));
    }
}
