//! Strategy-driven, transactional PE allocation over a [`Machine`].
//!
//! [`Machine`] is pure bookkeeping (which PE holds what); the [`Allocator`]
//! layered on top decides *which* PE an allocation lands on:
//!
//! * [`PlacementStrategy::Linear`] — lowest free linear index (the seed
//!   behavior: chip-major scan with a `next_free` low-water mark);
//! * [`PlacementStrategy::ChipPacked`] — like Linear for single
//!   allocations (the linear index order is already chip-major), but a
//!   whole PE *group* ([`Allocator::place_group`]) is co-located on the
//!   first chip that can hold all of it, minimizing inter-chip NoC hops
//!   between a layer's dominant/subordinate PEs;
//! * [`PlacementStrategy::Balanced`] — each allocation goes to the chip
//!   with the most free PEs, DTCM-load-aware (equally-free chips with
//!   less DTCM already loaded win), spreading load across the grid.
//!
//! All strategies are deterministic: identical request sequences on
//! identical machines produce bit-identical [`PeHandle`] sequences.
//!
//! Transactions ([`Allocator::begin`] / [`Allocator::commit`] /
//! [`Allocator::rollback`]) make group placement atomic: a layer's whole
//! PE group is placed or the machine is left untouched — no partially
//! placed layers on failure (the capacity-feasibility stage in
//! `switching::admission` makes such failures diagnosable up front).

use super::machine::{Machine, PeHandle};
use super::spec::MachineSpec;
use anyhow::{bail, Context, Result};

/// Deterministic PE-placement strategies.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum PlacementStrategy {
    /// Lowest free linear index (seed behavior).
    Linear,
    /// Co-locate each group on one chip when possible; otherwise Linear.
    ChipPacked,
    /// Spread across chips: most free PEs, then least DTCM loaded.
    Balanced,
}

impl PlacementStrategy {
    /// Every strategy, in documentation order (bench sweeps iterate this).
    pub const ALL: [PlacementStrategy; 3] =
        [PlacementStrategy::Linear, PlacementStrategy::ChipPacked, PlacementStrategy::Balanced];

    pub fn name(self) -> &'static str {
        match self {
            PlacementStrategy::Linear => "linear",
            PlacementStrategy::ChipPacked => "chip-packed",
            PlacementStrategy::Balanced => "balanced",
        }
    }

    /// Parse a CLI spelling (`linear` | `chip-packed` | `balanced`).
    pub fn parse(s: &str) -> Result<PlacementStrategy> {
        match s {
            "linear" => Ok(PlacementStrategy::Linear),
            "chip-packed" => Ok(PlacementStrategy::ChipPacked),
            "balanced" => Ok(PlacementStrategy::Balanced),
            other => bail!("unknown placement strategy '{other}' (linear|chip-packed|balanced)"),
        }
    }
}

impl std::fmt::Display for PlacementStrategy {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// A machine plus a placement strategy and an optional open transaction.
#[derive(Clone, Debug)]
pub struct Allocator {
    machine: Machine,
    strategy: PlacementStrategy,
    /// Journal of the open transaction's allocations (None = autocommit).
    journal: Option<Vec<PeHandle>>,
    /// When set, every strategy scans only this board's chips (sharded
    /// placement: each layer's PEs stay on its assigned board).
    board: Option<usize>,
}

impl Allocator {
    pub fn new(spec: MachineSpec, strategy: PlacementStrategy) -> Self {
        Allocator::from_machine(Machine::new(spec), strategy)
    }

    /// Wrap an existing (possibly partially allocated) machine.
    pub fn from_machine(machine: Machine, strategy: PlacementStrategy) -> Self {
        Allocator { machine, strategy, journal: None, board: None }
    }

    pub fn strategy(&self) -> PlacementStrategy {
        self.strategy
    }

    /// Restrict (or lift, with `None`) subsequent allocations to one
    /// board's chips. Sharded placement sets this per group so a layer's
    /// PEs land on the board the partitioner assigned it to.
    pub fn restrict_to_board(&mut self, board: Option<usize>) {
        if let Some(b) = board {
            assert!(b < self.machine.n_boards(), "board {b} out of range");
        }
        self.board = board;
    }

    /// The chips the current restriction allows, in deterministic scan
    /// order (full grid chip-major when unrestricted).
    fn scan_chips(&self) -> Vec<usize> {
        match self.board {
            Some(b) => self.machine.board_chips(b).collect(),
            None => (0..self.machine.n_chips()).collect(),
        }
    }

    pub fn machine(&self) -> &Machine {
        &self.machine
    }

    /// Unwrap the machine (any open transaction is committed implicitly).
    pub fn into_machine(self) -> Machine {
        self.machine
    }

    /// Allocate one PE under the strategy, charging `dtcm_bytes`.
    pub fn allocate(&mut self, label: &str, dtcm_bytes: usize) -> Result<PeHandle> {
        let idx = match self.strategy {
            // Single allocations: chip-packed *is* linear (the linear index
            // order is chip-major); groups differ — see `place_group`.
            PlacementStrategy::Linear | PlacementStrategy::ChipPacked => match self.board {
                None => self.machine.first_free_index(),
                // Restricted: lowest free PE in board-chip scan order (a
                // board's chips are per-row column ranges, so the global
                // low-water mark does not apply).
                Some(_) => self
                    .scan_chips()
                    .into_iter()
                    .find_map(|c| self.machine.first_free_in_chip(c)),
            },
            PlacementStrategy::Balanced => self.pick_balanced(),
        };
        let Some(idx) = idx else {
            if let Some(b) = self.board {
                bail!(
                    "board {b} full: all {} free PEs of its {} chips allocated",
                    self.machine.board_free_pes(b),
                    self.machine.spec().chips_per_board()
                );
            }
            bail!(
                "machine full: all {} usable PEs allocated ({} faulted)",
                self.machine.usable_pes(),
                self.machine.total_pes() - self.machine.usable_pes()
            );
        };
        self.alloc_index(idx, label, dtcm_bytes)
    }

    /// The most-spare chip, then its lowest free core. Ordering: most free
    /// PEs first, then the least DTCM already loaded (so equally-free chips
    /// with lighter memory load win), then the lowest chip index.
    fn pick_balanced(&self) -> Option<usize> {
        use std::cmp::Reverse;
        self.scan_chips()
            .into_iter()
            .filter(|&c| self.machine.chip_free_pes(c) > 0)
            .max_by_key(|&c| {
                (
                    self.machine.chip_free_pes(c),
                    Reverse(self.machine.chip_dtcm_used(c)),
                    Reverse(c),
                )
            })
            .and_then(|c| self.machine.first_free_in_chip(c))
    }

    fn alloc_index(&mut self, idx: usize, label: &str, dtcm_bytes: usize) -> Result<PeHandle> {
        let pe = self.machine.allocate_index(idx, label, dtcm_bytes)?;
        if let Some(journal) = self.journal.as_mut() {
            journal.push(pe);
        }
        Ok(pe)
    }

    /// Release a PE back to the pool. (Frees inside an open transaction are
    /// not journaled — rollback only undoes *allocations*.)
    pub fn free(&mut self, pe: PeHandle) {
        self.machine.free(pe);
    }

    /// Open a transaction; every allocation until [`Allocator::commit`] or
    /// [`Allocator::rollback`] is journaled. Transactions do not nest.
    pub fn begin(&mut self) {
        assert!(self.journal.is_none(), "allocator transactions do not nest");
        self.journal = Some(Vec::new());
    }

    /// Close the open transaction, keeping its allocations; returns them.
    pub fn commit(&mut self) -> Vec<PeHandle> {
        self.journal.take().unwrap_or_default()
    }

    /// Undo every allocation of the open transaction (reverse order), so
    /// the machine is exactly as it was at [`Allocator::begin`].
    pub fn rollback(&mut self) {
        if let Some(journal) = self.journal.take() {
            for pe in journal.into_iter().rev() {
                self.machine.free(pe);
            }
        }
    }

    /// Place a whole PE group — `(label, dtcm_bytes)` members —
    /// transactionally: all members are placed or the machine is left
    /// untouched. `ChipPacked` first looks for one chip that can hold the
    /// entire group; the other strategies (and the spill fallback) place
    /// member by member.
    pub fn place_group(&mut self, group: &str, members: &[(&str, usize)]) -> Result<Vec<PeHandle>> {
        self.begin();
        match self.try_place_group(members) {
            Ok(pes) => {
                self.commit();
                Ok(pes)
            }
            Err(e) => {
                self.rollback();
                Err(e).with_context(|| {
                    format!("placing group '{group}' ({} PEs)", members.len())
                })
            }
        }
    }

    fn try_place_group(&mut self, members: &[(&str, usize)]) -> Result<Vec<PeHandle>> {
        if self.strategy == PlacementStrategy::ChipPacked {
            let home = self
                .scan_chips()
                .into_iter()
                .find(|&c| self.machine.chip_free_pes(c) >= members.len());
            if let Some(chip) = home {
                return members
                    .iter()
                    .map(|&(label, dtcm)| {
                        let idx = self
                            .machine
                            .first_free_in_chip(chip)
                            .expect("chip had room for the whole group");
                        self.alloc_index(idx, label, dtcm)
                    })
                    .collect();
            }
            // No chip fits the whole group: spill in linear (chip-major)
            // order like the other strategies.
        }
        members.iter().map(|&(label, dtcm)| self.allocate(label, dtcm)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hardware::ChipSpec;

    fn grid(chips_x: usize, chips_y: usize, pes_per_chip: usize) -> MachineSpec {
        MachineSpec {
            chips_x,
            chips_y,
            chip: ChipSpec { pes_per_chip, ..Default::default() },
            ..Default::default()
        }
    }

    #[test]
    fn strategy_parse_roundtrip() {
        for s in PlacementStrategy::ALL {
            assert_eq!(PlacementStrategy::parse(s.name()).unwrap(), s);
        }
        assert!(PlacementStrategy::parse("zigzag").is_err());
    }

    #[test]
    fn linear_free_realloc_reuses_lowest_index() {
        let mut a = Allocator::new(grid(1, 1, 8), PlacementStrategy::Linear);
        let pes: Vec<_> = (0..4).map(|i| a.allocate(&format!("{i}"), 100).unwrap()).collect();
        a.free(pes[0]);
        a.free(pes[2]);
        // The low-water mark rewinds: the next allocation takes core 0,
        // then core 2, before advancing past core 3.
        assert_eq!(a.allocate("r0", 50).unwrap(), pes[0]);
        assert_eq!(a.allocate("r2", 50).unwrap(), pes[2]);
        assert_eq!(a.allocate("r4", 50).unwrap().core, 4);
    }

    #[test]
    fn rollback_leaves_machine_untouched() {
        let mut a = Allocator::new(grid(2, 1, 4), PlacementStrategy::Linear);
        a.allocate("keep", 500).unwrap();
        let (count, dtcm) = (a.machine().allocated_count(), a.machine().total_dtcm_used());
        a.begin();
        a.allocate("t0", 100).unwrap();
        a.allocate("t1", 200).unwrap();
        a.rollback();
        assert_eq!(a.machine().allocated_count(), count);
        assert_eq!(a.machine().total_dtcm_used(), dtcm);
        // And the freed indices are reused first, as if never taken.
        assert_eq!(a.allocate("next", 100).unwrap().core, 1);
    }

    #[test]
    fn commit_keeps_transaction_allocations() {
        let mut a = Allocator::new(grid(1, 1, 4), PlacementStrategy::Linear);
        a.begin();
        a.allocate("t0", 100).unwrap();
        a.allocate("t1", 100).unwrap();
        let committed = a.commit();
        assert_eq!(committed.len(), 2);
        assert_eq!(a.machine().allocated_count(), 2);
    }

    #[test]
    fn failed_group_rolls_back_entirely() {
        let mut a = Allocator::new(grid(1, 1, 2), PlacementStrategy::Linear);
        a.allocate("pre", 100).unwrap();
        let err = a.place_group("big", &[("m0", 100), ("m1", 100)]).unwrap_err();
        assert!(format!("{err:#}").contains("placing group 'big'"), "{err:#}");
        assert_eq!(a.machine().allocated_count(), 1, "partial placement must roll back");
    }

    #[test]
    fn chip_packed_colocates_groups() {
        // Chip 0 has 2 free PEs left; a 3-PE group must move to chip 1
        // whole under ChipPacked, while Linear splits it across the seam.
        let run = |strategy: PlacementStrategy| {
            let mut a = Allocator::new(grid(2, 1, 4), strategy);
            a.allocate("pre0", 100).unwrap();
            a.allocate("pre1", 100).unwrap();
            a.place_group("g", &[("g0", 10), ("g1", 10), ("g2", 10)]).unwrap()
        };
        let packed = run(PlacementStrategy::ChipPacked);
        assert!(packed.iter().all(|pe| pe.chip_x == 1), "group co-located: {packed:?}");
        let linear = run(PlacementStrategy::Linear);
        assert_eq!(linear.iter().filter(|pe| pe.chip_x == 0).count(), 2);
        assert_eq!(linear.iter().filter(|pe| pe.chip_x == 1).count(), 1);
    }

    #[test]
    fn chip_packed_spills_when_no_chip_fits() {
        let mut a = Allocator::new(grid(2, 1, 2), PlacementStrategy::ChipPacked);
        let pes = a.place_group("wide", &[("a", 1), ("b", 1), ("c", 1)]).unwrap();
        assert_eq!(pes.len(), 3);
        assert_eq!(a.machine().chips_used(), 2, "3 PEs cannot fit a 2-PE chip");
    }

    #[test]
    fn balanced_spreads_across_chips() {
        let mut a = Allocator::new(grid(2, 1, 4), PlacementStrategy::Balanced);
        let pes = a.place_group("g", &[("a", 1), ("b", 1), ("c", 1), ("d", 1)]).unwrap();
        let on0 = pes.iter().filter(|pe| pe.chip_x == 0).count();
        assert_eq!(on0, 2, "balanced must alternate chips: {pes:?}");
        // Headroom ties go to the lowest chip index → chip 0 first.
        assert_eq!((pes[0].chip_x, pes[0].core), (0, 0));
        assert_eq!((pes[1].chip_x, pes[1].core), (1, 0));
    }

    #[test]
    fn identical_inputs_give_bit_identical_placements() {
        for strategy in PlacementStrategy::ALL {
            let run = || {
                let mut a = Allocator::new(grid(2, 2, 3), strategy);
                let mut got = Vec::new();
                got.extend(a.place_group("g0", &[("a", 10), ("b", 20)]).unwrap());
                let lone = a.allocate("c", 30).unwrap();
                got.push(lone);
                a.free(lone);
                got.extend(a.place_group("g1", &[("d", 40), ("e", 50), ("f", 60)]).unwrap());
                got.push(a.allocate("g", 70).unwrap());
                got
            };
            assert_eq!(run(), run(), "strategy {strategy} must be deterministic");
        }
    }

    #[test]
    fn rollback_under_mid_transaction_fault_restores_machine_byte_for_byte() {
        // A PE dies *between* begin and commit: the journal must restore
        // the machine to exactly its pre-transaction state — byte-level
        // `Machine` equality, not just count/DTCM accounting.
        let mut a = Allocator::new(grid(2, 2, 4), PlacementStrategy::ChipPacked);
        a.allocate("keep0", 500).unwrap();
        a.allocate("keep1", 700).unwrap();
        let before = a.machine().clone();
        a.begin();
        let t0 = a.allocate("t0", 100).unwrap();
        let t1 = a.allocate("t1", 200).unwrap();
        // Mid-transaction fault on a PE the transaction just placed.
        assert!(a.machine.kill_pe(t1), "t1 hosts a transaction allocation");
        a.rollback();
        // Rollback frees the journal (dead PE included); the only residue
        // is the fault mark itself, which by design outlives transactions.
        let mut expected = before;
        expected.kill_pe(t1);
        assert_eq!(a.machine(), &expected, "journal must restore allocation state exactly");
        assert_eq!(a.machine().dtcm_used(t0), 0);
        assert_eq!(a.machine().label(t1), "");
        // And the next allocation routes around the dead PE.
        let next = a.allocate("next", 100).unwrap();
        assert_eq!(next, t0, "freed healthy PE is reused first");
        assert_ne!(a.allocate("after", 100).unwrap(), t1, "dead PE must not come back");
    }

    #[test]
    fn strategies_route_around_faults() {
        use crate::hardware::{FaultMap, Machine, PeHandle};
        for strategy in PlacementStrategy::ALL {
            let mut faults = FaultMap::healthy();
            faults.kill_chip(0, 0);
            faults.kill_pe(PeHandle { chip_x: 1, chip_y: 0, core: 0 });
            let machine = Machine::with_faults(grid(2, 1, 4), faults);
            let mut a = Allocator::from_machine(machine, strategy);
            let pes = a.place_group("g", &[("a", 10), ("b", 10), ("c", 10)]).unwrap();
            assert!(
                pes.iter().all(|pe| pe.chip_x == 1 && pe.core != 0),
                "{strategy}: placement must avoid faulted resources, got {pes:?}"
            );
            // 3 of the chip's 3 surviving PEs are taken; one more must fail
            // with the fault-aware capacity message.
            let err = a.allocate("overflow", 10).unwrap_err();
            assert!(format!("{err:#}").contains("5 faulted"), "{err:#}");
        }
    }

    #[test]
    fn board_restriction_pins_every_strategy_to_its_board() {
        let spec = MachineSpec::board_array(2, 2, 2);
        let spec = MachineSpec {
            chip: ChipSpec { pes_per_chip: 3, ..Default::default() },
            ..spec
        };
        for strategy in PlacementStrategy::ALL {
            let mut a = Allocator::new(spec, strategy);
            a.restrict_to_board(Some(1));
            let pes = a.place_group("g", &[("a", 10), ("b", 10), ("c", 10)]).unwrap();
            assert!(
                pes.iter().all(|pe| spec.board_of_chip_x(pe.chip_x) == 1),
                "{strategy}: group must land on board 1, got {pes:?}"
            );
            let lone = a.allocate("d", 10).unwrap();
            assert_eq!(spec.board_of_chip_x(lone.chip_x), 1, "{strategy}");
            // Fill the rest of the board, then overflow with the board's
            // own capacity error while the other board still has room.
            let free = a.machine().board_free_pes(1);
            for i in 0..free {
                a.allocate(&format!("f{i}"), 1).unwrap();
            }
            let err = a.allocate("over", 1).unwrap_err();
            assert!(format!("{err:#}").contains("board 1 full"), "{strategy}: {err:#}");
            assert!(a.machine().board_free_pes(0) > 0);
            // Lifting the restriction frees the whole grid again.
            a.restrict_to_board(None);
            let spill = a.allocate("spill", 1).unwrap();
            assert_eq!(spec.board_of_chip_x(spill.chip_x), 0, "{strategy}");
        }
    }

    #[test]
    fn oversized_member_fails_cleanly() {
        let mut a = Allocator::new(grid(1, 1, 4), PlacementStrategy::Balanced);
        let budget = a.machine().spec().chip.pe.dtcm_bytes;
        assert!(a.place_group("g", &[("ok", 100), ("huge", budget + 1)]).is_err());
        assert_eq!(a.machine().allocated_count(), 0);
    }
}
