//! Static SpiNNaker2 hardware constants (paper §II + Table I assumptions).

/// Geometry and precision of the per-PE MAC array.
///
/// "The MAC array on one PE has 64 MAC units in a 4×16 layout … Executing
/// matrix multiplication requires operand memory alignment to adapt to this
/// hardware architecture. The precision of operands could be 8-bit or
/// 16-bit, and the output precision can be configured to 8-/16-/32-bit."
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct MacArraySpec {
    /// Rows of MAC units (output alignment unit).
    pub rows: usize,
    /// Columns of MAC units (input alignment unit).
    pub cols: usize,
    /// Operand precision in bits (8 or 16).
    pub operand_bits: usize,
    /// Accumulator/output precision in bits (8, 16 or 32).
    pub output_bits: usize,
}

impl Default for MacArraySpec {
    fn default() -> Self {
        // The paper's experiments use 8-bit weights; we accumulate at 32-bit.
        MacArraySpec { rows: 4, cols: 16, operand_bits: 8, output_bits: 32 }
    }
}

impl MacArraySpec {
    /// Number of MAC units.
    pub fn units(&self) -> usize {
        self.rows * self.cols
    }

    /// Pad `n` up to the row-alignment multiple.
    pub fn align_rows(&self, n: usize) -> usize {
        n.div_ceil(self.rows) * self.rows
    }

    /// Pad `n` up to the column-alignment multiple.
    pub fn align_cols(&self, n: usize) -> usize {
        n.div_ceil(self.cols) * self.cols
    }
}

/// Per-PE memory and capacity constants.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PeSpec {
    /// Total local SRAM per PE in bytes (128 kB on SpiNNaker2).
    pub sram_bytes: usize,
    /// DTCM budget available to compiled data structures (the paper raises
    /// sPyNNaker's 64 kB to 96 kB for SpiNNaker2).
    pub dtcm_bytes: usize,
    /// Fixed "hw mgmt & OS" reserve inside the DTCM budget (Table I: 6000 B).
    pub os_reserve_bytes: usize,
    /// Serial-paradigm neuron capacity per PE (sPyNNaker's 255, §III-A).
    pub serial_neuron_cap: usize,
    /// MAC array attached to this PE.
    pub mac: MacArraySpec,
}

impl Default for PeSpec {
    fn default() -> Self {
        PeSpec {
            sram_bytes: 128 * 1024,
            dtcm_bytes: 96 * 1024,
            os_reserve_bytes: 6000,
            serial_neuron_cap: 255,
            mac: MacArraySpec::default(),
        }
    }
}

impl PeSpec {
    /// DTCM bytes usable by paradigm data structures after the OS reserve.
    pub fn usable_dtcm(&self) -> usize {
        self.dtcm_bytes - self.os_reserve_bytes
    }
}

/// Chip-level constants.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ChipSpec {
    /// PEs per chip (152 on the SpiNNaker2 chip, ref [11]).
    pub pes_per_chip: usize,
    pub pe: PeSpec,
}

impl Default for ChipSpec {
    fn default() -> Self {
        ChipSpec { pes_per_chip: 152, pe: PeSpec::default() }
    }
}

/// A whole machine: a W×H grid of chips (scales to supercomputer size).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct MachineSpec {
    pub chips_x: usize,
    pub chips_y: usize,
    pub chip: ChipSpec,
}

impl Default for MachineSpec {
    fn default() -> Self {
        // Single-chip default, like the paper's per-layer experiments.
        MachineSpec { chips_x: 1, chips_y: 1, chip: ChipSpec::default() }
    }
}

impl MachineSpec {
    /// A board-scale machine (SpiNNaker2 light board: 8×6 grid = 48 chips).
    pub fn board() -> Self {
        MachineSpec { chips_x: 8, chips_y: 6, chip: ChipSpec::default() }
    }

    pub fn chips(&self) -> usize {
        self.chips_x * self.chips_y
    }

    pub fn total_pes(&self) -> usize {
        self.chips() * self.chip.pes_per_chip
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_matches_paper_constants() {
        let pe = PeSpec::default();
        assert_eq!(pe.sram_bytes, 131072);
        assert_eq!(pe.dtcm_bytes, 98304);
        assert_eq!(pe.os_reserve_bytes, 6000);
        assert_eq!(pe.serial_neuron_cap, 255);
        assert_eq!(pe.mac.units(), 64);
        assert_eq!(pe.mac.rows, 4);
        assert_eq!(pe.mac.cols, 16);
    }

    #[test]
    fn mac_alignment() {
        let mac = MacArraySpec::default();
        assert_eq!(mac.align_rows(1), 4);
        assert_eq!(mac.align_rows(4), 4);
        assert_eq!(mac.align_rows(5), 8);
        assert_eq!(mac.align_cols(1), 16);
        assert_eq!(mac.align_cols(16), 16);
        assert_eq!(mac.align_cols(17), 32);
        assert_eq!(mac.align_cols(0), 0);
    }

    #[test]
    fn machine_pe_counts() {
        assert_eq!(MachineSpec::default().total_pes(), 152);
        assert_eq!(MachineSpec::board().total_pes(), 48 * 152);
    }

    #[test]
    fn usable_dtcm_subtracts_reserve() {
        let pe = PeSpec::default();
        assert_eq!(pe.usable_dtcm(), 98304 - 6000);
    }
}
