//! Static SpiNNaker2 hardware constants (paper §II + Table I assumptions).

/// Geometry and precision of the per-PE MAC array.
///
/// "The MAC array on one PE has 64 MAC units in a 4×16 layout … Executing
/// matrix multiplication requires operand memory alignment to adapt to this
/// hardware architecture. The precision of operands could be 8-bit or
/// 16-bit, and the output precision can be configured to 8-/16-/32-bit."
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct MacArraySpec {
    /// Rows of MAC units (output alignment unit).
    pub rows: usize,
    /// Columns of MAC units (input alignment unit).
    pub cols: usize,
    /// Operand precision in bits (8 or 16).
    pub operand_bits: usize,
    /// Accumulator/output precision in bits (8, 16 or 32).
    pub output_bits: usize,
}

impl Default for MacArraySpec {
    fn default() -> Self {
        // The paper's experiments use 8-bit weights; we accumulate at 32-bit.
        MacArraySpec { rows: 4, cols: 16, operand_bits: 8, output_bits: 32 }
    }
}

impl MacArraySpec {
    /// Number of MAC units.
    pub fn units(&self) -> usize {
        self.rows * self.cols
    }

    /// Pad `n` up to the row-alignment multiple.
    pub fn align_rows(&self, n: usize) -> usize {
        n.div_ceil(self.rows) * self.rows
    }

    /// Pad `n` up to the column-alignment multiple.
    pub fn align_cols(&self, n: usize) -> usize {
        n.div_ceil(self.cols) * self.cols
    }
}

/// Per-PE memory and capacity constants.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PeSpec {
    /// Total local SRAM per PE in bytes (128 kB on SpiNNaker2).
    pub sram_bytes: usize,
    /// DTCM budget available to compiled data structures (the paper raises
    /// sPyNNaker's 64 kB to 96 kB for SpiNNaker2).
    pub dtcm_bytes: usize,
    /// Fixed "hw mgmt & OS" reserve inside the DTCM budget (Table I: 6000 B).
    pub os_reserve_bytes: usize,
    /// Serial-paradigm neuron capacity per PE (sPyNNaker's 255, §III-A).
    pub serial_neuron_cap: usize,
    /// MAC array attached to this PE.
    pub mac: MacArraySpec,
}

impl Default for PeSpec {
    fn default() -> Self {
        PeSpec {
            sram_bytes: 128 * 1024,
            dtcm_bytes: 96 * 1024,
            os_reserve_bytes: 6000,
            serial_neuron_cap: 255,
            mac: MacArraySpec::default(),
        }
    }
}

impl PeSpec {
    /// DTCM bytes usable by paradigm data structures after the OS reserve.
    pub fn usable_dtcm(&self) -> usize {
        self.dtcm_bytes - self.os_reserve_bytes
    }
}

/// Chip-level constants.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ChipSpec {
    /// PEs per chip (152 on the SpiNNaker2 chip, ref [11]).
    pub pes_per_chip: usize,
    pub pe: PeSpec,
}

impl Default for ChipSpec {
    fn default() -> Self {
        ChipSpec { pes_per_chip: 152, pe: PeSpec::default() }
    }
}

/// A whole machine: `boards` boards arrayed along the x axis, each a
/// W×H grid of chips (scales to the 10M-core supercomputer shape:
/// board-of-boards, chips within boards).
///
/// `chips_x`/`chips_y` are **per-board** dimensions; the full chip grid is
/// `(boards × chips_x) × chips_y`, with board `b` owning chip columns
/// `b*chips_x .. (b+1)*chips_x`. Crossing between adjacent boards uses a
/// board-level link with its own latency cost (see
/// [`super::noc::NocConfig::per_board_link_ns`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct MachineSpec {
    /// Number of boards in the array (1 = the single-machine seed shape).
    pub boards: usize,
    /// Chip columns per board.
    pub chips_x: usize,
    /// Chip rows per board.
    pub chips_y: usize,
    pub chip: ChipSpec,
}

impl Default for MachineSpec {
    fn default() -> Self {
        // Single-chip default, like the paper's per-layer experiments.
        MachineSpec { boards: 1, chips_x: 1, chips_y: 1, chip: ChipSpec::default() }
    }
}

/// Typed rejection of a malformed `--machine` specification — the CLI
/// surfaces these instead of panicking on bad input.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum MachineParseError {
    /// Not `light-board`, `WxH` or `BxWxH` with integer dimensions.
    Malformed(String),
    /// Parsed, but some dimension is zero (a machine with no chips).
    ZeroDimension(String),
}

impl std::fmt::Display for MachineParseError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            MachineParseError::Malformed(s) => {
                write!(f, "malformed machine spec '{s}': expected WxH, BxWxH or light-board")
            }
            MachineParseError::ZeroDimension(s) => {
                write!(f, "machine spec '{s}' has a zero dimension: every one of boards, chips_x and chips_y must be >= 1")
            }
        }
    }
}

impl std::error::Error for MachineParseError {}

impl MachineSpec {
    /// A board-scale machine (SpiNNaker2 light board: 8×6 grid = 48 chips).
    pub fn board() -> Self {
        MachineSpec { boards: 1, chips_x: 8, chips_y: 6, chip: ChipSpec::default() }
    }

    /// A board array: `boards` boards of `chips_x`×`chips_y` chips each.
    pub fn board_array(boards: usize, chips_x: usize, chips_y: usize) -> Self {
        MachineSpec { boards, chips_x, chips_y, chip: ChipSpec::default() }
    }

    /// Parse a `--machine` spec: `light-board` (8×6), `WxH` (one board) or
    /// `BxWxH` (a B-board array of W×H-chip boards). Typed errors, never a
    /// panic, on malformed or zero-dimension input.
    pub fn parse(s: &str) -> Result<Self, MachineParseError> {
        if s == "light-board" {
            return Ok(MachineSpec::board());
        }
        let parts: Vec<&str> = s.split('x').collect();
        let dims: Vec<usize> = parts
            .iter()
            .map(|p| p.parse::<usize>())
            .collect::<Result<_, _>>()
            .map_err(|_| MachineParseError::Malformed(s.to_string()))?;
        let (boards, chips_x, chips_y) = match dims[..] {
            [w, h] => (1, w, h),
            [b, w, h] => (b, w, h),
            _ => return Err(MachineParseError::Malformed(s.to_string())),
        };
        if boards == 0 || chips_x == 0 || chips_y == 0 {
            return Err(MachineParseError::ZeroDimension(s.to_string()));
        }
        Ok(MachineSpec { boards, chips_x, chips_y, chip: ChipSpec::default() })
    }

    /// Chip columns across the whole board array.
    pub fn total_chips_x(&self) -> usize {
        self.boards * self.chips_x
    }

    /// The board owning chip column `x` of the full grid.
    pub fn board_of_chip_x(&self, x: usize) -> usize {
        x / self.chips_x
    }

    /// Chips per board.
    pub fn chips_per_board(&self) -> usize {
        self.chips_x * self.chips_y
    }

    /// PEs per board.
    pub fn pes_per_board(&self) -> usize {
        self.chips_per_board() * self.chip.pes_per_chip
    }

    /// Chips across the whole board array.
    pub fn chips(&self) -> usize {
        self.boards * self.chips_x * self.chips_y
    }

    /// PEs across the whole board array.
    pub fn total_pes(&self) -> usize {
        self.chips() * self.chip.pes_per_chip
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_matches_paper_constants() {
        let pe = PeSpec::default();
        assert_eq!(pe.sram_bytes, 131072);
        assert_eq!(pe.dtcm_bytes, 98304);
        assert_eq!(pe.os_reserve_bytes, 6000);
        assert_eq!(pe.serial_neuron_cap, 255);
        assert_eq!(pe.mac.units(), 64);
        assert_eq!(pe.mac.rows, 4);
        assert_eq!(pe.mac.cols, 16);
    }

    #[test]
    fn mac_alignment() {
        let mac = MacArraySpec::default();
        assert_eq!(mac.align_rows(1), 4);
        assert_eq!(mac.align_rows(4), 4);
        assert_eq!(mac.align_rows(5), 8);
        assert_eq!(mac.align_cols(1), 16);
        assert_eq!(mac.align_cols(16), 16);
        assert_eq!(mac.align_cols(17), 32);
        assert_eq!(mac.align_cols(0), 0);
    }

    #[test]
    fn machine_pe_counts() {
        assert_eq!(MachineSpec::default().total_pes(), 152);
        assert_eq!(MachineSpec::board().total_pes(), 48 * 152);
    }

    #[test]
    fn board_array_geometry() {
        let spec = MachineSpec::board_array(4, 2, 3);
        assert_eq!(spec.chips(), 24);
        assert_eq!(spec.chips_per_board(), 6);
        assert_eq!(spec.total_chips_x(), 8);
        assert_eq!(spec.pes_per_board(), 6 * 152);
        assert_eq!(spec.total_pes(), 24 * 152);
        assert_eq!(spec.board_of_chip_x(0), 0);
        assert_eq!(spec.board_of_chip_x(1), 0);
        assert_eq!(spec.board_of_chip_x(2), 1);
        assert_eq!(spec.board_of_chip_x(7), 3);
    }

    #[test]
    fn parse_accepts_all_three_forms() {
        assert_eq!(MachineSpec::parse("light-board").unwrap(), MachineSpec::board());
        let wh = MachineSpec::parse("3x2").unwrap();
        assert_eq!((wh.boards, wh.chips_x, wh.chips_y), (1, 3, 2));
        let bwh = MachineSpec::parse("4x3x2").unwrap();
        assert_eq!((bwh.boards, bwh.chips_x, bwh.chips_y), (4, 3, 2));
    }

    #[test]
    fn parse_rejects_empty() {
        assert_eq!(MachineSpec::parse(""), Err(MachineParseError::Malformed("".into())));
    }

    #[test]
    fn parse_rejects_bare_separator() {
        assert_eq!(MachineSpec::parse("x"), Err(MachineParseError::Malformed("x".into())));
    }

    #[test]
    fn parse_rejects_missing_dimension() {
        assert_eq!(MachineSpec::parse("2x"), Err(MachineParseError::Malformed("2x".into())));
    }

    #[test]
    fn parse_rejects_non_numeric() {
        assert_eq!(MachineSpec::parse("ax3"), Err(MachineParseError::Malformed("ax3".into())));
        assert_eq!(
            MachineSpec::parse("2x3x-1"),
            Err(MachineParseError::Malformed("2x3x-1".into()))
        );
    }

    #[test]
    fn parse_rejects_single_number() {
        assert_eq!(MachineSpec::parse("5"), Err(MachineParseError::Malformed("5".into())));
    }

    #[test]
    fn parse_rejects_four_dimensions() {
        assert_eq!(
            MachineSpec::parse("2x3x4x5"),
            Err(MachineParseError::Malformed("2x3x4x5".into()))
        );
    }

    #[test]
    fn parse_rejects_zero_dimensions() {
        for bad in ["0x3", "3x0", "0x2x2", "2x0x2", "2x2x0"] {
            assert_eq!(
                MachineSpec::parse(bad),
                Err(MachineParseError::ZeroDimension(bad.into())),
                "{bad}"
            );
        }
    }

    #[test]
    fn parse_error_displays_the_input() {
        let e = MachineSpec::parse("bogus").unwrap_err();
        assert!(e.to_string().contains("bogus"));
        let e = MachineSpec::parse("0x1").unwrap_err();
        assert!(e.to_string().contains("zero dimension"));
    }

    #[test]
    fn usable_dtcm_subtracts_reserve() {
        let pe = PeSpec::default();
        assert_eq!(pe.usable_dtcm(), 98304 - 6000);
    }
}
