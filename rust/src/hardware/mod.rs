//! SpiNNaker2 machine model.
//!
//! The paper's hardware backend (§II): a massively parallel system scaling
//! from one 152-PE chip to millions of cores. Each processing element (PE)
//! couples an ARM Cortex-M4F (the *serial* processor) with a 4×16 MAC array
//! (the *parallel* processor) and 128 kB local SRAM, of which the paper's
//! cost model budgets 96 kB of DTCM for compiled data structures. PEs
//! communicate over a Network-on-Chip.
//!
//! Submodules:
//! * [`spec`] — static hardware constants and per-component descriptions.
//! * [`machine`] — a machine instance with PE allocation bookkeeping.
//! * [`alloc`] — strategy-driven, transactional allocation over a machine
//!   (linear / chip-packed / balanced placement).
//! * [`noc`] — a hop-count/latency NoC model with multicast routing.
//! * [`fault`] — the fault model (dead PEs/chips, degraded links) and the
//!   deterministic seeded fault injector driving the recovery path.

pub mod alloc;
pub mod fault;
pub mod machine;
pub mod noc;
pub mod spec;

pub use alloc::{Allocator, PlacementStrategy};
pub use fault::{FaultError, FaultEvent, FaultMap, FaultSchedule};
pub use machine::{Machine, PeHandle};
pub use noc::{Noc, NocConfig, TreeHops};
pub use spec::{ChipSpec, MacArraySpec, MachineParseError, MachineSpec, PeSpec};
