//! Traffic-aware layer-to-board partitioning.
//!
//! On a board array (hardware::spec `boards > 1`) the admission pipeline
//! must decide which board each population — and therefore each layer's
//! synapse/neuron PEs — lives on. Crossing a board boundary costs an
//! order of magnitude more per hop than an on-board chip link
//! ([`crate::hardware::noc::NocConfig::per_board_link_ns`]), so the
//! partition objective is to keep heavily-spiking projections on one
//! board: minimize the estimated inter-board multicast traffic, following
//! the graph-clustering approach of Song et al., "Compiling Spiking
//! Neural Networks to Neuromorphic Hardware".
//!
//! Two deterministic strategies, toggled by the CLI's `--partition`:
//!
//! * [`PartitionStrategy::Linear`] — next-fit over populations in id
//!   order, the obvious baseline: fill board 0, move on. Cheap, but blind
//!   to topology — it cuts chains wherever the capacity seam happens to
//!   fall.
//! * [`PartitionStrategy::Traffic`] — greedy cluster growth. Each board
//!   is seeded with the unassigned population carrying the most total
//!   incident spike traffic, then grown by repeatedly pulling in the
//!   unassigned population with the highest affinity (summed projection
//!   traffic) to the board's current set, until nothing connected fits.
//!   Leftovers go first-fit. Ties break on the lowest population id, so
//!   the result is a pure function of (network, demand, capacity) — no
//!   RNG, no thread-count sensitivity.
//!
//! Traffic on a projection is estimated as its source population size
//! (every source neuron's spike traverses the multicast tree once per
//! timestep in the worst case) — the same proxy the NoC traffic
//! estimator uses for tree-hop accounting.

use crate::model::Network;
use anyhow::{bail, ensure, Result};
use std::collections::BTreeMap;

/// Deterministic layer-to-board partition strategies.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum PartitionStrategy {
    /// Next-fit over populations in id order (baseline).
    Linear,
    /// Greedy traffic-weighted cluster growth (default).
    Traffic,
}

impl PartitionStrategy {
    /// Every strategy, in documentation order (bench sweeps iterate this).
    pub const ALL: [PartitionStrategy; 2] =
        [PartitionStrategy::Linear, PartitionStrategy::Traffic];

    pub fn name(self) -> &'static str {
        match self {
            PartitionStrategy::Linear => "linear",
            PartitionStrategy::Traffic => "traffic",
        }
    }

    /// Parse a CLI spelling (`linear` | `traffic`).
    pub fn parse(s: &str) -> Result<PartitionStrategy> {
        match s {
            "linear" => Ok(PartitionStrategy::Linear),
            "traffic" => Ok(PartitionStrategy::Traffic),
            other => bail!("unknown partition strategy '{other}' (linear|traffic)"),
        }
    }
}

impl std::fmt::Display for PartitionStrategy {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// A population→board (and thus layer→board) assignment.
///
/// A *layer* (projection) always executes on its **target** population's
/// board: every projection into population P accumulates currents on P's
/// board, which is what keeps sharded accumulation order — and therefore
/// recorded spikes — bit-identical to the single-board run (see
/// DESIGN.md §Sharding).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct BoardAssignment {
    /// Number of boards partitioned over.
    pub boards: usize,
    /// Board index per population id.
    pub board_of_pop: Vec<usize>,
    /// Board index per projection (layer) id: the target's board.
    pub board_of_layer: Vec<usize>,
}

impl BoardAssignment {
    /// The trivial assignment: everything on board 0 (single-machine runs).
    pub fn single_board(net: &Network) -> Self {
        BoardAssignment {
            boards: 1,
            board_of_pop: vec![0; net.populations.len()],
            board_of_layer: vec![0; net.projections.len()],
        }
    }

    /// Estimated inter-board multicast traffic this assignment pays per
    /// timestep: for every projection whose source and target boards
    /// differ, its source population size (spikes per step, worst case)
    /// times the board-link crossings between the two boards (boards are
    /// arrayed along x, so that is their index distance). The partition
    /// objective, and the `BENCH_place.json` `cut_hops` metric.
    pub fn cut_hops(&self, net: &Network) -> u64 {
        net.projections
            .iter()
            .map(|proj| {
                let (sb, tb) =
                    (self.board_of_pop[proj.source.0], self.board_of_pop[proj.target.0]);
                net.populations[proj.source.0].n_neurons as u64 * sb.abs_diff(tb) as u64
            })
            .sum()
    }

    /// PE demand per board under this assignment (`demand` is per pop).
    pub fn board_demand(&self, demand: &[usize]) -> Vec<usize> {
        let mut per_board = vec![0usize; self.boards];
        for (p, &b) in self.board_of_pop.iter().enumerate() {
            per_board[b] += demand[p];
        }
        per_board
    }
}

/// Assign populations to boards.
///
/// * `demand[p]` — estimated PE demand of population `p` (its layers'
///   synapse/neuron PEs plus source hosting, from the admission
///   estimator).
/// * `capacity[b]` — usable PEs on board `b`.
///
/// Deterministic: same `(net, demand, capacity, strategy)` ⇒ same
/// assignment, regardless of caller thread count. Fails (typed error, no
/// panic) when some population fits no board.
pub fn partition(
    net: &Network,
    demand: &[usize],
    capacity: &[usize],
    strategy: PartitionStrategy,
) -> Result<BoardAssignment> {
    let n = net.populations.len();
    ensure!(demand.len() == n, "demand entries ({}) != populations ({n})", demand.len());
    ensure!(!capacity.is_empty(), "partitioning needs at least one board");
    let board_of_pop = match strategy {
        PartitionStrategy::Linear => partition_linear(demand, capacity)?,
        PartitionStrategy::Traffic => partition_traffic(net, demand, capacity)?,
    };
    let board_of_layer =
        net.projections.iter().map(|proj| board_of_pop[proj.target.0]).collect();
    Ok(BoardAssignment { boards: capacity.len(), board_of_pop, board_of_layer })
}

/// Next-fit in population-id order: fill the current board until the next
/// population no longer fits, then move to the next board (never back).
fn partition_linear(demand: &[usize], capacity: &[usize]) -> Result<Vec<usize>> {
    let mut board_of_pop = vec![0usize; demand.len()];
    let mut board = 0;
    let mut used = 0;
    for (p, &need) in demand.iter().enumerate() {
        while board < capacity.len() && used + need > capacity[board] {
            board += 1;
            used = 0;
        }
        if board == capacity.len() {
            bail!(
                "linear partition: population {p} (demand {need} PEs) fits no remaining board"
            );
        }
        board_of_pop[p] = board;
        used += need;
    }
    Ok(board_of_pop)
}

/// Greedy traffic-weighted cluster growth (see module docs).
fn partition_traffic(net: &Network, demand: &[usize], capacity: &[usize]) -> Result<Vec<usize>> {
    let n = net.populations.len();
    // Symmetric pop↔pop affinity: summed source-size traffic of the
    // projections between them (self-loops carry no cut cost — skip).
    let mut affinity: Vec<BTreeMap<usize, u64>> = vec![BTreeMap::new(); n];
    for proj in &net.projections {
        let (s, t) = (proj.source.0, proj.target.0);
        if s == t {
            continue;
        }
        let traffic = net.populations[s].n_neurons as u64;
        *affinity[s].entry(t).or_insert(0) += traffic;
        *affinity[t].entry(s).or_insert(0) += traffic;
    }
    let total_weight: Vec<u64> = affinity.iter().map(|m| m.values().sum()).collect();

    const UNASSIGNED: usize = usize::MAX;
    let mut board_of_pop = vec![UNASSIGNED; n];
    let mut remaining = capacity.to_vec();
    for board in 0..capacity.len() {
        // Seed: the unassigned population with the most total incident
        // traffic that fits this board (ties → lowest id).
        let seed = (0..n)
            .filter(|&p| board_of_pop[p] == UNASSIGNED && demand[p] <= remaining[board])
            .max_by_key(|&p| (total_weight[p], std::cmp::Reverse(p)));
        let Some(seed) = seed else { continue };
        board_of_pop[seed] = board;
        remaining[board] -= demand[seed];
        // Grow: pull in the unassigned population with the highest
        // affinity to the board's current set, while anything connected
        // still fits.
        loop {
            let next = (0..n)
                .filter(|&p| board_of_pop[p] == UNASSIGNED && demand[p] <= remaining[board])
                .filter_map(|p| {
                    let pull: u64 = affinity[p]
                        .iter()
                        .filter(|&(&q, _)| board_of_pop[q] == board)
                        .map(|(_, &w)| w)
                        .sum();
                    (pull > 0).then_some((pull, p))
                })
                .max_by_key(|&(pull, p)| (pull, std::cmp::Reverse(p)));
            let Some((_, p)) = next else { break };
            board_of_pop[p] = board;
            remaining[board] -= demand[p];
        }
    }
    // Leftovers (disconnected, or squeezed out of their cluster's board):
    // first-fit into any board with room.
    for p in 0..n {
        if board_of_pop[p] != UNASSIGNED {
            continue;
        }
        match (0..capacity.len()).find(|&b| demand[p] <= remaining[b]) {
            Some(b) => {
                board_of_pop[p] = b;
                remaining[b] -= demand[p];
            }
            None => bail!(
                "traffic partition: population {p} (demand {} PEs) fits no board \
                 (per-board free: {remaining:?})",
                demand[p]
            ),
        }
    }
    Ok(board_of_pop)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::connector::SynapseDraw;
    use crate::model::{Connector, LifParams, NetworkBuilder};

    /// `chains` parallel in→hid→out chains with **layer-major interleaved**
    /// pop ids (all ins, then all hids, then all outs) — the id order that
    /// makes next-fit cut every chain while traffic clustering keeps each
    /// chain whole.
    fn chain_net(chains: usize, width: usize) -> Network {
        let mut b = NetworkBuilder::new(7);
        let ins: Vec<_> =
            (0..chains).map(|i| b.spike_source(&format!("in{i}"), width)).collect();
        let hids: Vec<_> = (0..chains)
            .map(|i| b.lif_population(&format!("hid{i}"), width, LifParams::default()))
            .collect();
        let outs: Vec<_> = (0..chains)
            .map(|i| b.lif_population(&format!("out{i}"), width, LifParams::default()))
            .collect();
        for i in 0..chains {
            b.project(ins[i], hids[i], Connector::OneToOne, SynapseDraw::default(), 1.0);
            b.project(hids[i], outs[i], Connector::OneToOne, SynapseDraw::default(), 1.0);
        }
        b.build()
    }

    #[test]
    fn strategy_parse_roundtrip() {
        for s in PartitionStrategy::ALL {
            assert_eq!(PartitionStrategy::parse(s.name()).unwrap(), s);
        }
        assert!(PartitionStrategy::parse("radial").is_err());
    }

    #[test]
    fn linear_is_next_fit_in_id_order() {
        let net = chain_net(2, 4);
        // 6 pops, demand 2 each, 3 boards of 4: pops (0,1) (2,3) (4,5).
        let a = partition(&net, &[2; 6], &[4, 4, 4], PartitionStrategy::Linear).unwrap();
        assert_eq!(a.board_of_pop, vec![0, 0, 1, 1, 2, 2]);
        // Layers land on their target's board.
        assert_eq!(a.board_of_layer.len(), 4);
        for (i, proj) in net.projections.iter().enumerate() {
            assert_eq!(a.board_of_layer[i], a.board_of_pop[proj.target.0]);
        }
    }

    #[test]
    fn traffic_keeps_chains_whole_where_linear_cuts() {
        let net = chain_net(4, 8);
        // 12 pops of demand 1 over 4 boards of 3: each board holds exactly
        // one chain's 3 pops under traffic clustering; next-fit instead
        // packs by id (in0,in1,in2 | in3,hid0,hid1 | …), cutting chains.
        let demand = vec![1usize; 12];
        let capacity = vec![3usize; 4];
        let linear = partition(&net, &demand, &capacity, PartitionStrategy::Linear).unwrap();
        let traffic = partition(&net, &demand, &capacity, PartitionStrategy::Traffic).unwrap();
        assert_eq!(traffic.cut_hops(&net), 0, "{:?}", traffic.board_of_pop);
        assert!(
            linear.cut_hops(&net) > 0,
            "interleaved ids must force next-fit to cut: {:?}",
            linear.board_of_pop
        );
        for i in 0..4 {
            let chain = [i, 4 + i, 8 + i].map(|p| traffic.board_of_pop[p]);
            assert_eq!(chain[0], chain[1], "chain {i} split: {chain:?}");
            assert_eq!(chain[1], chain[2], "chain {i} split: {chain:?}");
        }
    }

    #[test]
    fn cut_hops_weighs_source_size_and_board_distance() {
        let net = chain_net(1, 8); // in(8) → hid(8) → out(8)
        let hand = |board_of_pop: Vec<usize>| {
            let board_of_layer =
                net.projections.iter().map(|p| board_of_pop[p.target.0]).collect();
            BoardAssignment { boards: 3, board_of_pop, board_of_layer }
        };
        assert_eq!(hand(vec![0, 0, 0]).cut_hops(&net), 0);
        assert_eq!(hand(vec![0, 0, 1]).cut_hops(&net), 8, "hid→out crosses once");
        assert_eq!(hand(vec![0, 2, 2]).cut_hops(&net), 16, "in→hid crosses two links");
    }

    #[test]
    fn board_demand_sums_per_board() {
        let net = chain_net(2, 4);
        let a = partition(&net, &[5, 1, 2, 2, 3, 3], &[8, 8], PartitionStrategy::Linear).unwrap();
        let per_board = a.board_demand(&[5, 1, 2, 2, 3, 3]);
        assert_eq!(per_board.iter().sum::<usize>(), 16);
        assert_eq!(per_board.len(), 2);
        assert!(per_board.iter().all(|&d| d <= 8));
    }

    #[test]
    fn over_capacity_is_a_typed_error() {
        let net = chain_net(1, 4);
        for s in PartitionStrategy::ALL {
            let err = partition(&net, &[4, 4, 4], &[5, 5], s).unwrap_err();
            assert!(format!("{err:#}").contains("fits no"), "{s}: {err:#}");
        }
    }

    #[test]
    fn partition_is_deterministic() {
        let net = chain_net(4, 8);
        let demand = vec![1usize; 12];
        let capacity = vec![3usize; 4];
        for s in PartitionStrategy::ALL {
            let a = partition(&net, &demand, &capacity, s).unwrap();
            let b = partition(&net, &demand, &capacity, s).unwrap();
            assert_eq!(a, b, "{s}");
        }
    }

    #[test]
    fn single_board_is_all_zeroes() {
        let net = chain_net(2, 4);
        let a = BoardAssignment::single_board(&net);
        assert_eq!(a.boards, 1);
        assert!(a.board_of_pop.iter().all(|&b| b == 0));
        assert!(a.board_of_layer.iter().all(|&b| b == 0));
        assert_eq!(a.cut_hops(&net), 0);
    }
}
