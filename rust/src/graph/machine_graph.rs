//! Machine graph: sub-populations placed on PEs plus their induced edges.
//!
//! "The neuron population in each vertex is then split into one or several
//! sub-populations to fit the SRAM resource of each PE. All the
//! sub-populations and the corresponding projections between them form a
//! machine graph." (paper §III)

use crate::hardware::PeHandle;
use crate::model::{PopulationId, ProjectionId};

/// A contiguous neuron index range [lo, hi) of a population.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SliceRange {
    pub lo: u32,
    pub hi: u32,
}

impl SliceRange {
    pub fn len(&self) -> usize {
        (self.hi - self.lo) as usize
    }

    pub fn is_empty(&self) -> bool {
        self.hi == self.lo
    }

    pub fn contains(&self, idx: u32) -> bool {
        (self.lo..self.hi).contains(&idx)
    }
}

/// What role a machine vertex plays in its paradigm's PE group.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum VertexRole {
    /// Serial-paradigm PE (neurons + synaptic rows).
    Serial,
    /// Parallel-paradigm dominant PE (spike preprocessing + neural update).
    ParallelDominant,
    /// Parallel-paradigm subordinate PE (weight-delay-map chunk on the MAC).
    ParallelSubordinate,
    /// Spike-source hosting PE.
    Source,
}

/// One machine vertex: a sub-population slice assigned to one PE.
#[derive(Clone, Debug)]
pub struct MachineVertex {
    pub id: usize,
    pub population: PopulationId,
    /// Target-neuron slice simulated/served by this vertex.
    pub slice: SliceRange,
    pub role: VertexRole,
    /// The PE the vertex is placed on (set by placement).
    pub pe: Option<PeHandle>,
    /// DTCM bytes this vertex loads.
    pub dtcm_bytes: usize,
    pub label: String,
}

/// One machine edge: spikes flow from one machine vertex to another.
#[derive(Clone, Debug)]
pub struct MachineEdge {
    pub projection: ProjectionId,
    pub source_vertex: usize,
    pub target_vertex: usize,
}

/// The machine graph.
#[derive(Clone, Debug, Default)]
pub struct MachineGraph {
    pub vertices: Vec<MachineVertex>,
    pub edges: Vec<MachineEdge>,
}

impl MachineGraph {
    pub fn add_vertex(
        &mut self,
        population: PopulationId,
        slice: SliceRange,
        role: VertexRole,
        dtcm_bytes: usize,
        label: String,
    ) -> usize {
        let id = self.vertices.len();
        self.vertices.push(MachineVertex { id, population, slice, role, pe: None, dtcm_bytes, label });
        id
    }

    pub fn add_edge(&mut self, projection: ProjectionId, source_vertex: usize, target_vertex: usize) {
        self.edges.push(MachineEdge { projection, source_vertex, target_vertex });
    }

    /// Vertices belonging to a population.
    pub fn vertices_of(&self, pop: PopulationId) -> Vec<&MachineVertex> {
        self.vertices.iter().filter(|v| v.population == pop).collect()
    }

    /// Machine edges fanning out of a vertex.
    pub fn out_edges(&self, vertex: usize) -> Vec<&MachineEdge> {
        self.edges.iter().filter(|e| e.source_vertex == vertex).collect()
    }

    /// Total DTCM across vertices (proxy for machine memory footprint).
    pub fn total_dtcm(&self) -> usize {
        self.vertices.iter().map(|v| v.dtcm_bytes).sum()
    }

    /// Place every vertex on a machine, allocating PEs in order.
    pub fn place(&mut self, machine: &mut crate::hardware::Machine) -> crate::Result<()> {
        for v in &mut self.vertices {
            let pe = machine.allocate(&v.label, v.dtcm_bytes)?;
            v.pe = Some(pe);
        }
        Ok(())
    }

    /// Place the graph group by group through a strategy-driven
    /// [`Allocator`]: each `(name, vertex indices)` group — a layer's PE
    /// group, or a population's source hosts — is placed transactionally
    /// (all of it or none of it), so a failure names the offending group
    /// and leaves no partially placed layer behind.
    pub fn place_groups(
        &mut self,
        alloc: &mut crate::hardware::Allocator,
        groups: &[(String, Vec<usize>)],
    ) -> crate::Result<()> {
        self.place_groups_on_boards(alloc, groups, &[])
    }

    /// [`MachineGraph::place_groups`] with a per-group board pin: group `i`
    /// is placed with the allocator restricted to `boards[i]` (missing /
    /// `None` entries = unrestricted, full-grid placement). Sharded
    /// placement pins each layer's group to the board the partitioner
    /// assigned it; the restriction is lifted afterwards.
    pub fn place_groups_on_boards(
        &mut self,
        alloc: &mut crate::hardware::Allocator,
        groups: &[(String, Vec<usize>)],
        boards: &[Option<usize>],
    ) -> crate::Result<()> {
        for (i, (name, members)) in groups.iter().enumerate() {
            alloc.restrict_to_board(boards.get(i).copied().flatten());
            let requests: Vec<(&str, usize)> = members
                .iter()
                .map(|&v| (self.vertices[v].label.as_str(), self.vertices[v].dtcm_bytes))
                .collect();
            let placed = alloc.place_group(name, &requests);
            let pes = match placed {
                Ok(pes) => pes,
                Err(e) => {
                    alloc.restrict_to_board(None);
                    return Err(e);
                }
            };
            for (&v, pe) in members.iter().zip(pes) {
                self.vertices[v].pe = Some(pe);
            }
        }
        alloc.restrict_to_board(None);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hardware::Machine;

    #[test]
    fn slice_basics() {
        let s = SliceRange { lo: 10, hi: 20 };
        assert_eq!(s.len(), 10);
        assert!(s.contains(10) && s.contains(19) && !s.contains(20));
        assert!(!s.is_empty());
        assert!(SliceRange { lo: 3, hi: 3 }.is_empty());
    }

    #[test]
    fn place_groups_assigns_and_diagnoses() {
        use crate::hardware::{Allocator, ChipSpec, MachineSpec, PlacementStrategy};
        let mut g = MachineGraph::default();
        let a = g.add_vertex(
            PopulationId(0),
            SliceRange { lo: 0, hi: 10 },
            VertexRole::Source,
            100,
            "src".into(),
        );
        let b = g.add_vertex(
            PopulationId(1),
            SliceRange { lo: 0, hi: 10 },
            VertexRole::Serial,
            200,
            "tgt".into(),
        );
        let groups = vec![("hosts".to_string(), vec![a]), ("layer0".to_string(), vec![b])];
        let mut alloc = Allocator::new(MachineSpec::default(), PlacementStrategy::ChipPacked);
        g.place_groups(&mut alloc, &groups).unwrap();
        assert!(g.vertices.iter().all(|v| v.pe.is_some()));

        // A machine too small for the second group names it in the error.
        let tiny = MachineSpec {
            chips_x: 1,
            chips_y: 1,
            chip: ChipSpec { pes_per_chip: 1, ..Default::default() },
            ..Default::default()
        };
        let mut g2 = g.clone();
        g2.vertices.iter_mut().for_each(|v| v.pe = None);
        let mut alloc = Allocator::new(tiny, PlacementStrategy::Linear);
        let err = g2.place_groups(&mut alloc, &groups).unwrap_err();
        assert!(format!("{err:#}").contains("layer0"), "{err:#}");
    }

    #[test]
    fn place_groups_on_boards_pins_each_group() {
        use crate::hardware::{Allocator, ChipSpec, MachineSpec, PlacementStrategy};
        let mut g = MachineGraph::default();
        let a = g.add_vertex(
            PopulationId(0),
            SliceRange { lo: 0, hi: 4 },
            VertexRole::Source,
            10,
            "a".into(),
        );
        let b = g.add_vertex(
            PopulationId(1),
            SliceRange { lo: 0, hi: 4 },
            VertexRole::Serial,
            10,
            "b".into(),
        );
        let spec = MachineSpec {
            boards: 2,
            chips_x: 1,
            chips_y: 1,
            chip: ChipSpec { pes_per_chip: 4, ..Default::default() },
        };
        let groups = vec![("g0".to_string(), vec![a]), ("g1".to_string(), vec![b])];
        let mut alloc = Allocator::new(spec, PlacementStrategy::Linear);
        g.place_groups_on_boards(&mut alloc, &groups, &[Some(1), Some(0)]).unwrap();
        assert_eq!(spec.board_of_chip_x(g.vertices[a].pe.unwrap().chip_x), 1);
        assert_eq!(spec.board_of_chip_x(g.vertices[b].pe.unwrap().chip_x), 0);
    }

    #[test]
    fn build_and_place() {
        let mut g = MachineGraph::default();
        let a = g.add_vertex(
            PopulationId(0),
            SliceRange { lo: 0, hi: 100 },
            VertexRole::Source,
            1000,
            "src".into(),
        );
        let b = g.add_vertex(
            PopulationId(1),
            SliceRange { lo: 0, hi: 50 },
            VertexRole::Serial,
            2000,
            "tgt".into(),
        );
        g.add_edge(ProjectionId(0), a, b);
        let mut m = Machine::single_chip();
        g.place(&mut m).unwrap();
        assert!(g.vertices.iter().all(|v| v.pe.is_some()));
        assert_eq!(m.allocated_count(), 2);
        assert_eq!(g.total_dtcm(), 3000);
        assert_eq!(g.out_edges(a).len(), 1);
        assert_eq!(g.vertices_of(PopulationId(1)).len(), 1);
    }
}
