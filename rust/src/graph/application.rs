//! Application graph: one vertex per population, one edge per projection.

use crate::model::{Network, PopulationId, ProjectionId};

/// Application-graph vertex — wraps one population.
#[derive(Clone, Debug)]
pub struct AppVertex {
    pub population: PopulationId,
    pub n_neurons: usize,
    pub label: String,
}

/// Application-graph edge — wraps one projection.
#[derive(Clone, Debug)]
pub struct AppEdge {
    pub projection: ProjectionId,
    pub source: PopulationId,
    pub target: PopulationId,
}

/// The application graph (paper Fig. 2: "each vertex of the application
/// graph contains all neurons of one layer, and edges indicate the
/// projections of the inter- and inner-layer").
#[derive(Clone, Debug)]
pub struct AppGraph {
    pub vertices: Vec<AppVertex>,
    pub edges: Vec<AppEdge>,
}

impl AppGraph {
    /// Interpret a network into its application graph.
    pub fn from_network(net: &Network) -> Self {
        let vertices = net
            .populations
            .iter()
            .map(|p| AppVertex {
                population: p.id,
                n_neurons: p.n_neurons,
                label: p.label.clone(),
            })
            .collect();
        let edges = net
            .projections
            .iter()
            .map(|p| AppEdge { projection: p.id, source: p.source, target: p.target })
            .collect();
        AppGraph { vertices, edges }
    }

    /// Edges targeting `pop`.
    pub fn in_edges(&self, pop: PopulationId) -> Vec<&AppEdge> {
        self.edges.iter().filter(|e| e.target == pop).collect()
    }

    /// Edges leaving `pop`.
    pub fn out_edges(&self, pop: PopulationId) -> Vec<&AppEdge> {
        self.edges.iter().filter(|e| e.source == pop).collect()
    }

    pub fn vertex(&self, pop: PopulationId) -> &AppVertex {
        &self.vertices[pop.0]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{Connector, LifParams, NetworkBuilder};
    use crate::model::connector::SynapseDraw;

    #[test]
    fn mirrors_network_topology() {
        let mut b = NetworkBuilder::new(1);
        let a = b.spike_source("in", 10);
        let h = b.lif_population("hid", 20, LifParams::default());
        b.project(a, h, Connector::AllToAll, SynapseDraw::default(), 1.0);
        let net = b.build();
        let g = AppGraph::from_network(&net);
        assert_eq!(g.vertices.len(), 2);
        assert_eq!(g.edges.len(), 1);
        assert_eq!(g.in_edges(h).len(), 1);
        assert_eq!(g.out_edges(a).len(), 1);
        assert_eq!(g.in_edges(a).len(), 0);
        assert_eq!(g.vertex(h).n_neurons, 20);
    }
}
