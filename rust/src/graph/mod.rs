//! Application graph → machine graph mapping (paper Fig. 2, after ref [14]).
//!
//! The SNN model is interpreted into an *application graph* whose vertices
//! hold one population each and whose edges are the projections. Each vertex
//! is split into sub-population *machine vertices* sized to fit one PE, and
//! the sub-population connectivity induces the *machine graph* plus the
//! multicast *routing table* loaded into the NoC routers. On board arrays,
//! [`mod@partition`] first assigns populations to boards, minimizing
//! estimated inter-board spike traffic.

pub mod application;
pub mod machine_graph;
pub mod partition;
pub mod routing;

pub use application::{AppEdge, AppGraph, AppVertex};
pub use machine_graph::{MachineEdge, MachineGraph, MachineVertex, SliceRange};
pub use partition::{partition, BoardAssignment, PartitionStrategy};
pub use routing::{RoutingEntry, RoutingTable};
