//! Multicast routing tables.
//!
//! "The connection relations of these sub-populations contribute to
//! generating a routing table." (paper §III). SpiNNaker-style routing keys
//! are (population, source-slice) pairs; each entry fans a source machine
//! vertex's spikes out to every machine vertex that consumes them.

use super::machine_graph::MachineGraph;
use crate::hardware::noc::{Noc, TreeHops};
use crate::hardware::PeHandle;
use std::collections::BTreeMap;

/// Routing key: identifies the spike-emitting machine vertex.
pub type RouteKey = u32;

/// One multicast route: key → set of destination PEs.
#[derive(Clone, Debug, PartialEq)]
pub struct RoutingEntry {
    pub key: RouteKey,
    pub source_vertex: usize,
    pub destinations: Vec<PeHandle>,
}

/// The machine's routing table.
#[derive(Clone, Debug, Default)]
pub struct RoutingTable {
    pub entries: Vec<RoutingEntry>,
    by_key: BTreeMap<RouteKey, usize>,
}

impl RoutingTable {
    /// Derive the routing table from a placed machine graph.
    ///
    /// Panics if the graph has unplaced vertices (placement must precede
    /// routing, as in Fig. 2's pipeline order).
    pub fn from_machine_graph(graph: &MachineGraph) -> Self {
        let mut table = RoutingTable::default();
        for v in &graph.vertices {
            let mut dests: Vec<PeHandle> = graph
                .out_edges(v.id)
                .iter()
                .map(|e| {
                    graph.vertices[e.target_vertex]
                        .pe
                        .expect("routing requires placed vertices")
                })
                .collect();
            dests.sort();
            dests.dedup();
            if !dests.is_empty() {
                let key = v.id as RouteKey;
                table.by_key.insert(key, table.entries.len());
                table.entries.push(RoutingEntry { key, source_vertex: v.id, destinations: dests });
            }
        }
        table
    }

    /// Look up the destinations for a source vertex's spikes.
    pub fn route(&self, key: RouteKey) -> Option<&RoutingEntry> {
        self.by_key.get(&key).map(|&i| &self.entries[i])
    }

    /// Number of multicast entries (router memory proxy).
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// Sum of x-then-y multicast-tree inter-chip hops over every entry —
    /// the static routing cost one packet per entry would incur. A
    /// placement-quality metric: co-located placements score lower than
    /// scattered ones on the same machine graph.
    ///
    /// Panics if the graph has unplaced vertices (like
    /// [`RoutingTable::from_machine_graph`]).
    pub fn total_tree_hops(&self, graph: &MachineGraph) -> u64 {
        self.tree_hops_split(graph, 0).total()
    }

    /// [`RoutingTable::total_tree_hops`] with the board-link split: on a
    /// board array of `board_chips_x`-column boards, x links crossing a
    /// board boundary are charged separately from on-board x-then-y hops
    /// (board links are an order of magnitude slower, so strategy
    /// comparisons must not conflate the two). `board_chips_x == 0` means
    /// no boundaries — everything counts as on-board, matching the
    /// single-machine seed accounting.
    pub fn tree_hops_split(&self, graph: &MachineGraph, board_chips_x: usize) -> TreeHops {
        let mut hops = TreeHops::default();
        for e in &self.entries {
            let src = graph.vertices[e.source_vertex].pe.expect("placed");
            hops += Noc::multicast_tree_hops_split(src, &e.destinations, board_chips_x);
        }
        hops
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::machine_graph::{SliceRange, VertexRole};
    use crate::hardware::Machine;
    use crate::model::{PopulationId, ProjectionId};

    fn placed_graph() -> MachineGraph {
        let mut g = MachineGraph::default();
        let s = g.add_vertex(PopulationId(0), SliceRange { lo: 0, hi: 10 }, VertexRole::Source, 100, "s".into());
        let a = g.add_vertex(PopulationId(1), SliceRange { lo: 0, hi: 5 }, VertexRole::Serial, 100, "a".into());
        let b = g.add_vertex(PopulationId(1), SliceRange { lo: 5, hi: 10 }, VertexRole::Serial, 100, "b".into());
        g.add_edge(ProjectionId(0), s, a);
        g.add_edge(ProjectionId(0), s, b);
        let mut m = Machine::single_chip();
        g.place(&mut m).unwrap();
        g
    }

    #[test]
    fn fans_out_to_all_consumers() {
        let g = placed_graph();
        let t = RoutingTable::from_machine_graph(&g);
        assert_eq!(t.len(), 1);
        let e = t.route(0).unwrap();
        assert_eq!(e.destinations.len(), 2);
    }

    #[test]
    fn leaf_vertices_emit_no_entries() {
        let g = placed_graph();
        let t = RoutingTable::from_machine_graph(&g);
        assert!(t.route(1).is_none());
        assert!(t.route(2).is_none());
    }

    #[test]
    fn dedups_destinations() {
        let mut g = MachineGraph::default();
        let s = g.add_vertex(PopulationId(0), SliceRange { lo: 0, hi: 4 }, VertexRole::Source, 10, "s".into());
        let a = g.add_vertex(PopulationId(1), SliceRange { lo: 0, hi: 4 }, VertexRole::Serial, 10, "a".into());
        // Two projections between the same pair → one destination.
        g.add_edge(ProjectionId(0), s, a);
        g.add_edge(ProjectionId(1), s, a);
        let mut m = Machine::single_chip();
        g.place(&mut m).unwrap();
        let t = RoutingTable::from_machine_graph(&g);
        assert_eq!(t.route(0).unwrap().destinations.len(), 1);
    }

    #[test]
    fn tree_hops_reflect_placement_spread() {
        use crate::hardware::{Allocator, ChipSpec, MachineSpec, PlacementStrategy};
        let build = |strategy: PlacementStrategy| {
            let mut g = MachineGraph::default();
            let s = g.add_vertex(
                PopulationId(0),
                SliceRange { lo: 0, hi: 8 },
                VertexRole::Source,
                10,
                "s".into(),
            );
            let mut members = vec![s];
            for i in 0..3 {
                let v = g.add_vertex(
                    PopulationId(1),
                    SliceRange { lo: i, hi: i + 1 },
                    VertexRole::Serial,
                    10,
                    format!("t{i}"),
                );
                g.add_edge(ProjectionId(0), s, v);
                members.push(v);
            }
            let spec = MachineSpec {
                chips_x: 4,
                chips_y: 1,
                chip: ChipSpec { pes_per_chip: 4, ..Default::default() },
                ..Default::default()
            };
            let mut alloc = Allocator::new(spec, strategy);
            let groups = vec![("g".to_string(), members)];
            g.place_groups(&mut alloc, &groups).unwrap();
            let t = RoutingTable::from_machine_graph(&g);
            t.total_tree_hops(&g)
        };
        let packed = build(PlacementStrategy::ChipPacked);
        let spread = build(PlacementStrategy::Balanced);
        assert_eq!(packed, 0, "a co-located group needs no inter-chip links");
        assert!(spread > 0, "a spread group must cross chips");
    }

    #[test]
    fn tree_hops_split_separates_board_links() {
        use crate::hardware::{Allocator, ChipSpec, MachineSpec, PlacementStrategy};
        // A source on board 0 feeding targets on board 1 of a 2-board,
        // 1-column-per-board machine: every x link crosses the boundary.
        let mut g = MachineGraph::default();
        let s = g.add_vertex(
            PopulationId(0),
            SliceRange { lo: 0, hi: 4 },
            VertexRole::Source,
            10,
            "s".into(),
        );
        let a = g.add_vertex(
            PopulationId(1),
            SliceRange { lo: 0, hi: 4 },
            VertexRole::Serial,
            10,
            "a".into(),
        );
        g.add_edge(ProjectionId(0), s, a);
        let spec = MachineSpec {
            boards: 2,
            chips_x: 1,
            chips_y: 1,
            chip: ChipSpec { pes_per_chip: 1, ..Default::default() },
        };
        // One PE per chip forces s → chip 0 (board 0), a → chip 1 (board 1).
        let mut alloc = Allocator::new(spec, PlacementStrategy::Linear);
        let groups = vec![("g".to_string(), vec![s, a])];
        g.place_groups(&mut alloc, &groups).unwrap();
        let t = RoutingTable::from_machine_graph(&g);
        let split = t.tree_hops_split(&g, spec.chips_x);
        assert_eq!(split, TreeHops { on_board: 0, board_links: 1 });
        assert_eq!(split.total(), t.total_tree_hops(&g));
        // Width 0 conflates the classes back into on-board, seed-style.
        let flat = t.tree_hops_split(&g, 0);
        assert_eq!(flat, TreeHops { on_board: 1, board_links: 0 });
    }

    #[test]
    #[should_panic(expected = "placed")]
    fn requires_placement() {
        let mut g = MachineGraph::default();
        let s = g.add_vertex(PopulationId(0), SliceRange { lo: 0, hi: 4 }, VertexRole::Source, 10, "s".into());
        let a = g.add_vertex(PopulationId(1), SliceRange { lo: 0, hi: 4 }, VertexRole::Serial, 10, "a".into());
        g.add_edge(ProjectionId(0), s, a);
        RoutingTable::from_machine_graph(&g);
    }
}
