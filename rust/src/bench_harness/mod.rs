//! Hand-rolled benchmark harness (criterion substitute — the offline
//! vendored crate set has no criterion).
//!
//! Each `cargo bench` target is a `harness = false` binary that uses
//! [`Bench`] for timed measurements and [`Report`] to print the paper's
//! table/figure rows as aligned text plus a machine-readable CSV dump under
//! `bench_out/`.

use std::time::Instant;

/// The one latency-percentile accumulator every latency report in the tree
/// shares: [`Bench`] iteration stats, the `simulate --batch` per-sample
/// latency line, and the serve daemon's per-request accounting all feed
/// this instead of growing private percentile copies.
///
/// Samples are raw nanoseconds; sorting is lazy (first percentile query
/// after a record sorts once), so recording on a hot path is a plain push.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct LatencyHistogram {
    ns: Vec<f64>,
    sorted: bool,
}

impl LatencyHistogram {
    pub fn new() -> Self {
        LatencyHistogram::default()
    }

    /// Build from integer-nanosecond samples (e.g. `BatchRun::sample_nanos`).
    pub fn from_nanos<I: IntoIterator<Item = u64>>(samples: I) -> Self {
        let mut h = LatencyHistogram::new();
        for s in samples {
            h.record(s);
        }
        h
    }

    pub fn record(&mut self, nanos: u64) {
        self.record_f64(nanos as f64);
    }

    pub fn record_f64(&mut self, nanos: f64) {
        self.ns.push(nanos);
        self.sorted = false;
    }

    /// Fold another histogram's samples into this one.
    pub fn merge(&mut self, other: &LatencyHistogram) {
        self.ns.extend_from_slice(&other.ns);
        self.sorted = self.ns.is_empty();
    }

    pub fn len(&self) -> usize {
        self.ns.len()
    }

    pub fn is_empty(&self) -> bool {
        self.ns.is_empty()
    }

    fn ensure_sorted(&mut self) {
        if !self.sorted {
            self.ns.sort_by(|a, b| a.partial_cmp(b).unwrap());
            self.sorted = true;
        }
    }

    /// Nearest-rank percentile in nanoseconds (`p` in `[0, 1]`; `0.0` is
    /// the minimum, `1.0` the maximum). Empty histograms report 0.
    pub fn percentile(&mut self, p: f64) -> f64 {
        if self.ns.is_empty() {
            return 0.0;
        }
        self.ensure_sorted();
        let n = self.ns.len();
        self.ns[((n as f64 - 1.0) * p.clamp(0.0, 1.0)).round() as usize]
    }

    /// Arithmetic mean in nanoseconds (0 when empty).
    pub fn mean(&self) -> f64 {
        if self.ns.is_empty() {
            return 0.0;
        }
        self.ns.iter().sum::<f64>() / self.ns.len() as f64
    }

    /// One-line human-readable summary: the quantities serve and
    /// `simulate --batch` print on exit.
    pub fn summary(&mut self) -> String {
        format!(
            "p50 {} | p99 {} | p999 {} | mean {} | max {} ({} samples)",
            human_ns(self.percentile(0.50)),
            human_ns(self.percentile(0.99)),
            human_ns(self.percentile(0.999)),
            human_ns(self.mean()),
            human_ns(self.percentile(1.0)),
            self.len()
        )
    }
}

/// Statistics over a set of per-iteration timings.
#[derive(Clone, Debug)]
pub struct Stats {
    pub iters: usize,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p99_ns: f64,
    pub min_ns: f64,
    pub max_ns: f64,
}

impl Stats {
    fn from_samples(ns: Vec<f64>) -> Stats {
        let mut h = LatencyHistogram::default();
        for v in ns {
            h.record_f64(v);
        }
        Stats {
            iters: h.len(),
            mean_ns: h.mean(),
            p50_ns: h.percentile(0.50),
            p99_ns: h.percentile(0.99),
            min_ns: h.percentile(0.0),
            max_ns: h.percentile(1.0),
        }
    }

    /// Human-readable mean with unit scaling.
    pub fn human_mean(&self) -> String {
        human_ns(self.mean_ns)
    }
}

/// Scale nanoseconds to a readable unit.
pub fn human_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Timed-measurement runner with warmup.
pub struct Bench {
    warmup_iters: usize,
    measure_iters: usize,
}

impl Default for Bench {
    fn default() -> Self {
        Bench { warmup_iters: 3, measure_iters: 10 }
    }
}

impl Bench {
    pub fn new(warmup_iters: usize, measure_iters: usize) -> Self {
        Bench { warmup_iters, measure_iters }
    }

    /// Measure `f`, returning timing stats. The closure's return value is
    /// passed through `std::hint::black_box` to defeat dead-code elimination.
    pub fn run<T>(&self, name: &str, mut f: impl FnMut() -> T) -> Stats {
        for _ in 0..self.warmup_iters {
            std::hint::black_box(f());
        }
        let mut samples = Vec::with_capacity(self.measure_iters);
        for _ in 0..self.measure_iters {
            let t0 = Instant::now();
            std::hint::black_box(f());
            samples.push(t0.elapsed().as_nanos() as f64);
        }
        let stats = Stats::from_samples(samples);
        println!(
            "  [bench] {:<42} mean {:>12}  p50 {:>12}  p99 {:>12}  ({} iters)",
            name,
            human_ns(stats.mean_ns),
            human_ns(stats.p50_ns),
            human_ns(stats.p99_ns),
            stats.iters
        );
        stats
    }
}

/// Tabular report printer + CSV dump, one per paper table/figure.
pub struct Report {
    title: String,
    columns: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Report {
    pub fn new(title: &str, columns: &[&str]) -> Self {
        Report {
            title: title.to_string(),
            columns: columns.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.columns.len(), "row arity mismatch");
        self.rows.push(cells);
    }

    /// Print the aligned table and write `bench_out/<slug>.csv`.
    pub fn finish(self) {
        let mut widths: Vec<usize> = self.columns.iter().map(|c| c.len()).collect();
        for row in &self.rows {
            for (i, cell) in row.iter().enumerate() {
                widths[i] = widths[i].max(cell.len());
            }
        }
        println!("\n=== {} ===", self.title);
        let fmt_row = |cells: &[String]| {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:<w$}", c, w = widths[i]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        println!("{}", fmt_row(&self.columns));
        println!("{}", "-".repeat(widths.iter().sum::<usize>() + 2 * (widths.len() - 1)));
        for row in &self.rows {
            println!("{}", fmt_row(row));
        }
        // CSV dump for downstream plotting.
        let slug: String = self
            .title
            .to_lowercase()
            .chars()
            .map(|c| if c.is_alphanumeric() { c } else { '_' })
            .collect();
        let dir = std::path::Path::new("bench_out");
        let path = dir.join(format!("{}.csv", slug.trim_matches('_')));
        let cols: Vec<&str> = self.columns.iter().map(|s| s.as_str()).collect();
        // The CSV layer has no quoting — sanitize display-oriented cells.
        let sanitized: Vec<Vec<String>> = self
            .rows
            .iter()
            .map(|r| r.iter().map(|c| c.replace([',', '\n'], ";")).collect())
            .collect();
        if let Err(e) = crate::io::csv::write_csv(&path, &cols, sanitized) {
            eprintln!("warning: could not write {}: {e}", path.display());
        } else {
            println!("(csv written to {})", path.display());
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_percentiles_ordered() {
        let s = Stats::from_samples((1..=100).map(|i| i as f64).collect());
        assert!(s.min_ns <= s.p50_ns && s.p50_ns <= s.p99_ns && s.p99_ns <= s.max_ns);
        assert_eq!(s.iters, 100);
        assert!((s.mean_ns - 50.5).abs() < 1e-9);
    }

    #[test]
    fn bench_runs_and_counts() {
        let b = Bench::new(1, 5);
        let mut calls = 0usize;
        let s = b.run("noop", || {
            calls += 1;
            calls
        });
        assert_eq!(s.iters, 5);
        assert_eq!(calls, 6); // warmup + measured
    }

    #[test]
    fn human_units() {
        assert!(human_ns(500.0).ends_with("ns"));
        assert!(human_ns(5_000.0).ends_with("µs"));
        assert!(human_ns(5_000_000.0).ends_with("ms"));
        assert!(human_ns(5_000_000_000.0).ends_with('s'));
    }

    #[test]
    #[should_panic(expected = "row arity")]
    fn report_rejects_bad_arity() {
        let mut r = Report::new("t", &["a", "b"]);
        r.row(vec!["1".into()]);
    }
}
