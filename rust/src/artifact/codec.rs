//! Hand-rolled little-endian binary codec for compiled artifacts.
//!
//! No serde / bincode in the offline crate set, so the wire format is
//! explicit: every number is little-endian, every variable-length field is
//! preceded by a `u64` element count, and enums travel as one tag byte.
//! Because the compiled structures were flattened into contiguous buffers
//! (flat `weights`, span-indexed tables), encoding is a linear walk and
//! decoding is a bulk `from_le_bytes` sweep — no pointer chasing, no
//! per-element allocation beyond the target `Vec`s themselves.
//!
//! Container layout (see DESIGN.md §Artifact-Format):
//!
//! ```text
//! header  (24 B): magic u32 | version u32 | n_sections u32 | reserved u32
//!                 | payload_len u64
//! section (20 B + body): tag u32 | body_len u64 | fnv1a64(body) u64 | body
//! ```
//!
//! The decoder rejects — with a typed [`ArtifactError`], never a panic —
//! wrong magic, unsupported versions, any length that runs past the buffer
//! (truncation), and any section whose checksum does not match its body.

use super::ArtifactError;
use crate::costmodel::parallel::DominantCost;
use crate::costmodel::serial::SerialCost;
use crate::graph::machine_graph::SliceRange;
use crate::hardware::MacArraySpec;
use crate::model::{LayerCharacter, LifParams};
use crate::paradigm::parallel::compiler::SubordinateProgram;
use crate::paradigm::parallel::splitting::{Chunk, SplitPlan};
use crate::paradigm::parallel::structures::MergeEntry;
use crate::paradigm::parallel::{DominantTables, ParallelCompiled, Wdm, WdmConfig};
use crate::paradigm::serial::{
    AddressEntry, AddressList, MasterPopulationTable, SerialCompiled, SerialPeProgram,
    SynapticMatrix, SynapticWord,
};
use crate::paradigm::{CompiledLayer, CostEstimate, Paradigm};

/// `"S2AF"` as a little-endian u32 — the first four bytes of every artifact.
pub const MAGIC: u32 = u32::from_le_bytes(*b"S2AF");
/// Bump on ANY wire-format change: readers reject other versions, which
/// demotes every existing on-disk artifact to a cache miss (recompile +
/// overwrite) instead of a misparse.
pub const VERSION: u32 = 1;

/// Section tags.
pub const SEC_LAYER: u32 = 1;
pub const SEC_ESTIMATE: u32 = 2;
pub const SEC_DECISIONS: u32 = 3;

const HEADER_BYTES: usize = 24;
const SECTION_HEADER_BYTES: usize = 20;

/// FNV-1a over a byte slice — the per-section checksum.
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x1000_0000_01b3);
    }
    h
}

// ---------------------------------------------------------------- encoder

/// Little-endian append-only byte sink.
#[derive(Default)]
pub struct Enc {
    pub buf: Vec<u8>,
}

impl Enc {
    fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    fn u16(&mut self, v: u16) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    fn usize(&mut self, v: usize) {
        self.u64(v as u64);
    }

    fn f32(&mut self, v: f32) {
        self.u32(v.to_bits());
    }

    fn f64(&mut self, v: f64) {
        self.u64(v.to_bits());
    }

    fn bulk_u32(&mut self, vs: &[u32]) {
        self.usize(vs.len());
        self.buf.reserve(4 * vs.len());
        for &v in vs {
            self.buf.extend_from_slice(&v.to_le_bytes());
        }
    }

    fn bulk_i16(&mut self, vs: &[i16]) {
        self.usize(vs.len());
        self.buf.reserve(2 * vs.len());
        for &v in vs {
            self.buf.extend_from_slice(&v.to_le_bytes());
        }
    }
}

// ---------------------------------------------------------------- decoder

/// Bounds-checked little-endian reader; every overrun is a typed
/// [`ArtifactError::Truncated`] carrying the field being read.
pub struct Dec<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Dec<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        Dec { buf, pos: 0 }
    }

    fn take(&mut self, n: usize, what: &'static str) -> Result<&'a [u8], ArtifactError> {
        let end = self.pos.checked_add(n).ok_or(ArtifactError::Truncated {
            what,
            need: u64::MAX,
            have: self.buf.len() as u64,
        })?;
        if end > self.buf.len() {
            return Err(ArtifactError::Truncated {
                what,
                need: end as u64,
                have: self.buf.len() as u64,
            });
        }
        let s = &self.buf[self.pos..end];
        self.pos = end;
        Ok(s)
    }

    fn u8(&mut self, what: &'static str) -> Result<u8, ArtifactError> {
        Ok(self.take(1, what)?[0])
    }

    fn u16(&mut self, what: &'static str) -> Result<u16, ArtifactError> {
        Ok(u16::from_le_bytes(self.take(2, what)?.try_into().unwrap()))
    }

    fn u32(&mut self, what: &'static str) -> Result<u32, ArtifactError> {
        Ok(u32::from_le_bytes(self.take(4, what)?.try_into().unwrap()))
    }

    fn u64(&mut self, what: &'static str) -> Result<u64, ArtifactError> {
        Ok(u64::from_le_bytes(self.take(8, what)?.try_into().unwrap()))
    }

    fn usize(&mut self, what: &'static str) -> Result<usize, ArtifactError> {
        Ok(self.u64(what)? as usize)
    }

    /// An element count that will drive an allocation: length-checked
    /// against the remaining bytes so a corrupt count cannot trigger a
    /// multi-gigabyte `Vec::with_capacity`.
    fn count(&mut self, elem_bytes: usize, what: &'static str) -> Result<usize, ArtifactError> {
        let n = self.usize(what)?;
        let need = n.checked_mul(elem_bytes).unwrap_or(usize::MAX);
        if self.pos.saturating_add(need) > self.buf.len() {
            return Err(ArtifactError::Truncated {
                what,
                need: (self.pos as u64).saturating_add(need as u64),
                have: self.buf.len() as u64,
            });
        }
        Ok(n)
    }

    fn f32(&mut self, what: &'static str) -> Result<f32, ArtifactError> {
        Ok(f32::from_bits(self.u32(what)?))
    }

    fn f64(&mut self, what: &'static str) -> Result<f64, ArtifactError> {
        Ok(f64::from_bits(self.u64(what)?))
    }

    fn bulk_u32(&mut self, what: &'static str) -> Result<Vec<u32>, ArtifactError> {
        let n = self.count(4, what)?;
        let raw = self.take(4 * n, what)?;
        Ok(raw.chunks_exact(4).map(|c| u32::from_le_bytes(c.try_into().unwrap())).collect())
    }

    fn bulk_i16(&mut self, what: &'static str) -> Result<Vec<i16>, ArtifactError> {
        let n = self.count(2, what)?;
        let raw = self.take(2 * n, what)?;
        Ok(raw.chunks_exact(2).map(|c| i16::from_le_bytes(c.try_into().unwrap())).collect())
    }

    fn done(&self) -> bool {
        self.pos == self.buf.len()
    }
}

// ----------------------------------------------------------- container

/// Frame `sections` into a checksummed artifact byte stream.
pub fn write_container(sections: &[(u32, Vec<u8>)]) -> Vec<u8> {
    let payload_len: usize =
        sections.iter().map(|(_, b)| SECTION_HEADER_BYTES + b.len()).sum();
    let mut e = Enc::default();
    e.u32(MAGIC);
    e.u32(VERSION);
    e.u32(sections.len() as u32);
    e.u32(0); // reserved
    e.usize(payload_len);
    for (tag, body) in sections {
        e.u32(*tag);
        e.usize(body.len());
        e.u64(fnv1a64(body));
        e.buf.extend_from_slice(body);
    }
    e.buf
}

/// Parse + validate a container: magic, version, declared payload length,
/// per-section bounds and checksums. Returns `(tag, body)` pairs borrowing
/// from `bytes`.
pub fn read_container(bytes: &[u8]) -> Result<Vec<(u32, &[u8])>, ArtifactError> {
    let mut d = Dec::new(bytes);
    let magic = d.u32("header magic")?;
    if magic != MAGIC {
        return Err(ArtifactError::BadMagic { found: magic });
    }
    let version = d.u32("header version")?;
    if version != VERSION {
        return Err(ArtifactError::BadVersion { found: version, supported: VERSION });
    }
    let n_sections = d.u32("header section count")?;
    let _reserved = d.u32("header reserved")?;
    let payload_len = d.u64("header payload length")?;
    let have = (bytes.len() - HEADER_BYTES) as u64;
    if payload_len != have {
        return Err(ArtifactError::Truncated {
            what: "container payload",
            need: HEADER_BYTES as u64 + payload_len,
            have: bytes.len() as u64,
        });
    }
    // Bound the allocation by what the payload could actually hold (each
    // section needs at least its 20 B header): a corrupt n_sections must
    // fail as Truncated below, not abort in the allocator.
    let max_sections = payload_len as usize / SECTION_HEADER_BYTES;
    if n_sections as usize > max_sections {
        return Err(ArtifactError::Truncated {
            what: "section headers",
            need: n_sections as u64 * SECTION_HEADER_BYTES as u64,
            have: payload_len,
        });
    }
    let mut sections = Vec::with_capacity(n_sections as usize);
    for _ in 0..n_sections {
        let tag = d.u32("section tag")?;
        let len = d.usize("section length")?;
        let stored = d.u64("section checksum")?;
        let body = d.take(len, "section body")?;
        let computed = fnv1a64(body);
        if computed != stored {
            return Err(ArtifactError::ChecksumMismatch { section: tag, stored, computed });
        }
        sections.push((tag, body));
    }
    if !d.done() {
        return Err(ArtifactError::Malformed {
            what: "container",
            detail: "trailing bytes after the last declared section".into(),
        });
    }
    Ok(sections)
}

// ------------------------------------------------------- leaf structures

fn put_character(e: &mut Enc, ch: &LayerCharacter) {
    e.usize(ch.n_source);
    e.usize(ch.n_target);
    e.f64(ch.density);
    e.u16(ch.delay_range);
}

fn get_character(d: &mut Dec) -> Result<LayerCharacter, ArtifactError> {
    Ok(LayerCharacter {
        n_source: d.usize("character n_source")?,
        n_target: d.usize("character n_target")?,
        density: d.f64("character density")?,
        delay_range: d.u16("character delay_range")?,
    })
}

fn put_params(e: &mut Enc, p: &LifParams) {
    e.f32(p.alpha);
    e.f32(p.v_th);
    e.f32(p.v_rest);
    e.u32(p.t_refrac);
    e.f32(p.i_offset);
    e.f32(p.v_init);
    e.f32(p.w_exc_scale);
    e.f32(p.w_inh_scale);
}

fn get_params(d: &mut Dec) -> Result<LifParams, ArtifactError> {
    Ok(LifParams {
        alpha: d.f32("lif alpha")?,
        v_th: d.f32("lif v_th")?,
        v_rest: d.f32("lif v_rest")?,
        t_refrac: d.u32("lif t_refrac")?,
        i_offset: d.f32("lif i_offset")?,
        v_init: d.f32("lif v_init")?,
        w_exc_scale: d.f32("lif w_exc_scale")?,
        w_inh_scale: d.f32("lif w_inh_scale")?,
    })
}

fn put_slice_range(e: &mut Enc, s: &SliceRange) {
    e.u32(s.lo);
    e.u32(s.hi);
}

fn get_slice_range(d: &mut Dec) -> Result<SliceRange, ArtifactError> {
    Ok(SliceRange { lo: d.u32("slice lo")?, hi: d.u32("slice hi")? })
}

fn put_paradigm(e: &mut Enc, p: Paradigm) {
    e.u8(p.label() as u8);
}

fn get_paradigm(d: &mut Dec) -> Result<Paradigm, ArtifactError> {
    match d.u8("paradigm tag")? {
        0 => Ok(Paradigm::Serial),
        1 => Ok(Paradigm::Parallel),
        t => Err(ArtifactError::Malformed {
            what: "paradigm tag",
            detail: format!("unknown value {t}"),
        }),
    }
}

// ------------------------------------------------------- serial paradigm

fn put_serial_cost(e: &mut Enc, c: &SerialCost) {
    for v in [
        c.input_spike_buffer,
        c.dma_buffer,
        c.master_population_table,
        c.address_list,
        c.synaptic_matrix,
        c.synaptic_input_buffer,
        c.neuron_synapse_model,
        c.output_recording,
        c.stack_heap,
        c.hw_mgmt_os,
    ] {
        e.usize(v);
    }
}

fn get_serial_cost(d: &mut Dec) -> Result<SerialCost, ArtifactError> {
    Ok(SerialCost {
        input_spike_buffer: d.usize("serial cost")?,
        dma_buffer: d.usize("serial cost")?,
        master_population_table: d.usize("serial cost")?,
        address_list: d.usize("serial cost")?,
        synaptic_matrix: d.usize("serial cost")?,
        synaptic_input_buffer: d.usize("serial cost")?,
        neuron_synapse_model: d.usize("serial cost")?,
        output_recording: d.usize("serial cost")?,
        stack_heap: d.usize("serial cost")?,
        hw_mgmt_os: d.usize("serial cost")?,
    })
}

fn put_serial_pe(e: &mut Enc, pe: &SerialPeProgram) {
    put_slice_range(e, &pe.target_slice);
    put_slice_range(e, &pe.source_slice);
    e.usize(pe.mpt.entries.len());
    for &(lo, hi, base) in &pe.mpt.entries {
        e.u32(lo);
        e.u32(hi);
        e.u32(base);
    }
    e.usize(pe.address_list.entries.len());
    for entry in &pe.address_list.entries {
        e.u32(entry.first_word);
        e.u32(entry.row_length);
    }
    // Packed synaptic words are already a flat u32 array: bulk copy.
    e.usize(pe.matrix.words.len());
    e.buf.reserve(4 * pe.matrix.words.len());
    for w in &pe.matrix.words {
        e.buf.extend_from_slice(&w.0.to_le_bytes());
    }
    e.u16(pe.delay_range);
    put_params(e, &pe.params);
    e.f32(pe.weight_scale);
    put_serial_cost(e, &pe.cost);
}

fn get_serial_pe(d: &mut Dec) -> Result<SerialPeProgram, ArtifactError> {
    let target_slice = get_slice_range(d)?;
    let source_slice = get_slice_range(d)?;
    let n_mpt = d.count(12, "mpt entries")?;
    let mut mpt = MasterPopulationTable::default();
    mpt.entries.reserve_exact(n_mpt);
    for _ in 0..n_mpt {
        mpt.entries.push((d.u32("mpt lo")?, d.u32("mpt hi")?, d.u32("mpt base")?));
    }
    let n_al = d.count(8, "address list")?;
    let mut address_list = AddressList::default();
    address_list.entries.reserve_exact(n_al);
    for _ in 0..n_al {
        address_list.entries.push(AddressEntry {
            first_word: d.u32("address first_word")?,
            row_length: d.u32("address row_length")?,
        });
    }
    let words = d.bulk_u32("synaptic matrix")?;
    let matrix = SynapticMatrix { words: words.into_iter().map(SynapticWord).collect() };
    Ok(SerialPeProgram {
        target_slice,
        source_slice,
        mpt,
        address_list,
        matrix,
        delay_range: d.u16("serial delay_range")?,
        params: get_params(d)?,
        weight_scale: d.f32("serial weight_scale")?,
        cost: get_serial_cost(d)?,
    })
}

fn put_serial(e: &mut Enc, c: &SerialCompiled) {
    put_character(e, &c.character);
    e.usize(c.n_target_chunks);
    e.usize(c.n_source_vertex);
    e.usize(c.pes.len());
    for pe in &c.pes {
        put_serial_pe(e, pe);
    }
}

fn get_serial(d: &mut Dec) -> Result<SerialCompiled, ArtifactError> {
    let character = get_character(d)?;
    let n_target_chunks = d.usize("n_target_chunks")?;
    let n_source_vertex = d.usize("n_source_vertex")?;
    let n_pes = d.count(1, "serial PE count")?;
    let mut pes = Vec::with_capacity(n_pes);
    for _ in 0..n_pes {
        pes.push(get_serial_pe(d)?);
    }
    Ok(SerialCompiled { pes, character, n_target_chunks, n_source_vertex })
}

// ----------------------------------------------------- parallel paradigm

fn put_wdm_config(e: &mut Enc, c: &WdmConfig) {
    let flags = (c.zero_row_elimination as u8)
        | (c.zero_col_elimination as u8) << 1
        | (c.delay_slot_merging as u8) << 2
        | (c.quantize_8bit as u8) << 3;
    e.u8(flags);
    e.usize(c.mac.rows);
    e.usize(c.mac.cols);
    e.usize(c.mac.operand_bits);
    e.usize(c.mac.output_bits);
}

fn get_wdm_config(d: &mut Dec) -> Result<WdmConfig, ArtifactError> {
    let flags = d.u8("wdm flags")?;
    Ok(WdmConfig {
        zero_row_elimination: flags & 1 != 0,
        zero_col_elimination: flags & 2 != 0,
        delay_slot_merging: flags & 4 != 0,
        quantize_8bit: flags & 8 != 0,
        mac: MacArraySpec {
            rows: d.usize("mac rows")?,
            cols: d.usize("mac cols")?,
            operand_bits: d.usize("mac operand_bits")?,
            output_bits: d.usize("mac output_bits")?,
        },
    })
}

fn put_wdm(e: &mut Enc, w: &Wdm) {
    // Row keys packed as (delay u16, source u32) pairs.
    e.usize(w.rows.len());
    e.buf.reserve(6 * w.rows.len());
    for rk in &w.rows {
        e.buf.extend_from_slice(&rk.delay.to_le_bytes());
        e.buf.extend_from_slice(&rk.source.to_le_bytes());
    }
    e.bulk_u32(&w.cols);
    e.bulk_i16(&w.weights);
    put_wdm_config(e, &w.config);
    e.u16(w.delay_range);
}

fn get_wdm(d: &mut Dec) -> Result<Wdm, ArtifactError> {
    use crate::paradigm::parallel::wdm::RowKey;
    let n_rows = d.count(6, "wdm rows")?;
    let mut rows = Vec::with_capacity(n_rows);
    for _ in 0..n_rows {
        rows.push(RowKey { delay: d.u16("row delay")?, source: d.u32("row source")? });
    }
    Ok(Wdm {
        rows,
        cols: d.bulk_u32("wdm cols")?,
        weights: d.bulk_i16("wdm weights")?,
        config: get_wdm_config(d)?,
        delay_range: d.u16("wdm delay_range")?,
    })
}

fn put_tables(e: &mut Enc, t: &DominantTables) {
    e.usize(t.reversed_order.len());
    e.buf.reserve(8 * t.reversed_order.len());
    for &(lo, hi) in &t.reversed_order {
        e.buf.extend_from_slice(&lo.to_le_bytes());
        e.buf.extend_from_slice(&hi.to_le_bytes());
    }
    e.usize(t.merging.len());
    e.buf.reserve(6 * t.merging.len());
    for m in &t.merging {
        e.buf.extend_from_slice(&m.delay.to_le_bytes());
        e.buf.extend_from_slice(&m.row.to_le_bytes());
    }
}

fn get_tables(d: &mut Dec) -> Result<DominantTables, ArtifactError> {
    let n_ro = d.count(8, "reversed order")?;
    let mut reversed_order = Vec::with_capacity(n_ro);
    for _ in 0..n_ro {
        reversed_order.push((d.u32("reversed lo")?, d.u32("reversed hi")?));
    }
    let n_merge = d.count(6, "merging table")?;
    let mut merging = Vec::with_capacity(n_merge);
    for _ in 0..n_merge {
        merging.push(MergeEntry { delay: d.u16("merge delay")?, row: d.u32("merge row")? });
    }
    Ok(DominantTables { reversed_order, merging })
}

fn put_dominant_cost(e: &mut Enc, c: &DominantCost) {
    for v in [
        c.input_spike_buffer,
        c.reversed_order,
        c.input_merging_table,
        c.stacked_input,
        c.neuron_synapse_model,
        c.output_recording,
        c.stack_heap,
        c.hw_mgmt_os,
    ] {
        e.usize(v);
    }
}

fn get_dominant_cost(d: &mut Dec) -> Result<DominantCost, ArtifactError> {
    Ok(DominantCost {
        input_spike_buffer: d.usize("dominant cost")?,
        reversed_order: d.usize("dominant cost")?,
        input_merging_table: d.usize("dominant cost")?,
        stacked_input: d.usize("dominant cost")?,
        neuron_synapse_model: d.usize("dominant cost")?,
        output_recording: d.usize("dominant cost")?,
        stack_heap: d.usize("dominant cost")?,
        hw_mgmt_os: d.usize("dominant cost")?,
    })
}

fn put_parallel(e: &mut Enc, c: &ParallelCompiled) {
    put_wdm(e, &c.wdm);
    put_tables(e, &c.tables);
    put_dominant_cost(e, &c.dominant_cost);
    e.usize(c.subordinates.len());
    for sub in &c.subordinates {
        e.usize(sub.row_lo);
        e.usize(sub.row_hi);
        e.usize(sub.col_lo);
        e.usize(sub.col_hi);
        e.bulk_i16(&sub.weights);
        e.usize(sub.dtcm_bytes);
    }
    e.usize(c.plan.row_parts);
    e.usize(c.plan.col_parts);
    e.usize(c.plan.chunks.len());
    for ch in &c.plan.chunks {
        e.usize(ch.row_lo);
        e.usize(ch.row_hi);
        e.usize(ch.col_lo);
        e.usize(ch.col_hi);
        e.usize(ch.dtcm_bytes);
    }
    put_character(e, &c.character);
    put_params(e, &c.params);
    e.f32(c.weight_scale);
    e.usize(c.n_source);
    e.usize(c.n_target);
    e.usize(c.n_source_vertex);
}

fn get_parallel(d: &mut Dec) -> Result<ParallelCompiled, ArtifactError> {
    let wdm = get_wdm(d)?;
    let tables = get_tables(d)?;
    let dominant_cost = get_dominant_cost(d)?;
    let n_subs = d.count(1, "subordinate count")?;
    let mut subordinates = Vec::with_capacity(n_subs);
    for _ in 0..n_subs {
        subordinates.push(SubordinateProgram {
            row_lo: d.usize("sub row_lo")?,
            row_hi: d.usize("sub row_hi")?,
            col_lo: d.usize("sub col_lo")?,
            col_hi: d.usize("sub col_hi")?,
            weights: d.bulk_i16("sub weights")?,
            dtcm_bytes: d.usize("sub dtcm_bytes")?,
        });
    }
    let row_parts = d.usize("plan row_parts")?;
    let col_parts = d.usize("plan col_parts")?;
    let n_chunks = d.count(40, "plan chunks")?;
    let mut chunks = Vec::with_capacity(n_chunks);
    for _ in 0..n_chunks {
        chunks.push(Chunk {
            row_lo: d.usize("chunk row_lo")?,
            row_hi: d.usize("chunk row_hi")?,
            col_lo: d.usize("chunk col_lo")?,
            col_hi: d.usize("chunk col_hi")?,
            dtcm_bytes: d.usize("chunk dtcm_bytes")?,
        });
    }
    Ok(ParallelCompiled {
        wdm,
        tables,
        dominant_cost,
        subordinates,
        plan: SplitPlan { row_parts, col_parts, chunks },
        character: get_character(d)?,
        params: get_params(d)?,
        weight_scale: d.f32("parallel weight_scale")?,
        n_source: d.usize("parallel n_source")?,
        n_target: d.usize("parallel n_target")?,
        n_source_vertex: d.usize("parallel n_source_vertex")?,
    })
}

// -------------------------------------------------------- public bodies

/// Encode one compiled layer into a `SEC_LAYER` section body.
pub fn encode_layer(layer: &CompiledLayer) -> Vec<u8> {
    let mut e = Enc::default();
    match layer {
        CompiledLayer::Serial(c) => {
            put_paradigm(&mut e, Paradigm::Serial);
            put_serial(&mut e, c);
        }
        CompiledLayer::Parallel(c) => {
            put_paradigm(&mut e, Paradigm::Parallel);
            put_parallel(&mut e, c);
        }
    }
    e.buf
}

/// Decode a `SEC_LAYER` section body.
pub fn decode_layer(body: &[u8]) -> Result<CompiledLayer, ArtifactError> {
    let mut d = Dec::new(body);
    let layer = match get_paradigm(&mut d)? {
        Paradigm::Serial => CompiledLayer::Serial(get_serial(&mut d)?),
        Paradigm::Parallel => CompiledLayer::Parallel(get_parallel(&mut d)?),
    };
    if !d.done() {
        return Err(ArtifactError::Malformed {
            what: "layer body",
            detail: "trailing bytes after the decoded layer".into(),
        });
    }
    Ok(layer)
}

/// Encode a cost estimate into a `SEC_ESTIMATE` section body.
pub fn encode_estimate(est: &CostEstimate) -> Vec<u8> {
    let mut e = Enc::default();
    put_paradigm(&mut e, est.paradigm);
    e.usize(est.layer_pes);
    e.usize(est.source_hosting_pes);
    e.usize(est.dtcm_bytes);
    e.usize(est.source_hosting_dtcm);
    e.buf
}

/// Decode a `SEC_ESTIMATE` section body.
pub fn decode_estimate(body: &[u8]) -> Result<CostEstimate, ArtifactError> {
    let mut d = Dec::new(body);
    let est = CostEstimate {
        paradigm: get_paradigm(&mut d)?,
        layer_pes: d.usize("estimate layer_pes")?,
        source_hosting_pes: d.usize("estimate source_hosting_pes")?,
        dtcm_bytes: d.usize("estimate dtcm_bytes")?,
        source_hosting_dtcm: d.usize("estimate source_hosting_dtcm")?,
    };
    if !d.done() {
        return Err(ArtifactError::Malformed {
            what: "estimate body",
            detail: "trailing bytes after the decoded estimate".into(),
        });
    }
    Ok(est)
}

/// One layer's saved paradigm decision inside a network artifact.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SavedDecision {
    /// What the policy prejudged (`None` = Ideal mode, decided by cost).
    pub prejudged: Option<Paradigm>,
    /// The paradigm the layer was actually compiled under.
    pub chosen: Paradigm,
    /// True when capacity feasibility overrode the prejudged winner.
    pub overridden: bool,
}

/// Encode per-layer decisions into a `SEC_DECISIONS` section body.
pub fn encode_decisions(decisions: &[SavedDecision]) -> Vec<u8> {
    let mut e = Enc::default();
    e.usize(decisions.len());
    for d in decisions {
        e.u8(match d.prejudged {
            None => 0,
            Some(Paradigm::Serial) => 1,
            Some(Paradigm::Parallel) => 2,
        });
        put_paradigm(&mut e, d.chosen);
        e.u8(d.overridden as u8);
    }
    e.buf
}

/// Decode a `SEC_DECISIONS` section body.
pub fn decode_decisions(body: &[u8]) -> Result<Vec<SavedDecision>, ArtifactError> {
    let mut d = Dec::new(body);
    let n = d.count(3, "decision count")?;
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        let prejudged = match d.u8("decision prejudged")? {
            0 => None,
            1 => Some(Paradigm::Serial),
            2 => Some(Paradigm::Parallel),
            t => {
                return Err(ArtifactError::Malformed {
                    what: "decision prejudged",
                    detail: format!("unknown value {t}"),
                })
            }
        };
        out.push(SavedDecision {
            prejudged,
            chosen: get_paradigm(&mut d)?,
            overridden: d.u8("decision overridden")? != 0,
        });
    }
    if !d.done() {
        return Err(ArtifactError::Malformed {
            what: "decisions body",
            detail: "trailing bytes after the decoded decisions".into(),
        });
    }
    Ok(out)
}
