//! Persistent compiled-artifact store: compile once, serve many.
//!
//! The paper's fast-switching saving — prejudge the paradigm, compile only
//! the winner — used to evaporate at every process restart because the
//! [`crate::switching::CompilePipeline`] dedup cache was memory-only. This
//! subsystem makes the saving durable: every materialized
//! [`CompiledLayer`] (and shape-only [`CostEstimate`]) can be written to a
//! **content-addressed store** keyed by the pipeline's cache-key hash, and
//! a later process boots the same network straight from disk — zero
//! materializing compiles (`simulate --artifact-dir` on a warm store).
//!
//! * [`codec`] — the versioned little-endian wire format (hand-rolled; no
//!   new dependencies), with a magic/version/length-checked header and a
//!   per-section FNV-1a checksum so truncated, corrupt, or
//!   foreign-version files are rejected with a typed [`ArtifactError`]
//!   instead of a panic or a misparse.
//! * [`ArtifactStore`] — the on-disk store: one `<key>.s2a` file per
//!   artifact, written atomically (temp file + rename) so concurrent
//!   writers and crashed processes can never publish a torn file.
//!
//! Invalidation is structural: the store key is a hash over everything
//! that determines a compile's output (layer character, connector
//! seed/fingerprint, LIF params, `PeSpec`, `WdmConfig`, paradigm), so a
//! changed config simply misses and compiles fresh, and a format change
//! bumps [`codec::VERSION`], demoting every older file to a miss.
//!
//! Because `paradigm` is part of the key, an ideal-mode compile persists
//! **both** compiled forms of every layer. That inventory is what makes
//! runtime re-switching free: when [`crate::switching::adaptive`] (or a
//! fault migration) asks for the paradigm a layer is *not* currently
//! running, the fetch is a disk hit, never a recompile — live hot-swaps
//! on a warm store report `total_compiles() == 0`.

pub mod codec;

pub use codec::{SavedDecision, MAGIC, VERSION};

use crate::paradigm::{CompiledLayer, CostEstimate};
use std::fmt;
use std::io::Write;
use std::path::{Path, PathBuf};

/// Typed artifact failure. Every decode path returns one of these —
/// corrupt bytes are never allowed to panic the pipeline; the caller
/// treats any error as a cache miss and recompiles.
#[derive(Debug)]
pub enum ArtifactError {
    /// Filesystem failure reading or writing the store.
    Io(std::io::Error),
    /// The file does not start with the `S2AF` magic.
    BadMagic { found: u32 },
    /// The file was written by a different codec version.
    BadVersion { found: u32, supported: u32 },
    /// A declared length runs past the available bytes.
    Truncated { what: &'static str, need: u64, have: u64 },
    /// A section body does not match its stored checksum.
    ChecksumMismatch { section: u32, stored: u64, computed: u64 },
    /// Structurally invalid content (bad enum tag, trailing bytes, …).
    Malformed { what: &'static str, detail: String },
}

impl fmt::Display for ArtifactError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ArtifactError::Io(e) => write!(f, "artifact io error: {e}"),
            ArtifactError::BadMagic { found } => {
                write!(f, "bad artifact magic {found:#010x} (want {:#010x})", MAGIC)
            }
            ArtifactError::BadVersion { found, supported } => {
                write!(f, "artifact version {found} unsupported (this build reads {supported})")
            }
            ArtifactError::Truncated { what, need, have } => {
                write!(f, "artifact truncated at {what}: need {need} bytes, have {have}")
            }
            ArtifactError::ChecksumMismatch { section, stored, computed } => write!(
                f,
                "artifact section {section} checksum mismatch \
                 (stored {stored:#018x}, computed {computed:#018x})"
            ),
            ArtifactError::Malformed { what, detail } => {
                write!(f, "malformed artifact {what}: {detail}")
            }
        }
    }
}

impl std::error::Error for ArtifactError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            ArtifactError::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for ArtifactError {
    fn from(e: std::io::Error) -> Self {
        ArtifactError::Io(e)
    }
}

/// Publish attempts after the first, for transient I/O failures only.
const PUBLISH_RETRIES: u32 = 3;

/// First retry backoff; doubles per attempt (5 → 10 → 20 ms).
const PUBLISH_BACKOFF_MS: u64 = 5;

/// I/O failures worth retrying: the operation may succeed unchanged a
/// moment later. Everything else (permissions, missing directory, full
/// disk) surfaces immediately.
fn is_transient(e: &std::io::Error) -> bool {
    matches!(
        e.kind(),
        std::io::ErrorKind::Interrupted
            | std::io::ErrorKind::WouldBlock
            | std::io::ErrorKind::TimedOut
    )
}

/// A whole compiled network as one artifact: per-layer paradigm decisions,
/// the materialized layers (projection order), and their cost estimates.
#[derive(Clone, Debug, PartialEq)]
pub struct NetworkArtifact {
    pub decisions: Vec<SavedDecision>,
    pub layers: Vec<CompiledLayer>,
    pub estimates: Vec<CostEstimate>,
}

/// The content-addressed on-disk store. One artifact per file,
/// `<key as 16 hex digits>.s2a`, plus named whole-network artifacts
/// (`<name>.net.s2a`).
#[derive(Debug)]
pub struct ArtifactStore {
    dir: PathBuf,
}

impl ArtifactStore {
    /// Open (creating if needed) a store rooted at `dir`.
    pub fn open(dir: impl Into<PathBuf>) -> Result<ArtifactStore, ArtifactError> {
        let dir = dir.into();
        std::fs::create_dir_all(&dir)?;
        Ok(ArtifactStore { dir })
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    fn key_path(&self, key: u64) -> PathBuf {
        self.dir.join(format!("{key:016x}.s2a"))
    }

    fn net_path(&self, name: &str) -> PathBuf {
        self.dir.join(format!("{name}.net.s2a"))
    }

    /// Number of artifacts currently on disk (bench/telemetry helper).
    pub fn len(&self) -> usize {
        std::fs::read_dir(&self.dir)
            .map(|rd| {
                rd.filter(|e| {
                    e.as_ref()
                        .ok()
                        .and_then(|e| e.path().extension().map(|x| x == "s2a"))
                        .unwrap_or(false)
                })
                .count()
            })
            .unwrap_or(0)
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Atomically publish `bytes` at `path`, retrying transient I/O
    /// failures a bounded number of times with doubling backoff (a busy
    /// NFS mount or an EINTR must not cost a recompile on the next boot).
    /// Non-transient errors surface immediately.
    fn publish(&self, path: &Path, bytes: &[u8]) -> Result<(), ArtifactError> {
        let mut delay = std::time::Duration::from_millis(PUBLISH_BACKOFF_MS);
        let mut attempt = 0;
        loop {
            match self.publish_once(path, bytes) {
                Ok(()) => return Ok(()),
                Err(ArtifactError::Io(e)) if attempt < PUBLISH_RETRIES && is_transient(&e) => {
                    attempt += 1;
                    eprintln!(
                        "artifact store: transient error publishing {} ({e}); \
                         retry {attempt}/{PUBLISH_RETRIES} in {delay:?}",
                        path.display()
                    );
                    std::thread::sleep(delay);
                    delay *= 2;
                }
                Err(e) => return Err(e),
            }
        }
    }

    /// One publish attempt: write a sibling temp file, then rename over
    /// the target (rename is atomic on POSIX, so readers see either the
    /// old complete file or the new complete file — never a torn write).
    /// The temp name is unique per process *and* per call so concurrent
    /// writers of the same key cannot interleave.
    fn publish_once(&self, path: &Path, bytes: &[u8]) -> Result<(), ArtifactError> {
        use std::sync::atomic::{AtomicU64, Ordering};
        static SEQ: AtomicU64 = AtomicU64::new(0);
        let tmp = path.with_extension(format!(
            "tmp.{}.{}",
            std::process::id(),
            SEQ.fetch_add(1, Ordering::Relaxed)
        ));
        {
            let mut f = std::fs::File::create(&tmp)?;
            f.write_all(bytes)?;
        }
        match std::fs::rename(&tmp, path) {
            Ok(()) => Ok(()),
            Err(e) => {
                std::fs::remove_file(&tmp).ok();
                Err(e.into())
            }
        }
    }

    /// Move a corrupt artifact aside as `<name>.s2a.bad` (atomic rename)
    /// with the decode failure logged, so it stops resurfacing as an error
    /// on every lookup and the next compile can republish the key cleanly.
    /// Best-effort: a failed rename leaves the file in place.
    fn quarantine(&self, path: &Path, why: &ArtifactError) {
        let bad = path.with_extension("s2a.bad");
        match std::fs::rename(path, &bad) {
            Ok(()) => eprintln!(
                "artifact store: quarantined corrupt {} → {} ({why})",
                path.display(),
                bad.display()
            ),
            Err(e) => eprintln!(
                "artifact store: {} is corrupt ({why}) but could not be quarantined: {e}",
                path.display()
            ),
        }
    }

    fn read(&self, path: &Path) -> Result<Option<Vec<u8>>, ArtifactError> {
        match std::fs::read(path) {
            Ok(bytes) => Ok(Some(bytes)),
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => Ok(None),
            Err(e) => Err(e.into()),
        }
    }

    /// Persist one compiled layer under its cache-key hash.
    pub fn save_layer(&self, key: u64, layer: &CompiledLayer) -> Result<(), ArtifactError> {
        let body = codec::encode_layer(layer);
        let bytes = codec::write_container(&[(codec::SEC_LAYER, body)]);
        self.publish(&self.key_path(key), &bytes)
    }

    /// Load a compiled layer. `Ok(None)` = not in the store; `Err` = the
    /// file exists but is truncated/corrupt/foreign (callers treat both as
    /// a miss, the latter is additionally worth surfacing in telemetry).
    /// A corrupt file is quarantined to `<key>.s2a.bad` on the way out, so
    /// the next lookup is a clean miss and the next compile re-publishes.
    pub fn load_layer(&self, key: u64) -> Result<Option<CompiledLayer>, ArtifactError> {
        let path = self.key_path(key);
        let Some(bytes) = self.read(&path)? else {
            return Ok(None);
        };
        self.decode_layer_bytes(&bytes).map(Some).map_err(|e| {
            self.quarantine(&path, &e);
            e
        })
    }

    fn decode_layer_bytes(&self, bytes: &[u8]) -> Result<CompiledLayer, ArtifactError> {
        let sections = codec::read_container(bytes)?;
        match sections.as_slice() {
            [(codec::SEC_LAYER, body)] => codec::decode_layer(body),
            _ => Err(ArtifactError::Malformed {
                what: "layer artifact",
                detail: format!("expected one LAYER section, found {}", sections.len()),
            }),
        }
    }

    /// Persist one shape-only cost estimate under its cache-key hash.
    pub fn save_estimate(&self, key: u64, est: &CostEstimate) -> Result<(), ArtifactError> {
        let body = codec::encode_estimate(est);
        let bytes = codec::write_container(&[(codec::SEC_ESTIMATE, body)]);
        self.publish(&self.key_path(key), &bytes)
    }

    /// Load a cost estimate (same miss/corrupt/quarantine contract as
    /// [`ArtifactStore::load_layer`]).
    pub fn load_estimate(&self, key: u64) -> Result<Option<CostEstimate>, ArtifactError> {
        let path = self.key_path(key);
        let Some(bytes) = self.read(&path)? else {
            return Ok(None);
        };
        self.decode_estimate_bytes(&bytes).map(Some).map_err(|e| {
            self.quarantine(&path, &e);
            e
        })
    }

    fn decode_estimate_bytes(&self, bytes: &[u8]) -> Result<CostEstimate, ArtifactError> {
        let sections = codec::read_container(bytes)?;
        match sections.as_slice() {
            [(codec::SEC_ESTIMATE, body)] => codec::decode_estimate(body),
            _ => Err(ArtifactError::Malformed {
                what: "estimate artifact",
                detail: format!("expected one ESTIMATE section, found {}", sections.len()),
            }),
        }
    }

    /// Persist a whole compiled network (decisions + layers + estimates)
    /// under a caller-chosen name.
    pub fn save_network(&self, name: &str, net: &NetworkArtifact) -> Result<(), ArtifactError> {
        let mut sections = Vec::with_capacity(1 + 2 * net.layers.len());
        sections.push((codec::SEC_DECISIONS, codec::encode_decisions(&net.decisions)));
        for layer in &net.layers {
            sections.push((codec::SEC_LAYER, codec::encode_layer(layer)));
        }
        for est in &net.estimates {
            sections.push((codec::SEC_ESTIMATE, codec::encode_estimate(est)));
        }
        self.publish(&self.net_path(name), &codec::write_container(&sections))
    }

    /// Load a whole-network artifact saved by
    /// [`ArtifactStore::save_network`] (corrupt files are quarantined like
    /// [`ArtifactStore::load_layer`]'s).
    pub fn load_network(&self, name: &str) -> Result<Option<NetworkArtifact>, ArtifactError> {
        let path = self.net_path(name);
        let Some(bytes) = self.read(&path)? else {
            return Ok(None);
        };
        self.decode_network_bytes(&bytes).map(Some).map_err(|e| {
            self.quarantine(&path, &e);
            e
        })
    }

    fn decode_network_bytes(&self, bytes: &[u8]) -> Result<NetworkArtifact, ArtifactError> {
        let sections = codec::read_container(bytes)?;
        let mut decisions = None;
        let mut layers = Vec::new();
        let mut estimates = Vec::new();
        for (tag, body) in sections {
            match tag {
                codec::SEC_DECISIONS => decisions = Some(codec::decode_decisions(body)?),
                codec::SEC_LAYER => layers.push(codec::decode_layer(body)?),
                codec::SEC_ESTIMATE => estimates.push(codec::decode_estimate(body)?),
                other => {
                    return Err(ArtifactError::Malformed {
                        what: "network artifact",
                        detail: format!("unknown section tag {other}"),
                    })
                }
            }
        }
        let decisions = decisions.ok_or_else(|| ArtifactError::Malformed {
            what: "network artifact",
            detail: "missing DECISIONS section".into(),
        })?;
        if decisions.len() != layers.len() || layers.len() != estimates.len() {
            return Err(ArtifactError::Malformed {
                what: "network artifact",
                detail: format!(
                    "section counts disagree: {} decisions, {} layers, {} estimates",
                    decisions.len(),
                    layers.len(),
                    estimates.len()
                ),
            });
        }
        Ok(NetworkArtifact { decisions, layers, estimates })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::realize_layer;
    use crate::hardware::PeSpec;
    use crate::model::LifParams;
    use crate::paradigm::parallel::WdmConfig;
    use crate::paradigm::{
        LayerJob, ParadigmCompiler, Paradigm, ParallelCompiler, SerialCompiler,
    };
    use crate::prop::Prop;
    use crate::rng::Rng;

    fn compile_pair(
        n_src: usize,
        n_tgt: usize,
        density: f64,
        delay: u16,
        seed: u64,
    ) -> (CompiledLayer, CompiledLayer, CostEstimate, CostEstimate) {
        let pe = PeSpec::default();
        let mut rng = Rng::new(seed);
        let proj = realize_layer(n_src, n_tgt, density, delay, &mut rng);
        let job = LayerJob::new(&proj, n_src, n_tgt, LifParams::default());
        let s = SerialCompiler.compile(&job, &pe).unwrap();
        let p = ParallelCompiler::new(WdmConfig::default()).compile(&job, &pe).unwrap();
        let se = SerialCompiler.estimate(&job, &pe).unwrap();
        let pe_est = ParallelCompiler::new(WdmConfig::default()).estimate(&job, &pe).unwrap();
        (s, p, se, pe_est)
    }

    fn tmp_store(tag: &str) -> ArtifactStore {
        let dir = std::env::temp_dir().join(format!("s2a-test-{tag}-{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        ArtifactStore::open(dir).unwrap()
    }

    #[test]
    fn layer_roundtrip_is_lossless_for_randomized_layers() {
        // The headline property: encode→decode is the identity on compiled
        // layers of either paradigm, across the sweep envelope.
        Prop::new("artifact layer roundtrip", 25).check(
            |g| {
                (
                    g.usize(20, 300),
                    g.usize(20, 300),
                    g.f64(0.05, 1.0),
                    g.usize(1, 16) as u16,
                    g.i64(0, 1 << 30) as u64,
                )
            },
            |&(ns, nt, d, dl, seed)| {
                let (s, p, _, _) = compile_pair(ns, nt, d, dl, seed);
                let s_back = codec::decode_layer(&codec::encode_layer(&s)).unwrap();
                let p_back = codec::decode_layer(&codec::encode_layer(&p)).unwrap();
                s_back == s && p_back == p
            },
        );
    }

    #[test]
    fn estimate_roundtrip_is_lossless() {
        Prop::new("artifact estimate roundtrip", 25).check(
            |g| {
                (
                    g.usize(20, 300),
                    g.usize(20, 300),
                    g.f64(0.05, 1.0),
                    g.usize(1, 16) as u16,
                    g.i64(0, 1 << 30) as u64,
                )
            },
            |&(ns, nt, d, dl, seed)| {
                let (_, _, se, pe_est) = compile_pair(ns, nt, d, dl, seed);
                codec::decode_estimate(&codec::encode_estimate(&se)).unwrap() == se
                    && codec::decode_estimate(&codec::encode_estimate(&pe_est)).unwrap()
                        == pe_est
            },
        );
    }

    #[test]
    fn store_roundtrips_layers_and_estimates_through_disk() {
        let store = tmp_store("rt");
        let (s, p, se, pe_est) = compile_pair(120, 80, 0.4, 6, 42);
        store.save_layer(1, &s).unwrap();
        store.save_layer(2, &p).unwrap();
        store.save_estimate(3, &se).unwrap();
        store.save_estimate(4, &pe_est).unwrap();
        assert_eq!(store.load_layer(1).unwrap().unwrap(), s);
        assert_eq!(store.load_layer(2).unwrap().unwrap(), p);
        assert_eq!(store.load_estimate(3).unwrap().unwrap(), se);
        assert_eq!(store.load_estimate(4).unwrap().unwrap(), pe_est);
        assert_eq!(store.len(), 4);
        assert!(store.load_layer(99).unwrap().is_none(), "missing key is a clean miss");
        std::fs::remove_dir_all(store.dir()).ok();
    }

    #[test]
    fn network_artifact_roundtrips() {
        let store = tmp_store("net");
        let (s, p, se, pe_est) = compile_pair(100, 100, 0.5, 4, 7);
        let art = NetworkArtifact {
            decisions: vec![
                SavedDecision {
                    prejudged: Some(Paradigm::Serial),
                    chosen: Paradigm::Serial,
                    overridden: false,
                },
                SavedDecision { prejudged: None, chosen: Paradigm::Parallel, overridden: true },
            ],
            layers: vec![s, p],
            estimates: vec![se, pe_est],
        };
        store.save_network("demo", &art).unwrap();
        assert_eq!(store.load_network("demo").unwrap().unwrap(), art);
        assert!(store.load_network("absent").unwrap().is_none());
        std::fs::remove_dir_all(store.dir()).ok();
    }

    /// A valid single-layer artifact byte stream to corrupt in the
    /// negative tests.
    fn valid_bytes() -> Vec<u8> {
        let (s, _, _, _) = compile_pair(60, 60, 0.3, 3, 9);
        codec::write_container(&[(codec::SEC_LAYER, codec::encode_layer(&s))])
    }

    fn decode_all(bytes: &[u8]) -> Result<CompiledLayer, ArtifactError> {
        let sections = codec::read_container(bytes)?;
        codec::decode_layer(sections[0].1)
    }

    #[test]
    fn truncation_is_a_typed_error_at_every_length() {
        let bytes = valid_bytes();
        // Every proper prefix must fail with a typed error — never panic,
        // never succeed.
        for cut in [0, 1, 3, 4, 8, 23, 24, 25, bytes.len() / 2, bytes.len() - 1] {
            let err = decode_all(&bytes[..cut]).unwrap_err();
            assert!(
                matches!(err, ArtifactError::Truncated { .. }),
                "cut at {cut}: expected Truncated, got {err}"
            );
        }
    }

    #[test]
    fn bad_magic_is_rejected() {
        let mut bytes = valid_bytes();
        bytes[0] ^= 0xff;
        match decode_all(&bytes).unwrap_err() {
            ArtifactError::BadMagic { found } => assert_ne!(found, MAGIC),
            other => panic!("expected BadMagic, got {other}"),
        }
    }

    #[test]
    fn version_mismatch_is_rejected() {
        let mut bytes = valid_bytes();
        bytes[4] = bytes[4].wrapping_add(1); // version field
        match decode_all(&bytes).unwrap_err() {
            ArtifactError::BadVersion { found, supported } => {
                assert_ne!(found, supported);
                assert_eq!(supported, VERSION);
            }
            other => panic!("expected BadVersion, got {other}"),
        }
    }

    #[test]
    fn checksum_corruption_is_rejected() {
        let mut bytes = valid_bytes();
        // Flip one byte in the section body (past the 24 B container
        // header and the 20 B section header).
        let idx = bytes.len() - 5;
        bytes[idx] ^= 0x40;
        match decode_all(&bytes).unwrap_err() {
            ArtifactError::ChecksumMismatch { section, stored, computed } => {
                assert_eq!(section, codec::SEC_LAYER);
                assert_ne!(stored, computed);
            }
            other => panic!("expected ChecksumMismatch, got {other}"),
        }
    }

    #[test]
    fn corrupt_store_file_surfaces_as_error_not_panic() {
        let store = tmp_store("corrupt");
        let (s, _, _, _) = compile_pair(50, 50, 0.5, 2, 11);
        store.save_layer(7, &s).unwrap();
        // Truncate the published file in place.
        let path = store.dir().join(format!("{:016x}.s2a", 7u64));
        let full = std::fs::read(&path).unwrap();
        std::fs::write(&path, &full[..full.len() / 2]).unwrap();
        assert!(matches!(
            store.load_layer(7).unwrap_err(),
            ArtifactError::Truncated { .. }
        ));
        // Garbage bytes are a BadMagic, not a panic.
        std::fs::write(&path, b"not an artifact at all").unwrap();
        assert!(matches!(store.load_layer(7).unwrap_err(), ArtifactError::BadMagic { .. }));
        std::fs::remove_dir_all(store.dir()).ok();
    }

    #[test]
    fn corrupt_artifacts_are_quarantined_and_the_key_self_heals() {
        let store = tmp_store("quarantine");
        let (s, _, _, _) = compile_pair(40, 40, 0.4, 2, 13);
        store.save_layer(9, &s).unwrap();
        let path = store.dir().join(format!("{:016x}.s2a", 9u64));
        std::fs::write(&path, b"garbage").unwrap();
        // First lookup surfaces the corruption and moves the file aside.
        assert!(store.load_layer(9).is_err());
        assert!(!path.exists(), "corrupt file must be renamed away");
        let bad = path.with_extension("s2a.bad");
        assert!(bad.exists(), "quarantined copy must exist for post-mortem");
        // Second lookup is a clean miss — the error does not resurface.
        assert!(store.load_layer(9).unwrap().is_none());
        // Republishing the key heals it.
        store.save_layer(9, &s).unwrap();
        assert_eq!(store.load_layer(9).unwrap().unwrap(), s);
        std::fs::remove_dir_all(store.dir()).ok();
    }

    #[test]
    fn error_display_names_the_failure() {
        let e = ArtifactError::BadVersion { found: 9, supported: VERSION };
        assert!(e.to_string().contains("version 9"));
        let e = ArtifactError::Truncated { what: "wdm rows", need: 100, have: 10 };
        assert!(e.to_string().contains("wdm rows"));
    }
}
