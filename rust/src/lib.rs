#![cfg_attr(feature = "simd", feature(portable_simd))]
//! # s2switch — Fast-Switching Serial/Parallel SNN Compilation for SpiNNaker2
//!
//! Reproduction of *"Fast Switching Serial and Parallel Paradigms of SNN
//! Inference on Multi-core Heterogeneous Neuromorphic Platform SpiNNaker2"*
//! (Huang et al., 2024).
//!
//! The library implements the full stack the paper depends on:
//!
//! * [`model`] — SNN model representation (populations, projections, LIF).
//! * [`graph`] — application graph → machine graph mapping and routing.
//! * [`hardware`] — the SpiNNaker2 machine model (PEs, SRAM/DTCM, MAC array,
//!   NoC).
//! * [`costmodel`] — the paper's Table I DTCM cost models.
//! * [`paradigm`] — the serial (ARM, event-driven) and parallel (MAC-array)
//!   compilation paradigms, unified behind the object-safe
//!   [`paradigm::ParadigmCompiler`] trait (shape-only estimate tier + full
//!   materialization tier; DESIGN.md §1).
//! * [`classifier`] — twelve from-scratch classifiers used to *prejudge* the
//!   cheaper paradigm per layer.
//! * [`dataset`] — the 16,000-random-layer dataset acquisition pipeline.
//! * [`switching`] — the paper's contribution: the classifier-integrated
//!   fast-switching compilation system, split into the pure
//!   [`switching::SwitchPolicy`] decision and the threaded, cache-aware
//!   [`switching::CompilePipeline`] execution engine.
//! * [`sim`] — a functional SpiNNaker2 simulator executing compiled layers
//!   under either paradigm with zero steady-state allocations,
//!   sparsity-gated readout, a vectorizable chunked LIF kernel and
//!   intra-sample wave parallelism ([`sim::NetworkSim::run_jobs`]), plus
//!   [`sim::BatchRunner`] for multi-sample batched inference (the parallel
//!   path can run AOT-compiled JAX/Pallas HLO through PJRT via [`runtime`],
//!   behind the `pjrt` cargo feature).
//! * [`artifact`] — the persistent compiled-artifact store: a versioned,
//!   checksummed binary codec plus a content-addressed on-disk store that
//!   turns the compile cache into a second, restart-surviving tier
//!   (compile once, serve many; `--artifact-dir`).
//! * [`serve`] — the long-lived inference daemon: warm-boots every tenant
//!   network from the artifact store (zero materializing compiles), admits
//!   them as co-tenants on one shared machine, and serves spike-count
//!   inference over a length-prefixed checksummed socket protocol with
//!   dynamic micro-batching onto persistent [`sim::SimPool`] engines
//!   (`s2switch serve`).
//! * [`calibrate`] — host calibration: micro-benchmarks measuring the real
//!   serial events/s and parallel MACs/s (per kernel variant — scalar or
//!   `std::simd` behind the `simd` feature), persisted as JSON next to the
//!   artifact store and threaded into [`costmodel::activity`]'s
//!   runtime-preference decision (`s2switch calibrate`).
//! * [`coordinator`] — the leader pipeline tying everything together.
//!
//! Offline-environment substitutes (see DESIGN.md §2): [`bench_harness`]
//! replaces criterion, [`prop`] replaces proptest, [`io`] replaces serde.

pub mod artifact;
pub mod bench_harness;
pub mod calibrate;
pub mod classifier;
pub mod coordinator;
pub mod costmodel;
pub mod criteria;
pub mod dataset;
pub mod graph;
pub mod hardware;
pub mod io;
pub mod model;
pub mod paradigm;
pub mod prop;
pub mod rng;
pub mod runtime;
pub mod serve;
pub mod sim;
pub mod switching;

/// Crate-wide result alias.
pub type Result<T> = anyhow::Result<T>;
