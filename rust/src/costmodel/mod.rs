//! DTCM cost models — the paper's Table I.
//!
//! Every data structure either paradigm loads into a PE's DTCM has a byte
//! cost. The serial paradigm's cost is fully closed-form; the parallel
//! paradigm's dominant-PE cost is closed-form while the subordinate-PE cost
//! depends on the *realized* optimized weight-delay-map ("can't be
//! accurately estimated" — Table I), which is why the paper (and we) obtain
//! subordinate PE counts by actually running the parallel compiler.
//!
//! Transcription decisions for Table I's two garbled rows are documented in
//! DESIGN.md §6.
//!
//! [`activity`] complements the storage model with a per-timestep *work*
//! model driven by the observed firing rate — the runtime half of the
//! serial-vs-parallel comparison (DESIGN.md §Runtime-Perf).

pub mod activity;
pub mod parallel;
pub mod serial;

pub use activity::{
    parallel_mac_issues_per_step, runtime_preferred, runtime_preferred_calibrated,
    runtime_preferred_with_margin, serial_events_per_step, CalibrationConstants,
    DEFAULT_HYSTERESIS_MARGIN,
};
pub use parallel::{DominantCost, SubordinateFixedCost};
pub use serial::{SerialCost, SerialLayout};

/// Bytes per 32-bit word (Table I writes costs as `(bits/8) * count`).
pub const WORD32: usize = 4;
/// Bytes per 16-bit half-word.
pub const WORD16: usize = 2;
/// Bytes per master-population-table entry (Table I: 96/8).
pub const MPT_ENTRY: usize = 12;
/// Projection types: excitatory + inhibitory (Table I `n_projection_type`).
pub const N_PROJECTION_TYPE: usize = 2;
/// LIF parameter count: 8 neuron + 6 synapse parameters (Table I).
pub const N_LIF_PARAMS: usize = 8 + 6;
