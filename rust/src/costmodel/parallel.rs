//! Parallel-paradigm DTCM cost model (Table I, lower blocks).
//!
//! The dominant PE's structures are closed-form; the subordinate PEs' main
//! structure — the optimized weight-delay-map — "can't be accurately
//! estimated" (Table I) and is sized by actually building it in
//! [`crate::paradigm::parallel`]. This module provides the closed-form rows
//! plus the fixed per-subordinate overhead the splitting algorithm budgets
//! around.

use super::{MPT_ENTRY, N_LIF_PARAMS, N_PROJECTION_TYPE, WORD16, WORD32};

/// Itemized dominant-PE cost (bytes), mirroring Table I's
/// "parallel paradigm (dominant)" block.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct DominantCost {
    pub input_spike_buffer: usize,
    pub reversed_order: usize,
    pub input_merging_table: usize,
    pub stacked_input: usize,
    pub neuron_synapse_model: usize,
    pub output_recording: usize,
    pub stack_heap: usize,
    pub hw_mgmt_os: usize,
}

impl DominantCost {
    pub fn total(&self) -> usize {
        self.input_spike_buffer
            + self.reversed_order
            + self.input_merging_table
            + self.stacked_input
            + self.neuron_synapse_model
            + self.output_recording
            + self.stack_heap
            + self.hw_mgmt_os
    }

    /// (name, bytes) pairs in Table I order, for the T1 bench.
    pub fn items(&self) -> [(&'static str, usize); 8] {
        [
            ("input spike buffer", self.input_spike_buffer),
            ("reversed order", self.reversed_order),
            ("input merging table", self.input_merging_table),
            ("stacked input", self.stacked_input),
            ("neuron and synapse model", self.neuron_synapse_model),
            ("output recording", self.output_recording),
            ("stack & heap", self.stack_heap),
            ("hw mgmt & OS", self.hw_mgmt_os),
        ]
    }
}

/// Table I dominant-PE cost.
///
/// * `n_source_neuron` — source neurons feeding the layer;
/// * `n_target_neuron` — target neurons of the layer (the dominant PE runs
///   the neural update over the subordinate PEs' accumulated currents and
///   records outputs — DESIGN.md §6);
/// * `delay_range` — delay slots in the stacked input;
/// * `n_source_vertex` — machine-graph in-edges (stack/heap bookkeeping).
pub fn dominant_cost(
    n_source_neuron: usize,
    n_target_neuron: usize,
    delay_range: usize,
    n_source_vertex: usize,
) -> DominantCost {
    DominantCost {
        // (32/8)*n_source_neuron.
        input_spike_buffer: WORD32 * n_source_neuron,
        // (32/16)*n_source_neuron*delay_range — 16-bit reverse-permutation
        // indices mapping arrival order to weight-delay-map row order.
        reversed_order: WORD16 * n_source_neuron * delay_range,
        // n_source_neuron*delay_range*3 — 3 B/entry (row id + slot tag).
        input_merging_table: 3 * n_source_neuron * delay_range,
        // n_source_neuron*delay_range*4 — the stacked spike train the MAC
        // array consumes, one word per (source, delay) lane.
        stacked_input: 4 * n_source_neuron * delay_range,
        // DESIGN.md §6: Table I's row is garbled; the dominant PE holds the
        // LIF parameter block plus per-target membrane state.
        neuron_synapse_model: WORD32 * N_LIF_PARAMS + WORD32 * n_target_neuron,
        // (32/8)*n_target_neuron*4.
        output_recording: WORD32 * n_target_neuron * 4,
        // (96/8)*n_source_vertex.
        stack_heap: MPT_ENTRY * n_source_vertex,
        hw_mgmt_os: 6000,
    }
}

/// Fixed (non-weight-delay-map) per-subordinate overhead from Table I's
/// "parallel paradigm (subordinate)" block.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct SubordinateFixedCost {
    pub output_recording: usize,
    pub stack_heap: usize,
    pub hw_mgmt_os: usize,
}

impl SubordinateFixedCost {
    pub fn total(&self) -> usize {
        self.output_recording + self.stack_heap + self.hw_mgmt_os
    }
}

/// Table I subordinate fixed cost for a chunk simulating `n_tgt_chunk`
/// target columns.
pub fn subordinate_fixed_cost(
    n_tgt_chunk: usize,
    delay_range: usize,
    n_source_vertex: usize,
) -> SubordinateFixedCost {
    SubordinateFixedCost {
        // (16/8)*n_neuron*delay_range*n_projection_type (verbatim Table I).
        output_recording: WORD16 * n_tgt_chunk * delay_range * N_PROJECTION_TYPE,
        // (96/8)*n_source_vertex.
        stack_heap: MPT_ENTRY * n_source_vertex,
        hw_mgmt_os: 6000,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hardware::PeSpec;

    #[test]
    fn dominant_reference_values() {
        let c = dominant_cost(255, 255, 16, 1);
        assert_eq!(c.input_spike_buffer, 4 * 255);
        assert_eq!(c.reversed_order, 2 * 255 * 16);
        assert_eq!(c.input_merging_table, 3 * 255 * 16);
        assert_eq!(c.stacked_input, 4 * 255 * 16);
        assert_eq!(c.output_recording, 4 * 255 * 4);
        assert_eq!(c.stack_heap, 12);
        assert_eq!(c.hw_mgmt_os, 6000);
        let item_sum: usize = c.items().iter().map(|(_, b)| b).sum();
        assert_eq!(item_sum, c.total());
    }

    #[test]
    fn one_dominant_suffices_across_paper_sweep() {
        // Paper §IV-A: "Within the scope of these settings, one dominant PE
        // is enough according to our calculation based on the cost model."
        let budget = PeSpec::default().dtcm_bytes;
        for &src in &[50usize, 250, 500] {
            for &tgt in &[50usize, 250, 500] {
                for &d in &[1usize, 8, 16] {
                    let c = dominant_cost(src, tgt, d, src.div_ceil(255));
                    assert!(
                        c.total() <= budget,
                        "dominant overflow at src={src} tgt={tgt} delay={d}: {} B",
                        c.total()
                    );
                }
            }
        }
    }

    #[test]
    fn dominant_scales_with_delay() {
        let d1 = dominant_cost(500, 500, 1, 2).total();
        let d16 = dominant_cost(500, 500, 16, 2).total();
        assert!(d16 > d1);
    }

    #[test]
    fn subordinate_fixed_values() {
        let c = subordinate_fixed_cost(255, 16, 1);
        assert_eq!(c.output_recording, 2 * 255 * 16 * 2);
        assert_eq!(c.stack_heap, 12);
        assert_eq!(c.total(), 2 * 255 * 16 * 2 + 12 + 6000);
    }
}
