//! Runtime activity cost model — per-timestep *work* estimates as a
//! function of the observed (or assumed) source firing rate.
//!
//! Table I prices what a paradigm *stores*; this module prices what it
//! *does* per timestep, closing the loop between execution telemetry
//! ([`crate::sim::LayerActivity`] reports observed rates) and the
//! serial-vs-parallel decision:
//!
//! * **serial** — event-driven: work scales with the synaptic events that
//!   actually arrive, `rate × n_source × n_target × density` accumulates
//!   per step (one ring-buffer update each);
//! * **parallel** — time-driven: once any stacked lane is populated the MAC
//!   array sweeps the whole weight-delay map, costing
//!   `ceil(rows/4) × ceil(cols/16)` array issues (DESIGN.md §Perf)
//!   regardless of how sparse the step was; only fully silent steps are
//!   free (the engines gate those — `slot_writes` counters on both sides).
//!
//! The crossover between the two curves is exactly the sparsity crossover
//! the paper's paradigm choice hinges on: sparse activity favors serial,
//! dense activity amortizes the MAC array. Units are "PE work items per
//! timestep" (one synaptic event ≈ one MAC-array issue ≈ one inner-loop
//! iteration); a first-order model, reported as a *relative* signal only —
//! see [`crate::paradigm::CostEstimate::step_cost`] and
//! [`crate::switching::SwitchPolicy::decide_with_rate`].

use crate::model::LayerCharacter;
use crate::paradigm::Paradigm;

/// MAC-array geometry the issue count is quantized to (4×16, §Perf).
pub const MAC_ARRAY_ROWS: f64 = 4.0;
pub const MAC_ARRAY_COLS: f64 = 16.0;

/// Expected synaptic events per timestep under the serial paradigm: each
/// source spike touches its fan-out once (`rate` = spikes per source neuron
/// per timestep, clamped to [0, 1]).
pub fn serial_events_per_step(ch: &LayerCharacter, rate: f64) -> f64 {
    rate.clamp(0.0, 1.0) * ch.n_source as f64 * ch.n_target as f64 * ch.density
}

/// Observed per-source-neuron firing rate from windowed spike counters:
/// `spikes / (steps × n_source)`, the empirical counterpart of the `rate`
/// parameter every cost function above takes. Total-by-construction: an
/// empty window (`steps == 0`) or a zero-neuron source reports `0.0` — a
/// silent window and an unobservable one both mean "no evidence of
/// activity", and the decision machinery must never see a NaN.
pub fn observed_rate(spikes: u64, steps: u64, n_source: usize) -> f64 {
    let denom = steps as f64 * n_source as f64;
    if denom == 0.0 {
        return 0.0;
    }
    spikes as f64 / denom
}

/// Expected occupied weight-delay-map rows: a `(source, delay)` lane exists
/// iff at least one of the source's `n_target` potential synapses drew that
/// delay (delays uniform over `1..=delay_range`, presence `density`).
pub fn wdm_occupied_rows(ch: &LayerCharacter) -> f64 {
    let lanes = ch.n_source as f64 * ch.delay_range as f64;
    let p_lane = 1.0 - (1.0 - ch.density / ch.delay_range as f64).powi(ch.n_target as i32);
    lanes * p_lane
}

/// Expected MAC-array issues per timestep under the parallel paradigm: the
/// full `rows × cols` sweep on every step with ≥1 due lane, zero on silent
/// steps (which the engine's slot gating skips).
pub fn parallel_mac_issues_per_step(ch: &LayerCharacter, rate: f64) -> f64 {
    let rate = rate.clamp(0.0, 1.0);
    if rate == 0.0 {
        return 0.0;
    }
    let issues = (wdm_occupied_rows(ch) / MAC_ARRAY_ROWS).ceil()
        * (ch.n_target as f64 / MAC_ARRAY_COLS).ceil();
    // P(step is non-silent) = P(any source fired this step).
    let p_active = 1.0 - (1.0 - rate).powi(ch.n_source as i32);
    issues * p_active
}

/// Per-step work of `paradigm` on this layer at the given firing rate.
pub fn step_cost(paradigm: Paradigm, ch: &LayerCharacter, rate: f64) -> f64 {
    match paradigm {
        Paradigm::Serial => serial_events_per_step(ch, rate),
        Paradigm::Parallel => parallel_mac_issues_per_step(ch, rate),
    }
}

/// Default hysteresis margin for [`runtime_preferred`]: parallel must beat
/// serial by this relative fraction before the preference flips away from
/// the serial default. A strict `<` flipped paradigms on epsilon-sized cost
/// differences, which is exactly the instability a runtime re-switcher
/// (ROADMAP item 4) cannot afford — every flip costs a reconfiguration.
pub const DEFAULT_HYSTERESIS_MARGIN: f64 = 0.05;

/// The paradigm with less per-step work at this firing rate, with the
/// default hysteresis margin (ties and near-ties go to serial, mirroring
/// [`crate::switching::SwitchPolicy::cheaper`]).
pub fn runtime_preferred(ch: &LayerCharacter, rate: f64) -> Paradigm {
    runtime_preferred_with_margin(ch, rate, DEFAULT_HYSTERESIS_MARGIN)
}

/// [`runtime_preferred`] with an explicit relative margin: parallel is
/// preferred only when `parallel < serial · (1 − margin)`. `margin = 0.0`
/// recovers the historical strict-`<` behavior.
pub fn runtime_preferred_with_margin(
    ch: &LayerCharacter,
    rate: f64,
    margin: f64,
) -> Paradigm {
    let serial = serial_events_per_step(ch, rate);
    let parallel = parallel_mac_issues_per_step(ch, rate);
    if parallel < serial * (1.0 - margin) {
        Paradigm::Parallel
    } else {
        Paradigm::Serial
    }
}

/// Measured per-second throughput constants produced by `s2switch
/// calibrate` ([`crate::calibrate`]): how many work items of each kind this
/// host actually retires per second, per kernel variant. They convert the
/// abstract work-item costs above into seconds, so the runtime preference
/// can track real hardware instead of assuming one synaptic event ≈ one
/// MAC-array issue.
#[derive(Debug, Clone, PartialEq)]
pub struct CalibrationConstants {
    /// Synaptic events the serial engine processes per second.
    pub serial_events_per_sec: f64,
    /// Scalar multiply-accumulates the MAC backend issues per second.
    pub parallel_macs_per_sec: f64,
    /// LIF neuron-steps per second (context for profiling output; not part
    /// of the paradigm decision, which prices only projection work).
    pub lif_neuron_steps_per_sec: f64,
    /// Which kernel the constants were measured on (`"scalar"`, `"simd"`).
    pub kernel_variant: String,
}

impl CalibrationConstants {
    /// Measured seconds per step under the serial paradigm.
    pub fn serial_step_seconds(&self, ch: &LayerCharacter, rate: f64) -> f64 {
        serial_events_per_step(ch, rate) / self.serial_events_per_sec.max(1.0)
    }

    /// Measured seconds per step under the parallel paradigm. Work items
    /// are 4×16 array issues; the backend constant counts scalar MACs, so
    /// issues convert at [`MACS_PER_ISSUE`].
    pub fn parallel_step_seconds(&self, ch: &LayerCharacter, rate: f64) -> f64 {
        parallel_mac_issues_per_step(ch, rate) * MACS_PER_ISSUE
            / self.parallel_macs_per_sec.max(1.0)
    }
}

/// Scalar MACs per 4×16 array issue.
pub const MACS_PER_ISSUE: f64 = MAC_ARRAY_ROWS * MAC_ARRAY_COLS;

/// [`runtime_preferred_with_margin`] on *measured seconds* instead of
/// abstract work items: the calibrated decision `s2switch calibrate`
/// unlocks. Parallel is preferred only when its measured step time beats
/// serial's by the relative margin.
pub fn runtime_preferred_calibrated(
    ch: &LayerCharacter,
    rate: f64,
    cal: &CalibrationConstants,
    margin: f64,
) -> Paradigm {
    let serial = cal.serial_step_seconds(ch, rate);
    let parallel = cal.parallel_step_seconds(ch, rate);
    if parallel < serial * (1.0 - margin) {
        Paradigm::Parallel
    } else {
        Paradigm::Serial
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn serial_work_is_linear_in_rate_parallel_saturates() {
        let ch = LayerCharacter::new(255, 255, 1.0, 1);
        let s1 = serial_events_per_step(&ch, 0.1);
        let s2 = serial_events_per_step(&ch, 0.2);
        assert!((s2 - 2.0 * s1).abs() < 1e-9, "serial work is linear in rate");
        let p_lo = parallel_mac_issues_per_step(&ch, 0.1);
        let p_hi = parallel_mac_issues_per_step(&ch, 0.9);
        assert!(p_hi / p_lo < 1.01, "parallel work saturates once steps are non-silent");
    }

    #[test]
    fn observed_rate_is_total_and_never_nan() {
        assert_eq!(observed_rate(50, 100, 10), 0.05);
        assert_eq!(observed_rate(0, 100, 10), 0.0, "silent window is rate 0");
        assert_eq!(observed_rate(0, 0, 10), 0.0, "empty window is rate 0, not NaN");
        assert_eq!(observed_rate(7, 5, 0), 0.0, "zero-neuron source is rate 0");
        assert!(observed_rate(u64::MAX, 1, 1).is_finite());
    }

    #[test]
    fn silent_layers_cost_nothing() {
        let ch = LayerCharacter::new(500, 500, 0.5, 8);
        assert_eq!(serial_events_per_step(&ch, 0.0), 0.0);
        assert_eq!(parallel_mac_issues_per_step(&ch, 0.0), 0.0);
    }

    #[test]
    fn sparsity_crossover_matches_the_paper_poles() {
        // Dense delay-1 at high rate → the MAC array amortizes (parallel);
        // the same layer at ≲1% activity → event-driven serial wins; a
        // sparse delay-16 layer stays serial even at high rates.
        let dense = LayerCharacter::new(255, 255, 1.0, 1);
        assert_eq!(runtime_preferred(&dense, 0.5), Paradigm::Parallel);
        assert_eq!(runtime_preferred(&dense, 0.005), Paradigm::Serial);
        let sparse = LayerCharacter::new(255, 255, 0.1, 16);
        assert_eq!(runtime_preferred(&sparse, 0.5), Paradigm::Serial);
    }

    #[test]
    fn occupied_rows_bounded_by_lanes_and_synapses() {
        for (ns, nt, d, dl) in [(100, 100, 0.3, 4), (255, 255, 1.0, 1), (2048, 20, 0.03, 16)] {
            let ch = LayerCharacter::new(ns, nt, d, dl);
            let rows = wdm_occupied_rows(&ch);
            assert!(rows >= 0.0);
            assert!(rows <= (ns * dl as usize) as f64 + 1e-9, "rows exceed lane count");
            // Can't occupy more rows than there are expected synapses.
            assert!(rows <= ch.expected_synapses() + 1e-9, "rows exceed synapses");
        }
    }

    #[test]
    fn step_cost_dispatches_by_paradigm() {
        let ch = LayerCharacter::new(200, 100, 0.5, 2);
        assert_eq!(step_cost(Paradigm::Serial, &ch, 0.2), serial_events_per_step(&ch, 0.2));
        assert_eq!(
            step_cost(Paradigm::Parallel, &ch, 0.2),
            parallel_mac_issues_per_step(&ch, 0.2)
        );
    }

    #[test]
    fn hysteresis_margin_keeps_near_ties_serial() {
        // Find a rate where parallel wins by under 10%: margin 0.0 flips to
        // parallel, a 15% margin holds serial, and the clear-win pole stays
        // parallel under any reasonable margin.
        let dense = LayerCharacter::new(255, 255, 1.0, 1);
        let serial = serial_events_per_step(&dense, 0.5);
        let parallel = parallel_mac_issues_per_step(&dense, 0.5);
        assert!(parallel < serial * 0.5, "dense@0.5 is a clear parallel win");
        assert_eq!(
            runtime_preferred_with_margin(&dense, 0.5, DEFAULT_HYSTERESIS_MARGIN),
            Paradigm::Parallel
        );
        // A synthetic near-tie: pick the rate where serial work equals
        // parallel work × 1.05 (serial linear in rate ⇒ solvable directly).
        let p = parallel_mac_issues_per_step(&dense, 1.0);
        let near_tie_rate = p * 1.05 / (dense.n_source as f64 * dense.n_target as f64);
        let s = serial_events_per_step(&dense, near_tie_rate);
        let pp = parallel_mac_issues_per_step(&dense, near_tie_rate);
        assert!(pp < s, "parallel nominally cheaper at the near-tie rate");
        assert_eq!(
            runtime_preferred_with_margin(&dense, near_tie_rate, 0.0),
            Paradigm::Parallel,
            "zero margin recovers strict-< behavior"
        );
        assert_eq!(
            runtime_preferred_with_margin(&dense, near_tie_rate, 0.15),
            Paradigm::Serial,
            "a 15% margin must hold the serial default on a <5% win"
        );
    }

    #[test]
    fn calibration_constants_flip_the_preference() {
        let ch = LayerCharacter::new(255, 255, 1.0, 1);
        // With balanced constants (1 event ≈ 64 MACs per issue, measured at
        // equal per-second throughput per item) the calibrated decision
        // mirrors the abstract one at the dense pole.
        let balanced = CalibrationConstants {
            serial_events_per_sec: 1e8,
            parallel_macs_per_sec: 64.0 * 1e8,
            lif_neuron_steps_per_sec: 1e9,
            kernel_variant: "scalar".into(),
        };
        assert_eq!(
            runtime_preferred_calibrated(&ch, 0.5, &balanced, DEFAULT_HYSTERESIS_MARGIN),
            Paradigm::Parallel
        );
        // A host whose MAC path measures 1000× slower must flip the same
        // layer to serial — the whole point of calibration.
        let slow_mac = CalibrationConstants {
            parallel_macs_per_sec: 64.0 * 1e5,
            ..balanced.clone()
        };
        assert_eq!(
            runtime_preferred_calibrated(&ch, 0.5, &slow_mac, DEFAULT_HYSTERESIS_MARGIN),
            Paradigm::Serial
        );
        // And a host whose serial path is the slow one prefers parallel
        // even at the sparse pole.
        let slow_serial = CalibrationConstants {
            serial_events_per_sec: 1e3,
            ..balanced
        };
        assert_eq!(
            runtime_preferred_calibrated(&ch, 0.005, &slow_serial, DEFAULT_HYSTERESIS_MARGIN),
            Paradigm::Parallel
        );
    }
}
