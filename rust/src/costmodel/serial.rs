//! Serial-paradigm DTCM cost model (Table I, upper block) and the
//! PE-allocation algorithm (§III-A / §IV-A).
//!
//! Layout rules from the paper:
//! * target populations are split into sub-populations of at most 255
//!   neurons per PE (sPyNNaker's capacity, ref [14]);
//! * source populations are split into source *vertices* of at most 255
//!   neurons (driving the master-population-table size);
//! * layers whose synaptic matrix exceeds one PE's DTCM ("the DTCM of one PE
//!   is incapable of holding all the data structures when the weight density
//!   is over 25%") equally distribute the matrix into **2–4 adjacent PEs**
//!   by splitting source rows; if even a 4-way split cannot fit, the target
//!   split is deepened instead.

use super::{MPT_ENTRY, N_LIF_PARAMS, N_PROJECTION_TYPE, WORD16, WORD32};
use crate::hardware::PeSpec;
use crate::model::LayerCharacter;

/// Itemized serial-paradigm DTCM cost for one PE (bytes), mirroring Table I
/// rows one-to-one.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct SerialCost {
    pub input_spike_buffer: usize,
    pub dma_buffer: usize,
    pub master_population_table: usize,
    pub address_list: usize,
    pub synaptic_matrix: usize,
    pub synaptic_input_buffer: usize,
    pub neuron_synapse_model: usize,
    pub output_recording: usize,
    pub stack_heap: usize,
    pub hw_mgmt_os: usize,
}

impl SerialCost {
    /// Total bytes on this PE.
    pub fn total(&self) -> usize {
        self.input_spike_buffer
            + self.dma_buffer
            + self.master_population_table
            + self.address_list
            + self.synaptic_matrix
            + self.synaptic_input_buffer
            + self.neuron_synapse_model
            + self.output_recording
            + self.stack_heap
            + self.hw_mgmt_os
    }

    /// (name, bytes) pairs in Table I order, for the T1 bench.
    pub fn items(&self) -> [(&'static str, usize); 10] {
        [
            ("input spike buffer", self.input_spike_buffer),
            ("DMA buffer", self.dma_buffer),
            ("master population table", self.master_population_table),
            ("address list", self.address_list),
            ("synaptic matrix", self.synaptic_matrix),
            ("synaptic input buffer", self.synaptic_input_buffer),
            ("neuron and synapse model", self.neuron_synapse_model),
            ("output recording", self.output_recording),
            ("stack & heap", self.stack_heap),
            ("hw mgmt & OS", self.hw_mgmt_os),
        ]
    }
}

/// Table I serial cost for one PE.
///
/// * `n_src_pe` — source neurons whose synaptic rows this PE stores;
/// * `n_tgt_pe` — target neurons simulated on this PE;
/// * `density` — weight density of the projection;
/// * `delay_range` — maximum synaptic delay (ring-buffer slots);
/// * `n_source_vertex` — source vertices in the machine graph (drives the
///   master population table and stack/heap rows).
pub fn serial_pe_cost(
    n_src_pe: usize,
    n_tgt_pe: usize,
    density: f64,
    delay_range: usize,
    n_source_vertex: usize,
) -> SerialCost {
    SerialCost {
        // (32/8)*n_neuron — one word per source neuron of in-flight spikes.
        input_spike_buffer: WORD32 * n_src_pe,
        // DRAM not involved in this paper's experiments.
        dma_buffer: 0,
        // (96/8)*n_source_vertex.
        master_population_table: MPT_ENTRY * n_source_vertex,
        // (32/8)*n_address_list_rows — one row per source neuron block.
        address_list: WORD32 * n_src_pe,
        // (32/8)*n_src*n_tgt*max_connected_rate — 4-byte synaptic words.
        synaptic_matrix: (WORD32 as f64 * n_src_pe as f64 * n_tgt_pe as f64 * density).ceil()
            as usize,
        // (16/8)*n_neuron*delay_range*n_projection_type — the delay ring
        // buffer, one 16-bit accumulator per (target, delay, type) slot.
        synaptic_input_buffer: WORD16 * n_tgt_pe * delay_range * N_PROJECTION_TYPE,
        // (32/8)*n_param with n_param = 8+6, held per neuron (DESIGN.md §6).
        neuron_synapse_model: WORD32 * N_LIF_PARAMS * n_tgt_pe,
        // (32/8)*(ceil(n/32)+1) + (32/8)*n*3 — spike bitmap + 3 words/neuron.
        output_recording: WORD32 * (n_tgt_pe.div_ceil(32) + 1) + WORD32 * n_tgt_pe * 3,
        // (96/8)*n_source_vertex.
        stack_heap: MPT_ENTRY * n_source_vertex,
        hw_mgmt_os: 6000,
    }
}

/// One PE of a serial layout.
#[derive(Clone, Debug)]
pub struct SerialPe {
    /// Which target chunk this PE serves.
    pub target_chunk: usize,
    /// Source-row split index within the chunk (0 when unsplit).
    pub row_split: usize,
    /// Source neurons handled by this PE.
    pub n_src: usize,
    /// Target neurons simulated/accumulated on this PE.
    pub n_tgt: usize,
    pub cost: SerialCost,
}

/// Result of serial PE allocation for one layer.
#[derive(Clone, Debug)]
pub struct SerialLayout {
    pub pes: Vec<SerialPe>,
    /// Target chunks (count of sub-populations).
    pub n_target_chunks: usize,
    /// Source vertices (master-population-table entries).
    pub n_source_vertex: usize,
}

impl SerialLayout {
    pub fn n_pes(&self) -> usize {
        self.pes.len()
    }

    pub fn total_dtcm(&self) -> usize {
        self.pes.iter().map(|p| p.cost.total()).sum()
    }
}

/// Split `n` into `parts` near-equal chunks (first chunks get the remainder).
pub fn balanced_split(n: usize, parts: usize) -> Vec<usize> {
    assert!(parts >= 1);
    let base = n / parts;
    let rem = n % parts;
    (0..parts).map(|i| base + usize::from(i < rem)).collect()
}

/// Maximum synaptic-matrix split factor before deepening the target split
/// ("equally distribute the synaptic matrix into 2-4 adjacent PEs").
pub const MAX_ROW_SPLIT: usize = 4;

/// Allocate serial-paradigm PEs for one layer per §III-A.
///
/// Returns `None` if the layer cannot be placed even with per-neuron target
/// chunks and 4-way row splits (cannot happen for the paper's sweep but the
/// API stays total).
pub fn serial_layout(ch: &LayerCharacter, pe: &PeSpec) -> Option<SerialLayout> {
    let budget = pe.dtcm_bytes;
    let cap = pe.serial_neuron_cap;
    let n_source_vertex = ch.n_source.div_ceil(cap);

    let mut n_chunks = ch.n_target.div_ceil(cap);
    'deepen: loop {
        if n_chunks > ch.n_target {
            return None;
        }
        let chunks = balanced_split(ch.n_target, n_chunks);
        let mut pes = Vec::new();
        for (chunk_idx, &n_tgt_pe) in chunks.iter().enumerate() {
            // Find the smallest row split 1..=4 that fits this chunk.
            let mut placed = false;
            for k in 1..=MAX_ROW_SPLIT {
                let rows = balanced_split(ch.n_source, k);
                let fits = rows.iter().all(|&n_src_pe| {
                    serial_pe_cost(n_src_pe, n_tgt_pe, ch.density, ch.delay_range as usize, n_source_vertex)
                        .total()
                        <= budget
                });
                if fits {
                    for (ri, &n_src_pe) in rows.iter().enumerate() {
                        pes.push(SerialPe {
                            target_chunk: chunk_idx,
                            row_split: ri,
                            n_src: n_src_pe,
                            n_tgt: n_tgt_pe,
                            cost: serial_pe_cost(
                                n_src_pe,
                                n_tgt_pe,
                                ch.density,
                                ch.delay_range as usize,
                                n_source_vertex,
                            ),
                        });
                    }
                    placed = true;
                    break;
                }
            }
            if !placed {
                // Even a 4-way row split does not fit: deepen target split.
                n_chunks += 1;
                continue 'deepen;
            }
        }
        return Some(SerialLayout { pes, n_target_chunks: n_chunks, n_source_vertex });
    }
}

/// Convenience: serial PE count for a layer character.
pub fn serial_pe_count(ch: &LayerCharacter, pe: &PeSpec) -> Option<usize> {
    serial_layout(ch, pe).map(|l| l.n_pes())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop::Prop;

    fn pe() -> PeSpec {
        PeSpec::default()
    }

    #[test]
    fn table1_reference_values() {
        // 255×255, density 1.0, delay 16, one source vertex — the paper's
        // per-PE reference configuration.
        let c = serial_pe_cost(255, 255, 1.0, 16, 1);
        assert_eq!(c.input_spike_buffer, 4 * 255);
        assert_eq!(c.master_population_table, 12);
        assert_eq!(c.address_list, 4 * 255);
        assert_eq!(c.synaptic_matrix, 4 * 255 * 255);
        assert_eq!(c.synaptic_input_buffer, 2 * 255 * 16 * 2);
        assert_eq!(c.neuron_synapse_model, 4 * 14 * 255);
        assert_eq!(c.output_recording, 4 * (8 + 1) + 4 * 255 * 3);
        assert_eq!(c.stack_heap, 12);
        assert_eq!(c.hw_mgmt_os, 6000);
        assert_eq!(c.dma_buffer, 0);
        let item_sum: usize = c.items().iter().map(|(_, b)| b).sum();
        assert_eq!(item_sum, c.total());
    }

    #[test]
    fn dense_255_needs_matrix_split() {
        // Paper: "the DTCM of one PE is incapable of holding all the data
        // structures when the weight density is over 25%".
        let over = serial_pe_cost(255, 255, 0.26, 16, 1);
        assert!(over.total() > pe().dtcm_bytes, "density 26% should overflow one PE");
        let under = serial_pe_cost(255, 255, 0.20, 16, 1);
        assert!(under.total() <= pe().dtcm_bytes, "density 20% should fit one PE");
    }

    #[test]
    fn layout_small_sparse_is_single_pe() {
        let ch = LayerCharacter::new(100, 100, 0.1, 4);
        let l = serial_layout(&ch, &pe()).unwrap();
        assert_eq!(l.n_pes(), 1);
        assert_eq!(l.n_target_chunks, 1);
        assert_eq!(l.n_source_vertex, 1);
    }

    #[test]
    fn layout_dense_splits_rows() {
        let ch = LayerCharacter::new(255, 255, 1.0, 16);
        let l = serial_layout(&ch, &pe()).unwrap();
        // 255×255 dense = 260 kB of matrix alone; needs several PEs.
        assert!(l.n_pes() >= 4, "got {}", l.n_pes());
        // Every PE fits its budget.
        assert!(l.pes.iter().all(|p| p.cost.total() <= pe().dtcm_bytes));
    }

    #[test]
    fn layout_large_population_splits_targets() {
        let ch = LayerCharacter::new(500, 500, 0.1, 1);
        let l = serial_layout(&ch, &pe()).unwrap();
        assert!(l.n_target_chunks >= 2, "500 targets need ≥2 chunks (cap 255)");
        assert_eq!(l.n_source_vertex, 2);
    }

    #[test]
    fn balanced_split_sums_and_balance() {
        Prop::new("balanced_split invariants", 300).check(
            |g| {
                let n = g.usize(0, 5000);
                let parts = g.usize(1, 64);
                (n, parts, balanced_split(n, parts))
            },
            |(n, parts, chunks)| {
                chunks.len() == *parts
                    && chunks.iter().sum::<usize>() == *n
                    && chunks.iter().max().unwrap() - chunks.iter().min().unwrap() <= 1
            },
        );
    }

    #[test]
    fn all_layout_pes_fit_budget_property() {
        Prop::new("serial layout fits DTCM", 150).check(
            |g| {
                let ch = LayerCharacter::new(
                    g.usize(50, 500),
                    g.usize(50, 500),
                    g.f64(0.1, 1.0),
                    g.usize(1, 16) as u16,
                );
                ch
            },
            |ch| {
                let l = serial_layout(ch, &PeSpec::default()).unwrap();
                l.pes.iter().all(|p| p.cost.total() <= PeSpec::default().dtcm_bytes)
                    && l.n_pes() >= ch.n_target.div_ceil(255)
            },
        );
    }

    #[test]
    fn pe_count_monotone_in_density() {
        let pe = pe();
        let mut prev = 0;
        for d in [0.1, 0.3, 0.5, 0.7, 0.9, 1.0] {
            let ch = LayerCharacter::new(400, 400, d, 8);
            let n = serial_pe_count(&ch, &pe).unwrap();
            assert!(n >= prev, "PE count should not decrease with density");
            prev = n;
        }
    }
}
