//! Long-lived inference daemon (`s2switch serve`) — DESIGN.md §Serving.
//!
//! Turns the one-shot CLI pipeline into a resident server, the ROADMAP's
//! "serves heavy traffic as fast as the hardware allows" shape:
//!
//! * [`tenants`] — boot every network once, as co-tenants of one shared
//!   machine (occupancy-mask admission), warm from the artifact store
//!   (zero materializing compiles, asserted).
//! * [`protocol`] — length-prefixed checksummed binary frames with typed
//!   errors, following the `artifact::codec` conventions.
//! * [`batcher`] — dynamic micro-batching onto persistent
//!   [`crate::sim::SimPool`] engines (reset between requests; no
//!   steady-state allocation).
//! * [`server`] — the socket loop: per-connection reader/writer threads,
//!   per-tenant batch workers, graceful drain on SIGINT/SIGTERM.
//! * [`client`] — a blocking request/response client for tests, benches
//!   and scripting.
//!
//! Determinism contract: a served response's spike counts are
//! bit-identical to a one-shot `simulate` of the same (network, steps,
//! seed, rate) at any client count, interleaving, batching window and
//! jobs setting — `tests/serve.rs` and the `serve-baseline` CI job hold
//! the line.

pub mod batcher;
pub mod client;
pub mod protocol;
pub mod server;
pub mod tenants;

pub use batcher::ServeMetrics;
pub use client::ServeClient;
pub use protocol::{ErrorCode, ProtocolError, Request, Response};
pub use server::{install_signal_handlers, ServeConfig, ServeReport, Server, ServerHandle};
pub use tenants::{BootReport, Tenant, TenantRegistry, TenantSpec};

use crate::model::PopulationId;
use crate::rng::Rng;

/// The canonical request stimulus: the same seeded Bernoulli spike
/// provider a one-shot `simulate` builds, parameterized by `(seed, rate)`
/// from the wire request. Serve responses are comparable bit-for-bit to
/// local runs precisely because both sides call this one function.
pub fn stimulus(
    pop_sizes: Vec<usize>,
    seed: u64,
    rate: f64,
) -> impl FnMut(PopulationId, u64, &mut Vec<u32>) {
    let mut rng = Rng::new(seed);
    move |p: PopulationId, _t: u64, out: &mut Vec<u32>| {
        out.extend((0..pop_sizes[p.0] as u32).filter(|_| rng.chance(rate)));
    }
}
