//! Blocking serve client: one request on the wire at a time.
//!
//! The protocol itself allows pipelining (responses carry the request id);
//! the bench's open-loop load generator drives raw
//! [`super::protocol`] frames over split sender/receiver threads instead
//! of this convenience wrapper.

use super::protocol::{
    decode_response, encode_request_frame, read_frame, ProtocolError, Request, Response,
    RESPONSE_MAGIC,
};
use std::io::Write;
use std::net::{TcpStream, ToSocketAddrs};

pub struct ServeClient {
    stream: TcpStream,
    next_id: u64,
}

impl ServeClient {
    pub fn connect(addr: impl ToSocketAddrs) -> std::io::Result<ServeClient> {
        let stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true)?;
        Ok(ServeClient { stream, next_id: 1 })
    }

    /// Run `steps` timesteps of tenant `network` under the canonical
    /// seeded stimulus; blocks for the (typed) response.
    pub fn request(
        &mut self,
        network: &str,
        steps: u64,
        seed: u64,
        rate: f64,
    ) -> Result<Response, ProtocolError> {
        let request_id = self.next_id;
        self.next_id += 1;
        let req = Request { request_id, network: network.to_string(), steps, seed, rate };
        self.stream.write_all(&encode_request_frame(&req))?;
        let body = read_frame(&mut self.stream, RESPONSE_MAGIC)?;
        decode_response(&body)
    }

    /// Escape hatch for protocol tests: the raw stream.
    pub fn into_stream(self) -> TcpStream {
        self.stream
    }
}
