//! The serve daemon's connection loop.
//!
//! Thread shape (all scoped, all joined before [`Server::run`] returns):
//!
//! ```text
//! accept loop ──┬── reader (per connection) ──> tenant queue ──> TenantWorker (per tenant)
//!               │        │                                            │
//!               │        └── writer (per connection) <── response frames
//! ```
//!
//! Readers decode frames and route submissions to tenant queues; each
//! connection has one writer thread draining an mpsc channel of encoded
//! response frames, so concurrent batch completions never interleave
//! partial frames on one socket.
//!
//! Graceful shutdown (SIGINT/SIGTERM via [`install_signal_handlers`], or
//! [`ServerHandle::shutdown`]): the accept loop stops taking connections
//! and flips the shared stop flag; workers finish the batch in flight,
//! answer everything still queued with a typed `Shutdown` response, and
//! exit; readers answer any parsed-but-unrouted request the same way;
//! writers drain their channels and flush. No client mid-request ever
//! sees a reset connection.

use super::batcher::{ServeMetrics, Submission, TenantWorker};
use super::protocol::{
    decode_request, encode_response_frame, ErrorCode, FrameHeader, ProtocolError, Response,
    HEADER_BYTES, MAX_STEPS, REQUEST_MAGIC,
};
use super::tenants::{BootReport, TenantRegistry};
use crate::sim::SimPool;
use anyhow::{ensure, Context, Result};
use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{self, Sender};
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// Serving knobs (`--batch-window-us`, `--max-batch`, `--jobs`).
#[derive(Clone, Copy, Debug)]
pub struct ServeConfig {
    /// Micro-batch accumulation window in microseconds; 0 = batching off.
    pub batch_window_us: u64,
    /// Most requests one batch may hold.
    pub max_batch: usize,
    /// Pool engines per tenant (0 = one per CPU).
    pub jobs: usize,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig { batch_window_us: 200, max_batch: 16, jobs: 0 }
    }
}

/// What a finished server hands back: boot accounting plus serving
/// counters (the shutdown summary and the serve bench's raw material).
#[derive(Clone, Debug)]
pub struct ServeReport {
    pub boot: BootReport,
    pub metrics: ServeMetrics,
}

/// Cloneable remote control for a running [`Server`] (tests and the bench
/// use it in place of process signals).
#[derive(Clone)]
pub struct ServerHandle {
    stop: Arc<AtomicBool>,
    addr: SocketAddr,
}

impl ServerHandle {
    /// Begin graceful shutdown: same path as SIGINT/SIGTERM.
    pub fn shutdown(&self) {
        self.stop.store(true, Ordering::SeqCst);
    }

    pub fn addr(&self) -> SocketAddr {
        self.addr
    }
}

/// The long-lived daemon: one bound listener over one booted
/// [`TenantRegistry`].
pub struct Server {
    listener: TcpListener,
    registry: TenantRegistry,
    cfg: ServeConfig,
    stop: Arc<AtomicBool>,
}

impl Server {
    /// Bind `addr` (e.g. `127.0.0.1:0` for an ephemeral test port). The
    /// listener is non-blocking so the accept loop can poll the stop flag.
    pub fn bind(registry: TenantRegistry, addr: &str, cfg: ServeConfig) -> Result<Server> {
        ensure!(cfg.max_batch >= 1, "--max-batch must be at least 1 (got {})", cfg.max_batch);
        let listener =
            TcpListener::bind(addr).with_context(|| format!("binding serve socket {addr}"))?;
        listener.set_nonblocking(true).context("setting the serve listener non-blocking")?;
        Ok(Server { listener, registry, cfg, stop: Arc::new(AtomicBool::new(false)) })
    }

    pub fn local_addr(&self) -> Result<SocketAddr> {
        Ok(self.listener.local_addr()?)
    }

    pub fn handle(&self) -> Result<ServerHandle> {
        Ok(ServerHandle { stop: self.stop.clone(), addr: self.local_addr()? })
    }

    /// Serve until shutdown, then drain and return the final report.
    /// Engine pools are built here, once, and live for the whole serve —
    /// the hot path never constructs engine state.
    pub fn run(self) -> Result<ServeReport> {
        let Server { listener, registry, cfg, stop } = self;
        let window = Duration::from_micros(cfg.batch_window_us);
        let metrics = Mutex::new(ServeMetrics::default());

        // Per-tenant queues + workers, built before the thread scope so
        // pool-construction errors surface as a clean boot failure.
        let mut queues: BTreeMap<String, Sender<Submission>> = BTreeMap::new();
        let mut workers = Vec::with_capacity(registry.tenants.len());
        for tenant in &registry.tenants {
            let pool = SimPool::new(&tenant.net, &tenant.layers, cfg.jobs)
                .with_context(|| format!("building engine pool for tenant '{}'", tenant.name))?;
            let (tx, rx) = mpsc::channel();
            queues.insert(tenant.name.clone(), tx);
            workers.push(TenantWorker {
                name: tenant.name.clone(),
                pop_sizes: tenant.pop_sizes(),
                pool,
                rx,
                window,
                max_batch: cfg.max_batch,
                stop: stop.clone(),
            });
        }

        let queues = &queues;
        let metrics_ref = &metrics;
        let stop_ref = &stop;
        std::thread::scope(|scope| -> Result<()> {
            for worker in workers {
                scope.spawn(move || worker.run(metrics_ref));
            }
            loop {
                if stop_ref.load(Ordering::SeqCst) || signals::requested() {
                    // Signal and handle paths converge on the one flag
                    // every worker and reader polls.
                    stop_ref.store(true, Ordering::SeqCst);
                    break;
                }
                match listener.accept() {
                    Ok((stream, _peer)) => {
                        scope.spawn(move || {
                            serve_connection(stream, queues, metrics_ref, stop_ref);
                        });
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        std::thread::sleep(Duration::from_millis(5));
                    }
                    Err(e) => {
                        stop_ref.store(true, Ordering::SeqCst);
                        return Err(e).context("accepting a serve connection");
                    }
                }
            }
            Ok(())
            // Scope exit joins every reader, writer and worker: in-flight
            // batches finish, queued requests get Shutdown, writers flush.
        })?;

        let metrics = metrics.into_inner().unwrap();
        Ok(ServeReport { boot: registry.report.clone(), metrics })
    }
}

/// Outcome of an interruptible exact read on a non-blocking-ish stream
/// (read timeout as the poll period).
enum ReadOutcome {
    Full,
    /// Peer closed; `read` bytes of the wanted span had arrived.
    Eof { read: usize },
    /// Shutdown flag flipped mid-read; `read` bytes had arrived.
    Stopped { read: usize },
}

fn read_interruptible(
    stream: &mut TcpStream,
    buf: &mut [u8],
    stop: &AtomicBool,
) -> std::io::Result<ReadOutcome> {
    let mut got = 0usize;
    while got < buf.len() {
        match stream.read(&mut buf[got..]) {
            Ok(0) => return Ok(ReadOutcome::Eof { read: got }),
            Ok(n) => got += n,
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                ) =>
            {
                if stop.load(Ordering::SeqCst) {
                    return Ok(ReadOutcome::Stopped { read: got });
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(e) => return Err(e),
        }
    }
    Ok(ReadOutcome::Full)
}

/// Per-connection reader: frame decode, typed-error replies, routing.
/// Protocol failures that lose framing (bad magic/version/oversize) answer
/// then close this connection only; failures with framing intact
/// (checksum, malformed payload, unknown tenant, bad request) answer and
/// keep serving the connection.
fn serve_connection(
    mut stream: TcpStream,
    queues: &BTreeMap<String, Sender<Submission>>,
    metrics: &Mutex<ServeMetrics>,
    stop: &AtomicBool,
) {
    if stream.set_read_timeout(Some(Duration::from_millis(50))).is_err()
        || stream.set_nodelay(true).is_err()
    {
        return;
    }
    let Ok(write_half) = stream.try_clone() else {
        return;
    };
    let (reply_tx, reply_rx) = mpsc::channel::<Vec<u8>>();
    let writer = std::thread::spawn(move || writer_loop(write_half, reply_rx));

    loop {
        let mut hdr = [0u8; HEADER_BYTES];
        match read_interruptible(&mut stream, &mut hdr, stop) {
            Ok(ReadOutcome::Full) => {}
            Ok(ReadOutcome::Eof { read: 0 }) | Ok(ReadOutcome::Stopped { read: 0 }) => break,
            Ok(ReadOutcome::Eof { .. }) => {
                metrics.lock().unwrap().truncated_frames += 1;
                break;
            }
            Ok(ReadOutcome::Stopped { .. }) => {
                send_shutdown(&reply_tx, 0, metrics);
                break;
            }
            Err(_) => break,
        }
        let header = FrameHeader::parse(&hdr);
        if let Err(e) = header.validate(REQUEST_MAGIC) {
            // Framing is unrecoverable — answer with the typed error and
            // close this connection; the server keeps serving others.
            send_protocol_error(&reply_tx, &e, metrics);
            break;
        }
        let mut body = vec![0u8; header.body_len as usize];
        match read_interruptible(&mut stream, &mut body, stop) {
            Ok(ReadOutcome::Full) => {}
            Ok(ReadOutcome::Eof { .. }) => {
                metrics.lock().unwrap().truncated_frames += 1;
                break;
            }
            Ok(ReadOutcome::Stopped { .. }) => {
                send_shutdown(&reply_tx, 0, metrics);
                break;
            }
            Err(_) => break,
        }
        if let Err(e) = header.verify_body(&body) {
            send_protocol_error(&reply_tx, &e, metrics);
            continue;
        }
        let req = match decode_request(&body) {
            Ok(req) => req,
            Err(e) => {
                send_protocol_error(&reply_tx, &e, metrics);
                continue;
            }
        };
        metrics.lock().unwrap().requests += 1;
        if stop.load(Ordering::SeqCst) {
            send_shutdown(&reply_tx, req.request_id, metrics);
            break;
        }
        if req.steps == 0 || req.steps > MAX_STEPS {
            send_error(
                &reply_tx,
                req.request_id,
                ErrorCode::BadRequest,
                format!("steps must be in 1..={MAX_STEPS} (got {})", req.steps),
                metrics,
            );
            continue;
        }
        if !req.rate.is_finite() || !(0.0..=1.0).contains(&req.rate) {
            send_error(
                &reply_tx,
                req.request_id,
                ErrorCode::BadRequest,
                format!("stimulus rate must be a finite probability in [0, 1] (got {})", req.rate),
                metrics,
            );
            continue;
        }
        let Some(queue) = queues.get(&req.network) else {
            let known: Vec<&str> = queues.keys().map(String::as_str).collect();
            send_error(
                &reply_tx,
                req.request_id,
                ErrorCode::UnknownNetwork,
                format!("no tenant '{}' (serving: {})", req.network, known.join(", ")),
                metrics,
            );
            continue;
        };
        let request_id = req.request_id;
        let sub = Submission { req, reply: reply_tx.clone(), enqueued: std::time::Instant::now() };
        if queue.send(sub).is_err() {
            // Worker already drained and exited: shutdown raced the route.
            send_shutdown(&reply_tx, request_id, metrics);
            break;
        }
    }
    drop(reply_tx);
    let _ = writer.join();
}

/// Connection writer: serializes whole response frames onto the socket.
/// Exits when every sender (reader + outstanding submissions) is gone —
/// i.e. after all in-flight responses for this connection are flushed.
fn writer_loop(mut stream: TcpStream, rx: mpsc::Receiver<Vec<u8>>) {
    while let Ok(frame) = rx.recv() {
        if stream.write_all(&frame).is_err() {
            break;
        }
    }
    let _ = stream.flush();
}

fn send_protocol_error(reply: &Sender<Vec<u8>>, e: &ProtocolError, metrics: &Mutex<ServeMetrics>) {
    let rsp = Response::Error { request_id: 0, code: ErrorCode::Protocol, message: e.to_string() };
    let _ = reply.send(encode_response_frame(&rsp));
    let mut m = metrics.lock().unwrap();
    m.protocol_errors += 1;
    m.error_responses += 1;
}

fn send_error(
    reply: &Sender<Vec<u8>>,
    request_id: u64,
    code: ErrorCode,
    message: String,
    metrics: &Mutex<ServeMetrics>,
) {
    let rsp = Response::Error { request_id, code, message };
    let _ = reply.send(encode_response_frame(&rsp));
    metrics.lock().unwrap().error_responses += 1;
}

fn send_shutdown(reply: &Sender<Vec<u8>>, request_id: u64, metrics: &Mutex<ServeMetrics>) {
    let rsp = Response::Shutdown {
        request_id,
        message: "server draining for shutdown".to_string(),
    };
    let _ = reply.send(encode_response_frame(&rsp));
    metrics.lock().unwrap().shutdown_responses += 1;
}

/// Install SIGINT/SIGTERM handlers that flip a process-wide flag every
/// [`Server::run`] accept loop polls — the CLI's graceful-shutdown entry.
/// Tests and the bench use [`ServerHandle::shutdown`] instead.
pub fn install_signal_handlers() {
    signals::install();
}

#[cfg(unix)]
mod signals {
    use std::sync::atomic::{AtomicBool, Ordering};

    static REQUESTED: AtomicBool = AtomicBool::new(false);

    const SIGINT: i32 = 2;
    const SIGTERM: i32 = 15;

    type Handler = extern "C" fn(i32);

    extern "C" {
        // POSIX `signal(2)`; returns the previous disposition (unused).
        fn signal(signum: i32, handler: Handler) -> usize;
    }

    extern "C" fn note(_signum: i32) {
        // Only an async-signal-safe atomic store happens here.
        REQUESTED.store(true, Ordering::SeqCst);
    }

    pub(super) fn install() {
        unsafe {
            signal(SIGINT, note);
            signal(SIGTERM, note);
        }
    }

    pub(super) fn requested() -> bool {
        REQUESTED.load(Ordering::SeqCst)
    }
}

#[cfg(not(unix))]
mod signals {
    pub(super) fn install() {}

    pub(super) fn requested() -> bool {
        false
    }
}
