//! Dynamic micro-batching: per-tenant request accumulation onto a
//! persistent [`SimPool`].
//!
//! One [`TenantWorker`] runs per tenant. It blocks on its request queue;
//! when the first request of a batch arrives it keeps accumulating until
//! either `max_batch` requests are in hand or `batch_window_us` has
//! elapsed since that first arrival — then the whole batch fans out over
//! the tenant's pool engines in one [`SimPool::run_each`] call. A window
//! of **0** disables micro-batching (strict request-at-a-time), which is
//! the bench's "batching off" comparison point.
//!
//! Determinism: each request's output is a pure function of the request
//! itself — the engine is [`crate::sim::NetworkSim::reset`] before it and
//! the stimulus is the request's own seeded provider — so the batch
//! assembly (arrival order, window cuts, pool size) affects latency only,
//! never a single response byte (DESIGN.md §Serving).

use super::protocol::{encode_response_frame, Request, Response};
use crate::bench_harness::LatencyHistogram;
use crate::model::PopulationId;
use crate::sim::SimPool;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// A decoded request routed to a tenant worker, with the channel its
/// encoded response frame goes back on (the connection's writer thread).
pub struct Submission {
    pub req: Request,
    pub reply: Sender<Vec<u8>>,
    pub enqueued: Instant,
}

/// Serving-side counters, shared across workers and readers. Batch sizes
/// feed the histogram `BENCH_serve.json` reports; request latencies feed
/// the shared [`LatencyHistogram`].
#[derive(Clone, Debug, Default)]
pub struct ServeMetrics {
    pub requests: u64,
    pub ok_responses: u64,
    pub error_responses: u64,
    pub shutdown_responses: u64,
    /// Frames rejected at the protocol layer (bad magic/version/size/...).
    pub protocol_errors: u64,
    /// Connections that died mid-frame.
    pub truncated_frames: u64,
    pub batches: u64,
    /// `batch_size_counts[s]` = batches executed with exactly `s+1` requests.
    pub batch_size_counts: Vec<u64>,
    /// Enqueue-to-response latency per served request.
    pub latency: LatencyHistogram,
}

impl ServeMetrics {
    pub fn note_batch(&mut self, size: usize) {
        self.batches += 1;
        if self.batch_size_counts.len() < size {
            self.batch_size_counts.resize(size, 0);
        }
        self.batch_size_counts[size - 1] += 1;
    }

    /// Mean executed batch size (0 when nothing ran).
    pub fn mean_batch(&self) -> f64 {
        let total: u64 = self.batch_size_counts.iter().sum();
        if total == 0 {
            return 0.0;
        }
        let weighted: u64 = self
            .batch_size_counts
            .iter()
            .enumerate()
            .map(|(i, &n)| (i as u64 + 1) * n)
            .sum();
        weighted as f64 / total as f64
    }
}

/// Accumulate one batch: `first` is already in hand; keep pulling from
/// `rx` until `max_batch` requests are collected or `window` has elapsed
/// since entry. `window == 0` returns immediately — micro-batching off.
pub fn collect_batch(
    rx: &Receiver<Submission>,
    first: Submission,
    window: Duration,
    max_batch: usize,
) -> Vec<Submission> {
    let mut batch = vec![first];
    if window.is_zero() {
        return batch;
    }
    let deadline = Instant::now() + window;
    while batch.len() < max_batch {
        let now = Instant::now();
        if now >= deadline {
            break;
        }
        match rx.recv_timeout(deadline - now) {
            Ok(sub) => batch.push(sub),
            Err(RecvTimeoutError::Timeout) | Err(RecvTimeoutError::Disconnected) => break,
        }
    }
    batch
}

/// One tenant's batching loop: queue → window accumulation → pool run →
/// per-request responses, until shutdown (then every queued request gets
/// a typed `Shutdown` response and the loop exits).
pub struct TenantWorker {
    pub name: String,
    pub pop_sizes: Vec<usize>,
    pub pool: SimPool,
    pub rx: Receiver<Submission>,
    pub window: Duration,
    pub max_batch: usize,
    pub stop: Arc<AtomicBool>,
}

impl TenantWorker {
    pub fn run(mut self, metrics: &Mutex<ServeMetrics>) {
        // Idle poll period: how quickly an idle tenant notices shutdown.
        let poll = Duration::from_millis(20);
        loop {
            let first = match self.rx.recv_timeout(poll) {
                Ok(sub) => sub,
                Err(RecvTimeoutError::Timeout) => {
                    if self.stop.load(Ordering::SeqCst) {
                        break;
                    }
                    continue;
                }
                Err(RecvTimeoutError::Disconnected) => break,
            };
            if self.stop.load(Ordering::SeqCst) {
                // Draining: everything still queued was not in flight when
                // shutdown began — typed Shutdown, never a dropped socket.
                self.refuse(first, metrics);
                while let Ok(sub) = self.rx.try_recv() {
                    self.refuse(sub, metrics);
                }
                break;
            }
            let batch = collect_batch(&self.rx, first, self.window, self.max_batch);
            self.execute(batch, metrics);
        }
    }

    fn refuse(&self, sub: Submission, metrics: &Mutex<ServeMetrics>) {
        let rsp = Response::Shutdown {
            request_id: sub.req.request_id,
            message: format!("server draining; tenant '{}' refused the request", self.name),
        };
        let _ = sub.reply.send(encode_response_frame(&rsp));
        metrics.lock().unwrap().shutdown_responses += 1;
    }

    /// Run every request of the batch on the persistent pool (one
    /// reset-isolated engine run per request) and answer in batch order.
    fn execute(&mut self, batch: Vec<Submission>, metrics: &Mutex<ServeMetrics>) {
        let sizes = &self.pop_sizes;
        let params: Vec<(u64, u64, f64)> =
            batch.iter().map(|s| (s.req.steps, s.req.seed, s.req.rate)).collect();
        let counts: Vec<Vec<u64>> = self.pool.run_each(batch.len(), |sim, i| {
            let (steps, seed, rate) = params[i];
            let mut provider = super::stimulus(sizes.clone(), seed, rate);
            sim.run_jobs(steps, &mut provider, 1);
            (0..sizes.len()).map(|p| sim.recorder.spike_count(PopulationId(p)) as u64).collect()
        });
        let size = batch.len();
        let mut m = metrics.lock().unwrap();
        m.note_batch(size);
        for (sub, spike_counts) in batch.into_iter().zip(counts) {
            let rsp = Response::Ok { request_id: sub.req.request_id, spike_counts };
            if sub.reply.send(encode_response_frame(&rsp)).is_ok() {
                m.ok_responses += 1;
            }
            m.latency.record(sub.enqueued.elapsed().as_nanos() as u64);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc;

    fn sub(id: u64) -> Submission {
        let (tx, _rx) = mpsc::channel();
        // The receiver is dropped — these tests exercise batching shape
        // only, not response delivery.
        Submission {
            req: Request {
                request_id: id,
                network: "t".to_string(),
                steps: 1,
                seed: id,
                rate: 0.1,
            },
            reply: tx,
            enqueued: Instant::now(),
        }
    }

    #[test]
    fn zero_window_disables_batching() {
        let (tx, rx) = mpsc::channel();
        tx.send(sub(2)).unwrap();
        tx.send(sub(3)).unwrap();
        let batch = collect_batch(&rx, sub(1), Duration::ZERO, 16);
        assert_eq!(batch.len(), 1, "window 0 must be strict request-at-a-time");
        assert_eq!(batch[0].req.request_id, 1);
        // The queued requests are untouched, ready for the next batch.
        assert_eq!(rx.try_recv().unwrap().req.request_id, 2);
    }

    #[test]
    fn max_batch_caps_accumulation() {
        let (tx, rx) = mpsc::channel();
        for id in 2..10 {
            tx.send(sub(id)).unwrap();
        }
        let batch = collect_batch(&rx, sub(1), Duration::from_secs(5), 4);
        assert_eq!(batch.len(), 4, "must stop at max_batch, not the window");
        let ids: Vec<u64> = batch.iter().map(|s| s.req.request_id).collect();
        assert_eq!(ids, vec![1, 2, 3, 4], "batch assembly order is arrival order");
    }

    #[test]
    fn window_expiry_closes_a_partial_batch() {
        let (tx, rx) = mpsc::channel();
        tx.send(sub(2)).unwrap();
        let batch = collect_batch(&rx, sub(1), Duration::from_millis(5), 16);
        assert_eq!(batch.len(), 2, "queued request joins, then the window expires");
        drop(tx);
    }

    #[test]
    fn batch_histogram_accounting() {
        let mut m = ServeMetrics::default();
        m.note_batch(1);
        m.note_batch(3);
        m.note_batch(3);
        assert_eq!(m.batches, 3);
        assert_eq!(m.batch_size_counts, vec![1, 0, 2]);
        let mean = m.mean_batch();
        assert!((mean - 7.0 / 3.0).abs() < 1e-9, "{mean}");
    }
}
