//! Length-prefixed binary wire protocol for the serve daemon.
//!
//! The framing reuses the [`crate::artifact::codec`] conventions: a fixed
//! little-endian header carrying magic, version and payload length, an
//! FNV-1a checksum over the body, and typed errors for every way a frame
//! can be wrong ([`ProtocolError`] — the socket-side sibling of
//! `ArtifactError`). Requests and responses use distinct magics so a
//! client that connects to the wrong side of a proxy fails with
//! [`ProtocolError::BadMagic`], not a silent mis-parse.
//!
//! Frame layout (all integers little-endian):
//!
//! ```text
//! magic     u32   b"S2RQ" (request) / b"S2RS" (response)
//! version   u32   protocol revision (1)
//! body_len  u64   payload bytes that follow the header
//! checksum  u64   fnv1a64(body)
//! body      [u8]  request / response payload
//! ```
//!
//! Request body: `request_id u64 | name_len u64 | name utf-8 | steps u64 |
//! seed u64 | rate f64-bits`. Response body: `request_id u64 | tag u8 |
//! payload` where tag 0 = Ok (`n u64` + `n` spike counts, one per
//! population in network order), tag 1 = Error (`code u8 | msg_len u64 |
//! msg`), tag 2 = Shutdown (`msg_len u64 | msg`).

use crate::artifact::codec::fnv1a64;
use std::fmt;
use std::io::{Read, Write};

/// Request-frame magic (`b"S2RQ"`).
pub const REQUEST_MAGIC: u32 = u32::from_le_bytes(*b"S2RQ");
/// Response-frame magic (`b"S2RS"`).
pub const RESPONSE_MAGIC: u32 = u32::from_le_bytes(*b"S2RS");
/// Protocol revision; bumped on any layout change.
pub const VERSION: u32 = 1;
/// Fixed frame-header size in bytes.
pub const HEADER_BYTES: usize = 24;
/// Hard ceiling on a frame body — requests are tiny and responses carry
/// one count per population, so anything bigger is hostile or corrupt.
pub const MAX_BODY_BYTES: u64 = 1 << 20;
/// Longest accepted tenant-network name.
pub const MAX_NAME_BYTES: u64 = 256;
/// Most timesteps one request may ask for (semantic bound, checked by the
/// server so the typed error is `ErrorCode::BadRequest`, not a frame kill).
pub const MAX_STEPS: u64 = 1_000_000;

/// Everything that can go wrong between bytes-on-the-wire and a decoded
/// frame. Mirrors `ArtifactError`: one variant per failure mode, each
/// carrying enough context to print an actionable message.
#[derive(Debug)]
pub enum ProtocolError {
    Io(std::io::Error),
    BadMagic { found: u32, want: u32 },
    BadVersion { found: u32, supported: u32 },
    Oversized { len: u64, max: u64 },
    Truncated { what: &'static str, need: u64, have: u64 },
    ChecksumMismatch { stored: u64, computed: u64 },
    Malformed { what: &'static str, detail: String },
}

impl fmt::Display for ProtocolError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ProtocolError::Io(e) => write!(f, "socket i/o: {e}"),
            ProtocolError::BadMagic { found, want } => {
                write!(f, "bad frame magic {found:#010x} (want {want:#010x})")
            }
            ProtocolError::BadVersion { found, supported } => {
                write!(f, "protocol version {found} unsupported (serving v{supported})")
            }
            ProtocolError::Oversized { len, max } => {
                write!(f, "frame body of {len} bytes exceeds the {max}-byte cap")
            }
            ProtocolError::Truncated { what, need, have } => {
                write!(f, "truncated {what}: need {need} bytes, have {have}")
            }
            ProtocolError::ChecksumMismatch { stored, computed } => {
                write!(f, "body checksum {computed:#018x} != stored {stored:#018x}")
            }
            ProtocolError::Malformed { what, detail } => {
                write!(f, "malformed {what}: {detail}")
            }
        }
    }
}

impl std::error::Error for ProtocolError {}

impl From<std::io::Error> for ProtocolError {
    fn from(e: std::io::Error) -> Self {
        ProtocolError::Io(e)
    }
}

/// One inference request: run `steps` timesteps of tenant `network` under
/// the canonical seeded Bernoulli stimulus (`seed`, `rate` — the same
/// provider a one-shot `simulate` builds, so responses are comparable
/// bit-for-bit).
#[derive(Clone, Debug, PartialEq)]
pub struct Request {
    pub request_id: u64,
    pub network: String,
    pub steps: u64,
    pub seed: u64,
    pub rate: f64,
}

/// Typed application-level error category carried in an Error response.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ErrorCode {
    /// The named tenant is not admitted on this server.
    UnknownNetwork,
    /// Structurally valid frame, semantically invalid request
    /// (zero/overlong steps, non-finite or out-of-range rate).
    BadRequest,
    /// The frame itself was undecodable (reported back when framing allows).
    Protocol,
    /// Server-side failure unrelated to the request.
    Internal,
}

impl ErrorCode {
    fn to_u8(self) -> u8 {
        match self {
            ErrorCode::UnknownNetwork => 1,
            ErrorCode::BadRequest => 2,
            ErrorCode::Protocol => 3,
            ErrorCode::Internal => 4,
        }
    }

    fn from_u8(v: u8) -> Option<ErrorCode> {
        match v {
            1 => Some(ErrorCode::UnknownNetwork),
            2 => Some(ErrorCode::BadRequest),
            3 => Some(ErrorCode::Protocol),
            4 => Some(ErrorCode::Internal),
            _ => None,
        }
    }
}

/// One response frame. `Ok` carries per-population spike counts in network
/// population order — the same numbers a one-shot `simulate` reports.
#[derive(Clone, Debug, PartialEq)]
pub enum Response {
    Ok { request_id: u64, spike_counts: Vec<u64> },
    Error { request_id: u64, code: ErrorCode, message: String },
    Shutdown { request_id: u64, message: String },
}

impl Response {
    pub fn request_id(&self) -> u64 {
        match self {
            Response::Ok { request_id, .. }
            | Response::Error { request_id, .. }
            | Response::Shutdown { request_id, .. } => *request_id,
        }
    }
}

/// Parsed frame header; validation is split from parsing so a server can
/// report *which* field was wrong before deciding to keep or drop the
/// connection.
#[derive(Clone, Copy, Debug)]
pub struct FrameHeader {
    pub magic: u32,
    pub version: u32,
    pub body_len: u64,
    pub checksum: u64,
}

impl FrameHeader {
    /// Split a raw header; cannot fail (validation is [`FrameHeader::validate`]).
    pub fn parse(bytes: &[u8; HEADER_BYTES]) -> FrameHeader {
        FrameHeader {
            magic: u32::from_le_bytes(bytes[0..4].try_into().unwrap()),
            version: u32::from_le_bytes(bytes[4..8].try_into().unwrap()),
            body_len: u64::from_le_bytes(bytes[8..16].try_into().unwrap()),
            checksum: u64::from_le_bytes(bytes[16..24].try_into().unwrap()),
        }
    }

    /// Magic / version / size-cap checks, in an order that yields the most
    /// specific typed error (wrong magic beats wrong version beats size).
    pub fn validate(&self, want_magic: u32) -> Result<(), ProtocolError> {
        if self.magic != want_magic {
            return Err(ProtocolError::BadMagic { found: self.magic, want: want_magic });
        }
        if self.version != VERSION {
            return Err(ProtocolError::BadVersion { found: self.version, supported: VERSION });
        }
        if self.body_len > MAX_BODY_BYTES {
            return Err(ProtocolError::Oversized { len: self.body_len, max: MAX_BODY_BYTES });
        }
        Ok(())
    }

    /// Body-side checks once the declared payload has been read.
    pub fn verify_body(&self, body: &[u8]) -> Result<(), ProtocolError> {
        if body.len() as u64 != self.body_len {
            return Err(ProtocolError::Truncated {
                what: "frame body",
                need: self.body_len,
                have: body.len() as u64,
            });
        }
        let computed = fnv1a64(body);
        if computed != self.checksum {
            return Err(ProtocolError::ChecksumMismatch { stored: self.checksum, computed });
        }
        Ok(())
    }
}

/// Assemble a complete frame (header + body) for one write.
pub fn frame(magic: u32, body: &[u8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(HEADER_BYTES + body.len());
    out.extend_from_slice(&magic.to_le_bytes());
    out.extend_from_slice(&VERSION.to_le_bytes());
    out.extend_from_slice(&(body.len() as u64).to_le_bytes());
    out.extend_from_slice(&fnv1a64(body).to_le_bytes());
    out.extend_from_slice(body);
    out
}

pub fn encode_request_frame(req: &Request) -> Vec<u8> {
    frame(REQUEST_MAGIC, &encode_request(req))
}

pub fn encode_response_frame(rsp: &Response) -> Vec<u8> {
    frame(RESPONSE_MAGIC, &encode_response(rsp))
}

/// Write a complete frame to `w` (single `write_all`, so a concurrent
/// writer thread never interleaves partial frames).
pub fn write_frame(w: &mut impl Write, magic: u32, body: &[u8]) -> Result<(), ProtocolError> {
    w.write_all(&frame(magic, body))?;
    Ok(())
}

/// Blocking read of one validated frame body. Client-side convenience;
/// the server uses the split [`FrameHeader`] API so its reads can poll a
/// shutdown flag between chunks.
pub fn read_frame(r: &mut impl Read, want_magic: u32) -> Result<Vec<u8>, ProtocolError> {
    let mut hdr = [0u8; HEADER_BYTES];
    read_exact_typed(r, &mut hdr, "frame header")?;
    let header = FrameHeader::parse(&hdr);
    header.validate(want_magic)?;
    let mut body = vec![0u8; header.body_len as usize];
    read_exact_typed(r, &mut body, "frame body")?;
    header.verify_body(&body)?;
    Ok(body)
}

fn read_exact_typed(
    r: &mut impl Read,
    buf: &mut [u8],
    what: &'static str,
) -> Result<(), ProtocolError> {
    r.read_exact(buf).map_err(|e| {
        if e.kind() == std::io::ErrorKind::UnexpectedEof {
            ProtocolError::Truncated { what, need: buf.len() as u64, have: 0 }
        } else {
            ProtocolError::Io(e)
        }
    })
}

pub fn encode_request(req: &Request) -> Vec<u8> {
    let name = req.network.as_bytes();
    let mut out = Vec::with_capacity(40 + name.len());
    out.extend_from_slice(&req.request_id.to_le_bytes());
    out.extend_from_slice(&(name.len() as u64).to_le_bytes());
    out.extend_from_slice(name);
    out.extend_from_slice(&req.steps.to_le_bytes());
    out.extend_from_slice(&req.seed.to_le_bytes());
    out.extend_from_slice(&req.rate.to_bits().to_le_bytes());
    out
}

pub fn decode_request(body: &[u8]) -> Result<Request, ProtocolError> {
    let mut r = Reader::new(body, "request body");
    let request_id = r.u64()?;
    let name_len = r.u64()?;
    if name_len > MAX_NAME_BYTES {
        return Err(ProtocolError::Malformed {
            what: "request body",
            detail: format!("network name of {name_len} bytes exceeds the {MAX_NAME_BYTES} cap"),
        });
    }
    let name = r.bytes(name_len)?;
    let network = String::from_utf8(name.to_vec()).map_err(|_| ProtocolError::Malformed {
        what: "request body",
        detail: "network name is not valid utf-8".to_string(),
    })?;
    let steps = r.u64()?;
    let seed = r.u64()?;
    let rate = f64::from_bits(r.u64()?);
    r.finish()?;
    Ok(Request { request_id, network, steps, seed, rate })
}

pub fn encode_response(rsp: &Response) -> Vec<u8> {
    let mut out = Vec::with_capacity(64);
    out.extend_from_slice(&rsp.request_id().to_le_bytes());
    match rsp {
        Response::Ok { spike_counts, .. } => {
            out.push(0);
            out.extend_from_slice(&(spike_counts.len() as u64).to_le_bytes());
            for c in spike_counts {
                out.extend_from_slice(&c.to_le_bytes());
            }
        }
        Response::Error { code, message, .. } => {
            out.push(1);
            out.push(code.to_u8());
            out.extend_from_slice(&(message.len() as u64).to_le_bytes());
            out.extend_from_slice(message.as_bytes());
        }
        Response::Shutdown { message, .. } => {
            out.push(2);
            out.extend_from_slice(&(message.len() as u64).to_le_bytes());
            out.extend_from_slice(message.as_bytes());
        }
    }
    out
}

pub fn decode_response(body: &[u8]) -> Result<Response, ProtocolError> {
    let mut r = Reader::new(body, "response body");
    let request_id = r.u64()?;
    let tag = r.u8()?;
    let rsp = match tag {
        0 => {
            let n = r.u64()?;
            if n > MAX_BODY_BYTES / 8 {
                return Err(ProtocolError::Malformed {
                    what: "response body",
                    detail: format!("{n} spike counts exceed the frame cap"),
                });
            }
            let mut spike_counts = Vec::with_capacity(n as usize);
            for _ in 0..n {
                spike_counts.push(r.u64()?);
            }
            Response::Ok { request_id, spike_counts }
        }
        1 => {
            let code = r.u8()?;
            let code = ErrorCode::from_u8(code).ok_or_else(|| ProtocolError::Malformed {
                what: "response body",
                detail: format!("unknown error code {code}"),
            })?;
            let message = r.string()?;
            Response::Error { request_id, code, message }
        }
        2 => {
            let message = r.string()?;
            Response::Shutdown { request_id, message }
        }
        t => {
            return Err(ProtocolError::Malformed {
                what: "response body",
                detail: format!("unknown response tag {t}"),
            })
        }
    };
    r.finish()?;
    Ok(rsp)
}

/// Bounds-checked little-endian reader over one frame body (the socket
/// sibling of the artifact codec's `Dec`): every read names what it
/// wanted, so truncation errors are self-describing.
struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
    what: &'static str,
}

impl<'a> Reader<'a> {
    fn new(buf: &'a [u8], what: &'static str) -> Self {
        Reader { buf, pos: 0, what }
    }

    fn bytes(&mut self, n: u64) -> Result<&'a [u8], ProtocolError> {
        let n = n as usize;
        let have = self.buf.len() - self.pos;
        if n > have {
            return Err(ProtocolError::Truncated {
                what: self.what,
                need: n as u64,
                have: have as u64,
            });
        }
        let out = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(out)
    }

    fn u8(&mut self) -> Result<u8, ProtocolError> {
        Ok(self.bytes(1)?[0])
    }

    fn u64(&mut self) -> Result<u64, ProtocolError> {
        Ok(u64::from_le_bytes(self.bytes(8)?.try_into().unwrap()))
    }

    fn string(&mut self) -> Result<String, ProtocolError> {
        let len = self.u64()?;
        if len > MAX_BODY_BYTES {
            return Err(ProtocolError::Malformed {
                what: self.what,
                detail: format!("string of {len} bytes exceeds the frame cap"),
            });
        }
        let raw = self.bytes(len)?;
        String::from_utf8(raw.to_vec()).map_err(|_| ProtocolError::Malformed {
            what: self.what,
            detail: "string is not valid utf-8".to_string(),
        })
    }

    /// Reject trailing garbage — a frame must be *exactly* its payload.
    fn finish(self) -> Result<(), ProtocolError> {
        if self.pos != self.buf.len() {
            return Err(ProtocolError::Malformed {
                what: self.what,
                detail: format!("{} trailing bytes after payload", self.buf.len() - self.pos),
            });
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req() -> Request {
        Request {
            request_id: 7,
            network: "mnist-lite".to_string(),
            steps: 40,
            seed: 1234,
            rate: 0.25,
        }
    }

    #[test]
    fn request_roundtrip() {
        let body = encode_request(&req());
        assert_eq!(decode_request(&body).unwrap(), req());
    }

    #[test]
    fn response_roundtrips() {
        let cases = vec![
            Response::Ok { request_id: 1, spike_counts: vec![0, 9, 312] },
            Response::Error {
                request_id: 2,
                code: ErrorCode::UnknownNetwork,
                message: "no tenant 'x'".to_string(),
            },
            Response::Shutdown { request_id: 3, message: "draining".to_string() },
        ];
        for rsp in cases {
            let body = encode_response(&rsp);
            assert_eq!(decode_response(&body).unwrap(), rsp, "roundtrip of {rsp:?}");
        }
    }

    #[test]
    fn frame_roundtrip_through_read_frame() {
        let bytes = encode_request_frame(&req());
        let body = read_frame(&mut bytes.as_slice(), REQUEST_MAGIC).unwrap();
        assert_eq!(decode_request(&body).unwrap(), req());
    }

    #[test]
    fn every_truncation_point_is_a_typed_error() {
        let bytes = encode_request_frame(&req());
        for cut in 0..bytes.len() {
            let err = read_frame(&mut &bytes[..cut], REQUEST_MAGIC)
                .expect_err("truncated frame must not decode");
            assert!(
                matches!(err, ProtocolError::Truncated { .. } | ProtocolError::Io(_)),
                "cut at {cut}: got {err}"
            );
        }
    }

    #[test]
    fn bad_magic_is_typed() {
        let mut bytes = encode_request_frame(&req());
        bytes[0] ^= 0xFF;
        let err = read_frame(&mut bytes.as_slice(), REQUEST_MAGIC).unwrap_err();
        assert!(matches!(err, ProtocolError::BadMagic { .. }), "{err}");
        // Response magic on the request side is the same typed failure.
        let swapped = encode_response_frame(&Response::Shutdown {
            request_id: 0,
            message: String::new(),
        });
        let err = read_frame(&mut swapped.as_slice(), REQUEST_MAGIC).unwrap_err();
        assert!(matches!(err, ProtocolError::BadMagic { .. }), "{err}");
    }

    #[test]
    fn version_mismatch_is_typed() {
        let mut bytes = encode_request_frame(&req());
        bytes[4..8].copy_from_slice(&99u32.to_le_bytes());
        let err = read_frame(&mut bytes.as_slice(), REQUEST_MAGIC).unwrap_err();
        assert!(
            matches!(err, ProtocolError::BadVersion { found: 99, supported: VERSION }),
            "{err}"
        );
    }

    #[test]
    fn oversized_declared_body_is_typed() {
        let mut bytes = encode_request_frame(&req());
        bytes[8..16].copy_from_slice(&(MAX_BODY_BYTES + 1).to_le_bytes());
        let err = read_frame(&mut bytes.as_slice(), REQUEST_MAGIC).unwrap_err();
        assert!(matches!(err, ProtocolError::Oversized { .. }), "{err}");
    }

    #[test]
    fn corrupt_body_is_a_checksum_mismatch() {
        let mut bytes = encode_request_frame(&req());
        let last = bytes.len() - 1;
        bytes[last] ^= 0x01;
        let err = read_frame(&mut bytes.as_slice(), REQUEST_MAGIC).unwrap_err();
        assert!(matches!(err, ProtocolError::ChecksumMismatch { .. }), "{err}");
    }

    #[test]
    fn malformed_payloads_are_typed() {
        // Overlong name length.
        let mut body = Vec::new();
        body.extend_from_slice(&1u64.to_le_bytes());
        body.extend_from_slice(&(MAX_NAME_BYTES + 1).to_le_bytes());
        let err = decode_request(&body).unwrap_err();
        assert!(matches!(err, ProtocolError::Malformed { .. }), "{err}");
        // Trailing garbage after a valid request.
        let mut ok = encode_request(&req());
        ok.push(0xAB);
        let err = decode_request(&ok).unwrap_err();
        assert!(matches!(err, ProtocolError::Malformed { .. }), "{err}");
        // Unknown response tag.
        let mut rsp = Vec::new();
        rsp.extend_from_slice(&1u64.to_le_bytes());
        rsp.push(9);
        let err = decode_response(&rsp).unwrap_err();
        assert!(matches!(err, ProtocolError::Malformed { .. }), "{err}");
        // Unknown error code.
        let mut rsp = Vec::new();
        rsp.extend_from_slice(&1u64.to_le_bytes());
        rsp.push(1);
        rsp.push(200);
        rsp.extend_from_slice(&0u64.to_le_bytes());
        let err = decode_response(&rsp).unwrap_err();
        assert!(matches!(err, ProtocolError::Malformed { .. }), "{err}");
    }

    #[test]
    fn header_layout_matches_artifact_codec_conventions() {
        let bytes = encode_request_frame(&req());
        assert_eq!(&bytes[0..4], b"S2RQ");
        let h = FrameHeader::parse(bytes[..HEADER_BYTES].try_into().unwrap());
        assert_eq!(h.version, VERSION);
        assert_eq!(h.body_len as usize, bytes.len() - HEADER_BYTES);
        assert_eq!(h.checksum, fnv1a64(&bytes[HEADER_BYTES..]));
    }
}
