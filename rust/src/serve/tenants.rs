//! Multi-tenant registry: boot every network onto **one shared machine**.
//!
//! Tenants are admitted sequentially in sorted-name order through the
//! existing capacity-aware admission path, with an *occupancy* fault map
//! threaded between admissions: after each tenant is placed, its PEs are
//! marked dead for everyone after it. That reuses the whole fault-aware
//! machinery — per-board headroom shrinking, paradigm capacity fallback,
//! routing around unusable PEs — to get genuine co-placement: tenant
//! placements are provably disjoint (tested in `tests/serve.rs`), and a
//! tenant that does not fit what is left fails with the same typed
//! capacity diagnostics a too-small machine produces.
//!
//! Warm boot: with an artifact directory attached to the
//! [`SwitchingSystem`], every admission materializes from the disk tier —
//! [`BootReport::compiles`] stays 0 and [`BootReport::disk_hits`] counts
//! the artifact loads (asserted by `--require-warm` and CI).

use crate::graph::PartitionStrategy;
use crate::hardware::{FaultMap, MachineSpec, PeHandle, PlacementStrategy};
use crate::model::Network;
use crate::switching::{CompiledLayer, LayerDecision, SwitchingSystem};
use anyhow::{bail, Context, Result};
use std::collections::BTreeSet;
use std::time::Instant;

/// One network to admit, by name. Names are the wire-protocol routing key.
pub struct TenantSpec {
    pub name: String,
    pub net: Network,
}

/// A booted tenant: its network, compiled layers, and the machine share it
/// occupies.
pub struct Tenant {
    pub name: String,
    pub net: Network,
    pub layers: Vec<CompiledLayer>,
    pub decisions: Vec<LayerDecision>,
    /// PEs this tenant's placement occupies (disjoint across tenants).
    pub pes: Vec<PeHandle>,
}

impl Tenant {
    /// Population sizes in network order (the stimulus provider's shape).
    pub fn pop_sizes(&self) -> Vec<usize> {
        self.net.populations.iter().map(|p| p.n_neurons).collect()
    }
}

/// Boot accounting: what admission cost and whether it was warm.
#[derive(Clone, Debug)]
pub struct BootReport {
    pub tenants: usize,
    pub boot_nanos: u64,
    /// Materializing compiles across all admissions (0 on a warm store).
    pub compiles: usize,
    /// In-memory compile-cache hits.
    pub cache_hits: usize,
    /// Artifact-store (disk tier) hits.
    pub disk_hits: usize,
    /// PEs occupied across all tenants.
    pub placed_pes: usize,
    /// Machine capacity the tenants share.
    pub machine_pes: usize,
}

impl BootReport {
    /// Zero materializing compiles and at least one artifact load: the
    /// boot was served entirely from the persistent store.
    pub fn is_warm(&self) -> bool {
        self.compiles == 0 && self.disk_hits > 0
    }
}

/// The admitted tenant set plus its boot accounting.
pub struct TenantRegistry {
    pub tenants: Vec<Tenant>,
    pub report: BootReport,
}

impl TenantRegistry {
    /// Admit `specs` as co-tenants of one `mspec` machine. Single-board
    /// machines go through `admit_network_faulted`; board arrays through
    /// `admit_network_sharded_faulted` with `partition`. Admission order is
    /// sorted by name, so the co-placement (and therefore every compiled
    /// artifact and every response) is independent of caller order.
    pub fn boot(
        specs: Vec<TenantSpec>,
        sys: &mut SwitchingSystem,
        mspec: MachineSpec,
        strategy: PlacementStrategy,
        partition: PartitionStrategy,
    ) -> Result<TenantRegistry> {
        if specs.is_empty() {
            bail!("no tenant networks to serve (give --networks a directory of .json networks)");
        }
        let mut names = BTreeSet::new();
        for s in &specs {
            if s.name.is_empty() {
                bail!("tenant network with an empty name");
            }
            if !names.insert(s.name.clone()) {
                bail!("duplicate tenant network name '{}'", s.name);
            }
        }
        let mut specs = specs;
        specs.sort_by(|a, b| a.name.cmp(&b.name));

        let t0 = Instant::now();
        let mut occupancy = FaultMap::healthy();
        let mut tenants = Vec::with_capacity(specs.len());
        let mut placed_pes = 0usize;
        for spec in specs {
            let admitted = if mspec.boards > 1 {
                sys.admit_network_sharded_faulted(&spec.net, mspec, strategy, partition, &occupancy)
                    .map(|s| s.admission)
            } else {
                sys.admit_network_faulted(&spec.net, mspec, strategy, &occupancy)
            };
            let admission = admitted.with_context(|| {
                format!(
                    "admitting tenant '{}' as co-tenant ({placed_pes} of {} PEs already occupied)",
                    spec.name,
                    mspec.total_pes()
                )
            })?;
            let pes: Vec<PeHandle> =
                admission.placement.graph.vertices.iter().filter_map(|v| v.pe).collect();
            for pe in &pes {
                occupancy.kill_pe(*pe);
            }
            placed_pes += admission.placement.n_pes();
            tenants.push(Tenant {
                name: spec.name,
                net: spec.net,
                layers: admission.layers,
                decisions: admission.decisions,
                pes,
            });
        }
        let stats = sys.stats;
        let report = BootReport {
            tenants: tenants.len(),
            boot_nanos: t0.elapsed().as_nanos() as u64,
            compiles: stats.total_compiles(),
            cache_hits: stats.cache_hits,
            disk_hits: stats.disk_hits,
            placed_pes,
            machine_pes: mspec.total_pes(),
        };
        Ok(TenantRegistry { tenants, report })
    }

    pub fn get(&self, name: &str) -> Option<&Tenant> {
        self.tenants.iter().find(|t| t.name == name)
    }
}
