//! Network-from-JSON configuration — the launcher's model description
//! format.
//!
//! ```json
//! {
//!   "seed": 7,
//!   "populations": [
//!     {"label": "in",  "n": 300, "kind": "spike_source"},
//!     {"label": "hid", "n": 200, "kind": "lif", "alpha": 0.9, "v_th": 1.0,
//!      "t_refrac": 0, "record_v": false}
//!   ],
//!   "projections": [
//!     {"source": "in", "target": "hid", "connector": "fixed_probability",
//!      "p": 0.3, "delay_range": 4, "w_min": 1, "w_max": 100,
//!      "weight_scale": 0.01, "inhibitory": false}
//!   ]
//! }
//! ```
//!
//! Supported connectors: `all_to_all`, `one_to_one`,
//! `fixed_probability` (requires `p`).

use super::connector::{Connector, SynapseDraw};
use super::network::{Network, NetworkBuilder};
use super::population::PopulationId;
use super::projection::SynapseType;
use super::LifParams;
use crate::io::Json;
use anyhow::{anyhow, bail, Context, Result};
use std::collections::BTreeMap;

fn get_f64(j: &Json, key: &str, default: f64) -> f64 {
    j.get(key).and_then(Json::as_f64).unwrap_or(default)
}

/// Parse a network description (see module docs) into a [`Network`].
pub fn network_from_json(text: &str) -> Result<Network> {
    let j = Json::parse(text).map_err(|e| anyhow!("config parse error: {e}"))?;
    let seed = get_f64(&j, "seed", 1.0) as u64;
    let mut b = NetworkBuilder::new(seed);
    let mut by_label: BTreeMap<String, PopulationId> = BTreeMap::new();

    let pops = j
        .get("populations")
        .and_then(Json::as_arr)
        .context("config needs a 'populations' array")?;
    for p in pops {
        let label = p
            .get("label")
            .and_then(Json::as_str)
            .context("population needs a 'label'")?
            .to_string();
        let n = p
            .get("n")
            .and_then(Json::as_usize)
            .context("population needs integer 'n'")?;
        let kind = p.get("kind").and_then(Json::as_str).unwrap_or("lif");
        let id = match kind {
            "spike_source" => b.spike_source(&label, n),
            "lif" => {
                let params = LifParams {
                    alpha: get_f64(p, "alpha", 0.9) as f32,
                    v_th: get_f64(p, "v_th", 1.0) as f32,
                    v_rest: get_f64(p, "v_rest", 0.0) as f32,
                    t_refrac: get_f64(p, "t_refrac", 0.0) as u32,
                    i_offset: get_f64(p, "i_offset", 0.0) as f32,
                    v_init: get_f64(p, "v_init", 0.0) as f32,
                    ..Default::default()
                };
                b.lif_population(&label, n, params)
            }
            other => bail!("unknown population kind '{other}'"),
        };
        if by_label.insert(label.clone(), id).is_some() {
            bail!("duplicate population label '{label}'");
        }
    }

    let projs = j.get("projections").and_then(Json::as_arr).unwrap_or(&[]);
    for p in projs {
        let src_label = p
            .get("source")
            .and_then(Json::as_str)
            .context("projection needs 'source'")?;
        let tgt_label = p
            .get("target")
            .and_then(Json::as_str)
            .context("projection needs 'target'")?;
        let src = *by_label
            .get(src_label)
            .with_context(|| format!("unknown population '{src_label}'"))?;
        let tgt = *by_label
            .get(tgt_label)
            .with_context(|| format!("unknown population '{tgt_label}'"))?;
        let connector = match p.get("connector").and_then(Json::as_str).unwrap_or("all_to_all")
        {
            "all_to_all" => Connector::AllToAll,
            "one_to_one" => Connector::OneToOne,
            "fixed_probability" => Connector::FixedProbability(
                p.get("p")
                    .and_then(Json::as_f64)
                    .context("fixed_probability connector needs 'p'")?,
            ),
            other => bail!("unknown connector '{other}'"),
        };
        let draw = SynapseDraw {
            w_min: get_f64(p, "w_min", 1.0) as u8,
            w_max: get_f64(p, "w_max", 127.0) as u8,
            delay_range: get_f64(p, "delay_range", 1.0) as u16,
            syn_type: if p.get("inhibitory").and_then(Json::as_bool).unwrap_or(false) {
                SynapseType::Inhibitory
            } else {
                SynapseType::Excitatory
            },
        };
        let weight_scale = get_f64(p, "weight_scale", 0.01) as f32;
        b.project(src, tgt, connector, draw, weight_scale);
    }

    Ok(b.build())
}

#[cfg(test)]
mod tests {
    use super::*;

    const DEMO: &str = r#"{
        "seed": 9,
        "populations": [
            {"label": "in", "n": 40, "kind": "spike_source"},
            {"label": "hid", "n": 30, "kind": "lif", "alpha": 0.85},
            {"label": "out", "n": 5, "kind": "lif", "t_refrac": 2}
        ],
        "projections": [
            {"source": "in", "target": "hid", "connector": "fixed_probability",
             "p": 0.4, "delay_range": 3, "w_max": 100, "weight_scale": 0.02},
            {"source": "hid", "target": "out", "connector": "all_to_all",
             "delay_range": 2, "weight_scale": 0.05, "inhibitory": true}
        ]
    }"#;

    #[test]
    fn demo_config_builds() {
        let net = network_from_json(DEMO).unwrap();
        assert_eq!(net.populations.len(), 3);
        assert_eq!(net.projections.len(), 2);
        assert!(net.populations[0].is_source());
        assert_eq!(net.populations[1].lif_params().unwrap().alpha, 0.85);
        assert_eq!(net.populations[2].lif_params().unwrap().t_refrac, 2);
        assert_eq!(net.projections[1].synapses.len(), 150);
        assert!(net.projections[1]
            .synapses
            .iter()
            .all(|s| s.syn_type == SynapseType::Inhibitory));
    }

    #[test]
    fn same_config_same_network() {
        let a = network_from_json(DEMO).unwrap();
        let b = network_from_json(DEMO).unwrap();
        assert_eq!(a.projections[0].synapses, b.projections[0].synapses);
    }

    #[test]
    fn helpful_errors() {
        assert!(network_from_json("{").is_err());
        assert!(network_from_json(r#"{"populations": [{"n": 3}]}"#).is_err());
        let bad_ref = r#"{"populations": [{"label": "a", "n": 2}],
                          "projections": [{"source": "a", "target": "zzz"}]}"#;
        let err = network_from_json(bad_ref).unwrap_err().to_string();
        assert!(err.contains("zzz"), "error should name the missing population: {err}");
        let dup = r#"{"populations": [{"label": "a", "n": 2}, {"label": "a", "n": 3}]}"#;
        assert!(network_from_json(dup).is_err());
        let bad_conn = r#"{"populations": [{"label": "a", "n": 2}],
                           "projections": [{"source": "a", "target": "a",
                                            "connector": "magic"}]}"#;
        assert!(network_from_json(bad_conn).is_err());
    }
}
