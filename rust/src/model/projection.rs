//! Projections — the edges of the application graph.
//!
//! A projection connects a source population to a target population with a
//! list of synapses. Each synapse carries the fields the serial paradigm's
//! synaptic-matrix rows store (paper §III-A): weight, delay, synapse type
//! (excitatory/inhibitory) and target neuron index; the source index is the
//! row key.

use super::population::PopulationId;

/// Index of a projection within a [`crate::model::Network`].
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct ProjectionId(pub usize);

/// Excitatory or inhibitory (the paper's two projection types;
/// `n_projection_type = 2` in Table I).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum SynapseType {
    Excitatory,
    Inhibitory,
}

impl SynapseType {
    pub const COUNT: usize = 2;

    pub fn index(self) -> usize {
        match self {
            SynapseType::Excitatory => 0,
            SynapseType::Inhibitory => 1,
        }
    }
}

/// One synapse. Weights are kept as quantized 8-bit magnitudes (the paper's
/// experiments use 8-bit weights) with a per-projection scale; delay is in
/// timesteps, 1-based like sPyNNaker (a spike at t affects the target at
/// t + delay).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Synapse {
    pub source: u32,
    pub target: u32,
    /// Quantized weight magnitude (0..=255).
    pub weight: u8,
    /// Delay in timesteps, 1..=delay_range.
    pub delay: u16,
    pub syn_type: SynapseType,
}

/// A source→target edge carrying its synapse list.
#[derive(Clone, Debug)]
pub struct Projection {
    pub id: ProjectionId,
    pub source: PopulationId,
    pub target: PopulationId,
    pub synapses: Vec<Synapse>,
    /// Weight dequantization scale: effective weight = weight * scale.
    pub weight_scale: f32,
}

impl Projection {
    /// Maximum delay used by any synapse (the layer's delay range).
    pub fn delay_range(&self) -> u16 {
        self.synapses.iter().map(|s| s.delay).max().unwrap_or(1)
    }

    /// Fraction of possible (source, target) pairs that have a synapse.
    pub fn density(&self, n_source: usize, n_target: usize) -> f64 {
        if n_source == 0 || n_target == 0 {
            return 0.0;
        }
        // Count distinct (source,target) pairs; multiple synapses per pair
        // (multapses) are rare in our generators but guard anyway.
        let mut pairs: Vec<(u32, u32)> = self.synapses.iter().map(|s| (s.source, s.target)).collect();
        pairs.sort_unstable();
        pairs.dedup();
        pairs.len() as f64 / (n_source as f64 * n_target as f64)
    }

    /// Per-source-neuron synapse counts (serial paradigm's row lengths).
    pub fn row_lengths(&self, n_source: usize) -> Vec<u32> {
        let mut rows = vec![0u32; n_source];
        for s in &self.synapses {
            rows[s.source as usize] += 1;
        }
        rows
    }

    /// Maximum row length (drives the serial synaptic-matrix row pitch).
    pub fn max_row_length(&self, n_source: usize) -> u32 {
        self.row_lengths(n_source).into_iter().max().unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn syn(s: u32, t: u32, d: u16) -> Synapse {
        Synapse { source: s, target: t, weight: 10, delay: d, syn_type: SynapseType::Excitatory }
    }

    #[test]
    fn delay_range_is_max() {
        let p = Projection {
            id: ProjectionId(0),
            source: PopulationId(0),
            target: PopulationId(1),
            synapses: vec![syn(0, 0, 1), syn(0, 1, 5), syn(1, 0, 3)],
            weight_scale: 1.0,
        };
        assert_eq!(p.delay_range(), 5);
    }

    #[test]
    fn density_counts_distinct_pairs() {
        let p = Projection {
            id: ProjectionId(0),
            source: PopulationId(0),
            target: PopulationId(1),
            synapses: vec![syn(0, 0, 1), syn(0, 0, 2), syn(1, 1, 1)],
            weight_scale: 1.0,
        };
        // (0,0) duplicated → 2 distinct pairs of 4 possible.
        assert!((p.density(2, 2) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn row_lengths_and_max() {
        let p = Projection {
            id: ProjectionId(0),
            source: PopulationId(0),
            target: PopulationId(1),
            synapses: vec![syn(0, 0, 1), syn(0, 1, 1), syn(2, 0, 1)],
            weight_scale: 1.0,
        };
        assert_eq!(p.row_lengths(3), vec![2, 0, 1]);
        assert_eq!(p.max_row_length(3), 2);
    }

    #[test]
    fn empty_projection_defaults() {
        let p = Projection {
            id: ProjectionId(0),
            source: PopulationId(0),
            target: PopulationId(1),
            synapses: vec![],
            weight_scale: 1.0,
        };
        assert_eq!(p.delay_range(), 1);
        assert_eq!(p.density(10, 10), 0.0);
        assert_eq!(p.max_row_length(10), 0);
    }
}
