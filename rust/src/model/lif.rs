//! Leaky integrate-and-fire dynamics (paper Eq. 1, after ref [15]):
//!
//! ```text
//! V_i^{t+1} = Σ_j W_ji · x_j^{t-d(j,i)} + α · V_i^t − z_i^t · V_th
//! ```
//!
//! A neuron spikes when its updated membrane potential reaches `v_th`; the
//! subtractive reset (−z·V_th) follows the paper's formulation.
//!
//! This module is the *reference semantics* shared by the serial engine, the
//! parallel engine, and the L1/L2 JAX artifacts — all three must agree with
//! [`lif_step`] exactly (the pytest oracle `ref.py` mirrors this formula).

/// LIF neuron + synapse parameters.
///
/// Table I charges `(32/8)*n_param` with `n_param = 8 + 6` (8 neuron + 6
/// synapse parameters) for the "neuron and synapse model" entry; the fields
/// here are the 8 neuron parameters, and the 6 synapse-model parameters are
/// the per-projection-type decay/scale constants kept with the projection.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct LifParams {
    /// Membrane leak factor α per timestep (0 < α ≤ 1).
    pub alpha: f32,
    /// Spike threshold.
    pub v_th: f32,
    /// Reset potential offset (subtractive reset uses v_th; this field
    /// supports the clamp-to-rest variant).
    pub v_rest: f32,
    /// Refractory period in timesteps (0 = none).
    pub t_refrac: u32,
    /// Constant bias current added each step.
    pub i_offset: f32,
    /// Initial membrane potential.
    pub v_init: f32,
    /// Excitatory input scale.
    pub w_exc_scale: f32,
    /// Inhibitory input scale.
    pub w_inh_scale: f32,
}

impl Default for LifParams {
    fn default() -> Self {
        LifParams {
            alpha: 0.9,
            v_th: 1.0,
            v_rest: 0.0,
            t_refrac: 0,
            i_offset: 0.0,
            v_init: 0.0,
            w_exc_scale: 1.0,
            w_inh_scale: 1.0,
        }
    }
}

impl LifParams {
    /// Number of neuron-model parameters (Table I's "8").
    pub const N_NEURON_PARAMS: usize = 8;
    /// Number of synapse-model parameters (Table I's "6").
    pub const N_SYNAPSE_PARAMS: usize = 6;
}

/// One reference LIF step for a single neuron.
///
/// `input` is the already-delay-resolved synaptic input current
/// (excitatory − inhibitory, scaled); returns `(v_next, spiked)`.
#[inline]
pub fn lif_step(p: &LifParams, v: f32, input: f32, refrac_left: u32) -> (f32, bool, u32) {
    if refrac_left > 0 {
        // Hold at rest during refractory period; input is discarded.
        return (p.v_rest, false, refrac_left - 1);
    }
    let v_new = input + p.alpha * v + p.i_offset;
    if v_new >= p.v_th {
        // Subtractive reset per Eq. (1): v − z·V_th with z = 1.
        (v_new - p.v_th, true, p.t_refrac)
    } else {
        (v_new, false, 0)
    }
}

/// Vectorized reference step over a population (used by tests as the oracle
/// for both execution engines and mirrored by python/compile/kernels/ref.py).
pub fn lif_step_batch(
    p: &LifParams,
    v: &mut [f32],
    input: &[f32],
    refrac: &mut [u32],
    spikes_out: &mut Vec<u32>,
) {
    assert_eq!(v.len(), input.len());
    assert_eq!(v.len(), refrac.len());
    spikes_out.clear();
    for i in 0..v.len() {
        let (vn, spiked, r) = lif_step(p, v[i], input[i], refrac[i]);
        v[i] = vn;
        refrac[i] = r;
        if spiked {
            spikes_out.push(i as u32);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn subthreshold_decays() {
        let p = LifParams::default();
        let (v, spiked, _) = lif_step(&p, 0.5, 0.0, 0);
        assert!(!spiked);
        assert!((v - 0.45).abs() < 1e-6);
    }

    #[test]
    fn threshold_crossing_spikes_and_subtractive_reset() {
        let p = LifParams::default();
        let (v, spiked, _) = lif_step(&p, 0.5, 0.8, 0);
        assert!(spiked);
        // v_new = 0.8 + 0.45 = 1.25 >= 1.0 → reset to 0.25
        assert!((v - 0.25).abs() < 1e-6);
    }

    #[test]
    fn refractory_holds_and_counts_down() {
        let p = LifParams { t_refrac: 2, ..Default::default() };
        let (v, s, r) = lif_step(&p, 0.3, 100.0, 2);
        assert!(!s);
        assert_eq!(v, p.v_rest);
        assert_eq!(r, 1);
        let (_, s2, r2) = lif_step(&p, v, 100.0, r);
        assert!(!s2);
        assert_eq!(r2, 0);
        // Out of refractory: fires again.
        let (_, s3, r3) = lif_step(&p, 0.0, 100.0, 0);
        assert!(s3);
        assert_eq!(r3, p.t_refrac);
    }

    #[test]
    fn batch_matches_scalar() {
        let p = LifParams::default();
        let mut v = vec![0.0, 0.5, 0.99, 2.0];
        let input = vec![0.1, 0.2, 0.3, 0.0];
        let mut refrac = vec![0, 0, 0, 0];
        let mut spikes = Vec::new();
        let v0 = v.clone();
        lif_step_batch(&p, &mut v, &input, &mut refrac, &mut spikes);
        for i in 0..4 {
            let (vs, sp, _) = lif_step(&p, v0[i], input[i], 0);
            assert_eq!(v[i], vs);
            assert_eq!(spikes.contains(&(i as u32)), sp);
        }
    }

    #[test]
    fn bias_current_accumulates_to_spike() {
        let p = LifParams { i_offset: 0.3, alpha: 1.0, ..Default::default() };
        let mut v = 0.0;
        let mut fired_at = None;
        for t in 0..10 {
            let (vn, sp, _) = lif_step(&p, v, 0.0, 0);
            v = vn;
            if sp {
                fired_at = Some(t);
                break;
            }
        }
        // 0.3/step with no leak → crosses 1.0 on step 3 (v=1.2).
        assert_eq!(fired_at, Some(3));
    }
}
