//! Leaky integrate-and-fire dynamics (paper Eq. 1, after ref [15]):
//!
//! ```text
//! V_i^{t+1} = Σ_j W_ji · x_j^{t-d(j,i)} + α · V_i^t − z_i^t · V_th
//! ```
//!
//! A neuron spikes when its updated membrane potential reaches `v_th`; the
//! subtractive reset (−z·V_th) follows the paper's formulation.
//!
//! This module is the *reference semantics* shared by the serial engine, the
//! parallel engine, and the L1/L2 JAX artifacts — all three must agree with
//! [`lif_step`] exactly (the pytest oracle `ref.py` mirrors this formula).

/// LIF neuron + synapse parameters.
///
/// Table I charges `(32/8)*n_param` with `n_param = 8 + 6` (8 neuron + 6
/// synapse parameters) for the "neuron and synapse model" entry; the fields
/// here are the 8 neuron parameters, and the 6 synapse-model parameters are
/// the per-projection-type decay/scale constants kept with the projection.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct LifParams {
    /// Membrane leak factor α per timestep (0 < α ≤ 1).
    pub alpha: f32,
    /// Spike threshold.
    pub v_th: f32,
    /// Reset potential offset (subtractive reset uses v_th; this field
    /// supports the clamp-to-rest variant).
    pub v_rest: f32,
    /// Refractory period in timesteps (0 = none).
    pub t_refrac: u32,
    /// Constant bias current added each step.
    pub i_offset: f32,
    /// Initial membrane potential.
    pub v_init: f32,
    /// Excitatory input scale.
    pub w_exc_scale: f32,
    /// Inhibitory input scale.
    pub w_inh_scale: f32,
}

impl Default for LifParams {
    fn default() -> Self {
        LifParams {
            alpha: 0.9,
            v_th: 1.0,
            v_rest: 0.0,
            t_refrac: 0,
            i_offset: 0.0,
            v_init: 0.0,
            w_exc_scale: 1.0,
            w_inh_scale: 1.0,
        }
    }
}

impl LifParams {
    /// Number of neuron-model parameters (Table I's "8").
    pub const N_NEURON_PARAMS: usize = 8;
    /// Number of synapse-model parameters (Table I's "6").
    pub const N_SYNAPSE_PARAMS: usize = 6;
}

/// One reference LIF step for a single neuron.
///
/// `input` is the already-delay-resolved synaptic input current
/// (excitatory − inhibitory, scaled); returns `(v_next, spiked)`.
#[inline]
pub fn lif_step(p: &LifParams, v: f32, input: f32, refrac_left: u32) -> (f32, bool, u32) {
    if refrac_left > 0 {
        // Hold at rest during refractory period; input is discarded.
        return (p.v_rest, false, refrac_left - 1);
    }
    let v_new = input + p.alpha * v + p.i_offset;
    if v_new >= p.v_th {
        // Subtractive reset per Eq. (1): v − z·V_th with z = 1.
        (v_new - p.v_th, true, p.t_refrac)
    } else {
        (v_new, false, 0)
    }
}

/// Vectorized reference step over a population (used by tests as the oracle
/// for both execution engines and mirrored by python/compile/kernels/ref.py).
pub fn lif_step_batch(
    p: &LifParams,
    v: &mut [f32],
    input: &[f32],
    refrac: &mut [u32],
    spikes_out: &mut Vec<u32>,
) {
    assert_eq!(v.len(), input.len());
    assert_eq!(v.len(), refrac.len());
    spikes_out.clear();
    for i in 0..v.len() {
        let (vn, spiked, r) = lif_step(p, v[i], input[i], refrac[i]);
        v[i] = vn;
        refrac[i] = r;
        if spiked {
            spikes_out.push(i as u32);
        }
    }
}

/// Chunk width of [`lif_step_chunked`]: the spike mask is collected per
/// 16-neuron window, so the inner loop carries no `Vec::push` branch — and
/// the window is exactly one `f32x16` vector for the explicit-SIMD kernel.
pub const LIF_CHUNK: usize = 16;

/// Which kernel implementation [`lif_step_chunked`] (and the native MAC
/// backend) dispatches to in this build: `"simd"` under the `simd` cargo
/// feature (`std::simd`, 16-lane f32), `"scalar"` otherwise.
pub fn kernel_variant() -> &'static str {
    if cfg!(feature = "simd") {
        "simd"
    } else {
        "scalar"
    }
}

/// The production LIF kernel: dispatches to the explicit-SIMD
/// implementation under the `simd` feature, the scalar chunked kernel
/// otherwise. Both are bit-identical to the [`lif_step`] oracle
/// (property-tested below) — the dispatch never changes results, only
/// instructions.
#[inline]
pub fn lif_step_chunked(
    p: &LifParams,
    v: &mut [f32],
    input: &[f32],
    refrac: &mut [u32],
    spikes_out: &mut Vec<u32>,
) {
    #[cfg(feature = "simd")]
    lif_step_chunked_simd(p, v, input, refrac, spikes_out);
    #[cfg(not(feature = "simd"))]
    lif_step_chunked_scalar(p, v, input, refrac, spikes_out);
}

/// The always-compiled scalar chunked kernel — the fallback every build
/// carries (and the equivalence oracle for the SIMD kernel): chunked,
/// branch-free in the arithmetic, auto-vectorizable.
///
/// Two paths:
/// * `t_refrac == 0` (the common sweep configuration) — the refractory
///   state is provably all-zero, so the kernel is a pure
///   multiply-add/compare/select loop over `v`/`input`;
/// * `t_refrac > 0` — refractory gating folded in with selects on
///   already-computed values (no early exits), so both paths present the
///   compiler a straight-line loop body.
///
/// Spike indices are collected from a per-chunk bitmask after each window,
/// keeping the unpredictable `push` out of the arithmetic loop.
pub fn lif_step_chunked_scalar(
    p: &LifParams,
    v: &mut [f32],
    input: &[f32],
    refrac: &mut [u32],
    spikes_out: &mut Vec<u32>,
) {
    assert_eq!(v.len(), input.len());
    assert_eq!(v.len(), refrac.len());
    spikes_out.clear();
    let mut base = 0usize;
    if p.t_refrac == 0 {
        // With t_refrac == 0 the oracle can never set a nonzero counter, so
        // a consistent state has refrac ≡ 0 and the gate can be dropped.
        debug_assert!(
            refrac.iter().all(|&r| r == 0),
            "t_refrac == 0 implies no neuron is refractory"
        );
        for (vs, is) in v.chunks_mut(LIF_CHUNK).zip(input.chunks(LIF_CHUNK)) {
            let mut mask = 0u32;
            for (j, (vj, &ij)) in vs.iter_mut().zip(is).enumerate() {
                let v_new = ij + p.alpha * *vj + p.i_offset;
                let fired = (v_new >= p.v_th) as u32;
                *vj = v_new - fired as f32 * p.v_th;
                mask |= fired << j;
            }
            push_spike_mask(spikes_out, base, mask);
            base += LIF_CHUNK;
        }
    } else {
        for ((vs, is), rs) in v
            .chunks_mut(LIF_CHUNK)
            .zip(input.chunks(LIF_CHUNK))
            .zip(refrac.chunks_mut(LIF_CHUNK))
        {
            let mut mask = 0u32;
            for (j, ((vj, &ij), rj)) in vs.iter_mut().zip(is).zip(rs.iter_mut()).enumerate() {
                let r = *rj;
                let active = r == 0;
                let v_new = ij + p.alpha * *vj + p.i_offset;
                let fired = active & (v_new >= p.v_th);
                let vf = v_new - fired as u32 as f32 * p.v_th;
                *vj = if active { vf } else { p.v_rest };
                *rj = if active { fired as u32 * p.t_refrac } else { r - 1 };
                mask |= (fired as u32) << j;
            }
            push_spike_mask(spikes_out, base, mask);
            base += LIF_CHUNK;
        }
    }
}

/// The explicit-SIMD LIF kernel (`std::simd`, one `f32x16` vector per
/// [`LIF_CHUNK`] window; `simd` feature only).
///
/// **Bit-identity contract** with [`lif_step_chunked_scalar`] (and hence the
/// [`lif_step`] oracle), property-tested below:
/// * the membrane update keeps the scalar association
///   `(input + alpha·v) + i_offset` — separate multiply then adds, never a
///   fused multiply-add (`std::simd` lane ops are strict IEEE-754 and do
///   not contract);
/// * the subtractive reset subtracts a selected `{v_th, 0.0}` per lane,
///   exactly the scalar `v_new − fired·v_th` (and `x − 0.0 == x` for every
///   f32, including −0.0);
/// * spike masks come from [`std::simd::Mask::to_bitmask`], whose lane→bit
///   order matches the scalar `fired << j` accumulation.
///
/// Slice tails shorter than a full vector run the scalar window body.
#[cfg(feature = "simd")]
pub fn lif_step_chunked_simd(
    p: &LifParams,
    v: &mut [f32],
    input: &[f32],
    refrac: &mut [u32],
    spikes_out: &mut Vec<u32>,
) {
    use std::simd::prelude::*;

    assert_eq!(v.len(), input.len());
    assert_eq!(v.len(), refrac.len());
    spikes_out.clear();
    let alpha = f32x16::splat(p.alpha);
    let i_offset = f32x16::splat(p.i_offset);
    let v_th = f32x16::splat(p.v_th);
    let zero = f32x16::splat(0.0);
    let n_full = (v.len() / LIF_CHUNK) * LIF_CHUNK;
    let mut base = 0usize;
    if p.t_refrac == 0 {
        debug_assert!(
            refrac.iter().all(|&r| r == 0),
            "t_refrac == 0 implies no neuron is refractory"
        );
        while base < n_full {
            let vs = &mut v[base..base + LIF_CHUNK];
            let vv = f32x16::from_slice(vs);
            let iv = f32x16::from_slice(&input[base..base + LIF_CHUNK]);
            let v_new = iv + alpha * vv + i_offset;
            let fired = v_new.simd_ge(v_th);
            (v_new - fired.select(v_th, zero)).copy_to_slice(vs);
            push_spike_mask(spikes_out, base, fired.to_bitmask() as u32);
            base += LIF_CHUNK;
        }
        // Tail: the scalar window body on the final partial chunk.
        let mut mask = 0u32;
        for (j, (vj, &ij)) in v[n_full..].iter_mut().zip(&input[n_full..]).enumerate() {
            let v_new = ij + p.alpha * *vj + p.i_offset;
            let fired = (v_new >= p.v_th) as u32;
            *vj = v_new - fired as f32 * p.v_th;
            mask |= fired << j;
        }
        push_spike_mask(spikes_out, base, mask);
    } else {
        let v_rest = f32x16::splat(p.v_rest);
        let t_refrac = u32x16::splat(p.t_refrac);
        let zero_u = u32x16::splat(0);
        let one_u = u32x16::splat(1);
        while base < n_full {
            let vs = &mut v[base..base + LIF_CHUNK];
            let rs = &mut refrac[base..base + LIF_CHUNK];
            let rv = u32x16::from_slice(rs);
            let active = rv.simd_eq(zero_u);
            let vv = f32x16::from_slice(vs);
            let iv = f32x16::from_slice(&input[base..base + LIF_CHUNK]);
            let v_new = iv + alpha * vv + i_offset;
            let fired = active & v_new.simd_ge(v_th);
            let vf = v_new - fired.select(v_th, zero);
            active.select(vf, v_rest).copy_to_slice(vs);
            // Inactive lanes count down (the wrapping r−1 on r==0 lanes is
            // discarded by the select, exactly like the scalar branch).
            let r_next = active.select(fired.select(t_refrac, zero_u), rv - one_u);
            r_next.copy_to_slice(rs);
            push_spike_mask(spikes_out, base, fired.to_bitmask() as u32);
            base += LIF_CHUNK;
        }
        let mut mask = 0u32;
        for (j, ((vj, &ij), rj)) in v[n_full..]
            .iter_mut()
            .zip(&input[n_full..])
            .zip(refrac[n_full..].iter_mut())
            .enumerate()
        {
            let r = *rj;
            let active = r == 0;
            let v_new = ij + p.alpha * *vj + p.i_offset;
            let fired = active & (v_new >= p.v_th);
            let vf = v_new - fired as u32 as f32 * p.v_th;
            *vj = if active { vf } else { p.v_rest };
            *rj = if active { fired as u32 * p.t_refrac } else { r - 1 };
            mask |= (fired as u32) << j;
        }
        push_spike_mask(spikes_out, base, mask);
    }
}

/// Append the set bits of `mask` (chunk-local neuron indices offset by
/// `base`) as spike ids, lowest index first.
#[inline]
fn push_spike_mask(spikes_out: &mut Vec<u32>, base: usize, mut mask: u32) {
    while mask != 0 {
        let b = mask.trailing_zeros();
        spikes_out.push((base + b as usize) as u32);
        mask &= mask - 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn subthreshold_decays() {
        let p = LifParams::default();
        let (v, spiked, _) = lif_step(&p, 0.5, 0.0, 0);
        assert!(!spiked);
        assert!((v - 0.45).abs() < 1e-6);
    }

    #[test]
    fn threshold_crossing_spikes_and_subtractive_reset() {
        let p = LifParams::default();
        let (v, spiked, _) = lif_step(&p, 0.5, 0.8, 0);
        assert!(spiked);
        // v_new = 0.8 + 0.45 = 1.25 >= 1.0 → reset to 0.25
        assert!((v - 0.25).abs() < 1e-6);
    }

    #[test]
    fn refractory_holds_and_counts_down() {
        let p = LifParams { t_refrac: 2, ..Default::default() };
        let (v, s, r) = lif_step(&p, 0.3, 100.0, 2);
        assert!(!s);
        assert_eq!(v, p.v_rest);
        assert_eq!(r, 1);
        let (_, s2, r2) = lif_step(&p, v, 100.0, r);
        assert!(!s2);
        assert_eq!(r2, 0);
        // Out of refractory: fires again.
        let (_, s3, r3) = lif_step(&p, 0.0, 100.0, 0);
        assert!(s3);
        assert_eq!(r3, p.t_refrac);
    }

    #[test]
    fn batch_matches_scalar() {
        let p = LifParams::default();
        let mut v = vec![0.0, 0.5, 0.99, 2.0];
        let input = vec![0.1, 0.2, 0.3, 0.0];
        let mut refrac = vec![0, 0, 0, 0];
        let mut spikes = Vec::new();
        let v0 = v.clone();
        lif_step_batch(&p, &mut v, &input, &mut refrac, &mut spikes);
        for i in 0..4 {
            let (vs, sp, _) = lif_step(&p, v0[i], input[i], 0);
            assert_eq!(v[i], vs);
            assert_eq!(spikes.contains(&(i as u32)), sp);
        }
    }

    /// Run the oracle, the scalar chunked kernel, and the dispatched kernel
    /// (the SIMD implementation under `--features simd`) over the same
    /// evolving state for `steps` steps and demand bit-identical
    /// trajectories (voltages, counters, spike ids) from all three.
    fn chunked_matches_oracle(p: &LifParams, n: usize, steps: usize, seed: u64) -> bool {
        let mut rng = crate::rng::Rng::new(seed);
        let mut v_a = vec![p.v_init; n];
        let mut v_b = v_a.clone();
        let mut v_c = v_a.clone();
        let mut r_a = vec![0u32; n];
        let mut r_b = r_a.clone();
        let mut r_c = r_a.clone();
        let (mut s_a, mut s_b, mut s_c) = (Vec::new(), Vec::new(), Vec::new());
        for _ in 0..steps {
            let input: Vec<f32> =
                (0..n).map(|_| (rng.range_f64(-0.4, 1.2)) as f32).collect();
            lif_step_batch(p, &mut v_a, &input, &mut r_a, &mut s_a);
            lif_step_chunked_scalar(p, &mut v_b, &input, &mut r_b, &mut s_b);
            lif_step_chunked(p, &mut v_c, &input, &mut r_c, &mut s_c);
            if v_a != v_b || r_a != r_b || s_a != s_b {
                return false;
            }
            if v_a != v_c || r_a != r_c || s_a != s_c {
                return false;
            }
        }
        true
    }

    #[test]
    fn chunked_kernel_is_bit_identical_to_oracle() {
        use crate::prop::Prop;
        Prop::new("lif_step_chunked ≡ lif_step", 60).check(
            |g| {
                let p = LifParams {
                    alpha: g.f64(0.5, 1.0) as f32,
                    v_th: g.f64(0.5, 1.5) as f32,
                    v_rest: g.f64(-0.2, 0.2) as f32,
                    t_refrac: g.usize(0, 4) as u32,
                    i_offset: g.f64(-0.1, 0.3) as f32,
                    v_init: g.f64(-0.5, 0.5) as f32,
                    ..Default::default()
                };
                // Sizes straddling the chunk width, incl. 0 and non-multiples.
                (p, g.usize(0, 3 * LIF_CHUNK + 5), g.i64(1, 1 << 20) as u64)
            },
            |&(p, n, seed)| chunked_matches_oracle(&p, n, 12, seed),
        );
    }

    #[test]
    fn chunked_kernel_handles_refractory_and_offset() {
        let p = LifParams { t_refrac: 3, i_offset: 0.25, alpha: 0.95, ..Default::default() };
        assert!(chunked_matches_oracle(&p, 100, 40, 7));
    }

    #[test]
    fn chunked_fast_path_matches_on_chunk_boundaries() {
        let p = LifParams::default();
        for n in [0, 1, LIF_CHUNK - 1, LIF_CHUNK, LIF_CHUNK + 1, 4 * LIF_CHUNK] {
            assert!(chunked_matches_oracle(&p, n, 10, 42 + n as u64), "n={n}");
        }
    }

    #[test]
    fn kernel_variant_matches_build_features() {
        let expect = if cfg!(feature = "simd") { "simd" } else { "scalar" };
        assert_eq!(kernel_variant(), expect);
    }

    /// Direct scalar-vs-SIMD equivalence (not through the dispatcher):
    /// random parameters, sizes straddling the vector width, refractory
    /// periods on and off.
    #[cfg(feature = "simd")]
    #[test]
    fn simd_kernel_is_bit_identical_to_scalar() {
        use crate::prop::Prop;
        Prop::new("lif_step_chunked_simd ≡ scalar", 60).check(
            |g| {
                let p = LifParams {
                    alpha: g.f64(0.5, 1.0) as f32,
                    v_th: g.f64(0.5, 1.5) as f32,
                    v_rest: g.f64(-0.2, 0.2) as f32,
                    t_refrac: g.usize(0, 4) as u32,
                    i_offset: g.f64(-0.1, 0.3) as f32,
                    v_init: g.f64(-0.5, 0.5) as f32,
                    ..Default::default()
                };
                (p, g.usize(0, 3 * LIF_CHUNK + 5), g.i64(1, 1 << 20) as u64)
            },
            |&(p, n, seed)| {
                let mut rng = crate::rng::Rng::new(seed);
                let mut v_s = vec![p.v_init; n];
                let mut v_v = v_s.clone();
                let mut r_s = vec![0u32; n];
                let mut r_v = r_s.clone();
                let (mut s_s, mut s_v) = (Vec::new(), Vec::new());
                for _ in 0..12 {
                    let input: Vec<f32> =
                        (0..n).map(|_| (rng.range_f64(-0.4, 1.2)) as f32).collect();
                    lif_step_chunked_scalar(&p, &mut v_s, &input, &mut r_s, &mut s_s);
                    lif_step_chunked_simd(&p, &mut v_v, &input, &mut r_v, &mut s_v);
                    if v_s != v_v || r_s != r_v || s_s != s_v {
                        return false;
                    }
                }
                true
            },
        );
    }

    #[test]
    fn bias_current_accumulates_to_spike() {
        let p = LifParams { i_offset: 0.3, alpha: 1.0, ..Default::default() };
        let mut v = 0.0;
        let mut fired_at = None;
        for t in 0..10 {
            let (vn, sp, _) = lif_step(&p, v, 0.0, 0);
            v = vn;
            if sp {
                fired_at = Some(t);
                break;
            }
        }
        // 0.3/step with no leak → crosses 1.0 on step 3 (v=1.2).
        assert_eq!(fired_at, Some(3));
    }
}
