//! SNN model representation.
//!
//! The compilation pipeline (paper Fig. 2) starts from a trained or
//! ANN-converted SNN model. We represent it as a set of neuron
//! [`Population`]s wired by [`Projection`]s whose synapses are produced by a
//! [`connector`]. Neuron dynamics are leaky integrate-and-fire
//! ([`lif::LifParams`], paper Eq. 1).
//!
//! Submodules:
//! * [`lif`] — LIF neuron/synapse parameters and the reference update rule.
//! * [`population`] — a named group of neurons sharing parameters.
//! * [`connector`] — synapse-generation strategies (all-to-all,
//!   fixed-probability, one-to-one, explicit list).
//! * [`projection`] — a source→target edge carrying a synapse list.
//! * [`network`] — the whole model plus a builder API.
//! * [`layer`] — the 4-feature layer characterization (delay range, source
//!   neurons, target neurons, weight density) the classifier consumes.

pub mod config;
pub mod connector;
pub mod layer;
pub mod lif;
pub mod network;
pub mod population;
pub mod projection;

pub use connector::Connector;
pub use layer::LayerCharacter;
pub use lif::LifParams;
pub use network::{Network, NetworkBuilder};
pub use population::{Population, PopulationId};
pub use projection::{Projection, ProjectionId, Synapse, SynapseType};
