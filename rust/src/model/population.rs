//! Neuron populations — the vertices of the application graph.

use super::lif::LifParams;

/// Index of a population within a [`crate::model::Network`].
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct PopulationId(pub usize);

/// What the population's neurons do.
#[derive(Clone, Debug, PartialEq)]
pub enum NeuronKind {
    /// Leaky integrate-and-fire dynamics (paper Eq. 1).
    Lif(LifParams),
    /// External spike source: per-timestep list of firing neuron indices.
    /// Used for model inputs (the paper's input populations).
    SpikeSource,
}

/// A named group of neurons sharing parameters — one layer of the SNN.
#[derive(Clone, Debug)]
pub struct Population {
    pub id: PopulationId,
    pub label: String,
    pub n_neurons: usize,
    pub kind: NeuronKind,
    /// Whether spike output of this population is recorded by the simulator.
    pub record_spikes: bool,
    /// Whether membrane voltage is recorded.
    pub record_v: bool,
}

impl Population {
    pub fn lif(id: PopulationId, label: &str, n_neurons: usize, params: LifParams) -> Self {
        Population {
            id,
            label: label.to_string(),
            n_neurons,
            kind: NeuronKind::Lif(params),
            record_spikes: true,
            record_v: false,
        }
    }

    pub fn spike_source(id: PopulationId, label: &str, n_neurons: usize) -> Self {
        Population {
            id,
            label: label.to_string(),
            n_neurons,
            kind: NeuronKind::SpikeSource,
            record_spikes: false,
            record_v: false,
        }
    }

    /// LIF parameters if this is a LIF population.
    pub fn lif_params(&self) -> Option<&LifParams> {
        match &self.kind {
            NeuronKind::Lif(p) => Some(p),
            NeuronKind::SpikeSource => None,
        }
    }

    pub fn is_source(&self) -> bool {
        matches!(self.kind, NeuronKind::SpikeSource)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constructors() {
        let p = Population::lif(PopulationId(0), "hidden", 100, LifParams::default());
        assert_eq!(p.n_neurons, 100);
        assert!(p.lif_params().is_some());
        assert!(!p.is_source());

        let s = Population::spike_source(PopulationId(1), "input", 64);
        assert!(s.is_source());
        assert!(s.lif_params().is_none());
    }
}
