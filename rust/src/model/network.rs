//! The whole SNN model plus a builder API.

use super::connector::{Connector, SynapseDraw};
use super::lif::LifParams;
use super::population::{Population, PopulationId};
use super::projection::{Projection, ProjectionId};
use crate::rng::Rng;

/// A complete SNN model: populations + projections.
#[derive(Clone, Debug, Default)]
pub struct Network {
    pub populations: Vec<Population>,
    pub projections: Vec<Projection>,
}

impl Network {
    pub fn population(&self, id: PopulationId) -> &Population {
        &self.populations[id.0]
    }

    pub fn projection(&self, id: ProjectionId) -> &Projection {
        &self.projections[id.0]
    }

    /// Projections whose target is `pop`.
    pub fn incoming(&self, pop: PopulationId) -> Vec<&Projection> {
        self.projections.iter().filter(|p| p.target == pop).collect()
    }

    /// Projections whose source is `pop`.
    pub fn outgoing(&self, pop: PopulationId) -> Vec<&Projection> {
        self.projections.iter().filter(|p| p.source == pop).collect()
    }

    /// Total neuron count.
    pub fn total_neurons(&self) -> usize {
        self.populations.iter().map(|p| p.n_neurons).sum()
    }

    /// Total synapse count.
    pub fn total_synapses(&self) -> usize {
        self.projections.iter().map(|p| p.synapses.len()).sum()
    }

    /// Populations in topological order where possible (sources first).
    /// Cycles (recurrent nets) are appended in id order after the DAG part.
    pub fn topo_order(&self) -> Vec<PopulationId> {
        let n = self.populations.len();
        let mut indeg = vec![0usize; n];
        for proj in &self.projections {
            if proj.source != proj.target {
                indeg[proj.target.0] += 1;
            }
        }
        let mut queue: Vec<usize> = (0..n).filter(|&i| indeg[i] == 0).collect();
        let mut order = Vec::with_capacity(n);
        let mut seen = vec![false; n];
        while let Some(i) = queue.pop() {
            if seen[i] {
                continue;
            }
            seen[i] = true;
            order.push(PopulationId(i));
            for proj in &self.projections {
                if proj.source.0 == i && proj.source != proj.target {
                    indeg[proj.target.0] -= 1;
                    if indeg[proj.target.0] == 0 {
                        queue.push(proj.target.0);
                    }
                }
            }
        }
        for i in 0..n {
            if !seen[i] {
                order.push(PopulationId(i));
            }
        }
        order
    }
}

/// Fluent builder for [`Network`].
pub struct NetworkBuilder {
    net: Network,
    rng: Rng,
}

impl NetworkBuilder {
    pub fn new(seed: u64) -> Self {
        NetworkBuilder { net: Network::default(), rng: Rng::new(seed) }
    }

    /// Add a LIF population; returns its id.
    pub fn lif_population(&mut self, label: &str, n: usize, params: LifParams) -> PopulationId {
        let id = PopulationId(self.net.populations.len());
        self.net.populations.push(Population::lif(id, label, n, params));
        id
    }

    /// Add an external spike-source population; returns its id.
    pub fn spike_source(&mut self, label: &str, n: usize) -> PopulationId {
        let id = PopulationId(self.net.populations.len());
        self.net.populations.push(Population::spike_source(id, label, n));
        id
    }

    /// Connect two populations; returns the projection id.
    pub fn project(
        &mut self,
        source: PopulationId,
        target: PopulationId,
        connector: Connector,
        draw: SynapseDraw,
        weight_scale: f32,
    ) -> ProjectionId {
        let n_source = self.net.population(source).n_neurons;
        let n_target = self.net.population(target).n_neurons;
        let synapses = connector.build(n_source, n_target, draw, &mut self.rng);
        let id = ProjectionId(self.net.projections.len());
        self.net.projections.push(Projection { id, source, target, synapses, weight_scale });
        id
    }

    pub fn build(self) -> Network {
        self.net
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::SynapseType;

    fn small_net() -> Network {
        let mut b = NetworkBuilder::new(42);
        let inp = b.spike_source("in", 10);
        let hid = b.lif_population("hid", 20, LifParams::default());
        let out = b.lif_population("out", 5, LifParams::default());
        b.project(
            inp,
            hid,
            Connector::FixedProbability(0.5),
            SynapseDraw { delay_range: 4, ..Default::default() },
            0.01,
        );
        b.project(
            hid,
            out,
            Connector::AllToAll,
            SynapseDraw { delay_range: 2, syn_type: SynapseType::Excitatory, ..Default::default() },
            0.01,
        );
        b.build()
    }

    #[test]
    fn builder_wires_everything() {
        let net = small_net();
        assert_eq!(net.populations.len(), 3);
        assert_eq!(net.projections.len(), 2);
        assert_eq!(net.total_neurons(), 35);
        assert_eq!(net.incoming(PopulationId(1)).len(), 1);
        assert_eq!(net.outgoing(PopulationId(1)).len(), 1);
        assert_eq!(net.projection(ProjectionId(1)).synapses.len(), 100);
    }

    #[test]
    fn topo_order_sources_first() {
        let net = small_net();
        let order = net.topo_order();
        let pos = |id: usize| order.iter().position(|p| p.0 == id).unwrap();
        assert!(pos(0) < pos(1));
        assert!(pos(1) < pos(2));
    }

    #[test]
    fn topo_order_handles_recurrence() {
        let mut b = NetworkBuilder::new(1);
        let a = b.lif_population("a", 5, LifParams::default());
        let c = b.lif_population("b", 5, LifParams::default());
        b.project(a, c, Connector::OneToOne, SynapseDraw::default(), 1.0);
        b.project(c, a, Connector::OneToOne, SynapseDraw::default(), 1.0); // cycle
        let net = b.build();
        let order = net.topo_order();
        assert_eq!(order.len(), 2); // all populations present despite cycle
    }

    #[test]
    fn deterministic_given_seed() {
        let a = small_net();
        let b = small_net();
        assert_eq!(a.projections[0].synapses, b.projections[0].synapses);
    }
}
