//! Layer characterization — the classifier's feature space.
//!
//! The paper (§IV-A) characterizes one SNN layer (one population of the
//! application graph plus its incoming projection) by four factors:
//! **delay range, source neuron number, target neuron number, weight
//! density**. These four numbers are both the dataset generator's sweep
//! axes and the classifier's input features.

use super::projection::Projection;

/// The four-factor layer character from the paper.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct LayerCharacter {
    pub n_source: usize,
    pub n_target: usize,
    /// Weight density in (0, 1]: fraction of possible synapses present.
    pub density: f64,
    /// Maximum synaptic delay in timesteps (1..=16 in the paper's sweep).
    pub delay_range: u16,
}

impl LayerCharacter {
    pub fn new(n_source: usize, n_target: usize, density: f64, delay_range: u16) -> Self {
        assert!(n_source > 0 && n_target > 0, "empty layer");
        assert!((0.0..=1.0).contains(&density), "density out of range");
        assert!(delay_range >= 1, "delay range is 1-based");
        LayerCharacter { n_source, n_target, density, delay_range }
    }

    /// Measure the character of a realized projection.
    pub fn of_projection(proj: &Projection, n_source: usize, n_target: usize) -> Self {
        LayerCharacter {
            n_source,
            n_target,
            density: proj.density(n_source, n_target),
            delay_range: proj.delay_range(),
        }
    }

    /// Feature vector in the order used throughout the classifier stack:
    /// `[delay_range, n_source, n_target, density]`.
    pub fn features(&self) -> [f64; 4] {
        [
            self.delay_range as f64,
            self.n_source as f64,
            self.n_target as f64,
            self.density,
        ]
    }

    /// Expected number of synapses.
    pub fn expected_synapses(&self) -> f64 {
        self.n_source as f64 * self.n_target as f64 * self.density
    }
}

/// Feature names matching [`LayerCharacter::features`] order.
pub const FEATURE_NAMES: [&str; 4] = ["delay_range", "n_source", "n_target", "density"];

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{PopulationId, ProjectionId, Synapse, SynapseType};

    #[test]
    fn feature_order_stable() {
        let c = LayerCharacter::new(100, 200, 0.5, 8);
        assert_eq!(c.features(), [8.0, 100.0, 200.0, 0.5]);
    }

    #[test]
    fn of_projection_measures() {
        let proj = Projection {
            id: ProjectionId(0),
            source: PopulationId(0),
            target: PopulationId(1),
            synapses: vec![
                Synapse { source: 0, target: 0, weight: 1, delay: 3, syn_type: SynapseType::Excitatory },
                Synapse { source: 1, target: 1, weight: 1, delay: 7, syn_type: SynapseType::Excitatory },
            ],
            weight_scale: 1.0,
        };
        let c = LayerCharacter::of_projection(&proj, 2, 2);
        assert_eq!(c.delay_range, 7);
        assert!((c.density - 0.5).abs() < 1e-12);
    }

    #[test]
    #[should_panic(expected = "density out of range")]
    fn rejects_bad_density() {
        LayerCharacter::new(10, 10, 1.5, 1);
    }

    #[test]
    #[should_panic(expected = "empty layer")]
    fn rejects_empty() {
        LayerCharacter::new(0, 10, 0.5, 1);
    }
}
