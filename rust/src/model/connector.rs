//! Synapse-generation strategies (PyNN-style connectors).
//!
//! The dataset generator uses [`Connector::FixedProbability`] to realize the
//! paper's "weight density 10%–100%" sweep; examples use the others.

use super::projection::{Synapse, SynapseType};
use crate::rng::Rng;

/// How to generate the synapses of a projection.
#[derive(Clone, Debug)]
pub enum Connector {
    /// Every (source, target) pair gets a synapse.
    AllToAll,
    /// Each (source, target) pair gets a synapse with probability `p`
    /// (the paper's *weight density*).
    FixedProbability(f64),
    /// Source i connects to target i (populations must be the same size).
    OneToOne,
    /// Explicit synapse list (used when loading trained models).
    Explicit(Vec<Synapse>),
}

/// Weight/delay draw configuration for generated synapses.
#[derive(Clone, Copy, Debug)]
pub struct SynapseDraw {
    /// Weight magnitudes drawn uniformly from [w_min, w_max] (quantized u8).
    pub w_min: u8,
    pub w_max: u8,
    /// Delays drawn uniformly from [1, delay_range].
    pub delay_range: u16,
    pub syn_type: SynapseType,
}

impl Default for SynapseDraw {
    fn default() -> Self {
        SynapseDraw {
            w_min: 1,
            w_max: 255,
            delay_range: 1,
            syn_type: SynapseType::Excitatory,
        }
    }
}

impl Connector {
    /// Materialize the synapse list for an (n_source × n_target) projection.
    pub fn build(
        &self,
        n_source: usize,
        n_target: usize,
        draw: SynapseDraw,
        rng: &mut Rng,
    ) -> Vec<Synapse> {
        let mk = |s: u32, t: u32, rng: &mut Rng| Synapse {
            source: s,
            target: t,
            weight: draw.w_min + rng.below((draw.w_max - draw.w_min + 1) as usize) as u8,
            delay: 1 + rng.below(draw.delay_range as usize) as u16,
            syn_type: draw.syn_type,
        };
        match self {
            Connector::AllToAll => {
                let mut out = Vec::with_capacity(n_source * n_target);
                for s in 0..n_source as u32 {
                    for t in 0..n_target as u32 {
                        out.push(mk(s, t, rng));
                    }
                }
                out
            }
            Connector::FixedProbability(p) => {
                let mut out = Vec::new();
                for s in 0..n_source as u32 {
                    for t in 0..n_target as u32 {
                        if rng.chance(*p) {
                            out.push(mk(s, t, rng));
                        }
                    }
                }
                out
            }
            Connector::OneToOne => {
                assert_eq!(
                    n_source, n_target,
                    "OneToOne requires equal population sizes"
                );
                (0..n_source as u32).map(|i| mk(i, i, rng)).collect()
            }
            Connector::Explicit(list) => {
                for s in list {
                    assert!((s.source as usize) < n_source, "source index out of range");
                    assert!((s.target as usize) < n_target, "target index out of range");
                    assert!(s.delay >= 1, "delays are 1-based");
                }
                list.clone()
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop::Prop;

    #[test]
    fn all_to_all_count() {
        let mut rng = Rng::new(1);
        let syns = Connector::AllToAll.build(10, 20, SynapseDraw::default(), &mut rng);
        assert_eq!(syns.len(), 200);
    }

    #[test]
    fn one_to_one_diagonal() {
        let mut rng = Rng::new(2);
        let syns = Connector::OneToOne.build(8, 8, SynapseDraw::default(), &mut rng);
        assert_eq!(syns.len(), 8);
        assert!(syns.iter().all(|s| s.source == s.target));
    }

    #[test]
    #[should_panic(expected = "equal population sizes")]
    fn one_to_one_requires_square() {
        let mut rng = Rng::new(3);
        Connector::OneToOne.build(8, 9, SynapseDraw::default(), &mut rng);
    }

    #[test]
    fn fixed_probability_density_close() {
        let mut rng = Rng::new(4);
        let p = 0.3;
        let syns =
            Connector::FixedProbability(p).build(200, 200, SynapseDraw::default(), &mut rng);
        let density = syns.len() as f64 / (200.0 * 200.0);
        assert!((density - p).abs() < 0.02, "density {density}");
    }

    #[test]
    fn delays_and_weights_within_draw_bounds() {
        Prop::new("connector draw bounds", 50).check(
            |g| {
                let dr = g.usize(1, 16) as u16;
                let mut rng = Rng::new(g.i64(0, 1 << 30) as u64);
                let draw = SynapseDraw { delay_range: dr, w_min: 5, w_max: 9, ..Default::default() };
                let syns = Connector::FixedProbability(0.5).build(20, 20, draw, &mut rng);
                (dr, syns)
            },
            |(dr, syns)| {
                syns.iter().all(|s| {
                    (1..=*dr).contains(&s.delay) && (5..=9).contains(&s.weight)
                })
            },
        );
    }

    #[test]
    fn explicit_passthrough_and_validation() {
        let mut rng = Rng::new(5);
        let list = vec![Synapse {
            source: 0,
            target: 1,
            weight: 7,
            delay: 2,
            syn_type: SynapseType::Inhibitory,
        }];
        let syns = Connector::Explicit(list.clone()).build(2, 2, SynapseDraw::default(), &mut rng);
        assert_eq!(syns, list);
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn explicit_rejects_bad_indices() {
        let mut rng = Rng::new(6);
        let list = vec![Synapse {
            source: 5,
            target: 0,
            weight: 1,
            delay: 1,
            syn_type: SynapseType::Excitatory,
        }];
        Connector::Explicit(list).build(2, 2, SynapseDraw::default(), &mut rng);
    }
}
