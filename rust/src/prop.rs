//! Minimal property-based testing kit (proptest substitute — the offline
//! vendored crate set has no proptest).
//!
//! Usage pattern (`no_run`: doctest binaries don't get the xla rpath the
//! cargo config injects, so this is compile-checked only — the same
//! pattern executes in every module's unit tests):
//!
//! ```no_run
//! use s2switch::prop::{Prop, Gen};
//! Prop::new("addition commutes", 200).check(
//!     |g| (g.i64(0, 100), g.i64(0, 100)),
//!     |&(a, b)| a + b == b + a,
//! );
//! ```
//!
//! On failure the harness re-runs a bounded shrink loop that retries the
//! failing case with smaller regenerated cases (halving the generator's size
//! hint) and panics with the smallest failing case's debug representation
//! and the seed needed to reproduce it.

use crate::rng::Rng;

/// Generator handle passed to the case-generation closure.
pub struct Gen<'a> {
    rng: &'a mut Rng,
    /// Size hint in [0,1]; shrink passes lower it so ranges contract toward
    /// their lower bounds.
    pub size: f64,
}

impl<'a> Gen<'a> {
    /// Integer in [lo, hi], contracted toward `lo` under shrinking.
    pub fn i64(&mut self, lo: i64, hi: i64) -> i64 {
        let span = ((hi - lo) as f64 * self.size).round() as i64;
        self.rng.range_i64(lo, lo + span.max(0))
    }

    /// usize in [lo, hi], contracted toward `lo` under shrinking.
    pub fn usize(&mut self, lo: usize, hi: usize) -> usize {
        self.i64(lo as i64, hi as i64) as usize
    }

    /// f64 in [lo, hi), contracted toward `lo` under shrinking.
    pub fn f64(&mut self, lo: f64, hi: f64) -> f64 {
        self.rng.range_f64(lo, lo + (hi - lo) * self.size)
    }

    /// Bernoulli with probability p.
    pub fn bool(&mut self, p: f64) -> bool {
        self.rng.chance(p)
    }

    /// Pick one element of a slice.
    pub fn choose<'b, T>(&mut self, xs: &'b [T]) -> &'b T {
        &xs[self.rng.below(xs.len())]
    }

    /// Vector of `n` items from a sub-generator.
    pub fn vec<T>(&mut self, n: usize, mut f: impl FnMut(&mut Gen) -> T) -> Vec<T> {
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            let mut g = Gen { rng: self.rng, size: self.size };
            out.push(f(&mut g));
        }
        out
    }

    /// Access the underlying RNG for custom distributions.
    pub fn rng(&mut self) -> &mut Rng {
        self.rng
    }
}

/// A named property with a case budget.
pub struct Prop {
    name: &'static str,
    cases: usize,
    seed: u64,
}

impl Prop {
    /// A property that will be checked against `cases` generated cases.
    pub fn new(name: &'static str, cases: usize) -> Self {
        // Default seed derives from the name so distinct properties explore
        // distinct streams but remain reproducible.
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        for b in name.bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x100_0000_01b3);
        }
        Prop { name, cases, seed: h }
    }

    /// Override the seed (printed on failure for reproduction).
    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Generate cases with `gen` and assert `check` holds for each.
    pub fn check<T: std::fmt::Debug>(
        &self,
        mut gen: impl FnMut(&mut Gen) -> T,
        mut check: impl FnMut(&T) -> bool,
    ) {
        let mut rng = Rng::new(self.seed);
        for case_idx in 0..self.cases {
            let mut g = Gen { rng: &mut rng, size: 1.0 };
            let case = gen(&mut g);
            if !check(&case) {
                // Shrink: regenerate at progressively smaller sizes from the
                // same stream until we stop finding failures.
                let mut smallest: Option<T> = None;
                let mut size = 0.5;
                let mut shrink_rng = Rng::new(self.seed ^ 0x5bd1_e995);
                for _ in 0..64 {
                    let mut g = Gen { rng: &mut shrink_rng, size };
                    let cand = gen(&mut g);
                    if !check(&cand) {
                        smallest = Some(cand);
                        size *= 0.5;
                    }
                }
                let shown = smallest.as_ref().unwrap_or(&case);
                panic!(
                    "property '{}' failed at case {} (seed {:#x}):\n  failing case: {:?}",
                    self.name, case_idx, self.seed, shown
                );
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        Prop::new("abs is non-negative", 500).check(|g| g.i64(-1000, 1000), |&x| x.abs() >= 0);
    }

    #[test]
    #[should_panic(expected = "property 'always fails'")]
    fn failing_property_panics_with_case() {
        Prop::new("always fails", 10).check(|g| g.i64(0, 10), |_| false);
    }

    #[test]
    fn generators_respect_bounds() {
        Prop::new("bounds", 1000).check(
            |g| (g.i64(-5, 5), g.usize(2, 9), g.f64(1.0, 2.0)),
            |&(a, b, c)| (-5..=5).contains(&a) && (2..=9).contains(&b) && (1.0..2.0).contains(&c),
        );
    }

    #[test]
    fn vec_generator_has_requested_len() {
        Prop::new("vec len", 100).check(
            |g| {
                let n = g.usize(0, 20);
                (n, g.vec(n, |g| g.i64(0, 1)))
            },
            |(n, v)| v.len() == *n,
        );
    }
}
