//! Dataset acquisition (paper §IV-A).
//!
//! "We run on parallel paradigm's compiler the randomly generated 16000 SNN
//! layers, whose source and target neurons range from 50 to 500 with step
//! length 50, weight density 10% − 100% with 10% step length, delay range
//! 1 − 16 with step length 1."
//!
//! Each layer is compiled under both paradigms; the label is the paradigm
//! needing fewer PEs (ties go to serial — no dominant-PE overhead). The
//! serial PE count comes from the closed-form Table I model; the parallel
//! count requires actually running the parallel compiler (Table I: the WDM
//! size "can't be accurately estimated").

pub mod generator;

pub use generator::{
    generate_grid, generate_grid_jobs, generate_grid_opts, label_layer, realize_layer, Dataset,
    Sample, SweepConfig, CSV_COLUMNS,
};
