//! Grid sweep generation + double-compile labeling.

use crate::costmodel::serial::serial_pe_count;
use crate::hardware::PeSpec;
use crate::io::csv;
use crate::model::connector::{Connector, SynapseDraw};
use crate::model::{LayerCharacter, PopulationId, Projection, ProjectionId};
use crate::paradigm::parallel::splitting::two_stage_split;
use crate::paradigm::parallel::wdm::{build_wdm_shape, WdmConfig};
use crate::paradigm::Paradigm;
use crate::rng::Rng;
use anyhow::{Context, Result};
use std::path::Path;

/// The paper's sweep axes.
#[derive(Clone, Debug)]
pub struct SweepConfig {
    pub sources: Vec<usize>,
    pub targets: Vec<usize>,
    pub densities: Vec<f64>,
    pub delays: Vec<u16>,
    pub seed: u64,
}

impl Default for SweepConfig {
    fn default() -> Self {
        // 10 × 10 × 10 × 16 = 16,000 layers, exactly the paper's grid.
        SweepConfig {
            sources: (1..=10).map(|i| i * 50).collect(),
            targets: (1..=10).map(|i| i * 50).collect(),
            densities: (1..=10).map(|i| i as f64 / 10.0).collect(),
            delays: (1..=16).collect(),
            seed: 2024,
        }
    }
}

impl SweepConfig {
    /// A reduced grid for tests and quick runs (2×2×3×4 = 48 layers).
    pub fn small() -> Self {
        SweepConfig {
            sources: vec![50, 250],
            targets: vec![50, 250],
            densities: vec![0.1, 0.5, 1.0],
            delays: vec![1, 4, 8, 16],
            seed: 7,
        }
    }

    /// A medium grid (4×4×5×8 = 640 layers) — dense enough to train a
    /// usable prejudger in integration tests without paying for the full
    /// 16k corpus.
    pub fn medium() -> Self {
        SweepConfig {
            sources: vec![50, 150, 300, 500],
            targets: vec![50, 150, 300, 500],
            densities: vec![0.1, 0.3, 0.5, 0.8, 1.0],
            delays: vec![1, 2, 4, 6, 8, 10, 13, 16],
            seed: 7,
        }
    }

    pub fn n_layers(&self) -> usize {
        self.sources.len() * self.targets.len() * self.densities.len() * self.delays.len()
    }
}

/// One labeled layer.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Sample {
    pub character: LayerCharacter,
    pub serial_pes: usize,
    pub parallel_pes: usize,
}

impl Sample {
    /// The cheaper paradigm; ties go to serial.
    pub fn label(&self) -> Paradigm {
        if self.parallel_pes < self.serial_pes {
            Paradigm::Parallel
        } else {
            Paradigm::Serial
        }
    }

    /// Classifier features `[delay_range, n_source, n_target, density]`.
    pub fn features(&self) -> [f64; 4] {
        self.character.features()
    }
}

/// The labeled corpus.
#[derive(Clone, Debug, Default)]
pub struct Dataset {
    pub samples: Vec<Sample>,
}

impl Dataset {
    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    /// Feature matrix + label vector for classifier training.
    pub fn xy(&self) -> (Vec<[f64; 4]>, Vec<usize>) {
        (
            self.samples.iter().map(|s| s.features()).collect(),
            self.samples.iter().map(|s| s.label().label()).collect(),
        )
    }

    /// Persist to CSV.
    pub fn save_csv(&self, path: &Path) -> Result<()> {
        csv::write_csv(
            path,
            &["delay_range", "n_source", "n_target", "density", "serial_pes", "parallel_pes", "label"],
            self.samples.iter().map(|s| {
                vec![
                    s.character.delay_range.to_string(),
                    s.character.n_source.to_string(),
                    s.character.n_target.to_string(),
                    format!("{:.6}", s.character.density),
                    s.serial_pes.to_string(),
                    s.parallel_pes.to_string(),
                    s.label().label().to_string(),
                ]
            }),
        )?;
        Ok(())
    }

    /// Load from CSV.
    pub fn load_csv(path: &Path) -> Result<Dataset> {
        let (_, rows) = csv::read_csv(path)?;
        let mut samples = Vec::with_capacity(rows.len());
        for row in rows {
            let f = |i: usize| -> Result<f64> {
                row.get(i)
                    .context("short row")?
                    .parse::<f64>()
                    .context("bad number in dataset csv")
            };
            samples.push(Sample {
                character: LayerCharacter::new(
                    f(1)? as usize,
                    f(2)? as usize,
                    f(3)?,
                    f(0)? as u16,
                ),
                serial_pes: f(4)? as usize,
                parallel_pes: f(5)? as usize,
            });
        }
        Ok(Dataset { samples })
    }
}

/// Realize one random layer as a standalone projection (the dataset's and
/// benches' shared workload generator).
pub fn realize_layer(
    n_source: usize,
    n_target: usize,
    density: f64,
    delay_range: u16,
    rng: &mut Rng,
) -> Projection {
    let synapses = Connector::FixedProbability(density).build(
        n_source,
        n_target,
        SynapseDraw { delay_range, w_max: 127, ..Default::default() },
        rng,
    );
    Projection {
        id: ProjectionId(0),
        source: PopulationId(0),
        target: PopulationId(1),
        synapses,
        weight_scale: 1.0,
    }
}

/// Label one layer: realize its synapses, compile both paradigms, count PEs.
///
/// The parallel count runs the real WDM build + two-stage split (skipping
/// chunk-weight materialization, which does not affect PE counts); the
/// serial count uses the closed-form Table I layout.
pub fn label_layer(
    n_source: usize,
    n_target: usize,
    density: f64,
    delay_range: u16,
    pe: &PeSpec,
    config: WdmConfig,
    rng: &mut Rng,
) -> Sample {
    let proj = realize_layer(n_source, n_target, density, delay_range, rng);
    // Use the *nominal* sweep coordinates as the character (what the
    // classifier will see at prejudging time — before any compilation).
    let character = LayerCharacter::new(n_source, n_target, density, delay_range);

    // Serial per-layer PE count = target-side layout (Table I) plus the
    // ceil(n_source/255) PEs hosting the source population — the paper's
    // source-side 255 cap (and what makes its gesture model need 9 serial
    // PEs for 2048 inputs). The parallel paradigm absorbs source handling
    // into the dominant PE's input-spike buffer, so no analogous charge.
    let hosting = n_source.div_ceil(pe.serial_neuron_cap);
    let serial_pes = serial_pe_count(&character, pe)
        .expect("sweep layer must be serially placeable")
        + hosting;

    let n_source_vertex = n_source.div_ceil(pe.serial_neuron_cap);
    // Shape-only WDM: PE counting never touches the weight block.
    let wdm = build_wdm_shape(&proj, n_source, n_target, config);
    let plan = two_stage_split(&wdm, pe, n_source_vertex)
        .expect("sweep layer must be parallel placeable");
    let parallel_pes = 1 + plan.n_subordinates();

    Sample { character, serial_pes, parallel_pes }
}

/// Generate the full labeled grid, parallelized over OS threads.
pub fn generate_grid(cfg: &SweepConfig, pe: &PeSpec, config: WdmConfig) -> Dataset {
    // Flatten the grid into work items, each with its own derived RNG seed
    // so results are independent of thread scheduling.
    let mut items: Vec<(usize, usize, f64, u16, u64)> = Vec::with_capacity(cfg.n_layers());
    let mut idx = 0u64;
    for &src in &cfg.sources {
        for &tgt in &cfg.targets {
            for &d in &cfg.densities {
                for &dl in &cfg.delays {
                    items.push((src, tgt, d, dl, cfg.seed.wrapping_add(idx.wrapping_mul(0x9E3779B97F4A7C15))));
                    idx += 1;
                }
            }
        }
    }

    let n_threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
    let chunk = items.len().div_ceil(n_threads.max(1));
    let mut samples = vec![
        Sample {
            character: LayerCharacter::new(1, 1, 0.0, 1),
            serial_pes: 0,
            parallel_pes: 0
        };
        items.len()
    ];

    std::thread::scope(|scope| {
        for (slot, work) in samples.chunks_mut(chunk).zip(items.chunks(chunk)) {
            scope.spawn(move || {
                for (out, &(src, tgt, d, dl, seed)) in slot.iter_mut().zip(work) {
                    let mut rng = Rng::new(seed);
                    *out = label_layer(src, tgt, d, dl, pe, config, &mut rng);
                }
            });
        }
    });

    Dataset { samples }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sweep_grid_sizes() {
        assert_eq!(SweepConfig::default().n_layers(), 16_000);
        assert_eq!(SweepConfig::small().n_layers(), 48);
    }

    #[test]
    fn small_grid_generates_and_labels() {
        let ds = generate_grid(&SweepConfig::small(), &PeSpec::default(), WdmConfig::default());
        assert_eq!(ds.len(), 48);
        assert!(ds.samples.iter().all(|s| s.serial_pes >= 1 && s.parallel_pes >= 2));
        // Both classes must appear — the paradigms genuinely trade off.
        let (_, y) = ds.xy();
        assert!(y.iter().any(|&l| l == 0), "some layer favors serial");
        assert!(y.iter().any(|&l| l == 1), "some layer favors parallel");
    }

    #[test]
    fn labeling_is_deterministic() {
        let pe = PeSpec::default();
        let a = label_layer(100, 100, 0.5, 4, &pe, WdmConfig::default(), &mut Rng::new(9));
        let b = label_layer(100, 100, 0.5, 4, &pe, WdmConfig::default(), &mut Rng::new(9));
        assert_eq!(a, b);
    }

    #[test]
    fn generation_is_scheduling_independent() {
        // Per-item seeds mean the parallel generation equals a serial rerun.
        let cfg = SweepConfig::small();
        let pe = PeSpec::default();
        let a = generate_grid(&cfg, &pe, WdmConfig::default());
        let b = generate_grid(&cfg, &pe, WdmConfig::default());
        assert_eq!(a.samples, b.samples);
    }

    #[test]
    fn csv_roundtrip() {
        let ds = generate_grid(&SweepConfig::small(), &PeSpec::default(), WdmConfig::default());
        let dir = std::env::temp_dir().join("s2switch_ds_test");
        let path = dir.join("ds.csv");
        ds.save_csv(&path).unwrap();
        let back = Dataset::load_csv(&path).unwrap();
        assert_eq!(ds.samples.len(), back.samples.len());
        for (a, b) in ds.samples.iter().zip(&back.samples) {
            assert_eq!(a.serial_pes, b.serial_pes);
            assert_eq!(a.parallel_pes, b.parallel_pes);
            assert!((a.character.density - b.character.density).abs() < 1e-6);
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn delay_trend_matches_paper() {
        // Fig 3: parallel improves as delay range decreases. Compare the
        // parallel-win rate at delay 1 vs delay 16 on a dense slice.
        let pe = PeSpec::default();
        let mut wins_d1 = 0;
        let mut wins_d16 = 0;
        for (i, &src) in [100usize, 200, 300].iter().enumerate() {
            let mut rng = Rng::new(100 + i as u64);
            let s1 = label_layer(src, src, 0.8, 1, &pe, WdmConfig::default(), &mut rng);
            let s16 = label_layer(src, src, 0.8, 16, &pe, WdmConfig::default(), &mut rng);
            wins_d1 += usize::from(s1.label() == Paradigm::Parallel);
            wins_d16 += usize::from(s16.label() == Paradigm::Parallel);
        }
        assert!(wins_d1 >= wins_d16, "parallel should win more at delay 1");
        assert!(wins_d1 > 0, "parallel should win somewhere dense at delay 1");
    }
}
