//! Grid sweep generation + estimate-mode labeling.
//!
//! Labeling one layer is "run both compilers via the pipeline in estimate
//! mode": the serial and parallel [`crate::paradigm::ParadigmCompiler`]s
//! report shape-only [`crate::paradigm::CostEstimate`]s and
//! [`SwitchPolicy::cheaper`] ranks them — the *same* code path the Ideal
//! switching mode uses, so the 16k-layer corpus and the real compiler can
//! never disagree about what "cheaper" means.

use crate::hardware::PeSpec;
use crate::io::csv;
use crate::model::connector::{Connector, SynapseDraw};
use crate::model::{LayerCharacter, LifParams, PopulationId, Projection, ProjectionId};
use crate::paradigm::parallel::wdm::WdmConfig;
use crate::paradigm::{LayerJob, ParadigmCompiler, Paradigm, ParallelCompiler, SerialCompiler};
use crate::rng::Rng;
use crate::switching::pipeline::{fan_out, CompileJob, CompilePipeline};
use crate::switching::SwitchPolicy;
use anyhow::{ensure, Context, Result};
use std::path::Path;

/// The paper's sweep axes.
#[derive(Clone, Debug)]
pub struct SweepConfig {
    pub sources: Vec<usize>,
    pub targets: Vec<usize>,
    pub densities: Vec<f64>,
    pub delays: Vec<u16>,
    pub seed: u64,
}

impl Default for SweepConfig {
    fn default() -> Self {
        // 10 × 10 × 10 × 16 = 16,000 layers, exactly the paper's grid.
        SweepConfig {
            sources: (1..=10).map(|i| i * 50).collect(),
            targets: (1..=10).map(|i| i * 50).collect(),
            densities: (1..=10).map(|i| i as f64 / 10.0).collect(),
            delays: (1..=16).collect(),
            seed: 2024,
        }
    }
}

impl SweepConfig {
    /// A reduced grid for tests and quick runs (2×2×3×4 = 48 layers).
    pub fn small() -> Self {
        SweepConfig {
            sources: vec![50, 250],
            targets: vec![50, 250],
            densities: vec![0.1, 0.5, 1.0],
            delays: vec![1, 4, 8, 16],
            seed: 7,
        }
    }

    /// A medium grid (4×4×5×8 = 640 layers) — dense enough to train a
    /// usable prejudger in integration tests without paying for the full
    /// 16k corpus.
    pub fn medium() -> Self {
        SweepConfig {
            sources: vec![50, 150, 300, 500],
            targets: vec![50, 150, 300, 500],
            densities: vec![0.1, 0.3, 0.5, 0.8, 1.0],
            delays: vec![1, 2, 4, 6, 8, 10, 13, 16],
            seed: 7,
        }
    }

    pub fn n_layers(&self) -> usize {
        self.sources.len() * self.targets.len() * self.densities.len() * self.delays.len()
    }

    /// Flatten the grid into `(src, tgt, density, delay, connector seed)`
    /// work items. Each item carries its own derived RNG seed so labeling
    /// results are independent of thread scheduling.
    pub fn items(&self) -> Vec<(usize, usize, f64, u16, u64)> {
        let mut items = Vec::with_capacity(self.n_layers());
        let mut idx = 0u64;
        for &src in &self.sources {
            for &tgt in &self.targets {
                for &d in &self.densities {
                    for &dl in &self.delays {
                        items.push((
                            src,
                            tgt,
                            d,
                            dl,
                            self.seed.wrapping_add(idx.wrapping_mul(0x9E3779B97F4A7C15)),
                        ));
                        idx += 1;
                    }
                }
            }
        }
        items
    }
}

/// One labeled layer.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Sample {
    pub character: LayerCharacter,
    pub serial_pes: usize,
    pub parallel_pes: usize,
}

impl Sample {
    /// The cheaper paradigm — [`SwitchPolicy::cheaper`], the same
    /// comparison Ideal-mode compilation runs (ties go to serial).
    pub fn label(&self) -> Paradigm {
        SwitchPolicy::cheaper(self.serial_pes, self.parallel_pes)
    }

    /// Classifier features `[delay_range, n_source, n_target, density]`.
    pub fn features(&self) -> [f64; 4] {
        self.character.features()
    }
}

/// The labeled corpus.
#[derive(Clone, Debug, Default)]
pub struct Dataset {
    pub samples: Vec<Sample>,
}

/// Column names of the dataset CSV, in order.
pub const CSV_COLUMNS: [&str; 7] = [
    "delay_range",
    "n_source",
    "n_target",
    "density",
    "serial_pes",
    "parallel_pes",
    "label",
];

impl Dataset {
    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    /// Feature matrix + label vector for classifier training.
    pub fn xy(&self) -> (Vec<[f64; 4]>, Vec<usize>) {
        (
            self.samples.iter().map(|s| s.features()).collect(),
            self.samples.iter().map(|s| s.label().label()).collect(),
        )
    }

    /// Persist to CSV.
    pub fn save_csv(&self, path: &Path) -> Result<()> {
        csv::write_csv(
            path,
            &CSV_COLUMNS,
            self.samples.iter().map(|s| {
                vec![
                    s.character.delay_range.to_string(),
                    s.character.n_source.to_string(),
                    s.character.n_target.to_string(),
                    format!("{:.6}", s.character.density),
                    s.serial_pes.to_string(),
                    s.parallel_pes.to_string(),
                    s.label().label().to_string(),
                ]
            }),
        )?;
        Ok(())
    }

    /// Load from CSV, validating the header against [`CSV_COLUMNS`] and
    /// every row's shape/content (errors name the offending 1-based line).
    pub fn load_csv(path: &Path) -> Result<Dataset> {
        let (header, rows) = csv::read_csv(path)?;
        ensure!(
            header == CSV_COLUMNS,
            "dataset csv {}: header {:?} does not match expected columns {:?}",
            path.display(),
            header,
            CSV_COLUMNS
        );
        let mut samples = Vec::with_capacity(rows.len());
        for (i, row) in rows.iter().enumerate() {
            let line = i + 2; // 1-based, after the header row
            ensure!(
                row.len() == CSV_COLUMNS.len(),
                "dataset csv {} line {line}: {} fields, expected {}",
                path.display(),
                row.len(),
                CSV_COLUMNS.len()
            );
            let f = |col: usize| -> Result<f64> {
                row[col].parse::<f64>().with_context(|| {
                    format!(
                        "dataset csv {} line {line}: bad number {:?} in column '{}'",
                        path.display(),
                        row[col],
                        CSV_COLUMNS[col]
                    )
                })
            };
            samples.push(Sample {
                character: LayerCharacter::new(
                    f(1)? as usize,
                    f(2)? as usize,
                    f(3)?,
                    f(0)? as u16,
                ),
                serial_pes: f(4)? as usize,
                parallel_pes: f(5)? as usize,
            });
        }
        Ok(Dataset { samples })
    }
}

/// Realize one random layer as a standalone projection (the dataset's and
/// benches' shared workload generator).
pub fn realize_layer(
    n_source: usize,
    n_target: usize,
    density: f64,
    delay_range: u16,
    rng: &mut Rng,
) -> Projection {
    let synapses = Connector::FixedProbability(density).build(
        n_source,
        n_target,
        SynapseDraw { delay_range, w_max: 127, ..Default::default() },
        rng,
    );
    Projection {
        id: ProjectionId(0),
        source: PopulationId(0),
        target: PopulationId(1),
        synapses,
        weight_scale: 1.0,
    }
}

/// Label one layer: realize its synapses, run **both** paradigm compilers
/// in estimate mode, count PEs.
///
/// The parallel estimate runs the real WDM build + two-stage split
/// (skipping chunk-weight materialization, which does not affect PE
/// counts); the serial estimate uses the closed-form Table I layout. The
/// character is the *nominal* sweep coordinate (what the classifier will
/// see at prejudging time — before any compilation).
pub fn label_layer(
    n_source: usize,
    n_target: usize,
    density: f64,
    delay_range: u16,
    pe: &PeSpec,
    config: WdmConfig,
    rng: &mut Rng,
) -> Sample {
    let proj = realize_layer(n_source, n_target, density, delay_range, rng);
    let character = LayerCharacter::new(n_source, n_target, density, delay_range);
    let job = LayerJob::new(&proj, n_source, n_target, LifParams::default())
        .with_character(character);
    let serial = SerialCompiler
        .estimate(&job, pe)
        .expect("sweep layer must be serially placeable");
    let parallel = ParallelCompiler::new(config)
        .estimate(&job, pe)
        .expect("sweep layer must be parallel placeable");
    Sample {
        character,
        serial_pes: serial.total_pes(),
        parallel_pes: parallel.total_pes(),
    }
}

/// Generate the full labeled grid through the compile pipeline's estimate
/// mode, parallelized over OS threads (auto thread count).
pub fn generate_grid(cfg: &SweepConfig, pe: &PeSpec, config: WdmConfig) -> Dataset {
    generate_grid_jobs(cfg, pe, config, 0)
}

/// [`generate_grid`] with an explicit worker-thread count (0 = one per
/// CPU, 1 = sequential).
pub fn generate_grid_jobs(
    cfg: &SweepConfig,
    pe: &PeSpec,
    config: WdmConfig,
    jobs: usize,
) -> Dataset {
    generate_grid_opts(cfg, pe, config, jobs, None)
        .expect("grid generation without an artifact store is infallible")
}

/// [`generate_grid_jobs`] plus an optional persistent artifact store: with
/// `artifact_dir` set, every per-layer estimate is looked up on disk
/// before running and written back after, so re-labeling the same sweep
/// (or an overlapping one) in a later process skips the estimate work
/// entirely (the CLI's `dataset --artifact-dir`). Fails only when the
/// store directory cannot be created/opened.
pub fn generate_grid_opts(
    cfg: &SweepConfig,
    pe: &PeSpec,
    config: WdmConfig,
    jobs: usize,
    artifact_dir: Option<&Path>,
) -> Result<Dataset> {
    let items = cfg.items();
    let mut pipeline = CompilePipeline::new(*pe, config).with_jobs(jobs);
    if let Some(dir) = artifact_dir {
        pipeline.set_artifact_dir(dir)?;
    }
    let samples = fan_out(pipeline.jobs(), items.len(), |i| {
        let (src, tgt, d, dl, seed) = items[i];
        let mut rng = Rng::new(seed);
        let proj = realize_layer(src, tgt, d, dl, &mut rng);
        let character = LayerCharacter::new(src, tgt, d, dl);
        let job = CompileJob::from_character(&proj, character, LifParams::default());
        let (serial, parallel) = pipeline
            .estimate_pair(&job)
            .expect("sweep layer must be placeable under both paradigms");
        Sample {
            character,
            serial_pes: serial.total_pes(),
            parallel_pes: parallel.total_pes(),
        }
    });
    Ok(Dataset { samples })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sweep_grid_sizes() {
        assert_eq!(SweepConfig::default().n_layers(), 16_000);
        assert_eq!(SweepConfig::small().n_layers(), 48);
        assert_eq!(SweepConfig::small().items().len(), 48);
    }

    #[test]
    fn small_grid_generates_and_labels() {
        let ds = generate_grid(&SweepConfig::small(), &PeSpec::default(), WdmConfig::default());
        assert_eq!(ds.len(), 48);
        assert!(ds.samples.iter().all(|s| s.serial_pes >= 1 && s.parallel_pes >= 2));
        // Both classes must appear — the paradigms genuinely trade off.
        let (_, y) = ds.xy();
        assert!(y.iter().any(|&l| l == 0), "some layer favors serial");
        assert!(y.iter().any(|&l| l == 1), "some layer favors parallel");
    }

    #[test]
    fn labeling_is_deterministic() {
        let pe = PeSpec::default();
        let a = label_layer(100, 100, 0.5, 4, &pe, WdmConfig::default(), &mut Rng::new(9));
        let b = label_layer(100, 100, 0.5, 4, &pe, WdmConfig::default(), &mut Rng::new(9));
        assert_eq!(a, b);
    }

    #[test]
    fn generation_is_scheduling_independent() {
        // Per-item seeds mean any worker count labels identically.
        let cfg = SweepConfig::small();
        let pe = PeSpec::default();
        let a = generate_grid_jobs(&cfg, &pe, WdmConfig::default(), 1);
        let b = generate_grid_jobs(&cfg, &pe, WdmConfig::default(), 8);
        assert_eq!(a.samples, b.samples);
    }

    #[test]
    fn grid_labels_match_label_layer() {
        // The pipeline estimate path and the direct label_layer path are
        // the same code; spot-check agreement on the small grid.
        let cfg = SweepConfig::small();
        let pe = PeSpec::default();
        let ds = generate_grid(&cfg, &pe, WdmConfig::default());
        for (&(src, tgt, d, dl, seed), sample) in cfg.items().iter().zip(&ds.samples) {
            let direct =
                label_layer(src, tgt, d, dl, &pe, WdmConfig::default(), &mut Rng::new(seed));
            assert_eq!(*sample, direct);
        }
    }

    #[test]
    fn labeling_from_a_warm_artifact_store_matches_cold() {
        let dir = std::env::temp_dir()
            .join(format!("s2a-grid-{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        let cfg = SweepConfig::small();
        let pe = PeSpec::default();
        let cold = generate_grid_opts(&cfg, &pe, WdmConfig::default(), 1, Some(&dir)).unwrap();
        let warm = generate_grid_opts(&cfg, &pe, WdmConfig::default(), 4, Some(&dir)).unwrap();
        assert_eq!(cold.samples, warm.samples, "disk-served labels must be identical");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn csv_roundtrip() {
        let ds = generate_grid(&SweepConfig::small(), &PeSpec::default(), WdmConfig::default());
        let dir = std::env::temp_dir().join("s2switch_ds_test");
        let path = dir.join("ds.csv");
        ds.save_csv(&path).unwrap();
        let back = Dataset::load_csv(&path).unwrap();
        assert_eq!(ds.samples.len(), back.samples.len());
        for (a, b) in ds.samples.iter().zip(&back.samples) {
            assert_eq!(a.serial_pes, b.serial_pes);
            assert_eq!(a.parallel_pes, b.parallel_pes);
            assert!((a.character.density - b.character.density).abs() < 1e-6);
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn load_csv_rejects_wrong_header() {
        let dir = std::env::temp_dir().join("s2switch_ds_hdr_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.csv");
        std::fs::write(&path, "a,b,c\n1,2,3\n").unwrap();
        let err = Dataset::load_csv(&path).unwrap_err().to_string();
        assert!(err.contains("header"), "unhelpful error: {err}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn load_csv_reports_offending_line() {
        let dir = std::env::temp_dir().join("s2switch_ds_row_test");
        std::fs::create_dir_all(&dir).unwrap();

        // Short row on (1-based) line 3.
        let path = dir.join("short.csv");
        std::fs::write(
            &path,
            "delay_range,n_source,n_target,density,serial_pes,parallel_pes,label\n\
             4,100,100,0.5,3,4,1\n\
             4,100,100\n",
        )
        .unwrap();
        let err = Dataset::load_csv(&path).unwrap_err().to_string();
        assert!(err.contains("line 3"), "unhelpful error: {err}");

        // Non-numeric field on line 2.
        let path = dir.join("nan.csv");
        std::fs::write(
            &path,
            "delay_range,n_source,n_target,density,serial_pes,parallel_pes,label\n\
             4,oops,100,0.5,3,4,1\n",
        )
        .unwrap();
        let err = format!("{:#}", Dataset::load_csv(&path).unwrap_err());
        assert!(err.contains("line 2") && err.contains("n_source"), "unhelpful error: {err}");

        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn delay_trend_matches_paper() {
        // Fig 3: parallel improves as delay range decreases. Compare the
        // parallel-win rate at delay 1 vs delay 16 on a dense slice.
        let pe = PeSpec::default();
        let mut wins_d1 = 0;
        let mut wins_d16 = 0;
        for (i, &src) in [100usize, 200, 300].iter().enumerate() {
            let mut rng = Rng::new(100 + i as u64);
            let s1 = label_layer(src, src, 0.8, 1, &pe, WdmConfig::default(), &mut rng);
            let s16 = label_layer(src, src, 0.8, 16, &pe, WdmConfig::default(), &mut rng);
            wins_d1 += usize::from(s1.label() == Paradigm::Parallel);
            wins_d16 += usize::from(s16.label() == Paradigm::Parallel);
        }
        assert!(wins_d1 >= wins_d16, "parallel should win more at delay 1");
        assert!(wins_d1 > 0, "parallel should win somewhere dense at delay 1");
    }
}
