//! Per-timestep energy models for both paradigms.
//!
//! Energy = static leakage over the step latency (per occupied PE) +
//! dynamic per-op costs: synaptic events and neuron updates on the ARM
//! path; MAC operations, SRAM weight reads and merge scatters on the
//! parallel path. Constants are SpiNNaker2-class orders of magnitude
//! (22 nm FDSOI, cf. refs [10][13]); the deliverable is the *comparison*,
//! not absolute joules.

use super::timing::LayerTiming;
use super::Activity;
use crate::hardware::PeSpec;
use crate::model::LayerCharacter;

/// Per-timestep energy result (picojoules).
#[derive(Clone, Copy, Debug, Default)]
pub struct LayerEnergy {
    pub step_pj: f64,
    pub dynamic_pj: f64,
    pub static_pj: f64,
}

/// Energy cost constants.
#[derive(Clone, Copy, Debug)]
pub struct EnergyModel {
    /// Static power per occupied PE (µW) — leakage + clock tree.
    pub static_uw_per_pe: f64,
    /// ARM energy per synaptic event (pJ): row fetch + accumulate.
    pub pj_per_event: f64,
    /// ARM energy per neuron update (pJ).
    pub pj_per_neuron: f64,
    /// Energy per MAC operation (pJ).
    pub pj_per_mac: f64,
    /// SRAM read energy per byte (pJ) — weight streaming into the array.
    pub pj_per_sram_byte: f64,
    /// Dominant-PE energy per merge-table scatter (pJ).
    pub pj_per_merge: f64,
}

impl Default for EnergyModel {
    fn default() -> Self {
        EnergyModel {
            static_uw_per_pe: 300.0,
            pj_per_event: 120.0,
            pj_per_neuron: 200.0,
            pj_per_mac: 2.5,
            pj_per_sram_byte: 1.2,
            pj_per_merge: 40.0,
        }
    }
}

impl EnergyModel {
    fn static_pj(&self, pes: usize, step_ns: f64) -> f64 {
        // µW × ns = femtojoules × 1e0 … convert: 1 µW = 1e-6 J/s =
        // 1e-6 pJ/ps = 1e-3 pJ/ns.
        self.static_uw_per_pe * 1e-3 * step_ns * pes as f64
    }

    /// Serial paradigm per-step energy.
    pub fn serial(
        &self,
        ch: &LayerCharacter,
        act: Activity,
        pes: usize,
        timing: &LayerTiming,
    ) -> LayerEnergy {
        let events = act.spikes_per_step * ch.density * ch.n_target as f64;
        let dynamic =
            events * self.pj_per_event + ch.n_target as f64 * self.pj_per_neuron;
        let stat = self.static_pj(pes, timing.step_ns);
        LayerEnergy { step_pj: dynamic + stat, dynamic_pj: dynamic, static_pj: stat }
    }

    /// Parallel paradigm per-step energy: the whole padded WDM is read and
    /// multiplied every step (the sparsity-blindness the paper's intro
    /// flags as the MAC path's weakness).
    pub fn parallel(
        &self,
        ch: &LayerCharacter,
        act: Activity,
        pes: usize,
        timing: &LayerTiming,
        pe: &PeSpec,
    ) -> LayerEnergy {
        let d = ch.delay_range as f64;
        let p_row = 1.0 - (1.0 - 1.0 / d).powf(ch.density * ch.n_target as f64);
        let rows_pad =
            ((ch.n_source as f64 * d * p_row) / pe.mac.cols as f64).ceil() * pe.mac.cols as f64;
        let cols_pad =
            (ch.n_target as f64 / pe.mac.rows as f64).ceil() * pe.mac.rows as f64;
        let macs = rows_pad * cols_pad;
        let merges = act.spikes_per_step * d * p_row;
        let dynamic = macs * self.pj_per_mac
            + macs * self.pj_per_sram_byte // 8-bit weights: 1 B per MAC
            + merges * self.pj_per_merge
            + ch.n_target as f64 * self.pj_per_neuron;
        let stat = self.static_pj(pes, timing.step_ns);
        LayerEnergy { step_pj: dynamic + stat, dynamic_pj: dynamic, static_pj: stat }
    }
}

#[cfg(test)]
mod tests {
    use super::super::timing::TimingModel;
    use super::*;

    fn setup(d: f64, delay: u16, rate: f64) -> (LayerCharacter, Activity) {
        let ch = LayerCharacter::new(255, 255, d, delay);
        let act = Activity { spikes_per_step: 255.0 * rate };
        (ch, act)
    }

    #[test]
    fn serial_energy_tracks_activity() {
        let e = EnergyModel::default();
        let t = TimingModel::default();
        let (ch, quiet) = setup(0.5, 8, 0.01);
        let (_, busy) = setup(0.5, 8, 0.5);
        let tq = t.serial(&ch, quiet);
        let tb = t.serial(&ch, busy);
        assert!(
            e.serial(&ch, busy, 2, &tb).dynamic_pj
                > 5.0 * e.serial(&ch, quiet, 2, &tq).dynamic_pj
        );
    }

    #[test]
    fn parallel_energy_is_mostly_activity_blind() {
        let e = EnergyModel::default();
        let t = TimingModel::default();
        let pe = PeSpec::default();
        let (ch, quiet) = setup(0.5, 8, 0.01);
        let (_, busy) = setup(0.5, 8, 0.5);
        let tq = t.parallel(&ch, quiet, 2, &pe);
        let tb = t.parallel(&ch, busy, 2, &pe);
        let eq = e.parallel(&ch, quiet, 3, &tq, &pe).dynamic_pj;
        let eb = e.parallel(&ch, busy, 3, &tb, &pe).dynamic_pj;
        assert!(eb < eq * 1.5, "MAC energy dominated by the dense matmul");
    }

    #[test]
    fn quiet_sparse_layer_cheaper_serially() {
        // The paper's intro: the serial paradigm "fully utilizes the input
        // sparsity to achieve energy savings".
        let e = EnergyModel::default();
        let t = TimingModel::default();
        let pe = PeSpec::default();
        let (ch, act) = setup(0.1, 8, 0.005);
        let ts = t.serial(&ch, act);
        let tp = t.parallel(&ch, act, 2, &pe);
        assert!(
            e.serial(&ch, act, 2, &ts).step_pj < e.parallel(&ch, act, 3, &tp, &pe).step_pj
        );
    }

    #[test]
    fn static_energy_scales_with_pes_and_time() {
        let e = EnergyModel::default();
        let a = e.static_pj(1, 1000.0);
        assert!((e.static_pj(4, 1000.0) - 4.0 * a).abs() < 1e-9);
        assert!((e.static_pj(1, 4000.0) - 4.0 * a).abs() < 1e-9);
    }
}
