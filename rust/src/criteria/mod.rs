//! Temporal and energy evaluation criteria — the paper's stated future
//! work, implemented as an extension:
//!
//! > "Our future work will integrate the temporal and energy performances
//! > as evaluation criteria into this switching system." (§IV-C)
//!
//! [`timing`] models per-timestep latency of each paradigm from first
//! principles (ARM event loop vs MAC-array systolic schedule + dominant
//! preprocessing); [`energy`] models per-timestep energy from per-op
//! costs; [`MultiCriteriaSwitch`] extends the memory-only switching
//! decision to a weighted (PE, time, energy) objective.
//!
//! Constants are order-of-magnitude SpiNNaker2-class numbers (150 MHz PE
//! clock, tens of pJ per SRAM word / MAC) — documented per field and
//! overridable; the *comparisons* between paradigms, not the absolute
//! joules, are the deliverable.

pub mod energy;
pub mod timing;

use crate::hardware::PeSpec;
use crate::model::LayerCharacter;
use crate::paradigm::Paradigm;

pub use energy::{EnergyModel, LayerEnergy};
pub use timing::{LayerTiming, TimingModel};

/// Workload statistics a criteria evaluation needs: expected activity per
/// timestep for one layer.
#[derive(Clone, Copy, Debug)]
pub struct Activity {
    /// Expected source spikes per timestep.
    pub spikes_per_step: f64,
}

impl Activity {
    /// Assume each source neuron fires with rate `rate` per timestep.
    pub fn from_rate(ch: &LayerCharacter, rate: f64) -> Activity {
        Activity { spikes_per_step: ch.n_source as f64 * rate }
    }
}

/// Relative weights of the three criteria. Memory-only (the paper's
/// published system) is `{1, 0, 0}`.
#[derive(Clone, Copy, Debug)]
pub struct CriteriaWeights {
    pub memory: f64,
    pub time: f64,
    pub energy: f64,
}

impl CriteriaWeights {
    pub fn memory_only() -> Self {
        CriteriaWeights { memory: 1.0, time: 0.0, energy: 0.0 }
    }

    pub fn balanced() -> Self {
        CriteriaWeights { memory: 1.0, time: 1.0, energy: 1.0 }
    }
}

/// Per-paradigm criteria evaluation for one layer.
#[derive(Clone, Copy, Debug)]
pub struct CriteriaScore {
    pub pes: usize,
    pub time: LayerTiming,
    pub energy: LayerEnergy,
}

/// The extended switching decision: weighted normalized score over
/// (PEs, step latency, step energy). Each criterion is normalized by the
/// *other* paradigm's value, so weights express relative importance rather
/// than unit conversions.
pub struct MultiCriteriaSwitch {
    pub timing: TimingModel,
    pub energy: EnergyModel,
    pub weights: CriteriaWeights,
}

impl MultiCriteriaSwitch {
    pub fn new(weights: CriteriaWeights) -> Self {
        MultiCriteriaSwitch {
            timing: TimingModel::default(),
            energy: EnergyModel::default(),
            weights,
        }
    }

    /// Evaluate both paradigms for a layer; returns (serial, parallel).
    ///
    /// `serial_pes`/`parallel_pes` come from the compilers (as in the
    /// dataset labeler); activity drives the time/energy models.
    pub fn evaluate(
        &self,
        ch: &LayerCharacter,
        act: Activity,
        serial_pes: usize,
        parallel_pes: usize,
        pe: &PeSpec,
    ) -> (CriteriaScore, CriteriaScore) {
        let t_s = self.timing.serial(ch, act);
        let t_p = self.timing.parallel(ch, act, parallel_pes.saturating_sub(1).max(1), pe);
        let e_s = self.energy.serial(ch, act, serial_pes, &t_s);
        let e_p = self.energy.parallel(ch, act, parallel_pes, &t_p, pe);
        (
            CriteriaScore { pes: serial_pes, time: t_s, energy: e_s },
            CriteriaScore { pes: parallel_pes, time: t_p, energy: e_p },
        )
    }

    /// The weighted decision. Ties favor serial (as in the memory-only
    /// labeler).
    pub fn decide(
        &self,
        ch: &LayerCharacter,
        act: Activity,
        serial_pes: usize,
        parallel_pes: usize,
        pe: &PeSpec,
    ) -> Paradigm {
        let (s, p) = self.evaluate(ch, act, serial_pes, parallel_pes, pe);
        let norm = |a: f64, b: f64| if a + b > 0.0 { a / (a + b) } else { 0.5 };
        let w = self.weights;
        let score_s = w.memory * norm(s.pes as f64, p.pes as f64)
            + w.time * norm(s.time.step_ns, p.time.step_ns)
            + w.energy * norm(s.energy.step_pj, p.energy.step_pj);
        let score_p = w.memory * norm(p.pes as f64, s.pes as f64)
            + w.time * norm(p.time.step_ns, s.time.step_ns)
            + w.energy * norm(p.energy.step_pj, s.energy.step_pj);
        if score_p < score_s {
            Paradigm::Parallel
        } else {
            Paradigm::Serial
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pe() -> PeSpec {
        PeSpec::default()
    }

    #[test]
    fn memory_only_matches_pe_comparison() {
        let sw = MultiCriteriaSwitch::new(CriteriaWeights::memory_only());
        let ch = LayerCharacter::new(255, 255, 0.5, 8);
        let act = Activity::from_rate(&ch, 0.1);
        assert_eq!(sw.decide(&ch, act, 3, 5, &pe()), Paradigm::Serial);
        assert_eq!(sw.decide(&ch, act, 5, 3, &pe()), Paradigm::Parallel);
        assert_eq!(sw.decide(&ch, act, 3, 3, &pe()), Paradigm::Serial, "tie → serial");
    }

    #[test]
    fn high_activity_dense_layers_favor_parallel_in_time() {
        // Event-driven serial degrades with spike rate × fan-out; the MAC
        // array's dense matmul does not.
        let sw = MultiCriteriaSwitch::new(CriteriaWeights { memory: 0.0, time: 1.0, energy: 0.0 });
        let ch = LayerCharacter::new(255, 255, 1.0, 2);
        let busy = Activity::from_rate(&ch, 0.5);
        assert_eq!(sw.decide(&ch, busy, 4, 4, &pe()), Paradigm::Parallel);
    }

    #[test]
    fn sparse_quiet_layers_favor_serial_in_energy() {
        // Nearly-silent sparse input: event-driven processing does almost
        // nothing; the MAC array still multiplies the whole (padded) map.
        let sw =
            MultiCriteriaSwitch::new(CriteriaWeights { memory: 0.0, time: 0.0, energy: 1.0 });
        let ch = LayerCharacter::new(255, 255, 0.05, 8);
        let quiet = Activity::from_rate(&ch, 0.001);
        assert_eq!(sw.decide(&ch, quiet, 2, 2, &pe()), Paradigm::Serial);
    }

    #[test]
    fn weights_shift_the_decision() {
        // A layer where memory favors serial but time favors parallel:
        // the weighting determines the outcome.
        let ch = LayerCharacter::new(255, 255, 1.0, 2);
        let busy = Activity::from_rate(&ch, 0.5);
        let mem_only = MultiCriteriaSwitch::new(CriteriaWeights::memory_only());
        let time_heavy =
            MultiCriteriaSwitch::new(CriteriaWeights { memory: 0.1, time: 10.0, energy: 0.0 });
        let d_mem = mem_only.decide(&ch, busy, 3, 5, &pe());
        let d_time = time_heavy.decide(&ch, busy, 3, 5, &pe());
        assert_eq!(d_mem, Paradigm::Serial);
        assert_eq!(d_time, Paradigm::Parallel);
    }
}
