//! Per-timestep latency models for both paradigms.
//!
//! Serial (ARM, event-driven, §III-A): latency ≈ fixed tick overhead +
//! synaptic-event processing (each arriving spike walks its matrix block)
//! + time-triggered neural update over resident neurons.
//!
//! Parallel (MAC array, §III-B): dominant preprocessing (each spike's
//! merge-table entries scatter into the stacked input) + the slowest
//! subordinate's systolic matmul (64 MACs/cycle on the 4×16 array) +
//! current reduction + neural update on the dominant.

use super::Activity;
use crate::hardware::PeSpec;
use crate::model::LayerCharacter;

/// Paradigm-agnostic timing result (per simulated timestep).
#[derive(Clone, Copy, Debug, Default)]
pub struct LayerTiming {
    pub step_ns: f64,
    /// Dominant contributor, for reports.
    pub compute_ns: f64,
    pub overhead_ns: f64,
}

/// Clock + per-op cycle costs (SpiNNaker2-class: 150 MHz PEs).
#[derive(Clone, Copy, Debug)]
pub struct TimingModel {
    /// PE clock (Hz).
    pub clock_hz: f64,
    /// Fixed timer-tick overhead per step (cycles).
    pub tick_cycles: f64,
    /// ARM cycles per synaptic event (row fetch + ring-buffer accumulate).
    pub cycles_per_event: f64,
    /// ARM cycles per neuron LIF update.
    pub cycles_per_neuron: f64,
    /// Dominant cycles per merge-table entry per spike (stacked scatter).
    pub cycles_per_merge: f64,
    /// MACs per cycle on the 4×16 array.
    pub macs_per_cycle: f64,
}

impl Default for TimingModel {
    fn default() -> Self {
        TimingModel {
            clock_hz: 150e6,
            tick_cycles: 2_000.0,
            cycles_per_event: 12.0,
            cycles_per_neuron: 25.0,
            cycles_per_merge: 6.0,
            macs_per_cycle: 64.0,
        }
    }
}

impl TimingModel {
    fn ns(&self, cycles: f64) -> f64 {
        cycles / self.clock_hz * 1e9
    }

    /// Serial paradigm per-step latency. Synaptic events per step =
    /// spikes × fan-out (density × n_target).
    pub fn serial(&self, ch: &LayerCharacter, act: Activity) -> LayerTiming {
        let events = act.spikes_per_step * ch.density * ch.n_target as f64;
        let compute =
            events * self.cycles_per_event + ch.n_target as f64 * self.cycles_per_neuron;
        LayerTiming {
            step_ns: self.ns(compute + self.tick_cycles),
            compute_ns: self.ns(compute),
            overhead_ns: self.ns(self.tick_cycles),
        }
    }

    /// Parallel paradigm per-step latency with `n_subordinates` chunks.
    ///
    /// The WDM is consumed whole every step regardless of activity (that is
    /// the MAC trade-off); rows ≈ expected non-empty (source, delay) lanes,
    /// padded to the array geometry, split across subordinates which run in
    /// parallel (the slowest chunk governs).
    pub fn parallel(
        &self,
        ch: &LayerCharacter,
        act: Activity,
        n_subordinates: usize,
        pe: &PeSpec,
    ) -> LayerTiming {
        let d = ch.delay_range as f64;
        // Expected kept rows after zero-row elimination: lane (s, δ) is
        // non-empty with prob 1 − (1 − 1/D)^(density·n_target).
        let p_row = 1.0 - (1.0 - 1.0 / d).powf(ch.density * ch.n_target as f64);
        let rows = ch.n_source as f64 * d * p_row;
        let rows_pad = (rows / pe.mac.cols as f64).ceil() * pe.mac.cols as f64;
        let cols_pad =
            (ch.n_target as f64 / pe.mac.rows as f64).ceil() * pe.mac.rows as f64;
        let macs_per_sub = rows_pad * cols_pad / n_subordinates.max(1) as f64;
        let mac_cycles = macs_per_sub / self.macs_per_cycle;

        // Dominant: merge-table scatter per spike (≈ one entry per kept
        // delay slot of that source) + reduction + neural update.
        let merges = act.spikes_per_step * d * p_row;
        let dom_cycles = merges * self.cycles_per_merge
            + ch.n_target as f64 * self.cycles_per_neuron
            + n_subordinates as f64 * ch.n_target as f64; // current reduction
        let compute = mac_cycles + dom_cycles;
        LayerTiming {
            step_ns: self.ns(compute + self.tick_cycles),
            compute_ns: self.ns(compute),
            overhead_ns: self.ns(self.tick_cycles),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ch(d: f64, delay: u16) -> LayerCharacter {
        LayerCharacter::new(255, 255, d, delay)
    }

    #[test]
    fn serial_latency_scales_with_activity() {
        let m = TimingModel::default();
        let quiet = m.serial(&ch(0.5, 8), Activity { spikes_per_step: 1.0 });
        let busy = m.serial(&ch(0.5, 8), Activity { spikes_per_step: 100.0 });
        assert!(busy.step_ns > quiet.step_ns * 5.0, "event-driven cost tracks spikes");
    }

    #[test]
    fn parallel_latency_is_activity_insensitive() {
        let m = TimingModel::default();
        let pe = PeSpec::default();
        let quiet = m.parallel(&ch(0.5, 8), Activity { spikes_per_step: 1.0 }, 2, &pe);
        let busy = m.parallel(&ch(0.5, 8), Activity { spikes_per_step: 100.0 }, 2, &pe);
        assert!(
            busy.step_ns < quiet.step_ns * 1.5,
            "MAC matmul dominates; spikes only touch the merge scatter"
        );
    }

    #[test]
    fn more_subordinates_reduce_parallel_latency() {
        let m = TimingModel::default();
        let pe = PeSpec::default();
        let one = m.parallel(&ch(1.0, 16), Activity { spikes_per_step: 10.0 }, 1, &pe);
        let eight = m.parallel(&ch(1.0, 16), Activity { spikes_per_step: 10.0 }, 8, &pe);
        assert!(eight.step_ns < one.step_ns, "work splits across chunks");
    }

    #[test]
    fn crossover_exists_in_activity() {
        // On a sparse layer, low activity favors the event-driven serial
        // path; high activity favors the MAC array — the temporal analogue
        // of the paper's memory trade-off. (On fully dense layers parallel
        // wins at any activity, which the test above covers.)
        let m = TimingModel::default();
        let pe = PeSpec::default();
        let c = ch(0.05, 2);
        let low = Activity { spikes_per_step: 2.0 };
        let high = Activity { spikes_per_step: 200.0 };
        assert!(m.serial(&c, low).step_ns < m.parallel(&c, low, 1, &pe).step_ns);
        assert!(m.serial(&c, high).step_ns > m.parallel(&c, high, 1, &pe).step_ns);
    }
}
