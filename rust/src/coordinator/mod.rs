//! Leader pipeline: ties dataset acquisition, classifier training, model
//! persistence and end-to-end compile+simulate runs together behind one
//! API (and the `s2switch` CLI in `main.rs`).
//!
//! Concurrency note: the offline vendored crate set has no tokio, so the
//! coordinator parallelizes CPU-bound stages with scoped OS threads
//! (layer compilation and estimate-mode labeling through
//! [`crate::switching::CompilePipeline`], per-seed classifier training in
//! [`train_roster`]) — see DESIGN.md §2.

use crate::classifier::{accuracy, roster, train_test_split, AdaBoost, Classifier};
use crate::dataset::{generate_grid_opts, Dataset, SweepConfig};
use crate::hardware::PeSpec;
use crate::io::Json;
use crate::paradigm::parallel::WdmConfig;
use crate::switching::SwitchingSystem;
use anyhow::{Context, Result};
use std::path::Path;
use std::time::Instant;

/// Accuracy summary for one classifier across seeds (Fig. 4's bars +
/// red ranges).
#[derive(Clone, Debug)]
pub struct ClassifierScore {
    pub name: &'static str,
    pub accuracies: Vec<f64>,
}

impl ClassifierScore {
    pub fn mean(&self) -> f64 {
        self.accuracies.iter().sum::<f64>() / self.accuracies.len().max(1) as f64
    }

    pub fn min(&self) -> f64 {
        self.accuracies.iter().copied().fold(f64::INFINITY, f64::min)
    }

    pub fn max(&self) -> f64 {
        self.accuracies.iter().copied().fold(f64::NEG_INFINITY, f64::max)
    }
}

/// Generate (or load) the 16k-layer dataset, caching it as CSV.
pub fn dataset_cached(path: &Path, cfg: &SweepConfig) -> Result<Dataset> {
    dataset_cached_jobs(path, cfg, 0)
}

/// [`dataset_cached`] with an explicit labeling worker-thread count
/// (0 = auto).
pub fn dataset_cached_jobs(path: &Path, cfg: &SweepConfig, jobs: usize) -> Result<Dataset> {
    dataset_cached_opts(path, cfg, jobs, None)
}

/// [`dataset_cached_jobs`] plus an optional persistent artifact store
/// threaded into the labeling pipeline (`dataset --artifact-dir`): warm
/// stores serve per-layer estimates from disk instead of re-running them.
pub fn dataset_cached_opts(
    path: &Path,
    cfg: &SweepConfig,
    jobs: usize,
    artifact_dir: Option<&Path>,
) -> Result<Dataset> {
    if path.exists() {
        let ds = Dataset::load_csv(path)?;
        if ds.len() == cfg.n_layers() {
            return Ok(ds);
        }
        eprintln!(
            "cached dataset at {} has {} rows (want {}), regenerating",
            path.display(),
            ds.len(),
            cfg.n_layers()
        );
    }
    let t0 = Instant::now();
    let ds =
        generate_grid_opts(cfg, &PeSpec::default(), WdmConfig::default(), jobs, artifact_dir)
            .context("attaching the labeling artifact store")?;
    eprintln!("labeled {} layers in {:.2?}", ds.len(), t0.elapsed());
    ds.save_csv(path)?;
    Ok(ds)
}

/// Train the full 12-classifier roster over `n_seeds` train/test splits
/// (paper: "training with 20 different random seeds"), in parallel across
/// seeds. Returns per-classifier scores in roster order.
pub fn train_roster(dataset: &Dataset, n_seeds: usize) -> Vec<ClassifierScore> {
    let (x, y) = dataset.xy();
    let names: Vec<&'static str> = roster(0).iter().map(|c| c.name()).collect();
    // accuracies[seed][classifier]
    let mut per_seed: Vec<Vec<f64>> = vec![Vec::new(); n_seeds];

    let n_threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
    let chunk = n_seeds.div_ceil(n_threads.max(1)).max(1);
    std::thread::scope(|scope| {
        for (chunk_idx, slot) in per_seed.chunks_mut(chunk).enumerate() {
            let x = &x;
            let y = &y;
            scope.spawn(move || {
                for (k, out) in slot.iter_mut().enumerate() {
                    let seed = (chunk_idx * chunk + k) as u64;
                    let (xtr, ytr, xte, yte) = train_test_split(x, y, 0.2, seed);
                    *out = roster(seed)
                        .iter_mut()
                        .map(|c| {
                            c.train(&xtr, &ytr);
                            accuracy(&c.predict_batch(&xte), &yte)
                        })
                        .collect();
                }
            });
        }
    });

    names
        .into_iter()
        .enumerate()
        .map(|(ci, name)| ClassifierScore {
            name,
            accuracies: per_seed.iter().map(|row| row[ci]).collect(),
        })
        .collect()
}

/// Train the deployed AdaBoost on the full corpus and persist it as JSON.
pub fn train_and_save_adaboost(dataset: &Dataset, n_rounds: usize, path: &Path) -> Result<f64> {
    let (x, y) = dataset.xy();
    // Hold out 20% to report an honest accuracy next to the paper's 91.69%.
    let (xtr, ytr, xte, yte) = train_test_split(&x, &y, 0.2, 42);
    let mut ab = AdaBoost::new(n_rounds);
    ab.train(&xtr, &ytr);
    let acc = accuracy(&ab.predict_batch(&xte), &yte);
    let json = ab.to_json().context("adaboost serializes")?;
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    std::fs::write(path, json.to_string_compact())?;
    Ok(acc)
}

/// Load a previously saved AdaBoost model into a switching system.
pub fn load_switching_system(model_path: &Path, pe: PeSpec) -> Result<SwitchingSystem> {
    let text = std::fs::read_to_string(model_path)
        .with_context(|| format!("reading {}", model_path.display()))?;
    let json = Json::parse(&text).map_err(|e| anyhow::anyhow!("parsing model json: {e}"))?;
    let ab = AdaBoost::from_json(&json).context("malformed adaboost model json")?;
    Ok(SwitchingSystem::with_classifier(Box::new(ab), pe))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::generate_grid;

    fn small_dataset() -> Dataset {
        generate_grid(&SweepConfig::small(), &PeSpec::default(), WdmConfig::default())
    }

    #[test]
    fn roster_training_produces_sane_scores() {
        let ds = small_dataset();
        let scores = train_roster(&ds, 2);
        assert_eq!(scores.len(), 12);
        for s in &scores {
            assert_eq!(s.accuracies.len(), 2);
            assert!(s.min() >= 0.0 && s.max() <= 1.0);
            // 48-sample corpus: everything should beat coin flips on average
            // except possibly the weakest learners; keep a loose floor.
            assert!(s.mean() > 0.3, "{} mean {}", s.name, s.mean());
        }
    }

    #[test]
    fn adaboost_save_load_roundtrip() {
        let ds = small_dataset();
        let dir = std::env::temp_dir().join("s2switch_coord_test");
        let path = dir.join("model.json");
        let acc = train_and_save_adaboost(&ds, 40, &path).unwrap();
        assert!(acc > 0.5, "held-out accuracy {acc}");
        let sys = load_switching_system(&path, PeSpec::default()).unwrap();
        // The loaded system prejudges without compiling.
        let ch = crate::model::LayerCharacter::new(255, 255, 1.0, 1);
        let _ = sys.prejudge(&ch);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn dataset_cache_roundtrip() {
        let dir = std::env::temp_dir().join("s2switch_cache_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("ds.csv");
        let cfg = SweepConfig::small();
        let a = dataset_cached(&path, &cfg).unwrap();
        assert!(path.exists());
        let b = dataset_cached(&path, &cfg).unwrap(); // loads from cache
        assert_eq!(a.len(), b.len());
        std::fs::remove_dir_all(&dir).ok();
    }
}
