//! Tiny CSV reader/writer for the dataset corpus.
//!
//! The dataset rows are purely numeric, so no quoting support is needed; we
//! still reject fields containing commas/newlines on write to stay honest.

use std::io::{BufRead, BufReader, Write};
use std::path::Path;

/// Write a CSV file with a header row.
pub fn write_csv(
    path: &Path,
    header: &[&str],
    rows: impl IntoIterator<Item = Vec<String>>,
) -> std::io::Result<()> {
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    writeln!(f, "{}", header.join(","))?;
    for row in rows {
        for field in &row {
            assert!(
                !field.contains(',') && !field.contains('\n'),
                "CSV field needs quoting (unsupported): {field:?}"
            );
        }
        writeln!(f, "{}", row.join(","))?;
    }
    Ok(())
}

/// Read a CSV file, returning (header, rows).
pub fn read_csv(path: &Path) -> std::io::Result<(Vec<String>, Vec<Vec<String>>)> {
    let f = BufReader::new(std::fs::File::open(path)?);
    let mut lines = f.lines();
    let header = match lines.next() {
        Some(h) => h?.split(',').map(str::to_string).collect(),
        None => Vec::new(),
    };
    let mut rows = Vec::new();
    for line in lines {
        let line = line?;
        if line.is_empty() {
            continue;
        }
        rows.push(line.split(',').map(str::to_string).collect());
    }
    Ok((header, rows))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let dir = std::env::temp_dir().join("s2switch_csv_test");
        let path = dir.join("t.csv");
        write_csv(
            &path,
            &["a", "b"],
            vec![vec!["1".into(), "2.5".into()], vec!["3".into(), "4".into()]],
        )
        .unwrap();
        let (hdr, rows) = read_csv(&path).unwrap();
        assert_eq!(hdr, vec!["a", "b"]);
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0], vec!["1", "2.5"]);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    #[should_panic(expected = "CSV field needs quoting")]
    fn rejects_commas_in_fields() {
        let dir = std::env::temp_dir().join("s2switch_csv_test2");
        let path = dir.join("t.csv");
        write_csv(&path, &["a"], vec![vec!["1,2".into()]]).unwrap();
    }

    #[test]
    fn skips_blank_lines() {
        let dir = std::env::temp_dir().join("s2switch_csv_test3");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.csv");
        std::fs::write(&path, "h\n1\n\n2\n").unwrap();
        let (_, rows) = read_csv(&path).unwrap();
        assert_eq!(rows.len(), 2);
        std::fs::remove_dir_all(&dir).ok();
    }
}
