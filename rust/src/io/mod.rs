//! Lightweight JSON and CSV serialization (serde substitute — the offline
//! vendored crate set has no serde).
//!
//! [`json`] provides a small value model + writer + recursive-descent parser
//! sufficient for classifier model persistence and experiment manifests.
//! [`csv`] provides dataset reading/writing for the 16k-layer corpus.

pub mod csv;
pub mod json;

pub use json::Json;
