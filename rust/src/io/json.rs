//! Minimal JSON value model, writer and parser.
//!
//! Supports the full JSON grammar except for exotic escapes beyond
//! `\" \\ \/ \b \f \n \r \t \uXXXX`. Numbers round-trip through f64, which is
//! sufficient for classifier parameters and experiment metadata.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value. Objects use a BTreeMap so emission order is deterministic.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Build an object from key/value pairs.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// Build an array of numbers.
    pub fn nums<I: IntoIterator<Item = f64>>(xs: I) -> Json {
        Json::Arr(xs.into_iter().map(Json::Num).collect())
    }

    /// Get an object field.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Interpret as f64.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    /// Interpret as usize (must be a non-negative integral number).
    pub fn as_usize(&self) -> Option<usize> {
        match self {
            Json::Num(x) if *x >= 0.0 && x.fract() == 0.0 => Some(*x as usize),
            _ => None,
        }
    }

    /// Interpret as str.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// Interpret as array slice.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    /// Interpret as bool.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Array of f64 convenience accessor.
    pub fn as_f64_vec(&self) -> Option<Vec<f64>> {
        self.as_arr()?.iter().map(|v| v.as_f64()).collect()
    }

    /// Serialize to a compact string.
    pub fn to_string_compact(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 9e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    /// Parse a JSON document.
    pub fn parse(text: &str) -> Result<Json, String> {
        let bytes = text.as_bytes();
        let mut pos = 0;
        let v = parse_value(bytes, &mut pos)?;
        skip_ws(bytes, &mut pos);
        if pos != bytes.len() {
            return Err(format!("trailing characters at byte {pos}"));
        }
        Ok(v)
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

fn skip_ws(b: &[u8], pos: &mut usize) {
    while *pos < b.len() && matches!(b[*pos], b' ' | b'\t' | b'\n' | b'\r') {
        *pos += 1;
    }
}

fn parse_value(b: &[u8], pos: &mut usize) -> Result<Json, String> {
    skip_ws(b, pos);
    if *pos >= b.len() {
        return Err("unexpected end of input".into());
    }
    match b[*pos] {
        b'n' => expect_lit(b, pos, "null", Json::Null),
        b't' => expect_lit(b, pos, "true", Json::Bool(true)),
        b'f' => expect_lit(b, pos, "false", Json::Bool(false)),
        b'"' => parse_string(b, pos).map(Json::Str),
        b'[' => {
            *pos += 1;
            let mut arr = Vec::new();
            skip_ws(b, pos);
            if *pos < b.len() && b[*pos] == b']' {
                *pos += 1;
                return Ok(Json::Arr(arr));
            }
            loop {
                arr.push(parse_value(b, pos)?);
                skip_ws(b, pos);
                match b.get(*pos) {
                    Some(b',') => *pos += 1,
                    Some(b']') => {
                        *pos += 1;
                        return Ok(Json::Arr(arr));
                    }
                    _ => return Err(format!("expected ',' or ']' at byte {pos:?}")),
                }
            }
        }
        b'{' => {
            *pos += 1;
            let mut map = BTreeMap::new();
            skip_ws(b, pos);
            if *pos < b.len() && b[*pos] == b'}' {
                *pos += 1;
                return Ok(Json::Obj(map));
            }
            loop {
                skip_ws(b, pos);
                let key = parse_string(b, pos)?;
                skip_ws(b, pos);
                if b.get(*pos) != Some(&b':') {
                    return Err(format!("expected ':' at byte {pos:?}"));
                }
                *pos += 1;
                let val = parse_value(b, pos)?;
                map.insert(key, val);
                skip_ws(b, pos);
                match b.get(*pos) {
                    Some(b',') => *pos += 1,
                    Some(b'}') => {
                        *pos += 1;
                        return Ok(Json::Obj(map));
                    }
                    _ => return Err(format!("expected ',' or '}}' at byte {pos:?}")),
                }
            }
        }
        _ => parse_number(b, pos),
    }
}

fn expect_lit(b: &[u8], pos: &mut usize, lit: &str, val: Json) -> Result<Json, String> {
    if b[*pos..].starts_with(lit.as_bytes()) {
        *pos += lit.len();
        Ok(val)
    } else {
        Err(format!("invalid literal at byte {pos:?}"))
    }
}

fn parse_string(b: &[u8], pos: &mut usize) -> Result<String, String> {
    if b.get(*pos) != Some(&b'"') {
        return Err(format!("expected '\"' at byte {pos:?}"));
    }
    *pos += 1;
    let mut out = String::new();
    while *pos < b.len() {
        match b[*pos] {
            b'"' => {
                *pos += 1;
                return Ok(out);
            }
            b'\\' => {
                *pos += 1;
                match b.get(*pos) {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let hex = std::str::from_utf8(&b[*pos + 1..*pos + 5])
                            .map_err(|_| "bad \\u escape")?;
                        let code =
                            u32::from_str_radix(hex, 16).map_err(|_| "bad \\u escape")?;
                        out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        *pos += 4;
                    }
                    _ => return Err("bad escape".into()),
                }
                *pos += 1;
            }
            _ => {
                // copy a full UTF-8 sequence
                let start = *pos;
                let len = utf8_len(b[*pos]);
                *pos += len;
                out.push_str(
                    std::str::from_utf8(&b[start..*pos]).map_err(|_| "invalid utf8")?,
                );
            }
        }
    }
    Err("unterminated string".into())
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7f => 1,
        0xc0..=0xdf => 2,
        0xe0..=0xef => 3,
        _ => 4,
    }
}

fn parse_number(b: &[u8], pos: &mut usize) -> Result<Json, String> {
    let start = *pos;
    while *pos < b.len()
        && matches!(b[*pos], b'-' | b'+' | b'.' | b'e' | b'E' | b'0'..=b'9')
    {
        *pos += 1;
    }
    let s = std::str::from_utf8(&b[start..*pos]).map_err(|_| "bad number")?;
    s.parse::<f64>().map(Json::Num).map_err(|e| format!("bad number '{s}': {e}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_object() {
        let j = Json::obj(vec![
            ("name", Json::Str("adaboost".into())),
            ("acc", Json::Num(0.9169)),
            ("stumps", Json::nums(vec![1.0, 2.0, 3.0])),
            ("ok", Json::Bool(true)),
            ("none", Json::Null),
        ]);
        let s = j.to_string_compact();
        let back = Json::parse(&s).unwrap();
        assert_eq!(j, back);
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "x\ny"}], "c": -1.5e3}"#).unwrap();
        assert_eq!(j.get("c").unwrap().as_f64(), Some(-1500.0));
        let arr = j.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[2].get("b").unwrap().as_str(), Some("x\ny"));
    }

    #[test]
    fn parse_rejects_trailing() {
        assert!(Json::parse("{} extra").is_err());
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(Json::parse("{broken").is_err());
        assert!(Json::parse("[1,,2]").is_err());
    }

    #[test]
    fn integers_emit_without_decimal() {
        assert_eq!(Json::Num(42.0).to_string_compact(), "42");
        assert_eq!(Json::Num(0.5).to_string_compact(), "0.5");
    }

    #[test]
    fn escaped_strings_roundtrip() {
        let j = Json::Str("quote\" backslash\\ tab\t newline\n".into());
        let back = Json::parse(&j.to_string_compact()).unwrap();
        assert_eq!(j, back);
    }

    #[test]
    fn unicode_roundtrip() {
        let j = Json::Str("héllo 世界 𝄞".into());
        let back = Json::parse(&j.to_string_compact()).unwrap();
        assert_eq!(j, back);
    }

    #[test]
    fn random_values_roundtrip_property() {
        use crate::prop::{Gen, Prop};

        fn gen_json(g: &mut Gen, depth: usize) -> Json {
            match if depth == 0 { g.usize(0, 3) } else { g.usize(0, 5) } {
                0 => Json::Null,
                1 => Json::Bool(g.bool(0.5)),
                2 => Json::Num((g.i64(-1_000_000, 1_000_000) as f64) / 64.0),
                3 => {
                    let n = g.usize(0, 12);
                    Json::Str(
                        (0..n)
                            .map(|i| {
                                // Mix in escapes and non-ASCII.
                                ['a', '"', '\\', '\n', 'é', '世', '\t'][(g.usize(0, 6) + i) % 7]
                            })
                            .collect(),
                    )
                }
                4 => {
                    let n = g.usize(0, 4);
                    Json::Arr((0..n).map(|_| gen_json(g, depth - 1)).collect())
                }
                _ => {
                    let n = g.usize(0, 4);
                    Json::Obj(
                        (0..n)
                            .map(|i| (format!("k{i}"), gen_json(g, depth - 1)))
                            .collect(),
                    )
                }
            }
        }

        Prop::new("json roundtrip", 300).check(
            |g| gen_json(g, 3),
            |j| Json::parse(&j.to_string_compact()).as_ref() == Ok(j),
        );
    }
}
