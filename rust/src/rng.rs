//! Deterministic pseudo-random number generation.
//!
//! The `rand` crate is not available in the offline vendored set, so the
//! dataset generator, classifier training, and property-test kit all draw
//! from this small, fully deterministic xoshiro256++ implementation (seeded
//! through splitmix64, per the reference C implementations by Blackman &
//! Vigna). Determinism matters here: the paper's Fig. 4 reports accuracy
//! ranges over 20 seeds, which we reproduce bit-for-bit across runs.

/// splitmix64 step — used for seeding and as a cheap standalone generator.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// xoshiro256++ generator. Not cryptographic; fast and high quality for
/// simulation workloads.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create a generator from a 64-bit seed (expanded via splitmix64).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        // 53 high bits -> [0,1)
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform usize in [0, n). n must be > 0.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire's multiply-shift rejection-free approximation is fine here;
        // bias is < 2^-53 for the ranges we use. Use 128-bit multiply.
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Uniform i64 in [lo, hi] inclusive.
    #[inline]
    pub fn range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(lo <= hi);
        lo + self.below((hi - lo + 1) as usize) as i64
    }

    /// Uniform f64 in [lo, hi).
    #[inline]
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    /// Bernoulli trial with probability `p`.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller (one value per call; simple, adequate).
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.f64();
            if u1 > 1e-300 {
                let u2 = self.f64();
                return (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from [0, n) (k <= n), order randomized.
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        self.shuffle(&mut idx);
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..1000 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..100).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 3);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Rng::new(9);
        let mut seen = [false; 10];
        for _ in 0..10_000 {
            let v = r.below(10);
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s), "all buckets hit");
    }

    #[test]
    fn range_inclusive() {
        let mut r = Rng::new(11);
        let mut lo_seen = false;
        let mut hi_seen = false;
        for _ in 0..10_000 {
            let v = r.range_i64(3, 7);
            assert!((3..=7).contains(&v));
            lo_seen |= v == 3;
            hi_seen |= v == 7;
        }
        assert!(lo_seen && hi_seen);
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(13);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(17);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(19);
        let s = r.sample_indices(50, 20);
        assert_eq!(s.len(), 20);
        let mut d = s.clone();
        d.sort_unstable();
        d.dedup();
        assert_eq!(d.len(), 20);
    }
}
