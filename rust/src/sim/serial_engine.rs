//! Event-based serial execution engine (paper §III-A runtime semantics).
//!
//! Per timestep `t` each serial PE:
//! 1. reads + clears ring-buffer slot `t mod D` — excitatory minus
//!    inhibitory accumulators become the synaptic input current;
//! 2. processes the spikes arriving this step: master population table →
//!    address list → synaptic-matrix block; each synaptic word's weight is
//!    accumulated into slot `(t + delay) mod D` of its type's buffer.
//!
//! (Reading before writing makes a D-slot ring sufficient for delays up to
//! D: a write at delay D lands in the slot just cleared, to be read exactly
//! D steps later.)
//!
//! Steady-state execution is allocation-free: currents land in a persistent
//! scratch buffer, and arriving spikes are routed through a precomputed
//! source→PE dispatch table (CSR layout) so each spike touches only the PEs
//! whose `source_slice` actually contains it — not every PE of the layer.
//!
//! Readout is **sparsity-gated**: each PE keeps a pending-write counter per
//! ring slot, so Phase 1 skips any `(PE, slot)` pair nothing has written
//! into since it was last cleared. A silent step costs O(PEs), not
//! O(PEs × targets × types) — the event-driven cost profile the platform
//! paper's activity-sparsity argument assumes.

use crate::model::SynapseType;
use crate::paradigm::serial::SerialCompiled;
use crate::sim::spikebits::SpikeWords;
use anyhow::{ensure, Result};
use std::time::Instant;

struct PeState {
    /// Ring buffer: `[slot][type][local target]`, i32 accumulators
    /// (16-bit in hardware per Table I; i32 here to keep saturation out of
    /// the equivalence story — values stay far below either limit).
    ring: Vec<i32>,
    /// Synaptic writes into each ring slot since it was last consumed;
    /// 0 means the slot is identically zero and readout can skip it.
    slot_writes: Vec<u32>,
    /// Word-aligned written-target bitmap per ring slot
    /// (`[slot][tgt_words]`): bit `local` of slot `s` is set iff some
    /// synaptic word wrote local target `local` into slot `s` since it was
    /// last consumed. Readout walks set bits via `trailing_zeros` instead
    /// of scanning every local target.
    written: Vec<u64>,
    /// `n_tgt.div_ceil(64)` — the per-slot stride of `written`.
    tgt_words: usize,
    n_tgt: usize,
    delay_range: usize,
}

impl PeState {
    #[inline]
    fn idx(&self, slot: usize, syn_type: usize, target: usize) -> usize {
        (slot * SynapseType::COUNT + syn_type) * self.n_tgt + target
    }
}

/// Snapshot of one serial engine's dynamic state — ring buffers, pending
/// write counters, written-target bitmaps, current scratch, and the clock.
/// Telemetry (`events`/`spikes_in`/`steps`/profiling nanos) is deliberately
/// excluded: it is cumulative reporting state, not replay state, and
/// [`SerialLayerEngine::restore`] leaves it untouched.
#[derive(Clone, Debug, PartialEq)]
pub struct SerialEngineCheckpoint {
    rings: Vec<Vec<i32>>,
    slot_writes: Vec<Vec<u32>>,
    written: Vec<Vec<u64>>,
    currents: Vec<f32>,
    t: u64,
}

impl SerialEngineCheckpoint {
    /// True when every buffer is identically zero — the state [`SerialLayerEngine::reset`]
    /// produces (any clock value is consistent with empty rings).
    pub fn is_pristine(&self) -> bool {
        self.rings.iter().all(|r| r.iter().all(|&x| x == 0))
            && self.slot_writes.iter().all(|s| s.iter().all(|&x| x == 0))
            && self.written.iter().all(|w| w.iter().all(|&x| x == 0))
            && self.currents.iter().all(|&c| c == 0.0)
    }

    pub fn timestep(&self) -> u64 {
        self.t
    }

    /// In-memory footprint of the captured state (the recovery stats'
    /// checkpoint-cost accounting).
    pub fn byte_size(&self) -> usize {
        self.rings.iter().map(|r| r.len() * 4).sum::<usize>()
            + self.slot_writes.iter().map(|s| s.len() * 4).sum::<usize>()
            + self.written.iter().map(|w| w.len() * 8).sum::<usize>()
            + self.currents.len() * 4
            + 8
    }
}

/// Executes one serially-compiled layer.
pub struct SerialLayerEngine {
    compiled: SerialCompiled,
    pes: Vec<PeState>,
    /// CSR dispatch: `dispatch_pes[dispatch_off[s]..dispatch_off[s+1]]` are
    /// the PE indices whose `source_slice` contains global source `s`.
    dispatch_off: Vec<u32>,
    dispatch_pes: Vec<u32>,
    /// Persistent per-target current scratch, rewritten every step.
    currents: Vec<f32>,
    /// Scratch bitmap backing the id-list [`SerialLayerEngine::step_currents`]
    /// wrapper (the words path [`SerialLayerEngine::step_currents_words`] is
    /// the primary implementation).
    spike_scratch: SpikeWords,
    t: u64,
    /// Synaptic events processed (telemetry for the perf benches;
    /// cumulative — survives [`SerialLayerEngine::reset`]).
    pub events: u64,
    /// Incoming spikes seen (cumulative; with [`SerialLayerEngine::steps`]
    /// this is the observed-firing-rate telemetry the runtime-informed cost
    /// model consumes).
    pub spikes_in: u64,
    /// Timesteps executed (cumulative — survives reset, like `events`).
    pub steps: u64,
    /// Incoming spikes seen in the *current activity window* — dynamic
    /// state, unlike the lifetime telemetry above: cleared by
    /// [`SerialLayerEngine::reset`] and [`SerialLayerEngine::clear_window`],
    /// so the adaptive re-switcher reads recent activity, not history.
    pub window_spikes: u64,
    /// Timesteps executed in the current activity window (cleared with
    /// `window_spikes`).
    pub window_steps: u64,
    /// `(PE, slot)` ring reads skipped because no write was pending — the
    /// sparsity-gating win counter.
    pub skipped_slots: u64,
    /// Phase-1 (ring readout) wall-clock, accumulated only while profiling.
    pub readout_nanos: u64,
    /// Phase-2 (spike dispatch) wall-clock, accumulated only while profiling.
    pub dispatch_nanos: u64,
    profile: bool,
}

impl SerialLayerEngine {
    pub fn new(compiled: SerialCompiled, n_target: usize) -> Self {
        let pes: Vec<PeState> = compiled
            .pes
            .iter()
            .map(|p| {
                let n_tgt = p.target_slice.len();
                let delay_range = p.delay_range as usize;
                let tgt_words = n_tgt.div_ceil(64);
                PeState {
                    ring: vec![0; delay_range * SynapseType::COUNT * n_tgt],
                    slot_writes: vec![0; delay_range],
                    written: vec![0; delay_range * tgt_words],
                    tgt_words,
                    n_tgt,
                    delay_range,
                }
            })
            .collect();

        // Build the source→PE dispatch: source slices are contiguous per
        // PE, so a counting pass + fill yields a compact CSR index.
        let n_source = compiled
            .pes
            .iter()
            .map(|p| p.source_slice.hi as usize)
            .max()
            .unwrap_or(0);
        let mut counts = vec![0u32; n_source + 1];
        for prog in &compiled.pes {
            for s in prog.source_slice.lo..prog.source_slice.hi {
                counts[s as usize + 1] += 1;
            }
        }
        let mut dispatch_off = counts;
        for i in 1..dispatch_off.len() {
            dispatch_off[i] += dispatch_off[i - 1];
        }
        let mut dispatch_pes = vec![0u32; *dispatch_off.last().unwrap() as usize];
        let mut cursor: Vec<u32> = dispatch_off[..n_source].to_vec();
        for (pe_idx, prog) in compiled.pes.iter().enumerate() {
            for s in prog.source_slice.lo..prog.source_slice.hi {
                dispatch_pes[cursor[s as usize] as usize] = pe_idx as u32;
                cursor[s as usize] += 1;
            }
        }

        SerialLayerEngine {
            compiled,
            pes,
            dispatch_off,
            dispatch_pes,
            currents: vec![0.0; n_target],
            spike_scratch: SpikeWords::new(n_source),
            t: 0,
            events: 0,
            spikes_in: 0,
            steps: 0,
            window_spikes: 0,
            window_steps: 0,
            skipped_slots: 0,
            readout_nanos: 0,
            dispatch_nanos: 0,
            profile: false,
        }
    }

    pub fn timestep(&self) -> u64 {
        self.t
    }

    /// Enable per-phase wall-clock accumulation (`readout_nanos` /
    /// `dispatch_nanos`); off by default so the hot path carries no timer
    /// syscalls.
    pub fn set_profile(&mut self, on: bool) {
        self.profile = on;
    }

    /// Clear all dynamic state (ring buffers, clock, the activity window)
    /// so the engine can run a fresh stimulus without recompiling. The
    /// lifetime telemetry (`events`/`spikes_in`/`steps`) keeps accumulating
    /// across resets (batch accounting reads it at the end).
    pub fn reset(&mut self) {
        for pe in &mut self.pes {
            pe.ring.fill(0);
            pe.slot_writes.fill(0);
            pe.written.fill(0);
        }
        self.currents.fill(0.0);
        self.clear_window();
        self.t = 0;
    }

    /// Start a fresh activity window: zero `window_spikes`/`window_steps`
    /// without touching ring state or the lifetime telemetry. The adaptive
    /// re-switcher calls this at every sample boundary it evaluates.
    pub fn clear_window(&mut self) {
        self.window_spikes = 0;
        self.window_steps = 0;
    }

    /// Snapshot all dynamic state (see [`SerialEngineCheckpoint`]).
    pub fn checkpoint(&self) -> SerialEngineCheckpoint {
        SerialEngineCheckpoint {
            rings: self.pes.iter().map(|p| p.ring.clone()).collect(),
            slot_writes: self.pes.iter().map(|p| p.slot_writes.clone()).collect(),
            written: self.pes.iter().map(|p| p.written.clone()).collect(),
            currents: self.currents.clone(),
            t: self.t,
        }
    }

    /// Restore a [`SerialLayerEngine::checkpoint`] taken from an engine of
    /// identical shape (same compiled layer). Telemetry keeps accumulating
    /// across restores, like it does across [`SerialLayerEngine::reset`].
    pub fn restore(&mut self, ckpt: &SerialEngineCheckpoint) -> Result<()> {
        ensure!(
            ckpt.rings.len() == self.pes.len() && ckpt.currents.len() == self.currents.len(),
            "serial checkpoint shape mismatch: {} PEs / {} targets vs engine {} / {}",
            ckpt.rings.len(),
            ckpt.currents.len(),
            self.pes.len(),
            self.currents.len()
        );
        for (i, pe) in self.pes.iter().enumerate() {
            ensure!(
                ckpt.rings[i].len() == pe.ring.len()
                    && ckpt.slot_writes[i].len() == pe.slot_writes.len()
                    && ckpt.written[i].len() == pe.written.len(),
                "serial checkpoint PE {i} buffer shapes do not match the engine"
            );
        }
        for (i, pe) in self.pes.iter_mut().enumerate() {
            pe.ring.copy_from_slice(&ckpt.rings[i]);
            pe.slot_writes.copy_from_slice(&ckpt.slot_writes[i]);
            pe.written.copy_from_slice(&ckpt.written[i]);
        }
        self.currents.copy_from_slice(&ckpt.currents);
        self.t = ckpt.t;
        Ok(())
    }

    /// [`SerialLayerEngine::reset`] but resuming the clock at `t` — the
    /// cross-paradigm pristine-restore path (empty rings are consistent
    /// with any clock value).
    pub fn reset_to(&mut self, t: u64) {
        self.reset();
        self.t = t;
    }

    /// Id-list convenience wrapper around
    /// [`SerialLayerEngine::step_currents_words`]: packs `spikes_in` into
    /// the engine-owned scratch bitmap (duplicates collapse, out-of-range
    /// ids drop — both observationally identical to the historical per-id
    /// loop) and steps on the words path.
    pub fn step_currents(&mut self, spikes_in: &[u32]) -> &[f32] {
        let mut scratch = std::mem::take(&mut self.spike_scratch);
        scratch.fill_from_ids(spikes_in);
        self.step_currents_words(&scratch);
        self.spike_scratch = scratch;
        &self.currents
    }

    /// Advance one timestep: consume this step's ring slot into per-target
    /// currents, then process `spikes_in` (bitmap of source-population
    /// neuron ids firing *this* step) into future slots. The returned slice
    /// lives in engine-owned scratch and is valid until the next call.
    pub fn step_currents_words(&mut self, spikes_in: &SpikeWords) -> &[f32] {
        let SerialLayerEngine {
            ref compiled,
            ref mut pes,
            ref dispatch_off,
            ref dispatch_pes,
            ref mut currents,
            ref mut events,
            spikes_in: ref mut spikes_seen,
            ref mut skipped_slots,
            ref mut readout_nanos,
            ref mut dispatch_nanos,
            profile,
            t,
            ..
        } = *self;
        let t = t as usize;
        currents.fill(0.0);

        // Phase 1: neural-input read-out (time-triggered), gated per
        // (PE, slot) on the pending-write counter — an unwritten slot is
        // identically zero, so reading and clearing it would be pure waste.
        // Within a live slot, only *written* targets are visited: set bits
        // of the slot's bitmap, in ascending order, so the f32 accumulation
        // order (and thus every rounding step) matches the historical full
        // scan — unwritten targets contributed net == 0 there.
        let t0 = profile.then(Instant::now);
        for (prog, pe) in compiled.pes.iter().zip(pes.iter_mut()) {
            let slot = t % pe.delay_range;
            if pe.slot_writes[slot] == 0 {
                *skipped_slots += 1;
                continue;
            }
            pe.slot_writes[slot] = 0;
            let scale = prog.weight_scale;
            let wbase = slot * pe.tgt_words;
            for wi in 0..pe.tgt_words {
                let mut w = pe.written[wbase + wi];
                pe.written[wbase + wi] = 0;
                while w != 0 {
                    let local = (wi << 6) + w.trailing_zeros() as usize;
                    w &= w - 1;
                    let e = pe.idx(slot, SynapseType::Excitatory.index(), local);
                    let i = pe.idx(slot, SynapseType::Inhibitory.index(), local);
                    let net = pe.ring[e] - pe.ring[i];
                    pe.ring[e] = 0;
                    pe.ring[i] = 0;
                    if net != 0 {
                        currents[prog.target_slice.lo as usize + local] += net as f32 * scale;
                    }
                }
            }
        }
        if let Some(t0) = t0 {
            *readout_nanos += t0.elapsed().as_nanos() as u64;
        }

        // Phase 2: event-based synaptic processing of this step's spikes —
        // set bits walked via `trailing_zeros`, each dispatched only to the
        // PEs that store rows for that source. Ids at or beyond the dispatch
        // range (sources no PE stores rows for) end the walk: bits ascend,
        // so everything after the first such id is out of range too.
        let t0 = profile.then(Instant::now);
        let n_source = dispatch_off.len() - 1;
        'dispatch: for (swi, &sword) in spikes_in.words().iter().enumerate() {
            let mut sw = sword;
            while sw != 0 {
                let src = ((swi << 6) + sw.trailing_zeros() as usize) as u32;
                sw &= sw - 1;
                if src as usize >= n_source {
                    break 'dispatch;
                }
                let lo = dispatch_off[src as usize] as usize;
                let hi = dispatch_off[src as usize + 1] as usize;
                for &pe_idx in &dispatch_pes[lo..hi] {
                    let prog = &compiled.pes[pe_idx as usize];
                    let pe = &mut pes[pe_idx as usize];
                    let Some(slot_idx) = prog.mpt.lookup(src) else { continue };
                    let entry = prog.address_list.entries[slot_idx as usize];
                    for word in prog.matrix.block(entry) {
                        let write_slot = (t + word.delay() as usize) % pe.delay_range;
                        let target = word.target() as usize;
                        let j = pe.idx(write_slot, word.syn_type().index(), target);
                        pe.ring[j] += word.weight() as i32;
                        pe.slot_writes[write_slot] += 1;
                        pe.written[write_slot * pe.tgt_words + (target >> 6)] |=
                            1u64 << (target & 63);
                        *events += 1;
                    }
                }
            }
        }
        if let Some(t0) = t0 {
            *dispatch_nanos += t0.elapsed().as_nanos() as u64;
        }

        let n_in = spikes_in.count() as u64;
        *spikes_seen += n_in;
        self.steps += 1;
        self.window_spikes += n_in;
        self.window_steps += 1;
        self.t += 1;
        &self.currents
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hardware::PeSpec;
    use crate::model::{
        LifParams, PopulationId, Projection, ProjectionId, Synapse, SynapseType,
    };
    use crate::paradigm::serial::compile_serial;

    fn engine_for(synapses: Vec<Synapse>, n_src: usize, n_tgt: usize) -> SerialLayerEngine {
        let proj = Projection {
            id: ProjectionId(0),
            source: PopulationId(0),
            target: PopulationId(1),
            synapses,
            weight_scale: 0.5,
        };
        let c = compile_serial(&proj, n_src, n_tgt, LifParams::default(), &PeSpec::default())
            .unwrap();
        SerialLayerEngine::new(c, n_tgt)
    }

    fn syn(s: u32, t: u32, w: u8, d: u16, inh: bool) -> Synapse {
        Synapse {
            source: s,
            target: t,
            weight: w,
            delay: d,
            syn_type: if inh { SynapseType::Inhibitory } else { SynapseType::Excitatory },
        }
    }

    #[test]
    fn delay_one_arrives_next_step() {
        let mut e = engine_for(vec![syn(0, 1, 10, 1, false)], 2, 3);
        assert_eq!(e.step_currents(&[0]), [0.0, 0.0, 0.0], "nothing due at t=0");
        assert_eq!(e.step_currents(&[]), [0.0, 5.0, 0.0], "weight 10 × scale 0.5 at t=1");
        assert_eq!(e.step_currents(&[]), [0.0, 0.0, 0.0], "one-shot delivery");
    }

    #[test]
    fn delay_equal_to_range_wraps_correctly() {
        let mut e = engine_for(vec![syn(0, 0, 8, 4, false), syn(0, 1, 8, 1, false)], 1, 2);
        e.step_currents(&[0]);
        let mut hits = Vec::new();
        for t in 1..=5 {
            let c = e.step_currents(&[]);
            for (n, &v) in c.iter().enumerate() {
                if v != 0.0 {
                    hits.push((t, n, v));
                }
            }
        }
        assert_eq!(hits, vec![(1, 1, 4.0), (4, 0, 4.0)]);
    }

    #[test]
    fn excitation_and_inhibition_cancel() {
        let mut e =
            engine_for(vec![syn(0, 0, 9, 2, false), syn(1, 0, 9, 2, true)], 2, 1);
        e.step_currents(&[0, 1]);
        e.step_currents(&[]);
        assert_eq!(e.step_currents(&[]), [0.0], "equal E and I at the same slot cancel");
    }

    #[test]
    fn repeated_spikes_accumulate() {
        let mut e = engine_for(vec![syn(0, 0, 3, 2, false)], 1, 1);
        e.step_currents(&[0]); // lands at t=2
        e.step_currents(&[0]); // lands at t=3
        assert_eq!(e.step_currents(&[]), [1.5]);
        assert_eq!(e.step_currents(&[]), [1.5]);
    }

    #[test]
    fn split_layer_routes_to_correct_chunks() {
        // Dense enough to need several PEs; currents must land at global
        // target indices regardless of the split.
        let mut syns = Vec::new();
        for s in 0..300u32 {
            syns.push(syn(s, (s * 7) % 280, 1, 1, false));
        }
        let mut e = engine_for(syns.clone(), 300, 280);
        let all: Vec<u32> = (0..300).collect();
        e.step_currents(&all);
        let mut expect = vec![0.0f32; 280];
        for s in &syns {
            expect[s.target as usize] += 0.5;
        }
        assert_eq!(e.step_currents(&[]).to_vec(), expect);
        assert_eq!(e.events, 300);
    }

    #[test]
    fn reset_replays_identically() {
        let mut e = engine_for(vec![syn(0, 1, 10, 2, false), syn(1, 0, 6, 1, true)], 2, 3);
        let run = |e: &mut SerialLayerEngine| -> Vec<Vec<f32>> {
            let stim: [&[u32]; 4] = [&[0, 1], &[], &[1], &[]];
            stim.iter().map(|s| e.step_currents(s).to_vec()).collect()
        };
        let first = run(&mut e);
        e.reset();
        assert_eq!(e.timestep(), 0);
        let second = run(&mut e);
        assert_eq!(first, second, "reset must reproduce the run exactly");
    }

    #[test]
    fn checkpoint_restore_replays_in_flight_state() {
        // Checkpoint while delayed weights are still in flight; the restored
        // engine must deliver them at exactly the same steps.
        let mut e = engine_for(vec![syn(0, 1, 10, 3, false), syn(1, 0, 6, 1, true)], 2, 3);
        e.step_currents(&[0, 1]);
        let ckpt = e.checkpoint();
        assert!(!ckpt.is_pristine(), "in-flight weights must show in the snapshot");
        assert!(ckpt.byte_size() > 0);
        let tail = |e: &mut SerialLayerEngine| -> Vec<Vec<f32>> {
            (0..4).map(|_| e.step_currents(&[]).to_vec()).collect()
        };
        let first = tail(&mut e);
        e.restore(&ckpt).unwrap();
        assert_eq!(e.timestep(), 1);
        assert_eq!(tail(&mut e), first, "restore must replay bit-identically");
        // Pristine snapshots are recognized; mismatched shapes are typed errors.
        e.reset_to(7);
        assert!(e.checkpoint().is_pristine());
        assert_eq!(e.timestep(), 7);
        let mut other = engine_for(vec![syn(0, 0, 1, 1, false)], 1, 1);
        assert!(other.restore(&ckpt).is_err(), "foreign checkpoint must be refused");
    }

    #[test]
    fn silent_steps_skip_ring_readout() {
        // A silent engine must gate out every (PE, slot) read while still
        // producing the exact currents once activity arrives.
        let mut e = engine_for(vec![syn(0, 1, 10, 2, false)], 2, 3);
        for _ in 0..10 {
            assert_eq!(e.step_currents(&[]), [0.0, 0.0, 0.0]);
        }
        let n_pes = e.compiled.pes.len() as u64;
        assert_eq!(e.skipped_slots, 10 * n_pes, "all silent reads must be gated");
        // The spike lands at t+2 exactly as without gating.
        e.step_currents(&[0]);
        e.step_currents(&[]);
        assert_eq!(e.step_currents(&[]), [0.0, 5.0, 0.0]);
        assert_eq!(e.events, 1);
    }

    #[test]
    fn gating_never_changes_results_under_random_stimulus() {
        use crate::rng::Rng;
        // Dense-vs-gated differential: replay the same stimulus and check
        // the telemetry splits every step into read-or-skipped, while
        // delivered currents match the analytic expectation per synapse.
        let syns = vec![
            syn(0, 0, 4, 1, false),
            syn(0, 2, 6, 3, false),
            syn(1, 1, 8, 2, true),
            syn(2, 0, 2, 4, false),
        ];
        let mut e = engine_for(syns.clone(), 3, 3);
        let mut rng = Rng::new(5150);
        let mut expected = vec![vec![0.0f32; 3]; 64 + 8];
        for t in 0..64u64 {
            let firing: Vec<u32> = (0..3).filter(|_| rng.chance(0.3)).collect();
            for s in &syns {
                if firing.contains(&s.source) {
                    let sign = if s.syn_type == SynapseType::Inhibitory { -1.0 } else { 1.0 };
                    expected[(t + s.delay as u64) as usize][s.target as usize] +=
                        sign * s.weight as f32 * 0.5;
                }
            }
            assert_eq!(e.step_currents(&firing), expected[t as usize], "t={t}");
        }
        assert!(e.skipped_slots > 0, "a 30%-rate stimulus must leave silent slots");
    }

    #[test]
    fn window_counters_roll_over_independently_of_lifetime_telemetry() {
        let mut e = engine_for(vec![syn(0, 1, 10, 2, false)], 2, 3);
        e.step_currents(&[0, 1]);
        e.step_currents(&[0]);
        assert_eq!((e.window_spikes, e.window_steps), (3, 2));
        assert_eq!((e.spikes_in, e.steps), (3, 2));
        // Rolling the window starts a fresh count; lifetime keeps going.
        e.clear_window();
        assert_eq!((e.window_spikes, e.window_steps), (0, 0));
        e.step_currents(&[1]);
        assert_eq!((e.window_spikes, e.window_steps), (1, 1));
        assert_eq!((e.spikes_in, e.steps), (4, 3), "lifetime must span windows");
        // The clock is untouched by a window roll.
        assert_eq!(e.timestep(), 3);
    }

    #[test]
    fn reset_clears_the_window_but_preserves_lifetime_telemetry() {
        let mut e = engine_for(vec![syn(0, 0, 4, 1, false)], 2, 1);
        e.step_currents(&[0, 1]);
        e.step_currents(&[0]);
        let (life_spikes, life_steps, life_events) = (e.spikes_in, e.steps, e.events);
        assert!(life_spikes > 0 && life_events > 0);
        e.reset();
        assert_eq!((e.window_spikes, e.window_steps), (0, 0), "reset must clear the window");
        assert_eq!(
            (e.spikes_in, e.steps, e.events),
            (life_spikes, life_steps, life_events),
            "reset must not touch lifetime telemetry"
        );
    }

    #[test]
    fn zero_spike_windows_count_steps_and_rate_to_zero() {
        use crate::costmodel::activity::observed_rate;
        let mut e = engine_for(vec![syn(0, 0, 4, 1, false)], 2, 1);
        for _ in 0..5 {
            e.step_currents(&[]);
        }
        assert_eq!((e.window_spikes, e.window_steps), (0, 5));
        let rate = observed_rate(e.window_spikes, e.window_steps, 2);
        assert_eq!(rate, 0.0, "silent window must rate to exactly 0.0");
        // An empty window (no steps at all) must not divide by zero either.
        e.clear_window();
        assert_eq!(observed_rate(e.window_spikes, e.window_steps, 2), 0.0);
        assert!(observed_rate(0, 5, 0).is_finite(), "zero sources must not NaN");
    }

    #[test]
    fn out_of_range_spike_is_ignored() {
        let mut e = engine_for(vec![syn(0, 0, 3, 1, false)], 1, 1);
        e.step_currents(&[7]); // no PE stores rows for source 7
        assert_eq!(e.step_currents(&[]), [0.0]);
        assert_eq!(e.events, 0);
    }

    #[test]
    fn words_path_ignores_bits_beyond_dispatch_range() {
        // A caller-owned bitmap sized to the full population can carry bits
        // beyond the engine's dispatch range (trailing sources with no
        // synapses); those must be skipped, not panic.
        let mut e = engine_for(vec![syn(0, 0, 3, 1, false)], 1, 1);
        let mut s = SpikeWords::new(100);
        s.fill_from_ids(&[0, 7, 99]);
        e.step_currents_words(&s);
        assert_eq!(e.step_currents(&[]), [1.5]);
        assert_eq!(e.events, 1);
    }

    #[test]
    fn words_path_matches_id_list_path() {
        use crate::rng::Rng;
        // Two engines over the same compiled layer, one stepped with id
        // lists and one with pre-packed bitmaps, must produce bit-identical
        // current streams under random stimulus.
        let mut syns = Vec::new();
        let mut rng = Rng::new(909);
        for s in 0..80u32 {
            for _ in 0..3 {
                syns.push(syn(
                    s,
                    rng.below(70) as u32,
                    rng.below(9) as u8 + 1,
                    rng.below(6) as u16 + 1,
                    rng.chance(0.3),
                ));
            }
        }
        let mut by_ids = engine_for(syns.clone(), 80, 70);
        let mut by_words = engine_for(syns, 80, 70);
        let mut packed = SpikeWords::new(80);
        for t in 0..40 {
            let firing: Vec<u32> =
                (0..80).filter(|_| rng.chance(0.25)).collect();
            packed.fill_from_ids(&firing);
            let a = by_ids.step_currents(&firing).to_vec();
            let b = by_words.step_currents_words(&packed);
            assert_eq!(a, b, "t={t}");
        }
        assert_eq!(by_ids.events, by_words.events);
        assert_eq!(by_ids.spikes_in, by_words.spikes_in);
    }
}
