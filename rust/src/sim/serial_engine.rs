//! Event-based serial execution engine (paper §III-A runtime semantics).
//!
//! Per timestep `t` each serial PE:
//! 1. reads + clears ring-buffer slot `t mod D` — excitatory minus
//!    inhibitory accumulators become the synaptic input current;
//! 2. processes the spikes arriving this step: master population table →
//!    address list → synaptic-matrix block; each synaptic word's weight is
//!    accumulated into slot `(t + delay) mod D` of its type's buffer.
//!
//! (Reading before writing makes a D-slot ring sufficient for delays up to
//! D: a write at delay D lands in the slot just cleared, to be read exactly
//! D steps later.)
//!
//! Steady-state execution is allocation-free: currents land in a persistent
//! scratch buffer, and arriving spikes are routed through a precomputed
//! source→PE dispatch table (CSR layout) so each spike touches only the PEs
//! whose `source_slice` actually contains it — not every PE of the layer.
//!
//! Readout is **sparsity-gated**: each PE keeps a pending-write counter per
//! ring slot, so Phase 1 skips any `(PE, slot)` pair nothing has written
//! into since it was last cleared. A silent step costs O(PEs), not
//! O(PEs × targets × types) — the event-driven cost profile the platform
//! paper's activity-sparsity argument assumes.

use crate::model::SynapseType;
use crate::paradigm::serial::SerialCompiled;
use std::time::Instant;

struct PeState {
    /// Ring buffer: `[slot][type][local target]`, i32 accumulators
    /// (16-bit in hardware per Table I; i32 here to keep saturation out of
    /// the equivalence story — values stay far below either limit).
    ring: Vec<i32>,
    /// Synaptic writes into each ring slot since it was last consumed;
    /// 0 means the slot is identically zero and readout can skip it.
    slot_writes: Vec<u32>,
    n_tgt: usize,
    delay_range: usize,
}

impl PeState {
    #[inline]
    fn idx(&self, slot: usize, syn_type: usize, target: usize) -> usize {
        (slot * SynapseType::COUNT + syn_type) * self.n_tgt + target
    }
}

/// Executes one serially-compiled layer.
pub struct SerialLayerEngine {
    compiled: SerialCompiled,
    pes: Vec<PeState>,
    /// CSR dispatch: `dispatch_pes[dispatch_off[s]..dispatch_off[s+1]]` are
    /// the PE indices whose `source_slice` contains global source `s`.
    dispatch_off: Vec<u32>,
    dispatch_pes: Vec<u32>,
    /// Persistent per-target current scratch, rewritten every step.
    currents: Vec<f32>,
    t: u64,
    /// Synaptic events processed (telemetry for the perf benches;
    /// cumulative — survives [`SerialLayerEngine::reset`]).
    pub events: u64,
    /// Incoming spikes seen (cumulative; with [`SerialLayerEngine::steps`]
    /// this is the observed-firing-rate telemetry the runtime-informed cost
    /// model consumes).
    pub spikes_in: u64,
    /// Timesteps executed (cumulative — survives reset, like `events`).
    pub steps: u64,
    /// `(PE, slot)` ring reads skipped because no write was pending — the
    /// sparsity-gating win counter.
    pub skipped_slots: u64,
    /// Phase-1 (ring readout) wall-clock, accumulated only while profiling.
    pub readout_nanos: u64,
    /// Phase-2 (spike dispatch) wall-clock, accumulated only while profiling.
    pub dispatch_nanos: u64,
    profile: bool,
}

impl SerialLayerEngine {
    pub fn new(compiled: SerialCompiled, n_target: usize) -> Self {
        let pes: Vec<PeState> = compiled
            .pes
            .iter()
            .map(|p| {
                let n_tgt = p.target_slice.len();
                let delay_range = p.delay_range as usize;
                PeState {
                    ring: vec![0; delay_range * SynapseType::COUNT * n_tgt],
                    slot_writes: vec![0; delay_range],
                    n_tgt,
                    delay_range,
                }
            })
            .collect();

        // Build the source→PE dispatch: source slices are contiguous per
        // PE, so a counting pass + fill yields a compact CSR index.
        let n_source = compiled
            .pes
            .iter()
            .map(|p| p.source_slice.hi as usize)
            .max()
            .unwrap_or(0);
        let mut counts = vec![0u32; n_source + 1];
        for prog in &compiled.pes {
            for s in prog.source_slice.lo..prog.source_slice.hi {
                counts[s as usize + 1] += 1;
            }
        }
        let mut dispatch_off = counts;
        for i in 1..dispatch_off.len() {
            dispatch_off[i] += dispatch_off[i - 1];
        }
        let mut dispatch_pes = vec![0u32; *dispatch_off.last().unwrap() as usize];
        let mut cursor: Vec<u32> = dispatch_off[..n_source].to_vec();
        for (pe_idx, prog) in compiled.pes.iter().enumerate() {
            for s in prog.source_slice.lo..prog.source_slice.hi {
                dispatch_pes[cursor[s as usize] as usize] = pe_idx as u32;
                cursor[s as usize] += 1;
            }
        }

        SerialLayerEngine {
            compiled,
            pes,
            dispatch_off,
            dispatch_pes,
            currents: vec![0.0; n_target],
            t: 0,
            events: 0,
            spikes_in: 0,
            steps: 0,
            skipped_slots: 0,
            readout_nanos: 0,
            dispatch_nanos: 0,
            profile: false,
        }
    }

    pub fn timestep(&self) -> u64 {
        self.t
    }

    /// Enable per-phase wall-clock accumulation (`readout_nanos` /
    /// `dispatch_nanos`); off by default so the hot path carries no timer
    /// syscalls.
    pub fn set_profile(&mut self, on: bool) {
        self.profile = on;
    }

    /// Clear all dynamic state (ring buffers, clock) so the engine can run
    /// a fresh stimulus without recompiling. The `events` telemetry keeps
    /// accumulating across resets (batch accounting reads it at the end).
    pub fn reset(&mut self) {
        for pe in &mut self.pes {
            pe.ring.fill(0);
            pe.slot_writes.fill(0);
        }
        self.currents.fill(0.0);
        self.t = 0;
    }

    /// Advance one timestep: consume this step's ring slot into per-target
    /// currents, then process `spikes_in` (source-population neuron ids
    /// firing *this* step) into future slots. The returned slice lives in
    /// engine-owned scratch and is valid until the next call.
    pub fn step_currents(&mut self, spikes_in: &[u32]) -> &[f32] {
        let SerialLayerEngine {
            ref compiled,
            ref mut pes,
            ref dispatch_off,
            ref dispatch_pes,
            ref mut currents,
            ref mut events,
            spikes_in: ref mut spikes_seen,
            ref mut skipped_slots,
            ref mut readout_nanos,
            ref mut dispatch_nanos,
            profile,
            t,
            ..
        } = *self;
        let t = t as usize;
        currents.fill(0.0);

        // Phase 1: neural-input read-out (time-triggered), gated per
        // (PE, slot) on the pending-write counter — an unwritten slot is
        // identically zero, so reading and clearing it would be pure waste.
        let t0 = profile.then(Instant::now);
        for (prog, pe) in compiled.pes.iter().zip(pes.iter_mut()) {
            let slot = t % pe.delay_range;
            if pe.slot_writes[slot] == 0 {
                *skipped_slots += 1;
                continue;
            }
            pe.slot_writes[slot] = 0;
            let scale = prog.weight_scale;
            for local in 0..pe.n_tgt {
                let e = pe.idx(slot, SynapseType::Excitatory.index(), local);
                let i = pe.idx(slot, SynapseType::Inhibitory.index(), local);
                let net = pe.ring[e] - pe.ring[i];
                pe.ring[e] = 0;
                pe.ring[i] = 0;
                if net != 0 {
                    currents[prog.target_slice.lo as usize + local] += net as f32 * scale;
                }
            }
        }
        if let Some(t0) = t0 {
            *readout_nanos += t0.elapsed().as_nanos() as u64;
        }

        // Phase 2: event-based synaptic processing of this step's spikes,
        // dispatched only to the PEs that store rows for each source.
        let t0 = profile.then(Instant::now);
        let n_source = dispatch_off.len() - 1;
        for &src in spikes_in {
            if src as usize >= n_source {
                continue;
            }
            let lo = dispatch_off[src as usize] as usize;
            let hi = dispatch_off[src as usize + 1] as usize;
            for &pe_idx in &dispatch_pes[lo..hi] {
                let prog = &compiled.pes[pe_idx as usize];
                let pe = &mut pes[pe_idx as usize];
                let Some(slot_idx) = prog.mpt.lookup(src) else { continue };
                let entry = prog.address_list.entries[slot_idx as usize];
                for word in prog.matrix.block(entry) {
                    let write_slot = (t + word.delay() as usize) % pe.delay_range;
                    let j = pe.idx(write_slot, word.syn_type().index(), word.target() as usize);
                    pe.ring[j] += word.weight() as i32;
                    pe.slot_writes[write_slot] += 1;
                    *events += 1;
                }
            }
        }
        if let Some(t0) = t0 {
            *dispatch_nanos += t0.elapsed().as_nanos() as u64;
        }

        *spikes_seen += spikes_in.len() as u64;
        self.steps += 1;
        self.t += 1;
        &self.currents
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hardware::PeSpec;
    use crate::model::{
        LifParams, PopulationId, Projection, ProjectionId, Synapse, SynapseType,
    };
    use crate::paradigm::serial::compile_serial;

    fn engine_for(synapses: Vec<Synapse>, n_src: usize, n_tgt: usize) -> SerialLayerEngine {
        let proj = Projection {
            id: ProjectionId(0),
            source: PopulationId(0),
            target: PopulationId(1),
            synapses,
            weight_scale: 0.5,
        };
        let c = compile_serial(&proj, n_src, n_tgt, LifParams::default(), &PeSpec::default())
            .unwrap();
        SerialLayerEngine::new(c, n_tgt)
    }

    fn syn(s: u32, t: u32, w: u8, d: u16, inh: bool) -> Synapse {
        Synapse {
            source: s,
            target: t,
            weight: w,
            delay: d,
            syn_type: if inh { SynapseType::Inhibitory } else { SynapseType::Excitatory },
        }
    }

    #[test]
    fn delay_one_arrives_next_step() {
        let mut e = engine_for(vec![syn(0, 1, 10, 1, false)], 2, 3);
        assert_eq!(e.step_currents(&[0]), [0.0, 0.0, 0.0], "nothing due at t=0");
        assert_eq!(e.step_currents(&[]), [0.0, 5.0, 0.0], "weight 10 × scale 0.5 at t=1");
        assert_eq!(e.step_currents(&[]), [0.0, 0.0, 0.0], "one-shot delivery");
    }

    #[test]
    fn delay_equal_to_range_wraps_correctly() {
        let mut e = engine_for(vec![syn(0, 0, 8, 4, false), syn(0, 1, 8, 1, false)], 1, 2);
        e.step_currents(&[0]);
        let mut hits = Vec::new();
        for t in 1..=5 {
            let c = e.step_currents(&[]);
            for (n, &v) in c.iter().enumerate() {
                if v != 0.0 {
                    hits.push((t, n, v));
                }
            }
        }
        assert_eq!(hits, vec![(1, 1, 4.0), (4, 0, 4.0)]);
    }

    #[test]
    fn excitation_and_inhibition_cancel() {
        let mut e =
            engine_for(vec![syn(0, 0, 9, 2, false), syn(1, 0, 9, 2, true)], 2, 1);
        e.step_currents(&[0, 1]);
        e.step_currents(&[]);
        assert_eq!(e.step_currents(&[]), [0.0], "equal E and I at the same slot cancel");
    }

    #[test]
    fn repeated_spikes_accumulate() {
        let mut e = engine_for(vec![syn(0, 0, 3, 2, false)], 1, 1);
        e.step_currents(&[0]); // lands at t=2
        e.step_currents(&[0]); // lands at t=3
        assert_eq!(e.step_currents(&[]), [1.5]);
        assert_eq!(e.step_currents(&[]), [1.5]);
    }

    #[test]
    fn split_layer_routes_to_correct_chunks() {
        // Dense enough to need several PEs; currents must land at global
        // target indices regardless of the split.
        let mut syns = Vec::new();
        for s in 0..300u32 {
            syns.push(syn(s, (s * 7) % 280, 1, 1, false));
        }
        let mut e = engine_for(syns.clone(), 300, 280);
        let all: Vec<u32> = (0..300).collect();
        e.step_currents(&all);
        let mut expect = vec![0.0f32; 280];
        for s in &syns {
            expect[s.target as usize] += 0.5;
        }
        assert_eq!(e.step_currents(&[]).to_vec(), expect);
        assert_eq!(e.events, 300);
    }

    #[test]
    fn reset_replays_identically() {
        let mut e = engine_for(vec![syn(0, 1, 10, 2, false), syn(1, 0, 6, 1, true)], 2, 3);
        let run = |e: &mut SerialLayerEngine| -> Vec<Vec<f32>> {
            let stim: [&[u32]; 4] = [&[0, 1], &[], &[1], &[]];
            stim.iter().map(|s| e.step_currents(s).to_vec()).collect()
        };
        let first = run(&mut e);
        e.reset();
        assert_eq!(e.timestep(), 0);
        let second = run(&mut e);
        assert_eq!(first, second, "reset must reproduce the run exactly");
    }

    #[test]
    fn silent_steps_skip_ring_readout() {
        // A silent engine must gate out every (PE, slot) read while still
        // producing the exact currents once activity arrives.
        let mut e = engine_for(vec![syn(0, 1, 10, 2, false)], 2, 3);
        for _ in 0..10 {
            assert_eq!(e.step_currents(&[]), [0.0, 0.0, 0.0]);
        }
        let n_pes = e.compiled.pes.len() as u64;
        assert_eq!(e.skipped_slots, 10 * n_pes, "all silent reads must be gated");
        // The spike lands at t+2 exactly as without gating.
        e.step_currents(&[0]);
        e.step_currents(&[]);
        assert_eq!(e.step_currents(&[]), [0.0, 5.0, 0.0]);
        assert_eq!(e.events, 1);
    }

    #[test]
    fn gating_never_changes_results_under_random_stimulus() {
        use crate::rng::Rng;
        // Dense-vs-gated differential: replay the same stimulus and check
        // the telemetry splits every step into read-or-skipped, while
        // delivered currents match the analytic expectation per synapse.
        let syns = vec![
            syn(0, 0, 4, 1, false),
            syn(0, 2, 6, 3, false),
            syn(1, 1, 8, 2, true),
            syn(2, 0, 2, 4, false),
        ];
        let mut e = engine_for(syns.clone(), 3, 3);
        let mut rng = Rng::new(5150);
        let mut expected = vec![vec![0.0f32; 3]; 64 + 8];
        for t in 0..64u64 {
            let firing: Vec<u32> = (0..3).filter(|_| rng.chance(0.3)).collect();
            for s in &syns {
                if firing.contains(&s.source) {
                    let sign = if s.syn_type == SynapseType::Inhibitory { -1.0 } else { 1.0 };
                    expected[(t + s.delay as u64) as usize][s.target as usize] +=
                        sign * s.weight as f32 * 0.5;
                }
            }
            assert_eq!(e.step_currents(&firing), expected[t as usize], "t={t}");
        }
        assert!(e.skipped_slots > 0, "a 30%-rate stimulus must leave silent slots");
    }

    #[test]
    fn out_of_range_spike_is_ignored() {
        let mut e = engine_for(vec![syn(0, 0, 3, 1, false)], 1, 1);
        e.step_currents(&[7]); // no PE stores rows for source 7
        assert_eq!(e.step_currents(&[]), [0.0]);
        assert_eq!(e.events, 0);
    }
}
