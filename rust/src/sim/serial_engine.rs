//! Event-based serial execution engine (paper §III-A runtime semantics).
//!
//! Per timestep `t` each serial PE:
//! 1. reads + clears ring-buffer slot `t mod D` — excitatory minus
//!    inhibitory accumulators become the synaptic input current;
//! 2. processes the spikes arriving this step: master population table →
//!    address list → synaptic-matrix block; each synaptic word's weight is
//!    accumulated into slot `(t + delay) mod D` of its type's buffer.
//!
//! (Reading before writing makes a D-slot ring sufficient for delays up to
//! D: a write at delay D lands in the slot just cleared, to be read exactly
//! D steps later.)

use crate::model::SynapseType;
use crate::paradigm::serial::SerialCompiled;

struct PeState {
    /// Ring buffer: `[slot][type][local target]`, i32 accumulators
    /// (16-bit in hardware per Table I; i32 here to keep saturation out of
    /// the equivalence story — values stay far below either limit).
    ring: Vec<i32>,
    n_tgt: usize,
    delay_range: usize,
}

impl PeState {
    #[inline]
    fn idx(&self, slot: usize, syn_type: usize, target: usize) -> usize {
        (slot * SynapseType::COUNT + syn_type) * self.n_tgt + target
    }
}

/// Executes one serially-compiled layer.
pub struct SerialLayerEngine {
    compiled: SerialCompiled,
    pes: Vec<PeState>,
    n_target: usize,
    t: u64,
    /// Synaptic events processed (telemetry for the perf benches).
    pub events: u64,
}

impl SerialLayerEngine {
    pub fn new(compiled: SerialCompiled, n_target: usize) -> Self {
        let pes = compiled
            .pes
            .iter()
            .map(|p| {
                let n_tgt = p.target_slice.len();
                let delay_range = p.delay_range as usize;
                PeState {
                    ring: vec![0; delay_range * SynapseType::COUNT * n_tgt],
                    n_tgt,
                    delay_range,
                }
            })
            .collect();
        SerialLayerEngine { compiled, pes, n_target, t: 0, events: 0 }
    }

    pub fn timestep(&self) -> u64 {
        self.t
    }

    /// Advance one timestep: consume this step's ring slot into per-target
    /// currents, then process `spikes_in` (source-population neuron ids
    /// firing *this* step) into future slots.
    pub fn step_currents(&mut self, spikes_in: &[u32]) -> Vec<f32> {
        let mut currents = vec![0.0f32; self.n_target];
        let t = self.t as usize;

        // Phase 1: neural-input read-out (time-triggered).
        for (prog, pe) in self.compiled.pes.iter().zip(&mut self.pes) {
            let slot = t % pe.delay_range;
            let scale = prog.weight_scale;
            for local in 0..pe.n_tgt {
                let e = pe.idx(slot, SynapseType::Excitatory.index(), local);
                let i = pe.idx(slot, SynapseType::Inhibitory.index(), local);
                let net = pe.ring[e] - pe.ring[i];
                pe.ring[e] = 0;
                pe.ring[i] = 0;
                if net != 0 {
                    currents[prog.target_slice.lo as usize + local] += net as f32 * scale;
                }
            }
        }

        // Phase 2: event-based synaptic processing of this step's spikes.
        for &src in spikes_in {
            for (prog, pe) in self.compiled.pes.iter().zip(&mut self.pes) {
                if !prog.source_slice.contains(src) {
                    continue;
                }
                let Some(slot_idx) = prog.mpt.lookup(src) else { continue };
                let entry = prog.address_list.entries[slot_idx as usize];
                for word in prog.matrix.block(entry) {
                    let write_slot = (t + word.delay() as usize) % pe.delay_range;
                    let j = pe.idx(write_slot, word.syn_type().index(), word.target() as usize);
                    pe.ring[j] += word.weight() as i32;
                    self.events += 1;
                }
            }
        }

        self.t += 1;
        currents
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hardware::PeSpec;
    use crate::model::{
        LifParams, PopulationId, Projection, ProjectionId, Synapse, SynapseType,
    };
    use crate::paradigm::serial::compile_serial;

    fn engine_for(synapses: Vec<Synapse>, n_src: usize, n_tgt: usize) -> SerialLayerEngine {
        let proj = Projection {
            id: ProjectionId(0),
            source: PopulationId(0),
            target: PopulationId(1),
            synapses,
            weight_scale: 0.5,
        };
        let c = compile_serial(&proj, n_src, n_tgt, LifParams::default(), &PeSpec::default())
            .unwrap();
        SerialLayerEngine::new(c, n_tgt)
    }

    fn syn(s: u32, t: u32, w: u8, d: u16, inh: bool) -> Synapse {
        Synapse {
            source: s,
            target: t,
            weight: w,
            delay: d,
            syn_type: if inh { SynapseType::Inhibitory } else { SynapseType::Excitatory },
        }
    }

    #[test]
    fn delay_one_arrives_next_step() {
        let mut e = engine_for(vec![syn(0, 1, 10, 1, false)], 2, 3);
        let c0 = e.step_currents(&[0]); // spike at t=0
        assert_eq!(c0, vec![0.0, 0.0, 0.0], "nothing due at t=0");
        let c1 = e.step_currents(&[]);
        assert_eq!(c1, vec![0.0, 5.0, 0.0], "weight 10 × scale 0.5 at t=1");
        let c2 = e.step_currents(&[]);
        assert_eq!(c2, vec![0.0, 0.0, 0.0], "one-shot delivery");
    }

    #[test]
    fn delay_equal_to_range_wraps_correctly() {
        let mut e = engine_for(vec![syn(0, 0, 8, 4, false), syn(0, 1, 8, 1, false)], 1, 2);
        e.step_currents(&[0]);
        let mut hits = Vec::new();
        for t in 1..=5 {
            let c = e.step_currents(&[]);
            for (n, &v) in c.iter().enumerate() {
                if v != 0.0 {
                    hits.push((t, n, v));
                }
            }
        }
        assert_eq!(hits, vec![(1, 1, 4.0), (4, 0, 4.0)]);
    }

    #[test]
    fn excitation_and_inhibition_cancel() {
        let mut e =
            engine_for(vec![syn(0, 0, 9, 2, false), syn(1, 0, 9, 2, true)], 2, 1);
        e.step_currents(&[0, 1]);
        e.step_currents(&[]);
        let c = e.step_currents(&[]);
        assert_eq!(c, vec![0.0], "equal E and I at the same slot cancel");
    }

    #[test]
    fn repeated_spikes_accumulate() {
        let mut e = engine_for(vec![syn(0, 0, 3, 2, false)], 1, 1);
        e.step_currents(&[0]); // lands at t=2
        e.step_currents(&[0]); // lands at t=3
        let c2 = e.step_currents(&[]);
        assert_eq!(c2, vec![1.5]);
        let c3 = e.step_currents(&[]);
        assert_eq!(c3, vec![1.5]);
    }

    #[test]
    fn split_layer_routes_to_correct_chunks() {
        // Dense enough to need several PEs; currents must land at global
        // target indices regardless of the split.
        let mut syns = Vec::new();
        for s in 0..300u32 {
            syns.push(syn(s, (s * 7) % 280, 1, 1, false));
        }
        let mut e = engine_for(syns.clone(), 300, 280);
        let all: Vec<u32> = (0..300).collect();
        e.step_currents(&all);
        let c = e.step_currents(&[]);
        let mut expect = vec![0.0f32; 280];
        for s in &syns {
            expect[s.target as usize] += 0.5;
        }
        assert_eq!(c, expect);
        assert_eq!(e.events, 300);
    }
}
