//! Whole-network simulation: population LIF state, layer engines, spike
//! routing, recording.
//!
//! Populations are updated **wave by wave** each timestep: a population's
//! topological wave is its longest-path depth from the sources, so every
//! projection goes from an earlier wave into a strictly later one
//! (feed-forward networks only — recurrent edges would need a one-step
//! delay relaxation, which the paper's per-layer evaluation never
//! exercises). Within a wave, populations own disjoint membrane/current
//! buffers and engines own disjoint compiled state, which is what makes
//! [`NetworkSim::run_jobs`]'s intra-sample layer parallelism sound: engines
//! of one wave step concurrently on scoped worker threads, their outputs
//! are staged per engine, and the coordinator reduces them in fixed engine
//! order — recorders are bit-identical at any jobs count.
//!
//! The stepping loop is allocation-free in steady state: engines are
//! grouped by wave at construction, input currents accumulate into fixed
//! per-population buffers (zeroed after consumption, never reallocated),
//! per-population spike scratch is reused across steps, and the
//! [`SpikeProvider`] fills a caller-owned buffer instead of returning a
//! fresh `Vec`. [`NetworkSim::reset`] rewinds everything to t=0 so one
//! compiled simulator can serve many stimulus samples — the primitive
//! [`super::batch::BatchRunner`] builds on.

use super::backend::{BackendBox, NativeMac};
use super::parallel_engine::{ParallelEngineCheckpoint, ParallelLayerEngine};
use super::serial_engine::{SerialEngineCheckpoint, SerialLayerEngine};
use super::spikebits::SpikeWords;
#[cfg(not(feature = "pjrt"))]
use crate::costmodel::serial::balanced_split;
use crate::model::lif::lif_step_chunked;
use crate::model::{LifParams, Network, PopulationId};
use crate::paradigm::Paradigm;
use crate::switching::CompiledLayer;
use anyhow::{bail, ensure, Result};
use std::collections::BTreeMap;
#[cfg(not(feature = "pjrt"))]
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
#[cfg(not(feature = "pjrt"))]
use std::sync::atomic::{AtomicBool, Ordering};
#[cfg(not(feature = "pjrt"))]
use std::sync::{Barrier, Mutex, RwLock};
use std::time::Instant;

/// Supplies source-population spikes per timestep by filling the
/// caller-owned buffer (handed over cleared) with firing neuron ids —
/// steady state allocates nothing once the buffer has grown to its
/// high-water mark.
pub type SpikeProvider<'a> = dyn FnMut(PopulationId, u64, &mut Vec<u32>) + 'a;

/// Per-population LIF state.
struct PopState {
    params: LifParams,
    v: Vec<f32>,
    refrac: Vec<u32>,
}

/// One projection's execution engine.
enum LayerEngine {
    Serial(SerialLayerEngine),
    Parallel(ParallelLayerEngine),
}

impl LayerEngine {
    fn step_currents(&mut self, spikes_in: &[u32]) -> &[f32] {
        match self {
            LayerEngine::Serial(e) => e.step_currents(spikes_in),
            LayerEngine::Parallel(e) => e.step_currents(spikes_in),
        }
    }

    /// Bitmap fast path: the sequential stepping loop packs each source
    /// population's spikes once per step and hands every engine the shared
    /// words (the id-list path above packs per engine call instead — used
    /// by the worker threads, which carry staged id lists).
    fn step_currents_words(&mut self, spikes_in: &SpikeWords) -> &[f32] {
        match self {
            LayerEngine::Serial(e) => e.step_currents_words(spikes_in),
            LayerEngine::Parallel(e) => e.step_currents_words(spikes_in),
        }
    }

    fn reset(&mut self) {
        match self {
            LayerEngine::Serial(e) => e.reset(),
            LayerEngine::Parallel(e) => e.reset(),
        }
    }

    fn set_profile(&mut self, on: bool) {
        match self {
            LayerEngine::Serial(e) => e.set_profile(on),
            LayerEngine::Parallel(e) => e.set_profile(on),
        }
    }

    fn paradigm(&self) -> Paradigm {
        match self {
            LayerEngine::Serial(_) => Paradigm::Serial,
            LayerEngine::Parallel(_) => Paradigm::Parallel,
        }
    }

    /// (steps, spikes_in, events, macs) cumulative telemetry.
    fn telemetry(&self) -> (u64, u64, u64, u64) {
        match self {
            LayerEngine::Serial(e) => (e.steps, e.spikes_in, e.events, 0),
            LayerEngine::Parallel(e) => (e.steps, e.spikes_in, 0, e.macs),
        }
    }

    /// (window_spikes, window_steps) of the current activity window.
    fn window_counts(&self) -> (u64, u64) {
        match self {
            LayerEngine::Serial(e) => (e.window_spikes, e.window_steps),
            LayerEngine::Parallel(e) => (e.window_spikes, e.window_steps),
        }
    }

    fn clear_window(&mut self) {
        match self {
            LayerEngine::Serial(e) => e.clear_window(),
            LayerEngine::Parallel(e) => e.clear_window(),
        }
    }

    /// (readout, dispatch) nanos accumulated while profiling.
    fn phase_nanos(&self) -> (u64, u64) {
        match self {
            LayerEngine::Serial(e) => (e.readout_nanos, e.dispatch_nanos),
            LayerEngine::Parallel(e) => (e.readout_nanos, e.dispatch_nanos),
        }
    }

    /// The MAC-backend kernel variant, for parallel engines.
    fn backend_kernel(&self) -> Option<&'static str> {
        match self {
            LayerEngine::Serial(_) => None,
            LayerEngine::Parallel(e) => Some(e.backend_kernel_variant()),
        }
    }

    fn checkpoint(&self) -> EngineCheckpoint {
        match self {
            LayerEngine::Serial(e) => EngineCheckpoint::Serial(e.checkpoint()),
            LayerEngine::Parallel(e) => EngineCheckpoint::Parallel(e.checkpoint()),
        }
    }

    fn reset_to(&mut self, t: u64) {
        match self {
            LayerEngine::Serial(e) => e.reset_to(t),
            LayerEngine::Parallel(e) => e.reset_to(t),
        }
    }
}

/// Snapshot of one layer engine's dynamic state, tagged by paradigm.
#[derive(Clone, Debug, PartialEq)]
pub enum EngineCheckpoint {
    Serial(SerialEngineCheckpoint),
    Parallel(ParallelEngineCheckpoint),
}

impl EngineCheckpoint {
    /// True when every captured buffer is identically zero (the post-reset
    /// state) — the only state that can restore across a paradigm flip.
    pub fn is_pristine(&self) -> bool {
        match self {
            EngineCheckpoint::Serial(c) => c.is_pristine(),
            EngineCheckpoint::Parallel(c) => c.is_pristine(),
        }
    }

    pub fn timestep(&self) -> u64 {
        match self {
            EngineCheckpoint::Serial(c) => c.timestep(),
            EngineCheckpoint::Parallel(c) => c.timestep(),
        }
    }

    pub fn byte_size(&self) -> usize {
        match self {
            EngineCheckpoint::Serial(c) => c.byte_size(),
            EngineCheckpoint::Parallel(c) => c.byte_size(),
        }
    }

    fn paradigm(&self) -> Paradigm {
        match self {
            EngineCheckpoint::Serial(_) => Paradigm::Serial,
            EngineCheckpoint::Parallel(_) => Paradigm::Parallel,
        }
    }
}

/// Snapshot of a [`NetworkSim`]'s complete dynamic state at one timestep:
/// membrane voltages and refractory counters, input-current accumulators,
/// spike scratch (id lists and packed words), per-engine ring state, the
/// recorder, and the clock. Cumulative telemetry (activity counters,
/// profiling nanos) is deliberately excluded — it is reporting state, not
/// replay state. The recovery path takes one of these at every sample
/// boundary and rolls back to it when a fault invalidates the sample
/// ([`NetworkSim::restore`]); stimulus RNG cursors live with the caller's
/// [`SpikeProvider`], which the recovery runner snapshots alongside
/// (`crate::rng::Rng` is `Clone`).
#[derive(Clone, Debug)]
pub struct SimCheckpoint {
    /// Per engine: original projection index + paradigm-tagged state, in
    /// the sim's wave-grouped engine order.
    engines: Vec<(usize, EngineCheckpoint)>,
    /// Per population: `(v, refrac)` for LIF populations, `None` for
    /// spike sources.
    pops: Vec<Option<(Vec<f32>, Vec<u32>)>>,
    currents: Vec<Vec<f32>>,
    spike_buf: Vec<Vec<u32>>,
    spike_words: Vec<SpikeWords>,
    recorder: Recorder,
    t: u64,
}

impl SimCheckpoint {
    pub fn timestep(&self) -> u64 {
        self.t
    }

    /// In-memory footprint of the captured state — what a checkpoint costs
    /// (the `checkpoint_bytes` recovery statistic).
    pub fn byte_size(&self) -> usize {
        let engines: usize = self.engines.iter().map(|(_, e)| e.byte_size()).sum();
        let pops: usize = self
            .pops
            .iter()
            .flatten()
            .map(|(v, r)| v.len() * 4 + r.len() * 4)
            .sum();
        let currents: usize = self.currents.iter().map(|c| c.len() * 4).sum();
        let spikes: usize = self.spike_buf.iter().map(|s| s.len() * 4).sum();
        let words: usize = self.spike_words.iter().map(|w| w.words().len() * 8).sum();
        let recorder: usize = self.recorder.spikes.values().map(|v| v.len() * 12).sum::<usize>()
            + self.recorder.v.values().map(|t| t.data.len() * 4).sum::<usize>();
        engines + pops + currents + spikes + words + recorder + 8
    }
}

/// Flat voltage trace for one recorded population: a `(steps × neurons)`
/// row-major buffer appended to once per step — no per-step `Vec` clone.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct VoltageTrace {
    /// Neurons per recorded step (row width).
    pub n_neurons: usize,
    /// Row-major `steps × n_neurons` samples.
    pub data: Vec<f32>,
}

impl VoltageTrace {
    pub fn n_steps(&self) -> usize {
        if self.n_neurons == 0 {
            0
        } else {
            self.data.len() / self.n_neurons
        }
    }

    /// The recorded membrane row of timestep `t`.
    pub fn step(&self, t: usize) -> &[f32] {
        &self.data[t * self.n_neurons..(t + 1) * self.n_neurons]
    }
}

/// Recorded spikes (and optional voltages) per population.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Recorder {
    /// `spikes[pop] = [(t, neuron)]`.
    pub spikes: BTreeMap<usize, Vec<(u64, u32)>>,
    /// `v[pop]` = flat voltage trace for populations with `record_v`.
    pub v: BTreeMap<usize, VoltageTrace>,
}

impl Recorder {
    pub fn spikes_of(&self, pop: PopulationId) -> &[(u64, u32)] {
        self.spikes.get(&pop.0).map(Vec::as_slice).unwrap_or(&[])
    }

    /// The flat voltage trace of a recorded population, if any.
    pub fn v_of(&self, pop: PopulationId) -> Option<&VoltageTrace> {
        self.v.get(&pop.0)
    }

    /// Append one membrane row for `pop` (fixing the row width on first use).
    fn record_v_step(&mut self, pop: usize, v: &[f32]) {
        let trace = self.v.entry(pop).or_default();
        if trace.n_neurons == 0 {
            trace.n_neurons = v.len();
        }
        trace.data.extend_from_slice(v);
    }

    /// Pre-size `pop`'s voltage trace for `steps` more rows of `n` neurons.
    fn reserve_v(&mut self, pop: usize, n: usize, steps: usize) {
        let trace = self.v.entry(pop).or_default();
        if trace.n_neurons == 0 {
            trace.n_neurons = n;
        }
        trace.data.reserve(n * steps);
    }

    /// Export all recorded spikes as CSV (`population,timestep,neuron`).
    pub fn save_spikes_csv(&self, path: &std::path::Path) -> crate::Result<()> {
        crate::io::csv::write_csv(
            path,
            &["population", "timestep", "neuron"],
            self.spikes.iter().flat_map(|(&pop, spikes)| {
                spikes.iter().map(move |&(t, n)| {
                    vec![pop.to_string(), t.to_string(), n.to_string()]
                })
            }),
        )?;
        Ok(())
    }

    pub fn spike_count(&self, pop: PopulationId) -> usize {
        self.spikes_of(pop).len()
    }

    pub fn total_spikes(&self) -> usize {
        self.spikes.values().map(Vec::len).sum()
    }
}

/// Per-layer observed runtime activity (cumulative engine telemetry in
/// projection order) — the runtime-informed firing-rate input
/// [`crate::costmodel::activity`] and [`crate::paradigm::CostEstimate::step_cost`]
/// consume.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct LayerActivity {
    /// Projection index in the network (reporting order).
    pub proj: usize,
    pub source: PopulationId,
    pub target: PopulationId,
    pub paradigm: Paradigm,
    /// Source-population size (the firing-rate denominator).
    pub n_source: usize,
    /// Timesteps this engine has executed (cumulative across resets).
    pub steps: u64,
    /// Incoming spikes the engine has seen (cumulative).
    pub spikes_in: u64,
    /// Synaptic events processed (serial engines; cumulative).
    pub events: u64,
    /// MAC operations actually issued (parallel engines; cumulative).
    pub macs: u64,
    /// Incoming spikes in the *current activity window* (cleared by
    /// [`NetworkSim::reset`] / [`NetworkSim::clear_windows`] — recent
    /// activity, not lifetime history).
    pub window_spikes: u64,
    /// Timesteps executed in the current activity window.
    pub window_steps: u64,
}

impl LayerActivity {
    /// Observed source firing rate: spikes per source neuron per timestep.
    pub fn firing_rate(&self) -> f64 {
        crate::costmodel::activity::observed_rate(self.spikes_in, self.steps, self.n_source)
    }

    /// Observed firing rate over the *current activity window* only — the
    /// signal the adaptive re-switcher feeds to
    /// [`crate::switching::SwitchPolicy::decide_with_rate`]. Total: empty
    /// windows report `0.0`, never NaN.
    pub fn window_rate(&self) -> f64 {
        crate::costmodel::activity::observed_rate(
            self.window_spikes,
            self.window_steps,
            self.n_source,
        )
    }
}

/// Cumulative per-phase wall-clock of a profiled run
/// ([`NetworkSim::set_profile`]); engine phases are summed across engines,
/// so under [`NetworkSim::run_jobs`] they are CPU time, not elapsed time.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PhaseProfile {
    /// Ring/stacked-slot readout (serial Phase 1 / parallel MAC consume).
    pub readout_nanos: u64,
    /// Spike dispatch into future slots (both engines' Phase 2).
    pub dispatch_nanos: u64,
    /// LIF membrane updates.
    pub lif_nanos: u64,
    /// Spike/voltage recording.
    pub record_nanos: u64,
}

impl PhaseProfile {
    pub fn total_nanos(&self) -> u64 {
        self.readout_nanos + self.dispatch_nanos + self.lif_nanos + self.record_nanos
    }
}

/// One engine with its routing metadata, stored in wave-grouped order.
struct EngineSlot {
    /// Original projection index (telemetry is reported in this order).
    proj: usize,
    src: PopulationId,
    tgt: PopulationId,
    n_source: usize,
    engine: LayerEngine,
}

/// The network simulator.
pub struct NetworkSim {
    /// Engines grouped by topological wave of their source population
    /// (contiguous ranges per [`NetworkSim::wave_bounds`]).
    engines: Vec<EngineSlot>,
    /// `wave_bounds[w]` = engine range `[lo, hi)` whose sources sit in
    /// wave `w`.
    wave_bounds: Vec<(usize, usize)>,
    /// Population indices per topological wave (longest-path depth).
    pops_of_wave: Vec<Vec<usize>>,
    pops: Vec<Option<PopState>>,
    /// Fixed per-population input-current accumulators (zeroed after
    /// consumption each step, never reallocated).
    currents: Vec<Vec<f32>>,
    /// Per-population spike scratch for the current step.
    spike_buf: Vec<Vec<u32>>,
    /// Per-population bit-packed view of `spike_buf`, repacked once per
    /// step so every consuming engine dispatches on shared `u64` words.
    spike_words: Vec<SpikeWords>,
    record_spikes: Vec<bool>,
    record_v: Vec<bool>,
    pub recorder: Recorder,
    profile: bool,
    lif_nanos: u64,
    record_nanos: u64,
    t: u64,
}

impl NetworkSim {
    /// Build a simulator from a network and its compiled layers (one per
    /// projection, same order). `backend_factory` supplies a MAC backend per
    /// parallel layer (native by default; PJRT in the e2e example).
    pub fn new(
        net: &Network,
        layers: Vec<CompiledLayer>,
        backend_factory: impl FnMut() -> BackendBox,
    ) -> Result<Self> {
        let depth = Self::wave_depths(net);
        Self::with_depths(net, layers, backend_factory, &depth)
    }

    /// Longest-path depth per population ("wave"): sources sit at 0 and
    /// every projection crosses into a strictly deeper wave (guaranteed by
    /// the feed-forward check in `validate`).
    pub(crate) fn wave_depths(net: &Network) -> Vec<usize> {
        let topo = net.topo_order();
        let mut depth = vec![0usize; net.populations.len()];
        for &pid in &topo {
            for proj in &net.projections {
                if proj.target == pid {
                    depth[pid.0] = depth[pid.0].max(depth[proj.source.0] + 1);
                }
            }
        }
        depth
    }

    /// [`NetworkSim::new`] with a caller-supplied wave depth per population.
    /// The sharded driver builds each board's shard over a *sub-network*
    /// (fewer projections) but with the **global** depths of the full
    /// network, so every shard runs the same wave schedule and the
    /// wave-boundary spike exchange lines up across boards.
    pub(crate) fn with_depths(
        net: &Network,
        layers: Vec<CompiledLayer>,
        mut backend_factory: impl FnMut() -> BackendBox,
        depth: &[usize],
    ) -> Result<Self> {
        Self::validate(net, layers.len())?;
        ensure!(
            depth.len() == net.populations.len(),
            "wave depths cover {} populations, network has {}",
            depth.len(),
            net.populations.len()
        );
        for proj in &net.projections {
            ensure!(
                depth[proj.source.0] < depth[proj.target.0],
                "wave depths are not topological for projection {}",
                proj.id.0
            );
        }
        let topo = net.topo_order();
        let n_waves = depth.iter().max().map_or(1, |&d| d + 1);
        let mut pops_of_wave = vec![Vec::new(); n_waves];
        for &pid in &topo {
            pops_of_wave[depth[pid.0]].push(pid.0);
        }

        let mut engines: Vec<EngineSlot> = net
            .projections
            .iter()
            .zip(layers)
            .enumerate()
            .map(|(proj_idx, (proj, layer))| {
                let engine = match layer {
                    CompiledLayer::Serial(c) => {
                        let n_tgt = net.population(proj.target).n_neurons;
                        LayerEngine::Serial(SerialLayerEngine::new(c, n_tgt))
                    }
                    CompiledLayer::Parallel(c) => {
                        LayerEngine::Parallel(ParallelLayerEngine::new(c, backend_factory()))
                    }
                };
                EngineSlot {
                    proj: proj_idx,
                    src: proj.source,
                    tgt: proj.target,
                    n_source: net.population(proj.source).n_neurons,
                    engine,
                }
            })
            .collect();
        // Group engines by source wave; the sort is stable, so engines of
        // one wave keep projection order (the deterministic reduce order).
        engines.sort_by_key(|s| depth[s.src.0]);
        let mut wave_bounds = vec![(0usize, 0usize); n_waves];
        let mut cursor = 0usize;
        for (w, bounds) in wave_bounds.iter_mut().enumerate() {
            let lo = cursor;
            while cursor < engines.len() && depth[engines[cursor].src.0] == w {
                cursor += 1;
            }
            *bounds = (lo, cursor);
        }
        debug_assert_eq!(cursor, engines.len());

        let pops: Vec<Option<PopState>> = net
            .populations
            .iter()
            .map(|p| {
                p.lif_params().map(|params| PopState {
                    params: *params,
                    v: vec![params.v_init; p.n_neurons],
                    refrac: vec![0; p.n_neurons],
                })
            })
            .collect();

        Ok(NetworkSim {
            engines,
            wave_bounds,
            pops_of_wave,
            pops,
            currents: net.populations.iter().map(|p| vec![0.0; p.n_neurons]).collect(),
            spike_buf: vec![Vec::new(); net.populations.len()],
            spike_words: net
                .populations
                .iter()
                .map(|p| SpikeWords::new(p.n_neurons))
                .collect(),
            record_spikes: net.populations.iter().map(|p| p.record_spikes).collect(),
            record_v: net.populations.iter().map(|p| p.record_v).collect(),
            recorder: Recorder::default(),
            profile: false,
            lif_nanos: 0,
            record_nanos: 0,
            t: 0,
        })
    }

    /// The structural invariants simulation relies on, checked without
    /// materializing any engine state (shared with [`super::batch::BatchRunner`],
    /// whose workers then build sims infallibly): one compiled layer per
    /// projection, feed-forward topology, and every projection target is a
    /// LIF population (a projection into a spike source would accumulate
    /// currents nothing ever consumes).
    pub(crate) fn validate(net: &Network, n_layers: usize) -> Result<()> {
        ensure!(
            n_layers == net.projections.len(),
            "need one compiled layer per projection"
        );
        // Feed-forward check: topological position of source < target.
        let topo = net.topo_order();
        let pos: BTreeMap<usize, usize> =
            topo.iter().enumerate().map(|(i, p)| (p.0, i)).collect();
        for proj in &net.projections {
            ensure!(
                pos[&proj.source.0] < pos[&proj.target.0],
                "NetworkSim supports feed-forward networks only (projection {} is not)",
                proj.id.0
            );
            ensure!(
                net.population(proj.target).lif_params().is_some(),
                "projection {} targets spike source '{}' — targets must be LIF populations",
                proj.id.0,
                net.population(proj.target).label
            );
        }
        Ok(())
    }

    /// Default construction with the native MAC backend everywhere.
    pub fn native(net: &Network, layers: Vec<CompiledLayer>) -> Result<Self> {
        Self::new(net, layers, || Box::new(NativeMac))
    }

    pub fn timestep(&self) -> u64 {
        self.t
    }

    /// Enable per-phase wall-clock accumulation on the sim and every engine
    /// (read back via [`NetworkSim::phase_profile`]); off by default so the
    /// hot path carries no timer syscalls.
    pub fn set_profile(&mut self, on: bool) {
        self.profile = on;
        for slot in &mut self.engines {
            slot.engine.set_profile(on);
        }
    }

    /// Rewind to t=0 with fresh membrane/ring state and an empty recorder,
    /// keeping every compiled structure and buffer — the cheap path to run
    /// another stimulus sample without recompiling. Engine telemetry
    /// (`events`/`macs`/activity counters/profiling nanos) keeps
    /// accumulating across resets.
    pub fn reset(&mut self) {
        for slot in &mut self.engines {
            slot.engine.reset();
        }
        for state in self.pops.iter_mut().flatten() {
            state.v.fill(state.params.v_init);
            state.refrac.fill(0);
        }
        for c in &mut self.currents {
            c.fill(0.0);
        }
        for s in &mut self.spike_buf {
            s.clear();
        }
        for w in &mut self.spike_words {
            w.clear();
        }
        self.recorder = Recorder::default();
        self.t = 0;
    }

    /// Snapshot the sim's complete dynamic state (see [`SimCheckpoint`]).
    pub fn checkpoint(&self) -> SimCheckpoint {
        SimCheckpoint {
            engines: self.engines.iter().map(|s| (s.proj, s.engine.checkpoint())).collect(),
            pops: self
                .pops
                .iter()
                .map(|p| p.as_ref().map(|s| (s.v.clone(), s.refrac.clone())))
                .collect(),
            currents: self.currents.clone(),
            spike_buf: self.spike_buf.clone(),
            spike_words: self.spike_words.clone(),
            recorder: self.recorder.clone(),
            t: self.t,
        }
    }

    /// Restore a [`NetworkSim::checkpoint`] — into this sim, or into a
    /// freshly built sim over the *same network* (the recovery path builds
    /// a new sim from re-admitted layers and restores into it). Subsequent
    /// stepping replays bit-identically.
    ///
    /// An engine whose paradigm flipped since the snapshot (capacity-driven
    /// re-admission) accepts only a *pristine* snapshot — mid-sample ring
    /// state has no cross-paradigm representation; the recovery runner
    /// checkpoints at sample boundaries, where engines are pristine by
    /// construction. Telemetry is left accumulating, as across
    /// [`NetworkSim::reset`].
    pub fn restore(&mut self, ckpt: &SimCheckpoint) -> Result<()> {
        ensure!(
            ckpt.engines.len() == self.engines.len()
                && ckpt.pops.len() == self.pops.len()
                && ckpt.currents.len() == self.currents.len(),
            "checkpoint shape mismatch: {} engines / {} populations vs sim {} / {}",
            ckpt.engines.len(),
            ckpt.pops.len(),
            self.engines.len(),
            self.pops.len()
        );
        for (slot, (proj, eck)) in self.engines.iter_mut().zip(&ckpt.engines) {
            ensure!(
                slot.proj == *proj,
                "checkpoint engine order mismatch at projection {proj} (sim has {})",
                slot.proj
            );
            match (&mut slot.engine, eck) {
                (LayerEngine::Serial(e), EngineCheckpoint::Serial(c)) => e.restore(c)?,
                (LayerEngine::Parallel(e), EngineCheckpoint::Parallel(c)) => e.restore(c)?,
                (engine, ck) => {
                    ensure!(
                        ck.is_pristine(),
                        "layer {proj}: cannot restore mid-sample {} state into a {} engine",
                        ck.paradigm(),
                        engine.paradigm()
                    );
                    engine.reset_to(ck.timestep());
                }
            }
        }
        for (state, snap) in self.pops.iter_mut().zip(&ckpt.pops) {
            match (state, snap) {
                (Some(state), Some((v, refrac))) => {
                    ensure!(
                        v.len() == state.v.len(),
                        "checkpoint population size {} vs sim {}",
                        v.len(),
                        state.v.len()
                    );
                    state.v.copy_from_slice(v);
                    state.refrac.copy_from_slice(refrac);
                }
                (None, None) => {}
                _ => bail!("checkpoint population kinds do not match the sim"),
            }
        }
        for (c, snap) in self.currents.iter_mut().zip(&ckpt.currents) {
            ensure!(c.len() == snap.len(), "checkpoint current buffer shape mismatch");
            c.copy_from_slice(snap);
        }
        self.spike_buf.clone_from(&ckpt.spike_buf);
        self.spike_words.clone_from(&ckpt.spike_words);
        self.recorder = ckpt.recorder.clone();
        self.t = ckpt.t;
        Ok(())
    }

    /// Synaptic events processed by the serial engines (cumulative).
    pub fn total_events(&self) -> u64 {
        self.engines.iter().map(|s| s.engine.telemetry().2).sum()
    }

    /// MAC operations actually issued by the parallel engines (cumulative).
    pub fn total_macs(&self) -> u64 {
        self.engines.iter().map(|s| s.engine.telemetry().3).sum()
    }

    /// Per-layer observed activity (cumulative engine telemetry), in
    /// projection order.
    pub fn layer_activity(&self) -> Vec<LayerActivity> {
        let mut out: Vec<LayerActivity> = self
            .engines
            .iter()
            .map(|s| {
                let (steps, spikes_in, events, macs) = s.engine.telemetry();
                let (window_spikes, window_steps) = s.engine.window_counts();
                LayerActivity {
                    proj: s.proj,
                    source: s.src,
                    target: s.tgt,
                    paradigm: s.engine.paradigm(),
                    n_source: s.n_source,
                    steps,
                    spikes_in,
                    events,
                    macs,
                    window_spikes,
                    window_steps,
                }
            })
            .collect();
        out.sort_by_key(|a| a.proj);
        out
    }

    /// Start a fresh activity window on every engine without touching ring
    /// state, lifetime telemetry, or the recorder. The adaptive re-switcher
    /// calls this after reading [`NetworkSim::layer_activity`] at a sample
    /// boundary it chose not to act on ([`NetworkSim::reset`] clears
    /// windows too, as part of rewinding all dynamic state).
    pub fn clear_windows(&mut self) {
        for slot in &mut self.engines {
            slot.engine.clear_window();
        }
    }

    /// Hot-swap one projection's engine for a differently-compiled form of
    /// the *same layer* — the runtime re-switching primitive
    /// ([`crate::switching::adaptive`]). Legal only between samples: the
    /// outgoing engine must be pristine (post-[`NetworkSim::reset`] state),
    /// because mid-sample ring state has no cross-paradigm representation.
    ///
    /// The replacement is spliced in place: topology (projection index,
    /// source/target routing, wave membership) is untouched, so the wave
    /// schedule and [`NetworkSim::run_jobs`]'s engine partition stay valid.
    /// Lifetime `steps`/`spikes_in` telemetry carries over to the new
    /// engine so observed-rate reporting stays continuous; paradigm-specific
    /// counters (`events`/`macs`) start at zero, and the activity window
    /// starts fresh. Parallel replacements run on the native MAC backend.
    pub fn swap_layer_engine(&mut self, proj: usize, layer: CompiledLayer) -> Result<()> {
        let slot = self
            .engines
            .iter_mut()
            .find(|s| s.proj == proj)
            .ok_or_else(|| anyhow::anyhow!("no engine for projection {proj}"))?;
        let n_target = self.currents[slot.tgt.0].len();
        let ch = layer.character();
        ensure!(
            ch.n_source == slot.n_source && ch.n_target == n_target,
            "swap layer shape {}×{} does not match projection {proj} ({}×{})",
            ch.n_source,
            ch.n_target,
            slot.n_source,
            n_target
        );
        let ck = slot.engine.checkpoint();
        ensure!(
            ck.is_pristine(),
            "projection {proj} has in-flight ring state — engines swap only between samples"
        );
        let (steps, spikes_in, _, _) = slot.engine.telemetry();
        let mut engine = match layer {
            CompiledLayer::Serial(c) => {
                let mut e = SerialLayerEngine::new(c, n_target);
                e.steps = steps;
                e.spikes_in = spikes_in;
                LayerEngine::Serial(e)
            }
            CompiledLayer::Parallel(c) => {
                let mut e = ParallelLayerEngine::new(c, Box::new(NativeMac));
                e.steps = steps;
                e.spikes_in = spikes_in;
                LayerEngine::Parallel(e)
            }
        };
        engine.set_profile(self.profile);
        engine.reset_to(ck.timestep());
        slot.engine = engine;
        Ok(())
    }

    /// Distinct MAC-backend kernel variants across the parallel engines
    /// (empty when every layer runs serial) — `simulate --profile` prints
    /// this next to the LIF kernel variant so bench numbers are
    /// attributable to an implementation.
    pub fn backend_kernel_variants(&self) -> Vec<&'static str> {
        let mut out: Vec<&'static str> = Vec::new();
        for slot in &self.engines {
            if let Some(k) = slot.engine.backend_kernel() {
                if !out.contains(&k) {
                    out.push(k);
                }
            }
        }
        out
    }

    /// Cumulative phase breakdown of profiled runs (zeros unless
    /// [`NetworkSim::set_profile`] was enabled).
    pub fn phase_profile(&self) -> PhaseProfile {
        let mut p = PhaseProfile {
            lif_nanos: self.lif_nanos,
            record_nanos: self.record_nanos,
            ..Default::default()
        };
        for slot in &self.engines {
            let (r, d) = slot.engine.phase_nanos();
            p.readout_nanos += r;
            p.dispatch_nanos += d;
        }
        p
    }

    /// Pre-size voltage traces for `steps` more recorded rows.
    pub(crate) fn reserve_recording(&mut self, steps: u64) {
        for (p, state) in self.pops.iter().enumerate() {
            if self.record_v[p] {
                if let Some(state) = state {
                    self.recorder.reserve_v(p, state.v.len(), steps as usize);
                }
            }
        }
    }

    /// Advance one timestep. `provider` fills each spike-source
    /// population's firing neuron ids for this step into a reused buffer.
    pub fn step(&mut self, provider: &mut SpikeProvider) {
        let NetworkSim {
            ref mut engines,
            ref wave_bounds,
            ref pops_of_wave,
            ref mut pops,
            ref mut currents,
            ref mut spike_buf,
            ref mut spike_words,
            ref record_spikes,
            ref record_v,
            ref mut recorder,
            profile,
            ref mut lif_nanos,
            ref mut record_nanos,
            t,
            ..
        } = *self;

        for (w, &(lo, hi)) in wave_bounds.iter().enumerate() {
            // Phase A: this wave's populations produce their spikes — their
            // input currents are complete (all inbound engines ran in
            // earlier waves). Only the LIF branch is charged to the LIF
            // phase timer; provider (stimulus-generation) time is the
            // caller's, not the simulator's. Each population's spikes are
            // bit-packed once here, so every consuming engine in Phase B
            // dispatches on the shared words.
            for &p in &pops_of_wave[w] {
                let buf = &mut spike_buf[p];
                if let Some(state) = &mut pops[p] {
                    let t0 = profile.then(Instant::now);
                    lif_step_chunked(
                        &state.params,
                        &mut state.v,
                        &currents[p],
                        &mut state.refrac,
                        buf,
                    );
                    currents[p].fill(0.0);
                    if let Some(t0) = t0 {
                        *lif_nanos += t0.elapsed().as_nanos() as u64;
                    }
                } else {
                    buf.clear();
                    provider(PopulationId(p), t, buf);
                }
                spike_words[p].fill_from_ids(buf);
            }

            let t0 = profile.then(Instant::now);
            for &p in &pops_of_wave[w] {
                if record_v[p] {
                    if let Some(state) = &pops[p] {
                        recorder.record_v_step(p, &state.v);
                    }
                }
                if record_spikes[p] && !spike_buf[p].is_empty() {
                    let rec = recorder.spikes.entry(p).or_default();
                    rec.extend(spike_buf[p].iter().map(|&n| (t, n)));
                }
            }
            if let Some(t0) = t0 {
                *record_nanos += t0.elapsed().as_nanos() as u64;
            }

            // Phase B: engines sourced in this wave accumulate the currents
            // their (strictly deeper) targets owe.
            for slot in &mut engines[lo..hi] {
                let due = slot.engine.step_currents_words(&spike_words[slot.src.0]);
                for (a, &d) in currents[slot.tgt.0].iter_mut().zip(due) {
                    *a += d;
                }
            }
        }

        self.t += 1;
    }

    /// Number of topological waves per timestep.
    pub fn n_waves(&self) -> usize {
        self.wave_bounds.len()
    }

    /// Wave-granular Phase A for the **LIF populations** of wave `w`: fire
    /// from the accumulated currents, bit-pack the spikes, record. Spike
    /// sources of this wave are left untouched — the sharded driver injects
    /// their words via [`NetworkSim::inject_words`] instead of a provider
    /// callback. Together with [`NetworkSim::run_wave_engines`] and
    /// [`NetworkSim::advance_step`], this decomposes [`NetworkSim::step`]
    /// so a coordinator can splice a cross-shard spike exchange between a
    /// wave's firing and its engines.
    pub fn fire_wave(&mut self, w: usize) {
        let NetworkSim {
            ref pops_of_wave,
            ref mut pops,
            ref mut currents,
            ref mut spike_buf,
            ref mut spike_words,
            ref record_spikes,
            ref record_v,
            ref mut recorder,
            profile,
            ref mut lif_nanos,
            ref mut record_nanos,
            t,
            ..
        } = *self;

        for &p in &pops_of_wave[w] {
            let Some(state) = &mut pops[p] else { continue };
            let buf = &mut spike_buf[p];
            let t0 = profile.then(Instant::now);
            lif_step_chunked(&state.params, &mut state.v, &currents[p], &mut state.refrac, buf);
            currents[p].fill(0.0);
            if let Some(t0) = t0 {
                *lif_nanos += t0.elapsed().as_nanos() as u64;
            }
            spike_words[p].fill_from_ids(buf);
        }

        let t0 = profile.then(Instant::now);
        for &p in &pops_of_wave[w] {
            if pops[p].is_none() {
                continue;
            }
            if record_v[p] {
                if let Some(state) = &pops[p] {
                    recorder.record_v_step(p, &state.v);
                }
            }
            if record_spikes[p] && !spike_buf[p].is_empty() {
                let rec = recorder.spikes.entry(p).or_default();
                rec.extend(spike_buf[p].iter().map(|&n| (t, n)));
            }
        }
        if let Some(t0) = t0 {
            *record_nanos += t0.elapsed().as_nanos() as u64;
        }
    }

    /// Overwrite population `p`'s packed spike words for the current step
    /// with externally produced spikes (a remote shard's firing, or
    /// coordinator-generated stimulus), recording them if `p` is recorded
    /// here. The id rebuild iterates set bits in ascending order, matching
    /// the ascending ids the LIF kernel emits — injected spikes are
    /// bit-identical to locally fired ones.
    pub fn inject_words(&mut self, p: usize, words: &SpikeWords) {
        self.spike_words[p].copy_from(words);
        let buf = &mut self.spike_buf[p];
        buf.clear();
        words.for_each(|id| buf.push(id as u32));
        if self.record_spikes[p] && !buf.is_empty() {
            let t = self.t;
            let rec = self.recorder.spikes.entry(p).or_default();
            rec.extend(buf.iter().map(|&n| (t, n)));
        }
    }

    /// Wave-granular Phase B: the engines sourced in wave `w` consume the
    /// wave's packed spikes and accumulate currents into their (strictly
    /// deeper) targets, in fixed engine order.
    pub fn run_wave_engines(&mut self, w: usize) {
        let (lo, hi) = self.wave_bounds[w];
        for slot in &mut self.engines[lo..hi] {
            let due = slot.engine.step_currents_words(&self.spike_words[slot.src.0]);
            for (a, &d) in self.currents[slot.tgt.0].iter_mut().zip(due) {
                *a += d;
            }
        }
    }

    /// Advance the clock after all waves of a timestep ran through
    /// [`NetworkSim::fire_wave`] / [`NetworkSim::run_wave_engines`].
    pub fn advance_step(&mut self) {
        self.t += 1;
    }

    /// Population `p`'s packed spike words of the current step (valid after
    /// its wave fired).
    pub fn spike_words_of(&self, p: usize) -> &SpikeWords {
        &self.spike_words[p]
    }

    /// Run `steps` timesteps single-threaded.
    pub fn run(&mut self, steps: u64, provider: &mut SpikeProvider) {
        self.reserve_recording(steps);
        for _ in 0..steps {
            self.step(provider);
        }
    }

    /// Widest wave (engines): the intra-sample parallelism available.
    pub fn max_wave_width(&self) -> usize {
        self.wave_bounds.iter().map(|&(lo, hi)| hi - lo).max().unwrap_or(0)
    }

    /// Run `steps` timesteps with intra-sample layer parallelism: engines
    /// of one topological wave step concurrently on `jobs` scoped worker
    /// threads (0 = one per CPU; ≤1 or a chain-shaped network falls back to
    /// [`NetworkSim::run`], as does the whole `pjrt` build configuration —
    /// its `Rc`-based backends are single-threaded by construction).
    ///
    /// Determinism: workers only advance engines they exclusively own and
    /// write each engine's currents into a per-engine staging buffer; the
    /// coordinator runs LIF/providers/recording sequentially and reduces
    /// staged outputs in fixed engine order. Worker scheduling therefore
    /// never reaches the results — recorders are bit-identical at any jobs
    /// count (and to a sequential run), which composes with
    /// [`super::batch::BatchRunner`]'s cross-sample fan-out.
    pub fn run_jobs(&mut self, steps: u64, provider: &mut SpikeProvider, jobs: usize) {
        let jobs = if jobs == 0 {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
        } else {
            jobs
        };
        let jobs = jobs.min(self.max_wave_width());
        if jobs <= 1 || steps == 0 {
            self.run(steps, provider);
            return;
        }
        self.run_waves_parallel(steps, provider, jobs);
    }

    /// `pjrt` builds hold non-`Send` backends, so engines cannot cross into
    /// worker threads — step sequentially instead.
    #[cfg(feature = "pjrt")]
    fn run_waves_parallel(&mut self, steps: u64, provider: &mut SpikeProvider, _jobs: usize) {
        self.run(steps, provider);
    }

    /// The barrier-synchronized fork-join body behind [`NetworkSim::run_jobs`]
    /// (`jobs ≥ 2`, some wave has ≥2 engines).
    #[cfg(not(feature = "pjrt"))]
    fn run_waves_parallel(&mut self, steps: u64, provider: &mut SpikeProvider, jobs: usize) {
        self.reserve_recording(steps);

        // Per-engine staging buffers (sized to each target population) and
        // the spike buffers re-homed into reader-writer cells for the
        // scope's duration: the coordinator writes them in Phase A, workers
        // read them in Phase B — the barrier schedule keeps the two phases
        // disjoint, the locks make that sharing safe Rust.
        let staged: Vec<Mutex<Vec<f32>>> = self
            .engines
            .iter()
            .map(|s| Mutex::new(vec![0.0f32; self.currents[s.tgt.0].len()]))
            .collect();
        let engine_tgts: Vec<usize> = self.engines.iter().map(|s| s.tgt.0).collect();
        let spike_cells: Vec<RwLock<Vec<u32>>> = self
            .spike_buf
            .iter_mut()
            .map(|b| RwLock::new(std::mem::take(b)))
            .collect();

        let NetworkSim {
            ref mut engines,
            ref wave_bounds,
            ref pops_of_wave,
            ref mut pops,
            ref mut currents,
            ref record_spikes,
            ref record_v,
            ref mut recorder,
            profile,
            ref mut lif_nanos,
            ref mut record_nanos,
            ref mut t,
            ..
        } = *self;

        // Partition every wave's engine range into `jobs` chunks; worker k
        // owns chunk k of every wave (possibly empty), so all parties run
        // the same barrier schedule: steps × waves × 2 waits each.
        let mut per_worker: Vec<Vec<(usize, &mut [EngineSlot])>> = Vec::new();
        per_worker.resize_with(jobs, Vec::new);
        {
            let mut rest: &mut [EngineSlot] = engines;
            let mut consumed = 0usize;
            for &(lo, hi) in wave_bounds {
                debug_assert_eq!(consumed, lo);
                for (k, &sz) in balanced_split(hi - lo, jobs).iter().enumerate() {
                    let tmp = std::mem::take(&mut rest);
                    let (chunk, r) = tmp.split_at_mut(sz);
                    per_worker[k].push((consumed, chunk));
                    consumed += sz;
                    rest = r;
                }
            }
        }

        // Panic containment: a panicking provider or engine must not strand
        // the other parties on a barrier they will never all reach. Every
        // work region is wrapped in `catch_unwind`; the first payload is
        // stashed, `abort` silences all later regions, every party still
        // runs its complete barrier schedule, and the panic resumes on the
        // caller thread after the scope joins (the sim's dynamic state is
        // then unspecified — `reset()` or drop it).
        let abort = AtomicBool::new(false);
        let panic_payload: Mutex<Option<Box<dyn std::any::Any + Send>>> = Mutex::new(None);
        let trap = |r: std::thread::Result<()>| {
            if let Err(payload) = r {
                abort.store(true, Ordering::SeqCst);
                panic_payload.lock().unwrap().get_or_insert(payload);
            }
        };

        let barrier = Barrier::new(jobs + 1);
        std::thread::scope(|scope| {
            for chunks in per_worker {
                let barrier = &barrier;
                let staged = &staged;
                let spike_cells = &spike_cells;
                let abort = &abort;
                let trap = &trap;
                scope.spawn(move || {
                    let mut chunks = chunks;
                    for _ in 0..steps {
                        for (base, chunk) in chunks.iter_mut() {
                            barrier.wait();
                            if !abort.load(Ordering::SeqCst) {
                                trap(catch_unwind(AssertUnwindSafe(|| {
                                    for (off, slot) in chunk.iter_mut().enumerate() {
                                        let spikes = spike_cells[slot.src.0].read().unwrap();
                                        let due = slot.engine.step_currents(&spikes);
                                        staged[*base + off].lock().unwrap().copy_from_slice(due);
                                    }
                                })));
                            }
                            barrier.wait();
                        }
                    }
                });
            }

            // Coordinator (this thread): sequential LIF + recording, then
            // the deterministic reduce of each wave's staged outputs.
            for _ in 0..steps {
                for (w, &(lo, hi)) in wave_bounds.iter().enumerate() {
                    if !abort.load(Ordering::SeqCst) {
                        trap(catch_unwind(AssertUnwindSafe(|| {
                            for &p in &pops_of_wave[w] {
                                let mut buf = spike_cells[p].write().unwrap();
                                if let Some(state) = &mut pops[p] {
                                    let t0 = profile.then(Instant::now);
                                    lif_step_chunked(
                                        &state.params,
                                        &mut state.v,
                                        &currents[p],
                                        &mut state.refrac,
                                        &mut buf,
                                    );
                                    currents[p].fill(0.0);
                                    if let Some(t0) = t0 {
                                        *lif_nanos += t0.elapsed().as_nanos() as u64;
                                    }
                                } else {
                                    buf.clear();
                                    provider(PopulationId(p), *t, &mut buf);
                                }
                            }

                            let t0 = profile.then(Instant::now);
                            for &p in &pops_of_wave[w] {
                                if record_v[p] {
                                    if let Some(state) = &pops[p] {
                                        recorder.record_v_step(p, &state.v);
                                    }
                                }
                                let buf = spike_cells[p].read().unwrap();
                                if record_spikes[p] && !buf.is_empty() {
                                    let rec = recorder.spikes.entry(p).or_default();
                                    rec.extend(buf.iter().map(|&n| (*t, n)));
                                }
                            }
                            if let Some(t0) = t0 {
                                *record_nanos += t0.elapsed().as_nanos() as u64;
                            }
                        })));
                    }

                    barrier.wait(); // release workers onto wave w's engines
                    barrier.wait(); // wave w's engine outputs are staged
                    if !abort.load(Ordering::SeqCst) {
                        for ei in lo..hi {
                            let due = staged[ei].lock().unwrap();
                            let tgt = currents[engine_tgts[ei]].iter_mut();
                            for (a, &d) in tgt.zip(due.iter()) {
                                *a += d;
                            }
                        }
                    }
                }
                *t += 1;
            }
        });

        // Re-home the spike buffers for subsequent sequential stepping. A
        // contained panic may have poisoned a cell (writer unwound mid-hold)
        // — take the data anyway; the original payload resumes below.
        for (b, cell) in self.spike_buf.iter_mut().zip(spike_cells) {
            *b = cell.into_inner().unwrap_or_else(|e| e.into_inner());
        }
        if let Some(payload) = panic_payload.lock().unwrap().take() {
            resume_unwind(payload);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hardware::PeSpec;
    use crate::model::connector::{Connector, SynapseDraw};
    use crate::model::{NetworkBuilder, SynapseType};
    use crate::prop::Prop;
    use crate::rng::Rng;
    use crate::switching::{SwitchMode, SwitchingSystem};

    fn two_layer_net(seed: u64, n_in: usize, n_hid: usize, density: f64, delay: u16) -> Network {
        let mut b = NetworkBuilder::new(seed);
        let inp = b.spike_source("in", n_in);
        let hid = b.lif_population(
            "hid",
            n_hid,
            LifParams { alpha: 0.8, v_th: 1.0, ..Default::default() },
        );
        b.project(
            inp,
            hid,
            Connector::FixedProbability(density),
            SynapseDraw { delay_range: delay, w_max: 100, ..Default::default() },
            0.02,
        );
        b.build()
    }

    /// A 3-layer feed-forward net exercising two stacked projections.
    #[allow(clippy::too_many_arguments)]
    fn three_layer_net(
        seed: u64,
        n_in: usize,
        n_hid: usize,
        n_out: usize,
        d1: f64,
        d2: f64,
        delay1: u16,
        delay2: u16,
    ) -> Network {
        let mut b = NetworkBuilder::new(seed);
        let inp = b.spike_source("in", n_in);
        let hid = b.lif_population(
            "hid",
            n_hid,
            LifParams { alpha: 0.8, v_th: 1.0, ..Default::default() },
        );
        let out = b.lif_population(
            "out",
            n_out,
            LifParams { alpha: 0.85, v_th: 1.0, ..Default::default() },
        );
        b.project(
            inp,
            hid,
            Connector::FixedProbability(d1),
            SynapseDraw { delay_range: delay1, w_max: 100, ..Default::default() },
            0.02,
        );
        b.project(
            hid,
            out,
            Connector::FixedProbability(d2),
            SynapseDraw { delay_range: delay2, w_max: 100, ..Default::default() },
            0.05,
        );
        b.build()
    }

    /// A *wide* 3-layer net: input → k parallel hidden populations → out,
    /// with LIF dynamics exercising refractory periods and bias currents.
    /// Inhibitory-dominant when `inhibitory` is set: every excitatory
    /// pathway gains a stronger inhibitory sibling projection.
    fn wide_net(seed: u64, k: usize, inhibitory: bool, t_refrac: u32, i_offset: f32) -> Network {
        let mut b = NetworkBuilder::new(seed);
        let inp = b.spike_source("in", 60);
        let params = LifParams {
            alpha: 0.85,
            v_th: 1.0,
            t_refrac,
            i_offset,
            ..Default::default()
        };
        let hidden: Vec<_> =
            (0..k).map(|i| b.lif_population(&format!("hid{i}"), 30, params)).collect();
        let out = b.lif_population("out", 10, params);
        for &h in &hidden {
            b.project(
                inp,
                h,
                Connector::FixedProbability(0.5),
                SynapseDraw { delay_range: 3, w_max: 100, ..Default::default() },
                0.03,
            );
            if inhibitory {
                b.project(
                    inp,
                    h,
                    Connector::FixedProbability(0.5),
                    SynapseDraw {
                        delay_range: 2,
                        w_max: 120,
                        syn_type: SynapseType::Inhibitory,
                        ..Default::default()
                    },
                    0.03,
                );
            }
            b.project(
                h,
                out,
                Connector::FixedProbability(0.8),
                SynapseDraw { delay_range: 2, w_max: 100, ..Default::default() },
                0.04,
            );
        }
        b.build()
    }

    fn run_with(net: &Network, mode: SwitchMode, steps: u64, stim_seed: u64) -> Vec<(u64, u32)> {
        run_recording(net, mode, steps, stim_seed).spikes_of(PopulationId(1)).to_vec()
    }

    fn provider_with(
        n_in: usize,
        rate: f64,
        stim_seed: u64,
    ) -> impl FnMut(PopulationId, u64, &mut Vec<u32>) {
        let mut rng = Rng::new(stim_seed);
        move |_pop: PopulationId, _t: u64, out: &mut Vec<u32>| {
            out.extend((0..n_in as u32).filter(|_| rng.chance(rate)));
        }
    }

    fn run_recording(net: &Network, mode: SwitchMode, steps: u64, stim_seed: u64) -> Recorder {
        run_recording_jobs(net, mode, steps, stim_seed, 1)
    }

    fn run_recording_jobs(
        net: &Network,
        mode: SwitchMode,
        steps: u64,
        stim_seed: u64,
        jobs: usize,
    ) -> Recorder {
        let mut sys = SwitchingSystem::new(mode, PeSpec::default());
        let (layers, _) = sys.compile_network(net).unwrap();
        let mut sim = NetworkSim::native(net, layers).unwrap();
        let n_in = net.populations[0].n_neurons;
        let mut provider = provider_with(n_in, 0.2, stim_seed);
        sim.run_jobs(steps, &mut provider, jobs);
        sim.recorder
    }

    #[test]
    fn network_produces_spikes() {
        let net = two_layer_net(1, 50, 30, 0.5, 4);
        let spikes = run_with(&net, SwitchMode::ForceSerial, 50, 99);
        assert!(!spikes.is_empty(), "stimulated network must fire");
    }

    #[test]
    fn serial_and_parallel_execution_identical() {
        // The headline equivalence: both paradigms yield bit-identical
        // spike trains on the same stimulus.
        let net = two_layer_net(2, 60, 40, 0.4, 5);
        let s = run_with(&net, SwitchMode::ForceSerial, 80, 7);
        let p = run_with(&net, SwitchMode::ForceParallel, 80, 7);
        assert_eq!(s, p);
        assert!(!s.is_empty());
    }

    #[test]
    fn equivalence_property_across_random_layers() {
        Prop::new("serial ≡ parallel execution", 12).check(
            |g| {
                (
                    g.i64(1, 1 << 20) as u64,
                    g.usize(10, 80),
                    g.usize(10, 60),
                    g.f64(0.1, 1.0),
                    g.usize(1, 16) as u16,
                    g.i64(1, 1 << 20) as u64,
                )
            },
            |&(seed, n_in, n_hid, density, delay, stim)| {
                let net = two_layer_net(seed, n_in, n_hid, density, delay);
                let s = run_with(&net, SwitchMode::ForceSerial, 40, stim);
                let p = run_with(&net, SwitchMode::ForceParallel, 40, stim);
                s == p
            },
        );
    }

    #[test]
    fn equivalence_property_across_three_layer_nets() {
        // The refactored engines must stay bit-identical through *stacked*
        // projections too: full recorders (both populations) compared
        // across ForceSerial / ForceParallel / Ideal mixes.
        Prop::new("serial ≡ parallel ≡ ideal, 3-layer", 8).check(
            |g| {
                (
                    g.i64(1, 1 << 20) as u64,
                    g.usize(20, 70),
                    g.usize(10, 50),
                    g.usize(5, 20),
                    g.f64(0.2, 1.0),
                    g.f64(0.3, 1.0),
                    g.usize(1, 8) as u16,
                    g.usize(1, 8) as u16,
                    g.i64(1, 1 << 20) as u64,
                )
            },
            |&(seed, n_in, n_hid, n_out, d1, d2, dl1, dl2, stim)| {
                let net = three_layer_net(seed, n_in, n_hid, n_out, d1, d2, dl1, dl2);
                let s = run_recording(&net, SwitchMode::ForceSerial, 40, stim);
                let p = run_recording(&net, SwitchMode::ForceParallel, 40, stim);
                let i = run_recording(&net, SwitchMode::Ideal, 40, stim);
                s == p && s == i
            },
        );
    }

    #[test]
    fn equivalence_property_with_refractory_offset_and_inhibition() {
        // Sparsity gating and wave parallelism must not skip state they owe:
        // refractory periods, bias currents, and inhibitory-dominant
        // pathways all produce identical recorders across paradigms *and*
        // across jobs counts.
        Prop::new("gated engines ≡ reference under rich dynamics", 6).check(
            |g| {
                (
                    g.i64(1, 1 << 20) as u64,
                    g.usize(1, 3),
                    g.bool(0.5),
                    g.usize(0, 3) as u32,
                    g.f64(0.0, 0.25) as f32,
                    g.i64(1, 1 << 20) as u64,
                )
            },
            |&(seed, k, inhibitory, t_refrac, i_offset, stim)| {
                let net = wide_net(seed, k, inhibitory, t_refrac, i_offset);
                let s = run_recording(&net, SwitchMode::ForceSerial, 40, stim);
                let p = run_recording(&net, SwitchMode::ForceParallel, 40, stim);
                let i = run_recording(&net, SwitchMode::Ideal, 40, stim);
                let s4 = run_recording_jobs(&net, SwitchMode::ForceSerial, 40, stim, 4);
                let p4 = run_recording_jobs(&net, SwitchMode::ForceParallel, 40, stim, 4);
                s == p && s == i && s == s4 && p == p4
            },
        );
    }

    #[test]
    fn wave_parallel_run_is_jobs_invariant() {
        // Wide network (parallel branches in each wave): every jobs count
        // must produce the sequential recorder bit for bit.
        let net = wide_net(91, 4, true, 2, 0.1);
        let base = run_recording_jobs(&net, SwitchMode::Ideal, 60, 17, 1);
        assert!(base.total_spikes() > 0, "stimulated wide net must fire");
        for jobs in [2, 3, 4, 8] {
            let r = run_recording_jobs(&net, SwitchMode::Ideal, 60, 17, jobs);
            assert_eq!(base, r, "jobs={jobs} must be bit-identical");
        }
    }

    #[test]
    #[should_panic(expected = "stimulus boom")]
    fn panicking_provider_propagates_instead_of_deadlocking() {
        // A panic inside the coordinator's provider must resume on the
        // caller, not strand workers on the barrier.
        let net = wide_net(12, 3, false, 0, 0.0);
        let mut sys = SwitchingSystem::new(SwitchMode::Ideal, PeSpec::default());
        let (layers, _) = sys.compile_network(&net).unwrap();
        let mut sim = NetworkSim::native(&net, layers).unwrap();
        let mut provider = |_p: PopulationId, t: u64, out: &mut Vec<u32>| {
            assert!(t < 3, "stimulus boom");
            out.extend([0u32, 1, 2]);
        };
        sim.run_jobs(10, &mut provider, 4);
    }

    #[test]
    fn run_jobs_falls_back_on_chain_networks_and_resumes_sequentially() {
        // A chain has wave width 1 → run_jobs must silently run inline and
        // leave the sim usable for further sequential stepping.
        let net = three_layer_net(21, 40, 30, 10, 0.5, 0.8, 3, 2);
        let mut sys = SwitchingSystem::new(SwitchMode::Ideal, PeSpec::default());
        let (layers, _) = sys.compile_network(&net).unwrap();
        let mut sim = NetworkSim::native(&net, layers).unwrap();
        assert_eq!(sim.max_wave_width(), 1);
        let mut provider = provider_with(40, 0.25, 5);
        sim.run_jobs(30, &mut provider, 8);
        sim.run(10, &mut provider);
        assert_eq!(sim.timestep(), 40);
    }

    #[test]
    fn wave_granular_stepping_matches_step() {
        // The sharded driver's decomposition of `step` — fire_wave, an
        // inject_words exchange for the sources, run_wave_engines,
        // advance_step — must reproduce the monolithic loop bit-for-bit.
        let net = three_layer_net(33, 40, 30, 12, 0.4, 0.7, 3, 2);
        let mut sys = SwitchingSystem::new(SwitchMode::Ideal, PeSpec::default());
        let (layers, _) = sys.compile_network(&net).unwrap();

        let mut reference = NetworkSim::native(&net, layers.clone()).unwrap();
        let mut provider = provider_with(40, 0.25, 17);
        reference.run(60, &mut provider);

        let mut sim = NetworkSim::native(&net, layers).unwrap();
        let mut provider = provider_with(40, 0.25, 17);
        let mut ids = Vec::new();
        let mut scratch = SpikeWords::new(40);
        for _ in 0..60 {
            for w in 0..sim.n_waves() {
                sim.fire_wave(w);
                if w == 0 {
                    ids.clear();
                    provider(PopulationId(0), sim.timestep(), &mut ids);
                    scratch.fill_from_ids(&ids);
                    sim.inject_words(0, &scratch);
                }
                sim.run_wave_engines(w);
            }
            sim.advance_step();
        }
        assert_eq!(reference.recorder, sim.recorder);
        assert!(reference.recorder.total_spikes() > 0, "fixture must spike");
    }

    #[test]
    fn three_layer_feedforward_runs() {
        let mut b = NetworkBuilder::new(3);
        let inp = b.spike_source("in", 40);
        let hid = b.lif_population("hid", 30, LifParams::default());
        let out = b.lif_population("out", 10, LifParams::default());
        b.project(
            inp,
            hid,
            Connector::FixedProbability(0.5),
            SynapseDraw { delay_range: 2, w_max: 100, ..Default::default() },
            0.03,
        );
        b.project(
            hid,
            out,
            Connector::FixedProbability(0.8),
            SynapseDraw { delay_range: 3, w_max: 100, ..Default::default() },
            0.05,
        );
        let net = b.build();
        let mut sys = SwitchingSystem::new(SwitchMode::Ideal, PeSpec::default());
        let (layers, _) = sys.compile_network(&net).unwrap();
        let mut sim = NetworkSim::native(&net, layers).unwrap();
        let mut provider = provider_with(40, 0.3, 5);
        sim.run(60, &mut provider);
        assert!(sim.recorder.spike_count(PopulationId(1)) > 0);
        assert!(sim.recorder.spike_count(PopulationId(2)) > 0, "activity must propagate");
    }

    #[test]
    fn reset_reproduces_the_same_run() {
        let net = three_layer_net(21, 50, 30, 10, 0.5, 0.8, 3, 2);
        let mut sys = SwitchingSystem::new(SwitchMode::Ideal, PeSpec::default());
        let (layers, _) = sys.compile_network(&net).unwrap();
        let mut sim = NetworkSim::native(&net, layers).unwrap();
        let run_once = |sim: &mut NetworkSim| -> Recorder {
            let mut provider = provider_with(50, 0.25, 77);
            sim.run(50, &mut provider);
            std::mem::take(&mut sim.recorder)
        };
        let first = run_once(&mut sim);
        assert!(first.total_spikes() > 0);
        sim.reset();
        assert_eq!(sim.timestep(), 0);
        let second = run_once(&mut sim);
        assert_eq!(first, second, "reset + rerun must be bit-identical");
    }

    #[test]
    fn checkpoint_restore_resumes_bit_identically() {
        // Mid-run checkpoint: snapshot sim + stimulus RNG cursor, run on,
        // then roll both back and replay — recorders must match exactly.
        let net = three_layer_net(21, 50, 30, 10, 0.5, 0.8, 3, 2);
        let mut sys = SwitchingSystem::new(SwitchMode::Ideal, PeSpec::default());
        let (layers, _) = sys.compile_network(&net).unwrap();
        let mut sim = NetworkSim::native(&net, layers).unwrap();
        let mut rng = Rng::new(404);
        let stim = |rng: &mut Rng, out: &mut Vec<u32>| {
            out.extend((0..50u32).filter(|_| rng.chance(0.25)));
        };
        sim.run(30, &mut |_p, _t, out: &mut Vec<u32>| stim(&mut rng, out));
        let ckpt = sim.checkpoint();
        let mut rng_ck = rng.clone();
        assert_eq!(ckpt.timestep(), 30);
        assert!(ckpt.byte_size() > 0);
        sim.run(20, &mut |_p, _t, out: &mut Vec<u32>| stim(&mut rng, out));
        let first = sim.recorder.clone();
        sim.restore(&ckpt).unwrap();
        assert_eq!(sim.timestep(), 30);
        sim.run(20, &mut |_p, _t, out: &mut Vec<u32>| stim(&mut rng_ck, out));
        assert_eq!(sim.recorder, first, "rollback + replay must be bit-identical");
        assert_eq!(sim.timestep(), 50);
    }

    #[test]
    fn pristine_checkpoints_cross_paradigms_mid_sample_ones_do_not() {
        // The recovery contract: a sample-boundary (pristine) snapshot can
        // restore into a re-admitted sim whose layers flipped paradigm; a
        // mid-sample snapshot cannot.
        let net = two_layer_net(2, 60, 40, 0.4, 5);
        let compile = |mode| {
            let mut sys = SwitchingSystem::new(mode, PeSpec::default());
            sys.compile_network(&net).unwrap().0
        };
        let mut serial_sim =
            NetworkSim::native(&net, compile(SwitchMode::ForceSerial)).unwrap();
        let pristine = serial_sim.checkpoint();
        let mut provider = provider_with(60, 0.2, 11);
        serial_sim.run(60, &mut provider);
        let reference = serial_sim.recorder.clone();
        let mid_run = serial_sim.checkpoint();

        let mut parallel_sim =
            NetworkSim::native(&net, compile(SwitchMode::ForceParallel)).unwrap();
        parallel_sim.restore(&pristine).unwrap();
        let mut provider = provider_with(60, 0.2, 11);
        parallel_sim.run(60, &mut provider);
        assert_eq!(
            parallel_sim.recorder, reference,
            "pristine restore + replay must reproduce the run across paradigms"
        );
        let err = parallel_sim.restore(&mid_run).unwrap_err();
        assert!(format!("{err:#}").contains("cannot restore mid-sample"), "{err:#}");
    }

    #[test]
    fn recurrent_network_is_rejected() {
        let mut b = NetworkBuilder::new(4);
        let a = b.lif_population("a", 5, LifParams::default());
        let c = b.lif_population("b", 5, LifParams::default());
        b.project(a, c, Connector::OneToOne, SynapseDraw::default(), 1.0);
        b.project(c, a, Connector::OneToOne, SynapseDraw::default(), 1.0);
        let net = b.build();
        let mut sys = SwitchingSystem::new(SwitchMode::ForceSerial, PeSpec::default());
        let (layers, _) = sys.compile_network(&net).unwrap();
        assert!(NetworkSim::native(&net, layers).is_err());
    }

    #[test]
    fn refractory_limits_rate() {
        let mut b = NetworkBuilder::new(6);
        let inp = b.spike_source("in", 10);
        let hid = b.lif_population(
            "hid",
            5,
            LifParams { t_refrac: 3, alpha: 1.0, ..Default::default() },
        );
        b.project(
            inp,
            hid,
            Connector::AllToAll,
            SynapseDraw { delay_range: 1, w_max: 127, ..Default::default() },
            1.0,
        );
        let net = b.build();
        let mut sys = SwitchingSystem::new(SwitchMode::ForceSerial, PeSpec::default());
        let (layers, _) = sys.compile_network(&net).unwrap();
        let mut sim = NetworkSim::native(&net, layers).unwrap();
        // Constant max stimulation.
        let mut provider =
            |_p: PopulationId, _t: u64, out: &mut Vec<u32>| out.extend(0..10u32);
        sim.run(40, &mut provider);
        let per_neuron = sim.recorder.spike_count(PopulationId(1)) as f64 / 5.0;
        // refrac 3 → at most one spike per 4 steps (≈10 in 40 steps).
        assert!(per_neuron <= 10.5, "refractory cap violated: {per_neuron}");
        assert!(per_neuron > 5.0, "should still fire regularly");
    }

    #[test]
    fn telemetry_accumulates() {
        let net = two_layer_net(8, 40, 30, 0.6, 3);
        let mut sys = SwitchingSystem::new(SwitchMode::ForceSerial, PeSpec::default());
        let (layers, _) = sys.compile_network(&net).unwrap();
        let mut sim = NetworkSim::native(&net, layers).unwrap();
        let mut provider = provider_with(40, 0.3, 3);
        sim.run(30, &mut provider);
        assert!(sim.total_events() > 0, "serial layer must process events");
        assert_eq!(sim.total_macs(), 0, "no parallel layers here");
    }

    #[test]
    fn layer_activity_reports_observed_rates_in_projection_order() {
        let net = three_layer_net(33, 50, 30, 10, 0.5, 0.8, 3, 2);
        let mut sys = SwitchingSystem::new(SwitchMode::Ideal, PeSpec::default());
        let (layers, _) = sys.compile_network(&net).unwrap();
        let mut sim = NetworkSim::native(&net, layers).unwrap();
        let rate = 0.25;
        let mut provider = provider_with(50, rate, 123);
        sim.run(80, &mut provider);
        let acts = sim.layer_activity();
        assert_eq!(acts.len(), 2);
        assert_eq!(acts[0].proj, 0);
        assert_eq!(acts[1].proj, 1);
        assert_eq!(acts[0].source, PopulationId(0));
        assert_eq!(acts[1].source, PopulationId(1));
        assert_eq!(acts[0].steps, 80);
        // Layer 0 sees the Bernoulli(rate) stimulus — the observed rate must
        // sit near it; layer 1 sees the (lower) hidden-layer rate.
        let r0 = acts[0].firing_rate();
        assert!((r0 - rate).abs() < 0.05, "observed input rate {r0} vs stimulus {rate}");
        assert!(acts[1].firing_rate() >= 0.0);
        assert!(acts[0].spikes_in > 0);
    }

    #[test]
    fn voltage_recording_is_flat_and_complete() {
        let mut b = NetworkBuilder::new(10);
        let inp = b.spike_source("in", 20);
        let hid = b.lif_population("hid", 7, LifParams::default());
        b.project(
            inp,
            hid,
            Connector::FixedProbability(0.5),
            SynapseDraw { delay_range: 2, w_max: 100, ..Default::default() },
            0.02,
        );
        let mut net = b.build();
        net.populations[1].record_v = true;
        let mut sys = SwitchingSystem::new(SwitchMode::ForceSerial, PeSpec::default());
        let (layers, _) = sys.compile_network(&net).unwrap();
        let mut sim = NetworkSim::native(&net, layers).unwrap();
        let mut provider = provider_with(20, 0.3, 9);
        sim.run(25, &mut provider);
        let trace = sim.recorder.v_of(PopulationId(1)).expect("voltage recorded");
        assert_eq!(trace.n_neurons, 7);
        assert_eq!(trace.n_steps(), 25);
        assert_eq!(trace.data.len(), 25 * 7);
        assert_eq!(trace.step(24).len(), 7);
    }

    #[test]
    fn swap_layer_engine_splices_between_samples() {
        // Hot-swap both layers serial→parallel between samples: the swapped
        // sim's recorder must match a fresh fixed-parallel sim bit for bit,
        // lifetime telemetry must stay continuous, and the window must
        // start fresh.
        let net = three_layer_net(21, 50, 30, 10, 0.5, 0.8, 3, 2);
        let compile = |mode| {
            let mut sys = SwitchingSystem::new(mode, PeSpec::default());
            sys.compile_network(&net).unwrap().0
        };
        let parallel_layers = compile(SwitchMode::ForceParallel);
        let mut sim = NetworkSim::native(&net, compile(SwitchMode::ForceSerial)).unwrap();
        let mut provider = provider_with(50, 0.25, 77);
        sim.run(50, &mut provider);
        sim.reset();
        for (proj, layer) in parallel_layers.clone().into_iter().enumerate() {
            sim.swap_layer_engine(proj, layer).unwrap();
        }
        let acts = sim.layer_activity();
        assert_eq!(acts[0].paradigm, Paradigm::Parallel);
        assert_eq!(acts[0].steps, 50, "lifetime steps carry across the swap");
        assert!(acts[0].spikes_in > 0, "lifetime spikes carry across the swap");
        assert_eq!((acts[0].window_spikes, acts[0].window_steps), (0, 0));
        let mut provider = provider_with(50, 0.25, 78);
        sim.run(50, &mut provider);
        let swapped = std::mem::take(&mut sim.recorder);

        let mut fixed = NetworkSim::native(&net, parallel_layers).unwrap();
        let mut provider = provider_with(50, 0.25, 78);
        fixed.run(50, &mut provider);
        assert_eq!(swapped, fixed.recorder, "swapped ≡ fixed-paradigm run");
        assert!(swapped.total_spikes() > 0);
    }

    #[test]
    fn swap_layer_engine_refuses_mid_sample_and_foreign_shapes() {
        let net = two_layer_net(2, 60, 40, 0.4, 5);
        let compile = |n: &Network, mode| {
            let mut sys = SwitchingSystem::new(mode, PeSpec::default());
            sys.compile_network(n).unwrap().0
        };
        let parallel = compile(&net, SwitchMode::ForceParallel);
        let mut sim = NetworkSim::native(&net, compile(&net, SwitchMode::ForceSerial)).unwrap();
        let mut provider = provider_with(60, 0.2, 11);
        sim.run(30, &mut provider);
        let err = sim.swap_layer_engine(0, parallel[0].clone()).unwrap_err();
        assert!(format!("{err:#}").contains("in-flight ring state"), "{err:#}");
        sim.reset();
        assert!(sim.swap_layer_engine(7, parallel[0].clone()).is_err(), "unknown projection");
        let other = compile(&two_layer_net(3, 30, 20, 0.4, 2), SwitchMode::ForceParallel);
        let err = sim.swap_layer_engine(0, other[0].clone()).unwrap_err();
        assert!(format!("{err:#}").contains("does not match projection"), "{err:#}");
        sim.swap_layer_engine(0, parallel[0].clone()).unwrap();
        assert_eq!(sim.layer_activity()[0].paradigm, Paradigm::Parallel);
    }

    #[test]
    fn equivalence_property_at_arbitrary_swap_points() {
        // The tentpole equivalence: any per-sample paradigm sequence,
        // executed by hot-swapping one long-lived sim between samples, must
        // reproduce the recorders of per-sample fresh sims of the chosen
        // fixed paradigms — at jobs 1 and under wave-parallel stepping.
        Prop::new("hot-swapped ≡ fixed-engine-sequence", 6).check(
            |g| {
                (
                    g.i64(1, 1 << 20) as u64,
                    g.usize(20, 60),
                    g.usize(10, 40),
                    g.f64(0.2, 0.8),
                    g.usize(1, 6) as u16,
                    g.i64(1, 1 << 20) as u64,
                    g.i64(0, 1 << 16) as u64,
                )
            },
            |&(seed, n_in, n_hid, density, delay, stim, flips)| {
                let net = two_layer_net(seed, n_in, n_hid, density, delay);
                let compile = |mode| {
                    let mut sys = SwitchingSystem::new(mode, PeSpec::default());
                    sys.compile_network(&net).unwrap().0
                };
                let serial = compile(SwitchMode::ForceSerial);
                let parallel = compile(SwitchMode::ForceParallel);
                let layer_of = |p: Paradigm| match p {
                    Paradigm::Serial => serial[0].clone(),
                    Paradigm::Parallel => parallel[0].clone(),
                };
                // 6 samples, paradigm per sample from the `flips` bits.
                let seq: Vec<Paradigm> = (0..6)
                    .map(|s| {
                        if (flips >> s) & 1 == 1 {
                            Paradigm::Parallel
                        } else {
                            Paradigm::Serial
                        }
                    })
                    .collect();
                let mut sim = NetworkSim::native(&net, vec![layer_of(seq[0])]).unwrap();
                let mut ok = true;
                for (s, &p) in seq.iter().enumerate() {
                    sim.reset();
                    if sim.layer_activity()[0].paradigm != p {
                        sim.swap_layer_engine(0, layer_of(p)).unwrap();
                    }
                    let mut provider = provider_with(n_in, 0.25, stim + s as u64);
                    sim.run_jobs(20, &mut provider, 1 + (s % 3));
                    let mut fixed = NetworkSim::native(&net, vec![layer_of(p)]).unwrap();
                    let mut provider = provider_with(n_in, 0.25, stim + s as u64);
                    fixed.run(20, &mut provider);
                    ok &= sim.recorder == fixed.recorder;
                }
                ok
            },
        );
    }

    #[test]
    fn profiled_run_attributes_time_to_phases() {
        let net = two_layer_net(12, 60, 40, 0.5, 4);
        let mut sys = SwitchingSystem::new(SwitchMode::Ideal, PeSpec::default());
        let (layers, _) = sys.compile_network(&net).unwrap();
        let mut sim = NetworkSim::native(&net, layers).unwrap();
        assert_eq!(sim.phase_profile(), PhaseProfile::default(), "off by default");
        sim.set_profile(true);
        let mut provider = provider_with(60, 0.3, 2);
        sim.run(40, &mut provider);
        let prof = sim.phase_profile();
        assert!(prof.lif_nanos > 0, "LIF time must be attributed");
        assert!(prof.total_nanos() > 0);
    }
}
