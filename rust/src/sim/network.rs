//! Whole-network simulation: population LIF state, layer engines, spike
//! routing, recording.
//!
//! Populations are updated in topological order each timestep; a projection
//! engine consumes its source population's spikes from the *current* step
//! (feed-forward networks only — recurrent edges would need a one-step
//! delay relaxation, which the paper's per-layer evaluation never exercises).

use super::backend::{MacBackend, NativeMac};
use super::parallel_engine::ParallelLayerEngine;
use super::serial_engine::SerialLayerEngine;
use crate::model::lif::lif_step_batch;
use crate::model::{LifParams, Network, PopulationId};
use crate::switching::CompiledLayer;
use anyhow::{ensure, Result};
use std::collections::BTreeMap;

/// Supplies source-population spikes per timestep.
pub type SpikeProvider<'a> = dyn FnMut(PopulationId, u64) -> Vec<u32> + 'a;

/// Per-population LIF state.
struct PopState {
    params: LifParams,
    v: Vec<f32>,
    refrac: Vec<u32>,
}

/// One projection's execution engine.
enum LayerEngine {
    Serial(SerialLayerEngine),
    Parallel(ParallelLayerEngine),
}

impl LayerEngine {
    fn step_currents(&mut self, spikes_in: &[u32]) -> Vec<f32> {
        match self {
            LayerEngine::Serial(e) => e.step_currents(spikes_in),
            LayerEngine::Parallel(e) => e.step_currents(spikes_in),
        }
    }
}

/// Recorded spikes (and optional voltages) per population.
#[derive(Clone, Debug, Default)]
pub struct Recorder {
    /// `spikes[pop] = [(t, neuron)]`.
    pub spikes: BTreeMap<usize, Vec<(u64, u32)>>,
    /// `v[pop] = [per-step snapshot]` for populations with `record_v`.
    pub v: BTreeMap<usize, Vec<Vec<f32>>>,
}

impl Recorder {
    pub fn spikes_of(&self, pop: PopulationId) -> &[(u64, u32)] {
        self.spikes.get(&pop.0).map(Vec::as_slice).unwrap_or(&[])
    }

    /// Export all recorded spikes as CSV (`population,timestep,neuron`).
    pub fn save_spikes_csv(&self, path: &std::path::Path) -> crate::Result<()> {
        crate::io::csv::write_csv(
            path,
            &["population", "timestep", "neuron"],
            self.spikes.iter().flat_map(|(&pop, spikes)| {
                spikes.iter().map(move |&(t, n)| {
                    vec![pop.to_string(), t.to_string(), n.to_string()]
                })
            }),
        )?;
        Ok(())
    }

    pub fn spike_count(&self, pop: PopulationId) -> usize {
        self.spikes_of(pop).len()
    }

    pub fn total_spikes(&self) -> usize {
        self.spikes.values().map(Vec::len).sum()
    }
}

/// The network simulator.
pub struct NetworkSim {
    topo: Vec<PopulationId>,
    /// Engine + source population per projection, in projection order.
    engines: Vec<(PopulationId, PopulationId, LayerEngine)>,
    pops: Vec<Option<PopState>>,
    record_spikes: Vec<bool>,
    record_v: Vec<bool>,
    pub recorder: Recorder,
    t: u64,
}

impl NetworkSim {
    /// Build a simulator from a network and its compiled layers (one per
    /// projection, same order). `backend_factory` supplies a MAC backend per
    /// parallel layer (native by default; PJRT in the e2e example).
    pub fn new(
        net: &Network,
        layers: Vec<CompiledLayer>,
        mut backend_factory: impl FnMut() -> Box<dyn MacBackend>,
    ) -> Result<Self> {
        ensure!(
            layers.len() == net.projections.len(),
            "need one compiled layer per projection"
        );
        // Feed-forward check: topological position of source < target.
        let topo = net.topo_order();
        let pos: BTreeMap<usize, usize> =
            topo.iter().enumerate().map(|(i, p)| (p.0, i)).collect();
        for proj in &net.projections {
            ensure!(
                pos[&proj.source.0] < pos[&proj.target.0],
                "NetworkSim supports feed-forward networks only (projection {} is not)",
                proj.id.0
            );
        }

        let engines = net
            .projections
            .iter()
            .zip(layers)
            .map(|(proj, layer)| {
                let engine = match layer {
                    CompiledLayer::Serial(c) => {
                        let n_tgt = net.population(proj.target).n_neurons;
                        LayerEngine::Serial(SerialLayerEngine::new(c, n_tgt))
                    }
                    CompiledLayer::Parallel(c) => {
                        LayerEngine::Parallel(ParallelLayerEngine::new(c, backend_factory()))
                    }
                };
                (proj.source, proj.target, engine)
            })
            .collect();

        let pops = net
            .populations
            .iter()
            .map(|p| {
                p.lif_params().map(|params| PopState {
                    params: *params,
                    v: vec![params.v_init; p.n_neurons],
                    refrac: vec![0; p.n_neurons],
                })
            })
            .collect();

        Ok(NetworkSim {
            topo,
            engines,
            pops,
            record_spikes: net.populations.iter().map(|p| p.record_spikes).collect(),
            record_v: net.populations.iter().map(|p| p.record_v).collect(),
            recorder: Recorder::default(),
            t: 0,
        })
    }

    /// Default construction with the native MAC backend everywhere.
    pub fn native(net: &Network, layers: Vec<CompiledLayer>) -> Result<Self> {
        Self::new(net, layers, || Box::new(NativeMac))
    }

    pub fn timestep(&self) -> u64 {
        self.t
    }

    /// Advance one timestep. `provider` yields each spike-source
    /// population's firing neuron ids for this step.
    pub fn step(&mut self, provider: &mut SpikeProvider) -> BTreeMap<usize, Vec<u32>> {
        let mut spikes_now: BTreeMap<usize, Vec<u32>> = BTreeMap::new();
        let mut currents: BTreeMap<usize, Vec<f32>> = BTreeMap::new();

        for &pop in &self.topo.clone() {
            // 1. Every engine whose source is an *earlier* population has
            //    already seen its spikes; engines sourced at `pop` step
            //    after `pop`'s own spikes exist. So: first compute this
            //    population's spikes, then run its outgoing engines.
            let spikes = if let Some(state) = &mut self.pops[pop.0] {
                let n = state.v.len();
                let zero = vec![0.0f32; n];
                let input = currents.get(&pop.0).unwrap_or(&zero);
                let mut spikes = Vec::new();
                lif_step_batch(&state.params, &mut state.v, input, &mut state.refrac, &mut spikes);
                if self.record_v[pop.0] {
                    self.recorder.v.entry(pop.0).or_default().push(state.v.clone());
                }
                spikes
            } else {
                provider(pop, self.t)
            };
            if self.record_spikes[pop.0] && !spikes.is_empty() {
                let rec = self.recorder.spikes.entry(pop.0).or_default();
                rec.extend(spikes.iter().map(|&n| (self.t, n)));
            }

            // 2. Feed outgoing engines with this step's spikes, gathering
            //    the currents their targets owe *this* step.
            for (src, tgt, engine) in &mut self.engines {
                if *src != pop {
                    continue;
                }
                let due = engine.step_currents(&spikes);
                let acc = currents.entry(tgt.0).or_insert_with(|| vec![0.0; due.len()]);
                for (a, d) in acc.iter_mut().zip(due) {
                    *a += d;
                }
            }
            spikes_now.insert(pop.0, spikes);
        }

        self.t += 1;
        spikes_now
    }

    /// Run `steps` timesteps.
    pub fn run(&mut self, steps: u64, provider: &mut SpikeProvider) {
        for _ in 0..steps {
            self.step(provider);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hardware::PeSpec;
    use crate::model::connector::{Connector, SynapseDraw};
    use crate::model::NetworkBuilder;
    use crate::prop::Prop;
    use crate::rng::Rng;
    use crate::switching::{SwitchMode, SwitchingSystem};

    fn two_layer_net(seed: u64, n_in: usize, n_hid: usize, density: f64, delay: u16) -> Network {
        let mut b = NetworkBuilder::new(seed);
        let inp = b.spike_source("in", n_in);
        let hid = b.lif_population(
            "hid",
            n_hid,
            LifParams { alpha: 0.8, v_th: 1.0, ..Default::default() },
        );
        b.project(
            inp,
            hid,
            Connector::FixedProbability(density),
            SynapseDraw { delay_range: delay, w_max: 100, ..Default::default() },
            0.02,
        );
        b.build()
    }

    fn run_with(net: &Network, mode: SwitchMode, steps: u64, stim_seed: u64) -> Vec<(u64, u32)> {
        let mut sys = SwitchingSystem::new(mode, PeSpec::default());
        let (layers, _) = sys.compile_network(net).unwrap();
        let mut sim = NetworkSim::native(net, layers).unwrap();
        let n_in = net.populations[0].n_neurons;
        let mut rng = Rng::new(stim_seed);
        let mut provider = move |_pop: PopulationId, _t: u64| -> Vec<u32> {
            (0..n_in as u32).filter(|_| rng.chance(0.2)).collect()
        };
        sim.run(steps, &mut provider);
        sim.recorder.spikes_of(PopulationId(1)).to_vec()
    }

    #[test]
    fn network_produces_spikes() {
        let net = two_layer_net(1, 50, 30, 0.5, 4);
        let spikes = run_with(&net, SwitchMode::ForceSerial, 50, 99);
        assert!(!spikes.is_empty(), "stimulated network must fire");
    }

    #[test]
    fn serial_and_parallel_execution_identical() {
        // The headline equivalence: both paradigms yield bit-identical
        // spike trains on the same stimulus.
        let net = two_layer_net(2, 60, 40, 0.4, 5);
        let s = run_with(&net, SwitchMode::ForceSerial, 80, 7);
        let p = run_with(&net, SwitchMode::ForceParallel, 80, 7);
        assert_eq!(s, p);
        assert!(!s.is_empty());
    }

    #[test]
    fn equivalence_property_across_random_layers() {
        Prop::new("serial ≡ parallel execution", 12).check(
            |g| {
                (
                    g.i64(1, 1 << 20) as u64,
                    g.usize(10, 80),
                    g.usize(10, 60),
                    g.f64(0.1, 1.0),
                    g.usize(1, 16) as u16,
                    g.i64(1, 1 << 20) as u64,
                )
            },
            |&(seed, n_in, n_hid, density, delay, stim)| {
                let net = two_layer_net(seed, n_in, n_hid, density, delay);
                let s = run_with(&net, SwitchMode::ForceSerial, 40, stim);
                let p = run_with(&net, SwitchMode::ForceParallel, 40, stim);
                s == p
            },
        );
    }

    #[test]
    fn three_layer_feedforward_runs() {
        let mut b = NetworkBuilder::new(3);
        let inp = b.spike_source("in", 40);
        let hid = b.lif_population("hid", 30, LifParams::default());
        let out = b.lif_population("out", 10, LifParams::default());
        b.project(
            inp,
            hid,
            Connector::FixedProbability(0.5),
            SynapseDraw { delay_range: 2, w_max: 100, ..Default::default() },
            0.03,
        );
        b.project(
            hid,
            out,
            Connector::FixedProbability(0.8),
            SynapseDraw { delay_range: 3, w_max: 100, ..Default::default() },
            0.05,
        );
        let net = b.build();
        let mut sys = SwitchingSystem::new(SwitchMode::Ideal, PeSpec::default());
        let (layers, _) = sys.compile_network(&net).unwrap();
        let mut sim = NetworkSim::native(&net, layers).unwrap();
        let mut rng = Rng::new(5);
        let mut provider =
            move |_p: PopulationId, _t: u64| (0..40u32).filter(|_| rng.chance(0.3)).collect();
        sim.run(60, &mut provider);
        assert!(sim.recorder.spike_count(PopulationId(1)) > 0);
        assert!(sim.recorder.spike_count(PopulationId(2)) > 0, "activity must propagate");
    }

    #[test]
    fn recurrent_network_is_rejected() {
        let mut b = NetworkBuilder::new(4);
        let a = b.lif_population("a", 5, LifParams::default());
        let c = b.lif_population("b", 5, LifParams::default());
        b.project(a, c, Connector::OneToOne, SynapseDraw::default(), 1.0);
        b.project(c, a, Connector::OneToOne, SynapseDraw::default(), 1.0);
        let net = b.build();
        let mut sys = SwitchingSystem::new(SwitchMode::ForceSerial, PeSpec::default());
        let (layers, _) = sys.compile_network(&net).unwrap();
        assert!(NetworkSim::native(&net, layers).is_err());
    }

    #[test]
    fn refractory_limits_rate() {
        let mut b = NetworkBuilder::new(6);
        let inp = b.spike_source("in", 10);
        let hid = b.lif_population(
            "hid",
            5,
            LifParams { t_refrac: 3, alpha: 1.0, ..Default::default() },
        );
        b.project(inp, hid, Connector::AllToAll, SynapseDraw { delay_range: 1, w_max: 127, ..Default::default() }, 1.0);
        let net = b.build();
        let mut sys = SwitchingSystem::new(SwitchMode::ForceSerial, PeSpec::default());
        let (layers, _) = sys.compile_network(&net).unwrap();
        let mut sim = NetworkSim::native(&net, layers).unwrap();
        // Constant max stimulation.
        let mut provider = move |_p: PopulationId, _t: u64| (0..10u32).collect::<Vec<_>>();
        sim.run(40, &mut provider);
        let per_neuron = sim.recorder.spike_count(PopulationId(1)) as f64 / 5.0;
        // refrac 3 → at most one spike per 4 steps (≈10 in 40 steps).
        assert!(per_neuron <= 10.5, "refractory cap violated: {per_neuron}");
        assert!(per_neuron > 5.0, "should still fire regularly");
    }
}
