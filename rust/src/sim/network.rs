//! Whole-network simulation: population LIF state, layer engines, spike
//! routing, recording.
//!
//! Populations are updated in topological order each timestep; a projection
//! engine consumes its source population's spikes from the *current* step
//! (feed-forward networks only — recurrent edges would need a one-step
//! delay relaxation, which the paper's per-layer evaluation never exercises).
//!
//! The stepping loop is allocation-free in steady state: engine indices are
//! grouped by source population at construction (CSR-style, no per-step
//! scan over all engines), input currents accumulate into fixed
//! per-population buffers (zeroed after consumption, never reallocated),
//! and per-population spike scratch is reused across steps. [`NetworkSim::reset`]
//! rewinds everything to t=0 so one compiled simulator can serve many
//! stimulus samples — the primitive [`super::batch::BatchRunner`] builds on.

use super::backend::{MacBackend, NativeMac};
use super::parallel_engine::ParallelLayerEngine;
use super::serial_engine::SerialLayerEngine;
use crate::model::lif::lif_step_batch;
use crate::model::{LifParams, Network, PopulationId};
use crate::switching::CompiledLayer;
use anyhow::{ensure, Result};
use std::collections::BTreeMap;

/// Supplies source-population spikes per timestep.
pub type SpikeProvider<'a> = dyn FnMut(PopulationId, u64) -> Vec<u32> + 'a;

/// Per-population LIF state.
struct PopState {
    params: LifParams,
    v: Vec<f32>,
    refrac: Vec<u32>,
}

/// One projection's execution engine.
enum LayerEngine {
    Serial(SerialLayerEngine),
    Parallel(ParallelLayerEngine),
}

impl LayerEngine {
    fn step_currents(&mut self, spikes_in: &[u32]) -> &[f32] {
        match self {
            LayerEngine::Serial(e) => e.step_currents(spikes_in),
            LayerEngine::Parallel(e) => e.step_currents(spikes_in),
        }
    }

    fn reset(&mut self) {
        match self {
            LayerEngine::Serial(e) => e.reset(),
            LayerEngine::Parallel(e) => e.reset(),
        }
    }
}

/// Recorded spikes (and optional voltages) per population.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Recorder {
    /// `spikes[pop] = [(t, neuron)]`.
    pub spikes: BTreeMap<usize, Vec<(u64, u32)>>,
    /// `v[pop] = [per-step snapshot]` for populations with `record_v`.
    pub v: BTreeMap<usize, Vec<Vec<f32>>>,
}

impl Recorder {
    pub fn spikes_of(&self, pop: PopulationId) -> &[(u64, u32)] {
        self.spikes.get(&pop.0).map(Vec::as_slice).unwrap_or(&[])
    }

    /// Export all recorded spikes as CSV (`population,timestep,neuron`).
    pub fn save_spikes_csv(&self, path: &std::path::Path) -> crate::Result<()> {
        crate::io::csv::write_csv(
            path,
            &["population", "timestep", "neuron"],
            self.spikes.iter().flat_map(|(&pop, spikes)| {
                spikes.iter().map(move |&(t, n)| {
                    vec![pop.to_string(), t.to_string(), n.to_string()]
                })
            }),
        )?;
        Ok(())
    }

    pub fn spike_count(&self, pop: PopulationId) -> usize {
        self.spikes_of(pop).len()
    }

    pub fn total_spikes(&self) -> usize {
        self.spikes.values().map(Vec::len).sum()
    }
}

/// The network simulator.
pub struct NetworkSim {
    topo: Vec<PopulationId>,
    /// Engine + source/target population per projection, projection order.
    engines: Vec<(PopulationId, PopulationId, LayerEngine)>,
    /// Engine indices grouped by source population id (CSR-style index
    /// computed once; the step loop never scans engines it won't run).
    engines_of_src: Vec<Vec<usize>>,
    pops: Vec<Option<PopState>>,
    /// Fixed per-population input-current accumulators (zeroed after
    /// consumption each step, never reallocated).
    currents: Vec<Vec<f32>>,
    /// Per-population spike scratch for the current step.
    spike_buf: Vec<Vec<u32>>,
    record_spikes: Vec<bool>,
    record_v: Vec<bool>,
    pub recorder: Recorder,
    t: u64,
}

impl NetworkSim {
    /// Build a simulator from a network and its compiled layers (one per
    /// projection, same order). `backend_factory` supplies a MAC backend per
    /// parallel layer (native by default; PJRT in the e2e example).
    pub fn new(
        net: &Network,
        layers: Vec<CompiledLayer>,
        mut backend_factory: impl FnMut() -> Box<dyn MacBackend>,
    ) -> Result<Self> {
        Self::validate(net, layers.len())?;
        let topo = net.topo_order();

        let engines: Vec<(PopulationId, PopulationId, LayerEngine)> = net
            .projections
            .iter()
            .zip(layers)
            .map(|(proj, layer)| {
                let engine = match layer {
                    CompiledLayer::Serial(c) => {
                        let n_tgt = net.population(proj.target).n_neurons;
                        LayerEngine::Serial(SerialLayerEngine::new(c, n_tgt))
                    }
                    CompiledLayer::Parallel(c) => {
                        LayerEngine::Parallel(ParallelLayerEngine::new(c, backend_factory()))
                    }
                };
                (proj.source, proj.target, engine)
            })
            .collect();

        let mut engines_of_src = vec![Vec::new(); net.populations.len()];
        for (i, (src, _, _)) in engines.iter().enumerate() {
            engines_of_src[src.0].push(i);
        }

        let pops: Vec<Option<PopState>> = net
            .populations
            .iter()
            .map(|p| {
                p.lif_params().map(|params| PopState {
                    params: *params,
                    v: vec![params.v_init; p.n_neurons],
                    refrac: vec![0; p.n_neurons],
                })
            })
            .collect();

        Ok(NetworkSim {
            topo,
            engines,
            engines_of_src,
            pops,
            currents: net.populations.iter().map(|p| vec![0.0; p.n_neurons]).collect(),
            spike_buf: vec![Vec::new(); net.populations.len()],
            record_spikes: net.populations.iter().map(|p| p.record_spikes).collect(),
            record_v: net.populations.iter().map(|p| p.record_v).collect(),
            recorder: Recorder::default(),
            t: 0,
        })
    }

    /// The structural invariants simulation relies on, checked without
    /// materializing any engine state (shared with [`super::batch::BatchRunner`],
    /// whose workers then build sims infallibly): one compiled layer per
    /// projection, feed-forward topology, and every projection target is a
    /// LIF population (a projection into a spike source would accumulate
    /// currents nothing ever consumes).
    pub(crate) fn validate(net: &Network, n_layers: usize) -> Result<()> {
        ensure!(
            n_layers == net.projections.len(),
            "need one compiled layer per projection"
        );
        // Feed-forward check: topological position of source < target.
        let topo = net.topo_order();
        let pos: BTreeMap<usize, usize> =
            topo.iter().enumerate().map(|(i, p)| (p.0, i)).collect();
        for proj in &net.projections {
            ensure!(
                pos[&proj.source.0] < pos[&proj.target.0],
                "NetworkSim supports feed-forward networks only (projection {} is not)",
                proj.id.0
            );
            ensure!(
                net.population(proj.target).lif_params().is_some(),
                "projection {} targets spike source '{}' — targets must be LIF populations",
                proj.id.0,
                net.population(proj.target).label
            );
        }
        Ok(())
    }

    /// Default construction with the native MAC backend everywhere.
    pub fn native(net: &Network, layers: Vec<CompiledLayer>) -> Result<Self> {
        Self::new(net, layers, || Box::new(NativeMac))
    }

    pub fn timestep(&self) -> u64 {
        self.t
    }

    /// Rewind to t=0 with fresh membrane/ring state and an empty recorder,
    /// keeping every compiled structure and buffer — the cheap path to run
    /// another stimulus sample without recompiling. Engine telemetry
    /// (`events`/`macs`) keeps accumulating across resets.
    pub fn reset(&mut self) {
        for (_, _, engine) in &mut self.engines {
            engine.reset();
        }
        for state in self.pops.iter_mut().flatten() {
            state.v.fill(state.params.v_init);
            state.refrac.fill(0);
        }
        for c in &mut self.currents {
            c.fill(0.0);
        }
        for s in &mut self.spike_buf {
            s.clear();
        }
        self.recorder = Recorder::default();
        self.t = 0;
    }

    /// Synaptic events processed by the serial engines (cumulative).
    pub fn total_events(&self) -> u64 {
        self.engines
            .iter()
            .map(|(_, _, e)| match e {
                LayerEngine::Serial(s) => s.events,
                LayerEngine::Parallel(_) => 0,
            })
            .sum()
    }

    /// MAC operations actually issued by the parallel engines (cumulative).
    pub fn total_macs(&self) -> u64 {
        self.engines
            .iter()
            .map(|(_, _, e)| match e {
                LayerEngine::Serial(_) => 0,
                LayerEngine::Parallel(p) => p.macs,
            })
            .sum()
    }

    /// Advance one timestep. `provider` yields each spike-source
    /// population's firing neuron ids for this step.
    pub fn step(&mut self, provider: &mut SpikeProvider) {
        for i in 0..self.topo.len() {
            let pop = self.topo[i];
            let p = pop.0;
            // 1. Every engine whose source is an *earlier* population has
            //    already seen its spikes; engines sourced at `pop` step
            //    after `pop`'s own spikes exist. So: first compute this
            //    population's spikes, then run its outgoing engines.
            if let Some(state) = &mut self.pops[p] {
                lif_step_batch(
                    &state.params,
                    &mut state.v,
                    &self.currents[p],
                    &mut state.refrac,
                    &mut self.spike_buf[p],
                );
                self.currents[p].fill(0.0);
                if self.record_v[p] {
                    self.recorder.v.entry(p).or_default().push(state.v.clone());
                }
            } else {
                self.spike_buf[p] = provider(pop, self.t);
            }
            if self.record_spikes[p] && !self.spike_buf[p].is_empty() {
                let rec = self.recorder.spikes.entry(p).or_default();
                rec.extend(self.spike_buf[p].iter().map(|&n| (self.t, n)));
            }

            // 2. Feed outgoing engines with this step's spikes, accumulating
            //    the currents their targets owe *this* step.
            for &ei in &self.engines_of_src[p] {
                let (_, tgt, engine) = &mut self.engines[ei];
                let due = engine.step_currents(&self.spike_buf[p]);
                for (a, &d) in self.currents[tgt.0].iter_mut().zip(due) {
                    *a += d;
                }
            }
        }

        self.t += 1;
    }

    /// Run `steps` timesteps.
    pub fn run(&mut self, steps: u64, provider: &mut SpikeProvider) {
        for _ in 0..steps {
            self.step(provider);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hardware::PeSpec;
    use crate::model::connector::{Connector, SynapseDraw};
    use crate::model::NetworkBuilder;
    use crate::prop::Prop;
    use crate::rng::Rng;
    use crate::switching::{SwitchMode, SwitchingSystem};

    fn two_layer_net(seed: u64, n_in: usize, n_hid: usize, density: f64, delay: u16) -> Network {
        let mut b = NetworkBuilder::new(seed);
        let inp = b.spike_source("in", n_in);
        let hid = b.lif_population(
            "hid",
            n_hid,
            LifParams { alpha: 0.8, v_th: 1.0, ..Default::default() },
        );
        b.project(
            inp,
            hid,
            Connector::FixedProbability(density),
            SynapseDraw { delay_range: delay, w_max: 100, ..Default::default() },
            0.02,
        );
        b.build()
    }

    /// A 3-layer feed-forward net exercising two stacked projections.
    fn three_layer_net(
        seed: u64,
        n_in: usize,
        n_hid: usize,
        n_out: usize,
        d1: f64,
        d2: f64,
        delay1: u16,
        delay2: u16,
    ) -> Network {
        let mut b = NetworkBuilder::new(seed);
        let inp = b.spike_source("in", n_in);
        let hid = b.lif_population(
            "hid",
            n_hid,
            LifParams { alpha: 0.8, v_th: 1.0, ..Default::default() },
        );
        let out = b.lif_population(
            "out",
            n_out,
            LifParams { alpha: 0.85, v_th: 1.0, ..Default::default() },
        );
        b.project(
            inp,
            hid,
            Connector::FixedProbability(d1),
            SynapseDraw { delay_range: delay1, w_max: 100, ..Default::default() },
            0.02,
        );
        b.project(
            hid,
            out,
            Connector::FixedProbability(d2),
            SynapseDraw { delay_range: delay2, w_max: 100, ..Default::default() },
            0.05,
        );
        b.build()
    }

    fn run_with(net: &Network, mode: SwitchMode, steps: u64, stim_seed: u64) -> Vec<(u64, u32)> {
        run_recording(net, mode, steps, stim_seed).spikes_of(PopulationId(1)).to_vec()
    }

    fn run_recording(net: &Network, mode: SwitchMode, steps: u64, stim_seed: u64) -> Recorder {
        let mut sys = SwitchingSystem::new(mode, PeSpec::default());
        let (layers, _) = sys.compile_network(net).unwrap();
        let mut sim = NetworkSim::native(net, layers).unwrap();
        let n_in = net.populations[0].n_neurons;
        let mut rng = Rng::new(stim_seed);
        let mut provider = move |_pop: PopulationId, _t: u64| -> Vec<u32> {
            (0..n_in as u32).filter(|_| rng.chance(0.2)).collect()
        };
        sim.run(steps, &mut provider);
        sim.recorder
    }

    #[test]
    fn network_produces_spikes() {
        let net = two_layer_net(1, 50, 30, 0.5, 4);
        let spikes = run_with(&net, SwitchMode::ForceSerial, 50, 99);
        assert!(!spikes.is_empty(), "stimulated network must fire");
    }

    #[test]
    fn serial_and_parallel_execution_identical() {
        // The headline equivalence: both paradigms yield bit-identical
        // spike trains on the same stimulus.
        let net = two_layer_net(2, 60, 40, 0.4, 5);
        let s = run_with(&net, SwitchMode::ForceSerial, 80, 7);
        let p = run_with(&net, SwitchMode::ForceParallel, 80, 7);
        assert_eq!(s, p);
        assert!(!s.is_empty());
    }

    #[test]
    fn equivalence_property_across_random_layers() {
        Prop::new("serial ≡ parallel execution", 12).check(
            |g| {
                (
                    g.i64(1, 1 << 20) as u64,
                    g.usize(10, 80),
                    g.usize(10, 60),
                    g.f64(0.1, 1.0),
                    g.usize(1, 16) as u16,
                    g.i64(1, 1 << 20) as u64,
                )
            },
            |&(seed, n_in, n_hid, density, delay, stim)| {
                let net = two_layer_net(seed, n_in, n_hid, density, delay);
                let s = run_with(&net, SwitchMode::ForceSerial, 40, stim);
                let p = run_with(&net, SwitchMode::ForceParallel, 40, stim);
                s == p
            },
        );
    }

    #[test]
    fn equivalence_property_across_three_layer_nets() {
        // The refactored engines must stay bit-identical through *stacked*
        // projections too: full recorders (both populations) compared
        // across ForceSerial / ForceParallel / Ideal mixes.
        Prop::new("serial ≡ parallel ≡ ideal, 3-layer", 8).check(
            |g| {
                (
                    g.i64(1, 1 << 20) as u64,
                    g.usize(20, 70),
                    g.usize(10, 50),
                    g.usize(5, 20),
                    g.f64(0.2, 1.0),
                    g.f64(0.3, 1.0),
                    g.usize(1, 8) as u16,
                    g.usize(1, 8) as u16,
                    g.i64(1, 1 << 20) as u64,
                )
            },
            |&(seed, n_in, n_hid, n_out, d1, d2, dl1, dl2, stim)| {
                let net = three_layer_net(seed, n_in, n_hid, n_out, d1, d2, dl1, dl2);
                let s = run_recording(&net, SwitchMode::ForceSerial, 40, stim);
                let p = run_recording(&net, SwitchMode::ForceParallel, 40, stim);
                let i = run_recording(&net, SwitchMode::Ideal, 40, stim);
                s == p && s == i
            },
        );
    }

    #[test]
    fn three_layer_feedforward_runs() {
        let mut b = NetworkBuilder::new(3);
        let inp = b.spike_source("in", 40);
        let hid = b.lif_population("hid", 30, LifParams::default());
        let out = b.lif_population("out", 10, LifParams::default());
        b.project(
            inp,
            hid,
            Connector::FixedProbability(0.5),
            SynapseDraw { delay_range: 2, w_max: 100, ..Default::default() },
            0.03,
        );
        b.project(
            hid,
            out,
            Connector::FixedProbability(0.8),
            SynapseDraw { delay_range: 3, w_max: 100, ..Default::default() },
            0.05,
        );
        let net = b.build();
        let mut sys = SwitchingSystem::new(SwitchMode::Ideal, PeSpec::default());
        let (layers, _) = sys.compile_network(&net).unwrap();
        let mut sim = NetworkSim::native(&net, layers).unwrap();
        let mut rng = Rng::new(5);
        let mut provider =
            move |_p: PopulationId, _t: u64| (0..40u32).filter(|_| rng.chance(0.3)).collect();
        sim.run(60, &mut provider);
        assert!(sim.recorder.spike_count(PopulationId(1)) > 0);
        assert!(sim.recorder.spike_count(PopulationId(2)) > 0, "activity must propagate");
    }

    #[test]
    fn reset_reproduces_the_same_run() {
        let net = three_layer_net(21, 50, 30, 10, 0.5, 0.8, 3, 2);
        let mut sys = SwitchingSystem::new(SwitchMode::Ideal, PeSpec::default());
        let (layers, _) = sys.compile_network(&net).unwrap();
        let mut sim = NetworkSim::native(&net, layers).unwrap();
        let run_once = |sim: &mut NetworkSim| -> Recorder {
            let mut rng = Rng::new(77);
            let mut provider = move |_p: PopulationId, _t: u64| -> Vec<u32> {
                (0..50u32).filter(|_| rng.chance(0.25)).collect()
            };
            sim.run(50, &mut provider);
            std::mem::take(&mut sim.recorder)
        };
        let first = run_once(&mut sim);
        assert!(first.total_spikes() > 0);
        sim.reset();
        assert_eq!(sim.timestep(), 0);
        let second = run_once(&mut sim);
        assert_eq!(first, second, "reset + rerun must be bit-identical");
    }

    #[test]
    fn recurrent_network_is_rejected() {
        let mut b = NetworkBuilder::new(4);
        let a = b.lif_population("a", 5, LifParams::default());
        let c = b.lif_population("b", 5, LifParams::default());
        b.project(a, c, Connector::OneToOne, SynapseDraw::default(), 1.0);
        b.project(c, a, Connector::OneToOne, SynapseDraw::default(), 1.0);
        let net = b.build();
        let mut sys = SwitchingSystem::new(SwitchMode::ForceSerial, PeSpec::default());
        let (layers, _) = sys.compile_network(&net).unwrap();
        assert!(NetworkSim::native(&net, layers).is_err());
    }

    #[test]
    fn refractory_limits_rate() {
        let mut b = NetworkBuilder::new(6);
        let inp = b.spike_source("in", 10);
        let hid = b.lif_population(
            "hid",
            5,
            LifParams { t_refrac: 3, alpha: 1.0, ..Default::default() },
        );
        b.project(inp, hid, Connector::AllToAll, SynapseDraw { delay_range: 1, w_max: 127, ..Default::default() }, 1.0);
        let net = b.build();
        let mut sys = SwitchingSystem::new(SwitchMode::ForceSerial, PeSpec::default());
        let (layers, _) = sys.compile_network(&net).unwrap();
        let mut sim = NetworkSim::native(&net, layers).unwrap();
        // Constant max stimulation.
        let mut provider = move |_p: PopulationId, _t: u64| (0..10u32).collect::<Vec<_>>();
        sim.run(40, &mut provider);
        let per_neuron = sim.recorder.spike_count(PopulationId(1)) as f64 / 5.0;
        // refrac 3 → at most one spike per 4 steps (≈10 in 40 steps).
        assert!(per_neuron <= 10.5, "refractory cap violated: {per_neuron}");
        assert!(per_neuron > 5.0, "should still fire regularly");
    }

    #[test]
    fn telemetry_accumulates() {
        let net = two_layer_net(8, 40, 30, 0.6, 3);
        let mut sys = SwitchingSystem::new(SwitchMode::ForceSerial, PeSpec::default());
        let (layers, _) = sys.compile_network(&net).unwrap();
        let mut sim = NetworkSim::native(&net, layers).unwrap();
        let mut rng = Rng::new(3);
        let mut provider = move |_p: PopulationId, _t: u64| -> Vec<u32> {
            (0..40u32).filter(|_| rng.chance(0.3)).collect()
        };
        sim.run(30, &mut provider);
        assert!(sim.total_events() > 0, "serial layer must process events");
        assert_eq!(sim.total_macs(), 0, "no parallel layers here");
    }
}
