//! Multi-sample batched inference (DESIGN.md §Runtime-Perf).
//!
//! SNN serving workloads present many independent stimulus samples against
//! one compiled network (the SpiNNaker2 system paper's batch-style
//! many-sample evaluation). [`SimPool`] owns a set of engines built **once**
//! from the shared compiled layers and work-steals items over them — the
//! same idiom as [`crate::switching::pipeline::fan_out`] — with a
//! [`NetworkSim::reset`] before every item, so per-sample cost is pure
//! simulation, not reconstruction. [`BatchRunner`] is the one-shot batch
//! front-end over a fresh pool; the serve daemon holds a pool per tenant
//! for its whole lifetime (zero steady-state engine construction).
//!
//! Determinism: sample `i`'s stimulus comes from `make_provider(i)` and its
//! simulation state is fully reset beforehand, so each recorder depends only
//! on `i` — results are bit-identical at any `--jobs` count and identical to
//! S sequential [`NetworkSim`] runs (tested below).

use super::network::{NetworkSim, Recorder};
use crate::model::{Network, PopulationId};
use crate::switching::CompiledLayer;
use anyhow::Result;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::time::Instant;

/// A persistent pool of privately-owned [`NetworkSim`] engines that
/// survives across batch executions: engines are built **once** and every
/// [`SimPool::run_each`] call work-steals items over them with a
/// [`NetworkSim::reset`] before each item — the long-lived serve daemon's
/// hot path has zero steady-state engine construction, and [`BatchRunner`]
/// runs on the same pool built fresh per batch.
///
/// Determinism: item `i` is reset-isolated, so its result depends only on
/// what the caller's closure does for `i` — never on pool size, stealing
/// order, or which engine previously ran which item.
pub struct SimPool {
    sims: Vec<NetworkSim>,
}

impl SimPool {
    /// Build `jobs` engines from one compiled-layer set (0 = one per CPU).
    /// Validates the network/layers pairing up front so runs are infallible.
    pub fn new(net: &Network, layers: &[CompiledLayer], jobs: usize) -> Result<SimPool> {
        NetworkSim::validate(net, layers.len())?;
        let jobs = if jobs == 0 {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
        } else {
            jobs
        };
        let sims = (0..jobs.max(1))
            .map(|_| NetworkSim::native(net, layers.to_vec()))
            .collect::<Result<Vec<_>>>()?;
        Ok(SimPool { sims })
    }

    /// Engines in the pool (= maximum cross-item parallelism).
    pub fn jobs(&self) -> usize {
        self.sims.len()
    }

    /// Synaptic events processed across all engines since construction.
    pub fn total_events(&self) -> u64 {
        self.sims.iter().map(NetworkSim::total_events).sum()
    }

    /// MACs issued across all engines since construction.
    pub fn total_macs(&self) -> u64 {
        self.sims.iter().map(NetworkSim::total_macs).sum()
    }

    /// Run `run(sim, i)` for every `i < n_items`, work-stealing items over
    /// the pool's engines; each engine is [`NetworkSim::reset`] before each
    /// item. Results come back in item order. A panic inside `run`
    /// resurfaces on the caller via `resume_unwind` — never a hang.
    pub fn run_each<R, F>(&mut self, n_items: usize, run: F) -> Vec<R>
    where
        F: Fn(&mut NetworkSim, usize) -> R + Sync,
        R: Send,
    {
        let next = AtomicUsize::new(0);
        let worker = |sim: &mut NetworkSim| -> Vec<(usize, R)> {
            let mut local = Vec::new();
            loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n_items {
                    break;
                }
                sim.reset();
                local.push((i, run(sim, i)));
            }
            local
        };

        let mut slots: Vec<Option<R>> = (0..n_items).map(|_| None).collect();
        if self.sims.len() <= 1 || n_items <= 1 {
            for (i, r) in worker(&mut self.sims[0]) {
                slots[i] = Some(r);
            }
        } else {
            std::thread::scope(|scope| {
                let handles: Vec<_> = self
                    .sims
                    .iter_mut()
                    .map(|sim| {
                        let worker = &worker;
                        scope.spawn(move || worker(sim))
                    })
                    .collect();
                for h in handles {
                    match h.join() {
                        Ok(local) => {
                            for (i, r) in local {
                                slots[i] = Some(r);
                            }
                        }
                        Err(payload) => std::panic::resume_unwind(payload),
                    }
                }
            });
        }
        slots.into_iter().map(|s| s.expect("pool filled every item slot")).collect()
    }
}

/// One batch execution's output: per-sample recorders plus throughput
/// accounting (the quantities `BENCH_sim.json` records).
#[derive(Clone, Debug)]
pub struct BatchRun {
    /// Per-sample recorders, in sample order.
    pub recorders: Vec<Recorder>,
    /// Per-sample wall-clock, nanoseconds, in sample order.
    pub sample_nanos: Vec<u64>,
    /// Whole-batch wall-clock, nanoseconds.
    pub wall_nanos: u64,
    /// Timesteps simulated per sample.
    pub steps: u64,
    /// Synaptic events processed across all samples (serial engines).
    pub events: u64,
    /// MAC operations actually issued across all samples (parallel engines).
    pub macs: u64,
    /// Worker threads used.
    pub jobs: usize,
}

impl BatchRun {
    pub fn n_samples(&self) -> usize {
        self.recorders.len()
    }

    /// Timesteps simulated across the whole batch.
    pub fn total_steps(&self) -> u64 {
        self.steps * self.recorders.len() as u64
    }

    pub fn total_spikes(&self) -> usize {
        self.recorders.iter().map(Recorder::total_spikes).sum()
    }

    fn wall_secs(&self) -> f64 {
        (self.wall_nanos.max(1)) as f64 / 1e9
    }

    /// Aggregate timesteps per second over batch wall-clock.
    pub fn steps_per_sec(&self) -> f64 {
        self.total_steps() as f64 / self.wall_secs()
    }

    /// Aggregate synaptic events per second over batch wall-clock.
    pub fn events_per_sec(&self) -> f64 {
        self.events as f64 / self.wall_secs()
    }

    /// Aggregate issued MACs per second over batch wall-clock.
    pub fn macs_per_sec(&self) -> f64 {
        self.macs as f64 / self.wall_secs()
    }
}

/// Fans independent stimulus samples over worker threads, each driving a
/// privately-owned [`NetworkSim`] built once from shared compiled layers.
///
/// Workers run the native MAC backend (the PJRT client is single-threaded
/// by construction; route PJRT comparisons through a lone [`NetworkSim`]).
pub struct BatchRunner<'a> {
    net: &'a Network,
    layers: Vec<CompiledLayer>,
    jobs: usize,
    intra_jobs: usize,
}

impl<'a> BatchRunner<'a> {
    /// Validates the network/layers pairing up front (feed-forward shape,
    /// one layer per projection, LIF targets) so workers can build sims
    /// infallibly — structural checks only, no engine state materialized.
    pub fn new(net: &'a Network, layers: Vec<CompiledLayer>) -> Result<Self> {
        NetworkSim::validate(net, layers.len())?;
        Ok(BatchRunner { net, layers, jobs: 0, intra_jobs: 1 })
    }

    /// Builder-style worker-thread count (0 = one per CPU; 1 = inline).
    pub fn with_jobs(mut self, jobs: usize) -> Self {
        self.jobs = jobs;
        self
    }

    /// Intra-sample layer-parallel threads *per batch worker*
    /// ([`NetworkSim::run_jobs`]; default 1 = sequential stepping; 0 is
    /// clamped to 1 — auto-expansion to one-per-CPU *inside every batch
    /// worker* would oversubscribe quadratically). Results are
    /// jobs-invariant on both axes, so any `(jobs, intra_jobs)`
    /// combination yields bit-identical recorders; keep
    /// `jobs × intra_jobs ≲ CPUs`.
    pub fn with_intra_jobs(mut self, intra_jobs: usize) -> Self {
        self.intra_jobs = intra_jobs.max(1);
        self
    }

    /// Resolved worker count for `n_samples` items.
    fn effective_jobs(&self, n_samples: usize) -> usize {
        let jobs = if self.jobs == 0 {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
        } else {
            self.jobs
        };
        jobs.min(n_samples).max(1)
    }

    /// Run `n_samples` independent samples of `steps` timesteps each.
    /// `make_provider(i)` yields sample `i`'s spike provider (must be a
    /// pure function of `i` for jobs-invariant results).
    pub fn run<P, F>(&self, n_samples: usize, steps: u64, make_provider: F) -> BatchRun
    where
        F: Fn(usize) -> P + Sync,
        P: FnMut(PopulationId, u64, &mut Vec<u32>),
    {
        let jobs = self.effective_jobs(n_samples);
        let t0 = Instant::now();
        let mut pool = SimPool::new(self.net, &self.layers, jobs)
            .expect("validated in BatchRunner::new");
        let intra_jobs = self.intra_jobs;
        let results: Vec<(Recorder, u64)> = pool.run_each(n_samples, |sim, i| {
            let mut provider = make_provider(i);
            let s0 = Instant::now();
            sim.run_jobs(steps, &mut provider, intra_jobs);
            (std::mem::take(&mut sim.recorder), s0.elapsed().as_nanos() as u64)
        });

        let mut recorders = Vec::with_capacity(n_samples);
        let mut sample_nanos = Vec::with_capacity(n_samples);
        for (rec, ns) in results {
            recorders.push(rec);
            sample_nanos.push(ns);
        }
        BatchRun {
            recorders,
            sample_nanos,
            wall_nanos: t0.elapsed().as_nanos() as u64,
            steps,
            events: pool.total_events(),
            macs: pool.total_macs(),
            jobs,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hardware::PeSpec;
    use crate::model::connector::{Connector, SynapseDraw};
    use crate::model::{LifParams, NetworkBuilder};
    use crate::rng::Rng;
    use crate::switching::{SwitchMode, SwitchingSystem};

    fn demo_net() -> Network {
        let mut b = NetworkBuilder::new(44);
        let inp = b.spike_source("in", 60);
        let hid = b.lif_population("hid", 40, LifParams::default());
        let out = b.lif_population("out", 12, LifParams::default());
        b.project(
            inp,
            hid,
            Connector::FixedProbability(0.5),
            SynapseDraw { delay_range: 3, w_max: 100, ..Default::default() },
            0.02,
        );
        b.project(
            hid,
            out,
            Connector::FixedProbability(0.8),
            SynapseDraw { delay_range: 2, w_max: 100, ..Default::default() },
            0.05,
        );
        b.build()
    }

    fn compiled(net: &Network) -> Vec<CompiledLayer> {
        let mut sys = SwitchingSystem::new(SwitchMode::Ideal, PeSpec::default());
        sys.compile_network(net).unwrap().0
    }

    fn provider_for(i: usize) -> impl FnMut(crate::model::PopulationId, u64, &mut Vec<u32>) {
        let mut rng = Rng::new(1000 + i as u64);
        move |_p, _t, out: &mut Vec<u32>| {
            out.extend((0..60u32).filter(|_| rng.chance(0.25)));
        }
    }

    #[test]
    fn batch_output_is_jobs_invariant() {
        let net = demo_net();
        let layers = compiled(&net);
        let a = BatchRunner::new(&net, layers.clone())
            .unwrap()
            .with_jobs(1)
            .run(12, 40, provider_for);
        let b = BatchRunner::new(&net, layers)
            .unwrap()
            .with_jobs(8)
            .run(12, 40, provider_for);
        assert_eq!(a.recorders, b.recorders, "recorders must not depend on jobs");
        assert_eq!(a.events, b.events);
        assert_eq!(a.macs, b.macs);
        assert!(a.total_spikes() > 0, "batch must produce activity");
    }

    #[test]
    fn batch_matches_sequential_network_sim_runs() {
        let net = demo_net();
        let layers = compiled(&net);
        let batch = BatchRunner::new(&net, layers.clone())
            .unwrap()
            .with_jobs(4)
            .run(6, 50, provider_for);
        for i in 0..6 {
            let mut sim = NetworkSim::native(&net, layers.clone()).unwrap();
            let mut provider = provider_for(i);
            sim.run(50, &mut provider);
            assert_eq!(
                batch.recorders[i], sim.recorder,
                "sample {i} must equal a standalone NetworkSim run"
            );
        }
    }

    #[test]
    fn intra_sample_jobs_compose_without_changing_results() {
        // Wide net so NetworkSim::run_jobs actually engages: cross-sample
        // and intra-sample parallelism must compose bit-identically.
        let mut b = NetworkBuilder::new(77);
        let inp = b.spike_source("in", 60);
        let hids: Vec<_> =
            (0..3).map(|i| b.lif_population(&format!("h{i}"), 25, LifParams::default())).collect();
        let out = b.lif_population("out", 8, LifParams::default());
        for &h in &hids {
            b.project(
                inp,
                h,
                Connector::FixedProbability(0.5),
                SynapseDraw { delay_range: 2, w_max: 100, ..Default::default() },
                0.03,
            );
            b.project(
                h,
                out,
                Connector::FixedProbability(0.8),
                SynapseDraw { delay_range: 2, w_max: 100, ..Default::default() },
                0.04,
            );
        }
        let net = b.build();
        let layers = compiled(&net);
        let plain = BatchRunner::new(&net, layers.clone())
            .unwrap()
            .with_jobs(1)
            .run(6, 40, provider_for);
        let composed = BatchRunner::new(&net, layers)
            .unwrap()
            .with_jobs(2)
            .with_intra_jobs(3)
            .run(6, 40, provider_for);
        assert_eq!(plain.recorders, composed.recorders);
        assert!(plain.total_spikes() > 0);
    }

    #[test]
    fn empty_batch_is_well_formed() {
        let net = demo_net();
        let run = BatchRunner::new(&net, compiled(&net)).unwrap().run(0, 10, provider_for);
        assert_eq!(run.n_samples(), 0);
        assert_eq!(run.total_steps(), 0);
    }

    #[test]
    fn panicking_provider_surfaces_on_the_caller_without_deadlock() {
        let net = demo_net();
        let layers = compiled(&net);
        let runner = BatchRunner::new(&net, layers.clone()).unwrap().with_jobs(4);
        // Sample 3's provider panics; the panic must resurface on the
        // caller via resume_unwind — never a hang in the thread scope.
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            runner.run(8, 30, |i| {
                let mut inner = provider_for(i);
                move |p: crate::model::PopulationId, t: u64, out: &mut Vec<u32>| {
                    if i == 3 {
                        panic!("stimulus source {i} failed");
                    }
                    inner(p, t, out)
                }
            })
        }));
        let payload = result.expect_err("worker panic must propagate to the caller");
        let msg = payload.downcast_ref::<String>().cloned().unwrap_or_default();
        assert!(msg.contains("stimulus source 3"), "panic message lost: {msg:?}");
        // The runner stays usable and sibling state is uncorrupted: a
        // clean run afterwards still matches standalone sims bit for bit.
        let clean = runner.run(4, 30, provider_for);
        for i in 0..4 {
            let mut sim = NetworkSim::native(&net, layers.clone()).unwrap();
            let mut provider = provider_for(i);
            sim.run(30, &mut provider);
            assert_eq!(clean.recorders[i], sim.recorder, "sample {i} corrupted by the panic");
        }
    }

    #[test]
    fn sim_pool_reuse_is_reset_clean() {
        // A pool reused across run_each calls must behave exactly like
        // fresh engines: reset-isolation is the serve daemon's determinism
        // contract for persistent per-tenant pools.
        let net = demo_net();
        let layers = compiled(&net);
        let mut pool = SimPool::new(&net, &layers, 2).unwrap();
        let run = |pool: &mut SimPool| {
            pool.run_each(5, |sim, i| {
                let mut provider = provider_for(i);
                sim.run_jobs(30, &mut provider, 1);
                sim.recorder.clone()
            })
        };
        let first = run(&mut pool);
        let second = run(&mut pool);
        assert_eq!(first, second, "pool reuse leaked state between runs");
        for (i, rec) in first.iter().enumerate() {
            let mut sim = NetworkSim::native(&net, layers.clone()).unwrap();
            let mut provider = provider_for(i);
            sim.run(30, &mut provider);
            assert_eq!(rec, &sim.recorder, "pooled item {i} diverged from a standalone run");
        }
        assert!(pool.total_events() > 0 || pool.total_macs() > 0);
    }

    #[test]
    fn sim_pool_results_are_pool_size_invariant() {
        let net = demo_net();
        let layers = compiled(&net);
        let body = |sim: &mut NetworkSim, i: usize| {
            let mut provider = provider_for(i);
            sim.run_jobs(25, &mut provider, 1);
            sim.recorder.clone()
        };
        let a = SimPool::new(&net, &layers, 1).unwrap().run_each(9, body);
        let b = SimPool::new(&net, &layers, 8).unwrap().run_each(9, body);
        assert_eq!(a, b, "results must not depend on pool size");
    }

    #[test]
    fn throughput_accounting_adds_up() {
        let net = demo_net();
        let run = BatchRunner::new(&net, compiled(&net))
            .unwrap()
            .with_jobs(2)
            .run(4, 30, provider_for);
        assert_eq!(run.n_samples(), 4);
        assert_eq!(run.total_steps(), 120);
        assert_eq!(run.sample_nanos.len(), 4);
        assert!(run.steps_per_sec() > 0.0);
        assert!(run.events > 0 || run.macs > 0, "some engine must report work");
    }
}
