//! Functional SpiNNaker2 simulator.
//!
//! Executes compiled layers timestep-by-timestep with exactly the runtime
//! semantics §III describes:
//!
//! * [`serial_engine`] — event-based synaptic processing: spike → master
//!   population table → address list → synaptic-matrix block → delay ring
//!   buffer, per serial PE (spikes dispatched through a precomputed
//!   source→PE CSR index).
//! * [`parallel_engine`] — dominant-PE preprocessing (reversed order /
//!   input-merging tables → stacked input ring) + subordinate MAC-array
//!   matmuls, optionally through the AOT-compiled JAX/Pallas HLO via PJRT
//!   ([`crate::runtime`], behind the `pjrt` feature).
//! * [`network`] — whole-network simulation: population LIF state, spike
//!   routing between layers, recording. Steady state allocates nothing;
//!   [`NetworkSim::reset`] reuses one compiled sim across stimuli.
//! * [`batch`] — [`BatchRunner`]: many independent stimulus samples fanned
//!   over worker threads against shared compiled layers.
//!
//! **Numerical equivalence**: weights are integers (quantized u8 magnitudes,
//! sign = synapse type) and both engines accumulate them exactly (i32 /
//! integer-valued f32 ≤ 2²⁴), so serial and parallel execution produce
//! bit-identical spike trains — property-tested in [`network`].

pub mod backend;
pub mod batch;
pub mod network;
pub mod parallel_engine;
pub mod serial_engine;

pub use backend::{MacBackend, NativeMac};
pub use batch::{BatchRun, BatchRunner};
pub use network::{NetworkSim, Recorder, SpikeProvider};
pub use parallel_engine::ParallelLayerEngine;
pub use serial_engine::SerialLayerEngine;
