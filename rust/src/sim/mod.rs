//! Functional SpiNNaker2 simulator.
//!
//! Executes compiled layers timestep-by-timestep with exactly the runtime
//! semantics §III describes:
//!
//! * [`serial_engine`] — event-based synaptic processing: spike → master
//!   population table → address list → synaptic-matrix block → delay ring
//!   buffer, per serial PE (spikes dispatched through a precomputed
//!   source→PE CSR index; ring readout sparsity-gated per (PE, slot) by
//!   pending-write counters).
//! * [`parallel_engine`] — dominant-PE preprocessing (reversed order /
//!   input-merging tables → stacked input ring) + subordinate MAC-array
//!   matmuls, optionally through the AOT-compiled JAX/Pallas HLO via PJRT
//!   ([`crate::runtime`], behind the `pjrt` feature).
//! * [`network`] — whole-network simulation: wave-ordered population LIF
//!   state (chunked vectorizable kernel), spike routing between layers,
//!   flat-buffer recording, per-layer activity telemetry. Steady state
//!   allocates nothing; [`NetworkSim::reset`] reuses one compiled sim
//!   across stimuli, [`NetworkSim::run_jobs`] steps same-wave layers on
//!   scoped worker threads with bit-identical recorders.
//! * [`batch`] — [`BatchRunner`]: many independent stimulus samples fanned
//!   over worker threads against shared compiled layers (composable with
//!   intra-sample layer parallelism via `with_intra_jobs`).
//! * [`shard`] — [`ShardedSim`]: one `NetworkSim` per board of a board
//!   array, stepped in lock-step waves with a fixed-order spike-word
//!   exchange at wave boundaries; merged recorders are bit-identical to a
//!   single-board run at any board and worker count.
//! * [`spikebits`] — bit-packed spike words: `u64` bitmaps iterated via
//!   `trailing_zeros`, shared by both engines' spike dispatch and by the
//!   serial ring readout / parallel row-occupancy gating.
//!
//! **Numerical equivalence**: weights are integers (quantized u8 magnitudes,
//! sign = synapse type) and both engines accumulate them exactly (i32 /
//! integer-valued f32 ≤ 2²⁴), so serial and parallel execution produce
//! bit-identical spike trains — property-tested in [`network`].

pub mod backend;
pub mod batch;
pub mod network;
pub mod parallel_engine;
pub mod serial_engine;
pub mod shard;
pub mod spikebits;

pub use backend::{BackendBox, MacBackend, NativeMac};
pub use shard::ShardedSim;
pub use spikebits::SpikeWords;
pub use batch::{BatchRun, BatchRunner, SimPool};
pub use network::{
    EngineCheckpoint, LayerActivity, NetworkSim, PhaseProfile, Recorder, SimCheckpoint,
    SpikeProvider, VoltageTrace,
};
pub use parallel_engine::{ParallelEngineCheckpoint, ParallelLayerEngine};
pub use serial_engine::{SerialEngineCheckpoint, SerialLayerEngine};
