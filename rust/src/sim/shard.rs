//! Multi-board sharded simulation: one [`NetworkSim`] per board, lock-step
//! waves, spike-word exchange at wave boundaries.
//!
//! The partitioner ([`crate::graph::partition`]) assigns every population to
//! a board, and every layer runs on its **target** population's board. Each
//! board's shard is a [`NetworkSim`] over a sub-network: owned populations
//! keep their LIF state and recording flags; remote populations appear as
//! unrecorded spike-source *mirrors* (same id, same size) whose packed
//! spike words are injected by the coordinator each wave. All shards run the
//! **global** wave schedule ([`NetworkSim::with_depths`]), so a wave
//! boundary means the same thing on every board.
//!
//! ## Determinism argument
//!
//! The merged recorder is bit-identical to a single [`NetworkSim`] over the
//! whole network, at any board count and any worker count:
//!
//! 1. **Accumulation order.** Every projection into population `P` executes
//!    on `P`'s home board (enforced at construction), so `currents[P]` is
//!    accumulated by exactly one shard, whose engines run in the same
//!    wave-grouped projection order as the monolithic sim's — f32 sums see
//!    the same operands in the same order.
//! 2. **Spike representation.** The LIF kernel emits ascending neuron ids;
//!    [`SpikeWords`] iterates set bits ascending. An injected mirror
//!    therefore reproduces the producer's id list exactly.
//! 3. **Stimulus.** The coordinator alone calls the [`SpikeProvider`], in
//!    the same (wave-major, topo-minor) population order as
//!    [`NetworkSim::step`], once per source per step — a stateful provider
//!    RNG sees the identical call sequence.
//! 4. **Recording.** Each population is recorded on exactly one shard (its
//!    home), at the same `(t, neuron)` granularity; merging is a disjoint
//!    union keyed by population id.
//!
//! Worker threads only move *which CPU* runs a shard's already-deterministic
//! work between barriers — they never reorder any of the above.

use super::backend::NativeMac;
use super::network::{NetworkSim, Recorder, SpikeProvider};
use super::spikebits::SpikeWords;
use crate::graph::BoardAssignment;
use crate::model::population::NeuronKind;
use crate::model::{Network, Population, PopulationId, Projection};
use crate::switching::CompiledLayer;
use anyhow::{ensure, Result};
use std::collections::BTreeSet;
#[cfg(not(feature = "pjrt"))]
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
#[cfg(not(feature = "pjrt"))]
use std::sync::atomic::{AtomicBool, Ordering};
#[cfg(not(feature = "pjrt"))]
use std::sync::{Barrier, Mutex};

/// Board `b`'s view of the network: owned populations verbatim, remote ones
/// as unrecorded spike-source mirrors, and only the projections whose
/// target lives on `b`. Mirror projections carry no synapses — the shard's
/// engines run from the already-compiled layers, never from the model edge.
fn shard_net(net: &Network, assignment: &BoardAssignment, b: usize) -> Network {
    let populations: Vec<Population> = net
        .populations
        .iter()
        .map(|p| {
            if assignment.board_of_pop[p.id.0] == b {
                p.clone()
            } else {
                Population {
                    id: p.id,
                    label: format!("{}@b{}", p.label, assignment.board_of_pop[p.id.0]),
                    n_neurons: p.n_neurons,
                    kind: NeuronKind::SpikeSource,
                    record_spikes: false,
                    record_v: false,
                }
            }
        })
        .collect();
    let projections: Vec<Projection> = net
        .projections
        .iter()
        .enumerate()
        .filter(|&(i, _)| assignment.board_of_layer[i] == b)
        .map(|(_, proj)| Projection {
            id: proj.id,
            source: proj.source,
            target: proj.target,
            synapses: Vec::new(),
            weight_scale: proj.weight_scale,
        })
        .collect();
    Network { populations, projections }
}

/// One simulator shard per board, stepped in lock-step waves with a
/// fixed-order spike-word exchange at every wave boundary.
pub struct ShardedSim {
    shards: Vec<NetworkSim>,
    /// Home board per population.
    home: Vec<usize>,
    /// `sources[p]` — is population `p` a spike source (coordinator-fed)?
    sources: Vec<bool>,
    /// Boards population `p`'s words are injected into each wave: consumer
    /// boards other than its home for LIF populations; home plus all
    /// consumer boards for sources. Sorted — the fixed exchange order.
    inject_to: Vec<Vec<usize>>,
    /// Global wave schedule (population indices per wave, topo order).
    pops_of_wave: Vec<Vec<usize>>,
    /// Per-population exchange staging buffer.
    scratch: Vec<SpikeWords>,
    /// Reused source-spike id buffer for provider calls.
    ids: Vec<u32>,
    n_waves: usize,
    t: u64,
}

impl ShardedSim {
    /// Build one shard per board from a compiled network and its board
    /// assignment (one compiled layer per projection, same order).
    pub fn new(
        net: &Network,
        layers: &[CompiledLayer],
        assignment: &BoardAssignment,
    ) -> Result<Self> {
        let n_pops = net.populations.len();
        ensure!(
            layers.len() == net.projections.len(),
            "need one compiled layer per projection ({} vs {})",
            layers.len(),
            net.projections.len()
        );
        ensure!(
            assignment.board_of_pop.len() == n_pops
                && assignment.board_of_layer.len() == net.projections.len(),
            "board assignment shape does not match the network"
        );
        ensure!(assignment.boards >= 1, "need at least one board");
        for (p, &b) in assignment.board_of_pop.iter().enumerate() {
            ensure!(b < assignment.boards, "population {p} assigned to out-of-range board {b}");
        }
        for (i, proj) in net.projections.iter().enumerate() {
            ensure!(
                assignment.board_of_layer[i] == assignment.board_of_pop[proj.target.0],
                "layer {i} does not run on its target's board — the sharded \
                 accumulation-order invariant would break"
            );
        }

        let depth = NetworkSim::wave_depths(net);
        let n_waves = depth.iter().max().map_or(1, |&d| d + 1);
        let topo = net.topo_order();
        let mut pops_of_wave = vec![Vec::new(); n_waves];
        for &pid in &topo {
            pops_of_wave[depth[pid.0]].push(pid.0);
        }

        let shards: Vec<NetworkSim> = (0..assignment.boards)
            .map(|b| {
                let sub = shard_net(net, assignment, b);
                let sub_layers: Vec<CompiledLayer> = net
                    .projections
                    .iter()
                    .enumerate()
                    .filter(|&(i, _)| assignment.board_of_layer[i] == b)
                    .map(|(i, _)| layers[i].clone())
                    .collect();
                NetworkSim::with_depths(&sub, sub_layers, || Box::new(NativeMac), &depth)
            })
            .collect::<Result<_>>()?;

        let home = assignment.board_of_pop.clone();
        let sources: Vec<bool> = net.populations.iter().map(|p| p.is_source()).collect();
        let mut inject_sets: Vec<BTreeSet<usize>> = vec![BTreeSet::new(); n_pops];
        for (i, proj) in net.projections.iter().enumerate() {
            let b = assignment.board_of_layer[i];
            if sources[proj.source.0] || b != home[proj.source.0] {
                inject_sets[proj.source.0].insert(b);
            }
        }
        for p in 0..n_pops {
            if sources[p] {
                // The home shard always receives source spikes, so they are
                // recorded there (when flagged) exactly once.
                inject_sets[p].insert(home[p]);
            }
        }

        Ok(ShardedSim {
            shards,
            home,
            sources,
            inject_to: inject_sets.into_iter().map(|s| s.into_iter().collect()).collect(),
            pops_of_wave,
            scratch: net.populations.iter().map(|p| SpikeWords::new(p.n_neurons)).collect(),
            ids: Vec::new(),
            n_waves,
            t: 0,
        })
    }

    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }

    pub fn timestep(&self) -> u64 {
        self.t
    }

    /// Advance one timestep on every shard: per wave, all shards fire, the
    /// coordinator exchanges the wave's spike words in fixed population
    /// order, all shards run the wave's engines.
    pub fn step(&mut self, provider: &mut SpikeProvider) {
        for w in 0..self.n_waves {
            for shard in &mut self.shards {
                shard.fire_wave(w);
            }
            for &p in &self.pops_of_wave[w] {
                if self.sources[p] {
                    self.ids.clear();
                    provider(PopulationId(p), self.t, &mut self.ids);
                    self.scratch[p].fill_from_ids(&self.ids);
                } else {
                    if self.inject_to[p].is_empty() {
                        continue;
                    }
                    self.scratch[p].copy_from(self.shards[self.home[p]].spike_words_of(p));
                }
                for &b in &self.inject_to[p] {
                    self.shards[b].inject_words(p, &self.scratch[p]);
                }
            }
            for shard in &mut self.shards {
                shard.run_wave_engines(w);
            }
        }
        for shard in &mut self.shards {
            shard.advance_step();
        }
        self.t += 1;
    }

    /// Run `steps` timesteps with the coordinator stepping every shard.
    pub fn run(&mut self, steps: u64, provider: &mut SpikeProvider) {
        for shard in &mut self.shards {
            shard.reserve_recording(steps);
        }
        for _ in 0..steps {
            self.step(provider);
        }
    }

    /// Run `steps` timesteps with each shard on its own scoped worker
    /// thread (`jobs` = worker cap; 0 = one per CPU; capped at the board
    /// count; ≤1 boards/workers falls back to [`ShardedSim::run`]).
    ///
    /// Workers own disjoint shard subsets (round-robin) and execute each
    /// shard's fire/engine phases between barriers; the coordinator alone
    /// calls the provider and performs the wave-boundary exchange while the
    /// workers are parked between barriers. Which thread steps a shard is
    /// the only thing `jobs` changes — recorders stay bit-identical.
    #[cfg(not(feature = "pjrt"))]
    pub fn run_jobs(&mut self, steps: u64, provider: &mut SpikeProvider, jobs: usize) {
        let jobs = if jobs == 0 {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
        } else {
            jobs
        };
        let workers = jobs.min(self.shards.len());
        if workers <= 1 || steps == 0 {
            self.run(steps, provider);
            return;
        }
        self.run_shards_parallel(steps, provider, workers);
    }

    /// `pjrt` builds hold non-`Send` backends — step sequentially instead.
    #[cfg(feature = "pjrt")]
    pub fn run_jobs(&mut self, steps: u64, provider: &mut SpikeProvider, _jobs: usize) {
        self.run(steps, provider);
    }

    /// The barrier-synchronized body behind [`ShardedSim::run_jobs`]
    /// (`workers ≥ 2`). Schedule per step and wave (everybody waits 3×):
    ///
    /// | between            | workers                | coordinator          |
    /// |--------------------|------------------------|----------------------|
    /// | b1 → b2            | fire own shards        | provider → scratch   |
    /// | b2 → b3            | (parked at b3)         | inject spike words   |
    /// | b3 → next b1       | run own shards' engines| —                    |
    ///
    /// The barrier schedule makes shard access exclusive in every region,
    /// so the per-shard mutexes are uncontended formality.
    #[cfg(not(feature = "pjrt"))]
    fn run_shards_parallel(&mut self, steps: u64, provider: &mut SpikeProvider, workers: usize) {
        for shard in &mut self.shards {
            shard.reserve_recording(steps);
        }
        let n_waves = self.n_waves;
        let n_shards = self.shards.len();
        let cells: Vec<Mutex<&mut NetworkSim>> = self.shards.iter_mut().map(Mutex::new).collect();
        let ShardedSim {
            ref home,
            ref sources,
            ref inject_to,
            ref pops_of_wave,
            ref mut scratch,
            ref mut ids,
            ref mut t,
            ..
        } = *self;

        // Same panic containment as `NetworkSim::run_waves_parallel`: every
        // work region is caught, the first payload wins, `abort` silences
        // the rest, every party still runs its full barrier schedule, and
        // the panic resumes on the caller thread after the scope joins.
        let abort = AtomicBool::new(false);
        let panic_payload: Mutex<Option<Box<dyn std::any::Any + Send>>> = Mutex::new(None);
        let trap = |r: std::thread::Result<()>| {
            if let Err(payload) = r {
                abort.store(true, Ordering::SeqCst);
                panic_payload.lock().unwrap().get_or_insert(payload);
            }
        };

        let barrier = Barrier::new(workers + 1);
        std::thread::scope(|scope| {
            for k in 0..workers {
                let owned: Vec<usize> = (k..n_shards).step_by(workers).collect();
                let barrier = &barrier;
                let cells = &cells;
                let abort = &abort;
                let trap = &trap;
                scope.spawn(move || {
                    for _ in 0..steps {
                        for w in 0..n_waves {
                            barrier.wait(); // b1: coordinator generates stimulus
                            if !abort.load(Ordering::SeqCst) {
                                trap(catch_unwind(AssertUnwindSafe(|| {
                                    for &b in &owned {
                                        cells[b].lock().unwrap().fire_wave(w);
                                    }
                                })));
                            }
                            barrier.wait(); // b2: coordinator injects
                            barrier.wait(); // b3: words are in place
                            if !abort.load(Ordering::SeqCst) {
                                trap(catch_unwind(AssertUnwindSafe(|| {
                                    for &b in &owned {
                                        cells[b].lock().unwrap().run_wave_engines(w);
                                    }
                                })));
                            }
                        }
                        if !abort.load(Ordering::SeqCst) {
                            trap(catch_unwind(AssertUnwindSafe(|| {
                                for &b in &owned {
                                    cells[b].lock().unwrap().advance_step();
                                }
                            })));
                        }
                    }
                });
            }

            // Coordinator (this thread).
            for _ in 0..steps {
                for w in 0..n_waves {
                    barrier.wait(); // b1: workers fire wave w
                    if !abort.load(Ordering::SeqCst) {
                        trap(catch_unwind(AssertUnwindSafe(|| {
                            for &p in &pops_of_wave[w] {
                                if sources[p] {
                                    ids.clear();
                                    provider(PopulationId(p), *t, ids);
                                    scratch[p].fill_from_ids(ids);
                                }
                            }
                        })));
                    }
                    barrier.wait(); // b2: firing done, shards are exclusive
                    if !abort.load(Ordering::SeqCst) {
                        trap(catch_unwind(AssertUnwindSafe(|| {
                            for &p in &pops_of_wave[w] {
                                if !sources[p] {
                                    if inject_to[p].is_empty() {
                                        continue;
                                    }
                                    let words = cells[home[p]].lock().unwrap();
                                    scratch[p].copy_from(words.spike_words_of(p));
                                }
                                for &b in &inject_to[p] {
                                    cells[b].lock().unwrap().inject_words(p, &scratch[p]);
                                }
                            }
                        })));
                    }
                    barrier.wait(); // b3: workers run wave w's engines
                }
                *t += 1;
            }
        });

        if let Some(payload) = panic_payload.lock().unwrap().take() {
            resume_unwind(payload);
        }
    }

    /// Disjoint union of all shard recorders: every population is recorded
    /// on exactly one shard (its home board), so this is a re-keying, not a
    /// merge of overlapping data.
    pub fn merged_recorder(&self) -> Recorder {
        let mut out = Recorder::default();
        for shard in &self.shards {
            for (&p, spikes) in &shard.recorder.spikes {
                out.spikes.entry(p).or_default().extend(spikes.iter().copied());
            }
            for (&p, trace) in &shard.recorder.v {
                out.v.insert(p, trace.clone());
            }
        }
        out
    }

    /// Rewind every shard to t=0 (fresh state, empty recorders).
    pub fn reset(&mut self) {
        for shard in &mut self.shards {
            shard.reset();
        }
        self.t = 0;
    }

    /// Synaptic events processed by serial engines, summed across shards.
    pub fn total_events(&self) -> u64 {
        self.shards.iter().map(NetworkSim::total_events).sum()
    }

    /// MAC operations issued by parallel engines, summed across shards.
    pub fn total_macs(&self) -> u64 {
        self.shards.iter().map(NetworkSim::total_macs).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hardware::PeSpec;
    use crate::model::connector::SynapseDraw;
    use crate::model::{Connector, LifParams, NetworkBuilder};
    use crate::switching::{SwitchMode, SwitchingSystem};

    fn net3(seed: u64) -> Network {
        let mut b = NetworkBuilder::new(seed);
        let inp = b.spike_source("in", 40);
        let hid = b.lif_population(
            "hid",
            30,
            LifParams { alpha: 0.8, v_th: 1.0, ..Default::default() },
        );
        let out = b.lif_population(
            "out",
            12,
            LifParams { alpha: 0.85, v_th: 1.0, ..Default::default() },
        );
        b.project(
            inp,
            hid,
            Connector::FixedProbability(0.4),
            SynapseDraw { delay_range: 3, w_max: 100, ..Default::default() },
            0.02,
        );
        b.project(
            hid,
            out,
            Connector::FixedProbability(0.7),
            SynapseDraw { delay_range: 2, w_max: 100, ..Default::default() },
            0.05,
        );
        b.build()
    }

    fn stim(seed: u64) -> impl FnMut(PopulationId, u64, &mut Vec<u32>) {
        let mut rng = crate::rng::Rng::new(seed);
        move |_p, _t, out: &mut Vec<u32>| out.extend((0..40u32).filter(|_| rng.chance(0.25)))
    }

    #[test]
    fn shard_net_mirrors_remote_populations() {
        let net = net3(5);
        let asg =
            BoardAssignment { boards: 2, board_of_pop: vec![0, 0, 1], board_of_layer: vec![0, 1] };
        let s0 = shard_net(&net, &asg, 0);
        assert!(s0.populations[0].is_source() && !s0.populations[1].is_source());
        assert!(s0.populations[2].is_source(), "remote LIF becomes a mirror source");
        assert!(!s0.populations[2].record_spikes);
        assert_eq!(s0.projections.len(), 1);
        assert_eq!(s0.projections[0].id.0, 0);
        let s1 = shard_net(&net, &asg, 1);
        assert_eq!(s1.projections.len(), 1);
        assert_eq!(s1.projections[0].id.0, 1);
        assert!(s1.projections[0].synapses.is_empty(), "mirror edges carry no synapses");
    }

    #[test]
    fn new_rejects_layer_off_its_targets_board() {
        let net = net3(6);
        let mut sys = SwitchingSystem::new(SwitchMode::ForceSerial, PeSpec::default());
        let (layers, _) = sys.compile_network(&net).unwrap();
        let asg =
            BoardAssignment { boards: 2, board_of_pop: vec![0, 0, 1], board_of_layer: vec![0, 0] };
        let err = ShardedSim::new(&net, &layers, &asg).unwrap_err();
        assert!(err.to_string().contains("target's board"), "{err:#}");
    }

    #[test]
    fn two_board_run_matches_single_sim() {
        let net = net3(7);
        let mut sys = SwitchingSystem::new(SwitchMode::Ideal, PeSpec::default());
        let (layers, _) = sys.compile_network(&net).unwrap();
        let mut reference = NetworkSim::native(&net, layers.clone()).unwrap();
        let mut provider = stim(17);
        reference.run(80, &mut provider);

        let asg =
            BoardAssignment { boards: 2, board_of_pop: vec![0, 0, 1], board_of_layer: vec![0, 1] };
        let mut sharded = ShardedSim::new(&net, &layers, &asg).unwrap();
        let mut provider = stim(17);
        sharded.run(80, &mut provider);
        assert_eq!(sharded.merged_recorder(), reference.recorder);
        assert!(reference.recorder.total_spikes() > 0, "fixture must spike");
    }
}
