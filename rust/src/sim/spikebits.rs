//! Bit-packed spike sets.
//!
//! A timestep's spike set is naturally sparse and order-insensitive, so both
//! engines carry it as `u64` words — one bit per source neuron — instead of
//! per-neuron id lists. Dispatch loops then iterate *set bits* via
//! `trailing_zeros` (a handful of instructions per spike, zero work for
//! silent words) rather than branching once per neuron, which is where
//! event-driven throughput lives on SpiNNaker2-class cores.
//!
//! Semantics note: packing collapses duplicate ids (a bitmap has no
//! multiplicity) and drops out-of-range ids at `set` time. Neither occurs on
//! the sim's hot paths — a LIF population emits each id at most once per
//! step, and the engines already discarded out-of-range sources — so packed
//! dispatch is observationally identical to the per-id loops it replaces
//! (property-tested in [`crate::sim::network`]).

/// A fixed-capacity set of neuron ids, one bit per id, packed into `u64`
/// words. The word count is fixed at construction so steady-state reuse
/// ([`SpikeWords::fill_from_ids`]) never allocates.
#[derive(Debug, Clone, Default)]
pub struct SpikeWords {
    words: Vec<u64>,
    n_bits: usize,
}

impl SpikeWords {
    /// An empty set with capacity for ids `0..n_bits`.
    pub fn new(n_bits: usize) -> Self {
        SpikeWords { words: vec![0u64; n_bits.div_ceil(64)], n_bits }
    }

    /// Id capacity (ids `>= n_bits` are ignored by [`SpikeWords::set`]).
    pub fn n_bits(&self) -> usize {
        self.n_bits
    }

    /// The packed words, low ids in low bits of low words.
    pub fn words(&self) -> &[u64] {
        &self.words
    }

    /// Clear every bit (word-granular `fill`, not per-id).
    pub fn clear(&mut self) {
        self.words.fill(0);
    }

    /// Set the bit for `id`; ids beyond capacity are silently dropped
    /// (mirrors the engines' historical `src >= n_source` guard).
    #[inline]
    pub fn set(&mut self, id: u32) {
        let id = id as usize;
        if id < self.n_bits {
            self.words[id >> 6] |= 1u64 << (id & 63);
        }
    }

    /// Replace the set's contents with the given ids (duplicates collapse,
    /// out-of-range ids drop).
    pub fn fill_from_ids(&mut self, ids: &[u32]) {
        self.clear();
        for &id in ids {
            self.set(id);
        }
    }

    /// Copy another set's bits into this one without allocating (both sets
    /// must have the same capacity — the cross-shard exchange copies between
    /// same-population buffers only).
    pub fn copy_from(&mut self, other: &SpikeWords) {
        debug_assert_eq!(self.n_bits, other.n_bits, "spike-word capacity mismatch");
        self.words.copy_from_slice(&other.words);
    }

    /// Number of set bits.
    pub fn count(&self) -> usize {
        self.words.iter().map(|w| w.count_ones() as usize).sum()
    }

    /// Visit every set id in ascending order.
    #[inline]
    pub fn for_each(&self, mut f: impl FnMut(usize)) {
        for (wi, &word) in self.words.iter().enumerate() {
            let mut w = word;
            while w != 0 {
                f((wi << 6) + w.trailing_zeros() as usize);
                w &= w - 1; // clear lowest set bit
            }
        }
    }
}

/// Is any bit in `[lo, hi)` set across a word slice? Used by the parallel
/// engine to test a subordinate's row span against the slot-occupancy bitmap
/// without scanning f32 lanes.
#[inline]
pub fn any_set_in_range(words: &[u64], lo: usize, hi: usize) -> bool {
    if lo >= hi {
        return false;
    }
    let (wl, wh) = (lo >> 6, (hi - 1) >> 6);
    if wl == wh {
        // Single word: mask bits [lo&63, (hi-1)&63].
        let mask = (!0u64 << (lo & 63)) & (!0u64 >> (63 - ((hi - 1) & 63)));
        return words[wl] & mask != 0;
    }
    if words[wl] & (!0u64 << (lo & 63)) != 0 {
        return true;
    }
    if words[wh] & (!0u64 >> (63 - ((hi - 1) & 63))) != 0 {
        return true;
    }
    words[wl + 1..wh].iter().any(|&w| w != 0)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn collected(s: &SpikeWords) -> Vec<usize> {
        let mut out = Vec::new();
        s.for_each(|id| out.push(id));
        out
    }

    #[test]
    fn set_and_iterate_ascending() {
        let mut s = SpikeWords::new(200);
        for id in [199, 0, 63, 64, 127, 128, 5] {
            s.set(id);
        }
        assert_eq!(collected(&s), vec![0, 5, 63, 64, 127, 128, 199]);
        assert_eq!(s.count(), 7);
    }

    #[test]
    fn out_of_range_and_duplicates() {
        let mut s = SpikeWords::new(10);
        s.fill_from_ids(&[3, 3, 3, 9, 10, 500]);
        assert_eq!(collected(&s), vec![3, 9]);
    }

    #[test]
    fn clear_and_refill_reuses_capacity() {
        let mut s = SpikeWords::new(130);
        s.fill_from_ids(&[1, 129]);
        assert_eq!(s.count(), 2);
        s.fill_from_ids(&[64]);
        assert_eq!(collected(&s), vec![64]);
        s.clear();
        assert_eq!(s.count(), 0);
        assert_eq!(s.words().len(), 3);
    }

    #[test]
    fn zero_capacity_is_inert() {
        let mut s = SpikeWords::new(0);
        s.set(0);
        assert_eq!(s.count(), 0);
        assert!(s.words().is_empty());
    }

    #[test]
    fn range_test_matches_naive_scan() {
        use crate::prop::Prop;
        Prop::new("any_set_in_range ≡ naive", 200).check(
            |g| {
                let n = g.usize(1, 300);
                let ids = g.vec(g.usize(0, 12), |g| g.usize(0, n - 1) as u32);
                let lo = g.usize(0, n);
                let hi = g.usize(0, n);
                (n, ids, lo, hi)
            },
            |(n, ids, lo, hi)| {
                let mut s = SpikeWords::new(*n);
                s.fill_from_ids(ids);
                let naive = ids.iter().any(|&id| (*lo..*hi).contains(&(id as usize)));
                any_set_in_range(s.words(), *lo, *hi) == naive
            },
        );
    }

    #[test]
    fn range_test_word_boundaries() {
        let mut s = SpikeWords::new(256);
        s.set(64);
        assert!(any_set_in_range(s.words(), 64, 65));
        assert!(any_set_in_range(s.words(), 0, 65));
        assert!(any_set_in_range(s.words(), 64, 256));
        assert!(!any_set_in_range(s.words(), 0, 64));
        assert!(!any_set_in_range(s.words(), 65, 256));
        assert!(!any_set_in_range(s.words(), 64, 64));
    }

    #[test]
    fn set_and_iterate_exactly_at_word_boundaries() {
        // Capacities 63/64/65 straddle the one-word/two-word edge; the last
        // legal id and the first illegal one differ by a single bit.
        for cap in [63usize, 64, 65] {
            let mut s = SpikeWords::new(cap);
            assert_eq!(s.words().len(), cap.div_ceil(64), "cap={cap}");
            let last = (cap - 1) as u32;
            s.fill_from_ids(&[0, last]);
            assert_eq!(collected(&s), vec![0, last as usize], "cap={cap}");
            s.set(cap as u32); // first out-of-range id: silently dropped
            assert_eq!(s.count(), 2, "cap={cap}: id {cap} must drop");
        }
        // Ids 63/64/65 in a roomy set land on both sides of the word seam.
        let mut s = SpikeWords::new(128);
        s.fill_from_ids(&[63, 64, 65]);
        assert_eq!(collected(&s), vec![63, 64, 65]);
        assert_eq!(s.words()[0], 1u64 << 63, "bit 63 is the top of word 0");
        assert_eq!(s.words()[1], 0b11, "bits 64/65 are the bottom of word 1");
    }

    #[test]
    fn range_test_spans_partial_first_and_last_words() {
        // A three-word set with bits only in the middle word: ranges whose
        // partial first/last words clip the middle from either side must
        // agree with the bit positions exactly.
        let mut s = SpikeWords::new(192);
        s.fill_from_ids(&[70, 120]);
        assert!(any_set_in_range(s.words(), 65, 121), "partial words contain both");
        assert!(any_set_in_range(s.words(), 70, 71), "tightest window on bit 70");
        assert!(any_set_in_range(s.words(), 100, 190), "partial first word after 70");
        assert!(!any_set_in_range(s.words(), 0, 70), "stops one short of bit 70");
        assert!(!any_set_in_range(s.words(), 71, 120), "interior gap between bits");
        assert!(!any_set_in_range(s.words(), 121, 192), "starts one past bit 120");
        // Range spanning all three words with only edge words populated.
        s.fill_from_ids(&[10, 180]);
        assert!(any_set_in_range(s.words(), 5, 64), "partial first word only");
        assert!(any_set_in_range(s.words(), 128, 181), "partial last word only");
        assert!(!any_set_in_range(s.words(), 11, 180), "middle word is empty");
    }

    #[test]
    fn out_of_range_ids_drop_without_corrupting_neighbors() {
        // Dropping must be exact: id == n_bits (first illegal, same word as
        // legal bits when n_bits % 64 != 0) and huge ids alike leave the
        // word content of legal ids untouched.
        let mut s = SpikeWords::new(65);
        s.fill_from_ids(&[64, 65, 66, 127, 128, u32::MAX]);
        assert_eq!(collected(&s), vec![64], "only the last legal id survives");
        assert_eq!(s.words()[1], 1, "word 1 holds exactly bit 64");
        assert!(!any_set_in_range(s.words(), 0, 64));
        assert!(any_set_in_range(s.words(), 64, 65));
    }
}
