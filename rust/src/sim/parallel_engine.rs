//! Parallel (MAC-array) execution engine (paper §III-B runtime semantics).
//!
//! Per timestep `t`:
//! 1. the engine consumes the stacked-input slot `t mod D` — one lane per
//!    WDM row — and has every subordinate multiply its WDM chunk against
//!    its row span on a [`MacBackend`] (native or PJRT/Pallas);
//! 2. chunk results are reduced into per-target currents on the dominant
//!    PE (column chunks are disjoint; row chunks add up);
//! 3. this step's arriving spikes are pre-processed through the
//!    reversed-order + input-merging tables into future stacked slots.
//!
//! Steady-state execution is allocation-free: subordinate results land in a
//! persistent output scratch, currents in a persistent per-target buffer.
//! A per-slot write counter lets fully silent stacked slots (no spike wrote
//! into them) skip the MAC phase entirely, and per-chunk silent row spans
//! skip individual subordinates — `macs` counts only the work the backend
//! actually issued, so MACs/s telemetry is honest.

use super::backend::BackendBox;
use crate::paradigm::parallel::ParallelCompiled;
use std::time::Instant;

/// Executes one parallel-compiled layer.
pub struct ParallelLayerEngine {
    compiled: ParallelCompiled,
    /// Stacked-input ring, one flat slot-major buffer: lane `(slot, row)`
    /// lives at `slot * n_rows + row` (spike counts as f32). Flat instead
    /// of `Vec<Vec<f32>>` so a step touches one contiguous span and the
    /// whole ring is one allocation.
    ring: Vec<f32>,
    /// WDM row count — the ring's slot stride.
    n_rows: usize,
    /// Writes into each ring slot since it was last cleared; 0 means the
    /// slot is all-zero and the whole MAC phase can be skipped.
    slot_writes: Vec<u32>,
    /// All chunk weights pre-converted to f32 for the backend, packed
    /// into one contiguous buffer; `chunk_spans[i]` is subordinate `i`'s
    /// `(offset, len)` slice of it.
    chunk_weights: Vec<f32>,
    chunk_spans: Vec<(usize, usize)>,
    /// Persistent per-target current scratch, rewritten every step.
    currents: Vec<f32>,
    /// Persistent subordinate-output scratch (sized to the widest chunk).
    out_scratch: Vec<f32>,
    backend: BackendBox,
    t: u64,
    /// MAC multiply-accumulate operations actually issued by the backend
    /// (telemetry; cumulative — survives [`ParallelLayerEngine::reset`]).
    pub macs: u64,
    /// Incoming spikes seen (cumulative; with [`ParallelLayerEngine::steps`]
    /// this is the observed-firing-rate telemetry the runtime-informed cost
    /// model consumes).
    pub spikes_in: u64,
    /// Timesteps executed (cumulative — survives reset, like `macs`).
    pub steps: u64,
    /// Phase-1 (MAC consume + reduce) wall-clock, accumulated only while
    /// profiling.
    pub readout_nanos: u64,
    /// Phase-2 (spike preprocessing) wall-clock, accumulated only while
    /// profiling.
    pub dispatch_nanos: u64,
    profile: bool,
}

impl ParallelLayerEngine {
    pub fn new(compiled: ParallelCompiled, backend: BackendBox) -> Self {
        let d = compiled.wdm.delay_range as usize;
        let rows = compiled.wdm.n_rows();
        let total_weights: usize =
            compiled.subordinates.iter().map(|s| s.weights.len()).sum();
        let mut chunk_weights = Vec::with_capacity(total_weights);
        let mut chunk_spans = Vec::with_capacity(compiled.subordinates.len());
        for s in &compiled.subordinates {
            let offset = chunk_weights.len();
            chunk_weights.extend(s.weights.iter().map(|&w| w as f32));
            chunk_spans.push((offset, s.weights.len()));
        }
        let max_cols =
            compiled.subordinates.iter().map(|s| s.n_cols()).max().unwrap_or(0);
        let n_target = compiled.n_target;
        ParallelLayerEngine {
            compiled,
            ring: vec![0.0; d * rows],
            n_rows: rows,
            slot_writes: vec![0; d],
            chunk_weights,
            chunk_spans,
            currents: vec![0.0; n_target],
            out_scratch: vec![0.0; max_cols],
            backend,
            t: 0,
            macs: 0,
            spikes_in: 0,
            steps: 0,
            readout_nanos: 0,
            dispatch_nanos: 0,
            profile: false,
        }
    }

    pub fn timestep(&self) -> u64 {
        self.t
    }

    /// Enable per-phase wall-clock accumulation (`readout_nanos` /
    /// `dispatch_nanos`); off by default so the hot path carries no timer
    /// syscalls.
    pub fn set_profile(&mut self, on: bool) {
        self.profile = on;
    }

    pub fn backend_name(&self) -> &'static str {
        self.backend.name()
    }

    /// Clear all dynamic state (stacked rings, clock) so the engine can run
    /// a fresh stimulus without recompiling. The `macs` telemetry keeps
    /// accumulating across resets (batch accounting reads it at the end).
    pub fn reset(&mut self) {
        self.ring.fill(0.0);
        self.slot_writes.fill(0);
        self.currents.fill(0.0);
        self.t = 0;
    }

    /// Advance one timestep (same contract as
    /// [`super::serial_engine::SerialLayerEngine::step_currents`]; the
    /// returned slice lives in engine-owned scratch, valid until the next
    /// call).
    pub fn step_currents(&mut self, spikes_in: &[u32]) -> &[f32] {
        let ParallelLayerEngine {
            ref compiled,
            ref mut ring,
            n_rows,
            ref mut slot_writes,
            ref chunk_weights,
            ref chunk_spans,
            ref mut currents,
            ref mut out_scratch,
            ref mut backend,
            ref mut macs,
            ref mut readout_nanos,
            ref mut dispatch_nanos,
            profile,
            t,
            ..
        } = *self;
        let d = compiled.wdm.delay_range as usize;
        let t = t as usize;
        let slot = t % d;
        let base = slot * n_rows;
        let scale = compiled.weight_scale;
        currents.fill(0.0);
        let t0 = profile.then(Instant::now);

        // Phase 1: subordinate MAC matmuls over the due stacked slot.
        // A slot nothing wrote into since its last clear is identically
        // zero — skip the whole phase (and the clear).
        if slot_writes[slot] > 0 {
            let stacked = &ring[base..base + n_rows];
            for (sub, &(w_off, w_len)) in compiled.subordinates.iter().zip(chunk_spans) {
                let lanes = &stacked[sub.row_lo..sub.row_hi];
                if lanes.iter().all(|&s| s == 0.0) {
                    continue; // this chunk's row span is silent this step
                }
                let rows = sub.n_rows();
                let cols = sub.n_cols();
                let weights = &chunk_weights[w_off..w_off + w_len];
                let out = &mut out_scratch[..cols];
                *macs += backend.matvec_into(out, lanes, weights, rows, cols);
                // Reduce into global targets via the WDM column map.
                for (local, &v) in out.iter().enumerate() {
                    if v != 0.0 {
                        let target = compiled.wdm.cols[sub.col_lo + local];
                        currents[target as usize] += v * scale;
                    }
                }
            }
            ring[base..base + n_rows].fill(0.0);
            slot_writes[slot] = 0;
        }
        if let Some(t0) = t0 {
            *readout_nanos += t0.elapsed().as_nanos() as u64;
        }

        // Phase 2: dominant-PE spike preprocessing into future slots.
        let t0 = profile.then(Instant::now);
        for &src in spikes_in {
            for e in compiled.tables.entries_of(src) {
                let write_slot = (t + e.delay as usize) % d;
                ring[write_slot * n_rows + e.row as usize] += 1.0;
                slot_writes[write_slot] += 1;
            }
        }
        if let Some(t0) = t0 {
            *dispatch_nanos += t0.elapsed().as_nanos() as u64;
        }

        self.spikes_in += spikes_in.len() as u64;
        self.steps += 1;
        self.t += 1;
        &self.currents
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hardware::PeSpec;
    use crate::model::{
        LifParams, PopulationId, Projection, ProjectionId, Synapse, SynapseType,
    };
    use crate::paradigm::parallel::{compile_parallel, WdmConfig};
    use crate::sim::backend::NativeMac;

    fn engine_for(synapses: Vec<Synapse>, n_src: usize, n_tgt: usize) -> ParallelLayerEngine {
        let proj = Projection {
            id: ProjectionId(0),
            source: PopulationId(0),
            target: PopulationId(1),
            synapses,
            weight_scale: 0.5,
        };
        let c = compile_parallel(
            &proj,
            n_src,
            n_tgt,
            LifParams::default(),
            &PeSpec::default(),
            WdmConfig::default(),
        )
        .unwrap();
        ParallelLayerEngine::new(c, Box::new(NativeMac))
    }

    fn syn(s: u32, t: u32, w: u8, d: u16, inh: bool) -> Synapse {
        Synapse {
            source: s,
            target: t,
            weight: w,
            delay: d,
            syn_type: if inh { SynapseType::Inhibitory } else { SynapseType::Excitatory },
        }
    }

    #[test]
    fn delay_one_arrives_next_step() {
        let mut e = engine_for(vec![syn(0, 1, 10, 1, false)], 2, 3);
        assert_eq!(e.step_currents(&[0]), [0.0, 0.0, 0.0]);
        assert_eq!(e.step_currents(&[]), [0.0, 5.0, 0.0]);
        assert_eq!(e.step_currents(&[]), [0.0, 0.0, 0.0]);
    }

    #[test]
    fn inhibition_is_negative() {
        let mut e = engine_for(vec![syn(0, 0, 6, 1, true)], 1, 1);
        e.step_currents(&[0]);
        assert_eq!(e.step_currents(&[]), [-3.0]);
    }

    #[test]
    fn delay_wraps_at_ring_boundary() {
        let mut e = engine_for(vec![syn(0, 0, 8, 4, false), syn(0, 1, 8, 1, false)], 1, 2);
        e.step_currents(&[0]);
        let mut hits = Vec::new();
        for t in 1..=5 {
            let c = e.step_currents(&[]);
            for (n, &v) in c.iter().enumerate() {
                if v != 0.0 {
                    hits.push((t, n, v));
                }
            }
        }
        assert_eq!(hits, vec![(1, 1, 4.0), (4, 0, 4.0)]);
    }

    #[test]
    fn macs_count_only_issued_work() {
        let mut e = engine_for(vec![syn(0, 0, 1, 1, false)], 4, 4);
        e.step_currents(&[]);
        assert_eq!(e.macs, 0, "a silent slot must not charge the MAC array");
        e.step_currents(&[0]);
        assert_eq!(e.macs, 0, "the spike lands one slot ahead");
        e.step_currents(&[]);
        assert!(e.macs > 0, "the populated slot issues real work");
        let total_cells: u64 = e
            .compiled
            .subordinates
            .iter()
            .map(|s| (s.n_rows() * s.n_cols()) as u64)
            .sum();
        assert!(e.macs <= total_cells, "issued {} > WDM cells {total_cells}", e.macs);
    }

    #[test]
    fn reset_replays_identically() {
        let mut e = engine_for(vec![syn(0, 1, 10, 2, false), syn(1, 0, 6, 1, true)], 2, 3);
        let run = |e: &mut ParallelLayerEngine| -> Vec<Vec<f32>> {
            let stim: [&[u32]; 4] = [&[0, 1], &[], &[1], &[]];
            stim.iter().map(|s| e.step_currents(s).to_vec()).collect()
        };
        let first = run(&mut e);
        e.reset();
        assert_eq!(e.timestep(), 0);
        let second = run(&mut e);
        assert_eq!(first, second, "reset must reproduce the run exactly");
    }
}
