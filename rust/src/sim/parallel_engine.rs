//! Parallel (MAC-array) execution engine (paper §III-B runtime semantics).
//!
//! Per timestep `t`:
//! 1. the engine consumes the stacked-input slot `t mod D` — one lane per
//!    WDM row — and has every subordinate multiply its WDM chunk against
//!    its row span on a [`MacBackend`] (native or PJRT/Pallas);
//! 2. chunk results are reduced into per-target currents on the dominant
//!    PE (column chunks are disjoint; row chunks add up);
//! 3. this step's arriving spikes are pre-processed through the
//!    reversed-order + input-merging tables into future stacked slots.
//!
//! Steady-state execution is allocation-free: subordinate results land in a
//! persistent output scratch, currents in a persistent per-target buffer.
//! A per-slot write counter lets fully silent stacked slots (no spike wrote
//! into them) skip the MAC phase entirely, and per-chunk silent row spans
//! skip individual subordinates — `macs` counts only the work the backend
//! actually issued, so MACs/s telemetry is honest.

use super::backend::BackendBox;
use crate::paradigm::parallel::ParallelCompiled;
use crate::sim::spikebits::{any_set_in_range, SpikeWords};
use anyhow::{ensure, Result};
use std::time::Instant;

/// Snapshot of one parallel engine's dynamic state — the stacked-input
/// ring, slot write counters, row-occupancy bitmaps, current scratch, and
/// the clock. Telemetry (`macs`/`spikes_in`/`steps`/profiling nanos) is
/// deliberately excluded: it is cumulative reporting state, not replay
/// state, and [`ParallelLayerEngine::restore`] leaves it untouched.
#[derive(Clone, Debug, PartialEq)]
pub struct ParallelEngineCheckpoint {
    ring: Vec<f32>,
    slot_writes: Vec<u32>,
    occupied: Vec<u64>,
    currents: Vec<f32>,
    t: u64,
}

impl ParallelEngineCheckpoint {
    /// True when every buffer is identically zero — the state [`ParallelLayerEngine::reset`]
    /// produces (any clock value is consistent with an empty ring).
    pub fn is_pristine(&self) -> bool {
        self.ring.iter().all(|&x| x == 0.0)
            && self.slot_writes.iter().all(|&x| x == 0)
            && self.occupied.iter().all(|&x| x == 0)
            && self.currents.iter().all(|&c| c == 0.0)
    }

    pub fn timestep(&self) -> u64 {
        self.t
    }

    /// In-memory footprint of the captured state (the recovery stats'
    /// checkpoint-cost accounting).
    pub fn byte_size(&self) -> usize {
        self.ring.len() * 4
            + self.slot_writes.len() * 4
            + self.occupied.len() * 8
            + self.currents.len() * 4
            + 8
    }
}

/// Executes one parallel-compiled layer.
pub struct ParallelLayerEngine {
    compiled: ParallelCompiled,
    /// Stacked-input ring, one flat slot-major buffer: lane `(slot, row)`
    /// lives at `slot * n_rows + row` (spike counts as f32). Flat instead
    /// of `Vec<Vec<f32>>` so a step touches one contiguous span and the
    /// whole ring is one allocation.
    ring: Vec<f32>,
    /// WDM row count — the ring's slot stride.
    n_rows: usize,
    /// Writes into each ring slot since it was last cleared; 0 means the
    /// slot is all-zero and the whole MAC phase can be skipped.
    slot_writes: Vec<u32>,
    /// Word-aligned row-occupancy bitmap per ring slot
    /// (`[slot][row_words]`): bit `row` of slot `s` is set iff some spike
    /// wrote stacked lane `row` of slot `s` since it was last cleared. A
    /// subordinate's silence test becomes a masked word scan of its row
    /// span instead of an f32 scan of its lanes.
    occupied: Vec<u64>,
    /// `n_rows.div_ceil(64)` — the per-slot stride of `occupied`.
    row_words: usize,
    /// All chunk weights pre-converted to f32 for the backend, packed
    /// into one contiguous buffer; `chunk_spans[i]` is subordinate `i`'s
    /// `(offset, len)` slice of it.
    chunk_weights: Vec<f32>,
    chunk_spans: Vec<(usize, usize)>,
    /// Persistent per-target current scratch, rewritten every step.
    currents: Vec<f32>,
    /// Persistent subordinate-output scratch (sized to the widest chunk).
    out_scratch: Vec<f32>,
    /// Scratch bitmap backing the id-list
    /// [`ParallelLayerEngine::step_currents`] wrapper (the words path
    /// [`ParallelLayerEngine::step_currents_words`] is the primary
    /// implementation).
    spike_scratch: SpikeWords,
    backend: BackendBox,
    t: u64,
    /// MAC multiply-accumulate operations actually issued by the backend
    /// (telemetry; cumulative — survives [`ParallelLayerEngine::reset`]).
    pub macs: u64,
    /// Incoming spikes seen (cumulative; with [`ParallelLayerEngine::steps`]
    /// this is the observed-firing-rate telemetry the runtime-informed cost
    /// model consumes).
    pub spikes_in: u64,
    /// Timesteps executed (cumulative — survives reset, like `macs`).
    pub steps: u64,
    /// Incoming spikes seen in the *current activity window* — dynamic
    /// state, unlike the lifetime telemetry above: cleared by
    /// [`ParallelLayerEngine::reset`] and
    /// [`ParallelLayerEngine::clear_window`], so the adaptive re-switcher
    /// reads recent activity, not history.
    pub window_spikes: u64,
    /// Timesteps executed in the current activity window (cleared with
    /// `window_spikes`).
    pub window_steps: u64,
    /// Phase-1 (MAC consume + reduce) wall-clock, accumulated only while
    /// profiling.
    pub readout_nanos: u64,
    /// Phase-2 (spike preprocessing) wall-clock, accumulated only while
    /// profiling.
    pub dispatch_nanos: u64,
    profile: bool,
}

impl ParallelLayerEngine {
    pub fn new(compiled: ParallelCompiled, backend: BackendBox) -> Self {
        let d = compiled.wdm.delay_range as usize;
        let rows = compiled.wdm.n_rows();
        let total_weights: usize =
            compiled.subordinates.iter().map(|s| s.weights.len()).sum();
        let mut chunk_weights = Vec::with_capacity(total_weights);
        let mut chunk_spans = Vec::with_capacity(compiled.subordinates.len());
        for s in &compiled.subordinates {
            let offset = chunk_weights.len();
            chunk_weights.extend(s.weights.iter().map(|&w| w as f32));
            chunk_spans.push((offset, s.weights.len()));
        }
        let max_cols =
            compiled.subordinates.iter().map(|s| s.n_cols()).max().unwrap_or(0);
        let n_target = compiled.n_target;
        let n_source = compiled.n_source;
        let row_words = rows.div_ceil(64);
        ParallelLayerEngine {
            compiled,
            ring: vec![0.0; d * rows],
            n_rows: rows,
            slot_writes: vec![0; d],
            occupied: vec![0; d * row_words],
            row_words,
            chunk_weights,
            chunk_spans,
            currents: vec![0.0; n_target],
            out_scratch: vec![0.0; max_cols],
            spike_scratch: SpikeWords::new(n_source),
            backend,
            t: 0,
            macs: 0,
            spikes_in: 0,
            steps: 0,
            window_spikes: 0,
            window_steps: 0,
            readout_nanos: 0,
            dispatch_nanos: 0,
            profile: false,
        }
    }

    pub fn timestep(&self) -> u64 {
        self.t
    }

    /// Enable per-phase wall-clock accumulation (`readout_nanos` /
    /// `dispatch_nanos`); off by default so the hot path carries no timer
    /// syscalls.
    pub fn set_profile(&mut self, on: bool) {
        self.profile = on;
    }

    pub fn backend_name(&self) -> &'static str {
        self.backend.name()
    }

    /// The backend's MAC inner-loop implementation (`"scalar"`, `"simd"`,
    /// `"pjrt-aot"`) — surfaced by `simulate --profile`.
    pub fn backend_kernel_variant(&self) -> &'static str {
        self.backend.kernel_variant()
    }

    /// Clear all dynamic state (stacked rings, clock, the activity window)
    /// so the engine can run a fresh stimulus without recompiling. The
    /// `macs` telemetry keeps accumulating across resets (batch accounting
    /// reads it at the end).
    pub fn reset(&mut self) {
        self.ring.fill(0.0);
        self.slot_writes.fill(0);
        self.occupied.fill(0);
        self.currents.fill(0.0);
        self.clear_window();
        self.t = 0;
    }

    /// Start a fresh activity window: zero `window_spikes`/`window_steps`
    /// without touching ring state or the lifetime telemetry. The adaptive
    /// re-switcher calls this at every sample boundary it evaluates.
    pub fn clear_window(&mut self) {
        self.window_spikes = 0;
        self.window_steps = 0;
    }

    /// Snapshot all dynamic state (see [`ParallelEngineCheckpoint`]).
    pub fn checkpoint(&self) -> ParallelEngineCheckpoint {
        ParallelEngineCheckpoint {
            ring: self.ring.clone(),
            slot_writes: self.slot_writes.clone(),
            occupied: self.occupied.clone(),
            currents: self.currents.clone(),
            t: self.t,
        }
    }

    /// Restore a [`ParallelLayerEngine::checkpoint`] taken from an engine
    /// of identical shape (same compiled layer). Telemetry keeps
    /// accumulating across restores, like it does across
    /// [`ParallelLayerEngine::reset`].
    pub fn restore(&mut self, ckpt: &ParallelEngineCheckpoint) -> Result<()> {
        ensure!(
            ckpt.ring.len() == self.ring.len()
                && ckpt.slot_writes.len() == self.slot_writes.len()
                && ckpt.occupied.len() == self.occupied.len()
                && ckpt.currents.len() == self.currents.len(),
            "parallel checkpoint buffer shapes do not match the engine"
        );
        self.ring.copy_from_slice(&ckpt.ring);
        self.slot_writes.copy_from_slice(&ckpt.slot_writes);
        self.occupied.copy_from_slice(&ckpt.occupied);
        self.currents.copy_from_slice(&ckpt.currents);
        self.t = ckpt.t;
        Ok(())
    }

    /// [`ParallelLayerEngine::reset`] but resuming the clock at `t` — the
    /// cross-paradigm pristine-restore path (an empty ring is consistent
    /// with any clock value).
    pub fn reset_to(&mut self, t: u64) {
        self.reset();
        self.t = t;
    }

    /// Id-list convenience wrapper around
    /// [`ParallelLayerEngine::step_currents_words`]: packs `spikes_in` into
    /// the engine-owned scratch bitmap (duplicates collapse, out-of-range
    /// ids drop) and steps on the words path.
    pub fn step_currents(&mut self, spikes_in: &[u32]) -> &[f32] {
        let mut scratch = std::mem::take(&mut self.spike_scratch);
        scratch.fill_from_ids(spikes_in);
        self.step_currents_words(&scratch);
        self.spike_scratch = scratch;
        &self.currents
    }

    /// Advance one timestep (same contract as
    /// [`super::serial_engine::SerialLayerEngine::step_currents_words`]; the
    /// returned slice lives in engine-owned scratch, valid until the next
    /// call).
    pub fn step_currents_words(&mut self, spikes_in: &SpikeWords) -> &[f32] {
        let ParallelLayerEngine {
            ref compiled,
            ref mut ring,
            n_rows,
            ref mut slot_writes,
            ref mut occupied,
            row_words,
            ref chunk_weights,
            ref chunk_spans,
            ref mut currents,
            ref mut out_scratch,
            ref mut backend,
            ref mut macs,
            ref mut readout_nanos,
            ref mut dispatch_nanos,
            profile,
            t,
            ..
        } = *self;
        let d = compiled.wdm.delay_range as usize;
        let t = t as usize;
        let slot = t % d;
        let base = slot * n_rows;
        let scale = compiled.weight_scale;
        currents.fill(0.0);
        let t0 = profile.then(Instant::now);

        // Phase 1: subordinate MAC matmuls over the due stacked slot.
        // A slot nothing wrote into since its last clear is identically
        // zero — skip the whole phase (and the clear). Within a live slot,
        // each subordinate's silence test is a masked word scan of its row
        // span in the occupancy bitmap — O(rows/64), not O(rows) f32 loads.
        if slot_writes[slot] > 0 {
            let occ = &occupied[slot * row_words..(slot + 1) * row_words];
            let stacked = &ring[base..base + n_rows];
            for (sub, &(w_off, w_len)) in compiled.subordinates.iter().zip(chunk_spans) {
                if !any_set_in_range(occ, sub.row_lo, sub.row_hi) {
                    continue; // this chunk's row span is silent this step
                }
                let lanes = &stacked[sub.row_lo..sub.row_hi];
                let rows = sub.n_rows();
                let cols = sub.n_cols();
                let weights = &chunk_weights[w_off..w_off + w_len];
                let out = &mut out_scratch[..cols];
                *macs += backend.matvec_into(out, lanes, weights, rows, cols);
                // Reduce into global targets via the WDM column map.
                for (local, &v) in out.iter().enumerate() {
                    if v != 0.0 {
                        let target = compiled.wdm.cols[sub.col_lo + local];
                        currents[target as usize] += v * scale;
                    }
                }
            }
            ring[base..base + n_rows].fill(0.0);
            occupied[slot * row_words..(slot + 1) * row_words].fill(0);
            slot_writes[slot] = 0;
        }
        if let Some(t0) = t0 {
            *readout_nanos += t0.elapsed().as_nanos() as u64;
        }

        // Phase 2: dominant-PE spike preprocessing into future slots — set
        // bits walked via `trailing_zeros`. Ids at or beyond the merging
        // tables' range end the walk (bits ascend), mirroring the serial
        // engine's dispatch guard.
        let t0 = profile.then(Instant::now);
        let n_source = compiled.n_source;
        'dispatch: for (swi, &sword) in spikes_in.words().iter().enumerate() {
            let mut sw = sword;
            while sw != 0 {
                let src = ((swi << 6) + sw.trailing_zeros() as usize) as u32;
                sw &= sw - 1;
                if src as usize >= n_source {
                    break 'dispatch;
                }
                for e in compiled.tables.entries_of(src) {
                    let write_slot = (t + e.delay as usize) % d;
                    let row = e.row as usize;
                    ring[write_slot * n_rows + row] += 1.0;
                    occupied[write_slot * row_words + (row >> 6)] |= 1u64 << (row & 63);
                    slot_writes[write_slot] += 1;
                }
            }
        }
        if let Some(t0) = t0 {
            *dispatch_nanos += t0.elapsed().as_nanos() as u64;
        }

        let n_in = spikes_in.count() as u64;
        self.spikes_in += n_in;
        self.steps += 1;
        self.window_spikes += n_in;
        self.window_steps += 1;
        self.t += 1;
        &self.currents
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hardware::PeSpec;
    use crate::model::{
        LifParams, PopulationId, Projection, ProjectionId, Synapse, SynapseType,
    };
    use crate::paradigm::parallel::{compile_parallel, WdmConfig};
    use crate::sim::backend::NativeMac;

    fn engine_for(synapses: Vec<Synapse>, n_src: usize, n_tgt: usize) -> ParallelLayerEngine {
        let proj = Projection {
            id: ProjectionId(0),
            source: PopulationId(0),
            target: PopulationId(1),
            synapses,
            weight_scale: 0.5,
        };
        let c = compile_parallel(
            &proj,
            n_src,
            n_tgt,
            LifParams::default(),
            &PeSpec::default(),
            WdmConfig::default(),
        )
        .unwrap();
        ParallelLayerEngine::new(c, Box::new(NativeMac))
    }

    fn syn(s: u32, t: u32, w: u8, d: u16, inh: bool) -> Synapse {
        Synapse {
            source: s,
            target: t,
            weight: w,
            delay: d,
            syn_type: if inh { SynapseType::Inhibitory } else { SynapseType::Excitatory },
        }
    }

    #[test]
    fn delay_one_arrives_next_step() {
        let mut e = engine_for(vec![syn(0, 1, 10, 1, false)], 2, 3);
        assert_eq!(e.step_currents(&[0]), [0.0, 0.0, 0.0]);
        assert_eq!(e.step_currents(&[]), [0.0, 5.0, 0.0]);
        assert_eq!(e.step_currents(&[]), [0.0, 0.0, 0.0]);
    }

    #[test]
    fn inhibition_is_negative() {
        let mut e = engine_for(vec![syn(0, 0, 6, 1, true)], 1, 1);
        e.step_currents(&[0]);
        assert_eq!(e.step_currents(&[]), [-3.0]);
    }

    #[test]
    fn delay_wraps_at_ring_boundary() {
        let mut e = engine_for(vec![syn(0, 0, 8, 4, false), syn(0, 1, 8, 1, false)], 1, 2);
        e.step_currents(&[0]);
        let mut hits = Vec::new();
        for t in 1..=5 {
            let c = e.step_currents(&[]);
            for (n, &v) in c.iter().enumerate() {
                if v != 0.0 {
                    hits.push((t, n, v));
                }
            }
        }
        assert_eq!(hits, vec![(1, 1, 4.0), (4, 0, 4.0)]);
    }

    #[test]
    fn macs_count_only_issued_work() {
        let mut e = engine_for(vec![syn(0, 0, 1, 1, false)], 4, 4);
        e.step_currents(&[]);
        assert_eq!(e.macs, 0, "a silent slot must not charge the MAC array");
        e.step_currents(&[0]);
        assert_eq!(e.macs, 0, "the spike lands one slot ahead");
        e.step_currents(&[]);
        assert!(e.macs > 0, "the populated slot issues real work");
        let total_cells: u64 = e
            .compiled
            .subordinates
            .iter()
            .map(|s| (s.n_rows() * s.n_cols()) as u64)
            .sum();
        assert!(e.macs <= total_cells, "issued {} > WDM cells {total_cells}", e.macs);
    }

    #[test]
    fn reset_replays_identically() {
        let mut e = engine_for(vec![syn(0, 1, 10, 2, false), syn(1, 0, 6, 1, true)], 2, 3);
        let run = |e: &mut ParallelLayerEngine| -> Vec<Vec<f32>> {
            let stim: [&[u32]; 4] = [&[0, 1], &[], &[1], &[]];
            stim.iter().map(|s| e.step_currents(s).to_vec()).collect()
        };
        let first = run(&mut e);
        e.reset();
        assert_eq!(e.timestep(), 0);
        let second = run(&mut e);
        assert_eq!(first, second, "reset must reproduce the run exactly");
    }

    #[test]
    fn checkpoint_restore_replays_in_flight_state() {
        let mut e = engine_for(vec![syn(0, 1, 10, 3, false), syn(1, 0, 6, 1, true)], 2, 3);
        e.step_currents(&[0, 1]);
        let ckpt = e.checkpoint();
        assert!(!ckpt.is_pristine(), "in-flight spikes must show in the snapshot");
        assert!(ckpt.byte_size() > 0);
        let tail = |e: &mut ParallelLayerEngine| -> Vec<Vec<f32>> {
            (0..4).map(|_| e.step_currents(&[]).to_vec()).collect()
        };
        let first = tail(&mut e);
        e.restore(&ckpt).unwrap();
        assert_eq!(e.timestep(), 1);
        assert_eq!(tail(&mut e), first, "restore must replay bit-identically");
        e.reset_to(5);
        assert!(e.checkpoint().is_pristine());
        assert_eq!(e.timestep(), 5);
        let mut other = engine_for(vec![syn(0, 0, 1, 1, false)], 1, 1);
        assert!(other.restore(&ckpt).is_err(), "foreign checkpoint must be refused");
    }

    #[test]
    fn words_path_matches_id_list_path() {
        use crate::rng::Rng;
        let mut syns = Vec::new();
        let mut rng = Rng::new(1213);
        for s in 0..60u32 {
            for _ in 0..4 {
                syns.push(syn(
                    s,
                    rng.below(50) as u32,
                    rng.below(9) as u8 + 1,
                    rng.below(5) as u16 + 1,
                    rng.chance(0.3),
                ));
            }
        }
        let mut by_ids = engine_for(syns.clone(), 60, 50);
        let mut by_words = engine_for(syns, 60, 50);
        let mut packed = SpikeWords::new(60);
        for t in 0..40 {
            let firing: Vec<u32> = (0..60).filter(|_| rng.chance(0.25)).collect();
            packed.fill_from_ids(&firing);
            let a = by_ids.step_currents(&firing).to_vec();
            let b = by_words.step_currents_words(&packed);
            assert_eq!(a, b, "t={t}");
        }
        assert_eq!(by_ids.macs, by_words.macs);
        assert_eq!(by_ids.spikes_in, by_words.spikes_in);
    }

    #[test]
    fn window_counters_track_recent_activity_and_reset_clears_them() {
        let mut e = engine_for(vec![syn(0, 1, 10, 1, false)], 2, 3);
        e.step_currents(&[0, 1]);
        e.step_currents(&[]);
        assert_eq!((e.window_spikes, e.window_steps), (2, 2));
        e.clear_window();
        assert_eq!((e.window_spikes, e.window_steps), (0, 0));
        e.step_currents(&[1]);
        assert_eq!((e.window_spikes, e.window_steps), (1, 1));
        assert_eq!((e.spikes_in, e.steps), (3, 3), "lifetime telemetry untouched");
        e.reset();
        assert_eq!((e.window_spikes, e.window_steps), (0, 0), "reset clears window");
        assert_eq!((e.spikes_in, e.steps), (3, 3), "reset preserves lifetime");
    }

    #[test]
    fn words_path_ignores_bits_beyond_table_range() {
        let mut e = engine_for(vec![syn(0, 0, 6, 1, false)], 2, 1);
        let mut s = SpikeWords::new(100);
        s.fill_from_ids(&[0, 50, 99]); // sources ≥ 2 have no merging entries
        e.step_currents_words(&s);
        assert_eq!(e.step_currents(&[]), [3.0]);
    }
}
