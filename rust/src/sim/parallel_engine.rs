//! Parallel (MAC-array) execution engine (paper §III-B runtime semantics).
//!
//! Per timestep `t`:
//! 1. the engine consumes the stacked-input slot `t mod D` — one lane per
//!    WDM row — and has every subordinate multiply its WDM chunk against
//!    its row span on a [`MacBackend`] (native or PJRT/Pallas);
//! 2. chunk results are reduced into per-target currents on the dominant
//!    PE (column chunks are disjoint; row chunks add up);
//! 3. this step's arriving spikes are pre-processed through the
//!    reversed-order + input-merging tables into future stacked slots.

use super::backend::MacBackend;
use crate::paradigm::parallel::ParallelCompiled;

/// Executes one parallel-compiled layer.
pub struct ParallelLayerEngine {
    compiled: ParallelCompiled,
    /// Stacked-input ring: `[slot][wdm row]`, spike counts as f32.
    ring: Vec<Vec<f32>>,
    /// Per-chunk weights pre-converted to f32 for the backend.
    chunk_weights: Vec<Vec<f32>>,
    backend: Box<dyn MacBackend>,
    t: u64,
    /// MAC multiply-accumulate operations issued (telemetry).
    pub macs: u64,
}

impl ParallelLayerEngine {
    pub fn new(compiled: ParallelCompiled, backend: Box<dyn MacBackend>) -> Self {
        let d = compiled.wdm.delay_range as usize;
        let rows = compiled.wdm.n_rows();
        let chunk_weights = compiled
            .subordinates
            .iter()
            .map(|s| s.weights.iter().map(|&w| w as f32).collect())
            .collect();
        ParallelLayerEngine {
            compiled,
            ring: vec![vec![0.0; rows]; d],
            chunk_weights,
            backend,
            t: 0,
            macs: 0,
        }
    }

    pub fn timestep(&self) -> u64 {
        self.t
    }

    pub fn backend_name(&self) -> &'static str {
        self.backend.name()
    }

    /// Advance one timestep (same contract as
    /// [`super::serial_engine::SerialLayerEngine::step_currents`]).
    pub fn step_currents(&mut self, spikes_in: &[u32]) -> Vec<f32> {
        let d = self.compiled.wdm.delay_range as usize;
        let t = self.t as usize;
        let slot = t % d;
        let scale = self.compiled.weight_scale;
        let mut currents = vec![0.0f32; self.compiled.n_target];

        // Phase 1: subordinate MAC matmuls over the due stacked slot.
        {
            let stacked = &self.ring[slot];
            for (sub, weights) in self.compiled.subordinates.iter().zip(&self.chunk_weights) {
                let rows = sub.n_rows();
                let cols = sub.n_cols();
                let out = self.backend.matvec(
                    &stacked[sub.row_lo..sub.row_hi],
                    weights,
                    rows,
                    cols,
                );
                self.macs += (rows * cols) as u64;
                // Reduce into global targets via the WDM column map.
                for (local, v) in out.into_iter().enumerate() {
                    if v != 0.0 {
                        let target = self.compiled.wdm.cols[sub.col_lo + local];
                        currents[target as usize] += v * scale;
                    }
                }
            }
        }
        self.ring[slot].fill(0.0);

        // Phase 2: dominant-PE spike preprocessing into future slots.
        for &src in spikes_in {
            for e in self.compiled.tables.entries_of(src) {
                let write_slot = (t + e.delay as usize) % d;
                self.ring[write_slot][e.row as usize] += 1.0;
            }
        }

        self.t += 1;
        currents
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hardware::PeSpec;
    use crate::model::{
        LifParams, PopulationId, Projection, ProjectionId, Synapse, SynapseType,
    };
    use crate::paradigm::parallel::{compile_parallel, WdmConfig};
    use crate::sim::backend::NativeMac;

    fn engine_for(synapses: Vec<Synapse>, n_src: usize, n_tgt: usize) -> ParallelLayerEngine {
        let proj = Projection {
            id: ProjectionId(0),
            source: PopulationId(0),
            target: PopulationId(1),
            synapses,
            weight_scale: 0.5,
        };
        let c = compile_parallel(
            &proj,
            n_src,
            n_tgt,
            LifParams::default(),
            &PeSpec::default(),
            WdmConfig::default(),
        )
        .unwrap();
        ParallelLayerEngine::new(c, Box::new(NativeMac))
    }

    fn syn(s: u32, t: u32, w: u8, d: u16, inh: bool) -> Synapse {
        Synapse {
            source: s,
            target: t,
            weight: w,
            delay: d,
            syn_type: if inh { SynapseType::Inhibitory } else { SynapseType::Excitatory },
        }
    }

    #[test]
    fn delay_one_arrives_next_step() {
        let mut e = engine_for(vec![syn(0, 1, 10, 1, false)], 2, 3);
        assert_eq!(e.step_currents(&[0]), vec![0.0, 0.0, 0.0]);
        assert_eq!(e.step_currents(&[]), vec![0.0, 5.0, 0.0]);
        assert_eq!(e.step_currents(&[]), vec![0.0, 0.0, 0.0]);
    }

    #[test]
    fn inhibition_is_negative() {
        let mut e = engine_for(vec![syn(0, 0, 6, 1, true)], 1, 1);
        e.step_currents(&[0]);
        assert_eq!(e.step_currents(&[]), vec![-3.0]);
    }

    #[test]
    fn delay_wraps_at_ring_boundary() {
        let mut e = engine_for(vec![syn(0, 0, 8, 4, false), syn(0, 1, 8, 1, false)], 1, 2);
        e.step_currents(&[0]);
        let mut hits = Vec::new();
        for t in 1..=5 {
            let c = e.step_currents(&[]);
            for (n, &v) in c.iter().enumerate() {
                if v != 0.0 {
                    hits.push((t, n, v));
                }
            }
        }
        assert_eq!(hits, vec![(1, 1, 4.0), (4, 0, 4.0)]);
    }

    #[test]
    fn macs_are_counted() {
        let mut e = engine_for(vec![syn(0, 0, 1, 1, false)], 4, 4);
        e.step_currents(&[]);
        assert!(e.macs > 0, "even empty steps run the MAC array");
    }
}
