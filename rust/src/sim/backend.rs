//! MAC-array execution backends.
//!
//! The subordinate-PE matmul (stacked spike vector × WDM chunk) can run on
//! a native Rust path or through the AOT-compiled JAX/Pallas artifact via
//! PJRT. Both operate on integer-valued f32 (spike counts and quantized
//! weights), so results are exactly equal as long as values stay below 2²⁴
//! — which the LIF regime guarantees by orders of magnitude.
//!
//! Backends write into caller-provided scratch ([`MacBackend::matvec_into`])
//! so the steady-state inference loop performs zero heap allocations, and
//! report the MAC operations they *actually issued* (sparsity-aware — silent
//! lanes are skipped), which is what the throughput benches charge.

/// The boxed backend slot a [`crate::sim::ParallelLayerEngine`] owns.
///
/// Default builds require `Send` so whole engines can cross into
/// [`crate::sim::NetworkSim::run_jobs`]'s scoped worker threads. The
/// `pjrt` feature relaxes the bound — its client is `Rc`-based and
/// single-threaded by construction — and in exchange that configuration
/// steps networks sequentially (`run_jobs` falls back to `run`).
#[cfg(not(feature = "pjrt"))]
pub type BackendBox = Box<dyn MacBackend + Send>;
#[cfg(feature = "pjrt")]
pub type BackendBox = Box<dyn MacBackend>;

/// A backend that can run the MAC-array matvec.
pub trait MacBackend {
    /// `out[c] = Σ_r stacked[r] · weights[r · n_cols + c]`
    ///
    /// `stacked` has `n_rows` entries; `weights` is row-major
    /// `n_rows × n_cols`; `out` has `n_cols` entries and is fully
    /// overwritten (the caller does not need to zero it).
    ///
    /// Returns the number of multiply-accumulate operations actually issued
    /// — sparse backends skip all-zero input lanes, so this can be far below
    /// `n_rows · n_cols`. Bucket/tile padding is excluded: only logical
    /// `rows × cols` work is counted, keeping MACs/s comparable across
    /// backends.
    fn matvec_into(
        &mut self,
        out: &mut [f32],
        stacked: &[f32],
        weights: &[f32],
        n_rows: usize,
        n_cols: usize,
    ) -> u64;

    /// Allocating convenience wrapper around [`MacBackend::matvec_into`]
    /// (tests and one-shot callers; the simulation hot path uses scratch).
    fn matvec(&mut self, stacked: &[f32], weights: &[f32], n_rows: usize, n_cols: usize)
        -> Vec<f32> {
        let mut out = vec![0.0f32; n_cols];
        self.matvec_into(&mut out, stacked, weights, n_rows, n_cols);
        out
    }

    /// Backend label for logs/benches.
    fn name(&self) -> &'static str;

    /// Which inner-loop implementation this backend runs (`"scalar"`,
    /// `"simd"`, …) — so bench/profile output is attributable to a kernel.
    fn kernel_variant(&self) -> &'static str {
        "scalar"
    }
}

/// `out[c] += s · row[c]` — the scalar MAC inner loop (always compiled; the
/// bit-identity oracle for [`axpy_simd`] and the kernel benches' baseline).
#[inline]
fn axpy_scalar(out: &mut [f32], row: &[f32], s: f32) {
    for (o, &w) in out.iter_mut().zip(row) {
        *o += s * w;
    }
}

/// `out[c] += s · row[c]` on 16-lane f32 vectors. Bit-identical to
/// [`axpy_scalar`]: each lane performs the same separate multiply-then-add
/// (`std::simd` never contracts to FMA), and the sub-vector tail runs the
/// scalar loop.
#[cfg(feature = "simd")]
#[inline]
fn axpy_simd(out: &mut [f32], row: &[f32], s: f32) {
    use std::simd::prelude::*;
    const LANES: usize = 16;
    let sv = f32x16::splat(s);
    let n_full = (out.len().min(row.len()) / LANES) * LANES;
    let mut c = 0usize;
    while c < n_full {
        let ov = f32x16::from_slice(&out[c..c + LANES]);
        let wv = f32x16::from_slice(&row[c..c + LANES]);
        (ov + sv * wv).copy_to_slice(&mut out[c..c + LANES]);
        c += LANES;
    }
    axpy_scalar(&mut out[n_full..], &row[n_full..], s);
}

/// The scalar-reference matvec with [`MacBackend::matvec_into`] semantics
/// (out fully overwritten, silent lanes skipped, issued MACs returned) —
/// always compiled, so benches and the equivalence tests can compare the
/// dispatched kernel against it under any feature set.
pub fn matvec_into_scalar(
    out: &mut [f32],
    stacked: &[f32],
    weights: &[f32],
    n_rows: usize,
    n_cols: usize,
) -> u64 {
    assert_eq!(stacked.len(), n_rows);
    assert_eq!(weights.len(), n_rows * n_cols);
    assert_eq!(out.len(), n_cols);
    out.fill(0.0);
    let mut issued = 0u64;
    for (r, &s) in stacked.iter().enumerate() {
        if s == 0.0 {
            continue; // stacked input is sparse: skip silent lanes
        }
        axpy_scalar(out, &weights[r * n_cols..(r + 1) * n_cols], s);
        issued += n_cols as u64;
    }
    issued
}

/// Plain Rust matvec — the default backend. The per-row MAC inner loop is
/// explicit 16-lane `std::simd` under the `simd` feature (bit-identical to
/// the scalar loop — see [`matvec_into_scalar`]); issued-MAC accounting is
/// shared between both variants.
#[derive(Default)]
pub struct NativeMac;

impl MacBackend for NativeMac {
    fn matvec_into(
        &mut self,
        out: &mut [f32],
        stacked: &[f32],
        weights: &[f32],
        n_rows: usize,
        n_cols: usize,
    ) -> u64 {
        #[cfg(not(feature = "simd"))]
        {
            matvec_into_scalar(out, stacked, weights, n_rows, n_cols)
        }
        #[cfg(feature = "simd")]
        {
            assert_eq!(stacked.len(), n_rows);
            assert_eq!(weights.len(), n_rows * n_cols);
            assert_eq!(out.len(), n_cols);
            out.fill(0.0);
            let mut issued = 0u64;
            for (r, &s) in stacked.iter().enumerate() {
                if s == 0.0 {
                    continue; // stacked input is sparse: skip silent lanes
                }
                axpy_simd(out, &weights[r * n_cols..(r + 1) * n_cols], s);
                issued += n_cols as u64;
            }
            issued
        }
    }

    fn name(&self) -> &'static str {
        "native"
    }

    fn kernel_variant(&self) -> &'static str {
        crate::model::lif::kernel_variant()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matvec_matches_manual() {
        let mut b = NativeMac;
        // 3 rows × 2 cols.
        let w = vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0];
        let s = vec![1.0, 0.0, 2.0];
        let out = b.matvec(&s, &w, 3, 2);
        assert_eq!(out, vec![1.0 + 10.0, 2.0 + 12.0]);
    }

    #[test]
    fn matvec_into_overwrites_dirty_scratch() {
        let mut b = NativeMac;
        let w = vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0];
        let s = vec![1.0, 0.0, 2.0];
        let mut out = vec![f32::NAN; 2];
        b.matvec_into(&mut out, &s, &w, 3, 2);
        assert_eq!(out, vec![11.0, 14.0]);
    }

    #[test]
    fn issued_macs_skip_silent_lanes() {
        let mut b = NativeMac;
        let mut out = vec![0.0f32; 2];
        // 4 rows, 2 active → 2 × 2 cols issued, not 4 × 2.
        let issued = b.matvec_into(&mut out, &[1.0, 0.0, 2.0, 0.0], &[1.0; 8], 4, 2);
        assert_eq!(issued, 4);
        let none = b.matvec_into(&mut out, &[0.0; 4], &[1.0; 8], 4, 2);
        assert_eq!(none, 0);
    }

    #[test]
    fn zero_stacked_gives_zeros() {
        let mut b = NativeMac;
        let out = b.matvec(&[0.0; 4], &[1.0; 8], 4, 2);
        assert_eq!(out, vec![0.0, 0.0]);
    }

    #[test]
    #[should_panic]
    fn shape_mismatch_panics() {
        let mut b = NativeMac;
        b.matvec(&[1.0; 3], &[1.0; 5], 3, 2);
    }

    #[test]
    fn native_kernel_variant_matches_build_features() {
        let expected = if cfg!(feature = "simd") { "simd" } else { "scalar" };
        assert_eq!(NativeMac.kernel_variant(), expected);
    }

    /// The dispatched kernel must match the scalar reference bit-for-bit on
    /// random integer-valued inputs — across shapes that exercise full
    /// 16-lane vectors, scalar tails, and sub-vector rows. Under the default
    /// build this is trivially true (same code); under `--features simd` it
    /// is the matvec half of the SIMD bit-identity guarantee.
    #[test]
    fn dispatched_matvec_is_bit_identical_to_scalar() {
        use crate::prop::Prop;
        Prop::new("NativeMac::matvec_into ≡ scalar", 80).check(
            |g| {
                let n_rows = g.usize(1, 40);
                let n_cols = g.usize(1, 70);
                // Integer-valued f32: spike counts and quantized weights.
                let stacked = g.vec(n_rows, |g| {
                    if g.bool(0.4) {
                        0.0f32
                    } else {
                        g.usize(0, 4) as f32
                    }
                });
                let weights = g.vec(n_rows * n_cols, |g| g.i64(-8, 8) as f32);
                (n_rows, n_cols, stacked, weights)
            },
            |(n_rows, n_cols, stacked, weights)| {
                let mut native = NativeMac;
                let mut out = vec![f32::NAN; *n_cols];
                let issued = native.matvec_into(&mut out, stacked, weights, *n_rows, *n_cols);
                let mut oracle = vec![f32::NAN; *n_cols];
                let issued_ref =
                    matvec_into_scalar(&mut oracle, stacked, weights, *n_rows, *n_cols);
                issued == issued_ref
                    && out
                        .iter()
                        .zip(oracle.iter())
                        .all(|(a, b)| a.to_bits() == b.to_bits())
            },
        );
    }
}
