//! `s2switch` CLI — the leader entrypoint.
//!
//! Subcommands (hand-rolled parser; clap is not in the offline crate set):
//!
//! ```text
//! s2switch dataset  [--out data/dataset.csv] [--small] [--jobs N] [--artifact-dir PATH]
//! s2switch train    [--data data/dataset.csv] [--seeds 20] [--out data/adaboost.json]
//! s2switch decide   --src N --tgt N --density F --delay N [--model data/adaboost.json]
//!                   [--rate R] [--artifact-dir PATH]
//! s2switch compile  --src N --tgt N --density F --delay N [--mode serial|parallel|ideal|classifier]
//!                   [--machine WxH|light-board] [--strategy linear|chip-packed|balanced]
//!                   [--artifact-dir PATH]
//! s2switch simulate [--steps 200] [--batch S] [--pjrt] [--jobs N]
//!                   [--intra-jobs N] [--profile]
//!                   [--machine BxWxH|WxH|light-board] [--strategy S]
//!                   [--partition linear|traffic]
//!                   [--artifact-dir PATH]
//!                   [--adaptive] [--swap-window W] [--swap-patience K]
//!                   [--fault-map PATH] [--fault-seed N] [--fault-rate F]
//!                   [--record-csv PATH]      # demo 3-layer network
//! s2switch calibrate [--artifact-dir PATH] [--out FILE]
//! s2switch serve    [--addr HOST:PORT] [--networks DIR] [--artifact-dir PATH]
//!                   [--batch-window-us U] [--max-batch N] [--jobs N]
//!                   [--machine BxWxH|WxH|light-board] [--strategy S]
//!                   [--partition linear|traffic] [--require-warm]
//! ```
//!
//! `--jobs N` sets the worker-thread count (0 = one thread per CPU) for
//! dataset labeling, network compilation, batched simulation, and — when
//! the network has same-wave layers — intra-sample layer parallelism
//! ([`NetworkSim::run_jobs`]). `--intra-jobs N` sets the per-sample thread
//! count inside a `--batch` run (default 1). `--profile` prints a
//! per-phase wall-clock breakdown (ring readout / spike dispatch / LIF /
//! recording) from the engine telemetry on single-sample runs (provider
//! time is excluded — it belongs to the stimulus, not the simulator). `--batch S` runs S independent
//! stimulus samples through the
//! [`BatchRunner`](s2switch::sim::BatchRunner); every run ends with a
//! throughput report (steps/s, synaptic events/s, issued MACs/s) and a
//! per-layer observed-activity table feeding the runtime-informed
//! paradigm check.
//! `--machine WxH` sizes the chip grid (`light-board` = the 8×6 48-chip
//! SpiNNaker2 light board; `BxWxH` = a board array of B light-board-class
//! boards, each a WxH chip grid, simulated as one shard per board with
//! wave-boundary spike exchange); `--partition linear|traffic` picks how
//! populations are assigned to boards (traffic = minimize estimated
//! inter-board multicast hops); `--strategy` picks the PE placement
//! strategy.
//! Compile/simulate runs end with a placement utilization + NoC hop
//! summary sourced from the real [`Placement`](s2switch::switching::Placement).
//! `--artifact-dir PATH` attaches the persistent compiled-artifact store
//! (compile-once, serve-many): compiles and estimates are looked up on
//! disk before running and written back after, so a warm store boots the
//! same network with **zero** materializing compiles — `dataset`
//! relabeling, `compile`, and `simulate` all share it.
//! `calibrate` micro-benchmarks this host's real kernels (serial events/s,
//! parallel MACs/s, LIF neuron-steps/s) and persists the constants as
//! `calibration.json` next to the artifact store, stamped with the
//! measuring host's fingerprint and timestamp; a later `simulate
//! --artifact-dir` auto-loads them so the runtime-informed paradigm check
//! prices the tie-break in measured step seconds instead of abstract work
//! items, warning when they are stale (>30 days), from another host, or
//! from a different kernel variant.
//! `simulate --adaptive` routes the batch through the live re-switching
//! loop ([`run_adaptive`](s2switch::switching::SwitchingSystem::run_adaptive)):
//! every `--swap-window W` samples of windowed activity feed the
//! rate-aware decision, and after `--swap-patience K` consecutive losses a
//! layer's engine is hot-swapped between samples with zero recompiles (the
//! alternate form comes from the compile cache / artifact store). Combined
//! with `--fault-*` flags the same knobs drive the recovery loop's
//! boundary re-switching, where every swap is ratified by a
//! preference-aware re-admission before it lands.
//! `decide --rate R` runs the runtime-informed decision for one layer from
//! the CLI; with `--artifact-dir` it requires (and consumes) the stored
//! calibration, erroring out with a `calibrate` hint when none exists.
//! `serve` turns the pipeline into a long-lived daemon (DESIGN.md
//! §Serving): every network under `--networks DIR` (or the built-in demo
//! net) warm-boots from the artifact store as a co-tenant of one shared
//! machine, then inference requests arrive over a length-prefixed binary
//! socket protocol and are dynamically micro-batched (`--batch-window-us`,
//! `--max-batch`) onto persistent reset-between-requests engine pools —
//! responses are bit-identical to a one-shot `simulate` at any client
//! count. SIGINT/SIGTERM drains in-flight batches and exits 0.

use anyhow::{bail, ensure, Context, Result};
use s2switch::coordinator::{
    dataset_cached, dataset_cached_opts, load_switching_system, train_and_save_adaboost,
    train_roster,
};
use s2switch::dataset::SweepConfig;
use s2switch::hardware::PeSpec;
use s2switch::model::connector::{Connector, SynapseDraw};
use s2switch::model::{LayerCharacter, LifParams, NetworkBuilder};
use s2switch::rng::Rng;
use s2switch::sim::NetworkSim;
use s2switch::switching::{SwitchMode, SwitchingSystem};
use std::collections::BTreeMap;
use std::path::PathBuf;

/// Tiny flag parser: `--key value` pairs after the subcommand.
struct Args {
    flags: BTreeMap<String, String>,
}

impl Args {
    fn parse(argv: &[String]) -> Result<Args> {
        let mut flags = BTreeMap::new();
        let mut i = 0;
        while i < argv.len() {
            let k = &argv[i];
            if !k.starts_with("--") {
                bail!("unexpected argument '{k}' (flags are --key value)");
            }
            let key = k.trim_start_matches("--").to_string();
            // Boolean flags: next token missing or another flag.
            if i + 1 >= argv.len() || argv[i + 1].starts_with("--") {
                flags.insert(key, "true".into());
                i += 1;
            } else {
                flags.insert(key, argv[i + 1].clone());
                i += 2;
            }
        }
        Ok(Args { flags })
    }

    fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(String::as_str)
    }

    fn parse_or<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T>
    where
        T::Err: std::fmt::Display,
    {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|e| anyhow::anyhow!("--{key} {v}: {e}")),
        }
    }

    fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }
}

const USAGE: &str = "usage: s2switch <dataset|train|decide|compile|simulate|calibrate|serve> [flags]
  dataset   --out PATH --small --jobs N --artifact-dir PATH
            generate + label the sweep corpus
  train     --data PATH --seeds N --out PATH   train 12 classifiers, save AdaBoost
  decide    --src N --tgt N --density F --delay N --model PATH
            (--rate R: runtime-informed decision at observed firing rate R
            instead of the classifier; --artifact-dir PATH: price the
            tie-break with the stored calibration — an error, with a
            `calibrate` hint, when none exists there)
  compile   --src N --tgt N --density F --delay N --mode MODE
            --machine WxH|light-board --strategy linear|chip-packed|balanced
            --artifact-dir PATH
  simulate  --steps N --batch S --pjrt --jobs N --intra-jobs N --profile
            --record-csv PATH --machine BxWxH|WxH|light-board --strategy S
            --partition linear|traffic --artifact-dir PATH
            --adaptive --swap-window W --swap-patience K
            --fault-map PATH --fault-seed N --fault-rate F
            run the demo network end to end (--batch S: S stimulus samples
            through the BatchRunner; --intra-jobs N: per-sample layer
            parallelism; --profile: per-phase wall-clock breakdown plus the
            kernel variants and calibration constants in play;
            --record-csv: dump recorded spikes; --adaptive: live re-switch
            layer engines from windowed activity — the other paradigm must
            win W-sample windows K boundaries in a row, then the layer
            hot-swaps between samples with zero recompiles, printing one
            deterministic `swap:` line per event; any --fault-* flag routes
            the run through the fault-tolerant recovery loop — --fault-map
            loads pre-existing dead PEs/chips/degraded links, --fault-rate
            injects seeded mid-run PE deaths recovered by checkpointed
            re-placement from the artifact store; --adaptive composes with
            --fault-*: boundary swaps are ratified by preference-aware
            re-admission so they survive fault migrations)
  calibrate --artifact-dir PATH --out FILE
            micro-benchmark this host's kernels (serial events/s, parallel
            MACs/s, LIF neuron-steps/s) and persist the constants as
            calibration.json next to the artifact store, stamped with this
            host's fingerprint + timestamp; simulate auto-loads them for
            the runtime-informed paradigm check and warns when they are
            stale (>30 days), foreign, or from another kernel variant
  serve     --addr HOST:PORT --networks DIR --artifact-dir PATH
            --batch-window-us U --max-batch N --jobs N
            --machine BxWxH|WxH|light-board --strategy S
            --partition linear|traffic --require-warm
            long-lived inference daemon: warm-boot every *.json network in
            --networks DIR (default: the built-in demo net as tenant
            'demo') as co-tenants of one machine, then serve inference
            over the binary socket protocol with dynamic micro-batching
            (--batch-window-us U: accumulation window per tenant, 0 =
            batching off; --max-batch N: batch size cap; --jobs N:
            persistent engines per tenant; --require-warm: error out
            unless the boot had zero materializing compiles and >0 disk
            hits); SIGINT/SIGTERM drains in-flight work and exits 0
  (--jobs N: worker threads for compiling, batching and same-wave layer
   stepping, 0 = one per CPU;
   --machine WxH: chip grid, light-board = 8x6, BxWxH: B-board array of WxH
   grids — simulate runs one shard per board with wave-boundary spike
   exchange, partitioned by --partition linear|traffic (default traffic);
   compile/simulate print a placement utilization + NoC hop summary (with
   the on-board / board-link-crossing split) on exit;
   --artifact-dir PATH: persistent compiled-artifact store — compiles and
   estimates are served from disk when present and written back when not,
   so a warm store boots with zero materializing compiles)";

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = argv.first() else {
        println!("{USAGE}");
        return Ok(());
    };
    let args = Args::parse(&argv[1..])?;
    match cmd.as_str() {
        "dataset" => cmd_dataset(&args),
        "train" => cmd_train(&args),
        "decide" => cmd_decide(&args),
        "compile" => cmd_compile(&args),
        "simulate" => cmd_simulate(&args),
        "calibrate" => cmd_calibrate(&args),
        "serve" => cmd_serve(&args),
        other => bail!("unknown subcommand '{other}'\n{USAGE}"),
    }
}

fn cmd_dataset(args: &Args) -> Result<()> {
    let out = PathBuf::from(args.get("out").unwrap_or("data/dataset.csv"));
    let cfg = if args.has("small") { SweepConfig::small() } else { SweepConfig::default() };
    let jobs: usize = args.parse_or("jobs", 0)?;
    let artifact_dir = args.get("artifact-dir").map(PathBuf::from);
    let ds = dataset_cached_opts(&out, &cfg, jobs, artifact_dir.as_deref())?;
    let parallel_wins = ds.samples.iter().filter(|s| s.parallel_pes < s.serial_pes).count();
    println!(
        "dataset: {} layers → {} ({} favor parallel, {} favor serial)",
        ds.len(),
        out.display(),
        parallel_wins,
        ds.len() - parallel_wins
    );
    Ok(())
}

fn cmd_train(args: &Args) -> Result<()> {
    let data = PathBuf::from(args.get("data").unwrap_or("data/dataset.csv"));
    let out = PathBuf::from(args.get("out").unwrap_or("data/adaboost.json"));
    let seeds: usize = args.parse_or("seeds", 20)?;
    let cfg = if args.has("small") { SweepConfig::small() } else { SweepConfig::default() };
    let ds = dataset_cached(&data, &cfg)?;

    println!("training 12 classifiers × {seeds} seeds on {} layers…", ds.len());
    let scores = train_roster(&ds, seeds);
    let mut ranked: Vec<_> = scores.iter().collect();
    ranked.sort_by(|a, b| b.mean().partial_cmp(&a.mean()).unwrap());
    println!("{:<22} {:>7} {:>7} {:>7}", "classifier", "mean", "min", "max");
    for s in ranked {
        println!(
            "{:<22} {:>6.2}% {:>6.2}% {:>6.2}%",
            s.name,
            100.0 * s.mean(),
            100.0 * s.min(),
            100.0 * s.max()
        );
    }
    let acc = train_and_save_adaboost(&ds, 100, &out)?;
    println!("deployed AdaBoost → {} (held-out accuracy {:.2}%)", out.display(), 100.0 * acc);
    Ok(())
}

/// `--jobs N` (absent or 0 → one worker per CPU, resolved by the pipeline).
fn resolve_jobs(args: &Args) -> Result<usize> {
    args.parse_or("jobs", 0)
}

/// `--artifact-dir PATH`: attach the persistent compiled-artifact store
/// so compiles/estimates are served from disk when warm and written back
/// when cold.
fn attach_artifact_dir(args: &Args, sys: &mut SwitchingSystem) -> Result<()> {
    if let Some(dir) = args.get("artifact-dir") {
        sys.set_artifact_dir(std::path::Path::new(dir))?;
    }
    Ok(())
}

/// `--machine WxH` (chip grid), `--machine BxWxH` (a board array: B boards
/// of WxH chips each), or `--machine light-board` (the 8×6 48-chip
/// SpiNNaker2 light board). Absent → the single-chip default. Parsing and
/// its typed rejections live in [`MachineSpec::parse`].
fn parse_machine(args: &Args) -> Result<s2switch::hardware::MachineSpec> {
    use s2switch::hardware::MachineSpec;
    match args.get("machine") {
        None => Ok(MachineSpec::default()),
        Some(s) => MachineSpec::parse(s).with_context(|| format!("--machine {s}")),
    }
}

/// `--partition linear|traffic` — the board partitioner objective (default:
/// traffic — greedy traffic-weighted clustering; only consulted when
/// `--machine BxWxH` names more than one board).
fn parse_partition(args: &Args) -> Result<s2switch::graph::PartitionStrategy> {
    match args.get("partition") {
        None => Ok(s2switch::graph::PartitionStrategy::Traffic),
        Some(s) => s2switch::graph::PartitionStrategy::parse(s),
    }
}

/// `--strategy linear|chip-packed|balanced` (default: chip-packed — the
/// hop-minimizing group placer).
fn parse_strategy(args: &Args) -> Result<s2switch::hardware::PlacementStrategy> {
    match args.get("strategy") {
        None => Ok(s2switch::hardware::PlacementStrategy::ChipPacked),
        Some(s) => s2switch::hardware::PlacementStrategy::parse(s),
    }
}

/// The placement utilization/hop summary every compile/simulate run prints
/// on exit (ISSUE: sourced from the real `Placement`, not estimates).
fn print_placement_summary(adm: &s2switch::switching::NetworkAdmission) {
    let p = &adm.placement;
    let spec = p.machine.spec();
    let machine_desc = if spec.boards > 1 {
        format!("{} boards x {}x{} chips", spec.boards, spec.chips_x, spec.chips_y)
    } else {
        format!("{}x{} machine", spec.chips_x, spec.chips_y)
    };
    println!(
        "placement [{}]: {} PEs on {}/{} chips ({machine_desc}), {} B DTCM placed, \
         mean utilization {:.1}%",
        p.strategy,
        p.n_pes(),
        p.chips_used(),
        spec.chips(),
        p.placed_dtcm(),
        100.0 * p.machine.mean_utilization()
    );
    let hops = p.static_hops_split();
    println!(
        "routing: {} multicast entries, {} static inter-chip tree hops \
         ({} on-board + {} board-link crossings), {} capacity override(s)",
        p.routing.len(),
        p.static_tree_hops(),
        hops.on_board,
        hops.board_links,
        adm.capacity_overrides()
    );
}

fn layer_flags(args: &Args) -> Result<LayerCharacter> {
    Ok(LayerCharacter::new(
        args.parse_or("src", 255usize)?,
        args.parse_or("tgt", 255usize)?,
        args.parse_or("density", 0.5f64)?,
        args.parse_or("delay", 8u16)?,
    ))
}

fn cmd_decide(args: &Args) -> Result<()> {
    let ch = layer_flags(args)?;
    if args.has("rate") {
        return cmd_decide_rate(args, &ch);
    }
    let model = PathBuf::from(args.get("model").unwrap_or("data/adaboost.json"));
    let sys = load_switching_system(&model, PeSpec::default())
        .context("train a model first: s2switch train")?;
    let verdict = sys.prejudge(&ch)?.ok_or_else(|| {
        anyhow::anyhow!(
            "the loaded model produced no prejudgment for this layer — \
             retrain it (s2switch train) and pass the new --model"
        )
    })?;
    println!(
        "layer (src={}, tgt={}, density={:.2}, delay={}) → {}",
        ch.n_source, ch.n_target, ch.density, ch.delay_range, verdict
    );
    Ok(())
}

/// `decide --rate R`: the runtime-informed decision path — storage first,
/// rate-priced step seconds as the tie-break — for one layer, reachable
/// without running a simulation. With `--artifact-dir` the stored
/// calibration is *required* (a typed error points at `s2switch calibrate`
/// when it is absent); without it the abstract work-item model prices the
/// tie-break.
fn cmd_decide_rate(args: &Args, ch: &LayerCharacter) -> Result<()> {
    use s2switch::switching::{CompileJob, CompilePipeline, SwitchPolicy};
    let rate: f64 = args.parse_or("rate", 0.0)?;
    ensure!((0.0..=1.0).contains(&rate), "--rate {rate}: firing rate must be in [0, 1]");
    let calibration = match args.get("artifact-dir") {
        Some(dir) => {
            let rec = s2switch::calibrate::load_record_from_dir(std::path::Path::new(dir))?
                .ok_or_else(|| {
                    anyhow::anyhow!(
                        "no calibration constants in {dir} — run \
                         `s2switch calibrate --artifact-dir {dir}` first"
                    )
                })?;
            warn_calibration_provenance(&rec);
            Some(rec.constants)
        }
        None => None,
    };
    // Realize the layer so both estimates price real synapse content, the
    // same way `compile` and the dataset labeler do.
    let mut rng = Rng::new(args.parse_or("seed", 1u64)?);
    let proj = s2switch::dataset::realize_layer(
        ch.n_source,
        ch.n_target,
        ch.density,
        ch.delay_range,
        &mut rng,
    );
    let job = CompileJob::new(&proj, ch.n_source, ch.n_target, LifParams::default());
    let pipeline = CompilePipeline::new(
        PeSpec::default(),
        s2switch::paradigm::parallel::WdmConfig::default(),
    );
    let (s, p) = pipeline.estimate_pair(&job)?;
    let verdict =
        SwitchPolicy::decide_with_rate(&s, &p, &job.character, rate, calibration.as_ref());
    let tied = s.total_pes() == p.total_pes();
    println!(
        "layer (src={}, tgt={}, density={:.2}, delay={}) at rate {rate:.3} → {verdict}",
        ch.n_source, ch.n_target, ch.density, ch.delay_range
    );
    println!(
        "  storage: serial {} PEs vs parallel {} PEs{}",
        s.total_pes(),
        p.total_pes(),
        if tied { " (tie — runtime model decides)" } else { "" }
    );
    match &calibration {
        Some(c) => println!("  tie-break: calibrated step seconds ({} kernel)", c.kernel_variant),
        None => println!("  tie-break: abstract work items (no calibration loaded)"),
    }
    Ok(())
}

fn cmd_compile(args: &Args) -> Result<()> {
    let ch = layer_flags(args)?;
    let mode = match args.get("mode").unwrap_or("ideal") {
        "serial" => SwitchMode::ForceSerial,
        "parallel" => SwitchMode::ForceParallel,
        "ideal" => SwitchMode::Ideal,
        "classifier" => SwitchMode::Classifier,
        m => bail!("unknown mode '{m}'"),
    };
    let mut sys = if mode == SwitchMode::Classifier {
        let model = PathBuf::from(args.get("model").unwrap_or("data/adaboost.json"));
        load_switching_system(&model, PeSpec::default())?
    } else {
        SwitchingSystem::new(mode, PeSpec::default())
    };
    sys.set_jobs(resolve_jobs(args)?);
    attach_artifact_dir(args, &mut sys)?;
    let mspec = parse_machine(args)?;
    let strategy = parse_strategy(args)?;
    // Realize the layer as a one-projection network (source → target) so
    // the capacity-aware admission path can place it for real.
    let mut b = NetworkBuilder::new(args.parse_or("seed", 1u64)?);
    let src = b.spike_source("src", ch.n_source);
    let tgt = b.lif_population("tgt", ch.n_target, LifParams::default());
    b.project(
        src,
        tgt,
        Connector::FixedProbability(ch.density),
        SynapseDraw { delay_range: ch.delay_range, w_max: 127, ..Default::default() },
        0.01,
    );
    let net = b.build();
    let adm = sys.admit_network(&net, mspec, strategy)?;
    let layer = &adm.layers[0];
    let d = adm.decisions[0];
    println!(
        "compiled under {}{}: {} PEs, {} B DTCM total ({} compiles run, {} artifact hits)",
        layer.paradigm(),
        if d.overridden { " (capacity override)" } else { "" },
        layer.n_pes(),
        layer.total_dtcm(),
        sys.stats.total_compiles(),
        sys.stats.disk_hits
    );
    print_placement_summary(&adm);
    Ok(())
}

/// `s2switch calibrate`: micro-benchmark the host's real kernels and
/// persist the measured constants where `simulate` will find them
/// (`--out FILE` wins; otherwise `<--artifact-dir>/calibration.json`,
/// defaulting the directory to `data`).
fn cmd_calibrate(args: &Args) -> Result<()> {
    let out = match args.get("out") {
        Some(p) => PathBuf::from(p),
        None => s2switch::calibrate::path_in(std::path::Path::new(
            args.get("artifact-dir").unwrap_or("data"),
        )),
    };
    println!(
        "calibrating host kernels (LIF kernel: {})…",
        s2switch::model::lif::kernel_variant()
    );
    let c = s2switch::calibrate::measure();
    s2switch::calibrate::save(&out, &c)?;
    println!(
        "measured: {:.2} Mevents/s serial | {:.2} MMAC/s parallel | \
         {:.2} Mneuron-steps/s LIF",
        c.serial_events_per_sec / 1e6,
        c.parallel_macs_per_sec / 1e6,
        c.lif_neuron_steps_per_sec / 1e6
    );
    println!("constants → {}", out.display());
    Ok(())
}

/// Warn when loaded calibration constants should not be trusted blind:
/// measured on a different kernel variant, on another host, or too long
/// ago ([`STALE_AFTER_SECS`](s2switch::calibrate::STALE_AFTER_SECS)). The
/// run proceeds either way — the warning tells the user to re-run
/// `s2switch calibrate`, it does not block.
fn warn_calibration_provenance(rec: &s2switch::calibrate::CalibrationRecord) {
    let built = s2switch::model::lif::kernel_variant();
    if rec.constants.kernel_variant != built {
        println!(
            "warning: calibration constants were measured on the `{}` kernel \
             but this binary runs `{built}` — re-run `s2switch calibrate`",
            rec.constants.kernel_variant
        );
    }
    let here = s2switch::calibrate::host_fingerprint();
    if rec.host != here {
        println!(
            "warning: calibration constants were measured on `{}` but this host \
             is `{here}` — re-run `s2switch calibrate`",
            rec.host
        );
    }
    let now = s2switch::calibrate::now_unix_secs();
    if rec.is_stale(now) {
        if rec.measured_unix_secs == 0 {
            println!(
                "warning: calibration constants carry no measurement timestamp — \
                 re-run `s2switch calibrate`"
            );
        } else {
            println!(
                "warning: calibration constants are {} day(s) old (stale after {}) — \
                 re-run `s2switch calibrate`",
                rec.age_secs(now) / 86_400,
                s2switch::calibrate::STALE_AFTER_SECS / 86_400
            );
        }
    }
}

/// The built-in 3-layer demo network (`simulate` without `--config`;
/// `serve` without `--networks` hosts it as tenant "demo").
fn demo_network() -> s2switch::model::Network {
    let mut b = NetworkBuilder::new(11);
    let inp = b.spike_source("input", 200);
    let hid = b.lif_population("hidden", 120, LifParams { alpha: 0.85, ..Default::default() });
    let out = b.lif_population("output", 20, LifParams { alpha: 0.9, ..Default::default() });
    b.project(
        inp,
        hid,
        Connector::FixedProbability(0.4),
        SynapseDraw { delay_range: 4, w_max: 100, ..Default::default() },
        0.015,
    );
    b.project(
        hid,
        out,
        Connector::FixedProbability(0.9),
        SynapseDraw { delay_range: 2, w_max: 100, ..Default::default() },
        0.02,
    );
    b.build()
}

fn cmd_simulate(args: &Args) -> Result<()> {
    let steps: u64 = args.parse_or("steps", 200)?;
    // --config FILE loads a JSON network description; otherwise a built-in
    // demo network is used.
    let net = if let Some(path) = args.get("config") {
        let text = std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
        s2switch::model::config::network_from_json(&text)?
    } else {
        demo_network()
    };

    let rate: f64 = args.parse_or("rate", 0.15)?;

    let mut sys = SwitchingSystem::new(SwitchMode::Ideal, PeSpec::default());
    sys.set_jobs(resolve_jobs(args)?);
    attach_artifact_dir(args, &mut sys)?;
    // Host calibration constants live next to the artifact store; when
    // present they re-price the runtime-informed paradigm check in measured
    // step seconds (run `s2switch calibrate` to produce them).
    // A corrupt or implausible calibration file must not poison paradigm
    // decisions: warn and fall back to the static cost formulas.
    let calibration = match args.get("artifact-dir") {
        Some(dir) => match s2switch::calibrate::load_record_from_dir(std::path::Path::new(dir)) {
            Ok(Some(rec)) => {
                // Provenance checks: never silently trust stale or foreign
                // constants (the decision still runs — forewarned).
                warn_calibration_provenance(&rec);
                Some(rec.constants)
            }
            Ok(None) => None,
            Err(e) => {
                println!("warning: ignoring calibration constants ({e:#}); using static formulas");
                None
            }
        },
        None => None,
    };
    // --machine BxWxH (boards > 1) routes through the sharded driver: the
    // traffic-aware partitioner assigns populations to boards, admission
    // plans against per-board headroom, and one NetworkSim shard per board
    // runs with spike-word exchange at wave boundaries.
    let mspec = parse_machine(args)?;
    if mspec.boards > 1 {
        ensure!(
            !args.has("fault-map") && !args.has("fault-seed") && !args.has("fault-rate"),
            "--fault-* recovery is single-board for now (drop the BxWxH --machine)"
        );
        ensure!(!args.has("adaptive"), "--adaptive re-switching is single-board for now");
        ensure!(args.parse_or("batch", 0usize)? == 0, "--batch is single-board for now");
        ensure!(!args.has("pjrt"), "sharded runs use the native backend");
        ensure!(!args.has("profile"), "--profile applies to single-board runs");
        return simulate_sharded(args, &net, &mut sys, steps, rate, mspec);
    }
    // Any --fault-* flag routes through the fault-tolerant recovery loop
    // (checkpoint at sample boundaries, re-admit + re-place survivors,
    // replay — DESIGN.md §Fault-Tolerance). --adaptive composes: the
    // recovery loop evaluates boundary swaps with the same knobs.
    if args.has("fault-map") || args.has("fault-seed") || args.has("fault-rate") {
        return simulate_faulted(args, &net, &mut sys, steps, rate);
    }
    // --adaptive without faults: the live re-switching loop.
    if args.has("adaptive") {
        return simulate_adaptive(args, &net, &mut sys, steps, rate, calibration);
    }

    // Capacity-aware admission: prejudge → feasibility check → compile →
    // place + route on the requested machine (Fig. 2's tail).
    let adm = sys.admit_network(&net, parse_machine(args)?, parse_strategy(args)?)?;
    for (i, l) in adm.layers.iter().enumerate() {
        println!(
            "layer {i}: {}{} ({} PEs, compiled in {:.2?})",
            l.paradigm(),
            if adm.decisions[i].overridden { " [capacity override]" } else { "" },
            l.n_pes(),
            std::time::Duration::from_nanos(adm.layer_nanos[i])
        );
    }
    println!(
        "compiled {} layers on {} worker(s) in {:.2?} \
         ({} compiles, {} cache hits, {} artifact hits)",
        adm.layers.len(),
        sys.jobs(),
        std::time::Duration::from_nanos(adm.wall_nanos),
        adm.stats.total_compiles(),
        adm.stats.cache_hits,
        adm.stats.disk_hits
    );
    print_placement_summary(&adm);
    let layers = adm.layers;
    let placement = adm.placement;

    // The layer characters feed the runtime-informed activity report after
    // the run (layers themselves move into the sim).
    let characters: Vec<s2switch::model::LayerCharacter> =
        layers.iter().map(|l| *l.character()).collect();

    // Sample `s` draws its stimulus from a seed derived with a golden-ratio
    // stride, so batch results are a pure function of the sample index.
    let sizes: Vec<usize> = net.populations.iter().map(|p| p.n_neurons).collect();
    let stimulus_for = |sample: usize| {
        let sizes = sizes.clone();
        let mut rng = Rng::new(99u64.wrapping_add(sample as u64 * 0x9E37_79B9_7F4A_7C15));
        move |p: s2switch::model::PopulationId, _t: u64, out: &mut Vec<u32>| {
            out.extend((0..sizes[p.0] as u32).filter(|_| rng.chance(rate)));
        }
    };
    let record_path = args.get("record-csv").or_else(|| args.get("record"));

    let batch: usize = args.parse_or("batch", 0)?;
    if batch > 0 {
        ensure!(
            !args.has("pjrt"),
            "--batch runs on the native backend (the PJRT client is single-threaded)"
        );
        ensure!(
            !args.has("profile"),
            "--profile applies to single-sample runs (batch workers own their sims); \
             drop --batch to get the phase breakdown"
        );
        let runner = s2switch::sim::BatchRunner::new(&net, layers)?
            .with_jobs(resolve_jobs(args)?)
            .with_intra_jobs(args.parse_or("intra-jobs", 1)?);
        let run = runner.run(batch, steps, stimulus_for);
        for (i, rec) in run.recorders.iter().enumerate() {
            println!(
                "sample {i:>3}: {:>6} spikes in {:.2?}",
                rec.total_spikes(),
                std::time::Duration::from_nanos(run.sample_nanos[i])
            );
        }
        println!(
            "batch: {} samples × {} steps on {} worker(s) in {:.2?}",
            run.n_samples(),
            steps,
            run.jobs,
            std::time::Duration::from_nanos(run.wall_nanos),
        );
        print_throughput(run.steps_per_sec(), run.events_per_sec(), run.macs_per_sec());
        // Same histogram utility the serve daemon reports with.
        let mut hist =
            s2switch::bench_harness::LatencyHistogram::from_nanos(run.sample_nanos.iter().copied());
        println!("sample latency: {}", hist.summary());
        if let Some(out) = record_path {
            // One CSV per sample: PATH gains a `.sN` suffix before `.csv`.
            for (i, rec) in run.recorders.iter().enumerate() {
                let path = sample_csv_path(out, i);
                rec.save_spikes_csv(&path)?;
            }
            println!("spikes exported to {out} (one file per sample, `.sN` suffix)");
        }
        return Ok(());
    }

    let mut sim = build_sim(args.has("pjrt"), &net, layers)?;
    if args.has("profile") {
        sim.set_profile(true);
    }
    let t0 = std::time::Instant::now();
    let mut provider = stimulus_for(0);
    // PJRT backends are single-threaded by construction; everything else
    // may exploit same-wave layer parallelism.
    if args.has("pjrt") {
        sim.run(steps, &mut provider);
    } else {
        sim.run_jobs(steps, &mut provider, resolve_jobs(args)?);
    }
    let dt = t0.elapsed();
    println!(
        "simulated {steps} steps in {:.2?} ({:.0} steps/s)",
        dt,
        steps as f64 / dt.as_secs_f64()
    );
    for pop in &net.populations {
        if pop.record_spikes {
            println!("  {}: {} spikes", pop.label, sim.recorder.spike_count(pop.id));
        }
    }
    let secs = dt.as_secs_f64();
    print_throughput(
        steps as f64 / secs,
        sim.total_events() as f64 / secs,
        sim.total_macs() as f64 / secs,
    );
    print_activity_report(&sim, &characters, calibration.as_ref());
    if args.has("profile") {
        print_phase_profile(&sim.phase_profile());
        print_kernel_report(&sim, calibration.as_ref());
    }
    // NoC traffic estimate for the recorded activity.
    let noc = placement
        .estimate_traffic(&s2switch::switching::placement::spike_counts(&sim.recorder));
    println!("NoC estimate: {} multicast packets, {} inter-chip hops", noc.packets, noc.hops);

    if let Some(out) = record_path {
        sim.recorder.save_spikes_csv(std::path::Path::new(out))?;
        println!("spikes exported to {out}");
    }
    Ok(())
}

/// `simulate --machine BxWxH` (boards > 1): partition → per-board
/// admission → sharded placement → one [`ShardedSim`](s2switch::sim::ShardedSim)
/// shard per board with wave-boundary spike exchange. The stimulus seed and
/// per-neuron draw order match the single-board path, so recorded spike
/// counts are comparable across `--machine` values (and identical when the
/// model is identical — the determinism the shard test suite pins down).
fn simulate_sharded(
    args: &Args,
    net: &s2switch::model::Network,
    sys: &mut SwitchingSystem,
    steps: u64,
    rate: f64,
    mspec: s2switch::hardware::MachineSpec,
) -> Result<()> {
    let pstrat = parse_partition(args)?;
    let sharded = sys.admit_network_sharded(net, mspec, parse_strategy(args)?, pstrat)?;
    let adm = &sharded.admission;
    for (i, l) in adm.layers.iter().enumerate() {
        println!(
            "layer {i}: {}{} on board {} ({} PEs, compiled in {:.2?})",
            l.paradigm(),
            if adm.decisions[i].overridden { " [capacity override]" } else { "" },
            sharded.assignment.board_of_layer[i],
            l.n_pes(),
            std::time::Duration::from_nanos(adm.layer_nanos[i])
        );
    }
    print_placement_summary(adm);
    let cap = mspec.pes_per_board();
    for (b, d) in sharded.assignment.board_demand(&sharded.demand).iter().enumerate() {
        println!(
            "board {b}: {d}/{cap} PEs estimated demand ({:.1}% of board capacity)",
            100.0 * *d as f64 / cap as f64
        );
    }
    println!(
        "partition [{pstrat}]: {} boards, {} estimated inter-board cut hops",
        sharded.assignment.boards,
        sharded.assignment.cut_hops(net)
    );

    let mut sim = s2switch::sim::ShardedSim::new(net, &adm.layers, &sharded.assignment)?;
    let sizes: Vec<usize> = net.populations.iter().map(|p| p.n_neurons).collect();
    let mut rng = Rng::new(99);
    let mut provider = move |p: s2switch::model::PopulationId, _t: u64, out: &mut Vec<u32>| {
        out.extend((0..sizes[p.0] as u32).filter(|_| rng.chance(rate)));
    };
    let t0 = std::time::Instant::now();
    sim.run_jobs(steps, &mut provider, resolve_jobs(args)?);
    let dt = t0.elapsed();
    println!(
        "simulated {steps} steps on {} shard(s) in {:.2?} ({:.0} steps/s)",
        sim.n_shards(),
        dt,
        steps as f64 / dt.as_secs_f64()
    );
    let recorder = sim.merged_recorder();
    for pop in &net.populations {
        if pop.record_spikes {
            println!("  {}: {} spikes", pop.label, recorder.spike_count(pop.id));
        }
    }
    let secs = dt.as_secs_f64();
    print_throughput(
        steps as f64 / secs,
        sim.total_events() as f64 / secs,
        sim.total_macs() as f64 / secs,
    );
    if let Some(out) = args.get("record-csv").or_else(|| args.get("record")) {
        recorder.save_spikes_csv(std::path::Path::new(out))?;
        println!("spikes exported to {out}");
    }
    Ok(())
}

/// `simulate --fault-*`: run the stimulus samples through the recovery
/// loop instead of the plain simulator. `--batch S` sets the sample count
/// (default 1); each sample runs `--steps` timesteps. Output ends with the
/// deterministic [`RecoveryStats`](s2switch::switching::RecoveryStats)
/// line the CI chaos check compares across runs.
fn simulate_faulted(
    args: &Args,
    net: &s2switch::model::Network,
    sys: &mut SwitchingSystem,
    steps: u64,
    rate: f64,
) -> Result<()> {
    use s2switch::hardware::FaultMap;
    use s2switch::switching::RecoveryConfig;
    ensure!(!args.has("pjrt"), "--fault-* runs on the native backend");
    ensure!(
        !args.has("profile"),
        "--profile applies to plain single-sample runs (recovery rebuilds the sim mid-run)"
    );
    let initial_faults = match args.get("fault-map") {
        Some(path) => FaultMap::load(std::path::Path::new(path))?,
        None => FaultMap::healthy(),
    };
    let samples = args.parse_or("batch", 1u64)?.max(1);
    let adaptive = args.has("adaptive");
    let cfg = RecoveryConfig {
        samples,
        steps_per_sample: steps,
        fault_seed: args.parse_or("fault-seed", 7u64)?,
        fault_rate: args.parse_or("fault-rate", 0.0f64)?,
        initial_faults,
        swap_window: if adaptive { args.parse_or("swap-window", 2usize)? } else { 0 },
        swap_patience: if adaptive { args.parse_or("swap-patience", 2usize)? } else { 0 },
    };
    println!(
        "fault-tolerant run: {} sample(s) × {} steps, {} pre-dead PE(s), \
         {} pre-dead chip(s), fault rate {} (seed {})",
        cfg.samples,
        cfg.steps_per_sample,
        cfg.initial_faults.n_dead_pes(),
        cfg.initial_faults.n_dead_chips(),
        cfg.fault_rate,
        cfg.fault_seed
    );
    let sizes: Vec<usize> = net.populations.iter().map(|p| p.n_neurons).collect();
    let provider_for = |sample: u64| {
        let sizes = sizes.clone();
        let mut rng = Rng::new(99u64.wrapping_add(sample * 0x9E37_79B9_7F4A_7C15));
        move |p: s2switch::model::PopulationId, _t: u64, out: &mut Vec<u32>| {
            out.extend((0..sizes[p.0] as u32).filter(|_| rng.chance(rate)));
        }
    };
    let report = sys.run_fault_tolerant(
        net,
        parse_machine(args)?,
        parse_strategy(args)?,
        &cfg,
        provider_for,
    )?;
    for (i, rec) in report.recorders.iter().enumerate() {
        println!("sample {i:>3}: {:>6} spikes", rec.total_spikes());
    }
    for (i, status) in report.layer_status.iter().enumerate() {
        println!("layer {i}: {status}");
    }
    // One deterministic line per executed hot-swap (wall-clock is reported
    // separately: these lines are what the CI determinism diff compares).
    for w in &report.swaps {
        println!(
            "swap: sample={} layer={} {}->{} rate={:.4}",
            w.sample, w.layer, w.from, w.to, w.window_rate
        );
    }
    if adaptive {
        println!(
            "adaptive: {} swap(s) (window {}, patience {})",
            report.swaps.len(),
            cfg.swap_window,
            cfg.swap_patience
        );
    }
    println!("recovery: {}", report.stats);
    println!(
        "compiles: {} run, {} cache hits, {} artifact hits",
        report.compile.total_compiles(),
        report.compile.cache_hits,
        report.compile.disk_hits
    );
    if let Some(err) = &report.degraded {
        println!("degraded: {err}");
    }
    Ok(())
}

/// `simulate --adaptive`: drive the batch through the live re-switching
/// loop. `--batch S` sets the sample count (default 8), `--steps` the
/// timesteps per sample; `--swap-window W` / `--swap-patience K` tune the
/// hysteresis state machine. Prints one deterministic `swap:` line per
/// executed hot-swap (the CI determinism diff compares these across two
/// fixed-seed runs) plus a latency/compile summary.
fn simulate_adaptive(
    args: &Args,
    net: &s2switch::model::Network,
    sys: &mut SwitchingSystem,
    steps: u64,
    rate: f64,
    calibration: Option<s2switch::costmodel::CalibrationConstants>,
) -> Result<()> {
    use s2switch::switching::AdaptiveConfig;
    ensure!(!args.has("pjrt"), "--adaptive runs on the native backend");
    ensure!(
        !args.has("profile"),
        "--profile applies to plain single-sample runs (adaptive swaps engines mid-run)"
    );
    let calibrated = calibration.is_some();
    let cfg = AdaptiveConfig {
        samples: args.parse_or("batch", 8u64)?.max(1),
        steps_per_sample: steps,
        swap_window: args.parse_or("swap-window", 2usize)?,
        swap_patience: args.parse_or("swap-patience", 2usize)?,
        jobs: args.parse_or("intra-jobs", 1usize)?,
        calibration,
    };
    let (layers, _) = sys.compile_network(net)?;
    let initial: Vec<_> = layers.iter().map(|l| l.paradigm()).collect();
    println!(
        "adaptive run: {} sample(s) × {} steps (window {}, patience {}, {} tie-break)",
        cfg.samples,
        cfg.steps_per_sample,
        cfg.swap_window,
        cfg.swap_patience,
        if calibrated { "calibrated" } else { "abstract" }
    );
    let sizes: Vec<usize> = net.populations.iter().map(|p| p.n_neurons).collect();
    let provider_for = |sample: u64| {
        let sizes = sizes.clone();
        let mut rng = Rng::new(99u64.wrapping_add(sample * 0x9E37_79B9_7F4A_7C15));
        move |p: s2switch::model::PopulationId, _t: u64, out: &mut Vec<u32>| {
            out.extend((0..sizes[p.0] as u32).filter(|_| rng.chance(rate)));
        }
    };
    let report = sys.run_adaptive(net, layers, &cfg, provider_for)?;
    for (i, rec) in report.recorders.iter().enumerate() {
        println!("sample {i:>3}: {:>6} spikes", rec.total_spikes());
    }
    for w in &report.swaps {
        println!(
            "swap: sample={} layer={} {}->{} rate={:.4}",
            w.sample, w.layer, w.from, w.to, w.window_rate
        );
    }
    for (i, (a, b)) in initial.iter().zip(&report.paradigms).enumerate() {
        println!("layer {i}: {a} → {b}{}", if a == b { " (kept)" } else { " (re-switched)" });
    }
    let mean_ns = if report.swaps.is_empty() {
        0
    } else {
        report.swaps.iter().map(|w| w.swap_nanos).sum::<u64>() / report.swaps.len() as u64
    };
    println!(
        "adaptive: {} swap(s) over {} sample(s) in {:.2?}, mean swap latency {:.2?}",
        report.swaps.len(),
        report.recorders.len(),
        std::time::Duration::from_nanos(report.wall_nanos),
        std::time::Duration::from_nanos(mean_ns)
    );
    println!(
        "compiles: {} run, {} cache hits, {} artifact hits",
        report.compile.total_compiles(),
        report.compile.cache_hits,
        report.compile.disk_hits
    );
    Ok(())
}

/// Per-layer observed activity + the runtime-informed paradigm check: the
/// telemetry loop from execution back into the cost model
/// (`costmodel::activity`).
fn print_activity_report(
    sim: &NetworkSim,
    characters: &[s2switch::model::LayerCharacter],
    cal: Option<&s2switch::costmodel::CalibrationConstants>,
) {
    match cal {
        Some(_) => println!("observed activity (runtime-informed cost model, calibrated):"),
        None => println!("observed activity (runtime-informed cost model):"),
    }
    for a in sim.layer_activity() {
        let ch = &characters[a.proj];
        let rate = a.firing_rate();
        let preferred = match cal {
            Some(c) => s2switch::costmodel::activity::runtime_preferred_calibrated(
                ch,
                rate,
                c,
                s2switch::costmodel::DEFAULT_HYSTERESIS_MARGIN,
            ),
            None => s2switch::costmodel::activity::runtime_preferred(ch, rate),
        };
        let agrees = if preferred == a.paradigm { "✓" } else { "≠" };
        println!(
            "  layer {}: rate {rate:.3} | {} events, {} issued MACs | compiled {} \
             | runtime model prefers {preferred} {agrees}",
            a.proj, a.events, a.macs, a.paradigm
        );
    }
}

/// The `--profile` per-phase breakdown (engine phases are CPU time summed
/// across engines and, under `--jobs`, across worker threads).
fn print_phase_profile(p: &s2switch::sim::PhaseProfile) {
    let total = p.total_nanos().max(1) as f64;
    let row = |name: &str, ns: u64| {
        println!(
            "  {name:<14} {:>9.2} ms  ({:>4.1}%)",
            ns as f64 / 1e6,
            100.0 * ns as f64 / total
        );
    };
    println!("phase breakdown (cumulative CPU time):");
    row("ring readout", p.readout_nanos);
    row("spike dispatch", p.dispatch_nanos);
    row("LIF update", p.lif_nanos);
    row("recording", p.record_nanos);
}

/// The `--profile` kernel report: which LIF / MAC-backend kernel variants
/// actually ran (simd vs scalar, pjrt-aot under `--pjrt`) and the
/// calibration constants the activity report priced the tie-break with.
fn print_kernel_report(sim: &NetworkSim, cal: Option<&s2switch::costmodel::CalibrationConstants>) {
    let backends = sim.backend_kernel_variants();
    let backend_list = if backends.is_empty() {
        "none (all layers serial)".to_string()
    } else {
        backends.join(", ")
    };
    println!(
        "kernels: LIF `{}` | MAC backend [{}]",
        s2switch::model::lif::kernel_variant(),
        backend_list
    );
    match cal {
        Some(c) => println!(
            "calibration ({} kernel): {:.2} Mevents/s serial | {:.2} MMAC/s parallel | \
             {:.2} Mneuron-steps/s LIF",
            c.kernel_variant,
            c.serial_events_per_sec / 1e6,
            c.parallel_macs_per_sec / 1e6,
            c.lif_neuron_steps_per_sec / 1e6
        ),
        None => println!(
            "calibration: none loaded (run `s2switch calibrate --artifact-dir PATH` \
             and pass the same --artifact-dir here)"
        ),
    }
}

/// The exit throughput report every `simulate` run prints.
fn print_throughput(steps_s: f64, events_s: f64, macs_s: f64) {
    println!(
        "throughput: {:.0} steps/s | {:.2} Mevents/s | {:.2} MMAC/s (issued)",
        steps_s,
        events_s / 1e6,
        macs_s / 1e6
    );
}

/// `out.csv` + sample 3 → `out.s3.csv` (extensionless paths just append).
fn sample_csv_path(out: &str, sample: usize) -> std::path::PathBuf {
    let p = std::path::Path::new(out);
    match (p.file_stem().and_then(|s| s.to_str()), p.extension().and_then(|e| e.to_str())) {
        (Some(stem), Some(ext)) => p.with_file_name(format!("{stem}.s{sample}.{ext}")),
        _ => std::path::PathBuf::from(format!("{out}.s{sample}")),
    }
}

#[cfg(feature = "pjrt")]
fn build_sim(
    pjrt: bool,
    net: &s2switch::model::Network,
    layers: Vec<s2switch::switching::CompiledLayer>,
) -> Result<NetworkSim> {
    if pjrt {
        use std::cell::RefCell;
        use std::rc::Rc;
        let rt = Rc::new(RefCell::new(s2switch::runtime::PjrtRuntime::new(
            s2switch::runtime::artifact_dir(),
        )?));
        NetworkSim::new(net, layers, || {
            Box::new(s2switch::runtime::PjrtMac::new(rt.clone()))
        })
    } else {
        NetworkSim::native(net, layers)
    }
}

#[cfg(not(feature = "pjrt"))]
fn build_sim(
    pjrt: bool,
    net: &s2switch::model::Network,
    layers: Vec<s2switch::switching::CompiledLayer>,
) -> Result<NetworkSim> {
    ensure!(
        !pjrt,
        "this binary was built without the `pjrt` feature; rebuild with \
         `cargo build --features pjrt` (requires the vendored `xla` crate)"
    );
    NetworkSim::native(net, layers)
}

/// `serve` owns its run parameters: per-sample knobs travel in each wire
/// request, one-shot output flags have no serving analogue. Reject the
/// incompatible `simulate` flags up front, each with a hint at the serving
/// way to get the same effect (mirrors the sharded-path guards above).
fn validate_serve_flags(args: &Args) -> Result<()> {
    let rejected: &[(&str, &str)] = &[
        ("batch", "serve batches dynamically; tune --batch-window-us / --max-batch instead"),
        ("record-csv", "serve returns spike counts on the wire; use `simulate --record-csv`"),
        ("record", "serve returns spike counts on the wire; use `simulate --record-csv`"),
        ("steps", "steps travel in each request, not on the daemon"),
        ("rate", "the stimulus rate travels in each request, not on the daemon"),
        ("seed", "the stimulus seed travels in each request, not on the daemon"),
        ("config", "serve hosts a directory of networks; use --networks DIR"),
        ("pjrt", "serve runs persistent native engine pools only"),
        ("profile", "--profile applies to single-sample `simulate` runs"),
        ("intra-jobs", "serve parallelizes across requests; --jobs sizes the engine pools"),
        ("adaptive", "--adaptive re-switching is a `simulate` loop, not a serving mode"),
        ("swap-window", "--swap-window belongs to `simulate --adaptive`"),
        ("swap-patience", "--swap-patience belongs to `simulate --adaptive`"),
        ("fault-map", "fault recovery is a `simulate` mode for now"),
        ("fault-seed", "fault recovery is a `simulate` mode for now"),
        ("fault-rate", "fault recovery is a `simulate` mode for now"),
    ];
    for (flag, hint) in rejected {
        ensure!(!args.has(flag), "serve does not take --{flag} ({hint})");
    }
    Ok(())
}

/// `--networks DIR`: every `*.json` file is one tenant network, named by
/// its file stem, loaded in sorted order (the registry re-sorts anyway, so
/// admission is directory-order independent). No flag → the built-in demo
/// network as tenant "demo".
fn load_tenant_specs(args: &Args) -> Result<Vec<s2switch::serve::TenantSpec>> {
    use s2switch::serve::TenantSpec;
    let Some(dir) = args.get("networks") else {
        return Ok(vec![TenantSpec { name: "demo".into(), net: demo_network() }]);
    };
    let mut paths: Vec<std::path::PathBuf> = std::fs::read_dir(dir)
        .with_context(|| format!("reading --networks {dir}"))?
        .collect::<std::io::Result<Vec<_>>>()?
        .into_iter()
        .map(|e| e.path())
        .filter(|p| p.extension().and_then(|e| e.to_str()) == Some("json"))
        .collect();
    paths.sort();
    ensure!(!paths.is_empty(), "--networks {dir} holds no .json network files");
    let mut specs = Vec::with_capacity(paths.len());
    for path in paths {
        let name = path
            .file_stem()
            .and_then(|s| s.to_str())
            .map(str::to_string)
            .with_context(|| format!("non-UTF-8 network file name {}", path.display()))?;
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        let net = s2switch::model::config::network_from_json(&text)
            .with_context(|| format!("parsing tenant network {}", path.display()))?;
        specs.push(TenantSpec { name, net });
    }
    Ok(specs)
}

/// The long-lived inference daemon (DESIGN.md §Serving): warm-boot every
/// tenant network from the artifact store onto one shared machine, serve
/// micro-batched inference over the socket protocol until SIGINT/SIGTERM,
/// then drain and print the serving summary.
fn cmd_serve(args: &Args) -> Result<()> {
    validate_serve_flags(args)?;
    let addr = args.get("addr").unwrap_or("127.0.0.1:7272").to_string();
    let cfg = s2switch::serve::ServeConfig {
        batch_window_us: args.parse_or("batch-window-us", 200)?,
        max_batch: args.parse_or("max-batch", 16)?,
        jobs: resolve_jobs(args)?,
    };

    let mut sys = SwitchingSystem::new(SwitchMode::Ideal, PeSpec::default());
    sys.set_jobs(cfg.jobs);
    attach_artifact_dir(args, &mut sys)?;

    let specs = load_tenant_specs(args)?;
    let registry = s2switch::serve::TenantRegistry::boot(
        specs,
        &mut sys,
        parse_machine(args)?,
        parse_strategy(args)?,
        parse_partition(args)?,
    )?;
    for t in &registry.tenants {
        println!(
            "tenant {:<16} {} layers [{}] on {} PEs",
            t.name,
            t.layers.len(),
            t.layers.iter().map(|l| l.paradigm().to_string()).collect::<Vec<_>>().join(", "),
            t.pes.len()
        );
    }
    let report = &registry.report;
    println!(
        "boot: {} tenant(s) in {:.2?} — {} compiles, {} cache hits, {} artifact hits; \
         {}/{} PEs occupied ({})",
        report.tenants,
        std::time::Duration::from_nanos(report.boot_nanos),
        report.compiles,
        report.cache_hits,
        report.disk_hits,
        report.placed_pes,
        report.machine_pes,
        if report.is_warm() { "warm" } else { "cold" }
    );
    if args.has("require-warm") {
        ensure!(
            report.is_warm(),
            "--require-warm: boot ran {} materializing compile(s) with {} artifact hit(s); \
             pre-warm the store with `compile`/`simulate --artifact-dir` first",
            report.compiles,
            report.disk_hits
        );
    }

    s2switch::serve::install_signal_handlers();
    let server = s2switch::serve::Server::bind(registry, &addr, cfg)?;
    println!(
        "serving on {} (window {}µs, max batch {}, {} engine(s)/tenant); \
         SIGINT/SIGTERM drains and exits",
        server.local_addr()?,
        cfg.batch_window_us,
        cfg.max_batch,
        if cfg.jobs == 0 { "cpu".to_string() } else { cfg.jobs.to_string() }
    );
    let report = server.run()?;

    let mut m = report.metrics;
    println!(
        "served {} request(s): {} ok, {} error ({} protocol), {} shutdown-refused, \
         {} truncated frame(s)",
        m.requests,
        m.ok_responses,
        m.error_responses,
        m.protocol_errors,
        m.shutdown_responses,
        m.truncated_frames
    );
    if m.batches > 0 {
        println!(
            "batching: {} batch(es), mean size {:.2}, histogram {:?}",
            m.batches,
            m.mean_batch(),
            m.batch_size_counts
        );
        println!("latency: {}", m.latency.summary());
    }
    println!("drained and stopped cleanly");
    Ok(())
}
