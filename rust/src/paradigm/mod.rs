//! The two SNN compilation paradigms (paper §III).
//!
//! * [`serial`] — ARM-processor paradigm: event-based synaptic processing
//!   driven by a master population table, address list and synaptic-matrix
//!   blocks; time-triggered LIF update (sPyNNaker lineage, ref [14]).
//! * [`parallel`] — MAC-array paradigm: a dominant PE pre-processes spikes
//!   into a stacked input that subordinate PEs multiply against an
//!   optimized weight-delay-map (refs [7][8]).
//!
//! Both compile a [`crate::model::Projection`]-defined layer into loadable
//! per-PE programs, report their DTCM footprint per Table I, and are
//! executable by [`crate::sim`]. The [`Paradigm`] enum is the switching
//! system's decision alphabet.
//!
//! The [`ParadigmCompiler`] trait (DESIGN.md §1) unifies the two compile
//! entry points behind one object-safe interface with **two tiers**:
//!
//! * [`ParadigmCompiler::estimate`] — shape-only PE/DTCM accounting, the
//!   path the 16k-layer dataset labeler runs 32,000 times (it never needs
//!   per-PE programs, only counts);
//! * [`ParadigmCompiler::compile`] — full per-PE program materialization,
//!   the path real network deployment runs.
//!
//! Both tiers are implemented from the same cost-model/splitting code so
//! `estimate(job).layer_pes == compile(job).n_pes()` by construction; the
//! labeler and the real compiler can no longer diverge.

pub mod parallel;
pub mod serial;

use crate::costmodel::parallel::dominant_cost;
use crate::costmodel::serial::serial_layout;
use crate::hardware::PeSpec;
use crate::model::{LayerCharacter, LifParams, Projection};
use anyhow::{ensure, Context, Result};
use self::parallel::splitting::two_stage_split;
use self::parallel::wdm::build_wdm_shape;
use self::parallel::{compile_parallel, ParallelCompiled, WdmConfig};
use self::serial::{compile_serial, SerialCompiled};

/// Which paradigm a layer is compiled under — the classifier's label space.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Paradigm {
    Serial,
    Parallel,
}

impl Paradigm {
    /// Label encoding used by the dataset/classifiers (serial=0, parallel=1).
    pub fn label(self) -> usize {
        match self {
            Paradigm::Serial => 0,
            Paradigm::Parallel => 1,
        }
    }

    pub fn from_label(label: usize) -> Paradigm {
        if label == 0 {
            Paradigm::Serial
        } else {
            Paradigm::Parallel
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Paradigm::Serial => "serial",
            Paradigm::Parallel => "parallel",
        }
    }

    /// The other paradigm — the capacity-feasibility fallback partner.
    pub fn other(self) -> Paradigm {
        match self {
            Paradigm::Serial => Paradigm::Parallel,
            Paradigm::Parallel => Paradigm::Serial,
        }
    }
}

impl std::fmt::Display for Paradigm {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// A compiled layer under whichever paradigm was selected.
#[derive(Clone, Debug, PartialEq)]
pub enum CompiledLayer {
    Serial(SerialCompiled),
    Parallel(ParallelCompiled),
}

impl CompiledLayer {
    pub fn paradigm(&self) -> Paradigm {
        match self {
            CompiledLayer::Serial(_) => Paradigm::Serial,
            CompiledLayer::Parallel(_) => Paradigm::Parallel,
        }
    }

    pub fn n_pes(&self) -> usize {
        match self {
            CompiledLayer::Serial(c) => c.n_pes(),
            CompiledLayer::Parallel(c) => c.n_pes(),
        }
    }

    pub fn total_dtcm(&self) -> usize {
        match self {
            CompiledLayer::Serial(c) => c.total_dtcm(),
            CompiledLayer::Parallel(c) => c.total_dtcm(),
        }
    }

    pub fn character(&self) -> &LayerCharacter {
        match self {
            CompiledLayer::Serial(c) => &c.character,
            CompiledLayer::Parallel(c) => &c.character,
        }
    }

    /// Cost summary of a materialized layer, in the same units
    /// [`ParadigmCompiler::estimate`] reports — so Ideal-mode decisions made
    /// *after* compiling both and labeler decisions made *before* compiling
    /// anything feed identical numbers into [`CostEstimate`] comparisons.
    pub fn cost_estimate(&self, pe: &PeSpec) -> CostEstimate {
        let (source_hosting_pes, source_hosting_dtcm) = match self {
            CompiledLayer::Serial(c) => source_hosting_cost(c.character.n_source, pe),
            CompiledLayer::Parallel(_) => (0, 0),
        };
        CostEstimate {
            paradigm: self.paradigm(),
            layer_pes: self.n_pes(),
            source_hosting_pes,
            dtcm_bytes: self.total_dtcm(),
            source_hosting_dtcm,
        }
    }
}

/// PEs and DTCM bytes needed to *host* a serial layer's source population:
/// `ceil(n_source/255)` PEs, each carrying one 32-bit word per hosted
/// neuron plus the OS reserve (the same accounting
/// `switching::Placement` materializes for source-host vertices).
fn source_hosting_cost(n_source: usize, pe: &PeSpec) -> (usize, usize) {
    let hosts = n_source.div_ceil(pe.serial_neuron_cap);
    (hosts, 4 * n_source + pe.os_reserve_bytes * hosts)
}

/// Shape-only cost of compiling one layer under one paradigm.
///
/// The serial paradigm additionally charges `ceil(n_source/255)` PEs to host
/// the source population (sPyNNaker maps input populations to cores); the
/// parallel paradigm absorbs source handling into the dominant PE's
/// input-spike buffer (§III-B) and charges nothing. [`CostEstimate::total_pes`]
/// is the quantity every serial-vs-parallel comparison in the system ranks.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct CostEstimate {
    pub paradigm: Paradigm,
    /// PEs occupied by the layer itself (serial layout PEs, or the parallel
    /// dominant + subordinates).
    pub layer_pes: usize,
    /// Extra PEs charged for hosting the source population.
    pub source_hosting_pes: usize,
    /// Cost-model DTCM bytes across the layer's PEs.
    pub dtcm_bytes: usize,
    /// DTCM bytes the source-hosting PEs would load (0 for parallel — the
    /// dominant absorbs source handling). Together with `dtcm_bytes` this
    /// is the whole-machine footprint the capacity-feasibility stage
    /// charges against remaining headroom.
    pub source_hosting_dtcm: usize,
}

impl CostEstimate {
    /// The PE count the switching decision compares.
    pub fn total_pes(&self) -> usize {
        self.layer_pes + self.source_hosting_pes
    }

    /// Whole-machine DTCM footprint: layer PEs plus source hosting.
    pub fn total_dtcm(&self) -> usize {
        self.dtcm_bytes + self.source_hosting_dtcm
    }

    /// The runtime-informed tier: this paradigm's per-timestep work on a
    /// layer at the given source firing rate (observed via
    /// [`crate::sim::LayerActivity::firing_rate`] or assumed), in the
    /// [`crate::costmodel::activity`] model's work-item units. Storage
    /// ([`CostEstimate::total_pes`]) stays the primary decision axis; this
    /// closes the telemetry loop for rate-dependent comparisons
    /// ([`crate::switching::SwitchPolicy::decide_with_rate`]).
    pub fn step_cost(&self, ch: &LayerCharacter, rate: f64) -> f64 {
        crate::costmodel::activity::step_cost(self.paradigm, ch, rate)
    }
}

/// One layer's compile input: the realized projection plus the population
/// sizes and target-neuron parameters the compilers need.
///
/// `character` is the 4-factor character the estimator (and prejudger) sees.
/// [`LayerJob::new`] measures it from the projection; the dataset labeler
/// overrides it with the *nominal* sweep coordinates via
/// [`LayerJob::with_character`] (the classifier must see pre-compilation
/// numbers, exactly as it will at deployment time).
#[derive(Clone, Copy, Debug)]
pub struct LayerJob<'a> {
    pub proj: &'a Projection,
    pub character: LayerCharacter,
    pub n_source: usize,
    pub n_target: usize,
    pub params: LifParams,
}

impl<'a> LayerJob<'a> {
    pub fn new(
        proj: &'a Projection,
        n_source: usize,
        n_target: usize,
        params: LifParams,
    ) -> Self {
        LayerJob {
            proj,
            character: LayerCharacter::of_projection(proj, n_source, n_target),
            n_source,
            n_target,
            params,
        }
    }

    /// Override the measured character (dataset labeling uses the nominal
    /// sweep coordinates).
    pub fn with_character(mut self, character: LayerCharacter) -> Self {
        self.character = character;
        self
    }
}

/// One paradigm's compiler, object-safe so the switching system can hold
/// and dispatch over `&dyn ParadigmCompiler`.
pub trait ParadigmCompiler: Send + Sync {
    fn paradigm(&self) -> Paradigm;

    /// Shape-only cost estimate: PE count and cost-model DTCM bytes without
    /// materializing any per-PE program. This is the dataset labeler's path
    /// (and the cheap half of an Ideal-mode comparison).
    fn estimate(&self, job: &LayerJob<'_>, pe: &PeSpec) -> Result<CostEstimate>;

    /// Full materialization: per-PE loadable programs, executable by
    /// [`crate::sim`].
    fn compile(&self, job: &LayerJob<'_>, pe: &PeSpec) -> Result<CompiledLayer>;
}

/// The serial (ARM, event-driven) paradigm behind [`ParadigmCompiler`].
#[derive(Clone, Copy, Debug, Default)]
pub struct SerialCompiler;

impl ParadigmCompiler for SerialCompiler {
    fn paradigm(&self) -> Paradigm {
        Paradigm::Serial
    }

    fn estimate(&self, job: &LayerJob<'_>, pe: &PeSpec) -> Result<CostEstimate> {
        let layout = serial_layout(&job.character, pe)
            .context("layer does not fit the machine under the serial paradigm")?;
        let (source_hosting_pes, source_hosting_dtcm) = source_hosting_cost(job.n_source, pe);
        Ok(CostEstimate {
            paradigm: Paradigm::Serial,
            layer_pes: layout.n_pes(),
            source_hosting_pes,
            dtcm_bytes: layout.total_dtcm(),
            source_hosting_dtcm,
        })
    }

    fn compile(&self, job: &LayerJob<'_>, pe: &PeSpec) -> Result<CompiledLayer> {
        Ok(CompiledLayer::Serial(compile_serial(
            job.proj,
            job.n_source,
            job.n_target,
            job.params,
            pe,
        )?))
    }
}

/// The parallel (MAC-array) paradigm behind [`ParadigmCompiler`].
#[derive(Clone, Copy, Debug, Default)]
pub struct ParallelCompiler {
    pub config: WdmConfig,
}

impl ParallelCompiler {
    pub fn new(config: WdmConfig) -> Self {
        ParallelCompiler { config }
    }
}

impl ParadigmCompiler for ParallelCompiler {
    fn paradigm(&self) -> Paradigm {
        Paradigm::Parallel
    }

    fn estimate(&self, job: &LayerJob<'_>, pe: &PeSpec) -> Result<CostEstimate> {
        let n_source_vertex = job.n_source.div_ceil(pe.serial_neuron_cap);
        let dom = dominant_cost(
            job.n_source,
            job.n_target,
            job.character.delay_range as usize,
            n_source_vertex,
        );
        ensure!(
            dom.total() <= pe.dtcm_bytes,
            "dominant PE overflows DTCM ({} B > {} B); layer outside supported envelope",
            dom.total(),
            pe.dtcm_bytes
        );
        // Shape-only WDM: PE counting never touches the weight block.
        let wdm = build_wdm_shape(job.proj, job.n_source, job.n_target, self.config);
        let plan = two_stage_split(&wdm, pe, n_source_vertex)
            .context("weight-delay-map cannot be split to fit any PE")?;
        let dtcm_bytes =
            dom.total() + plan.chunks.iter().map(|c| c.dtcm_bytes).sum::<usize>();
        Ok(CostEstimate {
            paradigm: Paradigm::Parallel,
            layer_pes: 1 + plan.n_subordinates(),
            source_hosting_pes: 0,
            dtcm_bytes,
            source_hosting_dtcm: 0,
        })
    }

    fn compile(&self, job: &LayerJob<'_>, pe: &PeSpec) -> Result<CompiledLayer> {
        Ok(CompiledLayer::Parallel(compile_parallel(
            job.proj,
            job.n_source,
            job.n_target,
            job.params,
            pe,
            self.config,
        )?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::connector::{Connector, SynapseDraw};
    use crate::model::{PopulationId, ProjectionId};
    use crate::rng::Rng;

    #[test]
    fn label_roundtrip() {
        assert_eq!(Paradigm::from_label(Paradigm::Serial.label()), Paradigm::Serial);
        assert_eq!(Paradigm::from_label(Paradigm::Parallel.label()), Paradigm::Parallel);
    }

    #[test]
    fn display_names() {
        assert_eq!(Paradigm::Serial.to_string(), "serial");
        assert_eq!(Paradigm::Parallel.to_string(), "parallel");
    }

    fn proj(n_src: usize, n_tgt: usize, d: f64, dl: u16, seed: u64) -> Projection {
        let mut rng = Rng::new(seed);
        Projection {
            id: ProjectionId(0),
            source: PopulationId(0),
            target: PopulationId(1),
            synapses: Connector::FixedProbability(d).build(
                n_src,
                n_tgt,
                SynapseDraw { delay_range: dl, w_max: 127, ..Default::default() },
                &mut rng,
            ),
            weight_scale: 1.0,
        }
    }

    #[test]
    fn estimate_matches_compile_pe_counts() {
        // The two tiers must never disagree: shape-only estimates and fully
        // materialized layers report identical PE counts on the same job.
        let pe = PeSpec::default();
        for (ns, nt, d, dl, seed) in
            [(100, 100, 0.5, 4, 1), (255, 255, 1.0, 1, 2), (300, 200, 0.2, 16, 3)]
        {
            let p = proj(ns, nt, d, dl, seed);
            let job = LayerJob::new(&p, ns, nt, LifParams::default());
            let compilers: [&dyn ParadigmCompiler; 2] =
                [&SerialCompiler, &ParallelCompiler::new(WdmConfig::default())];
            for c in compilers {
                let est = c.estimate(&job, &pe).unwrap();
                let full = c.compile(&job, &pe).unwrap();
                assert_eq!(est.paradigm, c.paradigm());
                assert_eq!(est.layer_pes, full.n_pes(), "{} PE count", c.paradigm());
                assert_eq!(full.cost_estimate(&pe).total_pes(), est.total_pes());
            }
        }
    }

    #[test]
    fn serial_estimate_charges_source_hosting() {
        let pe = PeSpec::default();
        let p = proj(300, 100, 0.3, 4, 7);
        let job = LayerJob::new(&p, 300, 100, LifParams::default());
        let s = SerialCompiler.estimate(&job, &pe).unwrap();
        assert_eq!(s.source_hosting_pes, 2, "300 sources need 2 hosting PEs");
        // DTCM tier: one word per hosted neuron plus the OS reserve per host.
        assert_eq!(s.source_hosting_dtcm, 4 * 300 + 2 * pe.os_reserve_bytes);
        assert_eq!(s.total_dtcm(), s.dtcm_bytes + s.source_hosting_dtcm);
        let par = ParallelCompiler::new(WdmConfig::default()).estimate(&job, &pe).unwrap();
        assert_eq!(par.source_hosting_pes, 0, "parallel absorbs source handling");
        assert_eq!(par.source_hosting_dtcm, 0);
    }
}
