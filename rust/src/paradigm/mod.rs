//! The two SNN compilation paradigms (paper §III).
//!
//! * [`serial`] — ARM-processor paradigm: event-based synaptic processing
//!   driven by a master population table, address list and synaptic-matrix
//!   blocks; time-triggered LIF update (sPyNNaker lineage, ref [14]).
//! * [`parallel`] — MAC-array paradigm: a dominant PE pre-processes spikes
//!   into a stacked input that subordinate PEs multiply against an
//!   optimized weight-delay-map (refs [7][8]).
//!
//! Both compile a [`crate::model::Projection`]-defined layer into loadable
//! per-PE programs, report their DTCM footprint per Table I, and are
//! executable by [`crate::sim`]. The [`Paradigm`] enum is the switching
//! system's decision alphabet.

pub mod parallel;
pub mod serial;

/// Which paradigm a layer is compiled under — the classifier's label space.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Paradigm {
    Serial,
    Parallel,
}

impl Paradigm {
    /// Label encoding used by the dataset/classifiers (serial=0, parallel=1).
    pub fn label(self) -> usize {
        match self {
            Paradigm::Serial => 0,
            Paradigm::Parallel => 1,
        }
    }

    pub fn from_label(label: usize) -> Paradigm {
        if label == 0 {
            Paradigm::Serial
        } else {
            Paradigm::Parallel
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Paradigm::Serial => "serial",
            Paradigm::Parallel => "parallel",
        }
    }
}

impl std::fmt::Display for Paradigm {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn label_roundtrip() {
        assert_eq!(Paradigm::from_label(Paradigm::Serial.label()), Paradigm::Serial);
        assert_eq!(Paradigm::from_label(Paradigm::Parallel.label()), Paradigm::Parallel);
    }

    #[test]
    fn display_names() {
        assert_eq!(Paradigm::Serial.to_string(), "serial");
        assert_eq!(Paradigm::Parallel.to_string(), "parallel");
    }
}
