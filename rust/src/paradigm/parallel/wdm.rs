//! The optimized weight-delay-map (WDM).
//!
//! Logical form: a matrix `W[(source, delay) row][target column]` such that
//! the synaptic input of target `c` at timestep `t` is
//! `Σ_rows stacked[t][row] · W[row][c]`, where `stacked[t][(s, δ)] = 1` iff
//! source `s` fired at `t − δ`. Stored dense so the MAC array can consume
//! it; the four optimization strategies attack the zero-padding and sparsity
//! memory weaknesses the paper attributes to refs [7][8]:
//!
//! * **S1 zero-row elimination** — only (source, delay) pairs that carry at
//!   least one synapse get a row (realization-dependent, which is exactly
//!   why Table I says the WDM size "can't be accurately estimated").
//! * **S2 zero-column elimination** — targets with no synapses get no
//!   column.
//! * **S3 delay-slot merging** — rows of all delay slots share one
//!   contiguous matrix, so MAC alignment padding is paid once instead of
//!   once per delay block.
//! * **S4 8-bit quantization** — signed 8-bit weights (type folded into the
//!   sign) instead of 16-bit operands.
//!
//! Each strategy can be disabled individually for the ablation bench.

use crate::hardware::MacArraySpec;
use crate::model::{Projection, Synapse, SynapseType};

/// Strategy toggles + MAC geometry.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct WdmConfig {
    pub zero_row_elimination: bool,
    pub zero_col_elimination: bool,
    pub delay_slot_merging: bool,
    pub quantize_8bit: bool,
    pub mac: MacArraySpec,
}

impl Default for WdmConfig {
    fn default() -> Self {
        WdmConfig {
            zero_row_elimination: true,
            zero_col_elimination: true,
            delay_slot_merging: true,
            quantize_8bit: true,
            mac: MacArraySpec::default(),
        }
    }
}

impl WdmConfig {
    /// All strategies disabled — the naive dense baseline.
    pub fn naive() -> Self {
        WdmConfig {
            zero_row_elimination: false,
            zero_col_elimination: false,
            delay_slot_merging: false,
            quantize_8bit: false,
            mac: MacArraySpec::default(),
        }
    }

    /// Bytes per stored weight under S4.
    pub fn bytes_per_weight(&self) -> usize {
        if self.quantize_8bit {
            1
        } else {
            2
        }
    }
}

/// A WDM row key: one (source, delay) lane of the stacked input.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub struct RowKey {
    /// Delay first: rows are delay-major so one delay slot's rows are
    /// contiguous (what the stacked-input writer wants).
    pub delay: u16,
    pub source: u32,
}

/// The built weight-delay-map (logical, unpadded).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Wdm {
    pub rows: Vec<RowKey>,
    /// Kept target columns (projection-local target ids).
    pub cols: Vec<u32>,
    /// Dense row-major weights, `rows.len() × cols.len()`, signed:
    /// excitatory positive, inhibitory negative.
    pub weights: Vec<i16>,
    pub config: WdmConfig,
    /// Full delay range of the layer (stacked-input ring depth).
    pub delay_range: u16,
}

impl Wdm {
    pub fn n_rows(&self) -> usize {
        self.rows.len()
    }

    pub fn n_cols(&self) -> usize {
        self.cols.len()
    }

    #[inline]
    pub fn weight(&self, row: usize, col: usize) -> i16 {
        self.weights[row * self.cols.len() + col]
    }

    /// Stored bytes of the weight block for a chunk of `r` rows × `c` cols,
    /// honoring alignment (S3) and quantization (S4). The contraction
    /// dimension (rows) aligns to the MAC's 16-lane input side, the output
    /// dimension (cols) to its 4-lane output side.
    ///
    /// `rows_per_delay` is only consulted when S3 is off: each delay block
    /// pads separately.
    pub fn weight_block_bytes(&self, r: usize, c: usize, rows_per_delay: &[usize]) -> usize {
        let mac = self.config.mac;
        let c_pad = mac.align_rows(c);
        let bpw = self.config.bytes_per_weight();
        if self.config.delay_slot_merging {
            mac.align_cols(r) * c_pad * bpw
        } else {
            rows_per_delay
                .iter()
                .map(|&rd| mac.align_cols(rd) * c_pad * bpw)
                .sum()
        }
    }

    /// Row counts per delay slot (for unmerged padding accounting).
    pub fn rows_per_delay(&self) -> Vec<usize> {
        let mut counts = vec![0usize; self.delay_range as usize + 1];
        for rk in &self.rows {
            counts[rk.delay as usize] += 1;
        }
        counts
    }
}

/// Fold a synapse's type into a signed weight.
#[inline]
fn signed_weight(s: &Synapse) -> i32 {
    match s.syn_type {
        SynapseType::Excitatory => s.weight as i32,
        SynapseType::Inhibitory => -(s.weight as i32),
    }
}

/// Shared S1/S2 occupancy analysis: the kept (row, column) sets.
fn wdm_shape(
    proj: &Projection,
    n_source: usize,
    n_target: usize,
    config: WdmConfig,
) -> (Vec<RowKey>, Vec<u32>, u16) {
    let delay_range = proj.delay_range();
    let n_lanes = n_source * delay_range as usize;
    let lane = |s: &Synapse| (s.delay as usize - 1) * n_source + s.source as usize;
    let mut row_used = vec![false; n_lanes];
    let mut col_used = vec![false; n_target];
    for s in &proj.synapses {
        row_used[lane(s)] = true;
        col_used[s.target as usize] = true;
    }
    // S1: row set.
    let rows: Vec<RowKey> = (0..n_lanes)
        .filter(|&l| !config.zero_row_elimination || row_used[l])
        .map(|l| RowKey { delay: (l / n_source) as u16 + 1, source: (l % n_source) as u32 })
        .collect();
    // S2: column set.
    let cols: Vec<u32> = (0..n_target as u32)
        .filter(|&t| !config.zero_col_elimination || col_used[t as usize])
        .collect();
    (rows, cols, delay_range)
}

/// Build only the WDM *shape* (rows/columns kept; no weight block).
///
/// Sufficient for PE counting — the two-stage split depends only on the
/// shape — and ~5× cheaper than [`build_wdm`] on dense layers, which is
/// what makes labeling the 16k-layer corpus tractable. `weight()` must not
/// be called on the result.
pub fn build_wdm_shape(
    proj: &Projection,
    n_source: usize,
    n_target: usize,
    config: WdmConfig,
) -> Wdm {
    let (rows, cols, delay_range) = wdm_shape(proj, n_source, n_target, config);
    Wdm { rows, cols, weights: Vec::new(), config, delay_range }
}

/// Build the optimized WDM for one layer.
pub fn build_wdm(proj: &Projection, n_source: usize, n_target: usize, config: WdmConfig) -> Wdm {
    let (rows, cols, delay_range) = wdm_shape(proj, n_source, n_target, config);
    let n_lanes = n_source * delay_range as usize;
    let lane = |s: &Synapse| (s.delay as usize - 1) * n_source + s.source as usize;

    // Dense index maps.
    let mut row_of = vec![usize::MAX; n_lanes];
    for (i, rk) in rows.iter().enumerate() {
        row_of[(rk.delay as usize - 1) * n_source + rk.source as usize] = i;
    }
    let mut col_of = vec![usize::MAX; n_target];
    for (i, &c) in cols.iter().enumerate() {
        col_of[c as usize] = i;
    }

    // Fill weights (sum multapses, saturate to i16 — weights are ≤ 255 so a
    // pair would need 128 multapses to saturate).
    let mut weights = vec![0i16; rows.len() * cols.len()];
    for s in &proj.synapses {
        let r = row_of[lane(s)];
        let c = col_of[s.target as usize];
        debug_assert!(r != usize::MAX && c != usize::MAX);
        let idx = r * cols.len() + c;
        weights[idx] = (weights[idx] as i32 + signed_weight(s)).clamp(i16::MIN as i32, i16::MAX as i32) as i16;
    }

    Wdm { rows, cols, weights, config, delay_range }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::connector::{Connector, SynapseDraw};
    use crate::model::{PopulationId, ProjectionId};
    use crate::rng::Rng;
    use crate::prop::Prop;

    fn proj_with(synapses: Vec<Synapse>) -> Projection {
        Projection {
            id: ProjectionId(0),
            source: PopulationId(0),
            target: PopulationId(1),
            synapses,
            weight_scale: 1.0,
        }
    }

    fn syn(s: u32, t: u32, w: u8, d: u16, inh: bool) -> Synapse {
        Synapse {
            source: s,
            target: t,
            weight: w,
            delay: d,
            syn_type: if inh { SynapseType::Inhibitory } else { SynapseType::Excitatory },
        }
    }

    #[test]
    fn rows_and_cols_eliminate_zeros() {
        let p = proj_with(vec![syn(0, 0, 5, 1, false), syn(0, 2, 6, 3, false)]);
        let wdm = build_wdm(&p, 4, 4, WdmConfig::default());
        assert_eq!(wdm.n_rows(), 2); // (d1,s0) and (d3,s0)
        assert_eq!(wdm.cols, vec![0, 2]);
        assert_eq!(wdm.weight(0, 0), 5);
        assert_eq!(wdm.weight(1, 1), 6);
    }

    #[test]
    fn naive_config_keeps_everything() {
        let p = proj_with(vec![syn(0, 0, 5, 2, false)]);
        let wdm = build_wdm(&p, 3, 4, WdmConfig::naive());
        assert_eq!(wdm.n_rows(), 3 * 2); // all (source, delay) lanes, delay range 2
        assert_eq!(wdm.n_cols(), 4);
    }

    #[test]
    fn inhibitory_weights_are_negative() {
        let p = proj_with(vec![syn(1, 1, 9, 1, true)]);
        let wdm = build_wdm(&p, 2, 2, WdmConfig::default());
        assert_eq!(wdm.weight(0, 0), -9);
    }

    #[test]
    fn rows_are_delay_major_sorted() {
        let mut rng = Rng::new(3);
        let syns = Connector::FixedProbability(0.4).build(
            30,
            30,
            SynapseDraw { delay_range: 8, w_max: 127, ..Default::default() },
            &mut rng,
        );
        let wdm = build_wdm(&proj_with(syns), 30, 30, WdmConfig::default());
        let mut sorted = wdm.rows.clone();
        sorted.sort();
        assert_eq!(wdm.rows, sorted);
    }

    #[test]
    fn matvec_matches_bruteforce() {
        // The WDM linear map must equal direct synapse accumulation.
        let mut rng = Rng::new(7);
        let syns = Connector::FixedProbability(0.5).build(
            20,
            15,
            SynapseDraw { delay_range: 4, w_max: 100, ..Default::default() },
            &mut rng,
        );
        let p = proj_with(syns.clone());
        let wdm = build_wdm(&p, 20, 15, WdmConfig::default());

        // Pretend every source fired at delay-offset δ0 = 2 steps ago:
        // active rows are exactly delay == 2.
        let mut via_wdm = vec![0i32; 15];
        for (r, rk) in wdm.rows.iter().enumerate() {
            if rk.delay == 2 {
                for (ci, &c) in wdm.cols.iter().enumerate() {
                    via_wdm[c as usize] += wdm.weight(r, ci) as i32;
                }
            }
        }
        let mut direct = vec![0i32; 15];
        for s in &syns {
            if s.delay == 2 {
                direct[s.target as usize] += s.weight as i32;
            }
        }
        assert_eq!(via_wdm, direct);
    }

    #[test]
    fn merged_padding_never_exceeds_unmerged() {
        let mut rng = Rng::new(11);
        let syns = Connector::FixedProbability(0.3).build(
            50,
            50,
            SynapseDraw { delay_range: 8, w_max: 127, ..Default::default() },
            &mut rng,
        );
        let p = proj_with(syns);
        let merged = build_wdm(&p, 50, 50, WdmConfig::default());
        let unmerged =
            build_wdm(&p, 50, 50, WdmConfig { delay_slot_merging: false, ..Default::default() });
        let rpd = merged.rows_per_delay();
        let b_merged = merged.weight_block_bytes(merged.n_rows(), merged.n_cols(), &rpd);
        let b_unmerged = unmerged.weight_block_bytes(unmerged.n_rows(), unmerged.n_cols(), &rpd);
        assert!(b_merged <= b_unmerged, "S3 must not increase bytes");
    }

    #[test]
    fn quantization_halves_weight_bytes() {
        let p = proj_with(vec![syn(0, 0, 5, 1, false)]);
        let w8 = build_wdm(&p, 16, 4, WdmConfig::default());
        let w16 = build_wdm(&p, 16, 4, WdmConfig { quantize_8bit: false, ..Default::default() });
        let rpd = w8.rows_per_delay();
        assert_eq!(
            w8.weight_block_bytes(16, 4, &rpd) * 2,
            w16.weight_block_bytes(16, 4, &rpd)
        );
    }

    #[test]
    fn shape_build_matches_full_build() {
        // The labeling fast path must agree exactly with the compile path.
        Prop::new("wdm shape == full build shape", 40).check(
            |g| {
                let n_src = g.usize(10, 200);
                let n_tgt = g.usize(10, 200);
                let density = g.f64(0.05, 1.0);
                let delay = g.usize(1, 16) as u16;
                let seed = g.i64(0, 1 << 30) as u64;
                (n_src, n_tgt, density, delay, seed)
            },
            |&(n_src, n_tgt, density, delay, seed)| {
                let mut rng = Rng::new(seed);
                let syns = Connector::FixedProbability(density).build(
                    n_src,
                    n_tgt,
                    SynapseDraw { delay_range: delay, w_max: 127, ..Default::default() },
                    &mut rng,
                );
                let p = proj_with(syns);
                let full = build_wdm(&p, n_src, n_tgt, WdmConfig::default());
                let shape = super::build_wdm_shape(&p, n_src, n_tgt, WdmConfig::default());
                full.rows == shape.rows
                    && full.cols == shape.cols
                    && full.delay_range == shape.delay_range
                    && shape.weights.is_empty()
            },
        );
    }

    #[test]
    fn alignment_pads_to_mac_geometry() {
        let p = proj_with(vec![syn(0, 0, 5, 1, false)]);
        let wdm = build_wdm(&p, 2, 2, WdmConfig::default());
        // 1 row, 1 col → padded to 16 rows × 4 cols × 1 B.
        let rpd = wdm.rows_per_delay();
        assert_eq!(wdm.weight_block_bytes(1, 1, &rpd), 16 * 4);
    }
}
