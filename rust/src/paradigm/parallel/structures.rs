//! Dominant-PE spike-preprocessing tables (paper §III-B).
//!
//! "The reversed order and input merging table are saved in the dominant PE
//! to pre-process the spikes in the stacked input buffer to adapt to the
//! data layout of the optimized weight-delay-map."
//!
//! At runtime, a spike from source `s` at timestep `t` must set the stacked-
//! input lanes `(s, δ)` for every delay `δ` the WDM keeps for `s` — but in
//! the stacked buffer of timestep `t + δ`. The *reversed order* table gives,
//! per source, the span of its entries inside the *input merging table*;
//! each merging-table entry carries the delay and the WDM row index.

use super::wdm::Wdm;

/// One input-merging-table entry: (delay, WDM row index).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct MergeEntry {
    pub delay: u16,
    pub row: u32,
}

/// The dominant PE's preprocessing tables.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct DominantTables {
    /// Per source neuron: [start, end) span into `merging`.
    pub reversed_order: Vec<(u32, u32)>,
    /// Merging entries grouped by source (span order), delay-sorted within.
    pub merging: Vec<MergeEntry>,
}

impl DominantTables {
    /// Derive the tables from a built WDM.
    ///
    /// Two-pass counting sort straight into the flat `merging` array (one
    /// scratch allocation, no per-source buckets): pass 1 counts rows per
    /// source and lays out the spans; pass 2 scatters each row into its
    /// span. WDM rows are delay-major sorted ([`Wdm::rows`] is built in
    /// lane order), so filling in row order lands every source's entries
    /// already delay-sorted — no per-source sort needed.
    pub fn from_wdm(wdm: &Wdm, n_source: usize) -> Self {
        // Pass 1: count rows per source.
        let mut cursor = vec![0u32; n_source];
        for rk in &wdm.rows {
            cursor[rk.source as usize] += 1;
        }
        // Spans from the prefix sum; `cursor` becomes the per-source fill
        // cursor (initialized to each span's start).
        let mut reversed_order = Vec::with_capacity(n_source);
        let mut acc = 0u32;
        for c in cursor.iter_mut() {
            reversed_order.push((acc, acc + *c));
            let start = acc;
            acc += *c;
            *c = start;
        }
        // Pass 2: scatter rows into their spans.
        let mut merging = vec![MergeEntry { delay: 0, row: 0 }; wdm.n_rows()];
        for (row, rk) in wdm.rows.iter().enumerate() {
            let pos = &mut cursor[rk.source as usize];
            merging[*pos as usize] = MergeEntry { delay: rk.delay, row: row as u32 };
            *pos += 1;
        }
        debug_assert!(
            reversed_order.iter().all(|&(lo, hi)| {
                merging[lo as usize..hi as usize].windows(2).all(|w| w[0].delay <= w[1].delay)
            }),
            "WDM rows must be delay-major sorted"
        );
        DominantTables { reversed_order, merging }
    }

    /// The merge entries of one source neuron.
    pub fn entries_of(&self, source: u32) -> &[MergeEntry] {
        let (lo, hi) = self.reversed_order[source as usize];
        &self.merging[lo as usize..hi as usize]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::connector::{Connector, SynapseDraw};
    use crate::model::{PopulationId, Projection, ProjectionId};
    use crate::paradigm::parallel::wdm::{build_wdm, WdmConfig};
    use crate::rng::Rng;

    fn wdm_for(n_src: usize, n_tgt: usize, density: f64, delay: u16) -> Wdm {
        let mut rng = Rng::new(5);
        let synapses = Connector::FixedProbability(density).build(
            n_src,
            n_tgt,
            SynapseDraw { delay_range: delay, w_max: 127, ..Default::default() },
            &mut rng,
        );
        let proj = Projection {
            id: ProjectionId(0),
            source: PopulationId(0),
            target: PopulationId(1),
            synapses,
            weight_scale: 1.0,
        };
        build_wdm(&proj, n_src, n_tgt, WdmConfig::default())
    }

    #[test]
    fn spans_cover_all_rows_exactly_once() {
        let wdm = wdm_for(40, 40, 0.4, 6);
        let t = DominantTables::from_wdm(&wdm, 40);
        assert_eq!(t.merging.len(), wdm.n_rows());
        let mut seen = vec![false; wdm.n_rows()];
        for s in 0..40 {
            for e in t.entries_of(s) {
                assert!(!seen[e.row as usize], "row referenced twice");
                seen[e.row as usize] = true;
                // Entry's row really belongs to this source and delay.
                let rk = wdm.rows[e.row as usize];
                assert_eq!(rk.source, s);
                assert_eq!(rk.delay, e.delay);
            }
        }
        assert!(seen.iter().all(|&b| b));
    }

    #[test]
    fn entries_delay_sorted_per_source() {
        let wdm = wdm_for(30, 30, 0.8, 8);
        let t = DominantTables::from_wdm(&wdm, 30);
        for s in 0..30 {
            let e = t.entries_of(s);
            assert!(e.windows(2).all(|w| w[0].delay <= w[1].delay));
        }
    }

    #[test]
    fn silent_source_has_empty_span() {
        // Source 1 gets no synapses.
        let proj = Projection {
            id: ProjectionId(0),
            source: PopulationId(0),
            target: PopulationId(1),
            synapses: vec![crate::model::Synapse {
                source: 0,
                target: 0,
                weight: 3,
                delay: 2,
                syn_type: crate::model::SynapseType::Excitatory,
            }],
            weight_scale: 1.0,
        };
        let wdm = build_wdm(&proj, 3, 2, WdmConfig::default());
        let t = DominantTables::from_wdm(&wdm, 3);
        assert_eq!(t.entries_of(0).len(), 1);
        assert_eq!(t.entries_of(1).len(), 0);
        assert_eq!(t.entries_of(2).len(), 0);
    }
}
