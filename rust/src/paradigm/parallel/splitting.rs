//! The two-stage splitting algorithm (paper §III-B).
//!
//! "If one subordinate PE does not have sufficient DTCM to save the whole
//! optimized weight-delay-map, it will be split into multiple cores in a
//! spatial-temporal balancing way by the two-stage splitting algorithm."
//!
//! Stage 1 (*temporal*) splits the stacked-input rows — the (source, delay)
//! lanes; stage 2 (*spatial*) splits the target columns. The search picks
//! the (row parts × col parts) grid with the fewest subordinate PEs whose
//! every chunk fits the DTCM budget; ties prefer the more balanced grid
//! (|rows − cols| minimal) and then fewer column parts (column splits
//! duplicate the stacked input across PEs at runtime).

use super::wdm::Wdm;
use crate::costmodel::parallel::subordinate_fixed_cost;
use crate::costmodel::serial::balanced_split;
use crate::hardware::PeSpec;

/// One subordinate chunk of the WDM grid.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Chunk {
    pub row_lo: usize,
    pub row_hi: usize,
    pub col_lo: usize,
    pub col_hi: usize,
    /// Cost-model DTCM bytes for this chunk.
    pub dtcm_bytes: usize,
}

/// The chosen split.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct SplitPlan {
    pub row_parts: usize,
    pub col_parts: usize,
    pub chunks: Vec<Chunk>,
}

impl SplitPlan {
    pub fn n_subordinates(&self) -> usize {
        self.chunks.len()
    }
}

/// DTCM bytes of a chunk holding `r` rows × `c` cols of `wdm`.
///
/// Chunk contents: the aligned weight block, 4 B per row key, 2 B per column
/// id, one 32-bit accumulator per padded output column, plus Table I's fixed
/// subordinate block.
pub fn chunk_bytes(
    wdm: &Wdm,
    r: usize,
    c: usize,
    rows_per_delay: &[usize],
    n_source_vertex: usize,
) -> usize {
    let weight_block = wdm.weight_block_bytes(r, c, rows_per_delay);
    let row_keys = 4 * r;
    let col_ids = 2 * c;
    let accumulators = 4 * wdm.config.mac.align_rows(c);
    let fixed = subordinate_fixed_cost(c, wdm.delay_range as usize, n_source_vertex).total();
    weight_block + row_keys + col_ids + accumulators + fixed
}

/// Worst-case chunk bytes for an (nr × nc) grid: the largest chunk governs.
///
/// `global_rpd` is the whole-map rows-per-delay profile, computed once by
/// the caller (only consulted when S3/delay-merging is off): the worst
/// chunk's per-delay rows are conservatively the global profile scaled
/// down; the compiler re-checks exact chunk costs afterwards.
fn grid_max_chunk_bytes(
    wdm: &Wdm,
    nr: usize,
    nc: usize,
    n_source_vertex: usize,
    global_rpd: &[usize],
    rpd_scratch: &mut Vec<usize>,
) -> usize {
    let r_max = wdm.n_rows().div_ceil(nr);
    let c_max = wdm.n_cols().div_ceil(nc);
    if wdm.config.delay_slot_merging {
        // rows-per-delay is ignored under S3 — skip building it.
        chunk_bytes(wdm, r_max, c_max, &[], n_source_vertex)
    } else {
        rpd_scratch.clear();
        rpd_scratch.extend(global_rpd.iter().map(|&x| x.div_ceil(nr)));
        chunk_bytes(wdm, r_max, c_max, rpd_scratch, n_source_vertex)
    }
}

/// Run the two-stage split search.
///
/// Returns `None` when even a fully split grid (1 row × 1 col per chunk)
/// cannot fit — practically impossible for the paper's sweep.
pub fn two_stage_split(wdm: &Wdm, pe: &PeSpec, n_source_vertex: usize) -> Option<SplitPlan> {
    let budget = pe.dtcm_bytes;
    let (nrows, ncols) = (wdm.n_rows().max(1), wdm.n_cols().max(1));
    let global_rpd = if wdm.config.delay_slot_merging { Vec::new() } else { wdm.rows_per_delay() };
    let mut scratch = Vec::new();

    let mut best: Option<(usize, usize, usize)> = None; // (total, nr, nc)
    for nc in 1..=ncols {
        // Any grid with nc column parts needs ≥ nc PEs: once the incumbent
        // total can no longer be improved, stop scanning wider grids.
        if let Some((t, _, _)) = best {
            if nc > t {
                break;
            }
        }
        // Smallest nr that fits for this nc (bytes decrease with nr).
        // Binary search over nr.
        let mut fits = |nr: usize| {
            grid_max_chunk_bytes(wdm, nr, nc, n_source_vertex, &global_rpd, &mut scratch)
                <= budget
        };
        if !fits(nrows) {
            continue; // even single-row chunks overflow at this column width
        }
        let mut lo = 1usize;
        let mut hi = nrows;
        while lo < hi {
            let mid = (lo + hi) / 2;
            if fits(mid) {
                hi = mid;
            } else {
                lo = mid + 1;
            }
        }
        let nr = lo;
        let total = nr * nc;
        let better = match best {
            None => true,
            Some((t, bnr, bnc)) => {
                total < t
                    || (total == t
                        && (nr.abs_diff(nc), nc) < (bnr.abs_diff(bnc), bnc))
            }
        };
        if better {
            best = Some((total, nr, nc));
        }
        // A perfect single-PE fit cannot be beaten.
        if total == 1 {
            break;
        }
    }

    let (_, nr, nc) = best?;
    // Materialize balanced chunk bounds with exact per-chunk costs.
    let row_sizes = balanced_split(wdm.n_rows(), nr);
    let col_sizes = balanced_split(wdm.n_cols(), nc);
    let mut chunks = Vec::with_capacity(nr * nc);
    let mut row_lo = 0usize;
    for &rs in &row_sizes {
        let mut col_lo = 0usize;
        // Exact per-delay row profile of this chunk.
        let mut rpd = vec![0usize; wdm.delay_range as usize + 1];
        for rk in &wdm.rows[row_lo..row_lo + rs] {
            rpd[rk.delay as usize] += 1;
        }
        for &cs in &col_sizes {
            let bytes = chunk_bytes(wdm, rs, cs, &rpd, n_source_vertex);
            chunks.push(Chunk {
                row_lo,
                row_hi: row_lo + rs,
                col_lo,
                col_hi: col_lo + cs,
                dtcm_bytes: bytes,
            });
            col_lo += cs;
        }
        row_lo += rs;
    }

    // The balanced materialization can only shrink chunks relative to the
    // worst-case bound used in the search, so every chunk fits.
    debug_assert!(chunks.iter().all(|c| c.dtcm_bytes <= budget));
    Some(SplitPlan { row_parts: nr, col_parts: nc, chunks })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::connector::{Connector, SynapseDraw};
    use crate::model::{PopulationId, Projection, ProjectionId};
    use crate::paradigm::parallel::wdm::{build_wdm, WdmConfig};
    use crate::prop::Prop;
    use crate::rng::Rng;

    fn make_wdm(n_src: usize, n_tgt: usize, density: f64, delay: u16, seed: u64) -> Wdm {
        let mut rng = Rng::new(seed);
        let synapses = Connector::FixedProbability(density).build(
            n_src,
            n_tgt,
            SynapseDraw { delay_range: delay, w_max: 127, ..Default::default() },
            &mut rng,
        );
        let proj = Projection {
            id: ProjectionId(0),
            source: PopulationId(0),
            target: PopulationId(1),
            synapses,
            weight_scale: 1.0,
        };
        build_wdm(&proj, n_src, n_tgt, WdmConfig::default())
    }

    #[test]
    fn small_wdm_fits_one_subordinate() {
        let wdm = make_wdm(50, 50, 0.3, 1, 1);
        let plan = two_stage_split(&wdm, &PeSpec::default(), 1).unwrap();
        assert_eq!(plan.n_subordinates(), 1);
    }

    #[test]
    fn large_wdm_splits_and_fits() {
        let wdm = make_wdm(500, 500, 0.9, 16, 2);
        let plan = two_stage_split(&wdm, &PeSpec::default(), 2).unwrap();
        assert!(plan.n_subordinates() > 1);
        let budget = PeSpec::default().dtcm_bytes;
        assert!(plan.chunks.iter().all(|c| c.dtcm_bytes <= budget));
    }

    #[test]
    fn chunks_tile_the_wdm_exactly() {
        Prop::new("two-stage chunks tile", 30).check(
            |g| {
                let wdm = make_wdm(
                    g.usize(50, 300),
                    g.usize(50, 300),
                    g.f64(0.1, 1.0),
                    g.usize(1, 16) as u16,
                    g.i64(0, 1 << 20) as u64,
                );
                let plan = two_stage_split(&wdm, &PeSpec::default(), 1).unwrap();
                (wdm.n_rows(), wdm.n_cols(), plan)
            },
            |(nrows, ncols, plan)| {
                // Chunk cells sum to the full grid and chunks are disjoint
                // row/col intervals per grid construction.
                let cells: usize = plan
                    .chunks
                    .iter()
                    .map(|c| (c.row_hi - c.row_lo) * (c.col_hi - c.col_lo))
                    .sum();
                cells == nrows * ncols
                    && plan.chunks.len() == plan.row_parts * plan.col_parts
            },
        );
    }

    #[test]
    fn more_delay_means_more_subordinates_when_dense() {
        let pe = PeSpec::default();
        let s1 = two_stage_split(&make_wdm(300, 300, 1.0, 1, 3), &pe, 1).unwrap();
        let s16 = two_stage_split(&make_wdm(300, 300, 1.0, 16, 3), &pe, 1).unwrap();
        assert!(
            s16.n_subordinates() > s1.n_subordinates(),
            "delay 16 ({}) should need more PEs than delay 1 ({})",
            s16.n_subordinates(),
            s1.n_subordinates()
        );
    }

    #[test]
    fn grid_is_reasonably_balanced() {
        let wdm = make_wdm(400, 400, 1.0, 16, 4);
        let plan = two_stage_split(&wdm, &PeSpec::default(), 1).unwrap();
        // "spatial-temporal balancing": neither dimension should be split to
        // shreds while the other stays whole, unless forced.
        assert!(plan.row_parts >= 1 && plan.col_parts >= 1);
        let budget = PeSpec::default().dtcm_bytes;
        // No chunk wastes more than half its budget unless the grid is 1×1.
        if plan.n_subordinates() > 1 {
            let max = plan.chunks.iter().map(|c| c.dtcm_bytes).max().unwrap();
            assert!(max * 2 > budget, "over-split: max chunk only {max} B of {budget} B");
        }
    }
}
