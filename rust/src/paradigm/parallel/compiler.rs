//! Parallel-paradigm compiler: one layer → dominant + subordinate programs.

use super::splitting::{two_stage_split, SplitPlan};
use super::structures::DominantTables;
use super::wdm::{build_wdm, Wdm, WdmConfig};
use crate::costmodel::parallel::{dominant_cost, DominantCost};
use crate::hardware::PeSpec;
use crate::model::{LayerCharacter, LifParams, Projection};
use anyhow::{ensure, Context, Result};

/// One subordinate PE's program: a WDM chunk destined for the MAC array.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct SubordinateProgram {
    /// Row range [lo, hi) of the WDM this PE holds.
    pub row_lo: usize,
    pub row_hi: usize,
    /// Column range [lo, hi) of the WDM this PE accumulates.
    pub col_lo: usize,
    pub col_hi: usize,
    /// Dense row-major chunk weights, `(row_hi-row_lo) × (col_hi-col_lo)`.
    pub weights: Vec<i16>,
    /// Cost-model DTCM bytes (aligned weight block + tables + fixed).
    pub dtcm_bytes: usize,
}

impl SubordinateProgram {
    pub fn n_rows(&self) -> usize {
        self.row_hi - self.row_lo
    }

    pub fn n_cols(&self) -> usize {
        self.col_hi - self.col_lo
    }

    #[inline]
    pub fn weight(&self, local_row: usize, local_col: usize) -> i16 {
        self.weights[local_row * self.n_cols() + local_col]
    }
}

/// A fully compiled parallel layer.
#[derive(Clone, Debug, PartialEq)]
pub struct ParallelCompiled {
    pub wdm: Wdm,
    pub tables: DominantTables,
    pub dominant_cost: DominantCost,
    pub subordinates: Vec<SubordinateProgram>,
    pub plan: SplitPlan,
    pub character: LayerCharacter,
    pub params: LifParams,
    pub weight_scale: f32,
    pub n_source: usize,
    pub n_target: usize,
    pub n_source_vertex: usize,
}

impl ParallelCompiled {
    /// Total PEs: one dominant + the subordinates.
    pub fn n_pes(&self) -> usize {
        1 + self.subordinates.len()
    }

    /// Total cost-model DTCM across all PEs.
    pub fn total_dtcm(&self) -> usize {
        self.dominant_cost.total()
            + self.subordinates.iter().map(|s| s.dtcm_bytes).sum::<usize>()
    }
}

/// Compile one layer (projection) under the parallel paradigm.
pub fn compile_parallel(
    proj: &Projection,
    n_source: usize,
    n_target: usize,
    params: LifParams,
    pe: &PeSpec,
    config: WdmConfig,
) -> Result<ParallelCompiled> {
    let character = LayerCharacter::of_projection(proj, n_source, n_target);
    let n_source_vertex = n_source.div_ceil(pe.serial_neuron_cap);

    // Dominant PE: closed-form Table I cost; the paper verifies one dominant
    // suffices across its sweep — we enforce it.
    let dom = dominant_cost(n_source, n_target, character.delay_range as usize, n_source_vertex);
    ensure!(
        dom.total() <= pe.dtcm_bytes,
        "dominant PE overflows DTCM ({} B > {} B); layer outside supported envelope",
        dom.total(),
        pe.dtcm_bytes
    );

    // Build the optimized WDM and split it.
    let wdm = build_wdm(proj, n_source, n_target, config);
    let plan = two_stage_split(&wdm, pe, n_source_vertex)
        .context("weight-delay-map cannot be split to fit any PE")?;

    // Materialize per-chunk weight blocks.
    let subordinates: Vec<SubordinateProgram> = plan
        .chunks
        .iter()
        .map(|ch| {
            let (r0, r1, c0, c1) = (ch.row_lo, ch.row_hi, ch.col_lo, ch.col_hi);
            let mut weights = Vec::with_capacity((r1 - r0) * (c1 - c0));
            for r in r0..r1 {
                for c in c0..c1 {
                    weights.push(wdm.weight(r, c));
                }
            }
            SubordinateProgram {
                row_lo: r0,
                row_hi: r1,
                col_lo: c0,
                col_hi: c1,
                weights,
                dtcm_bytes: ch.dtcm_bytes,
            }
        })
        .collect();

    let tables = DominantTables::from_wdm(&wdm, n_source);

    Ok(ParallelCompiled {
        wdm,
        tables,
        dominant_cost: dom,
        subordinates,
        plan,
        character,
        params,
        weight_scale: proj.weight_scale,
        n_source,
        n_target,
        n_source_vertex,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::connector::{Connector, SynapseDraw};
    use crate::model::{PopulationId, ProjectionId};
    use crate::rng::Rng;

    fn make_proj(n_src: usize, n_tgt: usize, density: f64, delay: u16, seed: u64) -> Projection {
        let mut rng = Rng::new(seed);
        let synapses = Connector::FixedProbability(density).build(
            n_src,
            n_tgt,
            SynapseDraw { delay_range: delay, w_max: 127, ..Default::default() },
            &mut rng,
        );
        Projection {
            id: ProjectionId(0),
            source: PopulationId(0),
            target: PopulationId(1),
            synapses,
            weight_scale: 0.01,
        }
    }

    fn compile(n_src: usize, n_tgt: usize, d: f64, dl: u16, seed: u64) -> ParallelCompiled {
        let proj = make_proj(n_src, n_tgt, d, dl, seed);
        compile_parallel(
            &proj,
            n_src,
            n_tgt,
            LifParams::default(),
            &PeSpec::default(),
            WdmConfig::default(),
        )
        .unwrap()
    }

    #[test]
    fn small_layer_is_dominant_plus_one() {
        let c = compile(50, 50, 0.5, 1, 1);
        assert_eq!(c.n_pes(), 2);
    }

    #[test]
    fn chunks_reassemble_wdm() {
        let c = compile(300, 300, 0.8, 8, 2);
        assert!(c.subordinates.len() > 1);
        // Every WDM cell appears in exactly one chunk with the same weight.
        let mut covered = vec![false; c.wdm.n_rows() * c.wdm.n_cols()];
        for sub in &c.subordinates {
            for r in sub.row_lo..sub.row_hi {
                for col in sub.col_lo..sub.col_hi {
                    let idx = r * c.wdm.n_cols() + col;
                    assert!(!covered[idx]);
                    covered[idx] = true;
                    assert_eq!(
                        sub.weight(r - sub.row_lo, col - sub.col_lo),
                        c.wdm.weight(r, col)
                    );
                }
            }
        }
        assert!(covered.iter().all(|&b| b));
    }

    #[test]
    fn all_pes_fit_budget() {
        for (ns, nt, d, dl, seed) in
            [(500, 500, 1.0, 16, 3), (50, 500, 0.2, 4, 4), (500, 50, 0.9, 16, 5)]
        {
            let c = compile(ns, nt, d, dl, seed);
            let budget = PeSpec::default().dtcm_bytes;
            assert!(c.dominant_cost.total() <= budget);
            assert!(c.subordinates.iter().all(|s| s.dtcm_bytes <= budget));
        }
    }

    #[test]
    fn parallel_beats_serial_on_dense_low_delay() {
        // The paper's headline trend: "the parallel paradigm improves with
        // decreasing delay range and increasing weight density".
        let c = compile(255, 255, 1.0, 1, 6);
        let serial = crate::costmodel::serial::serial_pe_count(
            &c.character,
            &PeSpec::default(),
        )
        .unwrap();
        assert!(
            c.n_pes() < serial,
            "parallel {} should beat serial {serial} at density 1.0, delay 1",
            c.n_pes()
        );
    }

    #[test]
    fn serial_beats_parallel_on_sparse_high_delay() {
        let c = compile(255, 255, 0.1, 16, 7);
        let serial = crate::costmodel::serial::serial_pe_count(
            &c.character,
            &PeSpec::default(),
        )
        .unwrap();
        assert!(
            serial < c.n_pes(),
            "serial {serial} should beat parallel {} at density 0.1, delay 16",
            c.n_pes()
        );
    }

    #[test]
    fn pe_count_grows_with_delay() {
        let d1 = compile(300, 300, 0.9, 1, 8).n_pes();
        let d16 = compile(300, 300, 0.9, 16, 8).n_pes();
        assert!(d16 > d1);
    }
}
