//! Parallel paradigm (paper §III-B): MAC-array-accelerated synaptic
//! processing.
//!
//! A *dominant* PE pre-processes arriving spikes — via the reversed-order
//! and input-merging tables — into a *stacked input* vector laid out to
//! match the *optimized weight-delay-map* (WDM); *subordinate* PEs multiply
//! the stacked input against their WDM chunk on the 4×16 MAC array. When the
//! WDM exceeds one PE's DTCM it is "split into multiple cores in a
//! spatial-temporal balancing way by the two-stage splitting algorithm".
//!
//! * [`wdm`] — WDM construction with the four optimization strategies.
//! * [`splitting`] — the two-stage (rows × columns) splitting algorithm.
//! * [`structures`] — dominant-PE spike-preprocessing tables.
//! * [`compiler`] — compiles one layer into dominant + subordinate programs.

pub mod compiler;
pub mod splitting;
pub mod structures;
pub mod wdm;

pub use compiler::{compile_parallel, ParallelCompiled, SubordinateProgram};
pub use structures::DominantTables;
pub use wdm::{Wdm, WdmConfig};
