//! Serial-paradigm compiler: one layer → per-PE loadable programs.
//!
//! Follows the §III-A rules: targets split into ≤255-neuron sub-populations,
//! sources into ≤255-neuron vertices; dense layers additionally split their
//! synaptic matrix over 2–4 adjacent PEs by source rows. The PE layout is
//! delegated to [`crate::costmodel::serial::serial_layout`] so the compiled
//! artifact and the cost model can never disagree about PE counts.

use super::structures::{
    build_structures, AddressList, MasterPopulationTable, SynapticMatrix,
};
use crate::costmodel::serial::{balanced_split, serial_layout, SerialCost};
use crate::graph::machine_graph::SliceRange;
use crate::hardware::PeSpec;
use crate::model::{LayerCharacter, LifParams, Projection};
use anyhow::{bail, Context, Result};

/// One PE's compiled serial program.
#[derive(Clone, Debug, PartialEq)]
pub struct SerialPeProgram {
    /// Target neurons simulated on this PE (projection-local indices).
    pub target_slice: SliceRange,
    /// Source rows stored on this PE (projection-local indices).
    pub source_slice: SliceRange,
    pub mpt: MasterPopulationTable,
    pub address_list: AddressList,
    pub matrix: SynapticMatrix,
    /// Delay ring-buffer depth (= layer delay range).
    pub delay_range: u16,
    pub params: LifParams,
    pub weight_scale: f32,
    /// Table I cost-model breakdown for this PE.
    pub cost: SerialCost,
}

impl SerialPeProgram {
    /// Actual bytes of variable-size structures (≤ the cost model, which
    /// budgets the worst case n_src*n_tgt*density).
    pub fn actual_structure_bytes(&self) -> usize {
        self.mpt.dtcm_bytes() + self.address_list.dtcm_bytes() + self.matrix.dtcm_bytes()
    }
}

/// A fully compiled serial layer.
#[derive(Clone, Debug, PartialEq)]
pub struct SerialCompiled {
    pub pes: Vec<SerialPeProgram>,
    pub character: LayerCharacter,
    pub n_target_chunks: usize,
    pub n_source_vertex: usize,
}

impl SerialCompiled {
    pub fn n_pes(&self) -> usize {
        self.pes.len()
    }

    /// Total cost-model DTCM across PEs.
    pub fn total_dtcm(&self) -> usize {
        self.pes.iter().map(|p| p.cost.total()).sum()
    }
}

/// Compile one layer (projection) under the serial paradigm.
///
/// `n_source`/`n_target` are the projection's population sizes; `params` the
/// target population's LIF parameters.
pub fn compile_serial(
    proj: &Projection,
    n_source: usize,
    n_target: usize,
    params: LifParams,
    pe: &PeSpec,
) -> Result<SerialCompiled> {
    let character = LayerCharacter::of_projection(proj, n_source, n_target);
    let layout = serial_layout(&character, pe)
        .context("layer does not fit the machine under the serial paradigm")?;

    // Recover the chunk boundaries the layout used.
    let tgt_chunks = balanced_split(n_target, layout.n_target_chunks);
    let mut tgt_bounds = Vec::with_capacity(tgt_chunks.len());
    let mut acc = 0u32;
    for &c in &tgt_chunks {
        tgt_bounds.push(SliceRange { lo: acc, hi: acc + c as u32 });
        acc += c as u32;
    }
    // Source vertices: ≤255-neuron global key ranges.
    let src_vertex_chunks = balanced_split(n_source, layout.n_source_vertex);
    let mut src_vertices: Vec<(u32, u32)> = Vec::new();
    let mut acc = 0u32;
    for &c in &src_vertex_chunks {
        src_vertices.push((acc, acc + c as u32));
        acc += c as u32;
    }

    let mut pes = Vec::with_capacity(layout.pes.len());
    for lp in &layout.pes {
        let tgt = tgt_bounds[lp.target_chunk];
        // Row-split bounds within the full source range.
        let row_parts = layout
            .pes
            .iter()
            .filter(|p| p.target_chunk == lp.target_chunk)
            .count();
        let rows = balanced_split(n_source, row_parts);
        let mut lo = 0u32;
        for r in rows.iter().take(lp.row_split) {
            lo += *r as u32;
        }
        let src = SliceRange { lo, hi: lo + rows[lp.row_split] as u32 };

        // Synapses on this PE: its source rows × its target slice, with
        // targets re-based to PE-local indices.
        let mut local: Vec<_> = proj
            .synapses
            .iter()
            .filter(|s| src.contains(s.source) && tgt.contains(s.target))
            .copied()
            .collect();
        for s in &mut local {
            s.target -= tgt.lo;
        }
        // Source vertices clipped to this PE's row range.
        let my_vertices: Vec<(u32, u32)> = src_vertices
            .iter()
            .filter_map(|&(lo_v, hi_v)| {
                let lo_c = lo_v.max(src.lo);
                let hi_c = hi_v.min(src.hi);
                (lo_c < hi_c).then_some((lo_c, hi_c))
            })
            .collect();
        if my_vertices.is_empty() {
            bail!("internal: PE with no source coverage");
        }
        let (mpt, address_list, matrix) = build_structures(&local, &my_vertices);
        pes.push(SerialPeProgram {
            target_slice: tgt,
            source_slice: src,
            mpt,
            address_list,
            matrix,
            delay_range: character.delay_range,
            params,
            weight_scale: proj.weight_scale,
            cost: lp.cost,
        });
    }

    Ok(SerialCompiled {
        pes,
        character,
        n_target_chunks: layout.n_target_chunks,
        n_source_vertex: layout.n_source_vertex,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::connector::SynapseDraw;
    use crate::model::{Connector, PopulationId, ProjectionId};
    use crate::rng::Rng;

    fn make_proj(n_src: usize, n_tgt: usize, density: f64, delay: u16, seed: u64) -> Projection {
        let mut rng = Rng::new(seed);
        let synapses = Connector::FixedProbability(density).build(
            n_src,
            n_tgt,
            SynapseDraw { delay_range: delay, w_max: 127, ..Default::default() },
            &mut rng,
        );
        Projection {
            id: ProjectionId(0),
            source: PopulationId(0),
            target: PopulationId(1),
            synapses,
            weight_scale: 0.01,
        }
    }

    #[test]
    fn small_layer_compiles_to_one_pe() {
        let proj = make_proj(100, 100, 0.1, 4, 1);
        let c = compile_serial(&proj, 100, 100, LifParams::default(), &PeSpec::default()).unwrap();
        assert_eq!(c.n_pes(), 1);
        let pe = &c.pes[0];
        assert_eq!(pe.target_slice, SliceRange { lo: 0, hi: 100 });
        assert_eq!(pe.matrix.words.len(), proj.synapses.len());
    }

    #[test]
    fn synapses_partition_exactly_across_pes() {
        // Dense layer large enough to force target + row splits.
        let proj = make_proj(300, 300, 0.9, 8, 2);
        let c = compile_serial(&proj, 300, 300, LifParams::default(), &PeSpec::default()).unwrap();
        assert!(c.n_pes() > 1);
        let total: usize = c.pes.iter().map(|p| p.matrix.words.len()).sum();
        assert_eq!(total, proj.synapses.len(), "no synapse lost or duplicated");
    }

    #[test]
    fn every_pe_respects_budget_and_cost_model_bounds_actual() {
        let proj = make_proj(500, 400, 0.5, 16, 3);
        let c = compile_serial(&proj, 500, 400, LifParams::default(), &PeSpec::default()).unwrap();
        for pe in &c.pes {
            assert!(pe.cost.total() <= PeSpec::default().dtcm_bytes);
            // The cost model's synaptic-matrix budget is an expectation; the
            // realized matrix must be within a few std-devs of it.
            let budgeted = pe.cost.synaptic_matrix as f64;
            let actual = pe.matrix.dtcm_bytes() as f64;
            assert!(
                actual < budgeted * 1.2 + 2048.0,
                "realized matrix {actual} far above budget {budgeted}"
            );
        }
    }

    #[test]
    fn event_path_resolves_all_sources() {
        let proj = make_proj(200, 150, 0.3, 5, 4);
        let c = compile_serial(&proj, 200, 150, LifParams::default(), &PeSpec::default()).unwrap();
        // Every synapse must be reachable via MPT → address list → block.
        let mut found = 0usize;
        for pe in &c.pes {
            for src in pe.source_slice.lo..pe.source_slice.hi {
                if let Some(slot) = pe.mpt.lookup(src) {
                    let entry = pe.address_list.entries[slot as usize];
                    found += pe.matrix.block(entry).len();
                }
            }
        }
        assert_eq!(found, proj.synapses.len());
    }

    #[test]
    fn pe_count_matches_cost_model_layout() {
        for (ns, nt, d, dl, seed) in
            [(255, 255, 1.0, 16, 5), (500, 500, 0.1, 1, 6), (50, 500, 0.5, 8, 7)]
        {
            let proj = make_proj(ns, nt, d, dl, seed);
            let c = compile_serial(&proj, ns, nt, LifParams::default(), &PeSpec::default()).unwrap();
            let expect =
                crate::costmodel::serial::serial_pe_count(&c.character, &PeSpec::default())
                    .unwrap();
            assert_eq!(c.n_pes(), expect);
        }
    }
}
