//! Serial paradigm (paper §III-A): event-based synaptic processing on the
//! ARM core, time-triggered LIF update.
//!
//! * [`structures`] — the runtime data structures the compiler emits
//!   (master population table, address list, packed synaptic matrix).
//! * [`compiler`] — compiles one layer into per-PE [`SerialPeProgram`]s
//!   following the §III-A partitioning rules and the Table I cost model.

pub mod compiler;
pub mod structures;

pub use compiler::{compile_serial, SerialCompiled, SerialPeProgram};
pub use structures::{AddressEntry, AddressList, MasterPopulationTable, SynapticMatrix, SynapticWord};
