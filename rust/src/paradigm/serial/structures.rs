//! Serial-paradigm runtime data structures (paper §III-A).
//!
//! "The source neuron index embedded in the spiking package unlocks an entry
//! of the pre-loaded master population table. This entry points at one item
//! of the address list, indicating the first address and matrix row length
//! of a block of synaptic matrix on local SRAM. Each row within one block
//! saves the synaptic information between the spiked source neuron and one
//! of the target neurons, including weight, delay, synapse type (excitatory
//! or inhibitory), and target neuron index."

use crate::model::{Synapse, SynapseType};

/// A packed 32-bit synaptic word, sPyNNaker-style:
///
/// ```text
/// bits 31..24  weight magnitude (8-bit quantized)
/// bits 23..19  delay (5 bits, 1..=31 timesteps)
/// bit  18      synapse type (0 = excitatory, 1 = inhibitory)
/// bits 17..0   target neuron index (PE-local)
/// ```
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SynapticWord(pub u32);

impl SynapticWord {
    pub const TARGET_BITS: u32 = 18;
    pub const TARGET_MASK: u32 = (1 << Self::TARGET_BITS) - 1;

    pub fn pack(weight: u8, delay: u16, syn_type: SynapseType, target: u32) -> Self {
        assert!(delay >= 1 && delay < 32, "delay {delay} outside packable range 1..=31");
        assert!(target <= Self::TARGET_MASK, "target index {target} overflows packing");
        let t = match syn_type {
            SynapseType::Excitatory => 0u32,
            SynapseType::Inhibitory => 1u32,
        };
        SynapticWord(
            (weight as u32) << 24 | (delay as u32) << 19 | t << 18 | target,
        )
    }

    pub fn weight(self) -> u8 {
        (self.0 >> 24) as u8
    }

    pub fn delay(self) -> u16 {
        ((self.0 >> 19) & 0x1f) as u16
    }

    pub fn syn_type(self) -> SynapseType {
        if (self.0 >> 18) & 1 == 0 {
            SynapseType::Excitatory
        } else {
            SynapseType::Inhibitory
        }
    }

    pub fn target(self) -> u32 {
        self.0 & Self::TARGET_MASK
    }
}

/// Address-list entry: where one source neuron's synaptic-matrix block
/// starts and how many rows (synapses) it holds.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct AddressEntry {
    pub first_word: u32,
    pub row_length: u32,
}

/// The address list: one entry per source neuron handled by this PE.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct AddressList {
    pub entries: Vec<AddressEntry>,
}

impl AddressList {
    /// Table I bytes: (32/8)*n_address_list_rows.
    pub fn dtcm_bytes(&self) -> usize {
        4 * self.entries.len()
    }
}

/// Master population table: maps a global source-neuron key to the
/// (PE-local) address-list slot. One entry per source *vertex* (sub-
/// population), each covering a contiguous global key range.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct MasterPopulationTable {
    /// (key_lo, key_hi_exclusive, address_list_base) per source vertex.
    pub entries: Vec<(u32, u32, u32)>,
}

impl MasterPopulationTable {
    /// Resolve a global source neuron id to its address-list index.
    pub fn lookup(&self, source_global: u32) -> Option<u32> {
        // Entries are few (n_source_vertex ≤ 2 in the paper's sweep); linear
        // scan is faster than binary search at this size.
        for &(lo, hi, base) in &self.entries {
            if (lo..hi).contains(&source_global) {
                return Some(base + (source_global - lo));
            }
        }
        None
    }

    /// Table I bytes: (96/8)*n_source_vertex.
    pub fn dtcm_bytes(&self) -> usize {
        12 * self.entries.len()
    }
}

/// The synaptic matrix: all blocks concatenated, indexed via [`AddressList`].
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct SynapticMatrix {
    pub words: Vec<SynapticWord>,
}

impl SynapticMatrix {
    /// Table I bytes: 4 bytes per word actually stored.
    pub fn dtcm_bytes(&self) -> usize {
        4 * self.words.len()
    }

    /// The rows of one source neuron's block.
    pub fn block(&self, entry: AddressEntry) -> &[SynapticWord] {
        let lo = entry.first_word as usize;
        &self.words[lo..lo + entry.row_length as usize]
    }
}

/// Build (master population table, address list, synaptic matrix) for one
/// PE from the synapses it stores.
///
/// * `synapses` — synapses with *global* source ids and *PE-local* target
///   ids (the compiler pre-filters and re-bases targets);
/// * `source_vertices` — global source-id ranges, one per source vertex.
pub fn build_structures(
    synapses: &[Synapse],
    source_vertices: &[(u32, u32)],
) -> (MasterPopulationTable, AddressList, SynapticMatrix) {
    let n_sources: u32 = source_vertices.iter().map(|&(lo, hi)| hi - lo).sum();
    // Map global source id → dense address-list slot (vertex-major order).
    // `source_vertices` has at most a couple of entries, so calling this
    // twice per synapse (count pass + fill pass) is cheaper than buffering
    // resolved slots.
    let slot_of = |global: u32| -> Option<u32> {
        let mut base = 0u32;
        for &(lo, hi) in source_vertices {
            if (lo..hi).contains(&global) {
                return Some(base + (global - lo));
            }
            base += hi - lo;
        }
        None
    };

    // Two-pass counting sort into the flat synaptic matrix (one scratch
    // allocation; no per-source `Vec<Vec<&Synapse>>` buckets). Pass 1
    // counts each source's block; the prefix sum is the address list;
    // pass 2 scatters packed words into their blocks. Within a block,
    // synapses keep their input order (a stable scatter), exactly like
    // the bucketed build did.
    let mut cursor = vec![0u32; n_sources as usize];
    for syn in synapses {
        let slot = slot_of(syn.source).expect("synapse source outside declared vertices");
        cursor[slot as usize] += 1;
    }
    let mut address_list = AddressList::default();
    address_list.entries.reserve_exact(n_sources as usize);
    let mut acc = 0u32;
    for c in cursor.iter_mut() {
        address_list.entries.push(AddressEntry { first_word: acc, row_length: *c });
        let start = acc;
        acc += *c;
        *c = start; // `cursor` now holds each block's fill position
    }
    let mut matrix = SynapticMatrix { words: vec![SynapticWord(0); acc as usize] };
    for syn in synapses {
        let slot = slot_of(syn.source).expect("synapse source outside declared vertices");
        let pos = &mut cursor[slot as usize];
        matrix.words[*pos as usize] =
            SynapticWord::pack(syn.weight, syn.delay, syn.syn_type, syn.target);
        *pos += 1;
    }

    let mut mpt = MasterPopulationTable::default();
    let mut base = 0u32;
    for &(lo, hi) in source_vertices {
        mpt.entries.push((lo, hi, base));
        base += hi - lo;
    }
    (mpt, address_list, matrix)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop::Prop;

    #[test]
    fn word_pack_roundtrip() {
        Prop::new("synaptic word roundtrip", 500).check(
            |g| {
                (
                    g.usize(0, 255) as u8,
                    g.usize(1, 31) as u16,
                    g.bool(0.5),
                    g.usize(0, (1 << 18) - 1) as u32,
                )
            },
            |&(w, d, inh, t)| {
                let ty = if inh { SynapseType::Inhibitory } else { SynapseType::Excitatory };
                let word = SynapticWord::pack(w, d, ty, t);
                word.weight() == w && word.delay() == d && word.syn_type() == ty && word.target() == t
            },
        );
    }

    #[test]
    #[should_panic(expected = "outside packable range")]
    fn word_rejects_delay_zero() {
        SynapticWord::pack(1, 0, SynapseType::Excitatory, 0);
    }

    #[test]
    #[should_panic(expected = "overflows packing")]
    fn word_rejects_huge_target() {
        SynapticWord::pack(1, 1, SynapseType::Excitatory, 1 << 18);
    }

    fn syn(s: u32, t: u32, w: u8, d: u16) -> Synapse {
        Synapse { source: s, target: t, weight: w, delay: d, syn_type: SynapseType::Excitatory }
    }

    #[test]
    fn build_and_lookup_path() {
        // Two source vertices: global ids [0,3) and [10,12).
        let synapses = vec![syn(0, 1, 5, 1), syn(0, 2, 6, 2), syn(2, 0, 7, 1), syn(10, 1, 8, 3)];
        let (mpt, al, mat) = build_structures(&synapses, &[(0, 3), (10, 12)]);
        assert_eq!(mpt.entries.len(), 2);
        assert_eq!(al.entries.len(), 5); // 3 + 2 source neurons

        // Event path for global source 0: two rows.
        let slot = mpt.lookup(0).unwrap();
        let block = mat.block(al.entries[slot as usize]);
        assert_eq!(block.len(), 2);
        assert_eq!(block[0].weight(), 5);
        assert_eq!(block[1].target(), 2);

        // Source 1 has no synapses: empty block.
        let slot1 = mpt.lookup(1).unwrap();
        assert_eq!(al.entries[slot1 as usize].row_length, 0);

        // Second vertex re-bases correctly.
        let slot10 = mpt.lookup(10).unwrap();
        assert_eq!(slot10, 3);
        let b10 = mat.block(al.entries[3]);
        assert_eq!(b10[0].weight(), 8);

        // Out-of-range key misses.
        assert_eq!(mpt.lookup(5), None);
        assert_eq!(mpt.lookup(12), None);

        // Byte accounting matches Table I formulas.
        assert_eq!(mpt.dtcm_bytes(), 12 * 2);
        assert_eq!(al.dtcm_bytes(), 4 * 5);
        assert_eq!(mat.dtcm_bytes(), 4 * 4);
    }

    #[test]
    fn blocks_cover_matrix_exactly() {
        Prop::new("address list covers matrix", 100).check(
            |g| {
                let n_src = g.usize(1, 20);
                let n_syn = g.usize(0, 60);
                let syns = g.vec(n_syn, |g| {
                    syn(
                        g.usize(0, n_src - 1) as u32,
                        g.usize(0, 9) as u32,
                        g.usize(1, 127) as u8,
                        g.usize(1, 16) as u16,
                    )
                });
                (n_src, syns)
            },
            |(n_src, syns)| {
                let (_, al, mat) = build_structures(syns, &[(0, *n_src as u32)]);
                let covered: u32 = al.entries.iter().map(|e| e.row_length).sum();
                covered as usize == mat.words.len() && al.entries.len() == *n_src
            },
        );
    }
}
