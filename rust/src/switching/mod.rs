//! The fast-switching compilation system — the paper's contribution (§IV).
//!
//! "We train the classifier to prejudge a better paradigm before compiling
//! instead of making the decision afterward, saving a great amount of
//! compiling time and RAM space on the host PC."
//!
//! [`SwitchingSystem`] wraps the deployed classifier (AdaBoost by default)
//! and compiles each layer only under the predicted paradigm. The
//! alternatives the evaluation compares against:
//! * [`SwitchMode::ForceSerial`] / [`SwitchMode::ForceParallel`] — the two
//!   single-paradigm systems (Fig. 5 blue/green lines);
//! * [`SwitchMode::Ideal`] — compile **both**, keep the cheaper (Fig. 5
//!   pink line; what the paper's label collection does, at 2× compile cost);
//! * [`SwitchMode::Classifier`] — the fast-switching system (purple line).
//!
//! Architecture (DESIGN.md §1): the *decision* lives in
//! [`policy::SwitchPolicy`], the *execution* in [`pipeline::CompilePipeline`]
//! (threaded fan-out + compile cache + atomic stats), and the per-paradigm
//! compilers behind [`crate::paradigm::ParadigmCompiler`]. `SwitchingSystem`
//! is the thin stateful front the CLI, benches and examples drive.

pub mod adaptive;
pub mod admission;
pub mod pipeline;
pub mod placement;
pub mod policy;
pub mod recovery;

pub use crate::paradigm::CompiledLayer;
pub use adaptive::{AdaptiveConfig, AdaptiveRunReport, SwapEvent, SwapGovernor};
pub use admission::{LayerDecision, NetworkAdmission, ShardedAdmission};
pub use pipeline::{CompileJob, CompilePipeline, PipelineRun};
pub use placement::Placement;
pub use policy::{SwitchError, SwitchPolicy};
pub use recovery::{FaultRunReport, LayerStatus, RecoveryConfig, RecoveryStats};

use crate::classifier::{AdaBoost, Classifier};
use crate::dataset::Dataset;
use crate::hardware::PeSpec;
use crate::model::{LayerCharacter, LifParams, Network, Projection};
use crate::paradigm::parallel::WdmConfig;
use crate::paradigm::Paradigm;
use anyhow::Result;

/// How the system chooses a paradigm per layer.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SwitchMode {
    ForceSerial,
    ForceParallel,
    /// Compile both paradigms, keep the cheaper one (slow, 2× host RAM).
    Ideal,
    /// Prejudge with the trained classifier, compile only the winner.
    Classifier,
}

/// Compile-effort accounting (the quantity the paper's fast switching
/// saves: how many paradigm compilations actually ran).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CompileStats {
    pub serial_compiles: usize,
    pub parallel_compiles: usize,
    /// Shape-only cost estimates run (the dataset labeler's path — never
    /// materializes per-PE programs).
    pub serial_estimates: usize,
    pub parallel_estimates: usize,
    /// Jobs served from the in-memory compile cache instead of recompiling.
    pub cache_hits: usize,
    /// Jobs served from the on-disk artifact store (`--artifact-dir`)
    /// instead of recompiling — the *restart-surviving* saving, counted
    /// separately from `cache_hits` so benches and
    /// [`SwitchingSystem::compile_network_report`] attribute the win to
    /// the right tier.
    pub disk_hits: usize,
    /// Peak bytes of *discarded* compilation results (the "RAM crisis on
    /// the host PC" term: Ideal mode materializes both and throws one away).
    pub discarded_dtcm: usize,
    /// Layers whose prejudged paradigm was overridden by the
    /// capacity-feasibility stage because it did not fit the machine's
    /// remaining headroom ([`admission`]).
    pub capacity_overrides: usize,
}

impl CompileStats {
    pub fn total_compiles(&self) -> usize {
        self.serial_compiles + self.parallel_compiles
    }

    pub fn total_estimates(&self) -> usize {
        self.serial_estimates + self.parallel_estimates
    }
}

/// The classifier-integrated switching system.
pub struct SwitchingSystem {
    /// The per-layer decision (mode + optional trained prejudger).
    pub policy: SwitchPolicy,
    /// Snapshot of the pipeline's cumulative accounting after the most
    /// recent compile call.
    pub stats: CompileStats,
    pipeline: CompilePipeline,
}

impl SwitchingSystem {
    /// A system in the given mode without a classifier (prejudging in
    /// `SwitchMode::Classifier` yields [`SwitchError::MissingClassifier`]).
    /// Use [`SwitchingSystem::with_classifier`] for the deployed
    /// configuration.
    pub fn new(mode: SwitchMode, pe: PeSpec) -> Self {
        Self::from_policy(SwitchPolicy::forced(mode), pe)
    }

    /// The deployed configuration: prejudge with a trained classifier.
    pub fn with_classifier(classifier: Box<dyn Classifier>, pe: PeSpec) -> Self {
        Self::from_policy(SwitchPolicy::with_classifier(classifier), pe)
    }

    pub fn from_policy(policy: SwitchPolicy, pe: PeSpec) -> Self {
        SwitchingSystem {
            policy,
            stats: CompileStats::default(),
            pipeline: CompilePipeline::new(pe, WdmConfig::default()),
        }
    }

    /// Train an AdaBoost prejudger on a labeled dataset and deploy it
    /// (the paper's final system).
    pub fn train_adaboost(dataset: &Dataset, n_rounds: usize, pe: PeSpec) -> Self {
        let (x, y) = dataset.xy();
        let mut ab = AdaBoost::new(n_rounds);
        ab.train(&x, &y);
        Self::with_classifier(Box::new(ab), pe)
    }

    pub fn mode(&self) -> SwitchMode {
        self.policy.mode
    }

    /// The PE spec every compile (and cache key) uses — owned by the
    /// pipeline so the two can never disagree.
    pub fn pe(&self) -> PeSpec {
        self.pipeline.pe
    }

    pub fn wdm_config(&self) -> WdmConfig {
        self.pipeline.wdm
    }

    /// Worker threads used by [`SwitchingSystem::compile_network`]
    /// (0 = one per CPU, 1 = sequential).
    pub fn set_jobs(&mut self, jobs: usize) {
        self.pipeline.set_jobs(jobs);
    }

    pub fn jobs(&self) -> usize {
        self.pipeline.jobs()
    }

    /// Attach a persistent artifact store (compile-once, serve-many): the
    /// pipeline looks compiles up on disk before running them and writes
    /// fresh results back, so a warm store boots a network with zero
    /// materializing compiles (the CLI's `--artifact-dir`).
    pub fn set_artifact_dir(&mut self, dir: &std::path::Path) -> Result<()> {
        self.pipeline.set_artifact_dir(dir)
    }

    /// The attached artifact directory, if any.
    pub fn artifact_dir(&self) -> Option<&std::path::Path> {
        self.pipeline.artifact_dir()
    }

    /// Predict the paradigm for a layer character *without compiling* —
    /// the fast decision that replaces double compilation. `Ok(None)` means
    /// the mode (Ideal) has no prejudgment and compiles both;
    /// [`SwitchError::MissingClassifier`] means Classifier mode has no
    /// trained model.
    pub fn prejudge(&self, ch: &LayerCharacter) -> Result<Option<Paradigm>, SwitchError> {
        self.policy.prejudge(ch)
    }

    /// Compile one layer under the system's policy.
    pub fn compile_layer(
        &mut self,
        proj: &Projection,
        n_source: usize,
        n_target: usize,
        params: LifParams,
    ) -> Result<CompiledLayer> {
        let job = CompileJob::new(proj, n_source, n_target, params);
        let run = self.pipeline.run(&self.policy, std::slice::from_ref(&job))?;
        self.stats = run.stats;
        Ok(run.layers.into_iter().next().expect("one job in, one layer out"))
    }

    /// Compile every projection of a network through the pipeline; returns
    /// layers in projection order plus the total PE count (layer PEs only;
    /// see [`network_pe_count`] for whole-machine accounting).
    pub fn compile_network(&mut self, net: &Network) -> Result<(Vec<CompiledLayer>, usize)> {
        let run = self.compile_network_report(net)?;
        let pes = run.layer_pes();
        Ok((run.layers, pes))
    }

    /// Like [`SwitchingSystem::compile_network`] but returns the full
    /// pipeline report (stats snapshot + per-layer timing).
    pub fn compile_network_report(&mut self, net: &Network) -> Result<PipelineRun> {
        let jobs = network_jobs(net);
        let run = self.pipeline.run(&self.policy, &jobs)?;
        self.stats = run.stats;
        Ok(run)
    }
}

/// One [`CompileJob`] per projection of a network, in projection order —
/// the job list both [`SwitchingSystem::compile_network_report`] and the
/// capacity-aware [`admission`] path feed the pipeline.
pub fn network_jobs(net: &Network) -> Vec<CompileJob<'_>> {
    net.projections
        .iter()
        .map(|proj| {
            let n_source = net.population(proj.source).n_neurons;
            let n_target = net.population(proj.target).n_neurons;
            let params = net.population(proj.target).lif_params().copied().unwrap_or_default();
            CompileJob::new(proj, n_source, n_target, params)
        })
        .collect()
}

/// Extra PEs needed to *host* spike-source populations.
///
/// Under the serial paradigm a spike source occupies ceil(n/255) PEs of its
/// own (sPyNNaker maps input populations to cores); the parallel paradigm
/// absorbs source handling into the dominant PE's input-spike buffer
/// (§III-B), so sources feeding only parallel layers cost nothing extra.
/// This is the accounting that makes the paper's whole-network comparison
/// (§IV-C, gesture model) favor switching.
pub fn source_hosting_pes(net: &Network, layers: &[CompiledLayer], pe: &PeSpec) -> usize {
    net.populations
        .iter()
        .filter(|p| p.is_source())
        .map(|p| {
            let consumed_serially = net.projections.iter().zip(layers).any(|(proj, l)| {
                proj.source == p.id && matches!(l, CompiledLayer::Serial(_))
            });
            if consumed_serially {
                p.n_neurons.div_ceil(pe.serial_neuron_cap)
            } else {
                0
            }
        })
        .sum()
}

/// Whole-machine PE count: layer PEs plus source hosting.
pub fn network_pe_count(net: &Network, layers: &[CompiledLayer], pe: &PeSpec) -> usize {
    layers.iter().map(|l| l.n_pes()).sum::<usize>() + source_hosting_pes(net, layers, pe)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::{generate_grid, SweepConfig};
    use crate::model::connector::{Connector, SynapseDraw};
    use crate::model::{NetworkBuilder, PopulationId, ProjectionId};
    use crate::rng::Rng;

    fn proj(n_src: usize, n_tgt: usize, d: f64, dl: u16, seed: u64) -> Projection {
        let mut rng = Rng::new(seed);
        Projection {
            id: ProjectionId(0),
            source: PopulationId(0),
            target: PopulationId(1),
            synapses: Connector::FixedProbability(d).build(
                n_src,
                n_tgt,
                SynapseDraw { delay_range: dl, w_max: 127, ..Default::default() },
                &mut rng,
            ),
            weight_scale: 1.0,
        }
    }

    #[test]
    fn forced_modes_compile_one_paradigm_each() {
        let p = proj(100, 100, 0.5, 4, 1);
        let mut s = SwitchingSystem::new(SwitchMode::ForceSerial, PeSpec::default());
        let l = s.compile_layer(&p, 100, 100, LifParams::default()).unwrap();
        assert_eq!(l.paradigm(), Paradigm::Serial);
        assert_eq!(s.stats.total_compiles(), 1);

        let mut pm = SwitchingSystem::new(SwitchMode::ForceParallel, PeSpec::default());
        let l = pm.compile_layer(&p, 100, 100, LifParams::default()).unwrap();
        assert_eq!(l.paradigm(), Paradigm::Parallel);
    }

    #[test]
    fn ideal_compiles_both_and_picks_cheaper() {
        let p = proj(255, 255, 1.0, 1, 2); // parallel-friendly corner
        let mut sys = SwitchingSystem::new(SwitchMode::Ideal, PeSpec::default());
        let l = sys.compile_layer(&p, 255, 255, LifParams::default()).unwrap();
        assert_eq!(sys.stats.total_compiles(), 2);
        assert!(sys.stats.discarded_dtcm > 0, "one result must be thrown away");
        assert_eq!(l.paradigm(), Paradigm::Parallel);
    }

    #[test]
    fn ideal_mode_has_no_prejudgment() {
        let sys = SwitchingSystem::new(SwitchMode::Ideal, PeSpec::default());
        assert_eq!(sys.prejudge(&LayerCharacter::new(10, 10, 0.5, 1)), Ok(None));
    }

    #[test]
    fn classifier_mode_compiles_once_and_tracks_ideal() {
        // Train on a medium grid, then verify the switcher compiles exactly
        // one paradigm per layer and agrees with ideal often.
        let ds = generate_grid(&SweepConfig::medium(), &PeSpec::default(), WdmConfig::default());
        let mut sys = SwitchingSystem::train_adaboost(&ds, 60, PeSpec::default());
        let mut ideal = SwitchingSystem::new(SwitchMode::Ideal, PeSpec::default());

        let mut agree = 0;
        let cases: Vec<(usize, usize, f64, u16)> =
            vec![(255, 255, 1.0, 1), (255, 255, 0.1, 16), (100, 400, 0.5, 8), (400, 100, 0.9, 2)];
        for (i, &(ns, nt, d, dl)) in cases.iter().enumerate() {
            let p = proj(ns, nt, d, dl, 50 + i as u64);
            let l = sys.compile_layer(&p, ns, nt, LifParams::default()).unwrap();
            let li = ideal.compile_layer(&p, ns, nt, LifParams::default()).unwrap();
            agree += usize::from(l.paradigm() == li.paradigm());
        }
        assert_eq!(sys.stats.total_compiles(), cases.len(), "one compile per layer");
        assert_eq!(ideal.stats.total_compiles(), 2 * cases.len());
        assert!(agree >= 3, "classifier should usually match ideal, got {agree}/4");
    }

    fn demo_network() -> Network {
        let mut b = NetworkBuilder::new(9);
        let inp = b.spike_source("in", 200);
        let hid = b.lif_population("hid", 100, LifParams::default());
        let out = b.lif_population("out", 10, LifParams::default());
        b.project(
            inp,
            hid,
            Connector::FixedProbability(0.3),
            SynapseDraw { delay_range: 4, w_max: 127, ..Default::default() },
            0.01,
        );
        b.project(
            hid,
            out,
            Connector::FixedProbability(0.8),
            SynapseDraw { delay_range: 2, w_max: 127, ..Default::default() },
            0.01,
        );
        b.build()
    }

    #[test]
    fn compile_network_sums_pes() {
        let net = demo_network();
        let mut sys = SwitchingSystem::new(SwitchMode::Ideal, PeSpec::default());
        let (layers, pes) = sys.compile_network(&net).unwrap();
        assert_eq!(layers.len(), 2);
        assert_eq!(pes, layers.iter().map(|l| l.n_pes()).sum::<usize>());
    }

    #[test]
    fn compile_network_is_jobs_invariant() {
        // The pipeline contract at network level: any worker count produces
        // layer-for-layer identical outputs and identical stats.
        let net = demo_network();
        let mut seq = SwitchingSystem::new(SwitchMode::Ideal, PeSpec::default());
        seq.set_jobs(1);
        let (layers_seq, pes_seq) = seq.compile_network(&net).unwrap();

        let mut par = SwitchingSystem::new(SwitchMode::Ideal, PeSpec::default());
        par.set_jobs(4);
        let (layers_par, pes_par) = par.compile_network(&net).unwrap();

        assert_eq!(pes_seq, pes_par);
        assert_eq!(seq.stats, par.stats);
        for (a, b) in layers_seq.iter().zip(&layers_par) {
            assert_eq!(a.paradigm(), b.paradigm());
            assert_eq!(a.n_pes(), b.n_pes());
            assert_eq!(a.total_dtcm(), b.total_dtcm());
        }
    }

    #[test]
    fn compile_network_report_times_every_layer() {
        let net = demo_network();
        let mut sys = SwitchingSystem::new(SwitchMode::ForceSerial, PeSpec::default());
        let run = sys.compile_network_report(&net).unwrap();
        assert_eq!(run.layer_nanos.len(), run.layers.len());
        assert!(run.wall_nanos > 0);
    }

    #[test]
    fn classifier_mode_without_model_errors() {
        // Converted from a should_panic test: the missing model is now a
        // typed error surfaced through the system (and the pipeline).
        let mut sys = SwitchingSystem::new(SwitchMode::Classifier, PeSpec::default());
        assert_eq!(
            sys.prejudge(&LayerCharacter::new(10, 10, 0.5, 1)),
            Err(SwitchError::MissingClassifier)
        );
        // Compiling through the pipeline surfaces the same error instead of
        // panicking a worker thread.
        let p = proj(50, 50, 0.5, 2, 77);
        let err = sys.compile_layer(&p, 50, 50, LifParams::default()).unwrap_err();
        assert!(err.to_string().contains("trained classifier"), "{err:#}");
    }
}
