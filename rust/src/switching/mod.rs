//! The fast-switching compilation system — the paper's contribution (§IV).
//!
//! "We train the classifier to prejudge a better paradigm before compiling
//! instead of making the decision afterward, saving a great amount of
//! compiling time and RAM space on the host PC."
//!
//! [`SwitchingSystem`] wraps the deployed classifier (AdaBoost by default)
//! and compiles each layer only under the predicted paradigm. The
//! alternatives the evaluation compares against:
//! * [`SwitchMode::ForceSerial`] / [`SwitchMode::ForceParallel`] — the two
//!   single-paradigm systems (Fig. 5 blue/green lines);
//! * [`SwitchMode::Ideal`] — compile **both**, keep the cheaper (Fig. 5
//!   pink line; what the paper's label collection does, at 2× compile cost);
//! * [`SwitchMode::Classifier`] — the fast-switching system (purple line).

pub mod placement;

pub use placement::Placement;

use crate::classifier::{AdaBoost, Classifier};
use crate::dataset::Dataset;
use crate::hardware::PeSpec;
use crate::model::{LayerCharacter, LifParams, Network, Projection};
use crate::paradigm::parallel::{compile_parallel, ParallelCompiled, WdmConfig};
use crate::paradigm::serial::{compile_serial, SerialCompiled};
use crate::paradigm::Paradigm;
use anyhow::Result;

/// How the system chooses a paradigm per layer.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SwitchMode {
    ForceSerial,
    ForceParallel,
    /// Compile both paradigms, keep the cheaper one (slow, 2× host RAM).
    Ideal,
    /// Prejudge with the trained classifier, compile only the winner.
    Classifier,
}

/// A compiled layer under whichever paradigm was selected.
#[derive(Clone, Debug)]
pub enum CompiledLayer {
    Serial(SerialCompiled),
    Parallel(ParallelCompiled),
}

impl CompiledLayer {
    pub fn paradigm(&self) -> Paradigm {
        match self {
            CompiledLayer::Serial(_) => Paradigm::Serial,
            CompiledLayer::Parallel(_) => Paradigm::Parallel,
        }
    }

    pub fn n_pes(&self) -> usize {
        match self {
            CompiledLayer::Serial(c) => c.n_pes(),
            CompiledLayer::Parallel(c) => c.n_pes(),
        }
    }

    pub fn total_dtcm(&self) -> usize {
        match self {
            CompiledLayer::Serial(c) => c.total_dtcm(),
            CompiledLayer::Parallel(c) => c.total_dtcm(),
        }
    }

    pub fn character(&self) -> &LayerCharacter {
        match self {
            CompiledLayer::Serial(c) => &c.character,
            CompiledLayer::Parallel(c) => &c.character,
        }
    }
}

/// Compile-effort accounting (the quantity the paper's fast switching
/// saves: how many paradigm compilations actually ran).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CompileStats {
    pub serial_compiles: usize,
    pub parallel_compiles: usize,
    /// Peak bytes of *discarded* compilation results (the "RAM crisis on
    /// the host PC" term: Ideal mode materializes both and throws one away).
    pub discarded_dtcm: usize,
}

impl CompileStats {
    pub fn total_compiles(&self) -> usize {
        self.serial_compiles + self.parallel_compiles
    }
}

/// The classifier-integrated switching system.
pub struct SwitchingSystem {
    pub mode: SwitchMode,
    pub classifier: Option<Box<dyn Classifier>>,
    pub pe: PeSpec,
    pub wdm_config: WdmConfig,
    pub stats: CompileStats,
}

impl SwitchingSystem {
    /// A system in the given mode without a classifier (panics if asked to
    /// prejudge). Use [`SwitchingSystem::with_classifier`] for
    /// `SwitchMode::Classifier`.
    pub fn new(mode: SwitchMode, pe: PeSpec) -> Self {
        SwitchingSystem {
            mode,
            classifier: None,
            pe,
            wdm_config: WdmConfig::default(),
            stats: CompileStats::default(),
        }
    }

    /// The deployed configuration: prejudge with a trained classifier.
    pub fn with_classifier(classifier: Box<dyn Classifier>, pe: PeSpec) -> Self {
        SwitchingSystem {
            mode: SwitchMode::Classifier,
            classifier: Some(classifier),
            pe,
            wdm_config: WdmConfig::default(),
            stats: CompileStats::default(),
        }
    }

    /// Train an AdaBoost prejudger on a labeled dataset and deploy it
    /// (the paper's final system).
    pub fn train_adaboost(dataset: &Dataset, n_rounds: usize, pe: PeSpec) -> Self {
        let (x, y) = dataset.xy();
        let mut ab = AdaBoost::new(n_rounds);
        ab.train(&x, &y);
        Self::with_classifier(Box::new(ab), pe)
    }

    /// Predict the paradigm for a layer character *without compiling* —
    /// the fast decision that replaces double compilation.
    pub fn prejudge(&self, ch: &LayerCharacter) -> Paradigm {
        match self.mode {
            SwitchMode::ForceSerial => Paradigm::Serial,
            SwitchMode::ForceParallel => Paradigm::Parallel,
            SwitchMode::Ideal => {
                panic!("Ideal mode has no prejudgment; it compiles both")
            }
            SwitchMode::Classifier => {
                let c = self
                    .classifier
                    .as_ref()
                    .expect("Classifier mode requires a trained classifier");
                Paradigm::from_label(c.predict(&ch.features()))
            }
        }
    }

    /// Compile one layer under the system's policy.
    pub fn compile_layer(
        &mut self,
        proj: &Projection,
        n_source: usize,
        n_target: usize,
        params: LifParams,
    ) -> Result<CompiledLayer> {
        let pe = self.pe;
        let wdm_config = self.wdm_config;
        let compile_s = |stats: &mut CompileStats| -> Result<SerialCompiled> {
            stats.serial_compiles += 1;
            compile_serial(proj, n_source, n_target, params, &pe)
        };
        let compile_p = |stats: &mut CompileStats| -> Result<ParallelCompiled> {
            stats.parallel_compiles += 1;
            compile_parallel(proj, n_source, n_target, params, &pe, wdm_config)
        };
        match self.mode {
            SwitchMode::ForceSerial => Ok(CompiledLayer::Serial(compile_s(&mut self.stats)?)),
            SwitchMode::ForceParallel => {
                Ok(CompiledLayer::Parallel(compile_p(&mut self.stats)?))
            }
            SwitchMode::Ideal => {
                let s = compile_s(&mut self.stats)?;
                let p = compile_p(&mut self.stats)?;
                // Compare per-layer costs the way the dataset labels do:
                // serial additionally charges source-hosting PEs
                // (ceil(n_source/255)); ties go to serial.
                let s_pes = s.n_pes() + n_source.div_ceil(pe.serial_neuron_cap);
                if p.n_pes() < s_pes {
                    self.stats.discarded_dtcm += s.total_dtcm();
                    Ok(CompiledLayer::Parallel(p))
                } else {
                    self.stats.discarded_dtcm += p.total_dtcm();
                    Ok(CompiledLayer::Serial(s))
                }
            }
            SwitchMode::Classifier => {
                let ch = LayerCharacter::of_projection(proj, n_source, n_target);
                match self.prejudge(&ch) {
                    Paradigm::Serial => Ok(CompiledLayer::Serial(compile_s(&mut self.stats)?)),
                    Paradigm::Parallel => {
                        Ok(CompiledLayer::Parallel(compile_p(&mut self.stats)?))
                    }
                }
            }
        }
    }

    /// Compile every projection of a network; returns layers in projection
    /// order plus the total PE count (layer PEs only; see
    /// [`network_pe_count`] for whole-machine accounting).
    pub fn compile_network(&mut self, net: &Network) -> Result<(Vec<CompiledLayer>, usize)> {
        let mut layers = Vec::with_capacity(net.projections.len());
        for proj in &net.projections {
            let n_source = net.population(proj.source).n_neurons;
            let n_target = net.population(proj.target).n_neurons;
            let params = net
                .population(proj.target)
                .lif_params()
                .copied()
                .unwrap_or_default();
            layers.push(self.compile_layer(proj, n_source, n_target, params)?);
        }
        let pes = layers.iter().map(|l| l.n_pes()).sum();
        Ok((layers, pes))
    }
}

/// Extra PEs needed to *host* spike-source populations.
///
/// Under the serial paradigm a spike source occupies ceil(n/255) PEs of its
/// own (sPyNNaker maps input populations to cores); the parallel paradigm
/// absorbs source handling into the dominant PE's input-spike buffer
/// (§III-B), so sources feeding only parallel layers cost nothing extra.
/// This is the accounting that makes the paper's whole-network comparison
/// (§IV-C, gesture model) favor switching.
pub fn source_hosting_pes(net: &Network, layers: &[CompiledLayer], pe: &PeSpec) -> usize {
    net.populations
        .iter()
        .filter(|p| p.is_source())
        .map(|p| {
            let consumed_serially = net.projections.iter().zip(layers).any(|(proj, l)| {
                proj.source == p.id && matches!(l, CompiledLayer::Serial(_))
            });
            if consumed_serially {
                p.n_neurons.div_ceil(pe.serial_neuron_cap)
            } else {
                0
            }
        })
        .sum()
}

/// Whole-machine PE count: layer PEs plus source hosting.
pub fn network_pe_count(net: &Network, layers: &[CompiledLayer], pe: &PeSpec) -> usize {
    layers.iter().map(|l| l.n_pes()).sum::<usize>() + source_hosting_pes(net, layers, pe)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::{generate_grid, SweepConfig};
    use crate::model::connector::{Connector, SynapseDraw};
    use crate::model::{NetworkBuilder, PopulationId, ProjectionId};
    use crate::rng::Rng;

    fn proj(n_src: usize, n_tgt: usize, d: f64, dl: u16, seed: u64) -> Projection {
        let mut rng = Rng::new(seed);
        Projection {
            id: ProjectionId(0),
            source: PopulationId(0),
            target: PopulationId(1),
            synapses: Connector::FixedProbability(d).build(
                n_src,
                n_tgt,
                SynapseDraw { delay_range: dl, w_max: 127, ..Default::default() },
                &mut rng,
            ),
            weight_scale: 1.0,
        }
    }

    #[test]
    fn forced_modes_compile_one_paradigm_each() {
        let p = proj(100, 100, 0.5, 4, 1);
        let mut s = SwitchingSystem::new(SwitchMode::ForceSerial, PeSpec::default());
        let l = s.compile_layer(&p, 100, 100, LifParams::default()).unwrap();
        assert_eq!(l.paradigm(), Paradigm::Serial);
        assert_eq!(s.stats.total_compiles(), 1);

        let mut pm = SwitchingSystem::new(SwitchMode::ForceParallel, PeSpec::default());
        let l = pm.compile_layer(&p, 100, 100, LifParams::default()).unwrap();
        assert_eq!(l.paradigm(), Paradigm::Parallel);
    }

    #[test]
    fn ideal_compiles_both_and_picks_cheaper() {
        let p = proj(255, 255, 1.0, 1, 2); // parallel-friendly corner
        let mut sys = SwitchingSystem::new(SwitchMode::Ideal, PeSpec::default());
        let l = sys.compile_layer(&p, 255, 255, LifParams::default()).unwrap();
        assert_eq!(sys.stats.total_compiles(), 2);
        assert!(sys.stats.discarded_dtcm > 0, "one result must be thrown away");
        assert_eq!(l.paradigm(), Paradigm::Parallel);
    }

    #[test]
    fn classifier_mode_compiles_once_and_tracks_ideal() {
        // Train on a medium grid, then verify the switcher compiles exactly
        // one paradigm per layer and agrees with ideal often.
        let ds = generate_grid(&SweepConfig::medium(), &PeSpec::default(), WdmConfig::default());
        let mut sys = SwitchingSystem::train_adaboost(&ds, 60, PeSpec::default());
        let mut ideal = SwitchingSystem::new(SwitchMode::Ideal, PeSpec::default());

        let mut agree = 0;
        let cases: Vec<(usize, usize, f64, u16)> =
            vec![(255, 255, 1.0, 1), (255, 255, 0.1, 16), (100, 400, 0.5, 8), (400, 100, 0.9, 2)];
        for (i, &(ns, nt, d, dl)) in cases.iter().enumerate() {
            let p = proj(ns, nt, d, dl, 50 + i as u64);
            let l = sys.compile_layer(&p, ns, nt, LifParams::default()).unwrap();
            let li = ideal.compile_layer(&p, ns, nt, LifParams::default()).unwrap();
            agree += usize::from(l.paradigm() == li.paradigm());
        }
        assert_eq!(sys.stats.total_compiles(), cases.len(), "one compile per layer");
        assert_eq!(ideal.stats.total_compiles(), 2 * cases.len());
        assert!(agree >= 3, "classifier should usually match ideal, got {agree}/4");
    }

    #[test]
    fn compile_network_sums_pes() {
        let mut b = NetworkBuilder::new(9);
        let inp = b.spike_source("in", 200);
        let hid = b.lif_population("hid", 100, LifParams::default());
        let out = b.lif_population("out", 10, LifParams::default());
        b.project(
            inp,
            hid,
            Connector::FixedProbability(0.3),
            SynapseDraw { delay_range: 4, w_max: 127, ..Default::default() },
            0.01,
        );
        b.project(
            hid,
            out,
            Connector::FixedProbability(0.8),
            SynapseDraw { delay_range: 2, w_max: 127, ..Default::default() },
            0.01,
        );
        let net = b.build();
        let mut sys = SwitchingSystem::new(SwitchMode::Ideal, PeSpec::default());
        let (layers, pes) = sys.compile_network(&net).unwrap();
        assert_eq!(layers.len(), 2);
        assert_eq!(pes, layers.iter().map(|l| l.n_pes()).sum::<usize>());
    }

    #[test]
    #[should_panic(expected = "requires a trained classifier")]
    fn classifier_mode_without_model_panics() {
        let sys = SwitchingSystem::new(SwitchMode::Classifier, PeSpec::default());
        sys.prejudge(&LayerCharacter::new(10, 10, 0.5, 1));
    }
}
